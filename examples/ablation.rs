//! Mini Table 2 ablation (M1–M7) on one model: swing x generator x
//! latent-optimization x GENIE-M, at W2A4 where the gaps are widest.
//!
//!   cargo run --release --example ablation [model]

use anyhow::Result;
use genie::coordinator::{
    distill, eval_quantized, pretrain::teacher_or_pretrain, quantize,
    DistillCfg, DistillMode, Metrics, PretrainCfg, QuantCfg,
};
use genie::data::Dataset;
use genie::runtime::{ModelRt, Runtime};

fn main() -> Result<()> {
    let model = std::env::args().nth(1).unwrap_or_else(|| "toy".into());
    let rt = Runtime::cpu()?;
    let mrt = ModelRt::load(&rt, "artifacts", &model)?;
    let dataset = Dataset::load("artifacts")?;
    let mut metrics = Metrics::new();
    let teacher = teacher_or_pretrain(
        &mrt, &dataset, &PretrainCfg { steps: 400, ..Default::default() },
        std::path::Path::new("runs"), &mut metrics,
    )?;

    let arms: [(&str, DistillMode, bool, bool); 7] = [
        ("M1 zeroq           ", DistillMode::Direct, false, false),
        ("M2 zeroq+GENIE-M   ", DistillMode::Direct, false, true),
        ("M3 zeroq+swing     ", DistillMode::Direct, true, false),
        ("M4 GBA             ", DistillMode::Gba, false, false),
        ("M5 gen+z           ", DistillMode::Genie, false, false),
        ("M6 gen+z+swing     ", DistillMode::Genie, true, false),
        ("M7 GENIE (full)    ", DistillMode::Genie, true, true),
    ];
    for (name, mode, swing, genie_m) in arms {
        let dcfg = DistillCfg { mode, swing, samples: 64, steps: 100,
                                ..Default::default() };
        let mut qcfg = QuantCfg { wbits: 2, abits: 4, steps_per_block: 100,
                                  ..Default::default() };
        if !genie_m {
            qcfg = qcfg.adaround();
        }
        let images = distill(&mrt, &teacher, &dcfg, &mut metrics)?.images;
        let qstate = quantize(&mrt, &teacher, &images, &qcfg, &mut metrics)?;
        let acc = eval_quantized(&mrt, &teacher, &qstate, &dataset)?;
        println!("{name} W2A4: {:.2}%", acc * 100.0);
    }
    Ok(())
}

//! Few-shot quantization on real calibration data (the Table 5 setting):
//! GENIE-M vs the AdaRound baseline, with and without QDrop, at W2A4.
//!
//!   cargo run --release --example fsq_real_data [model] [samples]

use anyhow::Result;
use genie::coordinator::{
    eval_fp32, eval_quantized, pretrain::teacher_or_pretrain, quantize,
    Metrics, PretrainCfg, QuantCfg,
};
use genie::data::Dataset;
use genie::runtime::{ModelRt, Runtime};
use genie::tensor::Pcg32;

fn main() -> Result<()> {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let model = args.first().map(String::as_str).unwrap_or("resnet14");
    let samples: usize = args.get(1).and_then(|s| s.parse().ok()).unwrap_or(128);

    let rt = Runtime::cpu()?;
    let mrt = ModelRt::load(&rt, "artifacts", model)?;
    let dataset = Dataset::load("artifacts")?;
    let mut metrics = Metrics::new();
    let teacher = teacher_or_pretrain(
        &mrt, &dataset, &PretrainCfg { steps: 800, ..Default::default() },
        std::path::Path::new("runs"), &mut metrics,
    )?;
    println!("{model} FP32 top-1: {:.2}%",
             eval_fp32(&mrt, &teacher, &dataset)? * 100.0);

    let mut rng = Pcg32::new(0xf5a);
    let (calib, _) = dataset.calibration(&mut rng, samples);
    let base = QuantCfg { wbits: 2, abits: 4, steps_per_block: 150,
                          ..Default::default() };
    let arms = [
        ("AdaRound+NoDrop", base.clone().adaround().no_drop()),
        ("AdaRound+QDrop ", base.clone().adaround()),
        ("GENIE-M +NoDrop", base.clone().no_drop()),
        ("GENIE-M +QDrop ", base.clone()),
    ];
    for (name, q) in arms {
        let qstate = quantize(&mrt, &teacher, &calib, &q, &mut metrics)?;
        let acc = eval_quantized(&mrt, &teacher, &qstate, &dataset)?;
        println!("{name}  W2A4 ({samples} real imgs): {:.2}%", acc * 100.0);
    }
    Ok(())
}

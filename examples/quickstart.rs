//! Quickstart: the smallest end-to-end GENIE run (toy model, one distilled
//! batch, W4A4). ~1 minute on a single CPU core.
//!
//!   make artifacts && cargo run --release --example quickstart

use anyhow::Result;
use genie::coordinator::{
    eval_fp32, pretrain::teacher_or_pretrain, zsq, DistillCfg, Metrics,
    PretrainCfg, QuantCfg,
};
use genie::data::Dataset;
use genie::runtime::{ModelRt, Runtime};

fn main() -> Result<()> {
    let rt = Runtime::cpu()?;
    let mrt = ModelRt::load(&rt, "artifacts", "toy")?;
    let dataset = Dataset::load("artifacts")?;
    let mut metrics = Metrics::new();

    // FP32 teacher (cached under runs/)
    let pcfg = PretrainCfg { steps: 200, ..Default::default() };
    let teacher = teacher_or_pretrain(
        &mrt, &dataset, &pcfg, std::path::Path::new("runs"), &mut metrics,
    )?;
    println!("teacher FP32 top-1: {:.2}%",
             eval_fp32(&mrt, &teacher, &dataset)? * 100.0);

    // zero-shot quantization: GENIE-D data + GENIE-M W4A4
    let dcfg = DistillCfg { samples: 64, steps: 80, ..Default::default() };
    let qcfg = QuantCfg { steps_per_block: 80, ..Default::default() };
    let out = zsq(&mrt, &teacher, &dataset, &dcfg, &qcfg, &mut metrics)?;
    out.print("quickstart");
    Ok(())
}

//! Quickstart: the smallest end-to-end GENIE run (toy model, one distilled
//! batch, W4A4). ~1 minute on a single CPU core.
//!
//!   make artifacts && cargo run --release --example quickstart

use anyhow::Result;
use genie::artifacts::ArtifactCache;
use genie::coordinator::{
    eval_fp32, teacher_cached, zsq, DistillCfg, Metrics, PretrainCfg,
    QuantCfg,
};
use genie::data::Dataset;
use genie::runtime::{ModelRt, Runtime};

fn main() -> Result<()> {
    let rt = Runtime::cpu()?;
    let mrt = ModelRt::load(&rt, "artifacts", "toy")?;
    let dataset = Dataset::load("artifacts")?;
    let mut metrics = Metrics::new();
    // every stage is a content-addressed artifact under cache/ — a
    // second identical run loads them instead of recomputing
    let mut cache = ArtifactCache::open("cache", true, false)?;

    // FP32 teacher (cached by config content)
    let pcfg = PretrainCfg { steps: 200, ..Default::default() };
    let teacher = teacher_cached(&mrt, &dataset, &pcfg, &mut cache, &mut metrics)?;
    println!("teacher FP32 top-1: {:.2}%",
             eval_fp32(&mrt, &teacher, &dataset)? * 100.0);

    // zero-shot quantization: GENIE-D data + GENIE-M W4A4
    let dcfg = DistillCfg { samples: 64, steps: 80, ..Default::default() };
    let qcfg = QuantCfg { steps_per_block: 80, ..Default::default() };
    let out = zsq(&mrt, &teacher, &dataset, &dcfg, &qcfg, &mut cache, &mut metrics)?;
    out.print("quickstart");
    let s = cache.stats();
    println!("cache: {} hits, {} misses (re-run to see the hits)", s.hits, s.misses);
    Ok(())
}

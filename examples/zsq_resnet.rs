//! End-to-end driver (the DESIGN.md validation run): pretrain the
//! resnet14 teacher on the procedural dataset, log its loss curve, run the
//! full GENIE zero-shot pipeline at W4A4 and W2A4, and report FP32 vs
//! quantized accuracy plus phase wall-clock. Results are recorded in
//! EXPERIMENTS.md.
//!
//!   cargo run --release --example zsq_resnet [model] [distill_steps] [quant_steps]

use anyhow::Result;
use genie::artifacts::ArtifactCache;
use genie::coordinator::{
    eval_fp32, pretrain::teacher_or_pretrain, zsq, DistillCfg, Metrics,
    PretrainCfg, QuantCfg,
};
use genie::data::Dataset;
use genie::runtime::{ModelRt, Runtime};

fn main() -> Result<()> {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let model = args.first().map(String::as_str).unwrap_or("resnet14");
    let dsteps: usize = args.get(1).and_then(|s| s.parse().ok()).unwrap_or(150);
    let qsteps: usize = args.get(2).and_then(|s| s.parse().ok()).unwrap_or(150);

    let rt = Runtime::cpu()?;
    let mrt = ModelRt::load(&rt, "artifacts", model)?;
    let dataset = Dataset::load("artifacts")?;
    let mut metrics =
        Metrics::with_dir(format!("runs/example_zsq_{model}"))?;

    let pcfg = PretrainCfg { steps: 800, ..Default::default() };
    let teacher = teacher_or_pretrain(
        &mrt, &dataset, &pcfg, std::path::Path::new("runs"), &mut metrics,
    )?;
    let fp = eval_fp32(&mrt, &teacher, &dataset)?;
    println!("{model} FP32 top-1: {:.2}%", fp * 100.0);
    if let Some(series) = metrics.series("pretrain/loss") {
        println!("pretrain loss curve (step, loss):");
        for (s, v) in series {
            println!("  {s:>5}  {v:.4}");
        }
    }

    let mut cache = ArtifactCache::open("cache", true, false)?;
    for (w, a) in [(4u32, 4u32), (2, 4)] {
        let dcfg = DistillCfg { samples: 128, steps: dsteps, ..Default::default() };
        let qcfg = QuantCfg {
            wbits: w, abits: a, steps_per_block: qsteps, ..Default::default()
        };
        let out = zsq(&mrt, &teacher, &dataset, &dcfg, &qcfg, &mut cache, &mut metrics)?;
        out.print(&format!("zsq W{w}A{a}"));
    }
    metrics.flush()?;
    println!("loss curves flushed to runs/example_zsq_{model}/");
    Ok(())
}

# L2: paper's jax model fwd/bwd, calling kernels.*
import jax.numpy as jnp

"""Entrypoint catalogue: the named, ordered argument/result specs of every
AOT graph, shared between the lowering driver (aot.py) and manifest.json.

An Entry is a flat-positional function plus (name, dtype, shape) lists for
arguments and results. The rust runtime wires buffers purely by these
names (rust/src/runtime/manifest.rs)."""

import jax
import jax.numpy as jnp

from . import generator, ir, steps

F32, I32, U32 = "f32", "i32", "u32"
_NP = {F32: jnp.float32, I32: jnp.int32, U32: jnp.uint32}

# Baked batch sizes (manifest `batch`): rust slices its data accordingly.
BATCH = {"train": 64, "distill": 64, "recon": 32, "eval": 256, "stats": 64}


class Entry:
    def __init__(self, name, fn, args, results):
        self.name = name
        self.fn = fn
        self.args = args          # [(name, dtype, shape)]
        self.results = results    # [(name, dtype, shape)]

    def avals(self):
        return [jax.ShapeDtypeStruct(tuple(sh), _NP[dt])
                for _, dt, sh in self.args]


def _f(name, shape):
    return (name, F32, list(shape))


def _named(specs, prefix=""):
    return [_f(prefix + n, sh) for n, sh in specs]


def _dict_from(flat, specs, prefix=""):
    return {n: a for (n, _), a in zip(specs, flat)}


def _bounds_shapes(model, batch):
    params, bn = model.init(jax.random.PRNGKey(0))
    x = jnp.zeros((batch,) + tuple(model.image), jnp.float32)
    bounds = steps.collect_teacher(model, params, bn, x)
    return [list(b.shape) for b in bounds]


def build_entries(model):
    """All entrypoints for one model. Returns (entries, meta)."""
    pspecs = model.param_specs()
    bnspecs = model.bn_specs()
    qspecs = model.qstate_specs()
    gspecs = generator.param_specs(model.image)
    img = tuple(model.image)
    nb = len(model.blocks)
    bshapes = _bounds_shapes(model, BATCH["recon"])
    entries = []

    n_p, n_bn, n_q, n_g = len(pspecs), len(bnspecs), len(qspecs), len(gspecs)

    # ---- train_step ----
    def train_fn(*flat):
        i = 0
        params = _dict_from(flat[i:i + n_p], pspecs); i += n_p
        bn = _dict_from(flat[i:i + n_bn], bnspecs); i += n_bn
        ms = _dict_from(flat[i:i + n_p], pspecs); i += n_p
        vs = _dict_from(flat[i:i + n_p], pspecs); i += n_p
        t, x, y, lr = flat[i:i + 4]
        p2, bn2, m2, v2, loss, acc = steps.train_step(
            model, params, bn, ms, vs, t, x, y, lr)
        return (tuple(p2[n] for n, _ in pspecs)
                + tuple(bn2[n] for n, _ in bnspecs)
                + tuple(m2[n] for n, _ in pspecs)
                + tuple(v2[n] for n, _ in pspecs) + (loss, acc))

    bt = BATCH["train"]
    args = (_named(pspecs) + _named(bnspecs)
            + _named(pspecs, "am.") + _named(pspecs, "av.")
            + [_f("t", ()), _f("x", (bt,) + img), ("y", I32, [bt]),
               _f("lr", ())])
    res = (_named(pspecs) + _named(bnspecs) + _named(pspecs, "am.")
           + _named(pspecs, "av.") + [_f("loss", ()), _f("acc", ())])
    entries.append(Entry("train_step", train_fn, args, res))

    # ---- eval_batch ----
    be = BATCH["eval"]

    def eval_fn(*flat):
        params = _dict_from(flat[:n_p], pspecs)
        bn = _dict_from(flat[n_p:n_p + n_bn], bnspecs)
        return (steps.eval_batch(model, params, bn, flat[-1]),)

    entries.append(Entry(
        "eval_batch", eval_fn,
        _named(pspecs) + _named(bnspecs) + [_f("x", (be,) + img)],
        [_f("logits", (be, model.nclasses))]))

    # ---- act_stats ----
    bs = BATCH["stats"]
    nql = len(model.quant_layers())

    def stats_fn(*flat):
        params = _dict_from(flat[:n_p], pspecs)
        bn = _dict_from(flat[n_p:n_p + n_bn], bnspecs)
        return (steps.act_stats(model, params, bn, flat[-1]),)

    entries.append(Entry(
        "act_stats", stats_fn,
        _named(pspecs) + _named(bnspecs) + [_f("x", (bs,) + img)],
        [_f("act_stats", (nql,))]))

    # ---- collect_teacher ----
    br = BATCH["recon"]

    def collect_t_fn(*flat):
        params = _dict_from(flat[:n_p], pspecs)
        bn = _dict_from(flat[n_p:n_p + n_bn], bnspecs)
        return tuple(steps.collect_teacher(model, params, bn, flat[-1]))

    entries.append(Entry(
        "collect_teacher", collect_t_fn,
        _named(pspecs) + _named(bnspecs) + [_f("x", (br,) + img)],
        [_f(f"bound.{i}", sh) for i, sh in enumerate(bshapes)]))

    # ---- collect_student ----
    def collect_s_fn(*flat):
        i = 0
        params = _dict_from(flat[i:i + n_p], pspecs); i += n_p
        bn = _dict_from(flat[i:i + n_bn], bnspecs); i += n_bn
        qs = _dict_from(flat[i:i + n_q], qspecs); i += n_q
        x, key = flat[i], steps.unwrap_key(flat[i + 1])
        return tuple(steps.collect_student(model, params, bn, qs, x, key))

    entries.append(Entry(
        "collect_student", collect_s_fn,
        _named(pspecs) + _named(bnspecs) + _named(qspecs)
        + [_f("x", (br,) + img), ("key", U32, [2])],
        [_f(f"bound.{i}", sh) for i, sh in enumerate(bshapes)]))

    # ---- eval_quant ----
    def eval_q_fn(*flat):
        i = 0
        params = _dict_from(flat[i:i + n_p], pspecs); i += n_p
        bn = _dict_from(flat[i:i + n_bn], bnspecs); i += n_bn
        qs = _dict_from(flat[i:i + n_q], qspecs); i += n_q
        return (steps.eval_quant(model, params, bn, qs, flat[i]),)

    entries.append(Entry(
        "eval_quant", eval_q_fn,
        _named(pspecs) + _named(bnspecs) + _named(qspecs)
        + [_f("x", (be,) + img)],
        [_f("logits", (be, model.nclasses))]))

    # ---- gen_init / gen_images ----
    def gen_init_fn(raw):
        gp = generator.init(steps.unwrap_key(raw), model.image)
        return tuple(gp[n] for n, _ in gspecs)

    entries.append(Entry("gen_init", gen_init_fn, [("key", U32, [2])],
                         _named(gspecs)))

    bd = BATCH["distill"]

    def gen_images_fn(*flat):
        gp = _dict_from(flat[:n_g], gspecs)
        return (generator.apply(gp, flat[-1], model.image),)

    entries.append(Entry(
        "gen_images", gen_images_fn,
        _named(gspecs) + [_f("z", (bd, generator.LATENT))],
        [_f("images", (bd,) + img)]))

    # ---- distill steps ----
    for swing in (True, False):
        tag = "swing" if swing else "noswing"

        def genie_fn(*flat, _swing=swing):
            i = 0
            gp = _dict_from(flat[i:i + n_g], gspecs); i += n_g
            gm = _dict_from(flat[i:i + n_g], gspecs); i += n_g
            gv = _dict_from(flat[i:i + n_g], gspecs); i += n_g
            z, zm, zv, t = flat[i:i + 4]; i += 4
            params = _dict_from(flat[i:i + n_p], pspecs); i += n_p
            bn = _dict_from(flat[i:i + n_bn], bnspecs); i += n_bn
            key, lr_g, lr_z = steps.unwrap_key(flat[i]), flat[i + 1], flat[i + 2]
            gp2, gm2, gv2, z2, zm2, zv2, loss = steps.distill_genie_step(
                model, gp, gm, gv, z, zm, zv, t, params, bn, key, lr_g,
                lr_z, _swing)
            return (tuple(gp2[n] for n, _ in gspecs)
                    + tuple(gm2[n] for n, _ in gspecs)
                    + tuple(gv2[n] for n, _ in gspecs)
                    + (z2, zm2, zv2, loss))

        zsh = (bd, generator.LATENT)
        args = (_named(gspecs) + _named(gspecs, "am.") + _named(gspecs, "av.")
                + [_f("z", zsh), _f("zm", zsh), _f("zv", zsh), _f("t", ())]
                + _named(pspecs) + _named(bnspecs)
                + [("key", U32, [2]), _f("lr_g", ()), _f("lr_z", ())])
        res = (_named(gspecs) + _named(gspecs, "am.") + _named(gspecs, "av.")
               + [_f("z", zsh), _f("zm", zsh), _f("zv", zsh), _f("loss", ())])
        entries.append(Entry(f"distill_genie_{tag}", genie_fn, args, res))

        def direct_fn(*flat, _swing=swing):
            i = 0
            x, xm, xv, t = flat[i:i + 4]; i += 4
            params = _dict_from(flat[i:i + n_p], pspecs); i += n_p
            bn = _dict_from(flat[i:i + n_bn], bnspecs); i += n_bn
            key, lr = steps.unwrap_key(flat[i]), flat[i + 1]
            return steps.distill_direct_step(model, x, xm, xv, t, params,
                                             bn, key, lr, _swing)

        xsh = (bd,) + img
        args = ([_f("x", xsh), _f("xm", xsh), _f("xv", xsh), _f("t", ())]
                + _named(pspecs) + _named(bnspecs)
                + [("key", U32, [2]), _f("lr", ())])
        res = [_f("x", xsh), _f("xm", xsh), _f("xv", xsh), _f("loss", ())]
        entries.append(Entry(f"distill_direct_{tag}", direct_fn, args, res))

        def zaq_fn(*flat, _swing=swing):
            i = 0
            gp = _dict_from(flat[i:i + n_g], gspecs); i += n_g
            gm = _dict_from(flat[i:i + n_g], gspecs); i += n_g
            gv = _dict_from(flat[i:i + n_g], gspecs); i += n_g
            z, zm, zv, t = flat[i:i + 4]; i += 4
            params = _dict_from(flat[i:i + n_p], pspecs); i += n_p
            bn = _dict_from(flat[i:i + n_bn], bnspecs); i += n_bn
            key, lr_g, lr_z = steps.unwrap_key(flat[i]), flat[i + 1], flat[i + 2]
            wp, ap = flat[i + 3], flat[i + 4]
            gp2, gm2, gv2, z2, zm2, zv2, loss = steps.distill_zaq_step(
                model, gp, gm, gv, z, zm, zv, t, params, bn, key, lr_g,
                lr_z, wp, ap, _swing)
            return (tuple(gp2[n] for n, _ in gspecs)
                    + tuple(gm2[n] for n, _ in gspecs)
                    + tuple(gv2[n] for n, _ in gspecs)
                    + (z2, zm2, zv2, loss))

        # genie's signature plus the student proxy's Min-Max bit-widths
        args = (_named(gspecs) + _named(gspecs, "am.") + _named(gspecs, "av.")
                + [_f("z", zsh), _f("zm", zsh), _f("zv", zsh), _f("t", ())]
                + _named(pspecs) + _named(bnspecs)
                + [("key", U32, [2]), _f("lr_g", ()), _f("lr_z", ()),
                   _f("wp", ()), _f("ap", ())])
        res = (_named(gspecs) + _named(gspecs, "am.") + _named(gspecs, "av.")
               + [_f("z", zsh), _f("zm", zsh), _f("zv", zsh), _f("loss", ())])
        entries.append(Entry(f"distill_zaq_{tag}", zaq_fn, args, res))

    # ---- qat_step / eval_qat (netwise Min-Max QAT baseline) ----
    def qat_fn(*flat):
        i = 0
        sp = _dict_from(flat[i:i + n_p], pspecs); i += n_p
        ms = _dict_from(flat[i:i + n_p], pspecs); i += n_p
        vs = _dict_from(flat[i:i + n_p], pspecs); i += n_p
        t = flat[i]; i += 1
        tp = _dict_from(flat[i:i + n_p], pspecs); i += n_p
        bn = _dict_from(flat[i:i + n_bn], bnspecs); i += n_bn
        x, lr, wp, ap = flat[i:i + 4]
        p2, m2, v2, loss = steps.qat_step(model, sp, ms, vs, t, tp, bn, x,
                                          lr, wp, ap)
        return (tuple(p2[n] for n, _ in pspecs)
                + tuple(m2[n] for n, _ in pspecs)
                + tuple(v2[n] for n, _ in pspecs) + (loss,))

    args = (_named(pspecs, "s.") + _named(pspecs, "am.")
            + _named(pspecs, "av.") + [_f("t", ())]
            + _named(pspecs) + _named(bnspecs)
            + [_f("x", (bt,) + img), _f("lr", ()), _f("wp", ()),
               _f("ap", ())])
    res = (_named(pspecs, "s.") + _named(pspecs, "am.")
           + _named(pspecs, "av.") + [_f("loss", ())])
    entries.append(Entry("qat_step", qat_fn, args, res))

    def eval_qat_fn(*flat):
        i = 0
        sp = _dict_from(flat[i:i + n_p], pspecs); i += n_p
        bn = _dict_from(flat[i:i + n_bn], bnspecs); i += n_bn
        x, wp, ap = flat[i:i + 3]
        return (steps.eval_qat(model, sp, bn, x, wp, ap),)

    entries.append(Entry(
        "eval_qat", eval_qat_fn,
        _named(pspecs, "s.") + _named(bnspecs)
        + [_f("x", (be,) + img), _f("wp", ()), _f("ap", ())],
        [_f("logits", (be, model.nclasses))]))

    # ---- quant_step_{b} ----
    for b in range(nb):
        bp = model.block_param_specs(b)
        bbn = model.block_bn_specs(b)
        bq = model.block_qstate_specs(b)
        learn = model.qstate_learnable(block=b)
        lspecs = [(n, sh) for n, sh in bq if n in learn]
        n_bp, n_bbn, n_bq, n_l = len(bp), len(bbn), len(bq), len(lspecs)

        def qstep_fn(*flat, _b=b, _bp=bp, _bbn=bbn, _bq=bq, _ls=lspecs):
            i = 0
            params = _dict_from(flat[i:i + len(_bp)], _bp); i += len(_bp)
            bn = _dict_from(flat[i:i + len(_bbn)], _bbn); i += len(_bbn)
            qs = _dict_from(flat[i:i + len(_bq)], _bq); i += len(_bq)
            ms = _dict_from(flat[i:i + len(_ls)], _ls); i += len(_ls)
            vs = _dict_from(flat[i:i + len(_ls)], _ls); i += len(_ls)
            (t, x_in, y_ref, key, lr_sw, lr_v, lr_sa, lam, beta,
             drop_p) = flat[i:i + 10]
            out, m2, v2, loss, rec = steps.quant_block_step(
                model, _b, params, bn, qs, ms, vs, t, x_in, y_ref,
                steps.unwrap_key(key), lr_sw, lr_v, lr_sa, lam, beta,
                drop_p)
            return (tuple(out[n] for n, _ in _ls)
                    + tuple(m2[n] for n, _ in _ls)
                    + tuple(v2[n] for n, _ in _ls) + (loss, rec))

        args = (_named(bp) + _named(bbn) + _named(bq)
                + _named(lspecs, "am.") + _named(lspecs, "av.")
                + [_f("t", ()), _f("x_in", bshapes[b]),
                   _f("y_ref", bshapes[b + 1]), ("key", U32, [2]),
                   _f("lr_sw", ()), _f("lr_v", ()), _f("lr_sa", ()),
                   _f("lam", ()), _f("beta", ()), _f("drop_p", ())])
        res = (_named(lspecs) + _named(lspecs, "am.") + _named(lspecs, "av.")
               + [_f("loss", ()), _f("rec", ())])
        entries.append(Entry(f"quant_step_{b}", qstep_fn, args, res))

    meta = {
        "model": model.name,
        "image": list(model.image),
        "num_classes": model.nclasses,
        "num_blocks": nb,
        "latent": generator.LATENT,
        "batch": BATCH,
        "params": [[n, list(sh)] for n, sh in pspecs],
        "bn": [[n, list(sh)] for n, sh in bnspecs],
        "qstate": [[n, list(sh)] for n, sh in qspecs],
        "gen_params": [[n, list(sh)] for n, sh in gspecs],
        "quant_layers": [
            {"name": ql.name, "w_shape": list(ql.w_shape),
             "out_ch": ql.out_ch, "flat_k": ql.flat_k, "block": ql.block}
            for ql in model.quant_layers()],
        "learnable": {str(b): model.qstate_learnable(block=b)
                      for b in range(nb)},
        "bounds": bshapes,
    }
    return entries, meta

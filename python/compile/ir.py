"""Op-list IR and functional interpreter for the CNN model zoo.

A model is a list of blocks; a block is a list of ops. One interpreter
executes the IR in every mode the GENIE pipeline needs:

  * FP32 train   (batch-norm batch stats + running-stat update)
  * FP32 eval    (running stats)
  * BNS collect  (eval normalization, per-BN batch stats recorded via the
                  pallas bns_stats kernel -- the Eq. 5 loss inputs)
  * swing        (stride-n convs replaced by swing convolution, 3.1.1)
  * block collect(record activations at block boundaries for BRECQ-style
                  reconstruction)
  * quantized    (GENIE-M fake-quant weights + LSQ activations + QDrop),
                  soft (optimization) or hard (eval) softbits
  * act stats    (mean |x| at every activation-quant site, for LSQ s_a init)

Blocks never share residual state, so block-wise reconstruction simply runs
a block's op list on a cached boundary activation.

All parameters / BN state / quant state are flat name->array dicts so the
rust coordinator can wire buffers generically from the manifest.
"""

from dataclasses import dataclass, field
from typing import List, Optional

import jax
import jax.numpy as jnp

from .kernels import (bns_stats, fake_quant, fake_quant_hard, lsq_quant,
                      swing_select)

BN_EPS = 1e-5


@dataclass
class Conv:
    name: str
    cin: int
    cout: int
    k: int
    stride: int = 1
    groups: int = 1


@dataclass
class BN:
    name: str
    c: int


@dataclass
class Relu:
    cap: Optional[float] = None  # None -> relu, 6.0 -> relu6


@dataclass
class Save:
    tag: str


@dataclass
class Merge:
    """current += run(ops, saved[tag]); optional projection shortcut."""
    tag: str
    ops: List = field(default_factory=list)


@dataclass
class GAP:
    pass


@dataclass
class Dense:
    name: str
    cin: int
    cout: int


@dataclass
class QuantLayer:
    """One weight+activation quantization site (a conv or dense)."""
    name: str
    w_shape: tuple
    out_ch: int
    flat_k: int
    block: int


class ModelDef:
    def __init__(self, name, image, nclasses, blocks):
        self.name = name
        self.image = image          # (H, W, C)
        self.nclasses = nclasses
        self.blocks = blocks        # list[(block_name, [ops])]

    # -- static structure ---------------------------------------------------

    def _walk(self, ops=None):
        if ops is None:
            for _, bops in self.blocks:
                yield from self._walk(bops)
            return
        for op in ops:
            yield op
            if isinstance(op, Merge):
                yield from self._walk(op.ops)

    def param_specs(self):
        specs = []
        for op in self._walk():
            if isinstance(op, Conv):
                kshape = (op.k, op.k, op.cin // op.groups, op.cout)
                specs.append((f"{op.name}.w", kshape))
            elif isinstance(op, BN):
                specs.append((f"{op.name}.gamma", (op.c,)))
                specs.append((f"{op.name}.beta", (op.c,)))
            elif isinstance(op, Dense):
                specs.append((f"{op.name}.w", (op.cin, op.cout)))
                specs.append((f"{op.name}.b", (op.cout,)))
        return specs

    def bn_specs(self):
        specs = []
        for op in self._walk():
            if isinstance(op, BN):
                specs.append((f"{op.name}.mean", (op.c,)))
                specs.append((f"{op.name}.var", (op.c,)))
        return specs

    def bn_names(self):
        return [op.name for op in self._walk() if isinstance(op, BN)]

    def quant_layers(self):
        out = []
        for bi, (_, bops) in enumerate(self.blocks):
            for op in self._walk(bops):
                if isinstance(op, Conv):
                    ksh = (op.k, op.k, op.cin // op.groups, op.cout)
                    flat_k = op.k * op.k * (op.cin // op.groups)
                    out.append(QuantLayer(op.name, ksh, op.cout, flat_k, bi))
                elif isinstance(op, Dense):
                    out.append(QuantLayer(op.name, (op.cin, op.cout),
                                          op.cout, op.cin, bi))
        return out

    def qstate_specs(self):
        """Flat quant-state tensors, rust-initialized (Eq. 6 / LSQ init)."""
        specs = []
        for ql in self.quant_layers():
            o, k = ql.out_ch, ql.flat_k
            specs += [
                (f"q.{ql.name}.sw", (o,)), (f"q.{ql.name}.v", (o, k)),
                (f"q.{ql.name}.b", (o, k)), (f"q.{ql.name}.zp", (o,)),
                (f"q.{ql.name}.wn", ()), (f"q.{ql.name}.wp", ()),
                (f"q.{ql.name}.sa", ()), (f"q.{ql.name}.an", ()),
                (f"q.{ql.name}.ap", ()),
            ]
        return specs

    def qstate_learnable(self, block=None):
        """Names of learnable quant tensors (sw, v, sa), optionally per block."""
        names = []
        for ql in self.quant_layers():
            if block is not None and ql.block != block:
                continue
            names += [f"q.{ql.name}.sw", f"q.{ql.name}.v", f"q.{ql.name}.sa"]
        return names

    def _specs_for(self, ops):
        specs = []
        for op in self._walk(ops):
            if isinstance(op, Conv):
                specs.append((f"{op.name}.w",
                              (op.k, op.k, op.cin // op.groups, op.cout)))
            elif isinstance(op, BN):
                specs.append((f"{op.name}.gamma", (op.c,)))
                specs.append((f"{op.name}.beta", (op.c,)))
            elif isinstance(op, Dense):
                specs.append((f"{op.name}.w", (op.cin, op.cout)))
                specs.append((f"{op.name}.b", (op.cout,)))
        return specs

    def block_param_specs(self, b):
        return self._specs_for(self.blocks[b][1])

    def block_bn_specs(self, b):
        specs = []
        for op in self._walk(self.blocks[b][1]):
            if isinstance(op, BN):
                specs.append((f"{op.name}.mean", (op.c,)))
                specs.append((f"{op.name}.var", (op.c,)))
        return specs

    def block_qstate_specs(self, b):
        prefixes = [f"q.{ql.name}." for ql in self.quant_layers()
                    if ql.block == b]
        return [(n, sh) for n, sh in self.qstate_specs()
                if any(n.startswith(p) for p in prefixes)]

    # -- initialization -----------------------------------------------------

    def init(self, key):
        params, bn_state = {}, {}
        for name, shape in self.param_specs():
            key, sub = jax.random.split(key)
            if name.endswith(".gamma"):
                params[name] = jnp.ones(shape, jnp.float32)
            elif name.endswith(".beta") or name.endswith(".b"):
                params[name] = jnp.zeros(shape, jnp.float32)
            else:
                fan_in = 1
                for d in shape[:-1]:
                    fan_in *= d
                std = (2.0 / max(fan_in, 1)) ** 0.5
                params[name] = std * jax.random.normal(sub, shape, jnp.float32)
        for name, shape in self.bn_specs():
            bn_state[name] = (jnp.zeros(shape, jnp.float32)
                              if name.endswith(".mean")
                              else jnp.ones(shape, jnp.float32))
        return params, bn_state


class Ctx:
    """Per-forward mutable interpreter context."""

    def __init__(self, params, bn_state, *, train=False, momentum=0.1,
                 swing_key=None, collect_bns=False, qctx=None, hard=False,
                 drop_key=None, drop_p=None, act_stats=False, minmax=None):
        self.params = params
        self.bn_state = dict(bn_state)
        self.train = train
        self.momentum = momentum
        self.swing_key = swing_key
        self.collect_bns = collect_bns
        self.bns = []
        self.qctx = qctx
        self.hard = hard
        self.drop_key = drop_key
        self.drop_p = drop_p
        self.act_stats = act_stats
        # minmax: (wp, ap) scalars -> netwise Min-Max QAT fake-quant mode
        # (the GDFQ/AIT-style quantizer of the Table 4 baseline).
        self.minmax = minmax
        self.stats = []
        self.new_bn = {}
        self._fold = 0

    def next_key(self, base):
        self._fold += 1
        return jax.random.fold_in(base, self._fold)


def _conv(x, w, stride, groups):
    return jax.lax.conv_general_dilated(
        x, w, (stride, stride), "SAME",
        dimension_numbers=("NHWC", "HWIO", "NHWC"),
        feature_group_count=groups)


def _quant_weight(ctx, name, w, out_ch):
    q = ctx.qctx
    fq = fake_quant_hard if ctx.hard else fake_quant
    wq = fq(q[f"q.{name}.sw"], q[f"q.{name}.v"], q[f"q.{name}.b"],
            q[f"q.{name}.zp"], q[f"q.{name}.wn"], q[f"q.{name}.wp"])
    return jnp.moveaxis(wq.reshape((w.shape[-1],) + w.shape[:-1]), 0, -1)


def _minmax_w(w, wp):
    """Per-tensor symmetric Min-Max weight fake-quant (Eq. 3), STE via
    the lsq kernel with a stop-gradient step size."""
    s = jax.lax.stop_gradient(jnp.max(jnp.abs(w)) / wp + 1e-8)
    return lsq_quant(w, s, -wp - 1.0, wp)


def _minmax_a(x, ap):
    """Dynamic per-batch symmetric activation fake-quant."""
    s = jax.lax.stop_gradient(jnp.max(jnp.abs(x)) / ap + 1e-8)
    return lsq_quant(x, s, -ap - 1.0, ap)


def _quant_act(ctx, name, x):
    q = ctx.qctx
    xq = lsq_quant(x, q[f"q.{name}.sa"], q[f"q.{name}.an"], q[f"q.{name}.ap"])
    if ctx.drop_key is not None:
        # QDrop: each element keeps its FP value with probability drop_p.
        keep_fp = jax.random.bernoulli(
            ctx.next_key(ctx.drop_key), ctx.drop_p, x.shape)
        xq = jnp.where(keep_fp, x, xq)
    return xq


def run_ops(ops, x, ctx):
    saved = {}
    for op in ops:
        if isinstance(op, Conv):
            w = ctx.params[f"{op.name}.w"]
            if ctx.act_stats:
                ctx.stats.append(jnp.mean(jnp.abs(x)))
            if ctx.minmax is not None:
                w = _minmax_w(w, ctx.minmax[0])
                x = _minmax_a(x, ctx.minmax[1])
            if ctx.qctx is not None:
                w = _quant_weight(ctx, op.name, w, op.cout)
                x = _quant_act(ctx, op.name, x)
            if ctx.swing_key is not None and op.stride > 1:
                pad = op.stride - 1
                xp = jnp.pad(x, ((0, 0), (pad, pad), (pad, pad), (0, 0)),
                             mode="reflect")
                off = jax.random.randint(
                    ctx.next_key(ctx.swing_key), (2,), 0, 2 * pad + 1)
                x = swing_select(xp, off, x.shape[1], x.shape[2])
            x = _conv(x, w, op.stride, op.groups)
        elif isinstance(op, BN):
            gamma = ctx.params[f"{op.name}.gamma"]
            beta = ctx.params[f"{op.name}.beta"]
            rm = ctx.bn_state[f"{op.name}.mean"]
            rv = ctx.bn_state[f"{op.name}.var"]
            if ctx.train or ctx.collect_bns:
                bm, bv = bns_stats(x)
                if ctx.collect_bns:
                    ctx.bns.append((bm, bv))
            if ctx.train:
                mean, var = bm, bv
                mom = ctx.momentum
                ctx.new_bn[f"{op.name}.mean"] = (1 - mom) * rm + mom * bm
                ctx.new_bn[f"{op.name}.var"] = (1 - mom) * rv + mom * bv
            else:
                mean, var = rm, rv
            x = (x - mean) * jax.lax.rsqrt(var + BN_EPS) * gamma + beta
        elif isinstance(op, Relu):
            x = jnp.maximum(x, 0.0)
            if op.cap is not None:
                x = jnp.minimum(x, op.cap)
        elif isinstance(op, Save):
            saved[op.tag] = x
        elif isinstance(op, Merge):
            x = x + run_ops(op.ops, saved[op.tag], ctx)
        elif isinstance(op, GAP):
            x = jnp.mean(x, axis=(1, 2))
        elif isinstance(op, Dense):
            w = ctx.params[f"{op.name}.w"]
            b = ctx.params[f"{op.name}.b"]
            if ctx.act_stats:
                ctx.stats.append(jnp.mean(jnp.abs(x)))
            if ctx.minmax is not None:
                w = _minmax_w(w, ctx.minmax[0])
                x = _minmax_a(x, ctx.minmax[1])
            if ctx.qctx is not None:
                wq = _quant_weight_dense(ctx, op.name, w)
                x = _quant_act(ctx, op.name, x)
                x = x @ wq + b
            else:
                x = x @ w + b
        else:
            raise TypeError(f"unknown op {op!r}")
    return x


def _quant_weight_dense(ctx, name, w):
    q = ctx.qctx
    fq = fake_quant_hard if ctx.hard else fake_quant
    wq = fq(q[f"q.{name}.sw"], q[f"q.{name}.v"], q[f"q.{name}.b"],
            q[f"q.{name}.zp"], q[f"q.{name}.wn"], q[f"q.{name}.wp"])
    return wq.T  # stored [cout, cin] -> [cin, cout]


def forward(model, params, bn_state, x, *, collect_blocks=False, **kw):
    ctx = Ctx(params, bn_state, **kw)
    bounds = [x]
    for _, bops in model.blocks:
        x = run_ops(bops, x, ctx)
        bounds.append(x)
    if collect_blocks:
        return x, ctx, bounds
    return x, ctx


def forward_block(model, b, params, bn_state, x, **kw):
    ctx = Ctx(params, bn_state, **kw)
    return run_ops(model.blocks[b][1], x, ctx), ctx

"""GTS1: the tiny named-tensor binary interchange format.

Used for everything that crosses the python(build) / rust(runtime) boundary
besides HLO: initial parameters, the synthetic dataset, checkpoints. The
rust mirror lives in rust/src/store. Layout (little-endian):

  b"GTS1"  u32 count
  per tensor: u16 name_len | name utf8 | u8 dtype (0=f32,1=i32,2=u32)
              u8 ndim | u32 dims[ndim] | u64 nbytes | raw data
"""

import struct

import numpy as np

MAGIC = b"GTS1"
_DTYPES = {0: np.float32, 1: np.int32, 2: np.uint32}
_CODES = {np.dtype(np.float32): 0, np.dtype(np.int32): 1,
          np.dtype(np.uint32): 2}


def save(path, tensors):
    """tensors: list[(name, np.ndarray)] (order preserved)."""
    with open(path, "wb") as f:
        f.write(MAGIC)
        f.write(struct.pack("<I", len(tensors)))
        for name, arr in tensors:
            arr = np.ascontiguousarray(arr)
            code = _CODES[arr.dtype]
            nb = name.encode()
            f.write(struct.pack("<H", len(nb)))
            f.write(nb)
            f.write(struct.pack("<BB", code, arr.ndim))
            for d in arr.shape:
                f.write(struct.pack("<I", d))
            raw = arr.tobytes()
            f.write(struct.pack("<Q", len(raw)))
            f.write(raw)


def load(path):
    """Returns list[(name, np.ndarray)]."""
    out = []
    with open(path, "rb") as f:
        assert f.read(4) == MAGIC, f"{path}: bad magic"
        (count,) = struct.unpack("<I", f.read(4))
        for _ in range(count):
            (nlen,) = struct.unpack("<H", f.read(2))
            name = f.read(nlen).decode()
            code, ndim = struct.unpack("<BB", f.read(2))
            shape = tuple(struct.unpack("<I", f.read(4))[0]
                          for _ in range(ndim))
            (nbytes,) = struct.unpack("<Q", f.read(8))
            arr = np.frombuffer(f.read(nbytes), dtype=_DTYPES[code])
            out.append((name, arr.reshape(shape)))
    return out

"""Model zoo registry.

Scaled-down (16x16, 10-class) members of the same block families the paper
evaluates (DESIGN.md section 3): plain residual (resnet14 ~ ResNet-18),
bottleneck residual (resnet26b ~ ResNet-50), depthwise-separable
(mobilenetv1_t ~ MobileNet-b), inverted residual (mobilenetv2_t ~
MobileNetV2, mnasnet_t ~ MnasNet-1.0), plus `toy` for fast integration
tests. Every model has stride-2 convolutions -- the swing-conv target.
"""

from .resnet import resnet14, resnet26b, toy
from .mobilenet import mobilenetv1_t, mobilenetv2_t, mnasnet_t

ZOO = {
    "toy": toy,
    "resnet14": resnet14,
    "resnet26b": resnet26b,
    "mobilenetv1_t": mobilenetv1_t,
    "mobilenetv2_t": mobilenetv2_t,
    "mnasnet_t": mnasnet_t,
}


def get_model(name):
    return ZOO[name]()

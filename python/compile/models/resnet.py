"""Plain- and bottleneck-residual members of the zoo (He et al. families)."""

from ..ir import BN, Conv, Dense, GAP, Merge, ModelDef, Relu, Save

IMAGE = (16, 16, 3)
NCLASSES = 10


def _res(pfx, cin, cout, stride):
    """Basic residual unit: 3x3 -> 3x3 with (projected) identity."""
    short = []
    if stride != 1 or cin != cout:
        short = [Conv(f"{pfx}.sc", cin, cout, 1, stride),
                 BN(f"{pfx}.scbn", cout)]
    return [
        Save(f"{pfx}.in"),
        Conv(f"{pfx}.c1", cin, cout, 3, stride), BN(f"{pfx}.bn1", cout), Relu(),
        Conv(f"{pfx}.c2", cout, cout, 3, 1), BN(f"{pfx}.bn2", cout),
        Merge(f"{pfx}.in", short), Relu(),
    ]


def _bneck(pfx, cin, mid, cout, stride):
    """Bottleneck unit: 1x1 reduce -> 3x3 -> 1x1 expand."""
    short = []
    if stride != 1 or cin != cout:
        short = [Conv(f"{pfx}.sc", cin, cout, 1, stride),
                 BN(f"{pfx}.scbn", cout)]
    return [
        Save(f"{pfx}.in"),
        Conv(f"{pfx}.c1", cin, mid, 1, 1), BN(f"{pfx}.bn1", mid), Relu(),
        Conv(f"{pfx}.c2", mid, mid, 3, stride), BN(f"{pfx}.bn2", mid), Relu(),
        Conv(f"{pfx}.c3", mid, cout, 1, 1), BN(f"{pfx}.bn3", cout),
        Merge(f"{pfx}.in", short), Relu(),
    ]


def toy():
    """Two-block micro-model for integration tests."""
    b0 = [Conv("stem", 3, 8, 3, 1), BN("stembn", 8), Relu()] + _res("r1", 8, 16, 2)
    b1 = _res("r2", 16, 16, 1) + [GAP(), Dense("fc", 16, NCLASSES)]
    return ModelDef("toy", IMAGE, NCLASSES, [("b0", b0), ("b1", b1)])


def resnet14():
    """stem + 3 stages x 2 basic blocks (16/32/64 channels)."""
    b0 = ([Conv("stem", 3, 16, 3, 1), BN("stembn", 16), Relu()]
          + _res("s1.0", 16, 16, 1) + _res("s1.1", 16, 16, 1))
    b1 = _res("s2.0", 16, 32, 2) + _res("s2.1", 32, 32, 1)
    b2 = (_res("s3.0", 32, 64, 2) + _res("s3.1", 64, 64, 1)
          + [GAP(), Dense("fc", 64, NCLASSES)])
    return ModelDef("resnet14", IMAGE, NCLASSES, [("b0", b0), ("b1", b1), ("b2", b2)])


def resnet26b():
    """Bottleneck variant (~ResNet-50 family) with 4x expansion."""
    b0 = ([Conv("stem", 3, 16, 3, 1), BN("stembn", 16), Relu()]
          + _bneck("s1.0", 16, 16, 64, 1) + _bneck("s1.1", 64, 16, 64, 1))
    b1 = _bneck("s2.0", 64, 32, 128, 2) + _bneck("s2.1", 128, 32, 128, 1)
    b2 = (_bneck("s3.0", 128, 64, 256, 2) + _bneck("s3.1", 256, 64, 256, 1)
          + [GAP(), Dense("fc", 256, NCLASSES)])
    return ModelDef("resnet26b", IMAGE, NCLASSES, [("b0", b0), ("b1", b1), ("b2", b2)])

"""Depthwise-separable and inverted-residual members of the zoo."""

from ..ir import BN, Conv, Dense, GAP, Merge, ModelDef, Relu, Save

IMAGE = (16, 16, 3)
NCLASSES = 10


def _ds(pfx, cin, cout, stride):
    """MobileNetV1 depthwise-separable unit: dw 3x3 + pw 1x1."""
    return [
        Conv(f"{pfx}.dw", cin, cin, 3, stride, groups=cin),
        BN(f"{pfx}.dwbn", cin), Relu(cap=6.0),
        Conv(f"{pfx}.pw", cin, cout, 1, 1), BN(f"{pfx}.pwbn", cout),
        Relu(cap=6.0),
    ]


def _ir(pfx, cin, cout, expand, stride, k=3):
    """MobileNetV2/MnasNet inverted residual: expand -> dw(k) -> project."""
    mid = cin * expand
    ops = []
    if expand != 1:
        ops += [Conv(f"{pfx}.ex", cin, mid, 1, 1), BN(f"{pfx}.exbn", mid),
                Relu(cap=6.0)]
    ops += [Conv(f"{pfx}.dw", mid, mid, k, stride, groups=mid),
            BN(f"{pfx}.dwbn", mid), Relu(cap=6.0),
            Conv(f"{pfx}.pr", mid, cout, 1, 1), BN(f"{pfx}.prbn", cout)]
    if stride == 1 and cin == cout:
        return [Save(f"{pfx}.in")] + ops + [Merge(f"{pfx}.in", [])]
    return ops


def mobilenetv1_t():
    b0 = ([Conv("stem", 3, 16, 3, 1), BN("stembn", 16), Relu(cap=6.0)]
          + _ds("d1", 16, 32, 1))
    b1 = _ds("d2", 32, 64, 2) + _ds("d3", 64, 64, 1)
    b2 = (_ds("d4", 64, 128, 2) + _ds("d5", 128, 128, 1)
          + [GAP(), Dense("fc", 128, NCLASSES)])
    return ModelDef("mobilenetv1_t", IMAGE, NCLASSES,
                    [("b0", b0), ("b1", b1), ("b2", b2)])


def mobilenetv2_t():
    b0 = ([Conv("stem", 3, 16, 3, 1), BN("stembn", 16), Relu(cap=6.0)]
          + _ir("i1", 16, 16, 1, 1))
    b1 = _ir("i2", 16, 24, 4, 2) + _ir("i3", 24, 24, 4, 1)
    b2 = (_ir("i4", 24, 40, 4, 2) + _ir("i5", 40, 40, 4, 1)
          + [Conv("head", 40, 128, 1, 1), BN("headbn", 128), Relu(cap=6.0),
             GAP(), Dense("fc", 128, NCLASSES)])
    return ModelDef("mobilenetv2_t", IMAGE, NCLASSES,
                    [("b0", b0), ("b1", b1), ("b2", b2)])


def mnasnet_t():
    """MnasNet flavour: mixes 3x3 and 5x5 inverted residuals, expand 3/6."""
    b0 = ([Conv("stem", 3, 16, 3, 1), BN("stembn", 16), Relu(cap=6.0)]
          + _ir("m1", 16, 16, 1, 1))
    b1 = _ir("m2", 16, 24, 3, 2, k=3) + _ir("m3", 24, 24, 3, 1, k=3)
    b2 = (_ir("m4", 24, 40, 6, 2, k=5) + _ir("m5", 40, 40, 6, 1, k=5)
          + [Conv("head", 40, 128, 1, 1), BN("headbn", 128), Relu(cap=6.0),
             GAP(), Dense("fc", 128, NCLASSES)])
    return ModelDef("mnasnet_t", IMAGE, NCLASSES,
                    [("b0", b0), ("b1", b1), ("b2", b2)])

"""AOT driver: lower every entrypoint of every requested model to HLO text
and emit the manifest + initial parameters + the synthetic dataset.

HLO *text* (not HloModuleProto.serialize) is the interchange format: jax
>= 0.5 emits protos with 64-bit instruction ids which xla_extension 0.5.1
(the version the published `xla` 0.1.6 rust crate links) rejects; the text
parser reassigns ids and round-trips cleanly. See /opt/xla-example.

Usage:  cd python && python -m compile.aot --out-dir ../artifacts \
            --models toy,resnet14,mobilenetv2_t
"""

import argparse
import json
import os
import time

import jax
import numpy as np
from jax._src.lib import xla_client as xc

from . import data, generator, tensorstore
from .entries import build_entries
from .models import ZOO, get_model


def to_hlo_text(lowered):
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True)
    return comp.as_hlo_text()


def build_dataset(out_dir, train_n, test_n):
    xs, ys = data.make_dataset(train_n, seed=1)
    xte, yte = data.make_dataset(test_n, seed=2)
    path = os.path.join(out_dir, "dataset.bin")
    tensorstore.save(path, [
        ("train_x", xs), ("train_y", ys), ("test_x", xte), ("test_y", yte),
    ])
    print(f"dataset: {path} ({train_n}+{test_n} images)")


def build_model(name, out_dir, seed=0):
    t0 = time.time()
    model = get_model(name)
    mdir = os.path.join(out_dir, name)
    os.makedirs(mdir, exist_ok=True)

    params, bn = model.init(jax.random.PRNGKey(seed))
    gen = generator.init(jax.random.PRNGKey(seed + 1), model.image)
    tensors = ([(n, np.asarray(v)) for n, v in params.items()]
               + [(n, np.asarray(v)) for n, v in bn.items()]
               + [(n, np.asarray(v)) for n, v in gen.items()])
    tensorstore.save(os.path.join(mdir, "init.bin"), tensors)

    entries, meta = build_entries(model)
    eps = {}
    for e in entries:
        t1 = time.time()
        # keep_unused: XLA must keep every manifest argument as an entry
        # parameter even if the graph ignores it (e.g. the classifier
        # head inside the BNS-loss distill graphs), or the rust-side
        # buffer count would not match the manifest.
        lowered = jax.jit(e.fn, keep_unused=True).lower(*e.avals())
        text = to_hlo_text(lowered)
        fname = f"{e.name}.hlo.txt"
        with open(os.path.join(mdir, fname), "w") as f:
            f.write(text)
        eps[e.name] = {"file": fname, "args": e.args, "results": e.results}
        print(f"  {name}/{e.name}: {len(text)//1024}KiB "
              f"({time.time()-t1:.1f}s)")
    meta["entrypoints"] = eps
    with open(os.path.join(mdir, "manifest.json"), "w") as f:
        json.dump(meta, f, indent=1)
    print(f"{name}: done in {time.time()-t0:.1f}s")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument("--models", default="toy,resnet14,mobilenetv2_t")
    ap.add_argument("--train-size", type=int, default=8192)
    ap.add_argument("--test-size", type=int, default=2048)
    ap.add_argument("--no-dataset", action="store_true")
    args = ap.parse_args()

    os.makedirs(args.out_dir, exist_ok=True)
    if not args.no_dataset:
        build_dataset(args.out_dir, args.train_size, args.test_size)
    for i, name in enumerate(args.models.split(",")):
        name = name.strip()
        if not name:
            continue
        assert name in ZOO, f"unknown model {name}; have {list(ZOO)}"
        build_model(name, args.out_dir, seed=i)


if __name__ == "__main__":
    main()

"""Stochastic stride-phase selection -- the core of swing convolution.

Swing conv (paper section 3.1.1, Figure 4) = reflection-pad the feature map
by (stride-1) on every side, crop back to the original size at a random
integer offset, then run the ordinary strided conv. This kernel is the crop:
an offset-indexed dynamic window over the padded map. The conv itself stays
in XLA (on TPU the MXU conv is already optimal; the paper's randomness lives
entirely in *which phase* the strided conv samples).

TPU shaping: the offset-window read is expressed as a dynamic slice of the
padded map (BlockSpec-style HBM->VMEM gather); backward scatters the
cotangent back into pad-space. interpret=True: see fake_quant.py.
"""

from functools import partial

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _crop_kernel(off_ref, x_ref, o_ref, *, out_h, out_w):
    oy = off_ref[0]
    ox = off_ref[1]
    o_ref[...] = pl.load(
        x_ref,
        (slice(None), pl.dslice(oy, out_h), pl.dslice(ox, out_w), slice(None)),
    )


@partial(jax.custom_vjp, nondiff_argnums=(2, 3))
def swing_select(xpad, off, out_h, out_w):
    """Pallas offset crop; semantics of ref.swing_select_ref."""
    return _swing_impl(xpad, off, out_h, out_w)


def _swing_impl(xpad, off, out_h, out_w):
    n, hp, wp, c = xpad.shape
    return pl.pallas_call(
        partial(_crop_kernel, out_h=out_h, out_w=out_w),
        out_shape=jax.ShapeDtypeStruct((n, out_h, out_w, c), xpad.dtype),
        interpret=True,
    )(off, xpad)


def _swing_fwd(xpad, off, out_h, out_w):
    return _swing_impl(xpad, off, out_h, out_w), (xpad, off)


def _swing_bwd(out_h, out_w, res, g):
    xpad, off = res
    d_x = jax.lax.dynamic_update_slice(
        jnp.zeros_like(xpad), g, (0, off[0], off[1], 0)
    )
    return d_x, jnp.zeros_like(off)


swing_select.defvjp(_swing_fwd, _swing_bwd)

"""Pure-jnp differentiable oracles for every Pallas kernel.

These define the *intended semantics* (forward values AND custom gradients)
of the L1 kernels. pytest compares each pallas kernel against its oracle for
both the forward pass and the vjp cotangents. The oracles themselves are used
nowhere in the AOT path -- kernels/*.py are.

Notation follows the paper (GENIE, Jeon et al.):
  h(V)  rectified sigmoid softbit (AdaRound / Eq. 10)
  Wq = s * (clip(B + h(V), n, p) - z)   GENIE-M soft weight quantizer
  B detached from s (Eq. 9-11) -- B and z are plain inputs here.
"""

from functools import partial

import jax
import jax.numpy as jnp

ZETA = 1.1
GAMMA = -0.1


def h_sigmoid(v):
    """Rectified sigmoid h(V) in [0, 1] (Louizos et al. / AdaRound)."""
    return jnp.clip(jax.nn.sigmoid(v) * (ZETA - GAMMA) + GAMMA, 0.0, 1.0)


def h_sigmoid_grad(v):
    """dh/dv, masked where the outer clip saturates."""
    sig = jax.nn.sigmoid(v)
    inner = sig * (ZETA - GAMMA) + GAMMA
    mask = ((inner > 0.0) & (inner < 1.0)).astype(v.dtype)
    return mask * (ZETA - GAMMA) * sig * (1.0 - sig)


def h_hard(v):
    """Hardened softbit: 1 where h(V) >= 0.5 else 0 (eval-time rounding)."""
    return (h_sigmoid(v) >= 0.5).astype(v.dtype)


# ---------------------------------------------------------------------------
# fake_quant: GENIE-M soft weight quantizer
# ---------------------------------------------------------------------------

@partial(jax.custom_vjp, nondiff_argnums=())
def fake_quant_ref(w_s, v, b, z, n, p):
    """Soft-quantized weights.

    w_s: [O]    learnable per-channel step size
    v:   [O,K]  softbits (learnable)
    b:   [O,K]  detached base integer grid  clip(floor(W/s0)+z0, n, p)
    z:   [O]    detached per-channel zero point
    n,p: []     integer-grid bounds as f32 scalars (runtime-configurable bits)
    """
    c = jnp.clip(b + h_sigmoid(v), n, p)
    return w_s[:, None] * (c - z[:, None])


def _fake_quant_fwd(w_s, v, b, z, n, p):
    soft = b + h_sigmoid(v)
    c = jnp.clip(soft, n, p)
    out = w_s[:, None] * (c - z[:, None])
    return out, (w_s, v, b, z, n, p, soft, c)


def _fake_quant_bwd(res, g):
    w_s, v, b, z, n, p, soft, c = res
    in_range = ((soft > n) & (soft < p)).astype(g.dtype)
    d_s = jnp.sum(g * (c - z[:, None]), axis=1)
    d_v = g * w_s[:, None] * in_range * h_sigmoid_grad(v)
    zeros_b = jnp.zeros_like(b)
    zeros_z = jnp.zeros_like(z)
    zero = jnp.zeros_like(n)
    return d_s, d_v, zeros_b, zeros_z, zero, zero


fake_quant_ref.defvjp(_fake_quant_fwd, _fake_quant_bwd)


def fake_quant_hard_ref(w_s, v, b, z, n, p):
    """Eval-time hard quantizer (not differentiated)."""
    c = jnp.clip(b + h_hard(v), n, p)
    return w_s[:, None] * (c - z[:, None])


# ---------------------------------------------------------------------------
# lsq_quant: LSQ activation fake-quant (per-tensor, symmetric)
# ---------------------------------------------------------------------------

@partial(jax.custom_vjp, nondiff_argnums=())
def lsq_quant_ref(x, s, qn, qp):
    """xq = s * clip(round(x/s), qn, qp); LSQ gradient for s, clipped STE for x."""
    return s * jnp.clip(jnp.round(x / s), qn, qp)


def _lsq_fwd(x, s, qn, qp):
    vv = x / s
    out = s * jnp.clip(jnp.round(vv), qn, qp)
    return out, (x, s, qn, qp, vv)


def _lsq_bwd(res, g):
    x, s, qn, qp, vv = res
    inside = (vv >= qn) & (vv <= qp)
    d_x = g * inside.astype(g.dtype)
    gs = 1.0 / jnp.sqrt(jnp.asarray(x.size, g.dtype) * jnp.maximum(qp, 1.0))
    per = jnp.where(vv < qn, qn, jnp.where(vv > qp, qp, jnp.round(vv) - vv))
    d_s = jnp.sum(g * per) * gs
    zero = jnp.zeros_like(qn)
    return d_x, d_s, zero, zero


lsq_quant_ref.defvjp(_lsq_fwd, _lsq_bwd)


# ---------------------------------------------------------------------------
# bns_stats: per-channel batch statistics over (N, H, W) of an NHWC tensor
# ---------------------------------------------------------------------------

@jax.custom_vjp
def bns_stats_ref(x):
    """Returns (mean[C], biased var[C]) over all but the channel axis."""
    m = jnp.mean(x, axis=(0, 1, 2))
    var = jnp.mean((x - m) ** 2, axis=(0, 1, 2))
    return m, var


def _bns_fwd(x):
    m = jnp.mean(x, axis=(0, 1, 2))
    var = jnp.mean((x - m) ** 2, axis=(0, 1, 2))
    return (m, var), (x, m)


def _bns_bwd(res, g):
    x, m = res
    gm, gv = g
    cnt = x.shape[0] * x.shape[1] * x.shape[2]
    inv = 1.0 / jnp.asarray(cnt, x.dtype)
    d_x = gm * inv + gv * 2.0 * (x - m) * inv
    return (d_x,)


bns_stats_ref.defvjp(_bns_fwd, _bns_bwd)


# ---------------------------------------------------------------------------
# soft_round_reg: AdaRound rounding regularizer sum(1 - |2h(V)-1|^beta)
# ---------------------------------------------------------------------------

@partial(jax.custom_vjp, nondiff_argnums=())
def soft_round_reg_ref(v, beta):
    hh = h_sigmoid(v)
    return jnp.sum(1.0 - jnp.abs(2.0 * hh - 1.0) ** beta)


def _reg_fwd(v, beta):
    hh = h_sigmoid(v)
    t = 2.0 * hh - 1.0
    return jnp.sum(1.0 - jnp.abs(t) ** beta), (v, beta, t)


def _reg_bwd(res, g):
    v, beta, t = res
    at = jnp.abs(t)
    # d/dt |t|^beta = beta * |t|^(beta-1) * sign(t); guard |t|=0.
    safe = jnp.maximum(at, 1e-12)
    d_t = -beta * safe ** (beta - 1.0) * jnp.sign(t)
    d_v = g * d_t * 2.0 * h_sigmoid_grad(v)
    return d_v, jnp.zeros_like(beta)


soft_round_reg_ref.defvjp(_reg_fwd, _reg_bwd)


# ---------------------------------------------------------------------------
# swing_select: stochastic stride-phase crop of a reflection-padded map
# ---------------------------------------------------------------------------

@partial(jax.custom_vjp, nondiff_argnums=(2, 3))
def swing_select_ref(xpad, off, out_h, out_w):
    """Crop out_h x out_w window at integer offsets off=[oy, ox] from xpad.

    xpad: [N, Hp, Wp, C] reflection-padded feature map
    off:  int32[2]
    """
    n, _, _, c = xpad.shape
    return jax.lax.dynamic_slice(
        xpad, (0, off[0], off[1], 0), (n, out_h, out_w, c)
    )


def _swing_fwd(xpad, off, out_h, out_w):
    out = swing_select_ref(xpad, off, out_h, out_w)
    return out, (xpad, off)


def _swing_bwd(out_h, out_w, res, g):
    xpad, off = res
    d_x = jax.lax.dynamic_update_slice(
        jnp.zeros_like(xpad), g, (0, off[0], off[1], 0)
    )
    return d_x, jnp.zeros_like(off)


swing_select_ref.defvjp(_swing_fwd, _swing_bwd)

"""Per-channel batch statistics (the BNS-loss reduction) as a Pallas kernel.

Computes mean[C] and biased var[C] of an NHWC tensor over (N, H, W), the
inner reduction of the paper's Eq. 5 BNS loss.

TPU shaping: NHWC is flattened to (M, C) with the channel axis minor so
the per-channel reduction vectorizes across lanes; a single program reduces
the whole (M_pad x C_pad) block to (sum, sum-of-squares) rows that the
wrapper turns into mean/var (sublane-tiled grids ran ~300x slower under
the sequential interpret-mode grid; EXPERIMENTS.md section Perf). Backward
is the analytic cotangent (cheap, pure jnp). interpret=True: see
fake_quant.py.
"""

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

ROW_TILE = 8
LANE_TILE = 128


def _part_kernel(x_ref, s1_ref, s2_ref):
    x = x_ref[...]
    s1_ref[...] = jnp.sum(x, axis=0)[None, :]
    s2_ref[...] = jnp.sum(x * x, axis=0)[None, :]


def _partial_sums(x2, rows_p, cols_p):
    return pl.pallas_call(
        _part_kernel,
        grid=(),
        in_specs=[pl.BlockSpec((rows_p, cols_p), lambda: (0, 0))],
        out_specs=[pl.BlockSpec((1, cols_p), lambda: (0, 0)),
                   pl.BlockSpec((1, cols_p), lambda: (0, 0))],
        out_shape=[jax.ShapeDtypeStruct((1, cols_p), x2.dtype),
                   jax.ShapeDtypeStruct((1, cols_p), x2.dtype)],
        interpret=True,
    )(x2)


@jax.custom_vjp
def bns_stats(x):
    """Pallas per-channel (mean, biased var); semantics of ref.bns_stats_ref."""
    return _bns_impl(x)


def _bns_impl(x):
    n, h, w, c = x.shape
    m_rows = n * h * w
    rows_p = -(-m_rows // ROW_TILE) * ROW_TILE
    cols_p = -(-c // LANE_TILE) * LANE_TILE
    x2 = x.reshape(m_rows, c)
    x2 = jnp.pad(x2, ((0, rows_p - m_rows), (0, cols_p - c)))
    s1, s2 = _partial_sums(x2, rows_p, cols_p)
    inv = 1.0 / jnp.asarray(m_rows, x.dtype)
    mean = jnp.sum(s1, axis=0)[:c] * inv
    ex2 = jnp.sum(s2, axis=0)[:c] * inv
    var = jnp.maximum(ex2 - mean * mean, 0.0)
    return mean, var


def _bns_fwd(x):
    m, v = _bns_impl(x)
    return (m, v), (x, m)


def _bns_bwd(res, g):
    x, m = res
    gm, gv = g
    cnt = x.shape[0] * x.shape[1] * x.shape[2]
    inv = 1.0 / jnp.asarray(cnt, x.dtype)
    d_x = gm * inv + gv * 2.0 * (x - m) * inv
    return (d_x,)


bns_stats.defvjp(_bns_fwd, _bns_bwd)

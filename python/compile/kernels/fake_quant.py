"""GENIE-M soft weight fake-quantizer as a Pallas kernel (L1 hot-spot).

Forward:  Wq = s * (clip(B + h(V), n, p) - z)       (paper Eq. 9-10)
Backward: Eq. 11 with B, z detached -- implemented as a custom_vjp whose
cotangents match `ref.fake_quant_ref` exactly.

TPU shaping: the weight matrix is padded to (8, 128) multiples and tiled
into (O_pad x 128) VMEM column blocks -- the grid walks lane tiles only.
Earlier revisions also tiled the row axis at 8 (grid = O/8 x K/128); in
interpret mode every grid program executes sequentially, which made the
AOT graphs ~300x slower end-to-end (EXPERIMENTS.md section Perf), and on a
real TPU fine row tiles under-utilize the 8x128 VPU anyway. Column blocks
of a few hundred KiB stay well inside the ~16 MiB VMEM budget (the
footprint estimate lives in DESIGN.md section Hardware-Adaptation).
interpret=True everywhere: CPU PJRT cannot execute Mosaic custom-calls.
"""

from functools import partial

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .ref import ZETA, GAMMA, h_sigmoid_grad

ROW_TILE = 8
LANE_TILE = 128


def _h(v):
    sig = 1.0 / (1.0 + jnp.exp(-v))
    return jnp.clip(sig * (ZETA - GAMMA) + GAMMA, 0.0, 1.0)


def _fwd_kernel(s_ref, v_ref, b_ref, z_ref, n_ref, p_ref, o_ref):
    n = n_ref[0]
    p = p_ref[0]
    soft = b_ref[...] + _h(v_ref[...])
    c = jnp.clip(soft, n, p)
    o_ref[...] = s_ref[...][:, None] * (c - z_ref[...][:, None])


def _bwd_kernel(s_ref, v_ref, b_ref, z_ref, n_ref, p_ref, g_ref,
                ds_part_ref, dv_ref):
    n = n_ref[0]
    p = p_ref[0]
    g = g_ref[...]
    soft = b_ref[...] + _h(v_ref[...])
    c = jnp.clip(soft, n, p)
    in_range = ((soft > n) & (soft < p)).astype(g.dtype)
    dv_ref[...] = g * s_ref[...][:, None] * in_range * h_sigmoid_grad(v_ref[...])
    # per-(row-tile, lane-tile) partial sum for d_s; reduced by the wrapper.
    ds_part_ref[...] = jnp.sum(g * (c - z_ref[...][:, None]), axis=1)[:, None]


def _pad2(a, rows, cols):
    return jnp.pad(a, ((0, rows - a.shape[0]), (0, cols - a.shape[1])))


def _pad1(a, rows):
    return jnp.pad(a, ((0, rows - a.shape[0]),))


def _tiles(o, k):
    op = -(-o // ROW_TILE) * ROW_TILE
    kp = -(-k // LANE_TILE) * LANE_TILE
    return op, kp


def _row_spec(op):
    return pl.BlockSpec((op,), lambda j: (0,))


def _mat_spec(op):
    return pl.BlockSpec((op, LANE_TILE), lambda j: (0, j))


def _scalar_spec():
    return pl.BlockSpec((1,), lambda j: (0,))


@partial(jax.custom_vjp, nondiff_argnums=())
def fake_quant(w_s, v, b, z, n, p):
    """Pallas GENIE-M soft quantizer; semantics of ref.fake_quant_ref."""
    return _fake_quant_fwd_impl(w_s, v, b, z, n, p)


def _fake_quant_fwd_impl(w_s, v, b, z, n, p):
    o, k = v.shape
    op, kp = _tiles(o, k)
    grid = (kp // LANE_TILE,)
    out = pl.pallas_call(
        _fwd_kernel,
        grid=grid,
        in_specs=[_row_spec(op), _mat_spec(op), _mat_spec(op), _row_spec(op),
                  _scalar_spec(), _scalar_spec()],
        out_specs=_mat_spec(op),
        out_shape=jax.ShapeDtypeStruct((op, kp), v.dtype),
        interpret=True,
    )(_pad1(w_s, op), _pad2(v, op, kp), _pad2(b, op, kp), _pad1(z, op),
      jnp.reshape(n, (1,)), jnp.reshape(p, (1,)))
    return out[:o, :k]


def _fq_fwd(w_s, v, b, z, n, p):
    return _fake_quant_fwd_impl(w_s, v, b, z, n, p), (w_s, v, b, z, n, p)


def _fq_bwd(res, g):
    w_s, v, b, z, n, p = res
    o, k = v.shape
    op, kp = _tiles(o, k)
    n_lane_tiles = kp // LANE_TILE
    grid = (n_lane_tiles,)
    ds_part, dv = pl.pallas_call(
        _bwd_kernel,
        grid=grid,
        in_specs=[_row_spec(op), _mat_spec(op), _mat_spec(op), _row_spec(op),
                  _scalar_spec(), _scalar_spec(), _mat_spec(op)],
        out_specs=[pl.BlockSpec((op, 1), lambda j: (0, j)),
                   _mat_spec(op)],
        out_shape=[jax.ShapeDtypeStruct((op, n_lane_tiles), v.dtype),
                   jax.ShapeDtypeStruct((op, kp), v.dtype)],
        interpret=True,
    )(_pad1(w_s, op), _pad2(v, op, kp), _pad2(b, op, kp), _pad1(z, op),
      jnp.reshape(n, (1,)), jnp.reshape(p, (1,)), _pad2(g, op, kp))
    d_s = jnp.sum(ds_part, axis=1)[:o]
    d_v = dv[:o, :k]
    return (d_s, d_v, jnp.zeros_like(b), jnp.zeros_like(z),
            jnp.zeros_like(n), jnp.zeros_like(p))


fake_quant.defvjp(_fq_fwd, _fq_bwd)


def fake_quant_hard(w_s, v, b, z, n, p):
    """Eval-time hard rounding of the softbits (no gradient path)."""
    hh = (_h(v) >= 0.5).astype(v.dtype)
    c = jnp.clip(b + hh, n, p)
    return w_s[:, None] * (c - z[:, None])

"""LSQ activation fake-quantizer (per-tensor, symmetric) as a Pallas kernel.

Forward:  xq = s * clip(round(x/s), qn, qp)
Backward: clipped-STE for x, LSQ gradient for s (Esser et al., ICLR'20),
matching ref.lsq_quant_ref.

TPU shaping: activations of any rank are flattened into a (rows x 128)
lane-aligned block processed by a single program (row-tiled grids ran
~300x slower under the sequential interpret-mode grid; see fake_quant.py
and EXPERIMENTS.md section Perf). The s-gradient partial sum is emitted
per program and reduced by the wrapper. interpret=True: see fake_quant.py.
"""

from functools import partial

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

ROW_TILE = 8
LANE_TILE = 128


def _fwd_kernel(x_ref, s_ref, qn_ref, qp_ref, o_ref):
    s = s_ref[0]
    o_ref[...] = s * jnp.clip(jnp.round(x_ref[...] / s), qn_ref[0], qp_ref[0])


def _bwd_kernel(x_ref, s_ref, qn_ref, qp_ref, g_ref, dx_ref, ds_part_ref):
    s = s_ref[0]
    qn = qn_ref[0]
    qp = qp_ref[0]
    g = g_ref[...]
    vv = x_ref[...] / s
    inside = (vv >= qn) & (vv <= qp)
    dx_ref[...] = g * inside.astype(g.dtype)
    per = jnp.where(vv < qn, qn, jnp.where(vv > qp, qp, jnp.round(vv) - vv))
    ds_part_ref[...] = jnp.sum(g * per)[None, None]


def _shape2d(numel):
    cols = LANE_TILE
    rows = -(-numel // cols)
    rows_p = -(-rows // ROW_TILE) * ROW_TILE
    return rows_p, cols


def _flatten_pad(x, rows_p, cols):
    flat = jnp.ravel(x)
    flat = jnp.pad(flat, (0, rows_p * cols - flat.shape[0]))
    return flat.reshape(rows_p, cols)


def _mat_spec(rows_p):
    return pl.BlockSpec((rows_p, LANE_TILE), lambda: (0, 0))


def _scalar_spec():
    return pl.BlockSpec((1,), lambda: (0,))


@partial(jax.custom_vjp, nondiff_argnums=())
def lsq_quant(x, s, qn, qp):
    """Pallas LSQ fake-quant; semantics of ref.lsq_quant_ref."""
    return _lsq_fwd_impl(x, s, qn, qp)


def _lsq_fwd_impl(x, s, qn, qp):
    rows_p, cols = _shape2d(x.size)
    x2 = _flatten_pad(x, rows_p, cols)
    out = pl.pallas_call(
        _fwd_kernel,
        grid=(),
        in_specs=[_mat_spec(rows_p), _scalar_spec(), _scalar_spec(),
                  _scalar_spec()],
        out_specs=_mat_spec(rows_p),
        out_shape=jax.ShapeDtypeStruct((rows_p, cols), x.dtype),
        interpret=True,
    )(x2, jnp.reshape(s, (1,)), jnp.reshape(qn, (1,)), jnp.reshape(qp, (1,)))
    return jnp.ravel(out)[: x.size].reshape(x.shape)


def _lsq_fwd(x, s, qn, qp):
    return _lsq_fwd_impl(x, s, qn, qp), (x, s, qn, qp)


def _lsq_bwd(res, g):
    x, s, qn, qp = res
    rows_p, cols = _shape2d(x.size)
    x2 = _flatten_pad(x, rows_p, cols)
    # Padding lanes carry x=0, g=0 -> contribute g*per = 0 to the s-gradient.
    g2 = _flatten_pad(g, rows_p, cols)
    dx2, ds_part = pl.pallas_call(
        _bwd_kernel,
        grid=(),
        in_specs=[_mat_spec(rows_p), _scalar_spec(), _scalar_spec(),
                  _scalar_spec(), _mat_spec(rows_p)],
        out_specs=[_mat_spec(rows_p), pl.BlockSpec((1, 1), lambda: (0, 0))],
        out_shape=[jax.ShapeDtypeStruct((rows_p, cols), x.dtype),
                   jax.ShapeDtypeStruct((1, 1), x.dtype)],
        interpret=True,
    )(x2, jnp.reshape(s, (1,)), jnp.reshape(qn, (1,)), jnp.reshape(qp, (1,)),
      g2)
    d_x = jnp.ravel(dx2)[: x.size].reshape(x.shape)
    gs = 1.0 / jnp.sqrt(jnp.asarray(x.size, g.dtype) * jnp.maximum(qp, 1.0))
    d_s = jnp.sum(ds_part) * gs
    return d_x, jnp.reshape(d_s, s.shape), jnp.zeros_like(qn), jnp.zeros_like(qp)


lsq_quant.defvjp(_lsq_fwd, _lsq_bwd)

"""AdaRound rounding regularizer sum(1 - |2h(V)-1|^beta) as a Pallas kernel.

The annealed regularizer of Eq. A2 that pushes softbits to {0,1}. Beta is a
runtime scalar so the rust coordinator drives the annealing schedule.

TPU shaping: same flatten-to-lane-aligned-block scheme as lsq_quant
(single program; see the grid note there). Backward matches
ref.soft_round_reg_ref.
"""

from functools import partial

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .ref import h_sigmoid, h_sigmoid_grad

ROW_TILE = 8
LANE_TILE = 128


def _fwd_kernel(v_ref, beta_ref, mask_ref, part_ref):
    t = 2.0 * h_sigmoid(v_ref[...]) - 1.0
    term = (1.0 - jnp.abs(t) ** beta_ref[0]) * mask_ref[...]
    part_ref[...] = jnp.sum(term)[None, None]


def _shape2d(numel):
    cols = LANE_TILE
    rows = -(-numel // cols)
    rows_p = -(-rows // ROW_TILE) * ROW_TILE
    return rows_p, cols


def _flatten_pad(x, rows_p, cols, value=0.0):
    flat = jnp.ravel(x)
    flat = jnp.pad(flat, (0, rows_p * cols - flat.shape[0]),
                   constant_values=value)
    return flat.reshape(rows_p, cols)


@partial(jax.custom_vjp, nondiff_argnums=())
def soft_round_reg(v, beta):
    """Pallas rounding regularizer; semantics of ref.soft_round_reg_ref."""
    return _reg_impl(v, beta)


def _reg_impl(v, beta):
    rows_p, cols = _shape2d(v.size)
    v2 = _flatten_pad(v, rows_p, cols)
    # Padding lanes would contribute 1 - |2h(0)-1|^beta != 0; mask them out.
    mask = _flatten_pad(jnp.ones(v.size, v.dtype), rows_p, cols)
    parts = pl.pallas_call(
        _fwd_kernel,
        grid=(),
        in_specs=[pl.BlockSpec((rows_p, cols), lambda: (0, 0)),
                  pl.BlockSpec((1,), lambda: (0,)),
                  pl.BlockSpec((rows_p, cols), lambda: (0, 0))],
        out_specs=pl.BlockSpec((1, 1), lambda: (0, 0)),
        out_shape=jax.ShapeDtypeStruct((1, 1), v.dtype),
        interpret=True,
    )(v2, jnp.reshape(beta, (1,)), mask)
    return jnp.sum(parts)


def _reg_fwd(v, beta):
    return _reg_impl(v, beta), (v, beta)


def _reg_bwd(res, g):
    v, beta = res
    t = 2.0 * h_sigmoid(v) - 1.0
    safe = jnp.maximum(jnp.abs(t), 1e-12)
    d_t = -beta * safe ** (beta - 1.0) * jnp.sign(t)
    d_v = g * d_t * 2.0 * h_sigmoid_grad(v)
    return d_v, jnp.zeros_like(beta)


soft_round_reg.defvjp(_reg_fwd, _reg_bwd)

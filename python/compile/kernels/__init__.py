"""L1: Pallas kernels for GENIE's compute hot-spots.

Every kernel is wrapped in jax.custom_vjp with an analytic backward pass and
is verified against the pure-jnp oracles in ref.py (values and cotangents)
by python/tests/. All kernels lower with interpret=True so the AOT HLO runs
on the CPU PJRT client (see DESIGN.md section Hardware-Adaptation for the
TPU tiling rationale).
"""

from .fake_quant import fake_quant, fake_quant_hard
from .lsq_quant import lsq_quant
from .bns_stats import bns_stats
from .soft_round_reg import soft_round_reg
from .swing_select import swing_select

__all__ = [
    "fake_quant", "fake_quant_hard", "lsq_quant", "bns_stats",
    "soft_round_reg", "swing_select",
]

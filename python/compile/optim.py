"""Adam, as baked into the AOT step graphs.

State (m, v) and the step counter are threaded through every call so the
rust coordinator owns optimizer state; learning rates are runtime scalars
so rust drives every schedule (cosine annealing, exponential decay,
ReduceLROnPlateau) without re-lowering."""

import jax.numpy as jnp

B1 = 0.9
B2 = 0.999
EPS = 1e-8


def adam_update(p, g, m, v, t, lr):
    m2 = B1 * m + (1.0 - B1) * g
    v2 = B2 * v + (1.0 - B2) * g * g
    mhat = m2 / (1.0 - B1 ** t)
    vhat = v2 / (1.0 - B2 ** t)
    return p - lr * mhat / (jnp.sqrt(vhat) + EPS), m2, v2


def adam_update_tree(params, grads, ms, vs, t, lr):
    """Dict-of-arrays variant; returns (params', ms', vs')."""
    p2, m2, v2 = {}, {}, {}
    for k in params:
        p2[k], m2[k], v2[k] = adam_update(params[k], grads[k], ms[k], vs[k],
                                          t, lr)
    return p2, m2, v2

"""GDFQ-style image generator for GENIE-D (appendix E).

z[B, LATENT] -> dense -> [B, H/2, W/2, C0] -> BN -> LeakyReLU
  -> nearest-upsample x2 -> conv3x3 -> BN -> LeakyReLU  (the single
     "upscale block" of Figure A3)
  -> conv3x3 -> tanh  -> images in [-1, 1]

Generator BN uses batch statistics only (no running state): every distilled
batch re-initializes the generator (appendix A), so there is nothing to
track across batches. The rust coordinator re-initializes per batch via the
`gen_init` entrypoint.
"""

import jax
import jax.numpy as jnp

from .kernels import bns_stats

LATENT = 256
C0 = 32
LRELU = 0.2
BN_EPS = 1e-5


def param_specs(image):
    h, w, c = image
    h0, w0 = h // 2, w // 2
    return [
        ("gen.fc.w", (LATENT, h0 * w0 * C0)), ("gen.fc.b", (h0 * w0 * C0,)),
        ("gen.bn0.gamma", (C0,)), ("gen.bn0.beta", (C0,)),
        ("gen.c1.w", (3, 3, C0, C0)),
        ("gen.bn1.gamma", (C0,)), ("gen.bn1.beta", (C0,)),
        ("gen.c2.w", (3, 3, C0, c)), ("gen.c2.b", (c,)),
    ]


def init(key, image):
    params = {}
    for name, shape in param_specs(image):
        key, sub = jax.random.split(key)
        if name.endswith(".gamma"):
            params[name] = jnp.ones(shape, jnp.float32)
        elif name.endswith(".beta") or name.endswith(".b"):
            params[name] = jnp.zeros(shape, jnp.float32)
        else:
            fan_in = 1
            for d in shape[:-1]:
                fan_in *= d
            std = (2.0 / max(fan_in, 1)) ** 0.5
            params[name] = std * jax.random.normal(sub, shape, jnp.float32)
    return params


def _bn_batch(x, gamma, beta):
    m, v = bns_stats(x)
    return (x - m) * jax.lax.rsqrt(v + BN_EPS) * gamma + beta


def _lrelu(x):
    return jnp.where(x >= 0, x, LRELU * x)


def _upsample2(x):
    n, h, w, c = x.shape
    x = jnp.broadcast_to(x[:, :, None, :, None, :], (n, h, 2, w, 2, c))
    return x.reshape(n, h * 2, w * 2, c)


def apply(params, z, image):
    h, w, c = image
    h0, w0 = h // 2, w // 2
    x = z @ params["gen.fc.w"] + params["gen.fc.b"]
    x = x.reshape(z.shape[0], h0, w0, C0)
    x = _lrelu(_bn_batch(x, params["gen.bn0.gamma"], params["gen.bn0.beta"]))
    x = _upsample2(x)
    x = jax.lax.conv_general_dilated(
        x, params["gen.c1.w"], (1, 1), "SAME",
        dimension_numbers=("NHWC", "HWIO", "NHWC"))
    x = _lrelu(_bn_batch(x, params["gen.bn1.gamma"], params["gen.bn1.beta"]))
    x = jax.lax.conv_general_dilated(
        x, params["gen.c2.w"], (1, 1), "SAME",
        dimension_numbers=("NHWC", "HWIO", "NHWC")) + params["gen.c2.b"]
    return jnp.tanh(x)

"""Procedural 'structured-texture' dataset (the ImageNet stand-in).

Each class is an oriented sinusoidal texture (class-specific orientation and
frequency) with a class-colored Gaussian blob at a class-biased location,
random phase/position jitter, and additive noise -- enough structure that a
small CNN learns non-trivial BN statistics (the only thing ZSQ consumes)
and that held-out samples act as the 'real data' arm of Tables 3/5.
Substitution rationale: DESIGN.md section 3.
"""

import numpy as np

H = W = 16
C = 3
NCLASSES = 10


def make_dataset(n, seed):
    rng = np.random.default_rng(seed)
    ys = rng.integers(0, NCLASSES, size=n).astype(np.int32)
    xs = np.empty((n, H, W, C), np.float32)
    uu, vv = np.meshgrid(np.arange(W), np.arange(H))
    for i in range(n):
        c = ys[i]
        theta = np.pi * c / NCLASSES + rng.normal(0, 0.06)
        freq = (1.5 + (c % 5) * 0.7) * (2 * np.pi / W)
        phase = rng.uniform(0, 2 * np.pi)
        base = np.sin(freq * (np.cos(theta) * uu + np.sin(theta) * vv)
                      + phase)
        # class-colored blob at a class-biased location
        cx = (c % 4) * 4 + 2 + rng.normal(0, 1.0)
        cy = (c // 4) * 5 + 2 + rng.normal(0, 1.0)
        d2 = (uu - cx) ** 2 + (vv - cy) ** 2
        blob = np.exp(-d2 / 8.0)
        color = np.array([np.cos(2 * np.pi * c / NCLASSES),
                          np.sin(2 * np.pi * c / NCLASSES),
                          (c / NCLASSES) * 2 - 1], np.float32)
        img = (base[..., None] * 0.7
               + blob[..., None] * color[None, None, :] * 1.2
               + rng.normal(0, 0.25, (H, W, C)))
        xs[i] = img.astype(np.float32)
    # global standardization (the 'preprocessing' the teacher was trained on)
    xs = (xs - xs.mean()) / (xs.std() + 1e-8)
    return xs, ys

"""The AOT step graphs: pretraining, distillation (GENIE-D), block-wise
reconstruction (GENIE-M), collection and evaluation.

Every function here is pure and jit-lowerable; optimizer state, RNG keys,
learning rates and all annealed hyperparameters are runtime inputs so the
rust coordinator owns every schedule (appendix A)."""

import jax
import jax.numpy as jnp

from . import generator, ir
from .kernels import soft_round_reg
from .optim import adam_update, adam_update_tree

BN_EPS = 1e-5


def unwrap_key(raw):
    """uint32[2] -> typed threefry key (keys cross the FFI as raw words)."""
    return jax.random.wrap_key_data(raw, impl="threefry2x32")


# ---------------------------------------------------------------------------
# FP32 pretraining / evaluation
# ---------------------------------------------------------------------------

def train_step(model, params, bn_state, ms, vs, t, x, y, lr):
    def loss_fn(p):
        logits, ctx = ir.forward(model, p, bn_state, x, train=True)
        logp = jax.nn.log_softmax(logits)
        ce = -jnp.mean(logp[jnp.arange(x.shape[0]), y])
        return ce, (logits, ctx.new_bn)

    (loss, (logits, new_bn)), grads = jax.value_and_grad(
        loss_fn, has_aux=True)(params)
    p2, m2, v2 = adam_update_tree(params, grads, ms, vs, t, lr)
    acc = jnp.mean((jnp.argmax(logits, axis=1) == y).astype(jnp.float32))
    return p2, new_bn, m2, v2, loss, acc


def eval_batch(model, params, bn_state, x):
    logits, _ = ir.forward(model, params, bn_state, x)
    return logits


def act_stats(model, params, bn_state, x):
    """mean |x| at every activation-quant site (LSQ s_a initialization)."""
    ctx = ir.Ctx(params, bn_state, act_stats=True)
    for _, bops in model.blocks:
        x = ir.run_ops(bops, x, ctx)
    return jnp.stack(ctx.stats)


# ---------------------------------------------------------------------------
# GENIE-D distillation
# ---------------------------------------------------------------------------

def bns_loss(model, params, bn_state, x, key, swing):
    """Eq. 5: match per-BN batch stats of x to the learned running stats."""
    ctx = ir.Ctx(params, bn_state, collect_bns=True,
                 swing_key=(jax.random.fold_in(key, 1) if swing else None))
    h = x
    for _, bops in model.blocks:
        h = ir.run_ops(bops, h, ctx)
    loss = 0.0
    for (bm, bv), name in zip(ctx.bns, model.bn_names()):
        rm = bn_state[f"{name}.mean"]
        rv = bn_state[f"{name}.var"]
        loss = loss + jnp.sum((bm - rm) ** 2)
        loss = loss + jnp.sum((jnp.sqrt(bv + BN_EPS) - jnp.sqrt(rv + BN_EPS)) ** 2)
    return loss


def distill_genie_step(model, gen_params, gm, gv, z, zm, zv, t, params,
                       bn_state, key, lr_g, lr_z, swing):
    """One GENIE-D step: update both generator weights and latents (Alg. 1).

    GBA ablation arm = same graph driven with lr_z = 0."""
    def loss_fn(gp, zz):
        x = generator.apply(gp, zz, model.image)
        return bns_loss(model, params, bn_state, x, key, swing)

    loss, (g_gen, g_z) = jax.value_and_grad(loss_fn, argnums=(0, 1))(
        gen_params, z)
    gp2, gm2, gv2 = adam_update_tree(gen_params, g_gen, gm, gv, t, lr_g)
    z2, zm2, zv2 = adam_update(z, g_z, zm, zv, t, lr_z)
    return gp2, gm2, gv2, z2, zm2, zv2, loss


def distill_direct_step(model, x, xm, xv, t, params, bn_state, key, lr,
                        swing):
    """ZeroQ-style direct distillation (DBA); M1/M3 ablation arms."""
    loss, g_x = jax.value_and_grad(
        lambda xx: bns_loss(model, params, bn_state, xx, key, swing))(x)
    x2, xm2, xv2 = adam_update(x, g_x, xm, xv, t, lr)
    return x2, xm2, xv2, loss


# weight of the adversarial term against the BNS regularizer (ZAQ Eq. 8
# balances discrepancy against realism; BNS plays the realism role here)
ZAQ_ADV_WEIGHT = 10.0


def distill_zaq_step(model, gen_params, gm, gv, z, zm, zv, t, params,
                     bn_state, key, lr_g, lr_z, wp, ap, swing):
    """One ZAQ-style adversarial step: generator + latents *maximize* the
    teacher/student output discrepancy, where the student is the teacher's
    own weights under per-tensor Min-Max fake-quant at (wp, ap) bits —
    the synthesis-time adversary proxy. The BNS term regularizes the
    images onto the BN-statistics manifold so the discrepancy is not won
    by drifting off-distribution."""
    def loss_fn(gp, zz):
        x = generator.apply(gp, zz, model.image)
        t_logits, _ = ir.forward(model, params, bn_state, x)
        s_logits, _ = ir.forward(model, params, bn_state, x,
                                 minmax=(wp, ap))
        disc = jnp.mean(jnp.abs(jax.nn.softmax(t_logits)
                                - jax.nn.softmax(s_logits)))
        bns = bns_loss(model, params, bn_state, x, key, swing)
        return bns - ZAQ_ADV_WEIGHT * disc

    loss, (g_gen, g_z) = jax.value_and_grad(loss_fn, argnums=(0, 1))(
        gen_params, z)
    gp2, gm2, gv2 = adam_update_tree(gen_params, g_gen, gm, gv, t, lr_g)
    z2, zm2, zv2 = adam_update(z, g_z, zm, zv, t, lr_z)
    return gp2, gm2, gv2, z2, zm2, zv2, loss


# ---------------------------------------------------------------------------
# Collection + GENIE-M block reconstruction
# ---------------------------------------------------------------------------

def collect_teacher(model, params, bn_state, x):
    _, _, bounds = ir.forward(model, params, bn_state, x,
                              collect_blocks=True)
    return bounds


def collect_student(model, params, bn_state, qstate, x, key):
    """Block boundaries under the soft-quantized prefix (BRECQ-style
    sequential input refresh). No QDrop at collection time."""
    _, _, bounds = ir.forward(model, params, bn_state, x,
                              collect_blocks=True, qctx=qstate)
    return bounds


def eval_quant(model, params, bn_state, qstate, x):
    logits, _ = ir.forward(model, params, bn_state, x, qctx=qstate,
                           hard=True)
    return logits


def qat_step(model, sparams, ms, vs, t, teacher_params, bn_state, x, lr,
             wp, ap):
    """Netwise Min-Max QAT baseline (Table 4 / A2: GDFQ/AIT-style).

    Student weights are trained under per-tensor Min-Max fake-quant with
    STE; the loss is the KL divergence to the FP32 teacher's logits
    (AIT's KL-only observation). BN uses the teacher's running stats."""
    t_logits, _ = ir.forward(model, teacher_params, bn_state, x)
    t_prob = jax.nn.softmax(t_logits)

    def loss_fn(sp):
        logits, _ = ir.forward(model, sp, bn_state, x, minmax=(wp, ap))
        logq = jax.nn.log_softmax(logits)
        return jnp.mean(jnp.sum(t_prob * (jnp.log(t_prob + 1e-9) - logq),
                                axis=1))

    loss, grads = jax.value_and_grad(loss_fn)(sparams)
    p2, m2, v2 = adam_update_tree(sparams, grads, ms, vs, t, lr)
    return p2, m2, v2, loss


def eval_qat(model, sparams, bn_state, x, wp, ap):
    logits, _ = ir.forward(model, sparams, bn_state, x, minmax=(wp, ap))
    return logits


def quant_block_step(model, b, params, bn_state, qstate_b, ms, vs, t,
                     x_in, y_ref, key, lr_sw, lr_v, lr_sa, lam, beta,
                     drop_p):
    """One GENIE-M reconstruction step on block b (Eq. A2 / Alg. A1).

    Learnables: per-layer s_w, softbits V, s_a. AdaRound baseline = lr_sw=0;
    NoDrop = drop_p=0. beta anneals via the rust-side schedule."""
    learn_names = model.qstate_learnable(block=b)
    learn = {k: qstate_b[k] for k in learn_names}
    v_names = [k for k in learn_names if k.endswith(".v")]

    def loss_fn(lrn):
        qctx = dict(qstate_b)
        qctx.update(lrn)
        y, _ = ir.forward_block(model, b, params, bn_state, x_in, qctx=qctx,
                                drop_key=jax.random.fold_in(key, 7),
                                drop_p=drop_p)
        rec = jnp.mean((y - y_ref) ** 2)
        reg = 0.0
        for k in v_names:
            reg = reg + soft_round_reg(lrn[k], beta)
        return rec + lam * reg, rec

    (loss, rec), grads = jax.value_and_grad(loss_fn, has_aux=True)(learn)
    out, m2, v2 = {}, {}, {}
    for k in learn_names:
        lr = lr_v if k.endswith(".v") else (lr_sw if k.endswith(".sw")
                                            else lr_sa)
        out[k], m2[k], v2[k] = adam_update(learn[k], grads[k], ms[k], vs[k],
                                           t, lr)
    return out, m2, v2, loss, rec

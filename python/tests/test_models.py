"""L2 model-zoo invariants: shapes, BN semantics, block decomposition,
quantized-forward consistency."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import ir
from compile.models import ZOO, get_model

MODELS = list(ZOO)


def _init(name, seed=0):
    m = get_model(name)
    params, bn = m.init(jax.random.PRNGKey(seed))
    return m, params, bn


def _dummy_qstate(model, seed=7, bits=4):
    ks = iter(jax.random.split(jax.random.PRNGKey(seed), 512))
    p = float(2 ** bits - 1)
    qs = {}
    for name, shape in model.qstate_specs():
        if name.endswith(".sw"):
            qs[name] = jnp.full(shape, 0.05)
        elif name.endswith(".sa"):
            qs[name] = jnp.float32(0.1)
        elif name.endswith((".wn", ".an")):
            qs[name] = jnp.float32(-8.0 if name.endswith(".an") else 0.0)
        elif name.endswith((".wp", ".ap")):
            qs[name] = jnp.float32(7.0 if name.endswith(".ap") else p)
        elif name.endswith(".v"):
            qs[name] = jax.random.normal(next(ks), shape) * 0.5
        elif name.endswith(".b"):
            qs[name] = jnp.round(
                jax.random.uniform(next(ks), shape, minval=0.0, maxval=p))
        else:
            qs[name] = jnp.zeros(shape)
    return qs


@pytest.mark.parametrize("name", MODELS)
def test_forward_shape(name):
    m, params, bn = _init(name)
    x = jax.random.normal(jax.random.PRNGKey(1), (2,) + tuple(m.image))
    logits, _ = ir.forward(m, params, bn, x)
    assert logits.shape == (2, m.nclasses)
    assert bool(jnp.all(jnp.isfinite(logits)))


@pytest.mark.parametrize("name", MODELS)
def test_block_decomposition_matches_full_forward(name):
    """Sequential per-block execution == monolithic forward (the property
    BRECQ-style reconstruction relies on)."""
    m, params, bn = _init(name)
    x = jax.random.normal(jax.random.PRNGKey(2), (2,) + tuple(m.image))
    full, _, bounds = ir.forward(m, params, bn, x, collect_blocks=True)
    h = x
    for b in range(len(m.blocks)):
        np.testing.assert_allclose(h, bounds[b], rtol=1e-5, atol=1e-5)
        h, _ = ir.forward_block(m, b, params, bn, h)
    np.testing.assert_allclose(h, full, rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("name", ["toy", "resnet14"])
def test_bn_train_updates_running_stats(name):
    m, params, bn = _init(name)
    x = jax.random.normal(jax.random.PRNGKey(3), (8,) + tuple(m.image)) * 3
    _, ctx = ir.forward(m, params, bn, x, train=True)
    assert set(ctx.new_bn) == set(dict(m.bn_specs()))
    moved = sum(float(jnp.abs(ctx.new_bn[k] - bn[k]).max()) > 1e-6
                for k in bn)
    assert moved > 0


@pytest.mark.parametrize("name", ["toy", "mobilenetv2_t"])
def test_bns_collect_matches_layer_count(name):
    m, params, bn = _init(name)
    x = jax.random.normal(jax.random.PRNGKey(4), (4,) + tuple(m.image))
    _, ctx = ir.forward(m, params, bn, x, collect_bns=True)
    assert len(ctx.bns) == len(m.bn_names())
    for bm, bv in ctx.bns:
        assert bool(jnp.all(bv >= 0))


@pytest.mark.parametrize("name", MODELS)
def test_swing_changes_only_strided_path(name):
    """Swing forward differs from plain forward (strided convs exist) but
    has identical output shape; with offset-center keys the set of possible
    outputs includes the plain one."""
    m, params, bn = _init(name)
    x = jax.random.normal(jax.random.PRNGKey(5), (2,) + tuple(m.image))
    plain, _ = ir.forward(m, params, bn, x)
    sw, _ = ir.forward(m, params, bn, x, swing_key=jax.random.PRNGKey(11))
    assert sw.shape == plain.shape
    assert bool(jnp.all(jnp.isfinite(sw)))


@pytest.mark.parametrize("name", ["toy", "resnet14", "mobilenetv2_t"])
def test_quantized_forward_soft_vs_hard(name):
    m, params, bn = _init(name)
    qs = _dummy_qstate(m)
    x = jax.random.normal(jax.random.PRNGKey(6), (2,) + tuple(m.image))
    soft, _ = ir.forward(m, params, bn, x, qctx=qs)
    hard, _ = ir.forward(m, params, bn, x, qctx=qs, hard=True)
    assert soft.shape == hard.shape == (2, m.nclasses)
    assert bool(jnp.all(jnp.isfinite(soft)))
    assert bool(jnp.all(jnp.isfinite(hard)))
    # Pushing all softbits hard makes soft == hard.
    qs2 = {k: (jnp.sign(v - 0.0) * 10.0 if k.endswith(".v") else v)
           for k, v in qs.items()}
    soft2, _ = ir.forward(m, params, bn, x, qctx=qs2)
    hard2, _ = ir.forward(m, params, bn, x, qctx=qs2, hard=True)
    np.testing.assert_allclose(soft2, hard2, rtol=1e-4, atol=1e-4)


@pytest.mark.parametrize("name", MODELS)
def test_qstate_specs_cover_quant_layers(name):
    m = get_model(name)
    qls = m.quant_layers()
    specs = dict(m.qstate_specs())
    assert len(specs) == 9 * len(qls)
    for ql in qls:
        assert specs[f"q.{ql.name}.v"] == (ql.out_ch, ql.flat_k)
        assert specs[f"q.{ql.name}.sw"] == (ql.out_ch,)
    # block partition covers everything exactly once
    union = []
    for b in range(len(m.blocks)):
        union += [n for n, _ in m.block_qstate_specs(b)]
    assert sorted(union) == sorted(specs)


@pytest.mark.parametrize("name", ["toy", "mnasnet_t"])
def test_param_init_deterministic(name):
    m = get_model(name)
    p1, b1 = m.init(jax.random.PRNGKey(0))
    p2, b2 = m.init(jax.random.PRNGKey(0))
    for k in p1:
        np.testing.assert_array_equal(p1[k], p2[k])

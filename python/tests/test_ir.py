"""IR/interpreter invariants beyond the per-model zoo tests."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import ir
from compile.models import get_model


@pytest.fixture(scope="module")
def toy():
    m = get_model("toy")
    p, b = m.init(jax.random.PRNGKey(0))
    return m, p, b


def test_walk_yields_merge_subops():
    m = get_model("resnet14")
    names = [op.name for op in m._walk() if isinstance(op, ir.Conv)]
    # projection shortcut convs (inside Merge) must be visible to the walk
    assert "s2.0.sc" in names and "s3.0.sc" in names


def test_param_specs_unique_names():
    for name in ["toy", "resnet14", "resnet26b", "mobilenetv2_t"]:
        m = get_model(name)
        specs = [n for n, _ in m.param_specs()]
        assert len(specs) == len(set(specs)), name


def test_swing_deterministic_given_key(toy):
    m, p, b = toy
    x = jax.random.normal(jax.random.PRNGKey(1), (2,) + tuple(m.image))
    k = jax.random.PRNGKey(7)
    y1, _ = ir.forward(m, p, b, x, swing_key=k)
    y2, _ = ir.forward(m, p, b, x, swing_key=k)
    np.testing.assert_array_equal(y1, y2)
    y3, _ = ir.forward(m, p, b, x, swing_key=jax.random.PRNGKey(8))
    # different key -> different stride phase (almost surely)
    assert float(jnp.abs(y1 - y3).max()) > 0


def test_block_qstate_partition_disjoint():
    m = get_model("mnasnet_t")
    seen = set()
    for bi in range(len(m.blocks)):
        for n, _ in m.block_qstate_specs(bi):
            assert n not in seen
            seen.add(n)
    assert seen == {n for n, _ in m.qstate_specs()}


def test_qdrop_interpolates_between_fp_and_quant(toy):
    """drop_p=1 -> pure FP activations; drop_p=0 -> fully quantized."""
    m, p, b = toy
    x = jax.random.normal(jax.random.PRNGKey(2), (2,) + tuple(m.image))
    from tests.test_models import _dummy_qstate
    qs = _dummy_qstate(m)
    key = jax.random.PRNGKey(3)
    q0, _ = ir.forward(m, p, b, x, qctx=qs, drop_key=key,
                       drop_p=jnp.float32(0.0))
    q0b, _ = ir.forward(m, p, b, x, qctx=qs)
    np.testing.assert_allclose(q0, q0b, rtol=1e-5, atol=1e-5)


def test_minmax_qat_mode_quantizes(toy):
    m, p, b = toy
    x = jax.random.normal(jax.random.PRNGKey(4), (2,) + tuple(m.image))
    fp, _ = ir.forward(m, p, b, x)
    q, _ = ir.forward(m, p, b, x, minmax=(jnp.float32(7.0), jnp.float32(7.0)))
    assert q.shape == fp.shape
    assert float(jnp.abs(q - fp).max()) > 0  # 4-bit minmax must perturb
    q8, _ = ir.forward(m, p, b, x,
                       minmax=(jnp.float32(32767.0), jnp.float32(32767.0)))
    # 16-bit minmax is nearly exact
    np.testing.assert_allclose(q8, fp, rtol=1e-2, atol=1e-2)


def test_act_stats_order_matches_quant_layers(toy):
    m, p, b = toy
    x = jax.random.normal(jax.random.PRNGKey(5), (4,) + tuple(m.image))
    ctx = ir.Ctx(p, b, act_stats=True)
    h = x
    for _, bops in m.blocks:
        h = ir.run_ops(bops, h, ctx)
    assert len(ctx.stats) == len(m.quant_layers())
    # first stat site sees the raw input
    np.testing.assert_allclose(ctx.stats[0], jnp.mean(jnp.abs(x)), rtol=1e-5)

"""L1 correctness: every pallas kernel vs its pure-jnp oracle, forward
values AND vjp cotangents, swept over shapes/dtypes with hypothesis."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import (bns_stats, fake_quant, fake_quant_hard,
                             lsq_quant, soft_round_reg, swing_select)
from compile.kernels import ref

settings.register_profile("ci", max_examples=12, deadline=None)
settings.load_profile("ci")


def keyseq(seed, n):
    return jax.random.split(jax.random.PRNGKey(seed), n)


# ---------------------------------------------------------------- fake_quant

def _fq_inputs(seed, o, k, bits):
    ks = keyseq(seed, 4)
    p = float(2 ** bits - 1)
    s = jax.random.uniform(ks[0], (o,), minval=0.01, maxval=0.3)
    v = jax.random.normal(ks[1], (o, k)) * 2.0
    b = jnp.floor(jax.random.uniform(ks[2], (o, k), minval=-1.0, maxval=p + 1))
    z = jnp.round(jax.random.uniform(ks[3], (o,), minval=0.0, maxval=p))
    return s, v, b, z, jnp.float32(0.0), jnp.float32(p)


@given(o=st.integers(1, 40), k=st.integers(1, 300),
       bits=st.sampled_from([2, 3, 4, 8]), seed=st.integers(0, 99))
def test_fake_quant_forward(o, k, bits, seed):
    args = _fq_inputs(seed, o, k, bits)
    np.testing.assert_allclose(fake_quant(*args), ref.fake_quant_ref(*args),
                               rtol=1e-6, atol=1e-6)


@given(o=st.integers(1, 20), k=st.integers(1, 200), seed=st.integers(0, 99))
def test_fake_quant_grads(o, k, seed):
    s, v, b, z, n, p = _fq_inputs(seed, o, k, 4)
    g = jax.random.normal(jax.random.PRNGKey(seed + 1), (o, k))
    f1 = lambda s_, v_: jnp.vdot(fake_quant(s_, v_, b, z, n, p), g)
    f2 = lambda s_, v_: jnp.vdot(ref.fake_quant_ref(s_, v_, b, z, n, p), g)
    g1 = jax.grad(f1, (0, 1))(s, v)
    g2 = jax.grad(f2, (0, 1))(s, v)
    np.testing.assert_allclose(g1[0], g2[0], rtol=1e-5, atol=1e-6)
    np.testing.assert_allclose(g1[1], g2[1], rtol=1e-5, atol=1e-6)


def test_fake_quant_hard_is_binary_rounding():
    s, v, b, z, n, p = _fq_inputs(0, 8, 50, 4)
    got = fake_quant_hard(s, v, b, z, n, p)
    np.testing.assert_allclose(got, ref.fake_quant_hard_ref(s, v, b, z, n, p))
    # hard ints live on the integer grid within [n, p]
    ints = got / s[:, None] + z[:, None]
    np.testing.assert_allclose(ints, jnp.round(ints), atol=1e-4)
    assert float(ints.min()) >= -1e-4 and float(ints.max()) <= 15.0 + 1e-4


def test_fake_quant_base_detached():
    """Eq. 11: no gradient flows to B or z."""
    s, v, b, z, n, p = _fq_inputs(3, 4, 9, 4)
    g_b = jax.grad(lambda b_: jnp.sum(fake_quant(s, v, b_, z, n, p)))(b)
    g_z = jax.grad(lambda z_: jnp.sum(fake_quant(s, v, b, z_, n, p)))(z)
    assert float(jnp.abs(g_b).max()) == 0.0
    assert float(jnp.abs(g_z).max()) == 0.0


# ----------------------------------------------------------------- lsq_quant

@given(shape=st.sampled_from([(3,), (2, 5), (2, 3, 4, 5), (1, 16, 16, 3),
                              (128,), (7, 129)]),
       bits=st.sampled_from([2, 4, 8]), seed=st.integers(0, 99))
def test_lsq_forward(shape, bits, seed):
    x = jax.random.normal(jax.random.PRNGKey(seed), shape) * 3.0
    s = jnp.float32(0.17)
    qn, qp = jnp.float32(-(2 ** (bits - 1))), jnp.float32(2 ** (bits - 1) - 1)
    np.testing.assert_allclose(lsq_quant(x, s, qn, qp),
                               ref.lsq_quant_ref(x, s, qn, qp),
                               rtol=1e-6, atol=1e-6)


@given(shape=st.sampled_from([(5,), (3, 7), (2, 4, 4, 3)]),
       seed=st.integers(0, 99))
def test_lsq_grads(shape, seed):
    x = jax.random.normal(jax.random.PRNGKey(seed), shape) * 3.0
    s = jnp.float32(0.21)
    qn, qp = jnp.float32(-8.0), jnp.float32(7.0)
    g = jax.random.normal(jax.random.PRNGKey(seed + 1), shape)
    f1 = lambda x_, s_: jnp.vdot(lsq_quant(x_, s_, qn, qp), g)
    f2 = lambda x_, s_: jnp.vdot(ref.lsq_quant_ref(x_, s_, qn, qp), g)
    g1 = jax.grad(f1, (0, 1))(x, s)
    g2 = jax.grad(f2, (0, 1))(x, s)
    np.testing.assert_allclose(g1[0], g2[0], rtol=1e-5, atol=1e-6)
    np.testing.assert_allclose(g1[1], g2[1], rtol=1e-4, atol=1e-6)


def test_lsq_values_on_grid():
    x = jnp.linspace(-3, 3, 97)
    s = jnp.float32(0.25)
    out = lsq_quant(x, s, jnp.float32(-8.0), jnp.float32(7.0))
    ints = out / s
    np.testing.assert_allclose(ints, jnp.round(ints), atol=1e-5)
    assert float(out.min()) >= -8 * 0.25 and float(out.max()) <= 7 * 0.25


# ----------------------------------------------------------------- bns_stats

@given(n=st.integers(1, 4), h=st.integers(1, 9), c=st.integers(1, 140),
       seed=st.integers(0, 99))
def test_bns_forward(n, h, c, seed):
    x = jax.random.normal(jax.random.PRNGKey(seed), (n, h, h, c)) * 2 + 0.5
    m1, v1 = bns_stats(x)
    m2, v2 = ref.bns_stats_ref(x)
    np.testing.assert_allclose(m1, m2, rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(v1, v2, rtol=1e-4, atol=1e-5)


@given(seed=st.integers(0, 99))
def test_bns_grads(seed):
    x = jax.random.normal(jax.random.PRNGKey(seed), (2, 5, 5, 7))
    ks = keyseq(seed + 1, 2)
    gm = jax.random.normal(ks[0], (7,))
    gv = jax.random.normal(ks[1], (7,))

    def scal(f):
        return lambda x_: (lambda mv: jnp.vdot(mv[0], gm)
                           + jnp.vdot(mv[1], gv))(f(x_))

    np.testing.assert_allclose(jax.grad(scal(bns_stats))(x),
                               jax.grad(scal(ref.bns_stats_ref))(x),
                               rtol=1e-4, atol=1e-5)


# ------------------------------------------------------------ soft_round_reg

@given(o=st.integers(1, 30), k=st.integers(1, 200),
       beta=st.floats(1.5, 25.0), seed=st.integers(0, 99))
def test_reg_forward(o, k, beta, seed):
    v = jax.random.normal(jax.random.PRNGKey(seed), (o, k)) * 2
    b = jnp.float32(beta)
    np.testing.assert_allclose(soft_round_reg(v, b),
                               ref.soft_round_reg_ref(v, b),
                               rtol=1e-4, atol=1e-4)


@given(seed=st.integers(0, 99), beta=st.floats(2.0, 20.0))
def test_reg_grads(seed, beta):
    v = jax.random.normal(jax.random.PRNGKey(seed), (6, 37)) * 2
    b = jnp.float32(beta)
    np.testing.assert_allclose(
        jax.grad(lambda v_: soft_round_reg(v_, b))(v),
        jax.grad(lambda v_: ref.soft_round_reg_ref(v_, b))(v),
        rtol=1e-4, atol=1e-5)


def test_reg_bounds():
    """Regularizer is 0 when all softbits are hard, maximal at h=0.5."""
    v_hard = jnp.full((4, 4), 10.0)  # h -> 1
    assert float(soft_round_reg(v_hard, jnp.float32(4.0))) < 1e-5
    v_mid = jnp.zeros((4, 4))  # h(0) = 0.5
    assert abs(float(soft_round_reg(v_mid, jnp.float32(4.0))) - 16.0) < 1e-4


# -------------------------------------------------------------- swing_select

@given(n=st.integers(1, 3), h=st.integers(4, 12), c=st.integers(1, 8),
       pad=st.integers(1, 2), seed=st.integers(0, 99))
def test_swing_forward(n, h, c, pad, seed):
    ks = keyseq(seed, 2)
    xp = jax.random.normal(ks[0], (n, h + 2 * pad, h + 2 * pad, c))
    off = jax.random.randint(ks[1], (2,), 0, 2 * pad + 1)
    a = swing_select(xp, off, h, h)
    b = ref.swing_select_ref(xp, off, h, h)
    np.testing.assert_allclose(a, b)


@given(seed=st.integers(0, 99))
def test_swing_grads(seed):
    ks = keyseq(seed, 3)
    xp = jax.random.normal(ks[0], (2, 8, 8, 3))
    off = jax.random.randint(ks[1], (2,), 0, 3)
    g = jax.random.normal(ks[2], (2, 6, 6, 3))
    f1 = lambda x_: jnp.vdot(swing_select(x_, off, 6, 6), g)
    f2 = lambda x_: jnp.vdot(ref.swing_select_ref(x_, off, 6, 6), g)
    np.testing.assert_allclose(jax.grad(f1)(xp), jax.grad(f2)(xp))


def test_swing_identity_at_center():
    """Offset (pad, pad) on a reflect-padded map is the identity crop."""
    x = jax.random.normal(jax.random.PRNGKey(0), (1, 6, 6, 2))
    xp = jnp.pad(x, ((0, 0), (1, 1), (1, 1), (0, 0)), mode="reflect")
    out = swing_select(xp, jnp.array([1, 1], jnp.int32), 6, 6)
    np.testing.assert_allclose(out, x)

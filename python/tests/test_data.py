"""Synthetic dataset substrate tests."""

import numpy as np

from compile import data


def test_shapes_and_standardization():
    xs, ys = data.make_dataset(256, seed=0)
    assert xs.shape == (256, 16, 16, 3) and xs.dtype == np.float32
    assert ys.shape == (256,) and ys.dtype == np.int32
    assert abs(xs.mean()) < 0.05 and abs(xs.std() - 1.0) < 0.05
    assert set(np.unique(ys)) <= set(range(10))


def test_deterministic_by_seed():
    x1, y1 = data.make_dataset(32, seed=5)
    x2, y2 = data.make_dataset(32, seed=5)
    x3, _ = data.make_dataset(32, seed=6)
    np.testing.assert_array_equal(x1, x2)
    np.testing.assert_array_equal(y1, y2)
    assert np.abs(x1 - x3).max() > 0


def test_classes_carry_signal():
    """Nearest-centroid accuracy far above chance -> a CNN can learn it."""
    xtr, ytr = data.make_dataset(1500, seed=1)
    xte, yte = data.make_dataset(400, seed=2)
    cent = np.stack([xtr[ytr == c].mean(0).ravel() for c in range(10)])
    d = ((xte.reshape(len(xte), -1)[:, None, :] - cent[None]) ** 2).sum(-1)
    acc = (d.argmin(1) == yte).mean()
    assert acc > 0.5

"""Generator shape/behaviour tests (appendix E architecture)."""

import jax
import jax.numpy as jnp
import numpy as np

from compile import generator

IMAGE = (16, 16, 3)


def test_output_shape_and_range():
    gp = generator.init(jax.random.PRNGKey(0), IMAGE)
    z = jax.random.normal(jax.random.PRNGKey(1), (8, generator.LATENT))
    x = generator.apply(gp, z, IMAGE)
    assert x.shape == (8,) + IMAGE
    assert float(jnp.abs(x).max()) <= 1.0 + 1e-6


def test_different_latents_different_images():
    gp = generator.init(jax.random.PRNGKey(0), IMAGE)
    z = jax.random.normal(jax.random.PRNGKey(2), (4, generator.LATENT))
    x = generator.apply(gp, z, IMAGE)
    d = jnp.abs(x[0] - x[1]).mean()
    assert float(d) > 1e-4


def test_init_reproducible_and_seed_sensitive():
    g1 = generator.init(jax.random.PRNGKey(3), IMAGE)
    g2 = generator.init(jax.random.PRNGKey(3), IMAGE)
    g3 = generator.init(jax.random.PRNGKey(4), IMAGE)
    for k in g1:
        np.testing.assert_array_equal(g1[k], g2[k])
    assert any(float(jnp.abs(g1[k] - g3[k]).max()) > 0
               for k in g1 if k.endswith(".w"))


def test_grads_flow_to_latents():
    gp = generator.init(jax.random.PRNGKey(5), IMAGE)
    z = jax.random.normal(jax.random.PRNGKey(6), (2, generator.LATENT))
    g = jax.grad(lambda z_: jnp.sum(generator.apply(gp, z_, IMAGE) ** 2))(z)
    assert float(jnp.abs(g).max()) > 0

"""Step-graph behaviour: optimization actually reduces the right losses and
collection graphs are consistent with each other."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import generator, ir, steps
from compile.models import get_model


@pytest.fixture(scope="module")
def toy():
    m = get_model("toy")
    params, bn = m.init(jax.random.PRNGKey(0))
    return m, params, bn


def _zeros_like_tree(d):
    return {k: jnp.zeros_like(v) for k, v in d.items()}


def test_train_step_reduces_loss(toy):
    m, params, bn = toy
    x = jax.random.normal(jax.random.PRNGKey(1), (16,) + tuple(m.image))
    y = jnp.arange(16) % 10
    ms, vs = _zeros_like_tree(params), _zeros_like_tree(params)
    losses = []
    step = jax.jit(lambda p, b, ms_, vs_, t: steps.train_step(
        m, p, b, ms_, vs_, t, x, y, jnp.float32(5e-3)))
    for t in range(1, 13):
        params, bn, ms, vs, loss, acc = step(params, bn, ms, vs,
                                             jnp.float32(t))
        losses.append(float(loss))
    assert losses[-1] < losses[0]


def test_distill_direct_reduces_bns_loss(toy):
    m, params, bn = toy
    # teacher with non-trivial running stats
    bn = {k: (v + 0.3 if k.endswith(".mean") else v * 1.7) for k, v in bn.items()}
    x = jax.random.normal(jax.random.PRNGKey(2), (8,) + tuple(m.image))
    xm, xv = jnp.zeros_like(x), jnp.zeros_like(x)
    key = jax.random.PRNGKey(3)
    step = jax.jit(lambda x_, xm_, xv_, t: steps.distill_direct_step(
        m, x_, xm_, xv_, t, params, bn, key, jnp.float32(0.1), False))
    losses = []
    for t in range(1, 31):
        x, xm, xv, loss = step(x, xm, xv, jnp.float32(t))
        losses.append(float(loss))
    assert losses[-1] < 0.5 * losses[0]


def test_distill_genie_reduces_bns_loss(toy):
    m, params, bn = toy
    bn = {k: (v + 0.3 if k.endswith(".mean") else v * 1.7) for k, v in bn.items()}
    gp = generator.init(jax.random.PRNGKey(4), m.image)
    gm, gv = _zeros_like_tree(gp), _zeros_like_tree(gp)
    z = jax.random.normal(jax.random.PRNGKey(5), (8, generator.LATENT))
    zm, zv = jnp.zeros_like(z), jnp.zeros_like(z)
    key = jax.random.PRNGKey(6)
    step = jax.jit(lambda gp_, gm_, gv_, z_, zm_, zv_, t: (
        steps.distill_genie_step(m, gp_, gm_, gv_, z_, zm_, zv_, t, params,
                                 bn, key, jnp.float32(0.01),
                                 jnp.float32(0.1), True)))
    losses = []
    for t in range(1, 31):
        gp, gm, gv, z, zm, zv, loss = step(gp, gm, gv, z, zm, zv,
                                           jnp.float32(t))
        losses.append(float(loss))
    assert losses[-1] < 0.7 * losses[0]


def _toy_qstate(m, params, seed=9, bits=4):
    """Well-formed qstate from the actual weights (python mirror of the
    rust initializer, used only in tests)."""
    qs = {}
    p = float(2 ** bits - 1)
    for ql in m.quant_layers():
        w = params[f"{ql.name}.w"]
        wf = (jnp.moveaxis(w, -1, 0).reshape(ql.out_ch, -1)
              if w.ndim == 4 else w.T)
        lo = jnp.min(wf, axis=1)
        hi = jnp.max(wf, axis=1)
        s = jnp.maximum((hi - lo) / p, 1e-8)
        z = jnp.round(-lo / s)
        base = jnp.clip(jnp.floor(wf / s[:, None]) + z[:, None], 0.0, p)
        r = jnp.clip(wf / s[:, None] + z[:, None] - base, 0.01, 0.99)
        v = jnp.log((r - 0.0) / (1.0 - r))  # approx logit init
        qs[f"q.{ql.name}.sw"] = s
        qs[f"q.{ql.name}.v"] = v
        qs[f"q.{ql.name}.b"] = base
        qs[f"q.{ql.name}.zp"] = z
        qs[f"q.{ql.name}.wn"] = jnp.float32(0.0)
        qs[f"q.{ql.name}.wp"] = jnp.float32(p)
        qs[f"q.{ql.name}.sa"] = jnp.float32(0.2)
        qs[f"q.{ql.name}.an"] = jnp.float32(-8.0)
        qs[f"q.{ql.name}.ap"] = jnp.float32(7.0)
    return qs


def test_quant_block_step_reduces_reconstruction(toy):
    m, params, bn = toy
    qs = _toy_qstate(m, params)
    x = jax.random.normal(jax.random.PRNGKey(10), (4,) + tuple(m.image))
    bounds = steps.collect_teacher(m, params, bn, x)
    learn = m.qstate_learnable(block=0)
    ms = {k: jnp.zeros_like(qs[k]) for k in learn}
    vs = {k: jnp.zeros_like(qs[k]) for k in learn}
    key = jax.random.PRNGKey(11)
    recs = []
    step = jax.jit(lambda qs_, ms_, vs_, t: steps.quant_block_step(
        m, 0, params, bn, qs_, ms_, vs_, t, bounds[0], bounds[1], key,
        jnp.float32(1e-4), jnp.float32(1e-2), jnp.float32(4e-5),
        jnp.float32(0.0), jnp.float32(20.0), jnp.float32(0.0)))
    for t in range(1, 41):
        out, ms, vs, loss, rec = step(qs, ms, vs, jnp.float32(t))
        qs.update(out)
        recs.append(float(rec))
    assert recs[-1] < recs[0]


def test_collect_teacher_vs_eval(toy):
    m, params, bn = toy
    x = jax.random.normal(jax.random.PRNGKey(12), (4,) + tuple(m.image))
    bounds = steps.collect_teacher(m, params, bn, x)
    logits = steps.eval_batch(m, params, bn, x)
    np.testing.assert_allclose(bounds[-1], logits, rtol=1e-5, atol=1e-5)


def test_collect_student_fp_limit(toy):
    """With huge act step-bounds and hard-equivalent softbits == FP? No --
    but with 8-bit-like fine grids the student stays close to teacher."""
    m, params, bn = toy
    qs = _toy_qstate(m, params, bits=8)
    x = jax.random.normal(jax.random.PRNGKey(13), (4,) + tuple(m.image))
    t = steps.collect_teacher(m, params, bn, x)
    sx = steps.collect_student(m, params, bn, qs, x,
                               jax.random.PRNGKey(14))
    err = float(jnp.abs(sx[1] - t[1]).mean())
    scale = float(jnp.abs(t[1]).mean())
    assert err < 0.5 * scale


def test_act_stats_positive(toy):
    m, params, bn = toy
    x = jax.random.normal(jax.random.PRNGKey(15), (4,) + tuple(m.image))
    st = steps.act_stats(m, params, bn, x)
    assert st.shape == (len(m.quant_layers()),)
    assert bool(jnp.all(st > 0))

"""Entrypoint catalogue consistency: declared arg/result specs match the
actual traced shapes for every entrypoint (jax.eval_shape -- no execution),
i.e. manifest.json can never drift from the graphs."""

import jax
import jax.numpy as jnp
import pytest

from compile.entries import build_entries, _NP
from compile.models import get_model


@pytest.fixture(scope="module")
def toy_entries():
    return build_entries(get_model("toy"))


def test_all_entries_shape_check(toy_entries):
    entries, meta = toy_entries
    assert len(entries) == 14 + meta["num_blocks"]
    for e in entries:
        out = jax.eval_shape(e.fn, *e.avals())
        assert len(out) == len(e.results), e.name
        for got, (name, dt, sh) in zip(out, e.results):
            assert tuple(got.shape) == tuple(sh), (e.name, name)
            assert got.dtype == _NP[dt], (e.name, name)


def test_manifest_meta(toy_entries):
    _, meta = toy_entries
    assert meta["model"] == "toy"
    assert meta["image"] == [16, 16, 3]
    assert len(meta["bounds"]) == meta["num_blocks"] + 1
    assert meta["bounds"][0] == [meta["batch"]["recon"], 16, 16, 3]
    learn = sum((v for v in meta["learnable"].values()), [])
    qnames = [n for n, _ in meta["qstate"]]
    assert all(l in qnames for l in learn)


def test_train_and_distill_arg_names_unique(toy_entries):
    entries, _ = toy_entries
    for e in entries:
        names = [n for n, _, _ in e.args]
        assert len(names) == len(set(names)), e.name

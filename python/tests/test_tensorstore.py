"""GTS1 round-trip tests (mirrors rust/src/store tests)."""

import numpy as np
import pytest

from compile import tensorstore


def test_roundtrip(tmp_path):
    p = tmp_path / "t.bin"
    tensors = [
        ("a", np.arange(24, dtype=np.float32).reshape(2, 3, 4)),
        ("b.scalar", np.float32(3.5).reshape(())),
        ("c", np.array([1, -2, 3], np.int32)),
        ("d", np.array([7, 8], np.uint32)),
    ]
    tensorstore.save(p, tensors)
    out = tensorstore.load(p)
    assert [n for n, _ in out] == [n for n, _ in tensors]
    for (_, x), (_, y) in zip(tensors, out):
        np.testing.assert_array_equal(np.asarray(x), y)
        assert np.asarray(x).dtype == y.dtype


def test_empty(tmp_path):
    p = tmp_path / "e.bin"
    tensorstore.save(p, [])
    assert tensorstore.load(p) == []


def test_bad_magic(tmp_path):
    p = tmp_path / "bad.bin"
    p.write_bytes(b"NOPE\x00\x00\x00\x00")
    with pytest.raises(AssertionError):
        tensorstore.load(p)


def test_unicode_names(tmp_path):
    p = tmp_path / "u.bin"
    tensorstore.save(p, [("q.layer.v", np.zeros((1,), np.float32))])
    assert tensorstore.load(p)[0][0] == "q.layer.v"

//! Artifact-cache + checkpoint benchmarks (DESIGN.md §9). In-tree
//! harness (no criterion in the offline image); harness = false.
//!
//! Always writes `BENCH_artifacts.json`: checkpoint write/read cost (the
//! engine's mid-phase durability overhead), cache store/load cost, and
//! key-computation cost. With artifacts present it additionally measures
//! cold vs warm `zsq` — the cache hit skips distill + quantize entirely —
//! and records both wall clocks.

use genie::artifacts::{distill_key, ArtifactCache, KeyBuilder};
use genie::coordinator::{
    teacher_cached, zsq, DistillCfg, Metrics, PretrainCfg, QuantCfg,
};
use genie::data::Dataset;
use genie::phase::checkpoint;
use genie::runtime::{Manifest, ModelRt, Runtime, Scalars};
use genie::store::Store;
use genie::tensor::{Pcg32, Tensor};
use genie::testutil::{bench_secs, report};

fn main() {
    let mut rng = Pcg32::new(13);
    let rt = Runtime::cpu().unwrap();

    // ---- checkpoint write/read: a distill-shaped carried set ---------
    // (generator params + Adam moments + latents, ~1.2 MiB) through the
    // atomic GTS1 path. This is what the engine pays every
    // `checkpoint_every` steps.
    let mut dev = rt.device_store();
    let mut carried = Vec::new();
    for i in 0..24 {
        for prefix in ["g", "am.g", "av.g"] {
            let name = format!("{prefix}{i}");
            dev.insert(&name, &Tensor::randn(&[64, 64], &mut rng, 1.0))
                .unwrap();
            carried.push(name);
        }
    }
    dev.insert("z", &Tensor::randn(&[64, 256], &mut rng, 1.0)).unwrap();
    carried.push("z".to_string());
    let mut host = Store::new();
    host.insert("rng", checkpoint::rng_tensor(&rng));
    let mut sc = Scalars::new();
    sc.insert("loss", 1.0);
    let trace = vec![(50usize, sc.clone()), (100usize, sc)];

    let dir = std::env::temp_dir().join("genie_bench_artifacts");
    std::fs::remove_dir_all(&dir).ok();
    std::fs::create_dir_all(&dir).unwrap();
    let ckpt_path = dir.join("shard0.ckpt");
    let mut ckpt_bytes = 0u64;
    let ckpt_secs = bench_secs(3, 50, || {
        ckpt_bytes = checkpoint::write(
            &ckpt_path, 100, &carried, &host, &trace, &mut dev,
        )
        .unwrap();
    });
    report("artifacts/checkpoint_write", ckpt_secs);
    let ckpt_read_secs = bench_secs(3, 50, || {
        std::hint::black_box(checkpoint::read(&ckpt_path).unwrap());
    });
    report("artifacts/checkpoint_read", ckpt_read_secs);
    // amortized per step at the default cadence
    println!(
        "checkpoint overhead: {ckpt_bytes} B/write, \
         {:.1} us/step at checkpoint_every=50",
        ckpt_secs * 1e6 / 50.0
    );

    // ---- cache store/load of a synthetic-calibration artifact --------
    let mut cache = ArtifactCache::open(&dir, true, false).unwrap();
    let key = KeyBuilder::new("bench").field("x", 1).finish();
    let mut art = Store::new();
    art.insert("images", Tensor::randn(&[128, 16, 16, 3], &mut rng, 1.0));
    let store_secs = bench_secs(3, 50, || {
        cache.store("bench", key, &art).unwrap();
    });
    report("artifacts/cache_store_384KiB", store_secs);
    let load_secs = bench_secs(3, 50, || {
        std::hint::black_box(cache.load("bench", key).unwrap());
    });
    report("artifacts/cache_load_384KiB", load_secs);

    // ---- key computation (FNV over config + teacher content) ---------
    let m = Manifest::from_json_text(
        r#"{
            "model": "bench", "image": [16, 16, 3], "num_classes": 10,
            "num_blocks": 2, "latent": 256,
            "batch": {"train": 64},
            "params": [], "bn": [], "qstate": [], "gen_params": [],
            "quant_layers": [], "learnable": {"0": []},
            "bounds": [], "entrypoints": {}
        }"#,
    )
    .unwrap();
    let mut teacher = Store::new();
    for i in 0..32 {
        teacher
            .insert(&format!("w{i}"), Tensor::randn(&[32, 32], &mut rng, 1.0));
    }
    let dcfg = DistillCfg::default();
    let key_secs = bench_secs(3, 200, || {
        // including the teacher content hash — the dominant cost, paid
        // once per pipeline run and shared across its stage keys
        std::hint::black_box(distill_key(&m, &dcfg, teacher.content_hash()));
    });
    report("artifacts/distill_key_128KiB_teacher", key_secs);

    // ---- cold vs warm zsq (needs artifacts + real PJRT) --------------
    let mut cold_secs = -1.0f64;
    let mut warm_secs = -1.0f64;
    if std::path::Path::new("artifacts/toy/manifest.json").exists() {
        let mrt = ModelRt::load(&rt, "artifacts", "toy").unwrap();
        let dataset = Dataset::load("artifacts").unwrap();
        let mut metrics = Metrics::new();
        let mut zcache =
            ArtifactCache::open(dir.join("zsq_cache"), true, false).unwrap();
        let pcfg = PretrainCfg { steps: 60, ..Default::default() };
        let dcfg = DistillCfg { samples: 64, steps: 40, ..Default::default() };
        let qcfg = QuantCfg { steps_per_block: 40, ..Default::default() };
        let teacher =
            teacher_cached(&mrt, &dataset, &pcfg, &mut zcache, &mut metrics)
                .unwrap();
        let t0 = std::time::Instant::now();
        zsq(&mrt, &teacher, &dataset, &dcfg, &qcfg, &mut zcache, &mut metrics)
            .unwrap();
        cold_secs = t0.elapsed().as_secs_f64();
        let t0 = std::time::Instant::now();
        zsq(&mrt, &teacher, &dataset, &dcfg, &qcfg, &mut zcache, &mut metrics)
            .unwrap();
        warm_secs = t0.elapsed().as_secs_f64();
        println!(
            "zsq: cold {cold_secs:.2}s -> warm {warm_secs:.2}s \
             ({:.0}x, cache hit skips distill+quantize)",
            cold_secs / warm_secs.max(1e-9)
        );
        assert!(
            warm_secs < cold_secs,
            "a full cache hit must beat the cold run"
        );
    } else {
        println!("bench artifacts/zsq_cold_warm: skipped (run `make artifacts`)");
    }

    // negative sentinel (-1.0) = artifact-gated section did not run
    let json = format!(
        "{{\n  \"checkpoint_write_secs\": {ckpt_secs:.6},\n  \
         \"checkpoint_read_secs\": {ckpt_read_secs:.6},\n  \
         \"checkpoint_bytes\": {ckpt_bytes},\n  \
         \"checkpoint_secs_per_step_every50\": {:.8},\n  \
         \"cache_store_secs\": {store_secs:.6},\n  \
         \"cache_load_secs\": {load_secs:.6},\n  \
         \"distill_key_secs\": {key_secs:.6},\n  \
         \"cold_zsq_secs\": {cold_secs:.4},\n  \
         \"warm_zsq_secs\": {warm_secs:.4}\n}}\n",
        ckpt_secs / 50.0,
    );
    std::fs::write("BENCH_artifacts.json", json).unwrap();
    println!("wrote BENCH_artifacts.json");
    std::fs::remove_dir_all(&dir).ok();
}

//! Grid-orchestrator benchmarks (DESIGN.md §11). In-tree harness (no
//! criterion in the offline image); harness = false.
//!
//! Always writes `BENCH_grid.json`: cell expansion, DAG build/dedupe and
//! dry-run render costs over a 240-cell grid (pure host work). With
//! artifacts present it additionally runs a 3-bit-width grid against the
//! same three runs executed sequentially, at workers=1 and 4 — all on
//! cold caches — and asserts the grid beats sequential at workers=4 (it
//! dispatches the shared teacher/distill once and interleaves the
//! rest).

use std::collections::BTreeMap;

use genie::artifacts::ArtifactCache;
use genie::coordinator::{
    distill_cached, eval_fp32, eval_quantized, quantize_cached,
    teacher_cached, Metrics, RunConfig,
};
use genie::data::Dataset;
use genie::exec::Parallelism;
use genie::grid::{self, AxisValue, GridOpts, GridPlan, RunGrid};
use genie::runtime::{Manifest, ModelRt, Runtime};
use genie::testutil::{bench_secs, report};

fn toy_manifest() -> Manifest {
    Manifest::from_json_text(
        r#"{
            "model": "toy", "image": [16, 16, 3], "num_classes": 10,
            "num_blocks": 2, "latent": 256,
            "batch": {"train": 64},
            "params": [], "bn": [], "qstate": [], "gen_params": [],
            "quant_layers": [], "learnable": {"0": []},
            "bounds": [], "entrypoints": {}
        }"#,
    )
    .unwrap()
}

fn small_cfg(cache_dir: &std::path::Path) -> RunConfig {
    let mut cfg = RunConfig {
        model: "toy".into(),
        artifacts: "artifacts".into(),
        cache_dir: cache_dir.to_string_lossy().into_owned(),
        ..Default::default()
    };
    cfg.apply_overrides(&[
        "pretrain.steps=30".into(),
        "distill.samples=64".into(),
        "distill.steps=6".into(),
        "quant.steps=8".into(),
    ])
    .unwrap();
    cfg
}

fn main() {
    let cfg = RunConfig { model: "toy".into(), ..Default::default() };

    // ---- expansion: 4 bits x 30 seeds x 2 sample counts = 240 cells --
    let grid = RunGrid::new()
        .axis(
            "bits",
            vec![
                AxisValue::Bits(4, 4),
                AxisValue::Bits(3, 4),
                AxisValue::Bits(2, 4),
                AxisValue::Bits(2, 2),
            ],
        )
        .axis("seed", (0..30u64).map(AxisValue::Seed).collect())
        .axis(
            "samples",
            vec![AxisValue::Samples(64), AxisValue::Samples(128)],
        );
    let expand_secs = bench_secs(3, 50, || {
        std::hint::black_box(grid.cells(&cfg).unwrap());
    });
    report("grid/expand_240_cells", expand_secs);

    // ---- DAG build + dedupe over those cells ------------------------
    let mut manifests = BTreeMap::new();
    manifests.insert("toy".to_string(), toy_manifest());
    let cells = grid.cells(&cfg).unwrap();
    let dag_secs = bench_secs(3, 50, || {
        std::hint::black_box(
            GridPlan::build(cells.clone(), &manifests, false).unwrap(),
        );
    });
    report("grid/dag_build_240_cells", dag_secs);
    let plan = GridPlan::build(cells.clone(), &manifests, false).unwrap();
    println!(
        "dag: {} cells -> {} nodes ({} naive, {} deduplicated away)",
        plan.cells.len(),
        plan.nodes.len(),
        plan.naive_stages(),
        plan.naive_stages() - plan.nodes.len()
    );
    let waves_secs = bench_secs(3, 50, || {
        std::hint::black_box(genie::exec::waves(&plan.deps()));
    });
    report("grid/waves_240_cells", waves_secs);

    // ---- dry-run render (DAG + cache resolution, no dataset) ---------
    let cache = ArtifactCache::disabled();
    let dry_secs = bench_secs(3, 20, || {
        std::hint::black_box(plan.render(&manifests, &cache, None));
    });
    report("grid/dry_run_render", dry_secs);

    // ---- wave vs dataflow on a heterogeneous stage DAG ---------------
    // One 200ms source plus three independent 10-deep chains of 15ms
    // nodes (pure sleeps — runs without artifacts). Wave barriers hold
    // every chain rank behind the slowest node of its wave, so the long
    // source stalls all three chains (~335ms at 4 workers); the
    // dataflow ready queue drains the chains beside it (~200ms).
    let (chains, depth) = (3usize, 10usize);
    let n = 1 + chains * depth;
    let mut deps: Vec<Vec<usize>> = vec![Vec::new(); n];
    let mut ms = vec![15u64; n];
    ms[0] = 200;
    for c in 0..chains {
        for j in 1..depth {
            let id = 1 + c * depth + j;
            deps[id] = vec![id - 1];
        }
    }
    let par = Parallelism::new(4);
    let sleep_job = |i: usize| {
        std::thread::sleep(std::time::Duration::from_millis(ms[i]));
    };

    let t0 = std::time::Instant::now();
    for wave in &genie::exec::waves(&deps) {
        let jobs: Vec<_> = wave
            .iter()
            .map(|&i| move || -> anyhow::Result<()> { Ok(sleep_job(i)) })
            .collect();
        genie::exec::run_jobs(par, jobs).unwrap();
    }
    let wave_secs = t0.elapsed().as_secs_f64();
    report("grid/sched_wave_w4", wave_secs);

    let t0 = std::time::Instant::now();
    let prio = genie::exec::critical_path(&deps);
    let (_nodes, dag_rep) =
        genie::exec::run_dag(par, &deps, &prio, |i| (sleep_job(i), true));
    let dataflow_secs = t0.elapsed().as_secs_f64();
    let dataflow_util = dag_rep.pool.utilization();
    report("grid/sched_dataflow_w4", dataflow_secs);
    println!(
        "sched: dataflow {dataflow_secs:.3}s vs wave {wave_secs:.3}s \
         ({:.2}x; dataflow utilization {dataflow_util:.2})",
        wave_secs / dataflow_secs.max(1e-9)
    );
    assert!(
        dataflow_secs < wave_secs,
        "dataflow ({dataflow_secs:.3}s) must beat wave barriers \
         ({wave_secs:.3}s) on the heterogeneous DAG at workers=4"
    );

    // ---- grid vs sequential wall clock (needs artifacts + PJRT) ------
    let mut seq_w1 = -1.0f64;
    let mut seq_w4 = -1.0f64;
    let mut grid_w1 = -1.0f64;
    let mut grid_w4 = -1.0f64;
    let mut dedup_saved = -1.0f64;
    let mut cache_stores = -1.0f64;
    if std::path::Path::new("artifacts/toy/manifest.json").exists() {
        let rt = Runtime::cpu().unwrap();
        let root = std::env::temp_dir().join("genie_bench_grid");
        std::fs::remove_dir_all(&root).ok();
        let bits = [(4u32, 4u32), (3, 4), (2, 4)];

        for workers in [1usize, 4] {
            // sequential: each cell a standalone run on its own cold
            // cache — every run pays its own teacher + distill
            let t0 = std::time::Instant::now();
            for (i, (w, a)) in bits.iter().enumerate() {
                let mut c = small_cfg(
                    &root.join(format!("seq_w{workers}_{i}")),
                );
                c.set("wbits", &w.to_string()).unwrap();
                c.set("abits", &a.to_string()).unwrap();
                c.set("workers", &workers.to_string()).unwrap();
                let mrt =
                    ModelRt::load(&rt, &c.artifacts, &c.model).unwrap();
                let dataset = Dataset::load(&c.artifacts).unwrap();
                let mut metrics = Metrics::new();
                let mut cache =
                    ArtifactCache::open(&c.cache_dir, true, false).unwrap();
                let teacher = teacher_cached(
                    &mrt, &dataset, &c.pretrain, &mut cache, &mut metrics,
                )
                .unwrap();
                let out = distill_cached(
                    &mrt, &teacher, &c.distill, &mut cache, &mut metrics,
                )
                .unwrap();
                let qstate = quantize_cached(
                    &mrt, &teacher, &out.images, &c.quant, &mut cache,
                    &mut metrics,
                )
                .unwrap();
                std::hint::black_box(
                    eval_fp32(&mrt, &teacher, &dataset).unwrap(),
                );
                std::hint::black_box(
                    eval_quantized(&mrt, &teacher, &qstate, &dataset)
                        .unwrap(),
                );
            }
            let seq = t0.elapsed().as_secs_f64();

            // grid: the same three cells on the shared-artifact
            // scheduler, cold cache
            let mut c = small_cfg(&root.join(format!("grid_w{workers}")));
            c.set("workers", &workers.to_string()).unwrap();
            let g = RunGrid::new().axis(
                "bits",
                bits.iter().map(|&(w, a)| AxisValue::Bits(w, a)).collect(),
            );
            let mut metrics = Metrics::new();
            let t0 = std::time::Instant::now();
            let out = grid::execute(
                &rt, &c, &g, &GridOpts::default(), &mut metrics,
            )
            .unwrap();
            let gsecs = t0.elapsed().as_secs_f64();
            println!(
                "grid vs sequential @ workers={workers}: \
                 {gsecs:.2}s vs {seq:.2}s ({:.2}x; {} stages deduplicated)",
                seq / gsecs.max(1e-9),
                out.stats.dedup_saved()
            );
            if workers == 1 {
                seq_w1 = seq;
                grid_w1 = gsecs;
            } else {
                seq_w4 = seq;
                grid_w4 = gsecs;
                dedup_saved = out.stats.dedup_saved() as f64;
                cache_stores = out.stats.cache.stores as f64;
                assert!(
                    gsecs < seq,
                    "grid ({gsecs:.2}s) must beat sequential ({seq:.2}s) \
                     at workers=4"
                );
            }
        }
        std::fs::remove_dir_all(&root).ok();
    } else {
        println!("bench grid/vs_sequential: skipped (run `make artifacts`)");
    }

    // negative sentinel (-1.0) = artifact-gated section did not run
    let json = format!(
        "{{\n  \"expand_secs\": {expand_secs:.6},\n  \
         \"dag_build_secs\": {dag_secs:.6},\n  \
         \"waves_secs\": {waves_secs:.6},\n  \
         \"dry_run_secs\": {dry_secs:.6},\n  \
         \"seq_w1_secs\": {seq_w1:.4},\n  \
         \"seq_w4_secs\": {seq_w4:.4},\n  \
         \"grid_w1_secs\": {grid_w1:.4},\n  \
         \"grid_w4_secs\": {grid_w4:.4},\n  \
         \"dedup_saved\": {dedup_saved:.0},\n  \
         \"cache_stores\": {cache_stores:.0}\n}}\n"
    );
    std::fs::write("BENCH_grid.json", json).unwrap();
    println!("wrote BENCH_grid.json");

    let sched_json = format!(
        "{{\n  \"wave_w4_secs\": {wave_secs:.4},\n  \
         \"dataflow_w4_secs\": {dataflow_secs:.4},\n  \
         \"speedup\": {:.3},\n  \
         \"dataflow_utilization\": {dataflow_util:.4}\n}}\n",
        wave_secs / dataflow_secs.max(1e-9)
    );
    std::fs::write("BENCH_sched.json", sched_json).unwrap();
    println!("wrote BENCH_sched.json");
}

//! Precision-plan benchmarks (DESIGN.md §10). In-tree harness (no
//! criterion in the offline image); harness = false.
//!
//! Always writes `BENCH_precision.json`: the host-side costs of the
//! Pareto machinery — greedy bit allocation over a wide synthetic
//! sensitivity table, one fake-quant sensitivity probe, plan
//! fingerprint/key computation, and the plan GTS1 round-trip. With
//! artifacts present it additionally measures the real sensitivity
//! sweep on the toy model and uniform-vs-pareto end-to-end `zsq` wall
//! clocks.

use genie::artifacts::{quantize_key, ArtifactCache};
use genie::coordinator::{
    pretrain, zsq, DistillCfg, Metrics, PretrainCfg, QuantCfg,
};
use genie::data::Dataset;
use genie::precision::sensitivity::{allocate_bits, measure_sensitivity};
use genie::precision::{Granularity, Policy, PrecisionPlan};
use genie::quant::fake_quant_weights;
use genie::runtime::{Manifest, ModelRt, Runtime};
use genie::store::Store;
use genie::tensor::{Pcg32, Tensor};
use genie::testutil::{bench_secs, report};

/// A synthetic `L`-quant-layer manifest for host-side plan costs.
fn wide_manifest(l: usize) -> Manifest {
    let layers: Vec<String> = (0..l)
        .map(|i| {
            format!(
                r#"{{"name": "conv{i}", "w_shape": [3, 3, 64, 64],
                    "out_ch": 64, "flat_k": 576, "block": 0}}"#
            )
        })
        .collect();
    Manifest::from_json_text(&format!(
        r#"{{
            "model": "wide", "image": [32, 32, 3], "num_classes": 10,
            "num_blocks": 4, "latent": 64,
            "batch": {{"train": 32, "eval": 32}},
            "params": [], "bn": [], "qstate": [], "gen_params": [],
            "quant_layers": [{}], "learnable": {{"0": []}},
            "bounds": [], "entrypoints": {{}}
        }}"#,
        layers.join(",")
    ))
    .unwrap()
}

fn main() {
    let mut rng = Pcg32::new(17);

    // ---- greedy allocation over a 64-layer x 6-candidate table -------
    let l = 64usize;
    let candidates = vec![2u32, 3, 4, 5, 6, 8];
    let kl: Vec<Vec<f32>> = (0..l)
        .map(|_| {
            let base = 0.1 + rng.uniform() * 5.0;
            candidates
                .iter()
                .map(|&b| base / (b as f32 * b as f32))
                .collect()
        })
        .collect();
    let numel = vec![64 * 576usize; l];
    let pinned: Vec<Option<u32>> = (0..l)
        .map(|i| if i == 0 || i == l - 1 { Some(8) } else { None })
        .collect();
    let budget = (l * 64 * 576) * 4; // the average-4-bit budget
    let alloc_secs = bench_secs(3, 200, || {
        std::hint::black_box(
            allocate_bits(&kl, &candidates, &numel, &pinned, budget)
                .unwrap(),
        );
    });
    report("precision/allocate_64x6", alloc_secs);

    // ---- one sensitivity probe's host half: fake-quant a conv layer --
    let w = Tensor::randn(&[3, 3, 64, 64], &mut rng, 0.2);
    let probe_secs = bench_secs(1, 10, || {
        std::hint::black_box(
            fake_quant_weights(&w, 4, 2.4, Granularity::PerChannel).unwrap(),
        );
    });
    report("precision/fake_quant_3x3x64x64", probe_secs);

    // ---- plan fingerprint + qstate key over a wide manifest ----------
    let m = wide_manifest(l);
    let plan = PrecisionPlan::uniform(&m, 4, 4, Granularity::PerChannel)
        .unwrap()
        .with_first_last(8)
        .unwrap();
    let qcfg = QuantCfg::default();
    let calib = Tensor::randn(&[8, 32, 32, 3], &mut rng, 1.0);
    let key_secs = bench_secs(3, 200, || {
        std::hint::black_box(quantize_key(&m, &qcfg, 0x5eed, &calib, &plan));
    });
    report("precision/quantize_key_64_layer_plan", key_secs);

    // ---- plan GTS1 round-trip (the plan-artifact cache payload) ------
    let dir = std::env::temp_dir().join("genie_bench_precision");
    std::fs::remove_dir_all(&dir).ok();
    std::fs::create_dir_all(&dir).unwrap();
    let path = dir.join("plan.gts");
    let roundtrip_secs = bench_secs(3, 100, || {
        plan.to_store().save(&path).unwrap();
        std::hint::black_box(
            PrecisionPlan::from_store(&m, &Store::load(&path).unwrap())
                .unwrap(),
        );
    });
    report("precision/plan_gts1_roundtrip_64_layers", roundtrip_secs);

    // ---- real sensitivity sweep + uniform-vs-pareto zsq (gated) ------
    let mut sens_secs = -1.0f64;
    let mut zsq_uniform_secs = -1.0f64;
    let mut zsq_pareto_secs = -1.0f64;
    if std::path::Path::new("artifacts/toy/manifest.json").exists() {
        let rt = Runtime::cpu().unwrap();
        let mrt = ModelRt::load(&rt, "artifacts", "toy").unwrap();
        let dataset = Dataset::load("artifacts").unwrap();
        let mut metrics = Metrics::new();
        let pcfg = PretrainCfg { steps: 60, ..Default::default() };
        let teacher = pretrain(&mrt, &dataset, &pcfg, &mut metrics).unwrap();
        let dcfg = DistillCfg { samples: 64, steps: 30, ..Default::default() };
        let qcfg = QuantCfg { steps_per_block: 30, ..Default::default() };

        // sensitivity-sweep cost: every (layer, candidate) probe
        let mut rng2 = Pcg32::new(3);
        let (calib, _) = dataset.calibration(&mut rng2, 64);
        let t0 = std::time::Instant::now();
        let (sens, _) = measure_sensitivity(
            &mrt,
            &teacher,
            &calib,
            &qcfg.precision,
            qcfg.pnorm,
            qcfg.par,
        )
        .unwrap();
        sens_secs = t0.elapsed().as_secs_f64();
        println!(
            "sensitivity sweep: {} layers x {} candidates in {sens_secs:.2}s",
            sens.layers.len(),
            sens.candidates.len()
        );

        // end-to-end: uniform vs pareto (uncached, real wall clocks)
        let mut cache = ArtifactCache::disabled();
        let t0 = std::time::Instant::now();
        zsq(&mrt, &teacher, &dataset, &dcfg, &qcfg, &mut cache, &mut metrics)
            .unwrap();
        zsq_uniform_secs = t0.elapsed().as_secs_f64();
        let mut pareto = qcfg.clone();
        pareto.precision.policy = Policy::Pareto;
        pareto.precision.target_size = 0.25;
        let t0 = std::time::Instant::now();
        zsq(
            &mrt, &teacher, &dataset, &dcfg, &pareto, &mut cache,
            &mut metrics,
        )
        .unwrap();
        zsq_pareto_secs = t0.elapsed().as_secs_f64();
        println!(
            "zsq: uniform {zsq_uniform_secs:.2}s vs pareto \
             {zsq_pareto_secs:.2}s (plan overhead \
             {:.2}s)",
            zsq_pareto_secs - zsq_uniform_secs
        );
    } else {
        println!(
            "bench precision/sensitivity_sweep: skipped (run `make artifacts`)"
        );
    }

    // negative sentinel (-1.0) = artifact-gated section did not run
    let json = format!(
        "{{\n  \"allocate_64x6_secs\": {alloc_secs:.6},\n  \
         \"fake_quant_probe_secs\": {probe_secs:.6},\n  \
         \"quantize_key_secs\": {key_secs:.6},\n  \
         \"plan_roundtrip_secs\": {roundtrip_secs:.6},\n  \
         \"sensitivity_sweep_secs\": {sens_secs:.4},\n  \
         \"zsq_uniform_secs\": {zsq_uniform_secs:.4},\n  \
         \"zsq_pareto_secs\": {zsq_pareto_secs:.4}\n}}\n"
    );
    std::fs::write("BENCH_precision.json", json).unwrap();
    println!("wrote BENCH_precision.json");
    std::fs::remove_dir_all(&dir).ok();
}

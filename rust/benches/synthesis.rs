//! Synthesis-engine benchmarks (DESIGN.md §12). In-tree harness (no
//! criterion in the offline image); harness = false.
//!
//! Always writes `BENCH_synthesis.json`: host-side costs of the engine
//! machinery — per-engine distill cache keys and a DAG build over a
//! `synthesis x bits` ablation grid. With artifacts present it
//! additionally distills one small calibration set per engine on the
//! toy model (cold, uncached) and reports the per-engine wall clock —
//! the number the grid scheduler amortizes. Engines whose step graphs
//! the compiled artifacts predate (zaq on pre-§12 bundles) stay at the
//! -1.0 sentinel.

use std::collections::BTreeMap;

use genie::artifacts::{distill_spec_key, pretrain_key};
use genie::coordinator::{
    distill, pretrain, DistillCfg, Metrics, PretrainCfg, RunConfig,
};
use genie::data::Dataset;
use genie::grid::{AxisValue, GridPlan, RunGrid};
use genie::runtime::{Manifest, ModelRt, Runtime};
use genie::synthesis::Engine;
use genie::testutil::{bench_secs, report};

const ENGINES: [Engine; 3] = [Engine::Genie, Engine::Zeroq, Engine::Zaq];

fn toy_manifest() -> Manifest {
    Manifest::from_json_text(
        r#"{
            "model": "toy", "image": [16, 16, 3], "num_classes": 10,
            "num_blocks": 2, "latent": 256,
            "batch": {"train": 64},
            "params": [], "bn": [], "qstate": [], "gen_params": [],
            "quant_layers": [], "learnable": {"0": []},
            "bounds": [], "entrypoints": {}
        }"#,
    )
    .unwrap()
}

fn main() {
    let m = toy_manifest();

    // ---- per-engine distill keys: the folds every cache probe pays ---
    let tspec = pretrain_key(&m, &PretrainCfg::default());
    let key_secs = bench_secs(3, 200, || {
        for e in ENGINES {
            let cfg = DistillCfg { engine: e, ..Default::default() };
            std::hint::black_box(distill_spec_key(&m, &cfg, tspec));
        }
    });
    report("synthesis/spec_keys_3_engines", key_secs);

    // ---- DAG build over a synthesis x bits ablation grid -------------
    let cfg = RunConfig { model: "toy".into(), ..Default::default() };
    let grid = RunGrid::new()
        .axis(
            "synthesis",
            ENGINES.iter().copied().map(AxisValue::Synthesis).collect(),
        )
        .axis(
            "bits",
            vec![
                AxisValue::Bits(4, 4),
                AxisValue::Bits(3, 4),
                AxisValue::Bits(2, 4),
            ],
        )
        .axis("seed", (0..8u64).map(AxisValue::Seed).collect());
    let mut manifests = BTreeMap::new();
    manifests.insert("toy".to_string(), m);
    let cells = grid.cells(&cfg).unwrap();
    let dag_secs = bench_secs(3, 50, || {
        std::hint::black_box(
            GridPlan::build(cells.clone(), &manifests, false).unwrap(),
        );
    });
    report("synthesis/dag_build_72_cells", dag_secs);
    let plan = GridPlan::build(cells, &manifests, false).unwrap();
    println!(
        "dag: {} cells -> {} nodes ({} naive; one distill set per \
         engine/seed, teachers shared across engines)",
        plan.cells.len(),
        plan.nodes.len(),
        plan.naive_stages()
    );

    // ---- per-engine distill wall clock (needs artifacts + PJRT) ------
    let mut engine_secs = [-1.0f64; 3];
    if std::path::Path::new("artifacts/toy/manifest.json").exists() {
        let rt = Runtime::cpu().unwrap();
        let mrt = ModelRt::load(&rt, "artifacts", "toy").unwrap();
        let dataset = Dataset::load("artifacts").unwrap();
        let mut metrics = Metrics::new();
        let pcfg = PretrainCfg { steps: 60, ..Default::default() };
        let teacher = pretrain(&mrt, &dataset, &pcfg, &mut metrics).unwrap();

        for (i, e) in ENGINES.into_iter().enumerate() {
            let dcfg = DistillCfg {
                engine: e,
                samples: 64,
                steps: 30,
                ..Default::default()
            };
            let entry = e.policy().entry(&dcfg, "swing");
            if !mrt.manifest.entrypoints.contains_key(&entry) {
                println!(
                    "bench synthesis/distill_{}: skipped (artifacts \
                     predate entry '{entry}')",
                    e.as_str()
                );
                continue;
            }
            let t0 = std::time::Instant::now();
            let out = distill(&mrt, &teacher, &dcfg, &mut metrics).unwrap();
            engine_secs[i] = t0.elapsed().as_secs_f64();
            println!(
                "distill[{}]: {} samples in {:.2}s (final loss {:.4})",
                e.as_str(),
                out.images.shape[0],
                engine_secs[i],
                out.final_loss
            );
            report(&format!("synthesis/distill_{}", e.as_str()),
                   engine_secs[i]);
        }
    } else {
        println!(
            "bench synthesis/distill_per_engine: skipped (run `make \
             artifacts`)"
        );
    }

    // negative sentinel (-1.0) = artifact-gated section did not run
    let json = format!(
        "{{\n  \"spec_keys_3_engines_secs\": {key_secs:.6},\n  \
         \"dag_build_72_cells_secs\": {dag_secs:.6},\n  \
         \"distill_genie_secs\": {:.4},\n  \
         \"distill_zeroq_secs\": {:.4},\n  \
         \"distill_zaq_secs\": {:.4}\n}}\n",
        engine_secs[0], engine_secs[1], engine_secs[2]
    );
    std::fs::write("BENCH_synthesis.json", json).unwrap();
    println!("wrote BENCH_synthesis.json");
}

//! Host-side GENIE-M initialization benches: the Eq. 6 / Eq. A3 p-norm
//! grid search, weight flattening, and softbit init (the only non-PJRT
//! compute on the quantization path).

use genie::quant::{flatten_out_major, search_step_sizes, softbit_init};
use genie::tensor::{Pcg32, Tensor};
use genie::testutil::{bench_secs, report};

fn main() {
    let mut rng = Pcg32::new(11);
    for (o, k, label) in [
        (16usize, 144usize, "conv3x3_16x16"),
        (64, 576, "conv3x3_64x64"),
        (256, 256, "conv1x1_256x256"),
    ] {
        let rows: Vec<f32> =
            (0..o * k).map(|_| rng.normal() * 0.2).collect();
        report(
            &format!("quant_init/grid_search_{label}"),
            bench_secs(1, 10, || {
                std::hint::black_box(search_step_sizes(&rows, o, k, 4, 2.4));
            }),
        );
    }
    let w = Tensor::randn(&[3, 3, 64, 64], &mut rng, 0.2);
    report("quant_init/flatten_3x3x64x64", bench_secs(3, 100, || {
        std::hint::black_box(flatten_out_major(&w));
    }));
    report("quant_init/softbit_init_1e5", bench_secs(3, 50, || {
        let mut acc = 0.0f32;
        for i in 0..100_000 {
            acc += softbit_init((i as f32 / 100_000.0).clamp(0.01, 0.99));
        }
        std::hint::black_box(acc);
    }));
}

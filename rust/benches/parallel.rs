//! Exec-pool scaling bench: distill-shard and quant-block shaped
//! workloads at 1/2/4/8 workers (DESIGN.md §5). The synthetic sections
//! are pure host math so they run in the offline image; the final section
//! drives the real distill+quantize graphs and is artifact-gated like the
//! other benches. Every section asserts that the multi-worker result is
//! bit-identical to the serial one before reporting throughput.

use genie::exec::{chain_deps, independent_deps, run_jobs, waves, Parallelism};
use genie::runtime::{DeviceStore, Runtime};
use genie::store::Store;
use genie::tensor::{Pcg32, Tensor};
use genie::testutil::{bench_secs, report};

const WORKER_SWEEP: [usize; 4] = [1, 2, 4, 8];

/// A distill-shard-shaped job: synthesize a [64, 16, 16, 3] image batch
/// from the shard-keyed stream, then run a few smoothing/reduction sweeps
/// standing in for optimizer steps. Returns a checksum of the images.
fn synth_shard(seed: u64, shard: u64) -> f64 {
    let mut rng = Pcg32::new_stream(seed, shard);
    let t = Tensor::randn(&[64, 16, 16, 3], &mut rng, 1.0);
    let mut v = t.as_f32().to_vec();
    for _ in 0..20 {
        for i in 1..v.len() {
            v[i] = 0.5 * v[i] + 0.5 * v[i - 1];
        }
    }
    v.iter().map(|&x| x as f64).sum()
}

/// A quant-block-shaped job: per-block soft-rounding state optimized for a
/// fixed number of steps against stream-drawn "activations".
fn recon_block(seed: u64, block: u64) -> f64 {
    let mut rng = Pcg32::new_stream(seed, block);
    let mut state: Vec<f32> = (0..4096).map(|_| rng.normal()).collect();
    for _ in 0..50 {
        let target = rng.normal();
        for s in state.iter_mut() {
            *s -= 0.01 * (*s - target);
        }
    }
    state.iter().map(|&x| x as f64).sum()
}

fn run_shards(par: Parallelism, n: usize) -> Vec<f64> {
    let jobs: Vec<_> = (0..n as u64)
        .map(|b| move || Ok(synth_shard(7, b)))
        .collect();
    run_jobs(par, jobs).unwrap().0
}

/// A device-resident shard job: alias the shared base (zero transfer),
/// push shard-keyed learnables on top, fetch the "result" back. Returns
/// the fetched tensor and the shard's own h2d byte count.
fn device_shard(base: &DeviceStore<'_>, seed: u64, shard: u64) -> (Tensor, u64) {
    let mut rng = Pcg32::new_stream(seed, shard);
    let mut dev = base.clone();
    dev.insert("z", &Tensor::randn(&[16, 32], &mut rng, 1.0)).unwrap();
    dev.insert("t", &Tensor::scalar_f32(shard as f32)).unwrap();
    let z = dev.fetch("z").unwrap();
    (z, dev.transfer_bytes().0)
}

fn run_blocks(par: Parallelism, deps: &[Vec<usize>]) -> Vec<f64> {
    let mut out = vec![0.0; deps.len()];
    for wave in waves(deps) {
        let jobs: Vec<_> = wave
            .iter()
            .map(|&b| move || Ok(recon_block(31, b as u64)))
            .collect();
        let (res, _) = run_jobs(par, jobs).unwrap();
        for (&b, r) in wave.iter().zip(res) {
            out[b] = r;
        }
    }
    out
}

fn main() {
    // pool dispatch overhead: 64 empty jobs
    for &w in &WORKER_SWEEP {
        let par = Parallelism::new(w);
        let secs = bench_secs(2, 10, || {
            let jobs: Vec<_> = (0..64usize).map(|i| move || Ok(i)).collect();
            let _ = run_jobs(par, jobs).unwrap();
        });
        report(&format!("parallel/pool_overhead_64jobs_w{w}"), secs);
    }

    // distill: 16 independent latent shards
    let reference = run_shards(Parallelism::SERIAL, 16);
    for &w in &WORKER_SWEEP {
        let par = Parallelism::new(w);
        assert_eq!(run_shards(par, 16), reference,
                   "distill shards must be worker-count invariant");
        let secs = bench_secs(1, 5, || {
            std::hint::black_box(run_shards(par, 16));
        });
        report(&format!("parallel/distill_16shards_w{w}"), secs);
    }

    // quantize: 8 blocks, independent (one wave) vs chained (serial gate)
    let indep = independent_deps(8);
    let chain = chain_deps(8);
    let ref_blocks = run_blocks(Parallelism::SERIAL, &indep);
    for &w in &WORKER_SWEEP {
        let par = Parallelism::new(w);
        assert_eq!(run_blocks(par, &indep), ref_blocks,
                   "block recon must be worker-count invariant");
        assert_eq!(run_blocks(par, &chain), ref_blocks,
                   "wave gating must not change results");
        let secs = bench_secs(1, 5, || {
            std::hint::black_box(run_blocks(par, &indep));
        });
        report(&format!("parallel/quant_8blocks_indep_w{w}"), secs);
    }
    let secs = bench_secs(1, 5, || {
        std::hint::black_box(run_blocks(Parallelism::new(4), &chain));
    });
    report("parallel/quant_8blocks_chained_w4", secs);

    // device-store sharding (DESIGN.md §8): one uploaded base store is
    // Arc-shared across pool workers; each shard's inserts copy-on-write
    // onto its clone. The roundtrip arm re-uploads the base per shard —
    // the old per-shard teacher clone — for the transfer comparison.
    let rt = Runtime::cpu().unwrap();
    let mut base = Store::new();
    let mut rng = Pcg32::new(3);
    for i in 0..16 {
        base.insert(&format!("p{i}"), Tensor::randn(&[64, 64], &mut rng, 1.0));
    }
    let base_dev = rt.upload_store(&base).unwrap();
    let base_bytes = base_dev.transfer_bytes().0;
    let run_dev = |workers: usize| -> (Vec<Tensor>, u64) {
        let dev = &base_dev;
        let jobs: Vec<_> = (0..16u64)
            .map(|b| move || Ok(device_shard(dev, 11, b)))
            .collect();
        let (out, _) = run_jobs(Parallelism::new(workers), jobs).unwrap();
        let h2d: u64 = out.iter().map(|(_, x)| *x).sum();
        (out.into_iter().map(|(t, _)| t).collect(), h2d)
    };
    let (reference, shard_h2d) = run_dev(1);
    println!(
        "parallel/device_shards transfer: {} B shared upload + {} B \
         shard-local vs {} B if each of 16 shards re-uploaded the base",
        base_bytes,
        shard_h2d,
        base_bytes * 16 + shard_h2d
    );
    for &w in &WORKER_SWEEP {
        assert_eq!(run_dev(w).0, reference,
                   "device shards must be worker-count invariant");
        let secs = bench_secs(1, 5, || {
            std::hint::black_box(run_dev(w));
        });
        report(&format!("parallel/device_16shards_w{w}"), secs);
    }

    // real graphs, artifact-gated like benches/pipeline.rs
    if !std::path::Path::new("artifacts/toy/manifest.json").exists() {
        println!("bench parallel/zsq_*: skipped (run `make artifacts`)");
        return;
    }
    real_pipeline_section();
}

/// Distill + quantize over the real toy artifacts at 1 vs 4 workers.
fn real_pipeline_section() {
    use genie::coordinator::pretrain::{teacher_or_pretrain, PretrainCfg};
    use genie::coordinator::{distill, quantize, DistillCfg, Metrics, QuantCfg};
    use genie::data::Dataset;
    use genie::runtime::{ModelRt, Runtime};

    let rt = Runtime::cpu().unwrap();
    let mrt = ModelRt::load(&rt, "artifacts", "toy").unwrap();
    let dataset = Dataset::load("artifacts").unwrap();
    let mut metrics = Metrics::new();
    let teacher = teacher_or_pretrain(
        &mrt, &dataset,
        &PretrainCfg { steps: 30, ..Default::default() },
        std::path::Path::new("runs"), &mut metrics,
    )
    .unwrap();

    let mut images = None;
    for &w in &WORKER_SWEEP {
        let dcfg = DistillCfg {
            samples: 128,
            steps: 30,
            par: Parallelism::new(w),
            ..Default::default()
        };
        let secs = bench_secs(0, 2, || {
            let out = distill(&mrt, &teacher, &dcfg, &mut metrics).unwrap();
            match images.take() {
                None => images = Some(out.images),
                Some(r) => {
                    assert_eq!(out.images, r,
                               "distill must be worker-count invariant");
                    images = Some(r);
                }
            }
        });
        report(&format!("parallel/zsq_distill_128_w{w}"), secs);
    }
    let images = images.unwrap();

    for &w in &WORKER_SWEEP {
        let qcfg = QuantCfg {
            steps_per_block: 20,
            refresh_student: false, // independent blocks -> one wave
            par: Parallelism::new(w),
            ..Default::default()
        };
        let secs = bench_secs(0, 2, || {
            let q = quantize(&mrt, &teacher, &images, &qcfg, &mut metrics);
            std::hint::black_box(q.unwrap());
        });
        report(&format!("parallel/zsq_quantize_w{w}"), secs);
    }
}

//! Runtime micro-benchmarks: entrypoint dispatch latency (the L3 hot
//! path), literal marshalling, store ops, tensorstore IO, and the
//! resident-vs-roundtrip transfer comparison (DESIGN.md §8).
//! In-tree harness (no criterion in the offline image); harness = false.
//!
//! Always writes `BENCH_runtime.json` (per-step transfer bytes +
//! steps/sec for the distill-shaped step loop) — the CI smoke artifact.

use genie::coordinator::Metrics;
use genie::coordinator::pretrain::{teacher_or_pretrain, PretrainCfg};
use genie::data::Dataset;
use genie::phase::{Phase, StepLoop};
use genie::runtime::{to_literal, DeviceStore, ModelRt, Runtime};
use genie::store::Store;
use genie::tensor::{Pcg32, Tensor};
use genie::testutil::{bench_secs, report};

/// The per-step scalar traffic of a distill step: key/t/lr_g/lr_z.
fn step_scalars(dev: &mut DeviceStore, t: usize) {
    dev.insert("key", &Tensor::key(t as u32, 1)).unwrap();
    dev.insert("t", &Tensor::scalar_f32(t as f32)).unwrap();
    dev.insert("lr_g", &Tensor::scalar_f32(0.01)).unwrap();
    dev.insert("lr_z", &Tensor::scalar_f32(0.1)).unwrap();
}

/// A minimal fusible phase over the registered host-fn step graph: one
/// carried scalar, one scalar feed per step, no after_step device work.
struct FusedBenchPhase;

impl Phase for FusedBenchPhase {
    fn name(&self) -> String {
        "bench_fused".into()
    }

    fn entry(&self) -> String {
        "bench_step".into()
    }

    fn init(&mut self, dev: &mut DeviceStore) -> anyhow::Result<()> {
        dev.insert("state", &Tensor::scalar_f32(1.0))
    }

    fn before_step(
        &mut self,
        _t: usize,
        dev: &mut DeviceStore,
    ) -> anyhow::Result<()> {
        dev.insert("lr", &Tensor::scalar_f32(0.01))
    }

    fn carried(&self) -> Vec<String> {
        vec!["state".into()]
    }

    fn fusible(&self) -> bool {
        true
    }

    fn finish(&mut self, dev: &mut DeviceStore) -> anyhow::Result<Store> {
        let mut out = Store::new();
        out.insert("state", dev.fetch("state")?);
        Ok(out)
    }
}

/// Register the host-fn step graph the fused sweep drives (state' =
/// 0.999·state + lr; loss = state') and wrap it in a [`ModelRt`]. The
/// executable is a host function, so the sweep runs in the offline stub.
fn fused_bench_mrt(rt: &Runtime) -> ModelRt<'_> {
    let manifest = genie::runtime::Manifest::from_json_text(
        r#"{
            "model": "bench", "image": [2, 2, 1], "num_classes": 2,
            "num_blocks": 1, "latent": 4,
            "batch": {"train": 1},
            "params": [], "bn": [], "qstate": [], "gen_params": [],
            "quant_layers": [], "learnable": {"0": []},
            "bounds": [], "entrypoints": {
                "bench_step": {
                    "file": "bench_step.hlo.txt",
                    "args": [
                        ["state", "f32", []],
                        ["lr", "f32", []]
                    ],
                    "results": [
                        ["state", "f32", []],
                        ["loss", "f32", []]
                    ]
                }
            }
        }"#,
    )
    .unwrap();
    let spec = manifest.entry("bench_step").unwrap().clone();
    let exe = xla::PjRtLoadedExecutable::from_host_fn(2, |args| {
        let state = args[0].to_vec::<f32>()?[0];
        let lr = args[1].to_vec::<f32>()?[0];
        let next = state * 0.999 + lr;
        Ok(vec![
            xla::Literal::vec1(&[next]).reshape(&[])?,
            xla::Literal::vec1(&[next]).reshape(&[])?,
        ])
    });
    rt.register_entry(".", "bench_step", spec, exe);
    ModelRt { rt, dir: std::path::PathBuf::from("."), manifest }
}

fn main() {
    // host-only benches always run
    let mut rng = Pcg32::new(7);
    let big = Tensor::randn(&[64, 16, 16, 3], &mut rng, 1.0);
    let mut store = Store::new();
    for i in 0..200 {
        store.insert(&format!("t{i}"), Tensor::randn(&[32], &mut rng, 1.0));
    }
    report("store/insert_overwrite", bench_secs(10, 1000, || {
        store.insert("t7", Tensor::zeros(&[32]));
    }));
    report("store/get", bench_secs(10, 10000, || {
        store.get("t199").unwrap();
    }));
    let dir = std::env::temp_dir().join("genie_bench_store.bin");
    let mut io_store = Store::new();
    io_store.insert("x", big.clone());
    report("tensorstore/save_196KiB", bench_secs(3, 50, || {
        io_store.save(&dir).unwrap();
    }));
    report("tensorstore/load_196KiB", bench_secs(3, 50, || {
        Store::load(&dir).unwrap();
    }));
    report("tensor/gather_rows_32_of_8192", {
        let data = Tensor::randn(&[8192, 16 * 16 * 3], &mut rng, 1.0);
        let idx: Vec<usize> = (0..32).map(|i| i * 13 % 8192).collect();
        bench_secs(3, 200, || {
            std::hint::black_box(data.gather_rows(&idx));
        })
    });
    report("tensor/take_rows_4096_of_8192", {
        let data = Tensor::randn(&[8192, 16 * 16 * 3], &mut rng, 1.0);
        bench_secs(3, 200, || {
            std::hint::black_box(data.take_rows(4096));
        })
    });

    // ---- resident vs roundtrip (DESIGN.md §8) -------------------------
    // A distill-shaped working set: generator params + Adam moments +
    // latents. The round-trip path (Runtime::call) re-marshals every one
    // of these into a literal each step and downloads every result; the
    // device-resident path uploads them once and then moves only the
    // schedule scalars. Marshalling and the transfer accounting are real
    // in the offline stub, so this section always runs.
    let rt = Runtime::cpu().unwrap();
    let mut model = Store::new();
    for i in 0..24 {
        model.insert(&format!("g{i}"), Tensor::randn(&[64, 64], &mut rng, 1.0));
        model.insert(&format!("am.g{i}"), Tensor::zeros(&[64, 64]));
        model.insert(&format!("av.g{i}"), Tensor::zeros(&[64, 64]));
    }
    model.insert("z", Tensor::randn(&[64, 256], &mut rng, 1.0));

    let state_bytes: u64 = model
        .names()
        .iter()
        .map(|n| model.get(n).unwrap().byte_len() as u64)
        .sum();
    // per step: args up (state + 20 B of scalars), results down
    // (state + 4 B loss)
    let roundtrip_bytes_per_step = 2 * state_bytes + 24;
    let roundtrip_secs = bench_secs(2, 20, || {
        for n in model.names() {
            std::hint::black_box(to_literal(model.get(n).unwrap()).unwrap());
        }
    });
    report("runtime/roundtrip_marshal_per_step", roundtrip_secs);

    let mut dev = rt.upload_store(&model).unwrap();
    let upload_once = dev.transfer_bytes().0;
    assert_eq!(upload_once, state_bytes, "upload accounting must be exact");
    dev.reset_transfer_bytes();
    step_scalars(&mut dev, 1);
    let resident_bytes_per_step = dev.transfer_bytes().0 + 4; // + loss fetch
    let resident_secs = bench_secs(2, 200, || {
        step_scalars(&mut dev, 2);
    });
    report("runtime/resident_scalars_per_step", resident_secs);

    let reduction =
        roundtrip_bytes_per_step as f64 / resident_bytes_per_step as f64;
    println!(
        "transfer/step: roundtrip {roundtrip_bytes_per_step} B -> resident \
         {resident_bytes_per_step} B ({reduction:.0}x less; one-time upload \
         {upload_once} B)"
    );
    assert!(
        resident_bytes_per_step * 100 < roundtrip_bytes_per_step,
        "device residency must cut per-step transfer by >=100x \
         ({roundtrip_bytes_per_step} -> {resident_bytes_per_step})"
    );

    // ---- fused dispatch K-sweep (DESIGN.md §14) -----------------------
    // Drive the same resident-path StepLoop at K = 1/2/4/8 steps per
    // dispatch over a host-fn step graph. The per-step *dispatch count*
    // is the contract (64/K, strictly decreasing); wall time per step is
    // recorded alongside it so regressions in the staging/validation
    // overhead of the fused path show up in the artifact.
    const SWEEP_STEPS: usize = 64;
    let mrt = fused_bench_mrt(&rt);
    let mut sweep: Vec<(usize, f64, f64)> = Vec::new(); // (K, disp/step, s/step)
    for k in [1usize, 2, 4, 8] {
        let loop_k = StepLoop::new(SWEEP_STEPS, 0).with_steps_per_dispatch(k);
        // one untimed run to pin the dispatch count and final state
        let mut dev = rt.device_store();
        let mut phase = FusedBenchPhase;
        let out = loop_k.run(&mrt, &mut phase, &mut dev).unwrap();
        assert!(out.completed && out.ran_steps == SWEEP_STEPS);
        assert_eq!(out.dispatches, SWEEP_STEPS.div_ceil(k));
        let secs = bench_secs(2, 20, || {
            let mut dev = rt.device_store();
            let mut phase = FusedBenchPhase;
            std::hint::black_box(
                loop_k.run(&mrt, &mut phase, &mut dev).unwrap(),
            );
        }) / SWEEP_STEPS as f64;
        report(&format!("runtime/fused_step_k{k}"), secs);
        sweep.push((k, out.dispatches as f64 / SWEEP_STEPS as f64, secs));
    }
    for w in sweep.windows(2) {
        assert!(
            w[1].1 < w[0].1,
            "per-step dispatch count must strictly decrease with K \
             (K={} -> {:.3}/step, K={} -> {:.3}/step)",
            w[0].0, w[0].1, w[1].0, w[1].1,
        );
    }
    let fused_json: String = sweep
        .iter()
        .map(|(k, dps, sps)| {
            format!(
                "    {{\"steps_per_dispatch\": {k}, \
                 \"dispatches_per_step\": {dps:.4}, \
                 \"secs_per_step\": {sps:.3e}}}"
            )
        })
        .collect::<Vec<_>>()
        .join(",\n");

    // The *_marshal_steps_per_sec fields are host-side marshalling
    // throughput only (graph execution needs artifacts + real PJRT and
    // is benched in the artifact-gated section below) — named so the
    // artifact can't be misread as end-to-end step throughput.
    let json = format!(
        "{{\n  \"roundtrip_bytes_per_step\": {roundtrip_bytes_per_step},\n  \
         \"resident_bytes_per_step\": {resident_bytes_per_step},\n  \
         \"roundtrip_marshal_steps_per_sec\": {:.1},\n  \
         \"resident_marshal_steps_per_sec\": {:.1},\n  \
         \"transfer_reduction\": {reduction:.1},\n  \
         \"fused_dispatch_sweep\": [\n{fused_json}\n  ]\n}}\n",
        1.0 / roundtrip_secs.max(1e-12),
        1.0 / resident_secs.max(1e-12),
    );
    std::fs::write("BENCH_runtime.json", json).unwrap();
    println!("wrote BENCH_runtime.json");

    // device benches need artifacts
    if !std::path::Path::new("artifacts/toy/manifest.json").exists() {
        println!("bench runtime/*: skipped (run `make artifacts`)");
        return;
    }
    let mrt = ModelRt::load(&rt, "artifacts", "toy").unwrap();
    let dataset = Dataset::load("artifacts").unwrap();
    let mut metrics = Metrics::new();
    let pcfg = PretrainCfg { steps: 30, ..Default::default() };
    let teacher = teacher_or_pretrain(
        &mrt, &dataset, &pcfg, std::path::Path::new("runs"), &mut metrics,
    )
    .unwrap();

    let entry = mrt.entry("eval_batch").unwrap();
    let mut s = teacher.clone();
    s.insert("x", Tensor::zeros(&[256, 16, 16, 3]));
    rt.reset_stats();
    report("runtime/eval_batch_dispatch_b256", bench_secs(3, 30, || {
        rt.call(&entry, &mut s).unwrap();
    }));
    let round = rt.dispatch_stats()["eval_batch"].clone();

    // same graph, device-resident: params stay put; per call only x goes
    // up and (as in the real eval path) logits come back down
    rt.reset_stats();
    let mut dev = rt.upload_store(&s).unwrap();
    dev.reset_transfer_bytes();
    let x_eval = Tensor::zeros(&[256, 16, 16, 3]);
    report("runtime/eval_batch_resident_b256", bench_secs(3, 30, || {
        dev.insert("x", &x_eval).unwrap();
        rt.call_device(&entry, &mut dev).unwrap();
        std::hint::black_box(dev.fetch("logits").unwrap());
    }));
    let resident = rt.dispatch_stats()["eval_batch"].clone();
    let (dev_up, dev_down) = dev.transfer_bytes();
    println!(
        "eval_batch transfer/call: roundtrip {} B h2d + {} B d2h -> \
         resident {} B h2d + {} B d2h",
        round.bytes_h2d / round.calls,
        round.bytes_d2h / round.calls,
        dev_up / resident.calls,
        dev_down / resident.calls,
    );

    let entry = mrt.entry("collect_teacher").unwrap();
    s.insert("x", Tensor::zeros(&[32, 16, 16, 3]));
    report("runtime/collect_teacher_b32", bench_secs(3, 30, || {
        rt.call(&entry, &mut s).unwrap();
    }));

    // full dispatch table: the live stats plus the two eval_batch rows
    // snapshotted before the resets above wiped them
    let print_row = |name: &str, stats: &genie::runtime::DispatchStats| {
        println!(
            "dispatch {name:<28} {:>6} calls  {:>8.2} ms avg  \
             {:>10} B h2d  {:>10} B d2h",
            stats.calls,
            stats.total_secs * 1e3 / stats.calls as f64,
            stats.bytes_h2d,
            stats.bytes_d2h,
        );
    };
    print_row("eval_batch (roundtrip)", &round);
    print_row("eval_batch (resident)", &resident);
    for (name, stats) in rt.dispatch_stats() {
        print_row(&name, &stats);
    }
}

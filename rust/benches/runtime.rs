//! Runtime micro-benchmarks: entrypoint dispatch latency (the L3 hot
//! path), literal marshalling, store ops, tensorstore IO.
//! In-tree harness (no criterion in the offline image); harness = false.

use genie::coordinator::Metrics;
use genie::coordinator::pretrain::{teacher_or_pretrain, PretrainCfg};
use genie::data::Dataset;
use genie::runtime::{ModelRt, Runtime};
use genie::store::Store;
use genie::tensor::{Pcg32, Tensor};
use genie::testutil::{bench_secs, report};

fn main() {
    // host-only benches always run
    let mut rng = Pcg32::new(7);
    let big = Tensor::randn(&[64, 16, 16, 3], &mut rng, 1.0);
    let mut store = Store::new();
    for i in 0..200 {
        store.insert(&format!("t{i}"), Tensor::randn(&[32], &mut rng, 1.0));
    }
    report("store/insert_overwrite", bench_secs(10, 1000, || {
        store.insert("t7", Tensor::zeros(&[32]));
    }));
    report("store/get", bench_secs(10, 10000, || {
        store.get("t199").unwrap();
    }));
    let dir = std::env::temp_dir().join("genie_bench_store.bin");
    let mut io_store = Store::new();
    io_store.insert("x", big.clone());
    report("tensorstore/save_196KiB", bench_secs(3, 50, || {
        io_store.save(&dir).unwrap();
    }));
    report("tensorstore/load_196KiB", bench_secs(3, 50, || {
        Store::load(&dir).unwrap();
    }));
    report("tensor/gather_rows_32_of_8192", {
        let data = Tensor::randn(&[8192, 16 * 16 * 3], &mut rng, 1.0);
        let idx: Vec<usize> = (0..32).map(|i| i * 13 % 8192).collect();
        bench_secs(3, 200, || {
            std::hint::black_box(data.gather_rows(&idx));
        })
    });

    // device benches need artifacts
    if !std::path::Path::new("artifacts/toy/manifest.json").exists() {
        println!("bench runtime/*: skipped (run `make artifacts`)");
        return;
    }
    let rt = Runtime::cpu().unwrap();
    let mrt = ModelRt::load(&rt, "artifacts", "toy").unwrap();
    let dataset = Dataset::load("artifacts").unwrap();
    let mut metrics = Metrics::new();
    let pcfg = PretrainCfg { steps: 30, ..Default::default() };
    let teacher = teacher_or_pretrain(
        &mrt, &dataset, &pcfg, std::path::Path::new("runs"), &mut metrics,
    )
    .unwrap();

    let entry = mrt.entry("eval_batch").unwrap();
    let mut s = teacher.clone();
    s.insert("x", Tensor::zeros(&[256, 16, 16, 3]));
    report("runtime/eval_batch_dispatch_b256", bench_secs(3, 30, || {
        rt.call(&entry, &mut s).unwrap();
    }));

    let entry = mrt.entry("collect_teacher").unwrap();
    s.insert("x", Tensor::zeros(&[32, 16, 16, 3]));
    report("runtime/collect_teacher_b32", bench_secs(3, 30, || {
        rt.call(&entry, &mut s).unwrap();
    }));

    for (name, calls) in rt.dispatch_stats() {
        println!(
            "dispatch {name:<24} {:>6} calls  {:>8.2} ms avg",
            calls.calls,
            calls.total_secs * 1e3 / calls.calls as f64
        );
    }
}

//! Tiered artifact-store benchmarks (DESIGN.md §16). In-tree harness
//! (no criterion in the offline image); harness = false.
//!
//! Always writes `BENCH_cache.json`: a tier-0 hot hit vs the full disk
//! deserialization it replaces, and single-pass hash-while-write vs the
//! old write-then-rehash sidecar path. With artifacts present it
//! additionally times a warm 2-cell grid replay with an unlimited vs a
//! tight `cache.budget_bytes` (session pins keep the warm set live, so
//! the tight budget should cost ~nothing on the replay path).

use genie::artifacts::{self, ArtifactCache, KeyBuilder};
use genie::coordinator::{Metrics, RunConfig};
use genie::grid::{self, GridOpts, RunGrid};
use genie::runtime::Runtime;
use genie::store::{fnv1a, Store, FNV_OFFSET};
use genie::tensor::{Pcg32, Tensor};
use genie::testutil::{bench_secs, report};

fn main() {
    let mut rng = Pcg32::new(29);
    let dir = std::env::temp_dir().join("genie_bench_cache");
    std::fs::remove_dir_all(&dir).ok();
    std::fs::create_dir_all(&dir).unwrap();

    // ---- tier-0 hit vs disk load -------------------------------------
    // the same 384 KiB calibration-shaped artifact, served from the hot
    // tier's shared handle vs parsed back out of the GTS1 file
    let mut cache = ArtifactCache::open(&dir, true, false).unwrap();
    let key = KeyBuilder::new("bench").field("x", 1).finish();
    let mut art = Store::new();
    art.insert("images", Tensor::randn(&[128, 16, 16, 3], &mut rng, 1.0));
    cache.store("bench", key, &art).unwrap();

    let hot_secs = bench_secs(5, 500, || {
        std::hint::black_box(cache.load("bench", key).unwrap());
    });
    report("cache/tier0_hit_384KiB", hot_secs);
    let disk_secs = bench_secs(3, 100, || {
        // dropping tier 0 forces the verify-and-deserialize disk path
        artifacts::clear_hot(&dir);
        std::hint::black_box(cache.load("bench", key).unwrap());
    });
    report("cache/disk_load_384KiB", disk_secs);
    let speedup = disk_secs / hot_secs.max(1e-12);
    println!("tier-0 hit is {speedup:.0}x a disk load");
    assert!(
        hot_secs < disk_secs,
        "a shared hot handle must beat deserializing from disk"
    );

    // ---- hash-while-write vs write-then-rehash -----------------------
    // what `store()` pays to emit the `.fnv` sidecar: one serialization
    // walk that folds the hash as bytes stream out, vs serializing,
    // writing, then reading the file back to hash it (the old two-pass)
    let p1 = dir.join("one_pass.gts");
    let one_secs = bench_secs(3, 100, || {
        let (bytes, h) = art.to_bytes_hashed().unwrap();
        std::fs::write(&p1, &bytes).unwrap();
        std::hint::black_box(h);
    });
    report("cache/store_hash_while_write", one_secs);
    let p2 = dir.join("two_pass.gts");
    let two_secs = bench_secs(3, 100, || {
        let bytes = art.to_bytes().unwrap();
        std::fs::write(&p2, &bytes).unwrap();
        let back = std::fs::read(&p2).unwrap();
        std::hint::black_box(fnv1a(FNV_OFFSET, &back));
    });
    report("cache/store_write_then_rehash", two_secs);

    // ---- warm grid replay, budget unlimited vs tight (artifact-gated)
    let mut warm_unbounded = -1.0f64;
    let mut warm_tight = -1.0f64;
    if std::path::Path::new("artifacts/toy/manifest.json").exists() {
        let rt = Runtime::cpu().unwrap();
        let mut warm_grid = |tag: &str, budget: u64| -> f64 {
            let mut cfg = RunConfig {
                model: "toy".into(),
                artifacts: "artifacts".into(),
                cache_dir: dir.join(tag).to_string_lossy().into_owned(),
                ..Default::default()
            };
            // the bench measures the local tiers regardless of any
            // GENIE_CACHE_* environment the CI matrix exports
            cfg.apply_overrides(&[
                "pretrain.steps=30".into(),
                "distill.samples=64".into(),
                "distill.steps=6".into(),
                "quant.steps=8".into(),
                "workers=4".into(),
                "cache.backend=local".into(),
                format!("cache.budget_bytes={budget}"),
            ])
            .unwrap();
            let mut g = RunGrid::new();
            g.parse_axis("bits=4,2", &cfg).unwrap();
            let mut m = Metrics::new();
            grid::execute(&rt, &cfg, &g, &GridOpts::default(), &mut m)
                .unwrap();
            let t0 = std::time::Instant::now();
            let mut m2 = Metrics::new();
            grid::execute(&rt, &cfg, &g, &GridOpts::default(), &mut m2)
                .unwrap();
            t0.elapsed().as_secs_f64()
        };
        warm_unbounded = warm_grid("grid_unbounded", 0);
        warm_tight = warm_grid("grid_tight", 64 * 1024);
        println!(
            "warm 2-cell grid: {warm_unbounded:.2}s unlimited budget, \
             {warm_tight:.2}s at 64 KiB (pins keep the warm set live)"
        );
    } else {
        println!("bench cache/warm_grid: skipped (run `make artifacts`)");
    }

    // negative sentinel (-1.0) = artifact-gated section did not run
    let json = format!(
        "{{\n  \"tier0_hit_secs\": {hot_secs:.9},\n  \
         \"disk_load_secs\": {disk_secs:.9},\n  \
         \"tier0_speedup\": {speedup:.1},\n  \
         \"store_hash_while_write_secs\": {one_secs:.9},\n  \
         \"store_write_then_rehash_secs\": {two_secs:.9},\n  \
         \"warm_grid_unbounded_secs\": {warm_unbounded:.4},\n  \
         \"warm_grid_tight_budget_secs\": {warm_tight:.4}\n}}\n",
    );
    std::fs::write("BENCH_cache.json", json).unwrap();
    println!("wrote BENCH_cache.json");
    std::fs::remove_dir_all(&dir).ok();
}

//! Pipeline-step benches over the real toy artifacts: per-step cost of
//! each phase graph (the numbers behind Table 6 / EXPERIMENTS.md §Perf).

use genie::coordinator::pretrain::{teacher_or_pretrain, PretrainCfg};
use genie::coordinator::{insert_zeros, Metrics};
use genie::data::Dataset;
use genie::runtime::{ModelRt, Runtime};
use genie::tensor::{Pcg32, Tensor};
use genie::testutil::{bench_secs, report};

fn main() {
    if !std::path::Path::new("artifacts/toy/manifest.json").exists() {
        println!("bench pipeline/*: skipped (run `make artifacts`)");
        return;
    }
    let rt = Runtime::cpu().unwrap();
    let mrt = ModelRt::load(&rt, "artifacts", "toy").unwrap();
    let dataset = Dataset::load("artifacts").unwrap();
    let m = &mrt.manifest;
    let mut rng = Pcg32::new(13);
    let mut metrics = Metrics::new();
    let teacher = teacher_or_pretrain(
        &mrt, &dataset,
        &PretrainCfg { steps: 30, ..Default::default() },
        std::path::Path::new("runs"), &mut metrics,
    )
    .unwrap();

    // train step
    {
        let mut s = mrt.init_store().unwrap();
        insert_zeros(&mut s, &m.params, "am.");
        insert_zeros(&mut s, &m.params, "av.");
        let bs = m.batch("train");
        let (x, y) = dataset.train_batch(&mut rng, bs);
        s.insert("x", x);
        s.insert("y", Tensor::from_i32(&[bs], y));
        s.insert("t", Tensor::scalar_f32(1.0));
        s.insert("lr", Tensor::scalar_f32(1e-3));
        let e = mrt.entry("train_step").unwrap();
        report("pipeline/train_step_b64", bench_secs(3, 20, || {
            rt.call(&e, &mut s).unwrap();
        }));
    }

    // distill step (genie, swing)
    {
        let mut s = teacher.clone();
        s.insert("key", Tensor::key(1, 2));
        mrt.call("gen_init", &mut s).unwrap();
        insert_zeros(&mut s, &m.gen_params, "am.");
        insert_zeros(&mut s, &m.gen_params, "av.");
        let zshape = [m.batch("distill"), m.latent];
        s.insert("z", Tensor::randn(&zshape, &mut rng, 1.0));
        s.insert("zm", Tensor::zeros(&zshape));
        s.insert("zv", Tensor::zeros(&zshape));
        s.insert("t", Tensor::scalar_f32(1.0));
        s.insert("lr_g", Tensor::scalar_f32(0.01));
        s.insert("lr_z", Tensor::scalar_f32(0.1));
        let e = mrt.entry("distill_genie_swing").unwrap();
        report("pipeline/distill_genie_swing_b64", bench_secs(3, 20, || {
            rt.call(&e, &mut s).unwrap();
        }));
        let e = mrt.entry("distill_genie_noswing").unwrap();
        report("pipeline/distill_genie_noswing_b64", bench_secs(3, 20, || {
            rt.call(&e, &mut s).unwrap();
        }));
    }

    // quant block step via the full quantize path's graphs
    {
        use genie::precision::{Granularity, PrecisionPlan};
        use genie::quant::init_qstate;
        let plan = PrecisionPlan::uniform(m, 4, 4, Granularity::PerChannel)
            .unwrap()
            .with_first_last(8)
            .unwrap();
        let qs = init_qstate(m, &teacher, &plan, 2.4, None).unwrap();
        let mut s = teacher.clone();
        s.absorb(&qs);
        let br = m.batch("recon");
        let (x, _) = dataset.train_batch(&mut rng, br);
        s.insert("x", x.clone());
        mrt.call("collect_teacher", &mut s).unwrap();
        let b0 = s.get("bound.0").unwrap().clone();
        let b1 = s.get("bound.1").unwrap().clone();
        for name in m.learnable_block(0) {
            let shape = s.get(name).unwrap().shape.clone();
            s.insert(&format!("am.{name}"), Tensor::zeros(&shape));
            s.insert(&format!("av.{name}"), Tensor::zeros(&shape));
        }
        s.insert("x_in", b0);
        s.insert("y_ref", b1);
        s.insert("key", Tensor::key(3, 4));
        s.insert("t", Tensor::scalar_f32(1.0));
        for (k, v) in [("lr_sw", 1e-4f32), ("lr_v", 1e-2), ("lr_sa", 4e-5),
                       ("lam", 1.0), ("beta", 20.0), ("drop_p", 0.5)] {
            s.insert(k, Tensor::scalar_f32(v));
        }
        let e = mrt.entry("quant_step_0").unwrap();
        report("pipeline/quant_step_block0_b32", bench_secs(3, 20, || {
            rt.call(&e, &mut s).unwrap();
        }));
        let e = mrt.entry("eval_quant").unwrap();
        let (xe, _) = dataset.train_batch(&mut rng, m.batch("eval"));
        s.insert("x", xe);
        report("pipeline/eval_quant_b256", bench_secs(2, 10, || {
            rt.call(&e, &mut s).unwrap();
        }));
    }
}

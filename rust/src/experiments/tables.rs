//! Table harnesses (paper evaluation section; index in DESIGN.md §4).
//!
//! Models and budgets default to a single-CPU-core scale; override with
//! e.g. `model=resnet14,mobilenetv2_t distill.steps=500 quant.steps=500`.
//! Paper-vs-measured comparisons live in EXPERIMENTS.md.
//!
//! The sweep-shaped tables (2, 4, 5) and fig6 are declarative
//! [`RunGrid`]s on the shared-artifact scheduler (DESIGN.md §11): the
//! grid dedupes the teacher and every shared synthetic set across arms
//! and interleaves the remaining cells on the exec pool, instead of the
//! bespoke sequential loops these harnesses used to hand-roll.

use anyhow::{Context, Result};

use crate::artifacts::ArtifactCache;
use crate::coordinator::{
    distill, eval_fp32, eval_quantized, fsq, pretrain::teacher_or_pretrain,
    quantize, zsq, DistillCfg, DistillMode, Metrics, QuantCfg, RunConfig,
};
use crate::data::Dataset;
use crate::grid::{
    self, AxisValue, DataMode, GridOpts, QuantArm, RunGrid,
};
use crate::precision::sensitivity::{budget_bits, measure_sensitivity, pareto_plan};
use crate::precision::PrecisionPlan;
use crate::runtime::{Manifest, ModelRt, Runtime};
use crate::store::Store;
use crate::synthesis::Engine;
use crate::tensor::Pcg32;

use super::qat::{qat_eval, qat_train, QatCfg};
use super::{pct, ResultTable};

/// Models swept in multi-model tables: the `model` config key may hold a
/// comma-separated list.
fn models_of(cfg: &RunConfig) -> Vec<String> {
    cfg.model.split(',').map(|s| s.trim().to_string()).collect()
}

/// The model axis of a multi-model grid.
fn model_axis(cfg: &RunConfig) -> Vec<AxisValue> {
    models_of(cfg).into_iter().map(AxisValue::Model).collect()
}

/// One FP row per model, from the (deduplicated) FP32 eval of any cell
/// of that model.
fn fp_acc_of(out: &grid::GridOutcome, model: &str) -> Option<f32> {
    out.cells
        .iter()
        .filter(|c| c.spec.model == model)
        .find_map(|c| c.outcome.as_ref().map(|o| o.fp_acc))
}

/// One `ModelRt` per distinct model of a grid outcome (the post-grid
/// harness passes — QAT sweeps, sensitivity probes — reuse these
/// instead of reloading per cell).
fn model_rts<'rt>(
    rt: &'rt Runtime,
    cfg: &RunConfig,
    out: &grid::GridOutcome,
) -> Result<std::collections::BTreeMap<String, ModelRt<'rt>>> {
    let mut mrts = std::collections::BTreeMap::new();
    for cell in &out.cells {
        if !mrts.contains_key(&cell.spec.model) {
            let mrt = ModelRt::load(rt, &cfg.artifacts, &cell.spec.model)?;
            mrts.insert(cell.spec.model.clone(), mrt);
        }
    }
    Ok(mrts)
}

pub(crate) struct Ctx<'a> {
    pub mrt: ModelRt<'a>,
    pub dataset: Dataset,
    pub teacher: Store,
    pub fp_acc: f32,
}

pub(crate) fn load_ctx<'a>(
    rt: &'a Runtime,
    cfg: &RunConfig,
    model: &str,
) -> Result<Ctx<'a>> {
    let mrt = ModelRt::load(rt, &cfg.artifacts, model)?;
    let dataset = Dataset::load(&cfg.artifacts)?;
    let mut metrics = Metrics::new();
    let teacher = teacher_or_pretrain(
        &mrt,
        &dataset,
        &cfg.pretrain,
        std::path::Path::new(&cfg.runs_dir),
        &mut metrics,
    )?;
    let fp_acc = eval_fp32(&mrt, &teacher, &dataset)?;
    Ok(Ctx { mrt, dataset, teacher, fp_acc })
}

/// Distill + quantize + eval for one (distill-arm, quant-arm) combination.
fn arm(
    ctx: &Ctx,
    dcfg: &DistillCfg,
    qcfg: &QuantCfg,
    metrics: &mut Metrics,
) -> Result<f32> {
    let out = distill(&ctx.mrt, &ctx.teacher, dcfg, metrics)?;
    let qstate = quantize(&ctx.mrt, &ctx.teacher, &out.images, qcfg, metrics)?;
    eval_quantized(&ctx.mrt, &ctx.teacher, &qstate, &ctx.dataset)
}

/// Table 2: the M1–M7 ablation (swing x generator x latents x GENIE-M)
/// as a declarative grid — model × bits × arm. The M1/M3 pair shares a
/// teacher with every arm, M5 and the GENIE-M-less M6 share synthetic
/// sets with their quantizer-ablated twins, and the grid dispatches each
/// shared stage once.
pub fn table2(cfg: &RunConfig) -> Result<()> {
    let rt = Runtime::cpu()?;
    let mut table = ResultTable::new(
        "table2_ablation",
        &["bits", "arm", "swing", "gen", "z", "genie_m", "model", "top1"],
    );
    // (name, mode, swing, genie_m)
    let arm_defs: [(&str, DistillMode, bool, bool); 7] = [
        ("M1", DistillMode::Direct, false, false),
        ("M2", DistillMode::Direct, false, true),
        ("M3", DistillMode::Direct, true, false),
        ("M4", DistillMode::Gba, false, false),
        ("M5", DistillMode::Genie, false, false),
        ("M6", DistillMode::Genie, true, false),
        ("M7", DistillMode::Genie, true, true),
    ];
    let arms: Vec<AxisValue> = arm_defs
        .into_iter()
        .map(|(name, mode, swing, genie_m)| AxisValue::Arm {
            label: name.into(),
            data: DataMode::Synthetic { mode, swing },
            // non-GENIE-M arms fall back to AdaRound+QDrop
            quant: QuantArm { adaround: !genie_m, no_drop: false },
        })
        .collect();
    // low-bit panels: where the ablation spreads (the W4A4 panel of the
    // paper saturates on the scaled task, see EXPERIMENTS.md)
    let grid = RunGrid::new()
        .axis("model", model_axis(cfg))
        .axis("bits", vec![AxisValue::Bits(2, 4), AxisValue::Bits(2, 2)])
        .axis("arm", arms);
    let mut metrics = Metrics::new();
    let out =
        grid::execute(&rt, cfg, &grid, &GridOpts::default(), &mut metrics)?;

    for cell in &out.cells {
        let spec = &cell.spec;
        let o = cell.outcome.as_ref().context("table2: missing outcome")?;
        let (w, a) = (spec.quant.wbits, spec.quant.abits);
        let name = spec.coord("arm").unwrap_or("?");
        let (mode, swing) = match spec.data {
            DataMode::Synthetic { mode, swing } => (mode, swing),
            DataMode::Real => (DistillMode::Direct, false),
        };
        println!(
            "[table2] {} W{w}A{a} {name}: {}",
            spec.model,
            pct(o.q_acc)
        );
        table.row(vec![
            format!("{w}/{a}"),
            name.into(),
            swing.to_string(),
            (mode != DistillMode::Direct).to_string(),
            (mode == DistillMode::Genie).to_string(),
            // GENIE-M = learned step sizes (the AdaRound arms zero them)
            (spec.quant.lr_sw != 0.0).to_string(),
            spec.model.clone(),
            pct(o.q_acc),
        ]);
    }
    for model in models_of(cfg) {
        if let Some(fp) = fp_acc_of(&out, &model) {
            println!("[table2] {model}: FP32 {}", pct(fp));
            table.row(vec![
                "32/32".into(), "FP".into(), "-".into(), "-".into(),
                "-".into(), "-".into(), model, pct(fp),
            ]);
        }
    }
    table.print_and_save()
}

/// Table 3: data-source comparison under a fixed quantizer, plus Real rows.
pub fn table3(cfg: &RunConfig) -> Result<()> {
    let rt = Runtime::cpu()?;
    let mut table = ResultTable::new(
        "table3_data_sources",
        &["bits", "method", "model", "top1"],
    );
    for model in models_of(cfg) {
        let ctx = load_ctx(&rt, cfg, &model)?;
        for (w, a) in [(4u32, 4u32), (2, 4)] {
            let base_q = {
                let mut q = cfg.quant.clone();
                q.wbits = w;
                q.abits = a;
                q
            };
            // synthetic arms under the same BRECQ-like quantizer
            // (AdaRound + QDrop, frozen step size)
            for (name, mode, swing) in [
                ("ZeroQ+AR", DistillMode::Direct, false),
                ("GBA+AR", DistillMode::Gba, false),
                ("GENIE-D+AR", DistillMode::Genie, true),
            ] {
                let mut dcfg = cfg.distill.clone();
                dcfg.mode = mode;
                dcfg.swing = swing;
                let q = base_q.clone().adaround();
                let mut metrics = Metrics::new();
                let acc = arm(&ctx, &dcfg, &q, &mut metrics)?;
                println!("[table3] {model} W{w}A{a} {name}: {}", pct(acc));
                table.row(vec![format!("{w}/{a}"), name.into(), model.clone(), pct(acc)]);
            }
            // GENIE full (GENIE-D + GENIE-M)
            {
                let mut dcfg = cfg.distill.clone();
                dcfg.mode = DistillMode::Genie;
                dcfg.swing = true;
                let mut metrics = Metrics::new();
                let acc = arm(&ctx, &dcfg, &base_q, &mut metrics)?;
                println!("[table3] {model} W{w}A{a} GENIE: {}", pct(acc));
                table.row(vec![format!("{w}/{a}"), "GENIE".into(), model.clone(), pct(acc)]);
            }
            // Real-data rows: AdaRound+QDrop vs GENIE-M
            let mut rng = Pcg32::new(cfg.seed ^ 0x7ea1);
            let (calib, _) = ctx.dataset.calibration(&mut rng, cfg.fsq_samples);
            for (name, q) in [
                ("Real:AR+QDrop", base_q.clone().adaround()),
                ("Real:GENIE-M", base_q.clone()),
            ] {
                let mut metrics = Metrics::new();
                let qstate =
                    quantize(&ctx.mrt, &ctx.teacher, &calib, &q, &mut metrics)?;
                let acc =
                    eval_quantized(&ctx.mrt, &ctx.teacher, &qstate, &ctx.dataset)?;
                println!("[table3] {model} W{w}A{a} {name}: {}", pct(acc));
                table.row(vec![format!("{w}/{a}"), name.into(), model.clone(), pct(acc)]);
            }
        }
        table.row(vec!["32/32".into(), "FP".into(), model.clone(), pct(ctx.fp_acc)]);
    }

    // Mix* rows (MixMix-style ensembling, Table 3 bottom): pool GENIE-D
    // data distilled from EVERY model in the list, then quantize each
    // target model with the pooled set.
    let models = models_of(cfg);
    if models.len() > 1 {
        let mut ctxs = Vec::new();
        for model in &models {
            ctxs.push(load_ctx(&rt, cfg, model)?);
        }
        let per = cfg.distill.samples.div_ceil(models.len());
        let mut parts = Vec::new();
        for ctx in &ctxs {
            let mut dcfg = cfg.distill.clone();
            dcfg.mode = DistillMode::Genie;
            dcfg.swing = true;
            dcfg.samples = per;
            let mut metrics = Metrics::new();
            parts.push(distill(&ctx.mrt, &ctx.teacher, &dcfg, &mut metrics)?.images);
        }
        let refs: Vec<&crate::tensor::Tensor> = parts.iter().collect();
        let pooled = crate::tensor::Tensor::concat_rows(&refs);
        for (w, a) in [(4u32, 4u32), (2, 4)] {
            for ctx in &ctxs {
                let mut q = cfg.quant.clone();
                q.wbits = w;
                q.abits = a;
                let mut metrics = Metrics::new();
                let qstate =
                    quantize(&ctx.mrt, &ctx.teacher, &pooled, &q, &mut metrics)?;
                let acc =
                    eval_quantized(&ctx.mrt, &ctx.teacher, &qstate, &ctx.dataset)?;
                let model = ctx.mrt.manifest.model.clone();
                println!("[table3] {model} W{w}A{a} Mix:GENIE: {}", pct(acc));
                table.row(vec![
                    format!("{w}/{a}"), "Mix:GENIE".into(), model, pct(acc),
                ]);
            }
        }
    }
    table.print_and_save()
}

/// Table 4 (+ Table A2): PTQ (GENIE) vs netwise Min-Max QAT on the same
/// synthetic data, including the sample-count sweep of Table A2. The
/// PTQ cells run as a grid (model × bits over one GENIE-D data node per
/// model — the two bit panels share it); the QAT sweep then trains on
/// the grid-materialized images of each cell.
pub fn table4(cfg: &RunConfig) -> Result<()> {
    let rt = Runtime::cpu()?;
    let mut table = ResultTable::new(
        "table4_ptq_vs_qat",
        &["bits", "method", "samples", "model", "top1"],
    );
    let grid = RunGrid::new()
        .axis("model", model_axis(cfg))
        .axis("bits", vec![AxisValue::Bits(4, 4), AxisValue::Bits(2, 4)])
        .axis(
            "data",
            vec![AxisValue::Data(DataMode::Synthetic {
                mode: DistillMode::Genie,
                swing: true,
            })],
        );
    let opts = GridOpts {
        keep_calib: true,
        keep_teacher: true,
        ..Default::default()
    };
    let mut metrics = Metrics::new();
    let out = grid::execute(&rt, cfg, &grid, &opts, &mut metrics)?;
    let dataset = Dataset::load(&cfg.artifacts)?;
    let mrts = model_rts(&rt, cfg, &out)?;

    for cell in &out.cells {
        let spec = &cell.spec;
        let o = cell.outcome.as_ref().context("table4: missing outcome")?;
        let (w, a) = (spec.quant.wbits, spec.quant.abits);
        let model = spec.model.clone();
        println!("[table4] {model} W{w}A{a} GENIE(PTQ): {}", pct(o.q_acc));
        table.row(vec![
            format!("{w}/{a}"), "GENIE(PTQ)".into(),
            spec.distill.samples.to_string(), model.clone(), pct(o.q_acc),
        ]);

        // QAT sweep over sample counts (Table A2 shape), on the grid's
        // shared synthetic set (mult=1) and a doubled re-distill
        let mrt = &mrts[&model];
        let teacher =
            cell.teacher.as_ref().context("table4: teacher not kept")?;
        let images =
            cell.calib.as_ref().context("table4: calib not kept")?;
        for mult in [1usize, 2] {
            let mut d2 = spec.distill.clone();
            d2.samples = spec.distill.samples * mult;
            let imgs = if mult == 1 {
                images.clone()
            } else {
                distill(mrt, teacher, &d2, &mut metrics)?.images
            };
            let qat_cfg = QatCfg {
                wbits: w,
                abits: a,
                steps: spec.quant.steps_per_block * mrt.manifest.num_blocks,
                lr: 1e-4,
                seed: cfg.seed ^ 0x9a7,
            };
            let student =
                qat_train(mrt, teacher, &imgs, &qat_cfg, &mut metrics)?;
            let acc = qat_eval(mrt, teacher, &student, &dataset, &qat_cfg)?;
            println!(
                "[table4] {model} W{w}A{a} MinMax-QAT ({} imgs): {}",
                d2.samples, pct(acc)
            );
            table.row(vec![
                format!("{w}/{a}"), "MinMax-QAT".into(),
                d2.samples.to_string(), model.clone(), pct(acc),
            ]);
        }
    }
    table.print_and_save()
}

/// Per-layer precision-plan report (DESIGN.md §10): measure ZeroQ-style
/// sensitivity on GENIE-D synthetic data, resolve the uniform and
/// Pareto plans side by side, and tabulate per-layer bits, sensitivity
/// and payload — plus a budget line per model. The shared teacher +
/// synthetic set per model come from a data-only grid (DESIGN.md §11).
pub fn plan_report(cfg: &RunConfig) -> Result<()> {
    let rt = Runtime::cpu()?;
    let mut table = ResultTable::new(
        "plan_report",
        &[
            "model", "layer", "numel", "kl_at_min", "uniform_w", "pareto_w",
            "abits", "pareto_kbits",
        ],
    );
    let grid = RunGrid::new().axis("model", model_axis(cfg)).axis(
        "data",
        vec![AxisValue::Data(DataMode::Synthetic {
            mode: DistillMode::Genie,
            swing: true,
        })],
    );
    let opts = GridOpts {
        data_only: true,
        keep_calib: true,
        keep_teacher: true,
        ..Default::default()
    };
    let mut metrics = Metrics::new();
    let out = grid::execute(&rt, cfg, &grid, &opts, &mut metrics)?;
    let mrts = model_rts(&rt, cfg, &out)?;

    for cell in &out.cells {
        let model = cell.spec.model.clone();
        let mrt = &mrts[&model];
        let m = &mrt.manifest;
        let p = &cfg.quant.precision;
        let teacher =
            cell.teacher.as_ref().context("plan: teacher not kept")?;
        let images = cell.calib.as_ref().context("plan: calib not kept")?;

        let uniform =
            PrecisionPlan::uniform(m, cfg.quant.wbits, cfg.quant.abits,
                                   p.granularity)?
                .with_first_last(p.first_last_bits)?;
        // probe every layer (pins included) so the report has a KL
        // column for all of them; the allocation below uses the real
        // pin set
        let probe_cfg = crate::precision::PrecisionCfg {
            first_last_bits: 0,
            ..p.clone()
        };
        let (sens, _pool) = measure_sensitivity(
            mrt,
            teacher,
            images,
            &probe_cfg,
            cfg.quant.pnorm,
            cfg.quant.par,
        )?;
        let pareto = pareto_plan(m, &sens, cfg.quant.abits, p)?;

        for (li, ql) in m.quant_layers.iter().enumerate() {
            let numel = ql.out_ch * ql.flat_k;
            table.row(vec![
                model.clone(),
                ql.name.clone(),
                numel.to_string(),
                format!("{:.4}", sens.kl[li][0]),
                uniform.layers[li].wbits.to_string(),
                pareto.layers[li].wbits.to_string(),
                pareto.layers[li].abits.to_string(),
                format!(
                    "{:.1}",
                    numel as f64 * pareto.layers[li].wbits as f64 / 1000.0
                ),
            ]);
        }
        let fp = PrecisionPlan::fp32_bits(m).max(1);
        println!(
            "[plan] {model}: pareto {:.1}% of FP32 payload \
             (budget {:.1}%), uniform {:.1}%",
            100.0 * pareto.payload_bits(m) as f64 / fp as f64,
            100.0 * budget_bits(m, p.target_size) as f64 / fp as f64,
            100.0 * uniform.payload_bits(m) as f64 / fp as f64,
        );
        print!("{}", pareto.render(m));
    }
    table.print_and_save()
}

/// Table 5: FSQ on real data — AdaRound vs GENIE-M, +/- QDrop, at
/// W4A4 / W2A4 / W3A3 / W2A2 — as a grid over model × bits × quantizer
/// arm with a real-data calibration source (the `genie fsq` draw), all
/// sixteen cells of a model sharing one teacher and one FP32 eval.
pub fn table5(cfg: &RunConfig) -> Result<()> {
    let rt = Runtime::cpu()?;
    let mut table = ResultTable::new(
        "table5_real_data",
        &["bits", "method", "model", "top1"],
    );
    let arms: Vec<AxisValue> = [
        ("AdaRound+NoDrop", QuantArm { adaround: true, no_drop: true }),
        ("AdaRound+QDrop", QuantArm { adaround: true, no_drop: false }),
        ("GENIE-M+NoDrop", QuantArm { adaround: false, no_drop: true }),
        ("GENIE-M+QDrop", QuantArm { adaround: false, no_drop: false }),
    ]
    .into_iter()
    .map(|(name, quant)| AxisValue::Arm {
        label: name.into(),
        data: DataMode::Real,
        quant,
    })
    .collect();
    let grid = RunGrid::new()
        .axis("model", model_axis(cfg))
        .axis(
            "bits",
            vec![
                AxisValue::Bits(4, 4),
                AxisValue::Bits(2, 4),
                AxisValue::Bits(3, 3),
                AxisValue::Bits(2, 2),
            ],
        )
        .axis("arm", arms);
    let mut metrics = Metrics::new();
    let out =
        grid::execute(&rt, cfg, &grid, &GridOpts::default(), &mut metrics)?;

    for cell in &out.cells {
        let spec = &cell.spec;
        let o = cell.outcome.as_ref().context("table5: missing outcome")?;
        let (w, a) = (spec.quant.wbits, spec.quant.abits);
        let name = spec.coord("arm").unwrap_or("?");
        println!(
            "[table5] {} W{w}A{a} {name}: {}",
            spec.model,
            pct(o.q_acc)
        );
        table.row(vec![
            format!("{w}/{a}"),
            name.into(),
            spec.model.clone(),
            pct(o.q_acc),
        ]);
    }
    for model in models_of(cfg) {
        if let Some(fp) = fp_acc_of(&out, &model) {
            table.row(vec!["32/32".into(), "FP".into(), model, pct(fp)]);
        }
    }
    table.print_and_save()
}

/// Table 6: wall-clock to complete ZSQ — GENIE (distill + PTQ) vs the
/// netwise QAT baseline, with the generator-training share in its own
/// column, plus an FSQ row (real data, no synthesis: the distill column
/// renders "—" instead of a bogus zero).
pub fn table6(cfg: &RunConfig) -> Result<()> {
    let rt = Runtime::cpu()?;
    let mut table = ResultTable::new(
        "table6_elapsed",
        &["model", "method", "total_secs", "distill_secs", "top1"],
    );
    for model in models_of(cfg) {
        let ctx = load_ctx(&rt, cfg, &model)?;
        // GENIE: distill + PTQ, through the pipeline DAG (uncached so
        // the wall clock is the real cost)
        let mut metrics = Metrics::new();
        let mut dcfg = cfg.distill.clone();
        dcfg.mode = DistillMode::Genie;
        dcfg.swing = true;
        let mut cache = ArtifactCache::disabled();
        let out = zsq(
            &ctx.mrt, &ctx.teacher, &ctx.dataset, &dcfg, &cfg.quant,
            &mut cache, &mut metrics,
        )?;
        let d = out.distill_secs.unwrap_or(0.0);
        table.row(vec![
            model.clone(), "GENIE".into(),
            format!("{:.1}", d + out.quant_secs),
            out.distill_secs_cell(), pct(out.q_acc),
        ]);

        // FSQ: real calibration samples, no synthesis stage at all
        let mut metrics = Metrics::new();
        let out = fsq(
            &ctx.mrt, &ctx.teacher, &ctx.dataset, cfg.fsq_samples,
            &cfg.quant, &mut cache, &mut metrics,
        )?;
        table.row(vec![
            model.clone(), "FSQ(real)".into(),
            format!("{:.1}", out.quant_secs),
            out.distill_secs_cell(), pct(out.q_acc),
        ]);

        // QAT baseline: distill + netwise training (QAT needs far more
        // optimization steps — the paper's 80k-step regime, scaled).
        let mut metrics = Metrics::new();
        let images = distill(&ctx.mrt, &ctx.teacher, &dcfg, &mut metrics)?.images;
        let qat_cfg = QatCfg {
            wbits: cfg.quant.wbits,
            abits: cfg.quant.abits,
            steps: cfg.quant.steps_per_block * ctx.mrt.manifest.num_blocks * 4,
            lr: 1e-4,
            seed: cfg.seed ^ 0x6a7,
        };
        let student =
            qat_train(&ctx.mrt, &ctx.teacher, &images, &qat_cfg, &mut metrics)?;
        let acc = qat_eval(&ctx.mrt, &ctx.teacher, &student, &ctx.dataset, &qat_cfg)?;
        let d = metrics.timer_total("distill");
        let q = metrics.timer_total("qat");
        table.row(vec![
            model.clone(), "MinMax-QAT".into(), format!("{:.1}", d + q),
            format!("{d:.1}"), pct(acc),
        ]);
    }
    table.print_and_save()
}

/// Synthesis-engine ablation (DESIGN.md §12): every available engine
/// distills its own calibration set against one shared teacher, then
/// runs the same quantizer — the grid's exactly-once dedupe makes the
/// comparison one teacher + one distill per engine, so the top1 deltas
/// are attributable to the calibration data alone. Engines whose step
/// graphs the compiled artifacts predate are skipped with a notice.
pub fn synth(cfg: &RunConfig) -> Result<()> {
    let rt = Runtime::cpu()?;
    let mut table = ResultTable::new(
        "synth_engines",
        &["model", "engine", "top1", "fp32", "distill_secs"],
    );
    for model in models_of(cfg) {
        let m = Manifest::load(format!("{}/{}", cfg.artifacts, model))?;
        let engines: Vec<AxisValue> =
            [Engine::Genie, Engine::Zeroq, Engine::Zaq]
                .into_iter()
                .filter(|e| {
                    let mut dc = cfg.distill.clone();
                    dc.engine = *e;
                    let entry = e.policy().entry(&dc, "swing");
                    let ok = m.entrypoints.contains_key(&entry);
                    if !ok {
                        println!(
                            "[synth] {model}: skipping {} (artifacts \
                             predate entry '{entry}')",
                            e.as_str()
                        );
                    }
                    ok
                })
                .map(AxisValue::Synthesis)
                .collect();
        if engines.is_empty() {
            continue;
        }
        let grid = RunGrid::new()
            .axis("model", vec![AxisValue::Model(model.clone())])
            .axis("synthesis", engines);
        let mut metrics = Metrics::new();
        let out = grid::execute(
            &rt, cfg, &grid, &GridOpts::default(), &mut metrics,
        )?;
        for cell in &out.cells {
            let o =
                cell.outcome.as_ref().context("synth: missing outcome")?;
            let engine = cell.spec.coord("synthesis").unwrap_or("?");
            println!(
                "[synth] {} {}: {} (fp32 {})",
                cell.spec.model, engine, pct(o.q_acc), pct(o.fp_acc)
            );
            table.row(vec![
                cell.spec.model.clone(),
                engine.into(),
                pct(o.q_acc),
                pct(o.fp_acc),
                o.distill_secs_cell(),
            ]);
        }
    }
    table.print_and_save()
}

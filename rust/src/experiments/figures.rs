//! Figure harnesses (paper evaluation + appendix; index in DESIGN.md §4).
//! Fig. 6's sample-count sweep runs as a declarative grid
//! (DESIGN.md §11); the trace/energy figures keep their bespoke loops.

use anyhow::{Context, Result};

use crate::coordinator::{
    distill, eval_quantized, quantize, DistillCfg, DistillMode, Metrics,
    QuantCfg, RunConfig,
};
use crate::grid::{AxisValue, DataMode, GridOpts, QuantArm, RunGrid};
use crate::runtime::Runtime;
use crate::tensor::{checkerboard_energy, Pcg32};

use super::tables::load_ctx;
use super::{pct, ResultTable};

/// Fig. 5: swing conv vs checkerboard artifacts. Direct (generator-free)
/// distillation with and without swing; metric = fraction of image
/// variance in the 2x2 Haar HH band (stride-2 Nyquist energy).
pub fn fig5(cfg: &RunConfig) -> Result<()> {
    let rt = Runtime::cpu()?;
    let ctx = load_ctx(&rt, cfg, cfg.model.split(',').next().unwrap())?;
    let mut table = ResultTable::new(
        "fig5_checkerboard",
        &["arm", "hh_energy", "final_bns_loss"],
    );
    for (name, swing) in [("no_swing", false), ("swing", true)] {
        let mut dcfg = cfg.distill.clone();
        dcfg.mode = DistillMode::Direct;
        dcfg.swing = swing;
        let mut metrics = Metrics::new();
        let out = distill(&ctx.mrt, &ctx.teacher, &dcfg, &mut metrics)?;
        let e = checkerboard_energy(&out.images);
        println!("[fig5] {name}: HH energy {e:.4}, BNS {:.3}", out.final_loss);
        table.row(vec![
            name.into(),
            format!("{e:.5}"),
            format!("{:.4}", out.final_loss),
        ]);
    }
    // reference: real data HH energy
    let real = ctx.dataset.train_x.take_rows(256);
    table.row(vec![
        "real_data".into(),
        format!("{:.5}", checkerboard_energy(&real)),
        "-".into(),
    ]);
    table.print_and_save()
}

/// Fig. 6 / Table A1 / Fig. A4: accuracy vs number of synthetic samples,
/// for GENIE vs ZeroQ data (quantizer fixed) — a samples × arm grid; the
/// six cells share one teacher and one FP32 eval, and the scheduler
/// interleaves the six syntheses/quantizations on the pool.
pub fn fig6(cfg: &RunConfig) -> Result<()> {
    let rt = Runtime::cpu()?;
    let mut table = ResultTable::new(
        "fig6_sample_count",
        &["samples", "method", "top1"],
    );
    let arms = vec![
        AxisValue::Arm {
            label: "ZeroQ".into(),
            data: DataMode::Synthetic { mode: DistillMode::Direct, swing: false },
            quant: QuantArm { adaround: true, no_drop: false },
        },
        AxisValue::Arm {
            label: "GENIE".into(),
            data: DataMode::Synthetic { mode: DistillMode::Genie, swing: true },
            quant: QuantArm::default(),
        },
    ];
    let grid = RunGrid::new()
        .axis(
            "samples",
            [64usize, 128, 256].into_iter().map(AxisValue::Samples).collect(),
        )
        .axis("arm", arms);
    let mut metrics = Metrics::new();
    let out = crate::grid::execute(
        &rt, cfg, &grid, &GridOpts::default(), &mut metrics,
    )?;
    for cell in &out.cells {
        let o = cell.outcome.as_ref().context("fig6: missing outcome")?;
        let n = cell.spec.distill.samples;
        let name = cell.spec.coord("arm").unwrap_or("?");
        println!("[fig6] {name} n={n}: {}", pct(o.q_acc));
        table.row(vec![n.to_string(), name.into(), pct(o.q_acc)]);
    }
    table.print_and_save()
}

/// Fig. A2: initial step-size p-norm sweep — GENIE-M (learned s) vs
/// AdaRound (frozen s) sensitivity to the Eq. A3 exponent.
pub fn fig_a2(cfg: &RunConfig) -> Result<()> {
    let rt = Runtime::cpu()?;
    let ctx = load_ctx(&rt, cfg, cfg.model.split(',').next().unwrap())?;
    let mut rng = Pcg32::new(cfg.seed ^ 0xa2);
    let (calib, _) = ctx.dataset.calibration(&mut rng, cfg.fsq_samples);
    let mut table = ResultTable::new(
        "figA2_init_pnorm",
        &["pnorm", "method", "top1"],
    );
    for pnorm in [2.0f32, 2.4, 3.0, 4.0] {
        for (name, frozen) in [("GENIE-M", false), ("AdaRound", true)] {
            let mut q: QuantCfg = cfg.quant.clone();
            q.pnorm = pnorm;
            if frozen {
                q = q.adaround();
            }
            let mut metrics = Metrics::new();
            let qstate =
                quantize(&ctx.mrt, &ctx.teacher, &calib, &q, &mut metrics)?;
            let acc =
                eval_quantized(&ctx.mrt, &ctx.teacher, &qstate, &ctx.dataset)?;
            println!("[figA2] p={pnorm} {name}: {}", pct(acc));
            table.row(vec![format!("{pnorm}"), name.into(), pct(acc)]);
        }
    }
    table.print_and_save()
}

/// Fig. A5: BNS-loss convergence traces for ZeroQ (direct), GBA and GENIE.
pub fn fig_a5(cfg: &RunConfig) -> Result<()> {
    let rt = Runtime::cpu()?;
    let ctx = load_ctx(&rt, cfg, cfg.model.split(',').next().unwrap())?;
    let mut table = ResultTable::new(
        "figA5_bns_convergence",
        &["step", "zeroq", "gba", "genie"],
    );
    let mut traces = Vec::new();
    for (mode, swing) in [
        (DistillMode::Direct, false),
        (DistillMode::Gba, false),
        (DistillMode::Genie, true),
    ] {
        let mut dcfg: DistillCfg = cfg.distill.clone();
        dcfg.mode = mode;
        dcfg.swing = swing;
        dcfg.samples = dcfg.samples.min(64); // one batch for a clean trace
        dcfg.log_every = (dcfg.steps / 20).max(1);
        let mut metrics = Metrics::new();
        let out = distill(&ctx.mrt, &ctx.teacher, &dcfg, &mut metrics)?;
        traces.push(out.loss_trace);
    }
    let rows = traces[0].len();
    for i in 0..rows {
        table.row(vec![
            traces[0][i].0.to_string(),
            format!("{:.4}", traces[0][i].1),
            format!("{:.4}", traces[1][i].1),
            format!("{:.4}", traces[2][i].1),
        ]);
    }
    table.print_and_save()
}

//! Paper table/figure harnesses (experiment index: DESIGN.md section 4).
//!
//! Each harness regenerates the rows/series of one table or figure of the
//! paper on the scaled testbed (models/bits/sample counts configurable via
//! the usual `key=value` overrides; defaults are sized for a single CPU
//! core). Results are printed as aligned tables and written to
//! `results/<exp>.csv`.

pub mod qat;
pub mod tables;
pub mod figures;

use anyhow::{bail, Result};

use crate::coordinator::RunConfig;

pub fn run(exp: &str, cfg: &RunConfig) -> Result<()> {
    match exp {
        "table2" => tables::table2(cfg),
        "table3" => tables::table3(cfg),
        "table4" => tables::table4(cfg),
        "table5" => tables::table5(cfg),
        "table6" => tables::table6(cfg),
        "synth" => tables::synth(cfg),
        "plan" => tables::plan_report(cfg),
        "fig5" => figures::fig5(cfg),
        "fig6" => figures::fig6(cfg),
        "figA2" => figures::fig_a2(cfg),
        "figA5" => figures::fig_a5(cfg),
        "all" => {
            for e in ["table2", "table3", "table4", "table5", "table6",
                      "synth", "plan", "fig5", "fig6", "figA2", "figA5"] {
                println!("\n################ {e} ################");
                run(e, cfg)?;
            }
            Ok(())
        }
        "" => bail!(
            "experiments: pass --exp <table2|table3|table4|table5|table6|synth|plan|fig5|fig6|figA2|figA5|all>"
        ),
        other => bail!("unknown experiment '{other}'"),
    }
}

/// Aligned-table printer + CSV sink for experiment results.
pub struct ResultTable {
    name: String,
    header: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl ResultTable {
    pub fn new(name: &str, header: &[&str]) -> Self {
        ResultTable {
            name: name.to_string(),
            header: header.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    pub fn row(&mut self, cells: Vec<String>) {
        self.rows.push(cells);
    }

    pub fn print_and_save(&self) -> Result<()> {
        let mut widths: Vec<usize> =
            self.header.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for (i, c) in row.iter().enumerate() {
                widths[i] = widths[i].max(c.len());
            }
        }
        let fmt_row = |cells: &[String]| {
            cells
                .iter()
                .enumerate()
                .map(|(i, c)| format!("{:w$}", c, w = widths[i]))
                .collect::<Vec<_>>()
                .join("  ")
        };
        println!("\n=== {} ===", self.name);
        println!("{}", fmt_row(&self.header));
        for row in &self.rows {
            println!("{}", fmt_row(row));
        }
        std::fs::create_dir_all("results")?;
        let mut csv = String::new();
        csv.push_str(&self.header.join(","));
        csv.push('\n');
        for row in &self.rows {
            csv.push_str(&row.join(","));
            csv.push('\n');
        }
        let path = format!("results/{}.csv", self.name);
        std::fs::write(&path, csv)?;
        println!("(saved to {path})");
        Ok(())
    }
}

pub fn pct(x: f32) -> String {
    format!("{:.2}", x * 100.0)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn result_table_saves_csv() {
        let mut t = ResultTable::new("_test_table", &["a", "b"]);
        t.row(vec!["1".into(), "2".into()]);
        t.print_and_save().unwrap();
        let text = std::fs::read_to_string("results/_test_table.csv").unwrap();
        assert!(text.contains("a,b"));
        assert!(text.contains("1,2"));
        std::fs::remove_file("results/_test_table.csv").unwrap();
    }

    #[test]
    fn unknown_experiment_errors() {
        assert!(run("nope", &RunConfig::default()).is_err());
    }
}

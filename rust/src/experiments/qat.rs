//! Netwise Min-Max QAT baseline driver (the GDFQ/AIT-style comparator of
//! Table 4 / Table 6 / Table A2): student initialized from the teacher,
//! trained with KL-to-teacher under Min-Max fake-quant, evaluated under
//! the same quantizer.

use anyhow::Result;

use crate::data::{image_batches, Dataset};
use crate::quant::BitConfig;
use crate::runtime::ModelRt;
use crate::store::Store;
use crate::tensor::{accuracy, Pcg32, Tensor};

use crate::coordinator::Metrics;

#[derive(Debug, Clone)]
pub struct QatCfg {
    pub wbits: u32,
    pub abits: u32,
    pub steps: usize,
    pub lr: f32,
    pub seed: u64,
}

impl Default for QatCfg {
    fn default() -> Self {
        QatCfg { wbits: 4, abits: 4, steps: 300, lr: 1e-4, seed: 41 }
    }
}

/// Train the QAT student on `calib` images (synthetic or real); returns
/// the student params store (prefixed `s.`).
pub fn qat_train(
    mrt: &ModelRt,
    teacher: &Store,
    calib: &Tensor,
    cfg: &QatCfg,
    metrics: &mut Metrics,
) -> Result<Store> {
    let m = &mrt.manifest;
    let bs = m.batch("train");
    let mut rng = Pcg32::new(cfg.seed);
    let (_, wp) = BitConfig::wbounds(cfg.wbits);
    // symmetric weight grid in the minmax baseline: wp = 2^(b-1)-1
    let wp_sym = ((1u64 << (cfg.wbits - 1)) - 1) as f32;
    let (_, ap) = BitConfig::abounds(cfg.abits);
    let _ = wp;

    let mut store = teacher.clone();
    // student initialized from the teacher (Arc-shared, not copied)
    for (name, _) in &m.params {
        store.insert_shared(&format!("s.{name}"), teacher.get_shared(name)?);
        let shape = teacher.get(name)?.shape.clone();
        store.insert(&format!("am.{name}"), Tensor::zeros(&shape));
        store.insert(&format!("av.{name}"), Tensor::zeros(&shape));
    }
    store.insert("wp", Tensor::scalar_f32(wp_sym));
    store.insert("ap", Tensor::scalar_f32(ap));
    store.insert("lr", Tensor::scalar_f32(cfg.lr));

    metrics.start("qat");
    let entry = mrt.entry("qat_step")?;
    let batches = image_batches(calib, bs);
    // teacher + student + moments stay resident across the whole run;
    // batches are staged once and re-picked per step by zero-byte alias
    let mut dev = mrt.upload_store(&store)?;
    for (i, (bx, _)) in batches.iter().enumerate() {
        dev.insert(&format!("x.{i}"), bx)?;
    }
    for t in 1..=cfg.steps {
        let bi = rng.below(batches.len());
        dev.alias("x", &format!("x.{bi}"))?;
        dev.insert("t", &Tensor::scalar_f32(t as f32))?;
        let scalars = mrt.rt.call_device(&entry, &mut dev)?;
        if t % 100 == 0 || t == cfg.steps {
            metrics.log("qat/kl", t, scalars["loss"]);
        }
    }
    let (h2d, d2h) = dev.transfer_bytes();
    metrics.record_transfers("qat", cfg.steps, h2d, d2h);
    let secs = metrics.stop("qat");
    println!(
        "qat[{} W{}A{}]: {} steps in {:.1}s (KL {:.4})",
        m.model,
        cfg.wbits,
        cfg.abits,
        cfg.steps,
        secs,
        metrics.last("qat/kl").unwrap_or(f32::NAN)
    );

    // phase boundary: only the student params come home
    let mut out = Store::new();
    for (name, _) in &m.params {
        let n = format!("s.{name}");
        let t = dev.fetch(&n)?;
        out.insert(&n, t);
    }
    Ok(out)
}

/// Top-1 of the QAT student under Min-Max fake-quant.
pub fn qat_eval(
    mrt: &ModelRt,
    teacher: &Store,
    student: &Store,
    dataset: &Dataset,
    cfg: &QatCfg,
) -> Result<f32> {
    let m = &mrt.manifest;
    let bs = m.batch("eval");
    let wp_sym = ((1u64 << (cfg.wbits - 1)) - 1) as f32;
    let (_, ap) = BitConfig::abounds(cfg.abits);
    let entry = mrt.entry("eval_qat")?;
    let mut store = teacher.clone();
    store.absorb(student);
    store.insert("wp", Tensor::scalar_f32(wp_sym));
    store.insert("ap", Tensor::scalar_f32(ap));
    let mut dev = mrt.upload_store(&store)?;
    let mut correct = 0.0f64;
    let mut total = 0usize;
    for (x, y, valid) in dataset.eval_batches(bs) {
        dev.insert("x", &x)?;
        mrt.rt.call_device(&entry, &mut dev)?;
        let logits = dev.fetch("logits")?;
        let acc = accuracy(&logits, &y, valid);
        correct += acc as f64 * valid as f64;
        total += valid;
    }
    Ok((correct / total as f64) as f32)
}

//! Netwise Min-Max QAT baseline driver (the GDFQ/AIT-style comparator of
//! Table 4 / Table 6 / Table A2): student initialized from the teacher,
//! trained with KL-to-teacher under Min-Max fake-quant, evaluated under
//! the same quantizer. The training loop runs on the shared phase engine
//! ([`QatPhase`], DESIGN.md §9): teacher + student + moments stay
//! resident, batches are staged once and re-picked per step by zero-byte
//! alias.

use anyhow::Result;

use crate::coordinator::evaluate::EvalChunk;
use crate::coordinator::Metrics;
use crate::data::{image_batches, Dataset};
use crate::phase::{Phase, StepLoop};
use crate::precision::abounds;
use crate::runtime::{DeviceStore, ModelRt};
use crate::store::Store;
use crate::tensor::{Pcg32, Tensor};

#[derive(Debug, Clone)]
pub struct QatCfg {
    pub wbits: u32,
    pub abits: u32,
    pub steps: usize,
    pub lr: f32,
    pub seed: u64,
}

impl Default for QatCfg {
    fn default() -> Self {
        QatCfg { wbits: 4, abits: 4, steps: 300, lr: 1e-4, seed: 41 }
    }
}

/// The QAT step loop as a [`Phase`]: init stages the student/moments and
/// every candidate batch; each step aliases one batch in and dispatches.
struct QatPhase<'a, 'rt> {
    mrt: &'a ModelRt<'rt>,
    init_store: &'a Store,
    batches: &'a [(Tensor, usize)],
    rng: Pcg32,
}

impl Phase for QatPhase<'_, '_> {
    fn name(&self) -> String {
        "qat".into()
    }

    fn entry(&self) -> String {
        "qat_step".into()
    }

    fn init(&mut self, dev: &mut DeviceStore) -> Result<()> {
        dev.absorb(self.init_store)?;
        for (i, (bx, _)) in self.batches.iter().enumerate() {
            dev.insert(&format!("x.{i}"), bx)?;
        }
        Ok(())
    }

    fn before_step(&mut self, t: usize, dev: &mut DeviceStore) -> Result<()> {
        let bi = self.rng.below(self.batches.len());
        dev.alias("x", &format!("x.{bi}"))?;
        dev.insert("t", &Tensor::scalar_f32(t as f32))?;
        Ok(())
    }

    fn carried(&self) -> Vec<String> {
        let m = &self.mrt.manifest;
        let mut v = Vec::new();
        for (name, _) in &m.params {
            v.push(format!("s.{name}"));
            v.push(format!("am.{name}"));
            v.push(format!("av.{name}"));
        }
        v
    }

    fn finish(&mut self, dev: &mut DeviceStore) -> Result<Store> {
        // phase boundary: only the student params come home
        let mut out = Store::new();
        for (name, _) in &self.mrt.manifest.params {
            let n = format!("s.{name}");
            let t = dev.fetch(&n)?;
            out.insert(&n, t);
        }
        Ok(out)
    }
}

/// Train the QAT student on `calib` images (synthetic or real); returns
/// the student params store (prefixed `s.`).
pub fn qat_train(
    mrt: &ModelRt,
    teacher: &Store,
    calib: &Tensor,
    cfg: &QatCfg,
    metrics: &mut Metrics,
) -> Result<Store> {
    let m = &mrt.manifest;
    let bs = m.batch("train");
    // symmetric weight grid in the minmax baseline: wp = 2^(b-1)-1
    let wp_sym = ((1u64 << (cfg.wbits - 1)) - 1) as f32;
    let (_, ap) = abounds(cfg.abits);

    let mut store = teacher.clone();
    // student initialized from the teacher (Arc-shared, not copied)
    for (name, _) in &m.params {
        store.insert_shared(&format!("s.{name}"), teacher.get_shared(name)?);
        let shape = teacher.get(name)?.shape.clone();
        store.insert(&format!("am.{name}"), Tensor::zeros(&shape));
        store.insert(&format!("av.{name}"), Tensor::zeros(&shape));
    }
    store.insert("wp", Tensor::scalar_f32(wp_sym));
    store.insert("ap", Tensor::scalar_f32(ap));
    store.insert("lr", Tensor::scalar_f32(cfg.lr));

    metrics.start("qat");
    let batches = image_batches(calib, bs);
    let mut phase = QatPhase {
        mrt,
        init_store: &store,
        batches: &batches,
        rng: Pcg32::new(cfg.seed),
    };
    let mut dev = mrt.rt.device_store();
    let out = StepLoop::new(cfg.steps, 100)
        .run(mrt, &mut phase, &mut dev)?;
    for (t, sc) in &out.trace {
        metrics.log("qat/kl", *t, sc["loss"]);
    }
    let (h2d, d2h) = dev.transfer_bytes();
    metrics.record_transfers("qat", cfg.steps, h2d, d2h);
    let secs = metrics.stop("qat");
    println!(
        "qat[{} W{}A{}]: {} steps in {:.1}s (KL {:.4})",
        m.model,
        cfg.wbits,
        cfg.abits,
        cfg.steps,
        secs,
        metrics.last("qat/kl").unwrap_or(f32::NAN)
    );
    Ok(out.result)
}

/// Top-1 of the QAT student under Min-Max fake-quant — the coordinator's
/// [`EvalChunk`] phase driven with the `eval_qat` entry.
pub fn qat_eval(
    mrt: &ModelRt,
    teacher: &Store,
    student: &Store,
    dataset: &Dataset,
    cfg: &QatCfg,
) -> Result<f32> {
    let m = &mrt.manifest;
    let bs = m.batch("eval");
    let wp_sym = ((1u64 << (cfg.wbits - 1)) - 1) as f32;
    let (_, ap) = abounds(cfg.abits);
    let mut store = teacher.clone();
    store.absorb(student);
    store.insert("wp", Tensor::scalar_f32(wp_sym));
    store.insert("ap", Tensor::scalar_f32(ap));
    let mut dev = mrt.upload_store(&store)?;
    let batches = dataset.eval_batches(bs);
    let mut phase = EvalChunk {
        entry_name: "eval_qat",
        chunk: &batches,
        out: Vec::with_capacity(batches.len()),
    };
    StepLoop::new(batches.len(), 0).run(mrt, &mut phase, &mut dev)?;
    let mut correct = 0.0f64;
    let mut total = 0usize;
    for (c, v) in phase.out {
        correct += c;
        total += v;
    }
    anyhow::ensure!(total > 0, "qat eval: empty test set");
    Ok((correct / total as f64) as f32)
}

//! Deterministic fault injection (DESIGN.md §13): a config/env-driven
//! [`FaultPlan`] that fires failures at named stage/site/attempt points,
//! so every recovery path in the stack — pool panic containment, grid
//! retry/quarantine supervision, artifact-corruption quarantine — is
//! testable on demand and repeatable bit-for-bit.
//!
//! Grammar (`GENIE_FAULTS`, comma-separated entries):
//!
//! ```text
//! <stage>:<site>:attempt<N>=panic|err    inject at a named check point
//! <stage>:<site>:*=panic|err             ... on every attempt
//! <stage>:<site>:attempt<N>=sleep<MS>    delay the check point MS
//!                                        milliseconds, then succeed —
//!                                        forces adversarial completion
//!                                        orders for the scheduler-
//!                                        equivalence tests (§15)
//! artifact:corrupt:<key-prefix>          flip a byte in the next cached
//!                                        artifact whose file stem
//!                                        (`<kind>_<hexkey>`) starts with
//!                                        the prefix (`*` = any); each
//!                                        corrupt entry fires once
//! ```
//!
//! `<stage>`/`<site>` match exactly or via `*`. Check points are wired
//! through the stack: the grid executor checks `(<stagekind>, <tag>)` per
//! supervised attempt (e.g. `quantize:c3`, `distill:shared:distill`), the
//! distill scheduler checks `(distill, shard<b>)` per shard, the phase
//! engine checks `(steploop, <phase-name>)` per loop entry, and the
//! artifact cache offers every load to the corrupt hook. Attempt counters
//! are keyed by the concrete `(stage, site)` pair, so
//! `distill:shard2:attempt1=panic` panics the first execution of shard 2
//! and lets the supervised retry through — deterministically, whatever
//! the worker count or completion order.
//!
//! The active plan is process-global: seeded lazily from `GENIE_FAULTS`
//! (or eagerly via [`init_from_env`], which surfaces parse errors), and
//! swappable under a scope guard ([`scoped`]) for in-process tests. No
//! plan active (the production default) means every check is an inert
//! `Ok(())`.

use std::collections::HashMap;
use std::path::Path;
use std::sync::{Arc, Mutex, OnceLock, RwLock};

use anyhow::{bail, Context, Result};

/// What an injection point does when it fires.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum FaultKind {
    /// `panic!` at the check point — exercises `catch_unwind`
    /// containment in the pool and the grid supervisor.
    Panic,
    /// Return an `Err` from the check point — a transient failure the
    /// bounded-retry path recovers from.
    Err,
    /// Sleep this many milliseconds at the check point, then succeed —
    /// a pure scheduling perturbation (`sleep<MS>`) that forces
    /// adversarial completion orders without failing anything, so the
    /// scheduler-equivalence tests (DESIGN.md §15) can prove the merge
    /// is completion-order-independent.
    Delay(u64),
}

impl FaultKind {
    fn parse(s: &str) -> Result<Self> {
        if let Some(ms) = s.strip_prefix("sleep") {
            let ms: u64 = ms.parse().map_err(|e| {
                anyhow::anyhow!("bad sleep duration '{ms}': {e}")
            })?;
            return Ok(FaultKind::Delay(ms));
        }
        match s {
            "panic" => Ok(FaultKind::Panic),
            "err" | "error" => Ok(FaultKind::Err),
            other => bail!(
                "unknown fault kind '{other}' (want panic|err|sleep<MS>)"
            ),
        }
    }

    fn as_str(self) -> &'static str {
        match self {
            FaultKind::Panic => "panic",
            FaultKind::Err => "err",
            FaultKind::Delay(_) => "sleep",
        }
    }
}

/// One stage/site/attempt injection point (`*` wildcards stage or site;
/// `attempt == 0` means every attempt).
#[derive(Debug, Clone)]
struct FaultPoint {
    stage: String,
    site: String,
    attempt: u32,
    kind: FaultKind,
}

#[derive(Debug, Default)]
struct PlanState {
    /// Per-(stage, site) check counts — the attempt number each check
    /// observes. Keyed by the concrete pair, so a wildcard spec fires
    /// once per distinct site.
    attempts: HashMap<(String, String), u32>,
    /// One flag per corrupt prefix: each fires at most once.
    corrupt_fired: Vec<bool>,
    /// Human-readable log of every fault that actually fired.
    injected: Vec<String>,
}

/// A parsed, stateful fault plan. Instance methods are safe to share
/// across threads (attempt counters live behind a mutex); unit tests use
/// instances directly, the runtime consults the process-global one.
#[derive(Debug)]
pub struct FaultPlan {
    points: Vec<FaultPoint>,
    corrupt: Vec<String>,
    state: Mutex<PlanState>,
}

fn pat_matches(pat: &str, v: &str) -> bool {
    pat == "*" || pat == v
}

impl FaultPlan {
    /// Parse the `GENIE_FAULTS` grammar (see module docs).
    pub fn parse(text: &str) -> Result<FaultPlan> {
        let mut points = Vec::new();
        let mut corrupt = Vec::new();
        for raw in text.split(',') {
            let entry = raw.trim();
            if entry.is_empty() {
                continue;
            }
            if let Some(prefix) = entry.strip_prefix("artifact:corrupt:") {
                let prefix = prefix.trim();
                anyhow::ensure!(
                    !prefix.is_empty(),
                    "fault entry '{entry}': empty key prefix (use * for any)"
                );
                corrupt.push(prefix.to_string());
                continue;
            }
            let Some((point, kind)) = entry.split_once('=') else {
                bail!(
                    "fault entry '{entry}': expected \
                     stage:site:attemptN=panic|err or \
                     artifact:corrupt:<key-prefix>"
                );
            };
            let kind = FaultKind::parse(kind.trim())
                .with_context(|| format!("fault entry '{entry}'"))?;
            let parts: Vec<&str> = point.split(':').collect();
            let [stage, site, when] = parts.as_slice() else {
                bail!(
                    "fault entry '{entry}': expected three ':'-separated \
                     fields (stage:site:attemptN)"
                );
            };
            let attempt = if when.trim() == "*" {
                0
            } else {
                let n: u32 = when
                    .trim()
                    .strip_prefix("attempt")
                    .and_then(|n| n.parse().ok())
                    .with_context(|| {
                        format!(
                            "fault entry '{entry}': bad attempt selector \
                             '{when}' (want attempt<N> or *)"
                        )
                    })?;
                anyhow::ensure!(
                    n >= 1,
                    "fault entry '{entry}': attempts are 1-based"
                );
                n
            };
            points.push(FaultPoint {
                stage: stage.trim().to_string(),
                site: site.trim().to_string(),
                attempt,
                kind,
            });
        }
        let corrupt_fired = vec![false; corrupt.len()];
        Ok(FaultPlan {
            points,
            corrupt,
            state: Mutex::new(PlanState {
                corrupt_fired,
                ..Default::default()
            }),
        })
    }

    /// An inert plan (parses the empty string).
    pub fn empty() -> FaultPlan {
        FaultPlan {
            points: Vec::new(),
            corrupt: Vec::new(),
            state: Mutex::new(PlanState::default()),
        }
    }

    pub fn is_empty(&self) -> bool {
        self.points.is_empty() && self.corrupt.is_empty()
    }

    fn lock(&self) -> std::sync::MutexGuard<'_, PlanState> {
        self.state.lock().unwrap_or_else(|p| p.into_inner())
    }

    /// One named check point: bumps the `(stage, site)` attempt counter
    /// and fires any matching point — `panic!` for [`FaultKind::Panic`],
    /// `Err` for [`FaultKind::Err`]. Inert when nothing matches.
    pub fn check(&self, stage: &str, site: &str) -> Result<()> {
        if self.points.is_empty() {
            return Ok(());
        }
        let fired = {
            let mut st = self.lock();
            let n = st
                .attempts
                .entry((stage.to_string(), site.to_string()))
                .or_insert(0);
            *n += 1;
            let n = *n;
            let hit = self.points.iter().find(|p| {
                pat_matches(&p.stage, stage)
                    && pat_matches(&p.site, site)
                    && (p.attempt == 0 || p.attempt == n)
            });
            match hit {
                Some(p) => {
                    st.injected.push(format!(
                        "{stage}:{site}:attempt{n}={}",
                        p.kind.as_str()
                    ));
                    Some((p.kind, n))
                }
                None => None,
            }
        };
        // fire outside the lock: a panic must not poison the plan, and
        // a delay must not serialize other sites' checks
        match fired {
            None => Ok(()),
            Some((FaultKind::Err, n)) => bail!(
                "injected transient fault: {stage}:{site} attempt {n}"
            ),
            Some((FaultKind::Panic, n)) => {
                panic!("injected fault: {stage}:{site} attempt {n}")
            }
            Some((FaultKind::Delay(ms), _)) => {
                std::thread::sleep(std::time::Duration::from_millis(ms));
                Ok(())
            }
        }
    }

    /// Offer one on-disk artifact to the corrupt specs: the first unfired
    /// prefix matching `stem` (`<kind>_<hexkey>`) flips a byte in the
    /// middle of the file and is marked fired. Returns whether the file
    /// was corrupted.
    pub fn corrupt_artifact(&self, stem: &str, path: &Path) -> bool {
        if self.corrupt.is_empty() {
            return false;
        }
        let mut st = self.lock();
        for (i, prefix) in self.corrupt.iter().enumerate() {
            if st.corrupt_fired[i] {
                continue;
            }
            if !(prefix == "*" || stem.starts_with(prefix.as_str())) {
                continue;
            }
            let Ok(mut bytes) = std::fs::read(path) else { continue };
            if bytes.is_empty() {
                continue;
            }
            let mid = bytes.len() / 2;
            bytes[mid] ^= 0xff;
            if std::fs::write(path, &bytes).is_err() {
                continue;
            }
            st.corrupt_fired[i] = true;
            st.injected.push(format!("artifact:corrupt:{stem}"));
            return true;
        }
        false
    }

    /// Every fault that actually fired, in firing order.
    pub fn injected(&self) -> Vec<String> {
        self.lock().injected.clone()
    }
}

static ACTIVE: RwLock<Option<Arc<FaultPlan>>> = RwLock::new(None);
static ENV_SEEDED: OnceLock<()> = OnceLock::new();

fn seed_from_env() {
    ENV_SEEDED.get_or_init(|| {
        if let Ok(text) = std::env::var("GENIE_FAULTS") {
            if !text.trim().is_empty() {
                match FaultPlan::parse(&text) {
                    Ok(p) if !p.is_empty() => {
                        *ACTIVE.write().unwrap_or_else(|e| e.into_inner()) =
                            Some(Arc::new(p));
                    }
                    Ok(_) => {}
                    Err(e) => eprintln!(
                        "warning: GENIE_FAULTS ignored (parse error: {e})"
                    ),
                }
            }
        }
    });
}

/// The active plan, if any — seeded from `GENIE_FAULTS` on first use.
pub fn current() -> Option<Arc<FaultPlan>> {
    seed_from_env();
    ACTIVE.read().unwrap_or_else(|e| e.into_inner()).clone()
}

/// Eagerly parse `GENIE_FAULTS`, surfacing parse errors (the CLI calls
/// this at startup so a typo'd plan fails fast instead of being ignored
/// by the lazy path).
pub fn init_from_env() -> Result<()> {
    if let Ok(text) = std::env::var("GENIE_FAULTS") {
        if !text.trim().is_empty() {
            FaultPlan::parse(&text)
                .context("bad GENIE_FAULTS")?;
        }
    }
    seed_from_env();
    Ok(())
}

/// Process-global check point (see [`FaultPlan::check`]); inert without
/// an active plan.
pub fn check(stage: &str, site: &str) -> Result<()> {
    match current() {
        Some(p) => p.check(stage, site),
        None => Ok(()),
    }
}

/// Process-global corrupt hook: called by the artifact cache before every
/// load with the file stem (`<kind>_<hexkey>`) and path. Returns whether
/// the file was corrupted, so the caller can invalidate any in-memory
/// (tier-0) copy of the same artifact — an injected disk corruption must
/// be observed, not masked by the hot cache.
pub fn corrupt_hook(stem: &str, path: &Path) -> bool {
    if let Some(p) = current() {
        if p.corrupt_artifact(stem, path) {
            crate::progress!("faults: corrupted cached artifact {stem}");
            return true;
        }
    }
    false
}

/// Restores the previously active plan when dropped.
#[derive(Debug)]
pub struct ScopedPlan {
    prev: Option<Arc<FaultPlan>>,
}

impl Drop for ScopedPlan {
    fn drop(&mut self) {
        *ACTIVE.write().unwrap_or_else(|e| e.into_inner()) =
            self.prev.take();
    }
}

/// Install `plan` as the process-global plan for the guard's lifetime
/// (test harness hook — fault-injection tests in one binary must
/// serialize around this, the global is process-wide).
pub fn scoped(plan: FaultPlan) -> ScopedPlan {
    seed_from_env();
    let mut slot = ACTIVE.write().unwrap_or_else(|e| e.into_inner());
    let prev = slot.replace(Arc::new(plan));
    ScopedPlan { prev }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_grammar_and_wildcards() {
        let p = FaultPlan::parse(
            "distill:shard2:attempt1=panic, quantize:*:attempt1=err, \
             artifact:corrupt:distill, steploop:*:*=err",
        )
        .unwrap();
        assert_eq!(p.points.len(), 3);
        assert_eq!(p.corrupt, vec!["distill".to_string()]);
        assert_eq!(p.points[0].kind, FaultKind::Panic);
        assert_eq!(p.points[0].attempt, 1);
        assert_eq!(p.points[1].site, "*");
        assert_eq!(p.points[2].attempt, 0, "'*' selector = every attempt");
        assert!(!p.is_empty());
        assert!(FaultPlan::parse("").unwrap().is_empty());
    }

    #[test]
    fn parse_rejects_malformed_entries() {
        assert!(FaultPlan::parse("distill:shard2=panic").is_err());
        assert!(FaultPlan::parse("distill:shard2:attempt1=boom").is_err());
        assert!(FaultPlan::parse("distill:shard2:attempt0=err").is_err());
        assert!(FaultPlan::parse("distill:shard2:first=err").is_err());
        assert!(FaultPlan::parse("artifact:corrupt:").is_err());
        assert!(FaultPlan::parse("justtext").is_err());
    }

    #[test]
    fn err_fires_on_named_attempt_only() {
        let p = FaultPlan::parse("quantize:*:attempt1=err").unwrap();
        // attempt 1 at each distinct site fails; attempt 2 passes
        assert!(p.check("quantize", "c0").is_err());
        assert!(p.check("quantize", "c0").is_ok());
        assert!(p.check("quantize", "c1").is_err(), "per-site counters");
        assert!(p.check("distill", "c0").is_ok(), "stage must match");
        assert_eq!(p.injected().len(), 2);
    }

    #[test]
    fn sleep_kind_delays_then_succeeds() {
        let p = FaultPlan::parse("quantize:c0:attempt1=sleep40").unwrap();
        assert_eq!(p.points[0].kind, FaultKind::Delay(40));
        let t0 = std::time::Instant::now();
        assert!(p.check("quantize", "c0").is_ok(), "a delay never fails");
        assert!(
            t0.elapsed().as_millis() >= 35,
            "the check point must actually sleep"
        );
        // fired on attempt 1 only, and logged
        let t1 = std::time::Instant::now();
        assert!(p.check("quantize", "c0").is_ok());
        assert!(t1.elapsed().as_millis() < 35);
        assert_eq!(p.injected(), vec![
            "quantize:c0:attempt1=sleep".to_string()
        ]);
        // malformed durations are parse errors
        assert!(FaultPlan::parse("a:b:*=sleep").is_err());
        assert!(FaultPlan::parse("a:b:*=sleepfast").is_err());
    }

    #[test]
    fn every_attempt_selector_always_fires() {
        let p = FaultPlan::parse("quantize:c3:*=err").unwrap();
        for _ in 0..3 {
            assert!(p.check("quantize", "c3").is_err());
        }
        assert!(p.check("quantize", "c2").is_ok());
    }

    #[test]
    fn panic_kind_panics_and_is_catchable() {
        let p = FaultPlan::parse("distill:shard2:attempt1=panic").unwrap();
        let r = std::panic::catch_unwind(
            std::panic::AssertUnwindSafe(|| p.check("distill", "shard2")),
        );
        assert!(r.is_err(), "first attempt must panic");
        // the counter advanced: the retry passes
        assert!(p.check("distill", "shard2").is_ok());
        assert_eq!(p.injected(), vec![
            "distill:shard2:attempt1=panic".to_string()
        ]);
    }

    #[test]
    fn corrupt_fires_once_per_prefix_and_flips_a_byte() {
        let dir = std::env::temp_dir().join("genie_faults_corrupt_test");
        std::fs::create_dir_all(&dir).unwrap();
        let f = dir.join("distill_abcd.gts");
        std::fs::write(&f, b"0123456789").unwrap();
        let p = FaultPlan::parse("artifact:corrupt:distill").unwrap();
        assert!(!p.corrupt_artifact("qstate_abcd", &f), "prefix gates");
        assert!(p.corrupt_artifact("distill_abcd", &f));
        let bytes = std::fs::read(&f).unwrap();
        assert_ne!(bytes, b"0123456789", "a byte must have flipped");
        assert_eq!(bytes.len(), 10, "corruption preserves length");
        assert!(
            !p.corrupt_artifact("distill_abcd", &f),
            "each corrupt entry fires once"
        );
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn empty_plan_is_inert() {
        let p = FaultPlan::empty();
        for _ in 0..4 {
            assert!(p.check("any", "where").is_ok());
        }
        assert!(p.injected().is_empty());
    }
}

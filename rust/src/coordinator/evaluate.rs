//! Top-1 accuracy evaluation for the FP32 teacher (`eval_batch`) and the
//! hard-quantized student (`eval_quant`) over padded fixed-size batches.

use anyhow::Result;

use crate::data::Dataset;
use crate::runtime::ModelRt;
use crate::store::Store;
use crate::tensor::accuracy;

/// FP32 teacher top-1 on the test set.
pub fn eval_fp32(mrt: &ModelRt, teacher: &Store, dataset: &Dataset) -> Result<f32> {
    let bs = mrt.manifest.batch("eval");
    let entry = mrt.entry("eval_batch")?;
    let mut store = teacher.clone();
    let mut correct = 0.0f64;
    let mut total = 0usize;
    for (x, y, valid) in dataset.eval_batches(bs) {
        store.insert("x", x);
        mrt.rt.call(&entry, &mut store)?;
        let acc = accuracy(store.get("logits")?, &y, valid);
        correct += acc as f64 * valid as f64;
        total += valid;
    }
    Ok((correct / total as f64) as f32)
}

/// Hard-quantized student top-1 on the test set.
pub fn eval_quantized(
    mrt: &ModelRt,
    teacher: &Store,
    qstate: &Store,
    dataset: &Dataset,
) -> Result<f32> {
    let bs = mrt.manifest.batch("eval");
    let entry = mrt.entry("eval_quant")?;
    let mut store = teacher.clone();
    store.absorb(qstate);
    let mut correct = 0.0f64;
    let mut total = 0usize;
    for (x, y, valid) in dataset.eval_batches(bs) {
        store.insert("x", x);
        mrt.rt.call(&entry, &mut store)?;
        let acc = accuracy(store.get("logits")?, &y, valid);
        correct += acc as f64 * valid as f64;
        total += valid;
    }
    Ok((correct / total as f64) as f32)
}

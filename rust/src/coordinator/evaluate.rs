//! Top-1 accuracy evaluation for the FP32 teacher (`eval_batch`) and the
//! hard-quantized student (`eval_quant`) over padded fixed-size batches.
//!
//! The batch list is sharded into contiguous chunks across the exec pool
//! (DESIGN.md §5): each worker chunk clones the parameter store once and
//! streams its batches through it. Per-batch correct counts are reduced on
//! the main thread in batch order, so the accuracy is bit-identical for
//! any worker count. `eval_fp32` / `eval_quantized` keep the historical
//! serial signature and delegate with [`Parallelism::SERIAL`].

use anyhow::Result;

use crate::data::Dataset;
use crate::exec::{run_jobs, Parallelism};
use crate::runtime::ModelRt;
use crate::store::Store;
use crate::tensor::{accuracy, Tensor};

/// FP32 teacher top-1 on the test set (serial).
pub fn eval_fp32(mrt: &ModelRt, teacher: &Store, dataset: &Dataset) -> Result<f32> {
    eval_fp32_par(mrt, teacher, dataset, Parallelism::SERIAL)
}

/// FP32 teacher top-1 on the test set, sharded across the pool.
pub fn eval_fp32_par(
    mrt: &ModelRt,
    teacher: &Store,
    dataset: &Dataset,
    par: Parallelism,
) -> Result<f32> {
    sharded_eval(mrt, teacher, None, dataset, par, "eval_batch")
}

/// Hard-quantized student top-1 on the test set (serial).
pub fn eval_quantized(
    mrt: &ModelRt,
    teacher: &Store,
    qstate: &Store,
    dataset: &Dataset,
) -> Result<f32> {
    eval_quantized_par(mrt, teacher, qstate, dataset, Parallelism::SERIAL)
}

/// Hard-quantized student top-1 on the test set, sharded across the pool.
pub fn eval_quantized_par(
    mrt: &ModelRt,
    teacher: &Store,
    qstate: &Store,
    dataset: &Dataset,
    par: Parallelism,
) -> Result<f32> {
    sharded_eval(mrt, teacher, Some(qstate), dataset, par, "eval_quant")
}

/// Shared driver: chunk the eval batches, run chunks as pool jobs, reduce
/// per-batch (correct, valid) pairs in batch order.
fn sharded_eval(
    mrt: &ModelRt,
    teacher: &Store,
    qstate: Option<&Store>,
    dataset: &Dataset,
    par: Parallelism,
    entry_name: &str,
) -> Result<f32> {
    let bs = mrt.manifest.batch("eval");
    let batches = dataset.eval_batches(bs);
    let n_batches = batches.len();
    let workers = par.resolve_for(n_batches);
    let chunk_len = n_batches.div_ceil(workers.max(1));

    let mut chunks: Vec<Vec<(Tensor, Vec<i32>, usize)>> = Vec::new();
    let mut it = batches.into_iter().peekable();
    while it.peek().is_some() {
        chunks.push(it.by_ref().take(chunk_len).collect());
    }

    let jobs: Vec<_> = chunks
        .into_iter()
        .map(|chunk| {
            move || -> Result<Vec<(f64, usize)>> {
                let entry = mrt.entry(entry_name)?;
                let mut store = teacher.clone();
                if let Some(q) = qstate {
                    store.absorb(q);
                }
                let mut out = Vec::with_capacity(chunk.len());
                for (x, y, valid) in chunk {
                    store.insert("x", x);
                    mrt.rt.call(&entry, &mut store)?;
                    let acc = accuracy(store.get("logits")?, &y, valid);
                    out.push((acc as f64 * valid as f64, valid));
                }
                Ok(out)
            }
        })
        .collect();
    let (parts, _pool) = run_jobs(par, jobs)?;

    let mut correct = 0.0f64;
    let mut total = 0usize;
    for (c, v) in parts.into_iter().flatten() {
        correct += c;
        total += v;
    }
    anyhow::ensure!(total > 0, "eval: empty test set");
    Ok((correct / total as f64) as f32)
}

//! Top-1 accuracy evaluation for the FP32 teacher (`eval_batch`) and the
//! hard-quantized student (`eval_quant`) over padded fixed-size batches.
//!
//! The batch list is sharded into contiguous chunks across the exec pool
//! (DESIGN.md §5): params (+ quant state) are uploaded once and the
//! resident buffers are shared by every worker chunk; each chunk's
//! per-batch loop runs on the shared phase engine ([`EvalChunk`],
//! DESIGN.md §9) — only the images go up and the logits come down.
//! Per-batch correct counts are reduced on the main thread in batch
//! order, so the accuracy is bit-identical for any worker count.
//! `eval_fp32` / `eval_quantized` keep the historical serial signature
//! and delegate with [`Parallelism::SERIAL`].

use anyhow::Result;

use crate::data::Dataset;
use crate::exec::{run_jobs, Parallelism};
use crate::phase::{Phase, StepLoop};
use crate::runtime::{DeviceStore, ModelRt, Scalars};
use crate::store::Store;
use crate::tensor::{accuracy, Tensor};

/// FP32 teacher top-1 on the test set (serial).
pub fn eval_fp32(mrt: &ModelRt, teacher: &Store, dataset: &Dataset) -> Result<f32> {
    eval_fp32_par(mrt, teacher, dataset, Parallelism::SERIAL)
}

/// FP32 teacher top-1 on the test set, sharded across the pool.
pub fn eval_fp32_par(
    mrt: &ModelRt,
    teacher: &Store,
    dataset: &Dataset,
    par: Parallelism,
) -> Result<f32> {
    sharded_eval(mrt, teacher, None, dataset, par, "eval_batch", None)
}

/// [`eval_fp32_par`] that also records the phase's transfer-volume
/// series (`eval/transfer/*`) into `metrics`.
pub fn eval_fp32_metered(
    mrt: &ModelRt,
    teacher: &Store,
    dataset: &Dataset,
    par: Parallelism,
    metrics: &mut crate::coordinator::Metrics,
) -> Result<f32> {
    sharded_eval(mrt, teacher, None, dataset, par, "eval_batch", Some(metrics))
}

/// Hard-quantized student top-1 on the test set (serial).
pub fn eval_quantized(
    mrt: &ModelRt,
    teacher: &Store,
    qstate: &Store,
    dataset: &Dataset,
) -> Result<f32> {
    eval_quantized_par(mrt, teacher, qstate, dataset, Parallelism::SERIAL)
}

/// Hard-quantized student top-1 on the test set, sharded across the pool.
pub fn eval_quantized_par(
    mrt: &ModelRt,
    teacher: &Store,
    qstate: &Store,
    dataset: &Dataset,
    par: Parallelism,
) -> Result<f32> {
    sharded_eval(mrt, teacher, Some(qstate), dataset, par, "eval_quant", None)
}

/// [`eval_quantized_par`] that also records the phase's transfer-volume
/// series (`eval/transfer/*`) into `metrics`.
pub fn eval_quantized_metered(
    mrt: &ModelRt,
    teacher: &Store,
    qstate: &Store,
    dataset: &Dataset,
    par: Parallelism,
    metrics: &mut crate::coordinator::Metrics,
) -> Result<f32> {
    sharded_eval(
        mrt, teacher, Some(qstate), dataset, par, "eval_quant", Some(metrics),
    )
}

/// One chunk's per-batch eval loop as a [`Phase`]: step t uploads batch
/// t-1, the logits come back down in `after_step`, and the weighted
/// (correct, valid) pairs accumulate in batch order. `pub(crate)` so the
/// QAT baseline's eval (`experiments::qat`) drives the same phase with
/// its `eval_qat` entry instead of duplicating the loop.
pub(crate) struct EvalChunk<'a> {
    pub(crate) entry_name: &'a str,
    pub(crate) chunk: &'a [(Tensor, Vec<i32>, usize)],
    pub(crate) out: Vec<(f64, usize)>,
}

impl Phase for EvalChunk<'_> {
    fn name(&self) -> String {
        "eval".into()
    }

    fn entry(&self) -> String {
        self.entry_name.to_string()
    }

    fn init(&mut self, _dev: &mut DeviceStore) -> Result<()> {
        Ok(())
    }

    fn before_step(&mut self, t: usize, dev: &mut DeviceStore) -> Result<()> {
        dev.insert("x", &self.chunk[t - 1].0)
    }

    fn after_step(
        &mut self,
        t: usize,
        _scalars: &Scalars,
        dev: &mut DeviceStore,
    ) -> Result<()> {
        let (_, y, valid) = &self.chunk[t - 1];
        let logits = dev.fetch("logits")?;
        let acc = accuracy(&logits, y, *valid);
        self.out.push((acc as f64 * *valid as f64, *valid));
        Ok(())
    }

    fn carried(&self) -> Vec<String> {
        Vec::new()
    }

    fn finish(&mut self, _dev: &mut DeviceStore) -> Result<Store> {
        Ok(Store::new())
    }
}

/// Shared driver: chunk the eval batches, run chunks as engine-driven
/// pool jobs, reduce per-batch (correct, valid) pairs in batch order.
/// With `metrics`, the base upload plus every chunk's transfer bytes
/// land in the `eval/transfer/*` series.
#[allow(clippy::too_many_arguments)]
fn sharded_eval(
    mrt: &ModelRt,
    teacher: &Store,
    qstate: Option<&Store>,
    dataset: &Dataset,
    par: Parallelism,
    entry_name: &str,
    metrics: Option<&mut crate::coordinator::Metrics>,
) -> Result<f32> {
    let bs = mrt.manifest.batch("eval");
    let batches = dataset.eval_batches(bs);
    let n_batches = batches.len();
    let workers = par.resolve_for(n_batches);
    let chunk_len = n_batches.div_ceil(workers.max(1));

    let mut chunks: Vec<Vec<(Tensor, Vec<i32>, usize)>> = Vec::new();
    let mut it = batches.into_iter().peekable();
    while it.peek().is_some() {
        chunks.push(it.by_ref().take(chunk_len).collect());
    }

    // one upload of params (+ quant state), shared by every chunk
    let mut base = mrt.upload_store(teacher)?;
    if let Some(q) = qstate {
        base.absorb(q)?;
    }
    let base = &base;

    let jobs: Vec<_> = chunks
        .iter()
        .map(|chunk| {
            move || -> Result<(Vec<(f64, usize)>, (u64, u64))> {
                let mut dev = base.clone();
                let mut phase = EvalChunk {
                    entry_name,
                    chunk,
                    out: Vec::with_capacity(chunk.len()),
                };
                StepLoop::new(chunk.len(), 0)
                    .run(mrt, &mut phase, &mut dev)?;
                Ok((phase.out, dev.transfer_bytes()))
            }
        })
        .collect();
    let (parts, _pool) = run_jobs(par, jobs)?;

    let (mut h2d, mut d2h) = base.transfer_bytes();
    let mut correct = 0.0f64;
    let mut total = 0usize;
    for (chunk, xfer) in parts {
        h2d += xfer.0;
        d2h += xfer.1;
        for (c, v) in chunk {
            correct += c;
            total += v;
        }
    }
    if let Some(metrics) = metrics {
        metrics.record_transfers("eval", n_batches, h2d, d2h);
    }
    anyhow::ensure!(total > 0, "eval: empty test set");
    Ok((correct / total as f64) as f32)
}

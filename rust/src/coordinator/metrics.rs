//! Metrics: named scalar series + phase wall-clock timers, flushed as CSV
//! under a run directory, plus per-worker pool accounting and throughput
//! summaries for the parallel phases. EXPERIMENTS.md tables are generated
//! from these.

use std::collections::HashMap;
use std::io::Write;
use std::path::{Path, PathBuf};
use std::time::Instant;

use anyhow::Result;

use crate::artifacts::CacheStats;
use crate::exec::{DagReport, PoolReport};

#[derive(Debug, Default)]
pub struct Metrics {
    run_dir: Option<PathBuf>,
    series: Vec<(String, Vec<(usize, f32)>)>,
    index: HashMap<String, usize>,
    timers: Vec<(String, f64)>,
    open: HashMap<String, Instant>,
}

impl Metrics {
    pub fn new() -> Self {
        Metrics::default()
    }

    pub fn with_dir(dir: impl AsRef<Path>) -> Result<Self> {
        std::fs::create_dir_all(dir.as_ref())?;
        Ok(Metrics { run_dir: Some(dir.as_ref().to_path_buf()), ..Default::default() })
    }

    pub fn log(&mut self, name: &str, step: usize, value: f32) {
        let idx = *self.index.entry(name.to_string()).or_insert_with(|| {
            self.series.push((name.to_string(), Vec::new()));
            self.series.len() - 1
        });
        self.series[idx].1.push((step, value));
    }

    pub fn series(&self, name: &str) -> Option<&[(usize, f32)]> {
        self.index.get(name).map(|&i| self.series[i].1.as_slice())
    }

    pub fn last(&self, name: &str) -> Option<f32> {
        self.series(name).and_then(|s| s.last()).map(|&(_, v)| v)
    }

    pub fn start(&mut self, phase: &str) {
        self.open.insert(phase.to_string(), Instant::now());
    }

    pub fn stop(&mut self, phase: &str) -> f64 {
        let secs = self
            .open
            .remove(phase)
            .map(|t| t.elapsed().as_secs_f64())
            .unwrap_or(0.0);
        self.timers.push((phase.to_string(), secs));
        secs
    }

    pub fn timer_total(&self, phase: &str) -> f64 {
        self.timers
            .iter()
            .filter(|(n, _)| n == phase)
            .map(|(_, s)| s)
            .sum()
    }

    /// Record a pool run: per-worker busy time lands in the timers as
    /// `<phase>/worker<i>`, and jobs/steals/utilization are logged as
    /// series with the worker count as the step (the x-axis of a scaling
    /// curve).
    pub fn record_pool(&mut self, phase: &str, r: &PoolReport) {
        for (w, secs) in r.worker_busy_secs.iter().enumerate() {
            self.timers.push((format!("{phase}/worker{w}"), *secs));
        }
        self.log(&format!("{phase}/pool/jobs"), r.workers, r.jobs as f32);
        self.log(&format!("{phase}/pool/steals"), r.workers, r.steals as f32);
        self.log(
            &format!("{phase}/pool/utilization"),
            r.workers,
            r.utilization() as f32,
        );
        if r.panics > 0 {
            self.log(
                &format!("{phase}/pool/panics"),
                r.workers,
                r.panics as f32,
            );
        }
    }

    /// Record a dataflow-scheduler run (DESIGN.md §15) on top of its
    /// [`record_pool`](Self::record_pool) accounting:
    /// `<phase>/sched/utilization` and `<phase>/sched/ready_depth`
    /// (step = worker count, the x-axis of a scaling curve), plus a
    /// per-node `<phase>/sched/queue_wait_secs` series (step = node id,
    /// seconds between a node becoming ready and a worker picking it
    /// up — skipped nodes read 0).
    pub fn record_sched(&mut self, phase: &str, r: &DagReport) {
        self.log(
            &format!("{phase}/sched/utilization"),
            r.pool.workers,
            r.pool.utilization() as f32,
        );
        self.log(
            &format!("{phase}/sched/ready_depth"),
            r.pool.workers,
            r.max_ready_depth as f32,
        );
        for (i, secs) in r.queue_wait_secs.iter().enumerate() {
            self.log(
                &format!("{phase}/sched/queue_wait_secs"),
                i,
                *secs as f32,
            );
        }
    }

    /// Record one fault-tolerance event for a stage (DESIGN.md §13):
    /// bumps the `faults/<stage>/<event>` series (step = running count,
    /// like [`record_cache`](Self::record_cache)). Events in use:
    /// `retry` (a supervised attempt re-ran), `panic` (a caught job
    /// panic), `quarantine` (a corrupt artifact moved aside),
    /// `stage_failed` (retry budget exhausted), `skipped` (a node
    /// quarantined because an upstream failed).
    pub fn record_fault(&mut self, stage: &str, event: &str) {
        let name = format!("faults/{stage}/{event}");
        let n = self.series(&name).map_or(0, |s| s.len());
        self.log(&name, n + 1, 1.0);
    }

    /// Log a host↔device transfer-volume sample for a phase
    /// (`<phase>/transfer/{h2d,d2h}_bytes`, step = the step/sample count
    /// the bytes were accumulated over). Fed by the `DeviceStore`
    /// counters (DESIGN.md §8); note the series values are f32 like every
    /// metric, so totals above 2^24 bytes round — the exact u64 counters
    /// live on `DeviceStore`/`DispatchStats`, not here.
    pub fn record_transfers(
        &mut self,
        phase: &str,
        step: usize,
        h2d: u64,
        d2h: u64,
    ) {
        self.log(&format!("{phase}/transfer/h2d_bytes"), step, h2d as f32);
        self.log(&format!("{phase}/transfer/d2h_bytes"), step, d2h as f32);
    }

    /// Log a phase's dispatch accounting as two separate series:
    /// `<phase>/dispatches` (device programs launched) and
    /// `<phase>/steps` (optimization steps executed). Under fused
    /// dispatch (DESIGN.md §14) one dispatch covers K steps, so the two
    /// series diverge — throughput and progress always quote steps, and
    /// the dispatch series is the launch-overhead denominator. Step =
    /// the step count, mirroring [`record_transfers`](Self::record_transfers).
    pub fn record_dispatches(
        &mut self,
        phase: &str,
        dispatches: u64,
        steps: u64,
    ) {
        self.log(
            &format!("{phase}/dispatches"),
            steps as usize,
            dispatches as f32,
        );
        self.log(&format!("{phase}/steps"), steps as usize, steps as f32);
    }

    /// Record an artifact-cache lookup for a stage: bumps the
    /// `cache/<stage>/{hit|miss}` series (step = running count of that
    /// outcome) — the DAG-lookup counterpart of the dispatch stats.
    pub fn record_cache(&mut self, stage: &str, hit: bool) {
        let name = format!(
            "cache/{stage}/{}",
            if hit { "hit" } else { "miss" }
        );
        let n = self.series(&name).map_or(0, |s| s.len());
        self.log(&name, n + 1, 1.0);
    }

    /// Record the end-of-run tiered-cache rollup (DESIGN.md §16):
    /// totals plus `cache/<tier>/{hits,misses,evictions,bytes}` from
    /// the folded per-run [`CacheStats`]. Per-tier misses are derived
    /// from the hit waterfall — a load that misses tier 0 either hits a
    /// lower tier or misses outright, so `hot/misses = disk_hits +
    /// shared_hits + misses` and `disk/misses = shared_hits + misses`.
    /// One sample per run at step 0; every value is a deterministic
    /// function of *what* ran, not when, so the scheduler-equivalence
    /// test compares these across wave/dataflow and worker counts.
    pub fn record_cache_tiers(
        &mut self,
        s: &CacheStats,
        tier_bytes: (u64, u64),
    ) {
        let (hot_bytes, disk_bytes) = tier_bytes;
        self.log("cache/hits", 0, s.hits as f32);
        self.log("cache/misses", 0, s.misses as f32);
        self.log("cache/stores", 0, s.stores as f32);
        self.log("cache/quarantined", 0, s.quarantined as f32);
        self.log("cache/hot/hits", 0, s.hot_hits as f32);
        self.log(
            "cache/hot/misses",
            0,
            (s.disk_hits + s.shared_hits + s.misses) as f32,
        );
        self.log("cache/hot/evictions", 0, s.hot_evictions as f32);
        self.log("cache/hot/bytes", 0, hot_bytes as f32);
        self.log("cache/disk/hits", 0, s.disk_hits as f32);
        self.log(
            "cache/disk/misses",
            0,
            (s.shared_hits + s.misses) as f32,
        );
        self.log("cache/disk/evictions", 0, s.gc_evictions as f32);
        self.log("cache/disk/bytes", 0, disk_bytes as f32);
        self.log("cache/shared/hits", 0, s.shared_hits as f32);
        self.log("cache/shared/misses", 0, s.misses as f32);
    }

    /// Record a phase's checkpoint writes: `<phase>/checkpoint/bytes`
    /// with the write count as the step. Like every metric the value is
    /// f32; the byte-exact counters come from the engine's `LoopOutcome`.
    pub fn record_checkpoint(&mut self, phase: &str, writes: usize, bytes: u64) {
        self.log(&format!("{phase}/checkpoint/bytes"), writes, bytes as f32);
    }

    /// Fold another `Metrics` in under a namespace prefix (DESIGN.md
    /// §11): every series and timer of `other` lands here as
    /// `<prefix><name>`. The grid executor gives each stage job its own
    /// `Metrics` (jobs run concurrently and never share a sink) and
    /// absorbs them at the wave barrier — per-cell stages under
    /// `cell<i>/`, deduplicated stages under `shared/...` — so one flush
    /// writes the whole grid without cross-run interleaving. Open
    /// (un-stopped) timers of `other` are dropped.
    pub fn absorb(&mut self, prefix: &str, other: Metrics) {
        for (name, rows) in other.series {
            let full = format!("{prefix}{name}");
            for (step, value) in rows {
                self.log(&full, step, value);
            }
        }
        for (name, secs) in other.timers {
            self.timers.push((format!("{prefix}{name}"), secs));
        }
    }

    /// Log a throughput sample (`<phase>/<unit>_per_sec`, step = count)
    /// and return the rate for printing.
    pub fn throughput(
        &mut self,
        phase: &str,
        unit: &str,
        count: usize,
        secs: f64,
    ) -> f64 {
        let rate = if secs > 0.0 { count as f64 / secs } else { 0.0 };
        self.log(&format!("{phase}/{unit}_per_sec"), count, rate as f32);
        rate
    }

    /// Every series in insertion order — the scheduler-equivalence
    /// property test (`tests/faults.rs`) enumerates these to compare
    /// wave vs dataflow metrics without knowing the names up front.
    pub fn series_iter(
        &self,
    ) -> impl Iterator<Item = (&str, &[(usize, f32)])> {
        self.series
            .iter()
            .map(|(name, rows)| (name.as_str(), rows.as_slice()))
    }

    /// Flush every series to `<run_dir>/<name>.csv` (step,value rows).
    pub fn flush(&self) -> Result<()> {
        let Some(dir) = &self.run_dir else { return Ok(()) };
        for (name, rows) in &self.series {
            let safe = name.replace(['/', ' '], "_");
            let mut f = std::fs::File::create(dir.join(format!("{safe}.csv")))?;
            writeln!(f, "step,value")?;
            for (s, v) in rows {
                writeln!(f, "{s},{v}")?;
            }
        }
        if !self.timers.is_empty() {
            let mut f = std::fs::File::create(dir.join("timers.csv"))?;
            writeln!(f, "phase,seconds")?;
            for (n, s) in &self.timers {
                writeln!(f, "{n},{s:.3}")?;
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn log_and_query() {
        let mut m = Metrics::new();
        m.log("loss", 1, 2.0);
        m.log("loss", 2, 1.0);
        assert_eq!(m.last("loss"), Some(1.0));
        assert_eq!(m.series("loss").unwrap().len(), 2);
        assert_eq!(m.last("nope"), None);
    }

    #[test]
    fn timers_accumulate() {
        let mut m = Metrics::new();
        m.start("p");
        m.stop("p");
        m.start("p");
        m.stop("p");
        assert!(m.timer_total("p") >= 0.0);
        assert_eq!(m.timers.len(), 2);
    }

    #[test]
    fn record_pool_lands_in_timers_and_series() {
        let mut m = Metrics::new();
        let r = PoolReport {
            workers: 2,
            jobs: 8,
            wall_secs: 1.0,
            worker_busy_secs: vec![0.6, 0.8],
            worker_jobs: vec![3, 5],
            steals: 2,
            panics: 0,
        };
        m.record_pool("distill", &r);
        assert!(m.timer_total("distill/worker0") > 0.5);
        assert!(m.timer_total("distill/worker1") > 0.7);
        assert_eq!(m.last("distill/pool/jobs"), Some(8.0));
        assert_eq!(m.last("distill/pool/steals"), Some(2.0));
        let u = m.last("distill/pool/utilization").unwrap();
        assert!((u - 0.7).abs() < 1e-6, "utilization {u}");
    }

    #[test]
    fn record_sched_logs_utilization_depth_and_waits() {
        let mut m = Metrics::new();
        let r = DagReport {
            pool: PoolReport {
                workers: 4,
                jobs: 3,
                wall_secs: 2.0,
                worker_busy_secs: vec![2.0, 2.0, 2.0, 2.0],
                worker_jobs: vec![1, 1, 1, 0],
                steals: 0,
                panics: 0,
            },
            max_ready_depth: 5,
            queue_wait_secs: vec![0.0, 0.25, 0.5],
        };
        m.record_sched("grid", &r);
        assert_eq!(m.last("grid/sched/utilization"), Some(1.0));
        assert_eq!(m.last("grid/sched/ready_depth"), Some(5.0));
        let waits = m.series("grid/sched/queue_wait_secs").unwrap();
        assert_eq!(waits.len(), 3);
        assert_eq!(waits[1], (1, 0.25));
    }

    #[test]
    fn series_iter_enumerates_in_insertion_order() {
        let mut m = Metrics::new();
        m.log("b", 1, 2.0);
        m.log("a", 1, 1.0);
        m.log("b", 2, 3.0);
        let got: Vec<(&str, usize)> =
            m.series_iter().map(|(n, rows)| (n, rows.len())).collect();
        assert_eq!(got, vec![("b", 2), ("a", 1)]);
    }

    #[test]
    fn record_cache_tiers_rolls_up_the_waterfall() {
        let mut m = Metrics::new();
        let s = CacheStats {
            hits: 5,
            misses: 2,
            stores: 3,
            hot_hits: 3,
            disk_hits: 1,
            shared_hits: 1,
            hot_evictions: 4,
            gc_evictions: 6,
            ..Default::default()
        };
        m.record_cache_tiers(&s, (1024, 4096));
        assert_eq!(m.last("cache/hits"), Some(5.0));
        assert_eq!(m.last("cache/hot/hits"), Some(3.0));
        // hot misses = everything that fell past tier 0
        assert_eq!(m.last("cache/hot/misses"), Some(4.0));
        assert_eq!(m.last("cache/disk/misses"), Some(3.0));
        assert_eq!(m.last("cache/shared/misses"), Some(2.0));
        assert_eq!(m.last("cache/hot/evictions"), Some(4.0));
        assert_eq!(m.last("cache/disk/evictions"), Some(6.0));
        assert_eq!(m.last("cache/hot/bytes"), Some(1024.0));
        assert_eq!(m.last("cache/disk/bytes"), Some(4096.0));
    }

    #[test]
    fn record_transfers_logs_both_directions() {
        let mut m = Metrics::new();
        m.record_transfers("distill", 200, 4096, 800);
        assert_eq!(m.last("distill/transfer/h2d_bytes"), Some(4096.0));
        assert_eq!(m.last("distill/transfer/d2h_bytes"), Some(800.0));
        assert_eq!(
            m.series("distill/transfer/h2d_bytes").unwrap()[0].0,
            200
        );
    }

    #[test]
    fn record_dispatches_keeps_steps_and_dispatches_apart() {
        let mut m = Metrics::new();
        // 48 steps fused into 6 dispatches (K=8)
        m.record_dispatches("distill", 6, 48);
        assert_eq!(m.last("distill/dispatches"), Some(6.0));
        assert_eq!(m.last("distill/steps"), Some(48.0));
        assert_eq!(m.series("distill/dispatches").unwrap()[0].0, 48);
    }

    #[test]
    fn record_cache_counts_hits_and_misses() {
        let mut m = Metrics::new();
        m.record_cache("distill", false);
        m.record_cache("distill", false);
        m.record_cache("distill", true);
        assert_eq!(m.series("cache/distill/miss").unwrap().len(), 2);
        assert_eq!(m.series("cache/distill/miss").unwrap()[1].0, 2);
        assert_eq!(m.series("cache/distill/hit").unwrap().len(), 1);
        assert!(m.series("cache/quantize/hit").is_none());
    }

    #[test]
    fn record_fault_counts_per_stage_events() {
        let mut m = Metrics::new();
        m.record_fault("quantize", "retry");
        m.record_fault("quantize", "retry");
        m.record_fault("quantize", "panic");
        m.record_fault("distill", "quarantine");
        assert_eq!(m.series("faults/quantize/retry").unwrap().len(), 2);
        assert_eq!(m.series("faults/quantize/retry").unwrap()[1].0, 2);
        assert_eq!(m.series("faults/quantize/panic").unwrap().len(), 1);
        assert_eq!(m.series("faults/distill/quarantine").unwrap().len(), 1);
        assert!(m.series("faults/distill/retry").is_none());
    }

    #[test]
    fn record_checkpoint_logs_bytes_by_writes() {
        let mut m = Metrics::new();
        m.record_checkpoint("quantize", 3, 4096);
        assert_eq!(m.last("quantize/checkpoint/bytes"), Some(4096.0));
        assert_eq!(m.series("quantize/checkpoint/bytes").unwrap()[0].0, 3);
    }

    #[test]
    fn throughput_logs_rate() {
        let mut m = Metrics::new();
        let rate = m.throughput("distill", "images", 128, 2.0);
        assert!((rate - 64.0).abs() < 1e-9);
        assert_eq!(m.last("distill/images_per_sec"), Some(64.0));
        assert_eq!(m.throughput("x", "y", 5, 0.0), 0.0);
    }

    #[test]
    fn absorb_namespaces_series_and_timers() {
        let mut job = Metrics::new();
        job.log("distill/loss", 1, 0.5);
        job.log("distill/loss", 2, 0.4);
        job.start("quantize");
        job.stop("quantize");
        let mut grid = Metrics::new();
        grid.log("cell0/distill/loss", 1, 0.9);
        grid.absorb("cell1/", job);
        assert_eq!(grid.last("cell1/distill/loss"), Some(0.4));
        assert_eq!(grid.series("cell1/distill/loss").unwrap().len(), 2);
        // existing series under other prefixes are untouched
        assert_eq!(grid.last("cell0/distill/loss"), Some(0.9));
        assert!(grid.series("distill/loss").is_none());
        assert!(grid.timer_total("cell1/quantize") >= 0.0);
        assert_eq!(grid.timers.len(), 1);
    }

    #[test]
    fn flush_writes_csv() {
        let dir = std::env::temp_dir().join("genie_metrics_test");
        let mut m = Metrics::with_dir(&dir).unwrap();
        m.log("a b/c", 0, 1.5);
        m.flush().unwrap();
        let text = std::fs::read_to_string(dir.join("a_b_c.csv")).unwrap();
        assert!(text.contains("0,1.5"));
    }
}

//! FP32 teacher pretraining — the substitute for the paper's downloaded
//! ImageNet checkpoints (DESIGN.md section 3). Drives the AOT `train_step`
//! graph (Adam + BN running-stat updates baked in) with shuffled batches
//! from the procedural dataset; cosine-annealed LR; checkpoints the
//! params+BN store.
//!
//! The step loop runs on the shared phase engine (DESIGN.md §9):
//! [`PretrainPhase`] supplies the per-step batch + schedule scalars and
//! the carried state names (params, BN, Adam moments); [`StepLoop`] owns
//! device residency, the loss/acc trace, and — when a stage checkpoint is
//! attached — periodic GTS1 checkpoints that a `--resume` run continues
//! from bit-identically (the batch RNG is part of the snapshot).

use anyhow::Result;

use crate::artifacts::ArtifactCache;
use crate::data::Dataset;
use crate::phase::{checkpoint, Phase, StageCkpt, StepLoop};
use crate::runtime::{DeviceStore, ModelRt};
use crate::schedule::CosineAnnealing;
use crate::store::Store;
use crate::tensor::{Pcg32, Tensor};

use super::{insert_zeros, teacher_names, Metrics};

#[derive(Debug, Clone)]
pub struct PretrainCfg {
    pub steps: usize,
    pub lr: f32,
    pub log_every: usize,
    pub seed: u64,
    /// fused steps per device dispatch (`steps_per_dispatch=K`; 1 = off).
    /// Execution-shape knob: identity-neutral, never folded into content
    /// keys (DESIGN.md §14).
    pub steps_per_dispatch: usize,
}

impl Default for PretrainCfg {
    fn default() -> Self {
        PretrainCfg {
            steps: 600,
            lr: 4e-3,
            log_every: 50,
            seed: 17,
            steps_per_dispatch: 1,
        }
    }
}

/// The teacher-training step loop as a [`Phase`].
struct PretrainPhase<'a, 'rt> {
    mrt: &'a ModelRt<'rt>,
    dataset: &'a Dataset,
    bs: usize,
    rng: Pcg32,
    sched: CosineAnnealing,
}

impl Phase for PretrainPhase<'_, '_> {
    fn name(&self) -> String {
        "pretrain".into()
    }

    fn entry(&self) -> String {
        "train_step".into()
    }

    fn init(&mut self, dev: &mut DeviceStore) -> Result<()> {
        // one bulk upload; params/BN/moments then live on device
        let mut init = self.mrt.init_store()?;
        insert_zeros(&mut init, &self.mrt.manifest.params, "am.");
        insert_zeros(&mut init, &self.mrt.manifest.params, "av.");
        dev.absorb(&init)
    }

    fn before_step(&mut self, t: usize, dev: &mut DeviceStore) -> Result<()> {
        let (x, y) = self.dataset.train_batch(&mut self.rng, self.bs);
        dev.insert("x", &x)?;
        dev.insert("y", &Tensor::from_i32(&[self.bs], y))?;
        dev.insert("t", &Tensor::scalar_f32(t as f32))?;
        dev.insert("lr", &Tensor::scalar_f32(self.sched.lr(t - 1)))?;
        Ok(())
    }

    /// Eligible for fused dispatch: `before_step` draws batches from the
    /// snapshotted RNG and a deterministic schedule of `t`, feeds are
    /// host uploads only, and there is no `after_step` device work.
    fn fusible(&self) -> bool {
        true
    }

    fn carried(&self) -> Vec<String> {
        let m = &self.mrt.manifest;
        let mut v = teacher_names(m);
        for (n, _) in &m.params {
            v.push(format!("am.{n}"));
            v.push(format!("av.{n}"));
        }
        v
    }

    fn snapshot(&self) -> Store {
        let mut s = Store::new();
        s.insert("rng", checkpoint::rng_tensor(&self.rng));
        s
    }

    fn restore(&mut self, snap: &Store) -> Result<()> {
        self.rng = checkpoint::rng_from_tensor(snap.get("rng")?)?;
        Ok(())
    }

    fn finish(&mut self, dev: &mut DeviceStore) -> Result<Store> {
        // phase boundary: fetch exactly the teacher tensors, once
        let mut teacher = Store::new();
        for n in teacher_names(&self.mrt.manifest) {
            let t = dev.fetch(&n)?;
            teacher.insert(&n, t);
        }
        Ok(teacher)
    }
}

/// Train the FP32 teacher; returns the params+BN store (the "pre-trained
/// model" every later phase consumes).
pub fn pretrain(
    mrt: &ModelRt,
    dataset: &Dataset,
    cfg: &PretrainCfg,
    metrics: &mut Metrics,
) -> Result<Store> {
    pretrain_ck(mrt, dataset, cfg, None, metrics)
}

/// [`pretrain`] with an optional stage checkpoint: periodic engine
/// checkpoints to `ck`'s work dir, resumed (bit-identically) when `ck`
/// says so.
pub fn pretrain_ck(
    mrt: &ModelRt,
    dataset: &Dataset,
    cfg: &PretrainCfg,
    ck: Option<&StageCkpt>,
    metrics: &mut Metrics,
) -> Result<Store> {
    let m = &mrt.manifest;
    metrics.start("pretrain");
    let mut phase = PretrainPhase {
        mrt,
        dataset,
        bs: m.batch("train"),
        rng: Pcg32::new(cfg.seed),
        sched: CosineAnnealing::new(cfg.lr, cfg.steps),
    };
    let mut dev = mrt.rt.device_store();
    let out = StepLoop::new(cfg.steps, cfg.log_every.max(1))
        .with_checkpoint(ck.map(|c| c.shard("pretrain")))
        .with_steps_per_dispatch(cfg.steps_per_dispatch)
        .run(mrt, &mut phase, &mut dev)?;
    anyhow::ensure!(
        out.completed,
        "pretrain: interrupted by step budget at step {} (checkpoint \
         written; re-run with resume to continue)",
        out.resumed_from + out.ran_steps
    );
    for (t, sc) in &out.trace {
        metrics.log("pretrain/loss", *t, sc["loss"]);
        metrics.log("pretrain/acc", *t, sc["acc"]);
    }
    if out.checkpoints_written > 0 {
        metrics.record_checkpoint(
            "pretrain",
            out.checkpoints_written,
            out.checkpoint_bytes,
        );
    }
    let teacher = out.result;
    let (h2d, d2h) = dev.transfer_bytes();
    metrics.record_transfers("pretrain", cfg.steps, h2d, d2h);
    metrics.record_dispatches(
        "pretrain",
        out.dispatches as u64,
        out.ran_steps as u64,
    );
    let secs = metrics.stop("pretrain");
    crate::progress!(
        "pretrain[{}]: {} steps in {:.1}s  loss={:.3} acc={:.3}",
        m.model,
        cfg.steps,
        secs,
        metrics.last("pretrain/loss").unwrap_or(f32::NAN),
        metrics.last("pretrain/acc").unwrap_or(f32::NAN)
    );
    Ok(teacher)
}

/// Content-addressed teacher (DESIGN.md §9): load the `teacher` artifact
/// keyed by (manifest, pretrain config), or pretrain — resumably, when
/// the cache allows — and store it.
pub fn teacher_cached(
    mrt: &ModelRt,
    dataset: &Dataset,
    cfg: &PretrainCfg,
    cache: &mut ArtifactCache,
    metrics: &mut Metrics,
) -> Result<Store> {
    let key = crate::artifacts::pretrain_key(&mrt.manifest, cfg);
    // claim first (DESIGN.md §11): a concurrent run computing the same
    // teacher holds the lock; once it releases, the lookup below turns
    // this run's compute into a cache hit — and every stage performs
    // exactly one counted lookup
    let _claim = cache.claim("teacher", key)?;
    if let Some(s) = cache.load("teacher", key) {
        metrics.record_cache("teacher", true);
        crate::progress!(
            "teacher[{}]: cache hit ({})",
            mrt.manifest.model,
            key.hex()
        );
        // tier 0 hands out a shared handle; this API returns an owned
        // Store, which is a cheap COW clone (Arc-backed tensor maps)
        return Ok((*s).clone());
    }
    metrics.record_cache("teacher", false);
    let ck = cache.stage_ckpt("teacher", key);
    let teacher = pretrain_ck(mrt, dataset, cfg, ck.as_ref(), metrics)?;
    cache.store("teacher", key, &teacher)?;
    Ok(teacher)
}

/// Load a cached checkpoint if present, otherwise pretrain and cache it.
/// (Path-keyed legacy cache; prefer [`teacher_cached`], which keys by
/// config content and survives config changes.)
pub fn teacher_or_pretrain(
    mrt: &ModelRt,
    dataset: &Dataset,
    cfg: &PretrainCfg,
    runs_dir: &std::path::Path,
    metrics: &mut Metrics,
) -> Result<Store> {
    let ckpt = runs_dir.join(format!("teacher_{}.bin", mrt.manifest.model));
    if ckpt.exists() {
        let s = Store::load(&ckpt)?;
        crate::progress!("teacher[{}]: loaded {:?}", mrt.manifest.model, ckpt);
        return Ok(s);
    }
    let teacher = pretrain(mrt, dataset, cfg, metrics)?;
    std::fs::create_dir_all(runs_dir)?;
    teacher.save(&ckpt)?;
    crate::progress!("teacher[{}]: saved {:?}", mrt.manifest.model, ckpt);
    Ok(teacher)
}

//! FP32 teacher pretraining — the substitute for the paper's downloaded
//! ImageNet checkpoints (DESIGN.md section 3). Drives the AOT `train_step`
//! graph (Adam + BN running-stat updates baked in) with shuffled batches
//! from the procedural dataset; cosine-annealed LR; checkpoints the
//! params+BN store.
//!
//! The step loop is device-resident (DESIGN.md §8): params, BN state and
//! Adam moments are uploaded once and carried as live buffers across
//! `train_step` calls; per step only the fresh data batch and schedule
//! scalars go up and the loss/accuracy scalars come down. The trained
//! teacher is materialized on the host once, at the end of the phase.

use anyhow::Result;

use crate::data::Dataset;
use crate::runtime::ModelRt;
use crate::schedule::CosineAnnealing;
use crate::store::Store;
use crate::tensor::{Pcg32, Tensor};

use super::{insert_zeros, teacher_names, Metrics};

#[derive(Debug, Clone)]
pub struct PretrainCfg {
    pub steps: usize,
    pub lr: f32,
    pub log_every: usize,
    pub seed: u64,
}

impl Default for PretrainCfg {
    fn default() -> Self {
        PretrainCfg { steps: 600, lr: 4e-3, log_every: 50, seed: 17 }
    }
}

/// Train the FP32 teacher; returns the params+BN store (the "pre-trained
/// model" every later phase consumes).
pub fn pretrain(
    mrt: &ModelRt,
    dataset: &Dataset,
    cfg: &PretrainCfg,
    metrics: &mut Metrics,
) -> Result<Store> {
    let m = &mrt.manifest;
    let bs = m.batch("train");
    let mut rng = Pcg32::new(cfg.seed);
    let sched = CosineAnnealing::new(cfg.lr, cfg.steps);

    let mut init = mrt.init_store()?;
    insert_zeros(&mut init, &m.params, "am.");
    insert_zeros(&mut init, &m.params, "av.");

    metrics.start("pretrain");
    let entry = mrt.entry("train_step")?;
    // one bulk upload; params/BN/moments then live on device
    let mut dev = mrt.upload_store(&init)?;
    for t in 1..=cfg.steps {
        let (x, y) = dataset.train_batch(&mut rng, bs);
        dev.insert("x", &x)?;
        dev.insert("y", &Tensor::from_i32(&[bs], y))?;
        dev.insert("t", &Tensor::scalar_f32(t as f32))?;
        dev.insert("lr", &Tensor::scalar_f32(sched.lr(t - 1)))?;
        let scalars = mrt.rt.call_device(&entry, &mut dev)?;
        if t % cfg.log_every == 0 || t == cfg.steps {
            metrics.log("pretrain/loss", t, scalars["loss"]);
            metrics.log("pretrain/acc", t, scalars["acc"]);
        }
    }
    // phase boundary: fetch exactly the teacher tensors, once
    let mut teacher = Store::new();
    for n in teacher_names(m) {
        let t = dev.fetch(&n)?;
        teacher.insert(&n, t);
    }
    let (h2d, d2h) = dev.transfer_bytes();
    metrics.record_transfers("pretrain", cfg.steps, h2d, d2h);
    let secs = metrics.stop("pretrain");
    println!(
        "pretrain[{}]: {} steps in {:.1}s  loss={:.3} acc={:.3}",
        m.model,
        cfg.steps,
        secs,
        metrics.last("pretrain/loss").unwrap_or(f32::NAN),
        metrics.last("pretrain/acc").unwrap_or(f32::NAN)
    );
    Ok(teacher)
}

/// Load a cached checkpoint if present, otherwise pretrain and cache it.
pub fn teacher_or_pretrain(
    mrt: &ModelRt,
    dataset: &Dataset,
    cfg: &PretrainCfg,
    runs_dir: &std::path::Path,
    metrics: &mut Metrics,
) -> Result<Store> {
    let ckpt = runs_dir.join(format!("teacher_{}.bin", mrt.manifest.model));
    if ckpt.exists() {
        let s = Store::load(&ckpt)?;
        println!("teacher[{}]: loaded {:?}", mrt.manifest.model, ckpt);
        return Ok(s);
    }
    let teacher = pretrain(mrt, dataset, cfg, metrics)?;
    std::fs::create_dir_all(runs_dir)?;
    teacher.save(&ckpt)?;
    println!("teacher[{}]: saved {:?}", mrt.manifest.model, ckpt);
    Ok(teacher)
}

//! GENIE-M block-sequential post-training quantization (Algorithm 2 /
//! Algorithm A1):
//!
//!   1. LSQ activation-step init from teacher `act_stats`.
//!   2. Host-side quant-state init: Eq. 6 p-norm grid search for s_w,
//!      AdaRound base grid B + softbit V (crate::quant).
//!   3. Teacher block-boundary collection over the calibration set.
//!   4. Per block, Adam on (s_w, V, s_a) against the block reconstruction
//!      error + annealed rounding regularizer (Eq. A2), with QDrop.
//!      Block inputs come from the *quantized prefix* (refreshed via
//!      `collect_student` before each block, BRECQ-style).
//!
//! Ablation arms are pure config: `lr_sw = 0` -> AdaRound (no joint step
//! size, M1 vs M2 / Table 5), `drop_p = 0` -> NoDrop.
//!
//! Parallel structure (DESIGN.md §5): teacher boundary collection fans out
//! one job per calibration chunk, and block reconstruction runs on the
//! exec pool gated by a topological wave schedule — a chain when
//! `refresh_student` (block b reads the quantized prefix, BRECQ-style), a
//! single all-blocks wave otherwise. Block b draws all randomness from
//! `Pcg32::new_stream(seed, b)`, so the optimized quant state is
//! bit-identical for any worker count.
//!
//! Both per-batch collection and the per-block Adam loop run on the
//! shared phase engine (DESIGN.md §9): [`CollectChunk`] and
//! [`BlockPhase`] supply the per-step staging/scalars and carried names;
//! [`StepLoop`] owns residency and — with a stage checkpoint attached —
//! periodic mid-block GTS1 checkpoints plus `block{b}.done` results, so
//! a run killed mid-quantize resumes bit-identically: completed blocks
//! load, the interrupted block continues from its checkpointed step (RNG
//! stream included), and untouched blocks run fresh.

use anyhow::Result;

use crate::data::image_batches;
use crate::exec::{chain_deps, independent_deps, run_jobs, waves, Parallelism};
use crate::phase::{checkpoint, Phase, StageCkpt, StepLoop};
use crate::precision::sensitivity::{
    first_last_pins, measure_sensitivity, pareto_plan,
};
use crate::precision::{Policy, PrecisionCfg, PrecisionPlan};
use crate::quant::{init_qstate, set_act_steps};
use crate::runtime::{DeviceStore, ModelRt, Scalars};
use crate::schedule::{BetaAnneal, CosineAnnealing};
use crate::store::Store;
use crate::tensor::{Pcg32, Tensor};

use super::{subset, Metrics};

#[derive(Debug, Clone)]
pub struct QuantCfg {
    pub wbits: u32,
    pub abits: u32,
    pub steps_per_block: usize,
    /// weight step-size LR (0 => AdaRound baseline: s_w frozen)
    pub lr_sw: f32,
    /// softbit LR
    pub lr_v: f32,
    /// activation step LR (LSQ)
    pub lr_sa: f32,
    /// rounding-regularizer weight (paper: 1.0 for GENIE-M)
    pub lam: f32,
    pub beta_start: f32,
    pub beta_end: f32,
    /// QDrop keep-FP probability (0 => NoDrop)
    pub drop_p: f32,
    /// Eq. A3 p-norm for the step-size search (Fig. A2; default 2.4)
    pub pnorm: f32,
    /// refresh block inputs through the quantized prefix (BRECQ-style)
    pub refresh_student: bool,
    pub log_every: usize,
    pub seed: u64,
    /// worker pool for bounds collection + block waves (`workers=K`)
    pub par: Parallelism,
    /// fused steps per device dispatch in the block reconstruction loop
    /// (`steps_per_dispatch=K`; 1 = off). Execution-shape knob like
    /// `par`: identity-neutral, never folded into content keys
    /// (DESIGN.md §14).
    pub steps_per_dispatch: usize,
    /// precision-plan policy (DESIGN.md §10): uniform / FirstLast8 pin /
    /// Pareto mixed precision under `target_size`
    pub precision: PrecisionCfg,
}

impl Default for QuantCfg {
    fn default() -> Self {
        QuantCfg {
            wbits: 4,
            abits: 4,
            steps_per_block: 250,
            lr_sw: 1e-4,
            lr_v: 1e-2,
            lr_sa: 4e-5,
            lam: 1.0,
            beta_start: 20.0,
            beta_end: 2.0,
            drop_p: 0.5,
            pnorm: 2.4,
            refresh_student: true,
            log_every: 50,
            seed: 31,
            par: Parallelism::default(),
            steps_per_dispatch: 1,
            precision: PrecisionCfg::default(),
        }
    }
}

impl QuantCfg {
    /// AdaRound baseline arm: frozen step sizes.
    pub fn adaround(mut self) -> Self {
        self.lr_sw = 0.0;
        self.lr_sa = 0.0;
        self
    }

    /// NoDrop arm.
    pub fn no_drop(mut self) -> Self {
        self.drop_p = 0.0;
        self
    }
}

/// Teacher block-boundary collection over one chunk of calibration
/// batches, as a [`Phase`]: per "step" one batch goes up and the
/// `bound.{i}` tensors come back down.
struct CollectChunk<'a> {
    chunk: &'a [(Tensor, usize)],
    nb: usize,
    out: Vec<Vec<Tensor>>,
}

impl Phase for CollectChunk<'_> {
    fn name(&self) -> String {
        "quantize/bounds".into()
    }

    fn entry(&self) -> String {
        "collect_teacher".into()
    }

    fn init(&mut self, _dev: &mut DeviceStore) -> Result<()> {
        Ok(())
    }

    fn before_step(&mut self, t: usize, dev: &mut DeviceStore) -> Result<()> {
        dev.insert("x", &self.chunk[t - 1].0)
    }

    fn after_step(
        &mut self,
        _t: usize,
        _scalars: &Scalars,
        dev: &mut DeviceStore,
    ) -> Result<()> {
        self.out.push(
            (0..=self.nb)
                .map(|i| dev.fetch(&format!("bound.{i}")))
                .collect::<Result<Vec<_>>>()?,
        );
        Ok(())
    }

    fn carried(&self) -> Vec<String> {
        Vec::new()
    }

    fn finish(&mut self, _dev: &mut DeviceStore) -> Result<Store> {
        Ok(Store::new())
    }
}

/// Student-prefix staging for one block, as a nested [`Phase`] run from
/// [`BlockPhase::init`]: per step one calibration batch goes through the
/// quantized prefix (`collect_student`) and the produced boundary buffer
/// is pinned as `x_in.{i}` by zero-byte alias. Draws its keys from the
/// block's own stream, so the staging is part of the block's replayable
/// schedule.
struct StageInputs<'a> {
    batches: &'a [(Tensor, usize)],
    b: usize,
    rng: &'a mut Pcg32,
}

impl Phase for StageInputs<'_> {
    fn name(&self) -> String {
        format!("quantize/block{}/stage", self.b)
    }

    fn entry(&self) -> String {
        "collect_student".into()
    }

    fn init(&mut self, _dev: &mut DeviceStore) -> Result<()> {
        Ok(())
    }

    fn before_step(&mut self, t: usize, dev: &mut DeviceStore) -> Result<()> {
        dev.insert("x", &self.batches[t - 1].0)?;
        let (kh, kl) = self.rng.key_pair();
        dev.insert("key", &Tensor::key(kh, kl))?;
        Ok(())
    }

    fn after_step(
        &mut self,
        t: usize,
        _scalars: &Scalars,
        dev: &mut DeviceStore,
    ) -> Result<()> {
        // pin the freshly produced boundary buffer (device-side copy of
        // nothing: the alias shares the Arc handle)
        dev.alias(&format!("x_in.{}", t - 1), &format!("bound.{}", self.b))
    }

    fn carried(&self) -> Vec<String> {
        Vec::new()
    }

    fn finish(&mut self, _dev: &mut DeviceStore) -> Result<Store> {
        Ok(Store::new())
    }
}

/// One block's reconstruction loop as a [`Phase`]. Self-contained:
/// aliases the resident teacher, uploads the current quant state, stages
/// its inputs on device, and draws every random choice (batch picks,
/// QDrop/collect keys) from the block-keyed stream — never from worker
/// identity or schedule.
struct BlockPhase<'a, 'rt> {
    mrt: &'a ModelRt<'rt>,
    cfg: &'a QuantCfg,
    b: usize,
    batches: &'a [(Tensor, usize)],
    teacher_bounds: &'a [Vec<Tensor>],
    qstate: &'a Store,
    learn: Vec<String>,
    rng: Pcg32,
    sw_sched: CosineAnnealing,
    sa_sched: CosineAnnealing,
    beta: BetaAnneal,
}

impl Phase for BlockPhase<'_, '_> {
    fn name(&self) -> String {
        format!("quantize/block{}", self.b)
    }

    fn entry(&self) -> String {
        format!("quant_step_{}", self.b)
    }

    fn init(&mut self, dev: &mut DeviceStore) -> Result<()> {
        let b = self.b;
        dev.absorb(self.qstate)?;

        // Block inputs through the quantized prefix, staged on device as
        // x_in.{i}: the step loop's batch pick is then a zero-byte alias
        // instead of a per-step host upload.
        if b == 0 || !self.cfg.refresh_student {
            for (i, bounds) in self.teacher_bounds.iter().enumerate() {
                dev.insert(&format!("x_in.{i}"), &bounds[b])?;
            }
        } else {
            // nested engine run: the staging loop is a phase of its own
            let mut staging = StageInputs {
                batches: self.batches,
                b,
                rng: &mut self.rng,
            };
            StepLoop::new(self.batches.len(), 0)
                .run(self.mrt, &mut staging, dev)?;
        }
        for (i, bounds) in self.teacher_bounds.iter().enumerate() {
            dev.insert(&format!("y_ref.{i}"), &bounds[b + 1])?;
        }

        // fresh Adam state for this block's learnables
        for name in &self.learn {
            let shape = dev.get(name)?.shape().to_vec();
            dev.insert(&format!("am.{name}"), &Tensor::zeros(&shape))?;
            dev.insert(&format!("av.{name}"), &Tensor::zeros(&shape))?;
        }
        Ok(())
    }

    fn before_step(&mut self, t: usize, dev: &mut DeviceStore) -> Result<()> {
        let cfg = self.cfg;
        let bi = self.rng.below(self.batches.len());
        dev.alias("x_in", &format!("x_in.{bi}"))?;
        dev.alias("y_ref", &format!("y_ref.{bi}"))?;
        let (kh, kl) = self.rng.key_pair();
        dev.insert("key", &Tensor::key(kh, kl))?;
        dev.insert("t", &Tensor::scalar_f32(t as f32))?;
        dev.insert("lr_sw", &Tensor::scalar_f32(self.sw_sched.lr(t - 1)))?;
        dev.insert("lr_v", &Tensor::scalar_f32(cfg.lr_v))?;
        dev.insert("lr_sa", &Tensor::scalar_f32(self.sa_sched.lr(t - 1)))?;
        dev.insert("lam", &Tensor::scalar_f32(cfg.lam))?;
        dev.insert("beta", &Tensor::scalar_f32(self.beta.beta(t)))?;
        dev.insert("drop_p", &Tensor::scalar_f32(cfg.drop_p))?;
        Ok(())
    }

    /// Eligible for fused dispatch: `before_step` draws only from the
    /// snapshotted block RNG, its aliases pin resident `x_in.{i}` /
    /// `y_ref.{i}` buffers staged in `init`, and there is no
    /// `after_step` device work.
    fn fusible(&self) -> bool {
        true
    }

    fn carried(&self) -> Vec<String> {
        // the full quant state (this block's learnables evolve on device,
        // the rest sits as absorbed), the Adam moments, and the staged
        // block inputs — everything a resumed loop needs resident again
        let m = &self.mrt.manifest;
        let mut v: Vec<String> =
            m.qstate.iter().map(|(n, _)| n.clone()).collect();
        for n in &self.learn {
            v.push(format!("am.{n}"));
            v.push(format!("av.{n}"));
        }
        for i in 0..self.teacher_bounds.len() {
            v.push(format!("x_in.{i}"));
            v.push(format!("y_ref.{i}"));
        }
        v
    }

    fn snapshot(&self) -> Store {
        let mut s = Store::new();
        s.insert("rng", checkpoint::rng_tensor(&self.rng));
        s
    }

    fn restore(&mut self, snap: &Store) -> Result<()> {
        self.rng = checkpoint::rng_from_tensor(snap.get("rng")?)?;
        Ok(())
    }

    fn finish(&mut self, dev: &mut DeviceStore) -> Result<Store> {
        // phase boundary: only the block's optimized learnables come home
        let mut out = Store::new();
        for n in &self.learn {
            out.insert(n, dev.fetch(n)?);
        }
        Ok(out)
    }
}

/// Result of one block's reconstruction job.
struct BlockResult {
    block: usize,
    /// optimized learnables (sw / v / sa of this block), to merge back
    learned: Vec<(String, Tensor)>,
    /// (step, rec loss) at each logged step
    rec_trace: Vec<(usize, f32)>,
    last_rec: f32,
    /// (h2d, d2h) bytes this block's job moved
    transfer: (u64, u64),
    ckpt_writes: usize,
    ckpt_bytes: u64,
    /// (device dispatches, steps executed) — diverge under fused dispatch
    dispatch: (u64, u64),
}

/// Optimize one block's quant state against the teacher boundaries,
/// through the engine: a `block{b}.done` result from an interrupted run
/// is loaded outright, a mid-block checkpoint resumes the loop, and a
/// fresh block runs end to end (persisting its `done` for future
/// resumes).
#[allow(clippy::too_many_arguments)]
fn reconstruct_block(
    mrt: &ModelRt,
    teacher_dev: &DeviceStore<'_>,
    qstate: &Store,
    batches: &[(Tensor, usize)],
    teacher_bounds: &[Vec<Tensor>],
    cfg: &QuantCfg,
    b: usize,
    ck: Option<&StageCkpt>,
) -> Result<BlockResult> {
    let block_name = format!("block{b}");
    if let Some(ck) = ck {
        if let Some(done) = ck.load_done(&block_name) {
            let rec_trace = checkpoint::trace_from_store(&done, "rec")?;
            let learned = done
                .names()
                .iter()
                .filter(|n| !n.starts_with("rec."))
                .map(|n| Ok((n.clone(), done.get(n)?.clone())))
                .collect::<Result<Vec<_>>>()?;
            return Ok(BlockResult {
                block: b,
                learned,
                last_rec: rec_trace
                    .last()
                    .map(|&(_, v)| v)
                    .unwrap_or(f32::NAN),
                rec_trace,
                transfer: (0, 0),
                ckpt_writes: 0,
                ckpt_bytes: 0,
                dispatch: (0, 0),
            });
        }
    }
    let m = &mrt.manifest;
    let mut dev = teacher_dev.clone();
    let mut phase = BlockPhase {
        mrt,
        cfg,
        b,
        batches,
        teacher_bounds,
        qstate,
        learn: m.learnable_block(b).to_vec(),
        rng: Pcg32::new_stream(cfg.seed, b as u64),
        sw_sched: CosineAnnealing::new(cfg.lr_sw, cfg.steps_per_block),
        sa_sched: CosineAnnealing::new(cfg.lr_sa, cfg.steps_per_block),
        beta: BetaAnneal::new(
            cfg.beta_start,
            cfg.beta_end,
            0.2,
            cfg.steps_per_block,
        ),
    };
    let out = StepLoop::new(cfg.steps_per_block, cfg.log_every.max(1))
        .with_checkpoint(ck.map(|c| c.shard(&block_name)))
        .with_steps_per_dispatch(cfg.steps_per_dispatch)
        .run(mrt, &mut phase, &mut dev)?;
    anyhow::ensure!(
        out.completed,
        "quantize block {b}: interrupted by step budget (checkpoint \
         written; re-run with resume to continue)"
    );
    let rec_trace: Vec<(usize, f32)> =
        out.trace.iter().map(|(t, s)| (*t, s["rec"])).collect();
    let last_rec = rec_trace.last().map(|&(_, v)| v).unwrap_or(f32::NAN);
    let learned = phase
        .learn
        .iter()
        .map(|n| Ok((n.clone(), out.result.get(n)?.clone())))
        .collect::<Result<Vec<_>>>()?;
    if let Some(ck) = ck {
        let mut done = Store::new();
        for (n, t) in &learned {
            done.insert(n, t.clone());
        }
        checkpoint::trace_to_store(&mut done, "rec", &rec_trace);
        ck.write_done(&block_name, &done)?;
    }
    Ok(BlockResult {
        block: b,
        learned,
        rec_trace,
        last_rec,
        transfer: dev.transfer_bytes(),
        ckpt_writes: out.checkpoints_written,
        ckpt_bytes: out.checkpoint_bytes,
        dispatch: (out.dispatches as u64, out.ran_steps as u64),
    })
}

/// Resolve the precision plan for one quantize run (DESIGN.md §10):
/// Uniform composes the base bits with the FirstLast8 pin; Pareto
/// measures per-layer sensitivity on the calibration set (sharded on
/// the exec pool), greedily allocates bits under the `target_size`
/// budget, and prints the per-layer table.
pub fn resolve_plan(
    mrt: &ModelRt,
    teacher: &Store,
    calib: &Tensor,
    cfg: &QuantCfg,
    metrics: &mut Metrics,
) -> Result<PrecisionPlan> {
    let m = &mrt.manifest;
    let p = &cfg.precision;
    match p.policy {
        Policy::Uniform => {
            PrecisionPlan::uniform(m, cfg.wbits, cfg.abits, p.granularity)?
                .with_first_last(p.first_last_bits)
        }
        Policy::Pareto => {
            metrics.start("plan");
            let (sens, pool) =
                measure_sensitivity(mrt, teacher, calib, p, cfg.pnorm, cfg.par)?;
            metrics.record_pool("plan/sensitivity", &pool);
            // pinned layers were never probed — their zero rows are
            // placeholders, not measurements, so don't log them
            let pins = first_last_pins(m, p.first_last_bits);
            let mut probed = 0usize;
            for (li, name) in sens.layers.iter().enumerate() {
                if pins[li].is_some() {
                    continue;
                }
                probed += sens.candidates.len();
                for (ci, &b) in sens.candidates.iter().enumerate() {
                    metrics.log(
                        &format!("plan/sens/{name}"),
                        b as usize,
                        sens.kl[li][ci],
                    );
                }
            }
            let plan = pareto_plan(m, &sens, cfg.abits, p)?;
            let secs = metrics.stop("plan");
            crate::progress!(
                "plan[{}]: pareto target {:.2} -> {:.1}% of FP32 \
                 ({probed} probes in {secs:.1}s)",
                m.model,
                p.target_size,
                100.0 * plan.payload_bits(m) as f64
                    / PrecisionPlan::fp32_bits(m).max(1) as f64,
            );
            // one multi-line progress write: the rendered table cannot
            // shear across concurrent runs
            crate::progress!("{}", plan.render(m).trim_end());
            Ok(plan)
        }
    }
}

/// Run GENIE-M over a calibration set; returns the optimized quant state.
pub fn quantize(
    mrt: &ModelRt,
    teacher: &Store,
    calib: &Tensor,
    cfg: &QuantCfg,
    metrics: &mut Metrics,
) -> Result<Store> {
    quantize_ck(mrt, teacher, calib, cfg, None, metrics)
}

/// [`quantize`] with an optional stage checkpoint (mid-block engine
/// checkpoints + completed-block results in the stage's work dir).
/// Resolves the precision plan itself; the cached pipeline resolves the
/// plan first (through the plan artifact) and calls
/// [`quantize_planned`] directly.
pub fn quantize_ck(
    mrt: &ModelRt,
    teacher: &Store,
    calib: &Tensor,
    cfg: &QuantCfg,
    ck: Option<&StageCkpt>,
    metrics: &mut Metrics,
) -> Result<Store> {
    let plan = resolve_plan(mrt, teacher, calib, cfg, metrics)?;
    quantize_planned(mrt, teacher, calib, cfg, &plan, ck, metrics)
}

/// GENIE-M block reconstruction under an already-resolved
/// [`PrecisionPlan`].
pub fn quantize_planned(
    mrt: &ModelRt,
    teacher: &Store,
    calib: &Tensor,
    cfg: &QuantCfg,
    plan: &PrecisionPlan,
    ck: Option<&StageCkpt>,
    metrics: &mut Metrics,
) -> Result<Store> {
    let m = &mrt.manifest;
    let nb = m.num_blocks;
    let br = m.batch("recon");
    metrics.start("quantize");

    // 1. activation statistics for LSQ init
    let stats = {
        let bs = m.batch("stats");
        let first = pad_to(calib, bs);
        let mut store = teacher.clone();
        store.insert("x", first);
        mrt.call("act_stats", &mut store)?;
        store.get("act_stats")?.as_f32().to_vec()
    };

    // 2. host-side quant-state init (Eq. 6 grid search + AdaRound),
    // per-layer bits/granularity from the plan
    let mut qstate = init_qstate(m, teacher, plan, cfg.pnorm, Some(&stats))?;
    set_act_steps(&mut qstate, &m.quant_layers, &stats)?;
    let label = plan.label();
    for (li, lp) in plan.layers.iter().enumerate() {
        metrics.log("plan/wbits", li, lp.wbits as f32);
        metrics.log("plan/abits", li, lp.abits as f32);
    }

    // one teacher upload for the whole phase, Arc-shared by collection
    // chunks and block jobs alike
    let teacher_dev = mrt.upload_store(teacher)?;
    let tdev = &teacher_dev;
    let (mut h2d_total, mut d2h_total) = teacher_dev.transfer_bytes();

    // 3. teacher block boundaries: contiguous batch chunks, one engine-
    // driven pool job (sharing the resident teacher) per worker
    let batches = image_batches(calib, br);
    let chunk_len =
        batches.len().div_ceil(cfg.par.resolve_for(batches.len()).max(1));
    let bound_jobs: Vec<_> = batches
        .chunks(chunk_len.max(1))
        .map(|chunk| {
            move || -> Result<(Vec<Vec<Tensor>>, (u64, u64))> {
                let mut dev = tdev.clone();
                let mut phase = CollectChunk {
                    chunk,
                    nb,
                    out: Vec::with_capacity(chunk.len()),
                };
                StepLoop::new(chunk.len(), 0)
                    .run(mrt, &mut phase, &mut dev)?;
                Ok((phase.out, dev.transfer_bytes()))
            }
        })
        .collect();
    let (bound_chunks, bounds_pool) = run_jobs(cfg.par, bound_jobs)?;
    let mut teacher_bounds: Vec<Vec<Tensor>> = Vec::new();
    for (chunk, xfer) in bound_chunks {
        teacher_bounds.extend(chunk);
        h2d_total += xfer.0;
        d2h_total += xfer.1;
    }
    metrics.record_pool("quantize/bounds", &bounds_pool);

    // 4. block reconstruction in topological waves: a chain when the
    // student prefix feeds block inputs, one all-blocks wave otherwise.
    // The evolving quant state is read-shared within a wave and merged
    // at the wave barrier.
    let mut qstate_now = qstate;
    let deps = if cfg.refresh_student {
        chain_deps(nb)
    } else {
        independent_deps(nb)
    };
    let mut blocks_pool = crate::exec::PoolReport::default();
    let mut ckpt_writes = 0usize;
    let mut ckpt_bytes = 0u64;
    let (mut dispatches, mut steps_run) = (0u64, 0u64);
    for wave in waves(&deps) {
        let qsnap = &qstate_now;
        let jobs: Vec<_> = wave
            .iter()
            .map(|&b| {
                let batches = &batches;
                let teacher_bounds = &teacher_bounds;
                move || {
                    reconstruct_block(
                        mrt, tdev, qsnap, batches, teacher_bounds, cfg, b, ck,
                    )
                }
            })
            .collect();
        let (outs, pool) = run_jobs(cfg.par, jobs)?;
        blocks_pool.merge(&pool);
        for out in outs {
            for (name, t) in out.learned {
                qstate_now.insert(&name, t);
            }
            for (t, rec) in out.rec_trace {
                metrics.log(&format!("quant/block{}/rec", out.block), t, rec);
            }
            h2d_total += out.transfer.0;
            d2h_total += out.transfer.1;
            ckpt_writes += out.ckpt_writes;
            ckpt_bytes += out.ckpt_bytes;
            dispatches += out.dispatch.0;
            steps_run += out.dispatch.1;
            crate::progress!(
                "quantize[{} {label}] block {}/{}: rec {:.5}",
                m.model, out.block + 1, nb, out.last_rec
            );
        }
    }
    metrics.record_pool("quantize/blocks", &blocks_pool);
    metrics.record_transfers(
        "quantize",
        nb * cfg.steps_per_block,
        h2d_total,
        d2h_total,
    );
    metrics.record_dispatches("quantize", dispatches, steps_run);
    if ckpt_writes > 0 {
        metrics.record_checkpoint("quantize", ckpt_writes, ckpt_bytes);
    }
    let secs = metrics.stop("quantize");
    let rate = metrics.throughput("quantize", "blocks", nb, secs);
    crate::progress!(
        "quantize[{} {label}]: {} blocks x {} steps in {:.1}s ({rate:.2} blocks/sec)",
        m.model, nb, cfg.steps_per_block, secs
    );

    // return just the q.* tensors (with optimized learnables)
    let qnames: Vec<String> = m.qstate.iter().map(|(n, _)| n.clone()).collect();
    subset(&qstate_now, qnames)
}

/// Pad/repeat rows so shape[0] == bs (for fixed-batch stat graphs).
fn pad_to(x: &Tensor, bs: usize) -> Tensor {
    let n = x.shape[0];
    let idx: Vec<usize> = (0..bs).map(|i| i % n).collect();
    x.gather_rows(&idx)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn pad_to_repeats() {
        let x = Tensor::from_f32(&[2, 1], vec![1.0, 2.0]);
        let p = pad_to(&x, 5);
        assert_eq!(p.shape, vec![5, 1]);
        assert_eq!(p.as_f32(), &[1.0, 2.0, 1.0, 2.0, 1.0]);
    }

    #[test]
    fn ablation_arms() {
        let c = QuantCfg::default().adaround().no_drop();
        assert_eq!(c.lr_sw, 0.0);
        assert_eq!(c.drop_p, 0.0);
    }
}

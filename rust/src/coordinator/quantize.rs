//! GENIE-M block-sequential post-training quantization (Algorithm 2 /
//! Algorithm A1):
//!
//!   1. LSQ activation-step init from teacher `act_stats`.
//!   2. Host-side quant-state init: Eq. 6 p-norm grid search for s_w,
//!      AdaRound base grid B + softbit V (crate::quant).
//!   3. Teacher block-boundary collection over the calibration set.
//!   4. Per block, Adam on (s_w, V, s_a) against the block reconstruction
//!      error + annealed rounding regularizer (Eq. A2), with QDrop.
//!      Block inputs come from the *quantized prefix* (refreshed via
//!      `collect_student` before each block, BRECQ-style).
//!
//! Ablation arms are pure config: `lr_sw = 0` -> AdaRound (no joint step
//! size, M1 vs M2 / Table 5), `drop_p = 0` -> NoDrop.

use anyhow::Result;

use crate::data::image_batches;
use crate::quant::{init_qstate, set_act_steps, BitConfig};
use crate::runtime::ModelRt;
use crate::schedule::{BetaAnneal, CosineAnnealing};
use crate::store::Store;
use crate::tensor::{Pcg32, Tensor};

use super::{subset, Metrics};

#[derive(Debug, Clone)]
pub struct QuantCfg {
    pub wbits: u32,
    pub abits: u32,
    pub steps_per_block: usize,
    /// weight step-size LR (0 => AdaRound baseline: s_w frozen)
    pub lr_sw: f32,
    /// softbit LR
    pub lr_v: f32,
    /// activation step LR (LSQ)
    pub lr_sa: f32,
    /// rounding-regularizer weight (paper: 1.0 for GENIE-M)
    pub lam: f32,
    pub beta_start: f32,
    pub beta_end: f32,
    /// QDrop keep-FP probability (0 => NoDrop)
    pub drop_p: f32,
    /// Eq. A3 p-norm for the step-size search (Fig. A2; default 2.4)
    pub pnorm: f32,
    /// refresh block inputs through the quantized prefix (BRECQ-style)
    pub refresh_student: bool,
    pub log_every: usize,
    pub seed: u64,
}

impl Default for QuantCfg {
    fn default() -> Self {
        QuantCfg {
            wbits: 4,
            abits: 4,
            steps_per_block: 250,
            lr_sw: 1e-4,
            lr_v: 1e-2,
            lr_sa: 4e-5,
            lam: 1.0,
            beta_start: 20.0,
            beta_end: 2.0,
            drop_p: 0.5,
            pnorm: 2.4,
            refresh_student: true,
            log_every: 50,
            seed: 31,
        }
    }
}

impl QuantCfg {
    /// AdaRound baseline arm: frozen step sizes.
    pub fn adaround(mut self) -> Self {
        self.lr_sw = 0.0;
        self.lr_sa = 0.0;
        self
    }

    /// NoDrop arm.
    pub fn no_drop(mut self) -> Self {
        self.drop_p = 0.0;
        self
    }
}

/// Run GENIE-M over a calibration set; returns the optimized quant state.
pub fn quantize(
    mrt: &ModelRt,
    teacher: &Store,
    calib: &Tensor,
    cfg: &QuantCfg,
    metrics: &mut Metrics,
) -> Result<Store> {
    let m = &mrt.manifest;
    let nb = m.num_blocks;
    let br = m.batch("recon");
    let mut rng = Pcg32::new(cfg.seed);
    metrics.start("quantize");

    // 1. activation statistics for LSQ init
    let stats = {
        let bs = m.batch("stats");
        let first = pad_to(calib, bs);
        let mut store = teacher.clone();
        store.insert("x", first);
        mrt.call("act_stats", &mut store)?;
        store.get("act_stats")?.as_f32().to_vec()
    };

    // 2. host-side quant-state init (Eq. 6 grid search + AdaRound)
    let bits = BitConfig::new(cfg.wbits, cfg.abits);
    let mut qstate = init_qstate(m, teacher, bits, cfg.pnorm, Some(&stats))?;
    set_act_steps(&mut qstate, &m.quant_layers, &stats)?;

    // 3. teacher block boundaries over calibration batches
    let batches = image_batches(calib, br);
    let mut teacher_bounds: Vec<Vec<Tensor>> = Vec::new();
    {
        let mut store = teacher.clone();
        for (bx, _) in &batches {
            store.insert("x", bx.clone());
            mrt.call("collect_teacher", &mut store)?;
            let bounds = (0..=nb)
                .map(|i| store.get(&format!("bound.{i}")).map(Clone::clone))
                .collect::<Result<Vec<_>>>()?;
            teacher_bounds.push(bounds);
        }
    }

    // one store holds teacher + qstate + adam + per-step scalars
    let mut store = teacher.clone();
    store.absorb(&qstate);

    // 4. block-sequential reconstruction
    for b in 0..nb {
        // block inputs through the quantized prefix
        let inputs: Vec<Tensor> = if b == 0 || !cfg.refresh_student {
            teacher_bounds.iter().map(|t| t[b].clone()).collect()
        } else {
            let mut xs = Vec::new();
            for (bx, _) in &batches {
                store.insert("x", bx.clone());
                let (kh, kl) = rng.key_pair();
                store.insert("key", Tensor::key(kh, kl));
                mrt.call("collect_student", &mut store)?;
                xs.push(store.get(&format!("bound.{b}"))?.clone());
            }
            xs
        };

        // fresh Adam state for this block's learnables
        let learn = m.learnable_block(b).to_vec();
        for name in &learn {
            let shape = store.get(name)?.shape.clone();
            store.insert(&format!("am.{name}"), Tensor::zeros(&shape));
            store.insert(&format!("av.{name}"), Tensor::zeros(&shape));
        }

        let sw_sched = CosineAnnealing::new(cfg.lr_sw, cfg.steps_per_block);
        let sa_sched = CosineAnnealing::new(cfg.lr_sa, cfg.steps_per_block);
        let beta = BetaAnneal::new(cfg.beta_start, cfg.beta_end, 0.2,
                                   cfg.steps_per_block);
        let entry = mrt.entry(&format!("quant_step_{b}"))?;
        let mut last_rec = f32::NAN;
        for t in 1..=cfg.steps_per_block {
            let bi = rng.below(batches.len());
            store.insert("x_in", inputs[bi].clone());
            store.insert("y_ref", teacher_bounds[bi][b + 1].clone());
            let (kh, kl) = rng.key_pair();
            store.insert("key", Tensor::key(kh, kl));
            store.insert("t", Tensor::scalar_f32(t as f32));
            store.insert("lr_sw", Tensor::scalar_f32(sw_sched.lr(t - 1)));
            store.insert("lr_v", Tensor::scalar_f32(cfg.lr_v));
            store.insert("lr_sa", Tensor::scalar_f32(sa_sched.lr(t - 1)));
            store.insert("lam", Tensor::scalar_f32(cfg.lam));
            store.insert("beta", Tensor::scalar_f32(beta.beta(t)));
            store.insert("drop_p", Tensor::scalar_f32(cfg.drop_p));
            let scalars = mrt.rt.call(&entry, &mut store)?;
            last_rec = scalars["rec"];
            if t % cfg.log_every == 0 || t == cfg.steps_per_block {
                metrics.log(&format!("quant/block{b}/rec"), t, scalars["rec"]);
            }
        }
        println!(
            "quantize[{} W{}A{}] block {}/{}: rec {:.5}",
            m.model, cfg.wbits, cfg.abits, b + 1, nb, last_rec
        );
    }
    let secs = metrics.stop("quantize");
    println!(
        "quantize[{} W{}A{}]: {} blocks x {} steps in {:.1}s",
        m.model, cfg.wbits, cfg.abits, nb, cfg.steps_per_block, secs
    );

    // return just the q.* tensors (with optimized learnables)
    let qnames: Vec<String> = m.qstate.iter().map(|(n, _)| n.clone()).collect();
    Ok(subset(&store, qnames))
}

/// Pad/repeat rows so shape[0] == bs (for fixed-batch stat graphs).
fn pad_to(x: &Tensor, bs: usize) -> Tensor {
    let n = x.shape[0];
    let idx: Vec<usize> = (0..bs).map(|i| i % n).collect();
    x.gather_rows(&idx)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn pad_to_repeats() {
        let x = Tensor::from_f32(&[2, 1], vec![1.0, 2.0]);
        let p = pad_to(&x, 5);
        assert_eq!(p.shape, vec![5, 1]);
        assert_eq!(p.as_f32(), &[1.0, 2.0, 1.0, 2.0, 1.0]);
    }

    #[test]
    fn ablation_arms() {
        let c = QuantCfg::default().adaround().no_drop();
        assert_eq!(c.lr_sw, 0.0);
        assert_eq!(c.drop_p, 0.0);
    }
}

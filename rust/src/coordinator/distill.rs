//! Synthetic-data distillation scheduler (Algorithm 1) — sharding,
//! checkpoint/resume and aggregation for whichever [`Engine`] the config
//! selects (DESIGN.md §12). The per-shard optimization itself lives in
//! `crate::synthesis` behind the `SynthesisPolicy` trait; the default
//! GENIE-D engine keeps the Table 2 ablation arms:
//!
//!   * `Genie`  — generator + learnable latents (lr_z > 0), Alg. 1
//!   * `Gba`    — generator only, latents frozen (lr_z = 0) — M4
//!   * `Direct` — ZeroQ-style image-space distillation — M1/M3
//!
//! Each batch is distilled independently: the generator is re-initialized
//! per batch via the `gen_init` graph (appendix A: "the weights of the
//! generator are shared only within a batch"). Generator LR decays
//! exponentially (gamma 0.95 / 100 steps); latent LR follows
//! ReduceLROnPlateau "like that in ZeroQ". Swing conv is selected by
//! lowering variant (`*_swing` / `*_noswing` entrypoints).
//!
//! Because batches share nothing, they are synthesized as parallel shards
//! on the exec pool (DESIGN.md §5): shard b draws all of its randomness
//! from `Pcg32::new_stream(seed, b)`, so the synthetic set is bit-identical
//! for any worker count.
//!
//! Each shard's step loop runs on the shared phase engine (DESIGN.md §9):
//! the policy-built [`Phase`] supplies the per-step scalars and the
//! carried state names; [`StepLoop`] owns residency and — with a stage
//! checkpoint attached — periodic GTS1 checkpoints plus `shard{b}.done`
//! results, so an interrupted synthesis resumes per shard, mid-loop,
//! bit-identically (RNG + plateau scheduler travel in the snapshot).

use anyhow::Result;

use crate::exec::{run_jobs, Parallelism};
use crate::phase::{checkpoint, StageCkpt, StepLoop};
use crate::runtime::{DeviceStore, ModelRt};
use crate::store::Store;
use crate::synthesis::Engine;
use crate::tensor::{Pcg32, Tensor};

use super::Metrics;

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum DistillMode {
    Genie,
    Gba,
    Direct,
}

impl DistillMode {
    pub fn parse(s: &str) -> Result<Self> {
        match s {
            "genie" => Ok(DistillMode::Genie),
            "gba" => Ok(DistillMode::Gba),
            "direct" | "zeroq" => Ok(DistillMode::Direct),
            other => anyhow::bail!("unknown distill mode '{other}'"),
        }
    }

    /// Canonical lowercase name (config values, cache-key fields, grid
    /// cell labels).
    pub fn as_str(self) -> &'static str {
        match self {
            DistillMode::Genie => "genie",
            DistillMode::Gba => "gba",
            DistillMode::Direct => "direct",
        }
    }
}

#[derive(Debug, Clone)]
pub struct DistillCfg {
    /// which synthesis engine builds the shard phases (DESIGN.md §12)
    pub engine: Engine,
    pub mode: DistillMode,
    pub swing: bool,
    /// number of synthetic images to distill (rounded up to whole batches)
    pub samples: usize,
    /// optimization steps per batch
    pub steps: usize,
    pub lr_g: f32,
    pub lr_z: f32,
    pub log_every: usize,
    pub seed: u64,
    /// worker pool for the shard fan-out (`workers=K`; 0 = auto)
    pub par: Parallelism,
    /// fused steps per device dispatch (`steps_per_dispatch=K`; 1 = off).
    /// Execution-shape knob like `par`: identity-neutral, never folded
    /// into content keys (DESIGN.md §14).
    pub steps_per_dispatch: usize,
}

impl Default for DistillCfg {
    fn default() -> Self {
        DistillCfg {
            engine: Engine::Genie,
            mode: DistillMode::Genie,
            swing: true,
            samples: 128,
            steps: 200,
            lr_g: 0.01,
            lr_z: 0.1,
            log_every: 50,
            seed: 23,
            par: Parallelism::default(),
            steps_per_dispatch: 1,
        }
    }
}

#[derive(Debug)]
pub struct DistillOutput {
    /// [samples, H, W, C] synthetic calibration images
    pub images: Tensor,
    /// BNS loss trace (per logged step, averaged over batches)
    pub loss_trace: Vec<(usize, f32)>,
    /// final BNS loss averaged over batches
    pub final_loss: f32,
}

/// What one shard job hands back to the aggregation loop.
struct ShardResult {
    images: Tensor,
    /// (step, BNS loss) at each engine-logged step — real labels, so the
    /// aggregation never has to re-derive them from `log_every`
    trace: Vec<(usize, f32)>,
    transfer: (u64, u64),
    ckpt_writes: usize,
    ckpt_bytes: u64,
    /// (device dispatches, steps executed) — diverge under fused dispatch
    dispatch: (u64, u64),
}

/// One distill shard through the engine: load a `done` result when
/// resuming, otherwise run (possibly from a mid-loop checkpoint) and
/// persist the result for future resumes.
fn distill_shard(
    mrt: &ModelRt,
    teacher_dev: &DeviceStore<'_>,
    cfg: &DistillCfg,
    tag: &str,
    b: usize,
    ck: Option<&StageCkpt>,
) -> Result<ShardResult> {
    let shard_name = format!("shard{b}");
    // deterministic fault-injection site (DESIGN.md §13):
    // GENIE_FAULTS=distill:shard2:attempt1=panic fires here
    crate::faults::check("distill", &shard_name)?;
    if let Some(ck) = ck {
        if let Some(done) = ck.load_done(&shard_name) {
            return Ok(ShardResult {
                images: done.get("images")?.clone(),
                trace: checkpoint::trace_from_store(&done, "trace")?,
                transfer: (0, 0),
                ckpt_writes: 0,
                ckpt_bytes: 0,
                dispatch: (0, 0),
            });
        }
    }
    // shard-local view: teacher buffers shared, own learnables on top
    let mut dev = teacher_dev.clone();
    let steploop = StepLoop::new(cfg.steps, cfg.log_every.max(1))
        .with_checkpoint(ck.map(|c| c.shard(&shard_name)))
        .with_steps_per_dispatch(cfg.steps_per_dispatch);
    let rng = Pcg32::new_stream(cfg.seed, b as u64);
    let mut phase = cfg.engine.policy().shard(mrt, cfg, tag, rng);
    let out = steploop.run(mrt, phase.as_mut(), &mut dev)?;
    anyhow::ensure!(
        out.completed,
        "distill shard {b}: interrupted by step budget (checkpoint \
         written; re-run with resume to continue)"
    );
    let images = out.result.get("images")?.clone();
    let trace: Vec<(usize, f32)> =
        out.trace.iter().map(|(t, s)| (*t, s["loss"])).collect();
    if let Some(ck) = ck {
        let mut done = Store::new();
        done.insert("images", images.clone());
        checkpoint::trace_to_store(&mut done, "trace", &trace);
        ck.write_done(&shard_name, &done)?;
    }
    Ok(ShardResult {
        images,
        trace,
        transfer: dev.transfer_bytes(),
        ckpt_writes: out.checkpoints_written,
        ckpt_bytes: out.checkpoint_bytes,
        dispatch: (out.dispatches as u64, out.ran_steps as u64),
    })
}

/// Distill a synthetic calibration set from the teacher's BN statistics.
/// Shards (one per distill batch) run concurrently on the exec pool;
/// shard b's randomness comes exclusively from `new_stream(seed, b)`, so
/// the result is identical for every `cfg.par`.
pub fn distill(
    mrt: &ModelRt,
    teacher: &Store,
    cfg: &DistillCfg,
    metrics: &mut Metrics,
) -> Result<DistillOutput> {
    distill_ck(mrt, teacher, cfg, None, metrics)
}

/// [`distill`] with an optional stage checkpoint (per-shard engine
/// checkpoints + completed-shard results in the stage's work dir).
pub fn distill_ck(
    mrt: &ModelRt,
    teacher: &Store,
    cfg: &DistillCfg,
    ck: Option<&StageCkpt>,
    metrics: &mut Metrics,
) -> Result<DistillOutput> {
    let m = &mrt.manifest;
    let bd = m.batch("distill");
    let n_batches = cfg.samples.div_ceil(bd);
    let tag = if cfg.swing { "swing" } else { "noswing" };
    let mode_name = cfg.engine.display(cfg.mode);

    metrics.start("distill");
    // one teacher upload, Arc-shared by every shard (no per-shard clone
    // of the teacher tensors, host- or device-side)
    let teacher_dev = mrt.upload_store(teacher)?;
    let tdev = &teacher_dev;
    let jobs: Vec<_> = (0..n_batches)
        .map(|b| move || distill_shard(mrt, tdev, cfg, tag, b, ck))
        .collect();
    let (shards, pool) = run_jobs(cfg.par, jobs)?;
    let secs = metrics.stop("distill");
    metrics.record_pool("distill", &pool);

    let mut parts: Vec<Tensor> = Vec::new();
    let mut traces: Vec<Vec<(usize, f32)>> = Vec::new();
    let mut final_losses = Vec::new();
    let (mut h2d, mut d2h) = teacher_dev.transfer_bytes();
    let mut ckpt_writes = 0usize;
    let mut ckpt_bytes = 0u64;
    let (mut dispatches, mut steps_run) = (0u64, 0u64);
    for (b, shard) in shards.into_iter().enumerate() {
        final_losses.push(shard.trace.last().map(|&(_, v)| v).unwrap());
        traces.push(shard.trace);
        parts.push(shard.images);
        h2d += shard.transfer.0;
        d2h += shard.transfer.1;
        ckpt_writes += shard.ckpt_writes;
        ckpt_bytes += shard.ckpt_bytes;
        dispatches += shard.dispatch.0;
        steps_run += shard.dispatch.1;
        if b == 0 || b == n_batches - 1 {
            crate::progress!(
                "distill[{}/{mode_name}/{tag}] shard {}/{}: loss {:.3}",
                m.model,
                b + 1,
                n_batches,
                final_losses.last().unwrap()
            );
        }
    }
    metrics.record_transfers("distill", cfg.steps, h2d, d2h);
    metrics.record_dispatches("distill", dispatches, steps_run);
    if ckpt_writes > 0 {
        metrics.record_checkpoint("distill", ckpt_writes, ckpt_bytes);
    }

    // average trace across batches at each logged step; every shard logs
    // the same engine-labeled steps (log_every cadence plus the real
    // final step), so shard 0's labels are the series' labels
    let steps_logged = traces[0].len();
    let mut loss_trace = Vec::with_capacity(steps_logged);
    for i in 0..steps_logged {
        let avg =
            traces.iter().map(|t| t[i].1).sum::<f32>() / traces.len() as f32;
        let step = traces[0][i].0;
        metrics.log(&format!("distill/{mode_name}/bns_loss"), step, avg);
        loss_trace.push((step, avg));
    }

    let refs: Vec<&Tensor> = parts.iter().collect();
    let mut images = Tensor::concat_rows(&refs);
    images.truncate_rows(cfg.samples);
    let final_loss =
        final_losses.iter().sum::<f32>() / final_losses.len() as f32;
    let rate = metrics.throughput("distill", "images", cfg.samples, secs);
    crate::progress!(
        "distill[{}/{mode_name}/{tag}]: {} images in {:.1}s \
         ({rate:.1} images/sec on {} workers, final BNS {:.3})",
        m.model, cfg.samples, secs, pool.workers, final_loss
    );
    Ok(DistillOutput { images, loss_trace, final_loss })
}

//! GENIE-D data distillation scheduler (Algorithm 1) plus the baseline
//! arms of the Table 2 ablation:
//!
//!   * `Genie`  — generator + learnable latents (lr_z > 0), Alg. 1
//!   * `Gba`    — generator only, latents frozen (lr_z = 0) — M4
//!   * `Direct` — ZeroQ-style image-space distillation — M1/M3
//!
//! Each batch is distilled independently: the generator is re-initialized
//! per batch via the `gen_init` graph (appendix A: "the weights of the
//! generator are shared only within a batch"). Generator LR decays
//! exponentially (gamma 0.95 / 100 steps); latent LR follows
//! ReduceLROnPlateau "like that in ZeroQ". Swing conv is selected by
//! lowering variant (`*_swing` / `*_noswing` entrypoints).
//!
//! Because batches share nothing, they are synthesized as parallel shards
//! on the exec pool (DESIGN.md §5): shard b draws all of its randomness
//! from `Pcg32::new_stream(seed, b)`, so the synthetic set is bit-identical
//! for any worker count.
//!
//! Device residency (DESIGN.md §8): the teacher is uploaded once and its
//! buffers are `Arc`-shared by every shard; each shard's step loop runs on
//! a [`DeviceStore`], so per-step traffic is the schedule scalars up and
//! the loss down — the synthetic images come back to the host exactly
//! once, at the `gen_images` phase boundary.

use anyhow::Result;

use crate::exec::{run_jobs, Parallelism};
use crate::runtime::{DeviceStore, ModelRt};
use crate::schedule::{ExponentialDecay, ReduceLROnPlateau};
use crate::store::Store;
use crate::tensor::{Pcg32, Tensor};

use super::Metrics;

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum DistillMode {
    Genie,
    Gba,
    Direct,
}

impl DistillMode {
    pub fn parse(s: &str) -> Result<Self> {
        match s {
            "genie" => Ok(DistillMode::Genie),
            "gba" => Ok(DistillMode::Gba),
            "direct" | "zeroq" => Ok(DistillMode::Direct),
            other => anyhow::bail!("unknown distill mode '{other}'"),
        }
    }
}

#[derive(Debug, Clone)]
pub struct DistillCfg {
    pub mode: DistillMode,
    pub swing: bool,
    /// number of synthetic images to distill (rounded up to whole batches)
    pub samples: usize,
    /// optimization steps per batch
    pub steps: usize,
    pub lr_g: f32,
    pub lr_z: f32,
    pub log_every: usize,
    pub seed: u64,
    /// worker pool for the shard fan-out (`workers=K`; 0 = auto)
    pub par: Parallelism,
}

impl Default for DistillCfg {
    fn default() -> Self {
        DistillCfg {
            mode: DistillMode::Genie,
            swing: true,
            samples: 128,
            steps: 200,
            lr_g: 0.01,
            lr_z: 0.1,
            log_every: 50,
            seed: 23,
            par: Parallelism::default(),
        }
    }
}

#[derive(Debug)]
pub struct DistillOutput {
    /// [samples, H, W, C] synthetic calibration images
    pub images: Tensor,
    /// BNS loss trace (per logged step, averaged over batches)
    pub loss_trace: Vec<(usize, f32)>,
    /// final BNS loss averaged over batches
    pub final_loss: f32,
}

/// Distill a synthetic calibration set from the teacher's BN statistics.
/// Shards (one per distill batch) run concurrently on the exec pool;
/// shard b's randomness comes exclusively from `new_stream(seed, b)`, so
/// the result is identical for every `cfg.par`.
pub fn distill(
    mrt: &ModelRt,
    teacher: &Store,
    cfg: &DistillCfg,
    metrics: &mut Metrics,
) -> Result<DistillOutput> {
    let m = &mrt.manifest;
    let bd = m.batch("distill");
    let n_batches = cfg.samples.div_ceil(bd);
    let tag = if cfg.swing { "swing" } else { "noswing" };
    let mode_name = match cfg.mode {
        DistillMode::Genie => "genie",
        DistillMode::Gba => "gba",
        DistillMode::Direct => "direct",
    };

    metrics.start("distill");
    // one teacher upload, Arc-shared by every shard (no per-shard clone
    // of the teacher tensors, host- or device-side)
    let teacher_dev = mrt.upload_store(teacher)?;
    let tdev = &teacher_dev;
    let jobs: Vec<_> = (0..n_batches)
        .map(|b| {
            move || -> Result<(Tensor, Vec<f32>, (u64, u64))> {
                let mut rng = Pcg32::new_stream(cfg.seed, b as u64);
                match cfg.mode {
                    DistillMode::Direct => {
                        distill_direct(mrt, tdev, cfg, tag, &mut rng)
                    }
                    _ => distill_genie(mrt, tdev, cfg, tag, &mut rng),
                }
            }
        })
        .collect();
    let (shards, pool) = run_jobs(cfg.par, jobs)?;
    let secs = metrics.stop("distill");
    metrics.record_pool("distill", &pool);

    let mut parts: Vec<Tensor> = Vec::new();
    let mut traces: Vec<Vec<f32>> = Vec::new();
    let mut final_losses = Vec::new();
    let (mut h2d, mut d2h) = teacher_dev.transfer_bytes();
    for (b, (imgs, trace, xfer)) in shards.into_iter().enumerate() {
        final_losses.push(*trace.last().unwrap());
        traces.push(trace);
        parts.push(imgs);
        h2d += xfer.0;
        d2h += xfer.1;
        if b == 0 || b == n_batches - 1 {
            println!(
                "distill[{}/{mode_name}/{tag}] shard {}/{}: loss {:.3}",
                m.model,
                b + 1,
                n_batches,
                final_losses.last().unwrap()
            );
        }
    }
    metrics.record_transfers("distill", cfg.steps, h2d, d2h);

    // average trace across batches at each logged step; the final entry
    // lands at t == steps, which is not a multiple of log_every when
    // log_every does not divide steps — clamp the label to the real step
    let steps_logged = traces[0].len();
    let mut loss_trace = Vec::with_capacity(steps_logged);
    for i in 0..steps_logged {
        let avg = traces.iter().map(|t| t[i]).sum::<f32>() / traces.len() as f32;
        let step = ((i + 1) * cfg.log_every).min(cfg.steps);
        metrics.log(&format!("distill/{mode_name}/bns_loss"), step, avg);
        loss_trace.push((step, avg));
    }

    let refs: Vec<&Tensor> = parts.iter().collect();
    let mut images = Tensor::concat_rows(&refs);
    images.truncate_rows(cfg.samples);
    let final_loss =
        final_losses.iter().sum::<f32>() / final_losses.len() as f32;
    let rate = metrics.throughput("distill", "images", cfg.samples, secs);
    println!(
        "distill[{}/{mode_name}/{tag}]: {} images in {:.1}s \
         ({rate:.1} images/sec on {} workers, final BNS {:.3})",
        m.model, cfg.samples, secs, pool.workers, final_loss
    );
    Ok(DistillOutput { images, loss_trace, final_loss })
}

/// One generator-based shard (GENIE / GBA). Returns (images, loss trace,
/// shard transfer bytes). The whole optimization state — generator
/// params, Adam moments, latents — stays device-resident across steps;
/// only `key`/`t`/`lr_*` go up and the loss comes down per step.
fn distill_genie(
    mrt: &ModelRt,
    teacher_dev: &DeviceStore<'_>,
    cfg: &DistillCfg,
    tag: &str,
    rng: &mut Pcg32,
) -> Result<(Tensor, Vec<f32>, (u64, u64))> {
    let m = &mrt.manifest;
    let bd = m.batch("distill");
    // shard-local view: teacher buffers shared, own learnables on top
    let mut dev = teacher_dev.clone();

    // fresh generator per batch (appendix A)
    let (kh, kl) = rng.key_pair();
    dev.insert("key", &Tensor::key(kh, kl))?;
    mrt.call_device("gen_init", &mut dev)?;
    for (name, shape) in &m.gen_params {
        dev.insert(&format!("am.{name}"), &Tensor::zeros(shape))?;
        dev.insert(&format!("av.{name}"), &Tensor::zeros(shape))?;
    }

    // latents z ~ N(0, I), learnable (the GLO insight, section 3.1)
    let zshape = [bd, m.latent];
    dev.insert("z", &Tensor::randn(&zshape, rng, 1.0))?;
    dev.insert("zm", &Tensor::zeros(&zshape))?;
    dev.insert("zv", &Tensor::zeros(&zshape))?;

    let gen_sched = ExponentialDecay::new(cfg.lr_g, 0.95, 100);
    let mut z_sched = ReduceLROnPlateau::new(cfg.lr_z, 0.5, 30);
    let lr_z_active = cfg.mode == DistillMode::Genie;

    let entry = mrt.entry(&format!("distill_genie_{tag}"))?;
    let mut trace = Vec::new();
    let mut lr_z = if lr_z_active { cfg.lr_z } else { 0.0 };
    for t in 1..=cfg.steps {
        let (kh, kl) = rng.key_pair();
        dev.insert("key", &Tensor::key(kh, kl))?;
        dev.insert("t", &Tensor::scalar_f32(t as f32))?;
        dev.insert("lr_g", &Tensor::scalar_f32(gen_sched.lr(t - 1)))?;
        dev.insert("lr_z", &Tensor::scalar_f32(lr_z))?;
        let scalars = mrt.rt.call_device(&entry, &mut dev)?;
        let loss = scalars["loss"];
        if lr_z_active {
            lr_z = z_sched.observe(loss);
        }
        if t % cfg.log_every == 0 || t == cfg.steps {
            trace.push(loss);
        }
    }
    // phase boundary: the only full-tensor download of the shard
    mrt.call_device("gen_images", &mut dev)?;
    let images = dev.fetch("images")?;
    Ok((images, trace, dev.transfer_bytes()))
}

/// One direct (ZeroQ/DBA) batch: images themselves are the parameters,
/// living on device until the final fetch.
fn distill_direct(
    mrt: &ModelRt,
    teacher_dev: &DeviceStore<'_>,
    cfg: &DistillCfg,
    tag: &str,
    rng: &mut Pcg32,
) -> Result<(Tensor, Vec<f32>, (u64, u64))> {
    let m = &mrt.manifest;
    let bd = m.batch("distill");
    let img = &m.image;
    let xshape = [bd, img[0], img[1], img[2]];
    let mut dev = teacher_dev.clone();
    dev.insert("x", &Tensor::randn(&xshape, rng, 1.0))?;
    dev.insert("xm", &Tensor::zeros(&xshape))?;
    dev.insert("xv", &Tensor::zeros(&xshape))?;

    let mut sched = ReduceLROnPlateau::new(cfg.lr_z, 0.5, 30);
    let entry = mrt.entry(&format!("distill_direct_{tag}"))?;
    let mut trace = Vec::new();
    let mut lr = cfg.lr_z;
    for t in 1..=cfg.steps {
        let (kh, kl) = rng.key_pair();
        dev.insert("key", &Tensor::key(kh, kl))?;
        dev.insert("t", &Tensor::scalar_f32(t as f32))?;
        dev.insert("lr", &Tensor::scalar_f32(lr))?;
        let scalars = mrt.rt.call_device(&entry, &mut dev)?;
        let loss = scalars["loss"];
        lr = sched.observe(loss);
        if t % cfg.log_every == 0 || t == cfg.steps {
            trace.push(loss);
        }
    }
    let images = dev.fetch("x")?;
    Ok((images, trace, dev.transfer_bytes()))
}

//! L3 pipeline coordinator: the GENIE zero-shot-quantization state machine.
//!
//! Phases (Figure 2 of the paper):
//!   1. [`pretrain`]  — FP32 teacher training via the `train_step` graph
//!      (substitute for the paper's downloaded ImageNet checkpoints).
//!   2. [`distill`]   — GENIE-D: per-batch generator re-init, joint
//!      latent+generator optimization against the BNS loss, swing conv;
//!      plus the ZeroQ (direct) and GBA (frozen-latent) baseline arms.
//!   3. [`quantize`]  — GENIE-M: Eq. 6 step-size search, AdaRound softbit
//!      init, LSQ activation steps, block-sequential reconstruction with
//!      QDrop and the annealed rounding regularizer.
//!   4. [`evaluate`]  — FP32 / hard-quantized top-1 accuracy.
//!
//! All schedules (cosine, exponential, plateau, beta anneal) are computed
//! here and fed to the AOT graphs as runtime scalars.

pub mod config;
pub mod metrics;
pub mod pretrain;
pub mod distill;
pub mod quantize;
pub mod evaluate;
pub mod pipeline;

pub use config::RunConfig;
pub use distill::{distill, distill_ck, DistillCfg, DistillMode, DistillOutput};
pub use evaluate::{
    eval_fp32, eval_fp32_metered, eval_fp32_par, eval_quantized,
    eval_quantized_metered, eval_quantized_par,
};
pub use metrics::Metrics;
pub use pipeline::{
    distill_cached, distill_cached_keyed, fsq, plan_cached, quantize_cached,
    quantize_cached_planned, zsq, PipelineOutcome,
};
pub use pretrain::{pretrain, pretrain_ck, teacher_cached, PretrainCfg};
pub use quantize::{
    quantize, quantize_ck, quantize_planned, resolve_plan, QuantCfg,
};

use anyhow::{Context, Result};

use crate::runtime::manifest::NamedShape;
use crate::store::Store;
use crate::tensor::Tensor;

/// Insert zero tensors for every (name, shape) with an optional prefix —
/// used for Adam moment states ("am." / "av." + param name).
pub fn insert_zeros(store: &mut Store, specs: &[NamedShape], prefix: &str) {
    for (name, shape) in specs {
        store.insert(&format!("{prefix}{name}"), Tensor::zeros(shape));
    }
}

/// Subset of a store by exact names (shares the tensors, copies nothing).
/// Errors name the missing tensor instead of panicking, so a manifest /
/// store mismatch surfaces as a diagnosable failure at the call site.
pub fn subset(
    store: &Store,
    names: impl IntoIterator<Item = String>,
) -> Result<Store> {
    let mut out = Store::new();
    for n in names {
        let t = store
            .get_shared(&n)
            .with_context(|| format!("subset: missing tensor '{n}'"))?;
        out.insert_shared(&n, t);
    }
    Ok(out)
}

/// Names of the FP32 teacher tensors (params + BN state) in a manifest.
pub fn teacher_names(m: &crate::runtime::Manifest) -> Vec<String> {
    m.params
        .iter()
        .chain(m.bn.iter())
        .map(|(n, _)| n.clone())
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn insert_zeros_prefixes() {
        let mut s = Store::new();
        insert_zeros(&mut s, &[("w".into(), vec![2, 2])], "am.");
        assert_eq!(s.get("am.w").unwrap().numel(), 4);
    }

    #[test]
    fn subset_picks() {
        let mut s = Store::new();
        s.insert("a", Tensor::scalar_f32(1.0));
        s.insert("b", Tensor::scalar_f32(2.0));
        let sub = subset(&s, ["b".to_string()]).unwrap();
        assert_eq!(sub.len(), 1);
        assert!(sub.contains("b"));
    }

    #[test]
    fn subset_names_the_missing_tensor() {
        let s = Store::new();
        let err = subset(&s, ["q.gone.sw".to_string()]).unwrap_err();
        assert!(
            format!("{err:#}").contains("q.gone.sw"),
            "error must carry the name: {err:#}"
        );
    }
}

//! Run configuration: one struct covering every phase, buildable from
//! `key=value` CLI overrides (std-only; no clap in the offline testbed).
//!
//! Example:
//!   genie zsq --model resnet14 wbits=2 abits=4 workers=8 \
//!       distill.samples=256 distill.mode=genie quant.drop_p=0.5

use anyhow::{bail, Result};

use crate::artifacts::ArtifactCache;
use crate::exec::{Parallelism, Sched};
use crate::precision::{validate_bits, Granularity, Policy};
use crate::synthesis::Engine;

use super::{DistillCfg, DistillMode, PretrainCfg, QuantCfg};

/// Parse an env var as a number, treating unset/empty/garbage as absent
/// (the CI matrix sets these to `''` on legs that don't pin them).
fn env_u64(name: &str) -> Option<u64> {
    std::env::var(name).ok().and_then(|v| v.trim().parse().ok())
}

fn env_str(name: &str) -> Option<String> {
    std::env::var(name).ok().filter(|v| !v.trim().is_empty())
}

#[derive(Debug, Clone)]
pub struct RunConfig {
    pub model: String,
    pub artifacts: String,
    pub runs_dir: String,
    pub seed: u64,
    /// exec worker pool size (`workers=K`, 0 = one per hardware thread);
    /// fanned out into the distill/quant phase configs like `seed`
    pub par: Parallelism,
    pub pretrain: PretrainCfg,
    pub distill: DistillCfg,
    pub quant: QuantCfg,
    /// few-shot calibration sample count (fsq)
    pub fsq_samples: usize,
    /// artifact-cache directory (`--cache-dir`, DESIGN.md §9)
    pub cache_dir: String,
    /// content-addressed artifact caching on/off (`--no-cache` clears it)
    pub cache: bool,
    /// resume interrupted stages from their wip checkpoints (`--resume`)
    pub resume: bool,
    /// steps between mid-phase checkpoint writes (0 = shard-boundary
    /// durability only)
    pub checkpoint_every: usize,
    /// machine-readable outcome sink (`--json <path>`, DESIGN.md §11):
    /// `genie run`/`genie grid` write their outcome JSON here
    pub json: Option<String>,
    /// supervised-dispatch attempt budget per grid stage node
    /// (`retry.max=N`, DESIGN.md §13): 1 = no retries; the default 2
    /// absorbs one transient failure per stage
    pub retry_max: u32,
    /// deterministic backoff base between attempts, milliseconds
    /// (`retry.backoff_ms`): attempt k sleeps `(k-1) * backoff_ms`
    pub retry_backoff_ms: u64,
    /// grid scheduler (`sched=wave|dataflow`, DESIGN.md §15): both are
    /// bit-identical in outputs; `wave` keeps the barriered reference
    /// path. Default `dataflow`, overridable by `GENIE_SCHED` (the CI
    /// matrix knob)
    pub sched: Sched,
    /// tier-1 disk budget in bytes (`cache.budget_bytes`, DESIGN.md
    /// §16): every store runs a pin-aware GC pass back under it; 0 =
    /// unlimited. Default from `GENIE_CACHE_BUDGET_BYTES` (CI knob)
    pub cache_budget_bytes: u64,
    /// tier-0 in-memory budget in bytes (`cache.hot_bytes`): LRU-evict
    /// hot entries past it; 0 = unlimited. Default from
    /// `GENIE_CACHE_HOT_BYTES`
    pub cache_hot_bytes: u64,
    /// storage backend (`cache.backend=local|shared-dir`): `shared-dir`
    /// stacks a tier-2 shared directory pool under the local dir.
    /// Default from `GENIE_CACHE_BACKEND` (the CI matrix knob)
    pub cache_backend: String,
    /// the shared pool's directory (`cache.shared_dir`, required when
    /// backend is `shared-dir`). Default from `GENIE_CACHE_SHARED_DIR`
    pub cache_shared_dir: String,
}

impl Default for RunConfig {
    fn default() -> Self {
        RunConfig {
            model: "resnet14".into(),
            artifacts: "artifacts".into(),
            runs_dir: "runs".into(),
            seed: 1234,
            par: Parallelism::default(),
            pretrain: PretrainCfg::default(),
            distill: DistillCfg::default(),
            quant: QuantCfg::default(),
            fsq_samples: 128,
            cache_dir: "cache".into(),
            cache: true,
            resume: false,
            checkpoint_every: 50,
            json: None,
            retry_max: 2,
            retry_backoff_ms: 25,
            sched: Sched::from_env().unwrap_or_default(),
            cache_budget_bytes: env_u64("GENIE_CACHE_BUDGET_BYTES")
                .unwrap_or(0),
            cache_hot_bytes: env_u64("GENIE_CACHE_HOT_BYTES").unwrap_or(0),
            cache_backend: env_str("GENIE_CACHE_BACKEND")
                .unwrap_or_else(|| "local".into()),
            cache_shared_dir: env_str("GENIE_CACHE_SHARED_DIR")
                .unwrap_or_default(),
        }
    }
}

impl RunConfig {
    /// Apply one `key=value` override; nested keys use dots
    /// (e.g. `distill.steps=300`, `quant.lr_v=0.01`, `wbits=2`).
    pub fn set(&mut self, key: &str, value: &str) -> Result<()> {
        macro_rules! p {
            ($t:ty) => {
                value.parse::<$t>().map_err(|e| {
                    anyhow::anyhow!("bad value '{value}' for {key}: {e}")
                })?
            };
        }
        match key {
            "model" => self.model = value.to_string(),
            "artifacts" => self.artifacts = value.to_string(),
            "runs_dir" => self.runs_dir = value.to_string(),
            "seed" => {
                self.seed = p!(u64);
                self.pretrain.seed = self.seed ^ 1;
                self.distill.seed = self.seed ^ 2;
                self.quant.seed = self.seed ^ 3;
            }
            "workers" | "exec.workers" => {
                self.par = Parallelism::new(p!(usize));
                self.distill.par = self.par;
                self.quant.par = self.par;
            }
            "steps_per_dispatch" | "exec.steps_per_dispatch" => {
                let v = p!(usize);
                anyhow::ensure!(
                    v >= 1,
                    "steps_per_dispatch must be >= 1 (1 = unfused)"
                );
                self.pretrain.steps_per_dispatch = v;
                self.distill.steps_per_dispatch = v;
                self.quant.steps_per_dispatch = v;
            }
            "cache_dir" => self.cache_dir = value.to_string(),
            "cache" => self.cache = p!(bool),
            "cache.budget_bytes" => self.cache_budget_bytes = p!(u64),
            "cache.hot_bytes" => self.cache_hot_bytes = p!(u64),
            "cache.backend" => match value {
                "local" | "shared-dir" => {
                    self.cache_backend = value.to_string()
                }
                _ => bail!(
                    "bad value '{value}' for {key}: want local|shared-dir"
                ),
            },
            "cache.shared_dir" => self.cache_shared_dir = value.to_string(),
            "resume" => self.resume = p!(bool),
            "checkpoint_every" | "ckpt.every" => {
                self.checkpoint_every = p!(usize)
            }
            "json" => self.json = Some(value.to_string()),
            "retry.max" | "retries" => {
                let v = p!(u32);
                anyhow::ensure!(
                    v >= 1,
                    "retry.max must be >= 1 (1 = no retries)"
                );
                self.retry_max = v;
            }
            "retry.backoff_ms" => self.retry_backoff_ms = p!(u64),
            "sched" | "exec.sched" => {
                self.sched = Sched::parse(value).ok_or_else(|| {
                    anyhow::anyhow!(
                        "bad value '{value}' for {key}: want wave|dataflow"
                    )
                })?
            }
            "wbits" | "quant.wbits" => {
                self.quant.wbits = validate_bits("wbits", p!(u32))?
            }
            "abits" | "quant.abits" => {
                self.quant.abits = validate_bits("abits", p!(u32))?
            }
            "precision" | "quant.precision" => {
                self.quant.precision.policy = Policy::parse(value)?
            }
            "target_size" | "quant.target_size" => {
                let v = p!(f32);
                anyhow::ensure!(
                    v > 0.0 && v <= 1.0,
                    "target_size must be in (0, 1], got {v}"
                );
                self.quant.precision.target_size = v;
            }
            "first_last_bits" | "quant.first_last_bits" => {
                let v = p!(u32);
                if v != 0 {
                    validate_bits("first_last_bits", v)?;
                }
                self.quant.precision.first_last_bits = v;
            }
            "granularity" | "quant.granularity" => {
                self.quant.precision.granularity = Granularity::parse(value)?
            }
            "sens_batches" | "quant.sens_batches" => {
                let v = p!(usize);
                anyhow::ensure!(v >= 1, "sens_batches must be >= 1");
                self.quant.precision.sens_batches = v;
            }
            "candidates" | "quant.candidates" => {
                let mut cs = Vec::new();
                for part in value.split(',') {
                    let b = part.trim().parse::<u32>().map_err(|e| {
                        anyhow::anyhow!("bad candidate '{part}': {e}")
                    })?;
                    cs.push(validate_bits("candidates", b)?);
                }
                cs.sort_unstable();
                cs.dedup();
                anyhow::ensure!(!cs.is_empty(), "candidates must be non-empty");
                self.quant.precision.candidates = cs;
            }
            "fsq_samples" => self.fsq_samples = p!(usize),
            "pretrain.steps" => self.pretrain.steps = p!(usize),
            "pretrain.lr" => self.pretrain.lr = p!(f32),
            "synthesis" | "distill.engine" => {
                self.distill.engine = Engine::parse(value)?
            }
            "distill.mode" => self.distill.mode = DistillMode::parse(value)?,
            "distill.swing" => self.distill.swing = p!(bool),
            "distill.samples" => self.distill.samples = p!(usize),
            "distill.steps" => self.distill.steps = p!(usize),
            "distill.lr_g" => self.distill.lr_g = p!(f32),
            "distill.lr_z" => self.distill.lr_z = p!(f32),
            "quant.steps" => self.quant.steps_per_block = p!(usize),
            "quant.lr_sw" => self.quant.lr_sw = p!(f32),
            "quant.lr_v" => self.quant.lr_v = p!(f32),
            "quant.lr_sa" => self.quant.lr_sa = p!(f32),
            "quant.lam" => self.quant.lam = p!(f32),
            "quant.drop_p" => self.quant.drop_p = p!(f32),
            "quant.pnorm" => self.quant.pnorm = p!(f32),
            "quant.refresh_student" => self.quant.refresh_student = p!(bool),
            other => bail!("unknown config key '{other}'"),
        }
        Ok(())
    }

    /// Parse a list of `key=value` strings.
    pub fn apply_overrides(&mut self, kvs: &[String]) -> Result<()> {
        for kv in kvs {
            let Some((k, v)) = kv.split_once('=') else {
                bail!("expected key=value, got '{kv}'");
            };
            self.set(k.trim(), v.trim())?;
        }
        Ok(())
    }

    /// Open the artifact cache this config describes, with every tier
    /// knob applied (DESIGN.md §16): checkpoint cadence, tier-0/tier-1
    /// budgets, and the shared tier-2 backend when configured. The one
    /// construction path `genie run`, `genie grid` jobs, and
    /// `genie cache` all share.
    pub fn open_cache(&self) -> Result<ArtifactCache> {
        let mut cache =
            ArtifactCache::open(&self.cache_dir, self.cache, self.resume)?;
        cache.set_checkpoint_every(self.checkpoint_every);
        cache.set_hot_bytes(self.cache_hot_bytes);
        cache.set_budget_bytes(self.cache_budget_bytes);
        if self.cache_backend == "shared-dir" {
            cache.attach_shared(&self.cache_shared_dir)?;
        }
        Ok(cache)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn overrides_apply() {
        let mut c = RunConfig::default();
        c.apply_overrides(&[
            "wbits=2".into(),
            "distill.mode=gba".into(),
            "quant.drop_p=0".into(),
            "distill.swing=false".into(),
        ])
        .unwrap();
        assert_eq!(c.quant.wbits, 2);
        assert_eq!(c.distill.mode, DistillMode::Gba);
        assert_eq!(c.quant.drop_p, 0.0);
        assert!(!c.distill.swing);
    }

    #[test]
    fn synthesis_keys_apply() {
        use crate::synthesis::Engine;
        let mut c = RunConfig::default();
        assert_eq!(c.distill.engine, Engine::Genie);
        c.set("synthesis", "zeroq").unwrap();
        assert_eq!(c.distill.engine, Engine::Zeroq);
        // dotted alias, same field
        c.set("distill.engine", "zaq").unwrap();
        assert_eq!(c.distill.engine, Engine::Zaq);
        c.set("synthesis", "genie").unwrap();
        assert_eq!(c.distill.engine, Engine::Genie);
        assert!(c.set("synthesis", "synq").is_err());
    }

    #[test]
    fn workers_fans_out() {
        let mut c = RunConfig::default();
        c.set("workers", "4").unwrap();
        assert_eq!(c.par, Parallelism::new(4));
        assert_eq!(c.distill.par.workers, 4);
        assert_eq!(c.quant.par.workers, 4);
        c.set("exec.workers", "0").unwrap();
        assert_eq!(c.quant.par.workers, 0); // auto
    }

    #[test]
    fn steps_per_dispatch_fans_out() {
        let mut c = RunConfig::default();
        assert_eq!(c.pretrain.steps_per_dispatch, 1, "default is unfused");
        c.set("steps_per_dispatch", "8").unwrap();
        assert_eq!(c.pretrain.steps_per_dispatch, 8);
        assert_eq!(c.distill.steps_per_dispatch, 8);
        assert_eq!(c.quant.steps_per_dispatch, 8);
        // dotted alias, same fields
        c.set("exec.steps_per_dispatch", "4").unwrap();
        assert_eq!(c.distill.steps_per_dispatch, 4);
        // an execution-shape knob never disables itself to 0
        assert!(c.set("steps_per_dispatch", "0").is_err());
    }

    #[test]
    fn seed_fans_out() {
        let mut c = RunConfig::default();
        c.set("seed", "99").unwrap();
        assert_ne!(c.pretrain.seed, c.distill.seed);
        assert_ne!(c.distill.seed, c.quant.seed);
    }

    #[test]
    fn cache_keys_apply() {
        let mut c = RunConfig::default();
        assert!(c.cache && !c.resume);
        c.apply_overrides(&[
            "cache=false".into(),
            "resume=true".into(),
            "cache_dir=/tmp/x".into(),
            "ckpt.every=25".into(),
        ])
        .unwrap();
        assert!(!c.cache);
        assert!(c.resume);
        assert_eq!(c.cache_dir, "/tmp/x");
        assert_eq!(c.checkpoint_every, 25);
    }

    #[test]
    fn cache_tier_keys_apply() {
        let mut c = RunConfig::default();
        // defaults come from the GENIE_CACHE_* env knobs when set (the
        // CI matrix legs pin them); unset, everything is off/unlimited
        if std::env::var("GENIE_CACHE_BUDGET_BYTES")
            .map_or(true, |v| v.is_empty())
        {
            assert_eq!(c.cache_budget_bytes, 0, "default is unlimited");
        }
        if std::env::var("GENIE_CACHE_BACKEND")
            .map_or(true, |v| v.is_empty())
        {
            assert_eq!(c.cache_backend, "local");
        }
        c.apply_overrides(&[
            "cache.budget_bytes=4096".into(),
            "cache.hot_bytes=1024".into(),
            "cache.backend=shared-dir".into(),
            "cache.shared_dir=/tmp/pool".into(),
        ])
        .unwrap();
        assert_eq!(c.cache_budget_bytes, 4096);
        assert_eq!(c.cache_hot_bytes, 1024);
        assert_eq!(c.cache_backend, "shared-dir");
        assert_eq!(c.cache_shared_dir, "/tmp/pool");
        assert!(c.set("cache.backend", "s3").is_err());
        assert!(c.set("cache.budget_bytes", "lots").is_err());
    }

    #[test]
    fn open_cache_applies_the_tier_knobs() {
        let dir = std::env::temp_dir().join("genie_cfg_open_cache");
        let _ = std::fs::remove_dir_all(&dir);
        let pool = dir.join("pool");
        let mut c = RunConfig::default();
        c.cache_dir = dir.join("local").to_string_lossy().into_owned();
        c.set("cache.backend", "shared-dir").unwrap();
        c.cache_shared_dir = pool.to_string_lossy().into_owned();
        let cache = c.open_cache().unwrap();
        assert!(
            cache.shared_backend().is_some(),
            "shared-dir backend attaches tier 2"
        );
        assert!(pool.is_dir(), "tier-2 pool dir is created");
        // shared-dir without a directory is a config error, not a
        // silent local fallback
        c.cache_shared_dir = String::new();
        assert!(c.open_cache().is_err());
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn retry_keys_apply() {
        let mut c = RunConfig::default();
        assert_eq!(c.retry_max, 2, "default absorbs one transient failure");
        c.apply_overrides(&[
            "retry.max=4".into(),
            "retry.backoff_ms=5".into(),
        ])
        .unwrap();
        assert_eq!(c.retry_max, 4);
        assert_eq!(c.retry_backoff_ms, 5);
        c.set("retries", "1").unwrap();
        assert_eq!(c.retry_max, 1);
        assert!(c.set("retry.max", "0").is_err());
    }

    #[test]
    fn sched_key_applies() {
        let mut c = RunConfig::default();
        // default comes from GENIE_SCHED when set (the CI matrix legs
        // pin it); unset, the work-conserving scheduler is the default
        if std::env::var("GENIE_SCHED").map_or(true, |v| v.is_empty()) {
            assert_eq!(c.sched, Sched::Dataflow);
        }
        c.set("sched", "wave").unwrap();
        assert_eq!(c.sched, Sched::Wave);
        // dotted alias, same field
        c.set("exec.sched", "dataflow").unwrap();
        assert_eq!(c.sched, Sched::Dataflow);
        assert!(c.set("sched", "eager").is_err());
        assert_eq!(Sched::parse("wave").unwrap().as_str(), "wave");
        assert_eq!(Sched::parse("dataflow").unwrap().as_str(), "dataflow");
    }

    #[test]
    fn json_key_applies() {
        let mut c = RunConfig::default();
        assert!(c.json.is_none());
        c.set("json", "out.json").unwrap();
        assert_eq!(c.json.as_deref(), Some("out.json"));
    }

    #[test]
    fn bad_key_rejected() {
        let mut c = RunConfig::default();
        assert!(c.set("nope", "1").is_err());
        assert!(c.apply_overrides(&["garbage".into()]).is_err());
    }

    #[test]
    fn bad_value_rejected() {
        let mut c = RunConfig::default();
        assert!(c.set("wbits", "two").is_err());
    }

    #[test]
    fn degenerate_bit_widths_rejected_at_parse() {
        let mut c = RunConfig::default();
        // 0 would underflow abounds' shift; >8 overflows the export grid
        assert!(c.set("wbits", "0").is_err());
        assert!(c.set("abits", "0").is_err());
        assert!(c.set("wbits", "9").is_err());
        assert!(c.set("abits", "16").is_err());
        c.set("wbits", "2").unwrap();
        c.set("abits", "8").unwrap();
        assert_eq!((c.quant.wbits, c.quant.abits), (2, 8));
        // the first/last pin validates too, but 0 (= disabled) is legal
        assert!(c.set("first_last_bits", "12").is_err());
        c.set("first_last_bits", "0").unwrap();
        assert_eq!(c.quant.precision.first_last_bits, 0);
    }

    #[test]
    fn precision_keys_apply() {
        use crate::precision::{Granularity, Policy};
        let mut c = RunConfig::default();
        assert_eq!(c.quant.precision.policy, Policy::Uniform);
        c.apply_overrides(&[
            "precision=pareto".into(),
            "target_size=0.3".into(),
            "granularity=per_tensor".into(),
            "sens_batches=4".into(),
            "candidates=8,2,4,2".into(),
        ])
        .unwrap();
        assert_eq!(c.quant.precision.policy, Policy::Pareto);
        assert_eq!(c.quant.precision.target_size, 0.3);
        assert_eq!(c.quant.precision.granularity, Granularity::PerTensor);
        assert_eq!(c.quant.precision.sens_batches, 4);
        assert_eq!(c.quant.precision.candidates, vec![2, 4, 8]);
        assert!(c.set("precision", "nope").is_err());
        assert!(c.set("target_size", "0").is_err());
        assert!(c.set("target_size", "1.5").is_err());
        assert!(c.set("sens_batches", "0").is_err());
        assert!(c.set("candidates", "0,4").is_err());
    }
}

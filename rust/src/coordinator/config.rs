//! Run configuration: one struct covering every phase, buildable from
//! `key=value` CLI overrides (std-only; no clap in the offline testbed).
//!
//! Example:
//!   genie zsq --model resnet14 wbits=2 abits=4 workers=8 \
//!       distill.samples=256 distill.mode=genie quant.drop_p=0.5

use anyhow::{bail, Result};

use crate::exec::Parallelism;

use super::{DistillCfg, DistillMode, PretrainCfg, QuantCfg};

#[derive(Debug, Clone)]
pub struct RunConfig {
    pub model: String,
    pub artifacts: String,
    pub runs_dir: String,
    pub seed: u64,
    /// exec worker pool size (`workers=K`, 0 = one per hardware thread);
    /// fanned out into the distill/quant phase configs like `seed`
    pub par: Parallelism,
    pub pretrain: PretrainCfg,
    pub distill: DistillCfg,
    pub quant: QuantCfg,
    /// few-shot calibration sample count (fsq)
    pub fsq_samples: usize,
    /// artifact-cache directory (`--cache-dir`, DESIGN.md §9)
    pub cache_dir: String,
    /// content-addressed artifact caching on/off (`--no-cache` clears it)
    pub cache: bool,
    /// resume interrupted stages from their wip checkpoints (`--resume`)
    pub resume: bool,
    /// steps between mid-phase checkpoint writes (0 = shard-boundary
    /// durability only)
    pub checkpoint_every: usize,
}

impl Default for RunConfig {
    fn default() -> Self {
        RunConfig {
            model: "resnet14".into(),
            artifacts: "artifacts".into(),
            runs_dir: "runs".into(),
            seed: 1234,
            par: Parallelism::default(),
            pretrain: PretrainCfg::default(),
            distill: DistillCfg::default(),
            quant: QuantCfg::default(),
            fsq_samples: 128,
            cache_dir: "cache".into(),
            cache: true,
            resume: false,
            checkpoint_every: 50,
        }
    }
}

impl RunConfig {
    /// Apply one `key=value` override; nested keys use dots
    /// (e.g. `distill.steps=300`, `quant.lr_v=0.01`, `wbits=2`).
    pub fn set(&mut self, key: &str, value: &str) -> Result<()> {
        macro_rules! p {
            ($t:ty) => {
                value.parse::<$t>().map_err(|e| {
                    anyhow::anyhow!("bad value '{value}' for {key}: {e}")
                })?
            };
        }
        match key {
            "model" => self.model = value.to_string(),
            "artifacts" => self.artifacts = value.to_string(),
            "runs_dir" => self.runs_dir = value.to_string(),
            "seed" => {
                self.seed = p!(u64);
                self.pretrain.seed = self.seed ^ 1;
                self.distill.seed = self.seed ^ 2;
                self.quant.seed = self.seed ^ 3;
            }
            "workers" | "exec.workers" => {
                self.par = Parallelism::new(p!(usize));
                self.distill.par = self.par;
                self.quant.par = self.par;
            }
            "cache_dir" => self.cache_dir = value.to_string(),
            "cache" => self.cache = p!(bool),
            "resume" => self.resume = p!(bool),
            "checkpoint_every" | "ckpt.every" => {
                self.checkpoint_every = p!(usize)
            }
            "wbits" | "quant.wbits" => self.quant.wbits = p!(u32),
            "abits" | "quant.abits" => self.quant.abits = p!(u32),
            "fsq_samples" => self.fsq_samples = p!(usize),
            "pretrain.steps" => self.pretrain.steps = p!(usize),
            "pretrain.lr" => self.pretrain.lr = p!(f32),
            "distill.mode" => self.distill.mode = DistillMode::parse(value)?,
            "distill.swing" => self.distill.swing = p!(bool),
            "distill.samples" => self.distill.samples = p!(usize),
            "distill.steps" => self.distill.steps = p!(usize),
            "distill.lr_g" => self.distill.lr_g = p!(f32),
            "distill.lr_z" => self.distill.lr_z = p!(f32),
            "quant.steps" => self.quant.steps_per_block = p!(usize),
            "quant.lr_sw" => self.quant.lr_sw = p!(f32),
            "quant.lr_v" => self.quant.lr_v = p!(f32),
            "quant.lr_sa" => self.quant.lr_sa = p!(f32),
            "quant.lam" => self.quant.lam = p!(f32),
            "quant.drop_p" => self.quant.drop_p = p!(f32),
            "quant.pnorm" => self.quant.pnorm = p!(f32),
            "quant.refresh_student" => self.quant.refresh_student = p!(bool),
            other => bail!("unknown config key '{other}'"),
        }
        Ok(())
    }

    /// Parse a list of `key=value` strings.
    pub fn apply_overrides(&mut self, kvs: &[String]) -> Result<()> {
        for kv in kvs {
            let Some((k, v)) = kv.split_once('=') else {
                bail!("expected key=value, got '{kv}'");
            };
            self.set(k.trim(), v.trim())?;
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn overrides_apply() {
        let mut c = RunConfig::default();
        c.apply_overrides(&[
            "wbits=2".into(),
            "distill.mode=gba".into(),
            "quant.drop_p=0".into(),
            "distill.swing=false".into(),
        ])
        .unwrap();
        assert_eq!(c.quant.wbits, 2);
        assert_eq!(c.distill.mode, DistillMode::Gba);
        assert_eq!(c.quant.drop_p, 0.0);
        assert!(!c.distill.swing);
    }

    #[test]
    fn workers_fans_out() {
        let mut c = RunConfig::default();
        c.set("workers", "4").unwrap();
        assert_eq!(c.par, Parallelism::new(4));
        assert_eq!(c.distill.par.workers, 4);
        assert_eq!(c.quant.par.workers, 4);
        c.set("exec.workers", "0").unwrap();
        assert_eq!(c.quant.par.workers, 0); // auto
    }

    #[test]
    fn seed_fans_out() {
        let mut c = RunConfig::default();
        c.set("seed", "99").unwrap();
        assert_ne!(c.pretrain.seed, c.distill.seed);
        assert_ne!(c.distill.seed, c.quant.seed);
    }

    #[test]
    fn cache_keys_apply() {
        let mut c = RunConfig::default();
        assert!(c.cache && !c.resume);
        c.apply_overrides(&[
            "cache=false".into(),
            "resume=true".into(),
            "cache_dir=/tmp/x".into(),
            "ckpt.every=25".into(),
        ])
        .unwrap();
        assert!(!c.cache);
        assert!(c.resume);
        assert_eq!(c.cache_dir, "/tmp/x");
        assert_eq!(c.checkpoint_every, 25);
    }

    #[test]
    fn bad_key_rejected() {
        let mut c = RunConfig::default();
        assert!(c.set("nope", "1").is_err());
        assert!(c.apply_overrides(&["garbage".into()]).is_err());
    }

    #[test]
    fn bad_value_rejected() {
        let mut c = RunConfig::default();
        assert!(c.set("wbits", "two").is_err());
    }
}

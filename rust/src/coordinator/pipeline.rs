//! End-to-end pipelines:
//!
//!   * [`zsq`] — zero-shot: teacher -> GENIE-D synthetic calibration ->
//!     GENIE-M -> eval (the paper's headline setting).
//!   * [`fsq`] — few-shot: teacher -> real calibration samples ->
//!     GENIE-M -> eval (Table 5).

use anyhow::Result;

use crate::data::Dataset;
use crate::runtime::ModelRt;
use crate::store::Store;
use crate::tensor::{Pcg32, Tensor};

use super::{
    distill, eval_fp32_metered, eval_quantized_metered, eval_quantized_par,
    quantize, DistillCfg, Metrics, QuantCfg,
};

#[derive(Debug, Clone)]
pub struct PipelineOutcome {
    pub model: String,
    pub fp_acc: f32,
    pub q_acc: f32,
    pub distill_secs: f64,
    pub quant_secs: f64,
    pub final_bns_loss: f32,
}

impl PipelineOutcome {
    pub fn print(&self, label: &str) {
        println!(
            "== {label} [{}]: FP32 {:.2}%  quant {:.2}%  (distill {:.0}s, quant {:.0}s)",
            self.model,
            self.fp_acc * 100.0,
            self.q_acc * 100.0,
            self.distill_secs,
            self.quant_secs
        );
    }
}

/// Zero-shot quantization: synthesize calibration data, then quantize.
pub fn zsq(
    mrt: &ModelRt,
    teacher: &Store,
    dataset: &Dataset,
    dcfg: &DistillCfg,
    qcfg: &QuantCfg,
    metrics: &mut Metrics,
) -> Result<PipelineOutcome> {
    let out = distill(mrt, teacher, dcfg, metrics)?;
    let qstate = quantize(mrt, teacher, &out.images, qcfg, metrics)?;
    let fp_acc = eval_fp32_metered(mrt, teacher, dataset, qcfg.par, metrics)?;
    let q_acc = eval_quantized_metered(
        mrt, teacher, &qstate, dataset, qcfg.par, metrics,
    )?;
    Ok(PipelineOutcome {
        model: mrt.manifest.model.clone(),
        fp_acc,
        q_acc,
        distill_secs: metrics.timer_total("distill"),
        quant_secs: metrics.timer_total("quantize"),
        final_bns_loss: out.final_loss,
    })
}

/// Few-shot quantization on real calibration samples (Table 5 setting).
pub fn fsq(
    mrt: &ModelRt,
    teacher: &Store,
    dataset: &Dataset,
    samples: usize,
    qcfg: &QuantCfg,
    metrics: &mut Metrics,
) -> Result<PipelineOutcome> {
    let mut rng = Pcg32::new(qcfg.seed ^ 0x5eed);
    let (calib, _) = dataset.calibration(&mut rng, samples);
    let qstate = quantize(mrt, teacher, &calib, qcfg, metrics)?;
    let fp_acc = eval_fp32_metered(mrt, teacher, dataset, qcfg.par, metrics)?;
    let q_acc = eval_quantized_metered(
        mrt, teacher, &qstate, dataset, qcfg.par, metrics,
    )?;
    Ok(PipelineOutcome {
        model: mrt.manifest.model.clone(),
        fp_acc,
        q_acc,
        distill_secs: 0.0,
        quant_secs: metrics.timer_total("quantize"),
        final_bns_loss: f32::NAN,
    })
}

/// Quantize with a provided calibration image tensor (experiment harness).
pub fn quantize_with(
    mrt: &ModelRt,
    teacher: &Store,
    calib: &Tensor,
    dataset: &Dataset,
    qcfg: &QuantCfg,
    metrics: &mut Metrics,
) -> Result<f32> {
    let qstate = quantize(mrt, teacher, calib, qcfg, metrics)?;
    eval_quantized_par(mrt, teacher, &qstate, dataset, qcfg.par)
}

//! End-to-end pipelines, structured as artifact-DAG lookups
//! (DESIGN.md §9):
//!
//!   * [`zsq`] — zero-shot: teacher -> GENIE-D synthetic calibration ->
//!     GENIE-M -> eval (the paper's headline setting).
//!   * [`fsq`] — few-shot: teacher -> real calibration samples ->
//!     GENIE-M -> eval (Table 5).
//!
//! Each stage first consults the [`ArtifactCache`] under its
//! content-addressed key (config fields + upstream content hashes); a hit
//! loads the GTS1 artifact instead of re-running the stage, a miss runs
//! the stage — resumably, through the phase engine's checkpoints — and
//! stores the artifact. Pass [`ArtifactCache::disabled`] to opt out.

use anyhow::Result;

use crate::artifacts::{self, ArtifactCache, CacheStats};
use crate::data::Dataset;
use crate::phase::checkpoint;
use crate::precision::{Policy, PrecisionPlan};
use crate::runtime::json::Json;
use crate::runtime::ModelRt;
use crate::store::Store;
use crate::tensor::{Pcg32, Tensor};

use super::{
    distill_ck, eval_fp32_metered, eval_quantized_metered, eval_quantized_par,
    quantize, quantize_planned, resolve_plan, DistillCfg, DistillOutput,
    Metrics, QuantCfg,
};

#[derive(Debug, Clone)]
pub struct PipelineOutcome {
    pub model: String,
    pub fp_acc: f32,
    pub q_acc: f32,
    /// Wall-clock of the synthesis stage; `None` when no synthesis ran
    /// (fsq quantizes real samples).
    pub distill_secs: Option<f64>,
    pub quant_secs: f64,
    /// Final BNS loss of the synthesis; `None` when no synthesis ran.
    pub final_bns_loss: Option<f32>,
    /// FP32 weight payload of the quantized layers, in bits.
    pub fp_weight_bits: u64,
    /// Weight payload under the resolved precision plan, in bits.
    pub q_weight_bits: u64,
}

impl PipelineOutcome {
    /// Seconds cell for tables/prints; "—" when the stage didn't run.
    pub fn distill_secs_cell(&self) -> String {
        self.distill_secs
            .map(|s| format!("{s:.0}"))
            .unwrap_or_else(|| "—".into())
    }

    /// BNS-loss cell for tables/prints; "—" when no synthesis ran.
    pub fn bns_cell(&self) -> String {
        self.final_bns_loss
            .map(|l| format!("{l:.3}"))
            .unwrap_or_else(|| "—".into())
    }

    pub fn print(&self, label: &str) {
        crate::progress!(
            "== {label} [{}]: FP32 {:.2}%  quant {:.2}%  \
             (distill {}s, quant {:.0}s, BNS {})",
            self.model,
            self.fp_acc * 100.0,
            self.q_acc * 100.0,
            self.distill_secs_cell(),
            self.quant_secs,
            self.bns_cell(),
        );
    }

    /// Machine-readable outcome for `genie run --json` / `genie grid
    /// --json` (DESIGN.md §11): `Option` fields serialize as `null`,
    /// cache counters ride along when the caller has them.
    pub fn to_json(&self, cache: Option<&CacheStats>) -> Json {
        let mut pairs = vec![
            ("model", Json::Str(self.model.clone())),
            ("fp_top1", Json::num(self.fp_acc as f64)),
            ("q_top1", Json::num(self.q_acc as f64)),
            ("distill_secs", Json::opt(self.distill_secs)),
            ("quant_secs", Json::num(self.quant_secs)),
            (
                "final_bns_loss",
                Json::opt(self.final_bns_loss.map(|x| x as f64)),
            ),
            (
                "fp_weight_kib",
                Json::num(self.fp_weight_bits as f64 / 8.0 / 1024.0),
            ),
            (
                "q_weight_kib",
                Json::num(self.q_weight_bits as f64 / 8.0 / 1024.0),
            ),
        ];
        if let Some(s) = cache {
            pairs.push((
                "cache",
                Json::obj(vec![
                    ("hits", Json::num(s.hits as f64)),
                    ("misses", Json::num(s.misses as f64)),
                    ("stores", Json::num(s.stores as f64)),
                    ("hot_hits", Json::num(s.hot_hits as f64)),
                    ("disk_hits", Json::num(s.disk_hits as f64)),
                    ("shared_hits", Json::num(s.shared_hits as f64)),
                    ("hot_evictions", Json::num(s.hot_evictions as f64)),
                    ("gc_evictions", Json::num(s.gc_evictions as f64)),
                    ("quarantined", Json::num(s.quarantined as f64)),
                ]),
            ));
        }
        Json::obj(pairs)
    }
}

/// Cache-aware GENIE-D: load the synthetic-calibration artifact keyed by
/// (manifest, distill config, teacher content), or synthesize — resumably
/// — and store it (images + loss trace + final loss).
pub fn distill_cached(
    mrt: &ModelRt,
    teacher: &Store,
    dcfg: &DistillCfg,
    cache: &mut ArtifactCache,
    metrics: &mut Metrics,
) -> Result<DistillOutput> {
    distill_cached_keyed(mrt, teacher, teacher.content_hash(), dcfg, cache, metrics)
}

/// [`distill_cached`] with the teacher's content hash precomputed — the
/// pipelines hash the teacher once and share the hash across every stage
/// key of the run.
pub fn distill_cached_keyed(
    mrt: &ModelRt,
    teacher: &Store,
    teacher_hash: u64,
    dcfg: &DistillCfg,
    cache: &mut ArtifactCache,
    metrics: &mut Metrics,
) -> Result<DistillOutput> {
    let key = artifacts::distill_key(&mrt.manifest, dcfg, teacher_hash);
    // claim first (DESIGN.md §11): a concurrent run synthesizing the
    // same set holds the lock; when it releases, the lookup below hits
    let _claim = cache.claim("distill", key)?;
    // a parseable artifact missing any of its pieces is a miss, not an
    // error: recompute and rewrite, matching the dry-run prediction
    let coherent = |a: &Store| {
        a.get("images").is_ok()
            && a.get("final_loss").is_ok()
            && checkpoint::trace_from_store(a, "trace").is_ok()
    };
    if let Some(art) = cache.load_checked("distill", key, coherent) {
        metrics.record_cache("distill", true);
        crate::progress!(
            "distill[{}]: cache hit ({})",
            mrt.manifest.model,
            key.hex()
        );
        return Ok(DistillOutput {
            images: art.get("images")?.clone(),
            loss_trace: checkpoint::trace_from_store(&art, "trace")?,
            final_loss: art.get("final_loss")?.scalar(),
        });
    }
    metrics.record_cache("distill", false);
    let ck = cache.stage_ckpt("distill", key);
    let out = distill_ck(mrt, teacher, dcfg, ck.as_ref(), metrics)?;
    let mut art = Store::new();
    art.insert("images", out.images.clone());
    art.insert("final_loss", Tensor::scalar_f32(out.final_loss));
    checkpoint::trace_to_store(&mut art, "trace", &out.loss_trace);
    cache.store("distill", key, &art)?;
    Ok(out)
}

/// Cache-aware GENIE-M: load the qstate artifact keyed by (manifest,
/// quant config, teacher content, calibration content), or reconstruct —
/// resumably — and store it.
pub fn quantize_cached(
    mrt: &ModelRt,
    teacher: &Store,
    calib: &Tensor,
    qcfg: &QuantCfg,
    cache: &mut ArtifactCache,
    metrics: &mut Metrics,
) -> Result<Store> {
    quantize_cached_keyed(
        mrt,
        teacher,
        teacher.content_hash(),
        calib,
        qcfg,
        cache,
        metrics,
    )
}

/// Cache-aware precision-plan resolution (DESIGN.md §10). Uniform plans
/// are derived config — dispatch-free — so they never touch the cache;
/// a Pareto plan (one sensitivity sweep over the calibration set) is a
/// proper DAG node keyed by every plan-shaping knob plus the teacher and
/// calibration content, stored via the plan's GTS1 round-trip.
pub fn plan_cached(
    mrt: &ModelRt,
    teacher: &Store,
    teacher_hash: u64,
    calib: &Tensor,
    qcfg: &QuantCfg,
    cache: &mut ArtifactCache,
    metrics: &mut Metrics,
) -> Result<PrecisionPlan> {
    if qcfg.precision.policy == Policy::Uniform {
        return resolve_plan(mrt, teacher, calib, qcfg, metrics);
    }
    let key = artifacts::plan_key(&mrt.manifest, qcfg, teacher_hash, calib);
    let _claim = cache.claim("plan", key)?;
    if let Some(plan) = cache
        .load("plan", key)
        .and_then(|s| PrecisionPlan::from_store(&mrt.manifest, &s).ok())
    {
        metrics.record_cache("plan", true);
        crate::progress!(
            "plan[{}]: cache hit ({})",
            mrt.manifest.model,
            key.hex()
        );
        return Ok(plan);
    }
    metrics.record_cache("plan", false);
    let plan = resolve_plan(mrt, teacher, calib, qcfg, metrics)?;
    cache.store("plan", key, &plan.to_store())?;
    Ok(plan)
}

/// [`quantize_cached`] with the teacher's content hash precomputed.
/// Resolves the precision plan first (a cache lookup for Pareto runs);
/// the qstate key then folds the resolved plan in, so a changed plan is
/// a changed artifact.
pub fn quantize_cached_keyed(
    mrt: &ModelRt,
    teacher: &Store,
    teacher_hash: u64,
    calib: &Tensor,
    qcfg: &QuantCfg,
    cache: &mut ArtifactCache,
    metrics: &mut Metrics,
) -> Result<Store> {
    let plan =
        plan_cached(mrt, teacher, teacher_hash, calib, qcfg, cache, metrics)?;
    quantize_cached_planned(
        mrt, teacher, teacher_hash, calib, qcfg, &plan, cache, metrics,
    )
}

/// [`quantize_cached_keyed`] under an already-resolved plan — the grid
/// executor and the pipelines resolve the plan once (to report payload
/// sizes) and quantize under it.
#[allow(clippy::too_many_arguments)]
pub fn quantize_cached_planned(
    mrt: &ModelRt,
    teacher: &Store,
    teacher_hash: u64,
    calib: &Tensor,
    qcfg: &QuantCfg,
    plan: &PrecisionPlan,
    cache: &mut ArtifactCache,
    metrics: &mut Metrics,
) -> Result<Store> {
    let key = artifacts::quantize_key(
        &mrt.manifest,
        qcfg,
        teacher_hash,
        calib,
        plan,
    );
    let _claim = cache.claim("qstate", key)?;
    if let Some(qstate) = cache.load("qstate", key) {
        metrics.record_cache("qstate", true);
        crate::progress!(
            "quantize[{}]: cache hit ({})",
            mrt.manifest.model,
            key.hex()
        );
        // tier 0 hands out a shared handle; this API returns an owned
        // Store, which is a cheap COW clone (Arc-backed tensor maps)
        return Ok((*qstate).clone());
    }
    metrics.record_cache("qstate", false);
    let ck = cache.stage_ckpt("qstate", key);
    let qstate = quantize_planned(
        mrt, teacher, calib, qcfg, plan, ck.as_ref(), metrics,
    )?;
    cache.store("qstate", key, &qstate)?;
    Ok(qstate)
}

/// Zero-shot quantization: synthesize calibration data, then quantize —
/// each stage a cache lookup first.
pub fn zsq(
    mrt: &ModelRt,
    teacher: &Store,
    dataset: &Dataset,
    dcfg: &DistillCfg,
    qcfg: &QuantCfg,
    cache: &mut ArtifactCache,
    metrics: &mut Metrics,
) -> Result<PipelineOutcome> {
    // one content hash serves both stage keys of the run
    let teacher_hash = teacher.content_hash();
    let out =
        distill_cached_keyed(mrt, teacher, teacher_hash, dcfg, cache, metrics)?;
    let plan = plan_cached(
        mrt, teacher, teacher_hash, &out.images, qcfg, cache, metrics,
    )?;
    let qstate = quantize_cached_planned(
        mrt, teacher, teacher_hash, &out.images, qcfg, &plan, cache, metrics,
    )?;
    let fp_acc = eval_fp32_metered(mrt, teacher, dataset, qcfg.par, metrics)?;
    let q_acc = eval_quantized_metered(
        mrt, teacher, &qstate, dataset, qcfg.par, metrics,
    )?;
    metrics.record_cache_tiers(cache.stats(), cache.tier_bytes());
    Ok(PipelineOutcome {
        model: mrt.manifest.model.clone(),
        fp_acc,
        q_acc,
        distill_secs: Some(metrics.timer_total("distill")),
        quant_secs: metrics.timer_total("quantize"),
        final_bns_loss: Some(out.final_loss),
        fp_weight_bits: PrecisionPlan::fp32_bits(&mrt.manifest) as u64,
        q_weight_bits: plan.payload_bits(&mrt.manifest) as u64,
    })
}

/// Few-shot quantization on real calibration samples (Table 5 setting).
/// No synthesis runs, so the distill fields of the outcome are `None`.
pub fn fsq(
    mrt: &ModelRt,
    teacher: &Store,
    dataset: &Dataset,
    samples: usize,
    qcfg: &QuantCfg,
    cache: &mut ArtifactCache,
    metrics: &mut Metrics,
) -> Result<PipelineOutcome> {
    let mut rng = Pcg32::new(qcfg.seed ^ 0x5eed);
    let (calib, _) = dataset.calibration(&mut rng, samples);
    let teacher_hash = teacher.content_hash();
    let plan =
        plan_cached(mrt, teacher, teacher_hash, &calib, qcfg, cache, metrics)?;
    let qstate = quantize_cached_planned(
        mrt, teacher, teacher_hash, &calib, qcfg, &plan, cache, metrics,
    )?;
    let fp_acc = eval_fp32_metered(mrt, teacher, dataset, qcfg.par, metrics)?;
    let q_acc = eval_quantized_metered(
        mrt, teacher, &qstate, dataset, qcfg.par, metrics,
    )?;
    metrics.record_cache_tiers(cache.stats(), cache.tier_bytes());
    Ok(PipelineOutcome {
        model: mrt.manifest.model.clone(),
        fp_acc,
        q_acc,
        distill_secs: None,
        quant_secs: metrics.timer_total("quantize"),
        final_bns_loss: None,
        fp_weight_bits: PrecisionPlan::fp32_bits(&mrt.manifest) as u64,
        q_weight_bits: plan.payload_bits(&mrt.manifest) as u64,
    })
}

/// Quantize with a provided calibration image tensor (experiment harness).
pub fn quantize_with(
    mrt: &ModelRt,
    teacher: &Store,
    calib: &Tensor,
    dataset: &Dataset,
    qcfg: &QuantCfg,
    metrics: &mut Metrics,
) -> Result<f32> {
    let qstate = quantize(mrt, teacher, calib, qcfg, metrics)?;
    eval_quantized_par(mrt, teacher, &qstate, dataset, qcfg.par)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn outcome_renders_dashes_for_missing_stages() {
        let out = PipelineOutcome {
            model: "toy".into(),
            fp_acc: 0.9,
            q_acc: 0.8,
            distill_secs: None,
            quant_secs: 3.0,
            final_bns_loss: None,
            fp_weight_bits: 32 * 1024,
            q_weight_bits: 4 * 1024,
        };
        assert_eq!(out.distill_secs_cell(), "—");
        assert_eq!(out.bns_cell(), "—");
        let full = PipelineOutcome {
            distill_secs: Some(12.4),
            final_bns_loss: Some(0.1234),
            ..out
        };
        assert_eq!(full.distill_secs_cell(), "12");
        assert_eq!(full.bns_cell(), "0.123");
    }

    #[test]
    fn outcome_json_serializes_options_as_null() {
        let out = PipelineOutcome {
            model: "toy".into(),
            fp_acc: 0.5,
            q_acc: 0.25,
            distill_secs: None,
            quant_secs: 3.0,
            final_bns_loss: None,
            fp_weight_bits: 8 * 8 * 1024,
            q_weight_bits: 8 * 1024,
        };
        let text = out.to_json(None).render();
        assert!(text.contains("\"distill_secs\":null"), "{text}");
        assert!(text.contains("\"final_bns_loss\":null"), "{text}");
        assert!(text.contains("\"model\":\"toy\""), "{text}");
        assert!(text.contains("\"fp_weight_kib\":8"), "{text}");
        assert!(!text.contains("cache"), "{text}");
        // round-trips through the parser
        assert!(Json::parse(&text).is_ok());

        let stats = CacheStats {
            hits: 2,
            misses: 1,
            stores: 1,
            hot_hits: 1,
            disk_hits: 1,
            ..Default::default()
        };
        let with_cache = PipelineOutcome {
            distill_secs: Some(1.5),
            final_bns_loss: Some(0.25),
            ..out
        }
        .to_json(Some(&stats))
        .render();
        assert!(with_cache.contains("\"distill_secs\":1.5"), "{with_cache}");
        assert!(with_cache.contains("\"hits\":2"), "{with_cache}");
        assert!(with_cache.contains("\"hot_hits\":1"), "{with_cache}");
        assert!(with_cache.contains("\"gc_evictions\":0"), "{with_cache}");
    }
}

//! Parallel execution engine (DESIGN.md §5): a work-stealing worker pool
//! over std threads, shard-keyed deterministic RNG streams, and a
//! topological wave scheduler for dependent block graphs.
//!
//! The coordinator phases are embarrassingly parallel at two levels:
//! GENIE-D distills independent latent shards (one generator per batch,
//! appendix A of the paper), and GENIE-M reconstructs quantization
//! parameters block-by-block, where every block is independent given the
//! teacher's boundary activations. This module provides the shared
//! machinery; `coordinator::{distill, quantize, evaluate}` submit jobs.
//!
//! Reproducibility contract: a job's randomness may only come from a
//! [`Pcg32`](crate::tensor::Pcg32) stream keyed by `(seed, shard)` via
//! `Pcg32::new_stream`, never from the worker id or execution order.
//! Results are returned in submission order. Together these make every
//! parallel phase bit-identical for any worker count — `workers=4`
//! reproduces `workers=1` exactly (tested in `tests/exec.rs` and, over
//! real artifacts, in `tests/integration.rs`).

pub mod dag;
pub mod pool;
pub mod schedule;

pub use dag::{critical_path, run_dag, DagNode, DagReport};
pub use pool::{panic_message, run_jobs, PoolReport};
pub use schedule::{chain_deps, independent_deps, waves};

/// Grid scheduler selection (DESIGN.md §15): `Wave` is the barriered
/// reference implementation, `Dataflow` the work-conserving ready-queue
/// scheduler. Both are bit-identical in outputs; they differ only in
/// wall-clock shape.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub enum Sched {
    /// Topological waves with a full barrier between ranks.
    Wave,
    /// Dependency-counting ready queue, critical-path-first dispatch.
    #[default]
    Dataflow,
}

impl Sched {
    /// Parse a config/env value (`wave` | `dataflow`).
    pub fn parse(s: &str) -> Option<Sched> {
        match s {
            "wave" => Some(Sched::Wave),
            "dataflow" => Some(Sched::Dataflow),
            _ => None,
        }
    }

    pub fn as_str(&self) -> &'static str {
        match self {
            Sched::Wave => "wave",
            Sched::Dataflow => "dataflow",
        }
    }

    /// Scheduler pinned by `GENIE_SCHED` (the CI matrix knob), or `None`
    /// when unset/empty. Panics on an unrecognized value — a typo'd CI
    /// leg should fail loudly, not silently test the default.
    pub fn from_env() -> Option<Sched> {
        match std::env::var("GENIE_SCHED") {
            Ok(v) if v.is_empty() => None,
            Ok(v) => Some(Sched::parse(&v).unwrap_or_else(|| {
                panic!("GENIE_SCHED must be wave|dataflow, got {v:?}")
            })),
            Err(_) => None,
        }
    }
}

/// Worker-count configuration, threaded from the CLI (`workers=K`)
/// through [`RunConfig`](crate::coordinator::RunConfig) into every
/// parallel phase. `0` means auto-detect.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct Parallelism {
    /// Number of workers; 0 = one per available hardware thread.
    pub workers: usize,
}

impl Parallelism {
    /// Explicit worker count (`Parallelism::new(0)` = auto).
    pub fn new(workers: usize) -> Self {
        Parallelism { workers }
    }

    /// Single-worker (serial) execution.
    pub const SERIAL: Parallelism = Parallelism { workers: 1 };

    /// The concrete worker count: the configured value, or the hardware
    /// thread count when auto.
    pub fn resolve(&self) -> usize {
        if self.workers > 0 {
            self.workers
        } else {
            std::thread::available_parallelism()
                .map(|n| n.get())
                .unwrap_or(1)
        }
    }

    /// Worker count clamped to the number of jobs (never spawn idle
    /// workers for a short fan-out).
    pub fn resolve_for(&self, jobs: usize) -> usize {
        self.resolve().min(jobs.max(1))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn explicit_workers_win() {
        assert_eq!(Parallelism::new(3).resolve(), 3);
        assert_eq!(Parallelism::SERIAL.resolve(), 1);
    }

    #[test]
    fn auto_resolves_positive() {
        assert!(Parallelism::default().resolve() >= 1);
    }

    #[test]
    fn resolve_for_clamps_to_jobs() {
        assert_eq!(Parallelism::new(8).resolve_for(3), 3);
        assert_eq!(Parallelism::new(2).resolve_for(100), 2);
        // zero jobs still yields one worker (which then finds no work)
        assert_eq!(Parallelism::new(8).resolve_for(0), 1);
    }
}

//! Topological wave scheduler for dependent job graphs (DESIGN.md §5).
//!
//! GENIE-M's block reconstruction is a dependency graph: with
//! `refresh_student` on, block b reads activations from the quantized
//! prefix, so b depends on b-1 (a chain); with it off, every block is
//! independent given the teacher's boundary activations. [`waves`] turns
//! any such DAG into an ordered list of waves — within a wave, jobs are
//! mutually independent and run concurrently on the pool; between waves
//! there is a barrier where results merge back into shared state.

/// Dependency list of a sequential chain: job i depends on job i-1.
pub fn chain_deps(n: usize) -> Vec<Vec<usize>> {
    (0..n).map(|i| if i == 0 { vec![] } else { vec![i - 1] }).collect()
}

/// Dependency list of n fully independent jobs.
pub fn independent_deps(n: usize) -> Vec<Vec<usize>> {
    vec![Vec::new(); n]
}

/// Partition jobs into topological waves. `deps[i]` lists the jobs that
/// must complete before job i may start. Wave k holds every job whose
/// dependencies are all in waves < k, in ascending index order (a
/// deterministic schedule). Panics on a dependency cycle or an
/// out-of-range dependency — both are programmer errors in the graph
/// construction, not runtime conditions.
pub fn waves(deps: &[Vec<usize>]) -> Vec<Vec<usize>> {
    let n = deps.len();
    for (i, ds) in deps.iter().enumerate() {
        for &d in ds {
            assert!(d < n, "waves: job {i} depends on out-of-range {d}");
        }
    }
    let mut done = vec![false; n];
    let mut out: Vec<Vec<usize>> = Vec::new();
    let mut placed = 0;
    while placed < n {
        let wave: Vec<usize> = (0..n)
            .filter(|&i| !done[i] && deps[i].iter().all(|&d| done[d]))
            .collect();
        assert!(!wave.is_empty(), "waves: dependency cycle");
        for &i in &wave {
            done[i] = true;
        }
        placed += wave.len();
        out.push(wave);
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn chain_is_singleton_waves() {
        let w = waves(&chain_deps(4));
        assert_eq!(w, vec![vec![0], vec![1], vec![2], vec![3]]);
    }

    #[test]
    fn independent_is_one_wave() {
        let w = waves(&independent_deps(5));
        assert_eq!(w, vec![vec![0, 1, 2, 3, 4]]);
    }

    #[test]
    fn diamond_gates_on_both_parents() {
        // 0 -> {1, 2} -> 3
        let deps = vec![vec![], vec![0], vec![0], vec![1, 2]];
        let w = waves(&deps);
        assert_eq!(w, vec![vec![0], vec![1, 2], vec![3]]);
    }

    #[test]
    fn empty_graph_is_no_waves() {
        assert!(waves(&[]).is_empty());
    }

    #[test]
    #[should_panic(expected = "cycle")]
    fn cycle_panics() {
        waves(&[vec![1], vec![0]]);
    }

    #[test]
    #[should_panic(expected = "out-of-range")]
    fn bad_dep_panics() {
        waves(&[vec![7]]);
    }
}

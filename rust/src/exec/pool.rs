//! Work-stealing worker pool over scoped std threads (DESIGN.md §5).
//!
//! Jobs are enqueued round-robin into per-worker deques before any worker
//! starts; a worker pops from the front of its own deque and, when that
//! runs dry, steals from the back of a victim's. Nothing is enqueued after
//! startup, so a worker that observes every deque empty can exit — the
//! remaining in-flight jobs are already owned by other workers.
//!
//! Determinism: results land in a slot indexed by submission order, so the
//! output `Vec` is independent of which worker ran which job and of any
//! interleaving. Combined with shard-keyed RNG streams
//! ([`Pcg32::new_stream`](crate::tensor::Pcg32::new_stream)) inside the
//! jobs, every parallel phase is bit-identical for any worker count.
//!
//! Fault containment (DESIGN.md §13): a panicking job is caught via
//! `catch_unwind` and converted into a deterministic per-job-index error
//! instead of killing the pool — sibling jobs complete, their unwinding
//! destructors (claim lockfiles, device handles) run, and the error the
//! caller sees is always the *lowest-index* failure regardless of which
//! worker hit it first or in what order jobs finished.

use std::collections::VecDeque;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Mutex;
use std::time::Instant;

use anyhow::Result;

use super::Parallelism;

/// Best-effort human-readable payload of a caught panic.
pub fn panic_message(p: &(dyn std::any::Any + Send)) -> String {
    p.downcast_ref::<&str>()
        .map(|s| s.to_string())
        .or_else(|| p.downcast_ref::<String>().cloned())
        .unwrap_or_else(|| "non-string panic payload".to_string())
}

/// Run one job with panic containment: a panic becomes a deterministic
/// `Err` naming the job index, so the pool (and its caller) survive.
/// The flag reports whether the job panicked (for [`PoolReport::panics`]).
fn run_caught<T>(
    idx: usize,
    f: impl FnOnce() -> Result<T>,
) -> (Result<T>, bool) {
    match std::panic::catch_unwind(std::panic::AssertUnwindSafe(f)) {
        Ok(r) => (r, false),
        Err(p) => (
            Err(anyhow::anyhow!(
                "job {idx} panicked: {}",
                panic_message(p.as_ref())
            )),
            true,
        ),
    }
}

/// Poison-proof lock: a mutex poisoned by a panicking thread still
/// guards valid data here (slots hold plain `Option`s, deques plain
/// jobs), so recover the guard instead of propagating the poison.
pub(crate) fn lock_clean<T>(m: &Mutex<T>) -> std::sync::MutexGuard<'_, T> {
    m.lock().unwrap_or_else(|p| p.into_inner())
}

/// Per-run accounting: wall clock, per-worker busy time and job counts,
/// and the number of steals. Feeds
/// [`Metrics::record_pool`](crate::coordinator::Metrics::record_pool).
#[derive(Debug, Clone, Default)]
pub struct PoolReport {
    /// Workers actually spawned (after clamping to the job count).
    pub workers: usize,
    /// Jobs executed.
    pub jobs: usize,
    /// Wall-clock seconds for the whole fan-out.
    pub wall_secs: f64,
    /// Busy seconds per worker (index = worker id).
    pub worker_busy_secs: Vec<f64>,
    /// Jobs executed per worker (index = worker id).
    pub worker_jobs: Vec<usize>,
    /// Cross-deque steals (0 in serial runs).
    pub steals: usize,
    /// Jobs that panicked (caught and converted to per-index errors).
    pub panics: usize,
}

impl PoolReport {
    /// Ratio of summed busy time to `workers * wall` — 1.0 means no
    /// worker ever idled.
    pub fn utilization(&self) -> f64 {
        if self.workers == 0 || self.wall_secs <= 0.0 {
            return 0.0;
        }
        self.worker_busy_secs.iter().sum::<f64>()
            / (self.workers as f64 * self.wall_secs)
    }

    /// Fold another run into this one — used by wave-gated phases
    /// (quantize) to report one aggregate per phase instead of one row
    /// per wave. Wall time and jobs add; per-worker vectors add
    /// index-wise (a singleton wave only touches worker 0).
    pub fn merge(&mut self, other: &PoolReport) {
        self.workers = self.workers.max(other.workers);
        self.jobs += other.jobs;
        self.wall_secs += other.wall_secs;
        self.steals += other.steals;
        self.panics += other.panics;
        if self.worker_busy_secs.len() < other.worker_busy_secs.len() {
            self.worker_busy_secs.resize(other.worker_busy_secs.len(), 0.0);
            self.worker_jobs.resize(other.worker_jobs.len(), 0);
        }
        for (w, secs) in other.worker_busy_secs.iter().enumerate() {
            self.worker_busy_secs[w] += secs;
        }
        for (w, count) in other.worker_jobs.iter().enumerate() {
            self.worker_jobs[w] += count;
        }
    }
}

/// Run every job, returning results in submission order plus the pool
/// report. Jobs run on `par.resolve_for(jobs.len())` workers; a single
/// worker short-circuits to an in-thread loop (no spawn overhead).
///
/// Failure contract: a panicking job is caught and converted to an error
/// naming its index (the pool always survives), and the error returned
/// is the **lowest-submission-index** failure regardless of worker
/// count, steal pattern, or completion order — serial runs stop at the
/// first (= lowest-index) failure, parallel runs complete every job and
/// pick the lowest-index `Err` slot. Sibling results are dropped.
pub fn run_jobs<T, F>(par: Parallelism, jobs: Vec<F>) -> Result<(Vec<T>, PoolReport)>
where
    T: Send,
    F: FnOnce() -> Result<T> + Send,
{
    let n = jobs.len();
    let workers = par.resolve_for(n);
    let t0 = Instant::now();

    if workers <= 1 {
        let mut busy = 0.0;
        let mut out = Vec::with_capacity(n);
        let mut first_err = None;
        let mut panics = 0usize;
        let mut ran = 0usize;
        for (i, job) in jobs.into_iter().enumerate() {
            let tj = Instant::now();
            let (r, panicked) = run_caught(i, job);
            busy += tj.elapsed().as_secs_f64();
            panics += panicked as usize;
            ran += 1;
            match r {
                Ok(v) => out.push(v),
                Err(e) => {
                    first_err = Some(e);
                    break;
                }
            }
        }
        let report = PoolReport {
            workers: 1,
            jobs: n,
            wall_secs: t0.elapsed().as_secs_f64(),
            worker_busy_secs: vec![busy],
            worker_jobs: vec![ran],
            steals: 0,
            panics,
        };
        return match first_err {
            Some(e) => Err(e),
            None => Ok((out, report)),
        };
    }

    // Round-robin the (index, job) pairs into per-worker deques.
    let mut local: Vec<VecDeque<(usize, F)>> =
        (0..workers).map(|_| VecDeque::new()).collect();
    for (i, job) in jobs.into_iter().enumerate() {
        local[i % workers].push_back((i, job));
    }
    let deques: Vec<Mutex<VecDeque<(usize, F)>>> =
        local.into_iter().map(Mutex::new).collect();
    let slots: Vec<Mutex<Option<Result<T>>>> =
        (0..n).map(|_| Mutex::new(None)).collect();
    let steals = AtomicUsize::new(0);
    let panics = AtomicUsize::new(0);

    let mut worker_busy_secs = vec![0.0; workers];
    let mut worker_jobs = vec![0; workers];
    std::thread::scope(|s| {
        let handles: Vec<_> = (0..workers)
            .map(|w| {
                let deques = &deques;
                let slots = &slots;
                let steals = &steals;
                let panics = &panics;
                s.spawn(move || {
                    let mut busy = 0.0f64;
                    let mut count = 0usize;
                    loop {
                        // own queue first (front = submission order) ...
                        let mut job = lock_clean(&deques[w]).pop_front();
                        // ... then steal from a victim's back
                        if job.is_none() {
                            for k in 1..deques.len() {
                                let v = (w + k) % deques.len();
                                job = lock_clean(&deques[v]).pop_back();
                                if job.is_some() {
                                    steals.fetch_add(1, Ordering::Relaxed);
                                    break;
                                }
                            }
                        }
                        // deques only drain after startup: all-empty is
                        // final, so exiting here never strands a job.
                        let Some((idx, f)) = job else { break };
                        let tj = Instant::now();
                        // panic containment: the job's unwind stops
                        // here, its error lands in the slot like any
                        // other failure, and the worker keeps draining.
                        let (r, panicked) = run_caught(idx, f);
                        busy += tj.elapsed().as_secs_f64();
                        count += 1;
                        if panicked {
                            panics.fetch_add(1, Ordering::Relaxed);
                        }
                        *lock_clean(&slots[idx]) = Some(r);
                    }
                    (busy, count)
                })
            })
            .collect();
        for (w, h) in handles.into_iter().enumerate() {
            // workers never unwind (jobs are caught above); if one does
            // anyway, lose its accounting rather than the whole pool
            let (busy, count) = h.join().unwrap_or((0.0, 0));
            worker_busy_secs[w] = busy;
            worker_jobs[w] = count;
        }
    });

    let report = PoolReport {
        workers,
        jobs: n,
        wall_secs: t0.elapsed().as_secs_f64(),
        worker_busy_secs,
        worker_jobs,
        steals: steals.load(Ordering::Relaxed),
        panics: panics.load(Ordering::Relaxed),
    };
    // drain slots in submission order: the first `Err` seen is by
    // construction the lowest-index failure, whatever order jobs
    // actually completed in
    let mut out = Vec::with_capacity(n);
    for slot in slots {
        match slot.into_inner().unwrap_or_else(|p| p.into_inner()) {
            Some(Ok(v)) => out.push(v),
            Some(Err(e)) => return Err(e),
            None => anyhow::bail!("pool: job never ran (internal error)"),
        }
    }
    Ok((out, report))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn results_in_submission_order() {
        for workers in [1, 2, 4, 8] {
            let jobs: Vec<_> = (0..37usize)
                .map(|i| move || Ok(i * i))
                .collect();
            let (out, report) =
                run_jobs(Parallelism::new(workers), jobs).unwrap();
            assert_eq!(out, (0..37).map(|i| i * i).collect::<Vec<_>>());
            assert_eq!(report.jobs, 37);
            assert_eq!(report.workers, workers.min(37));
            assert_eq!(report.worker_jobs.iter().sum::<usize>(), 37);
        }
    }

    #[test]
    fn empty_job_list_is_fine() {
        let jobs: Vec<fn() -> Result<u8>> = Vec::new();
        let (out, report) = run_jobs(Parallelism::new(4), jobs).unwrap();
        assert!(out.is_empty());
        assert_eq!(report.jobs, 0);
    }

    #[test]
    fn workers_clamped_to_jobs() {
        let jobs: Vec<_> = (0..3usize).map(|i| move || Ok(i)).collect();
        let (_, report) = run_jobs(Parallelism::new(16), jobs).unwrap();
        assert_eq!(report.workers, 3);
    }

    #[test]
    fn errors_propagate_first_by_submission_order() {
        for workers in [1, 4] {
            let jobs: Vec<_> = (0..8usize)
                .map(|i| {
                    move || {
                        if i % 3 == 2 {
                            anyhow::bail!("job {i} failed")
                        }
                        Ok(i)
                    }
                })
                .collect();
            let err = run_jobs::<usize, _>(Parallelism::new(workers), jobs)
                .unwrap_err();
            assert_eq!(format!("{err}"), "job 2 failed");
        }
    }

    #[test]
    fn panics_become_per_index_errors_not_pool_death() {
        for workers in [1, 4] {
            let jobs: Vec<_> = (0..8usize)
                .map(|i| {
                    move || {
                        if i == 5 {
                            panic!("boom {i}");
                        }
                        Ok(i)
                    }
                })
                .collect();
            let err = run_jobs::<usize, _>(Parallelism::new(workers), jobs)
                .unwrap_err();
            assert_eq!(
                format!("{err}"),
                "job 5 panicked: boom 5",
                "workers={workers}"
            );
        }
        // the report still lands when no job fails, and panics count
        let jobs: Vec<_> = (0..4usize).map(|i| move || Ok(i)).collect();
        let (_, report) = run_jobs(Parallelism::new(4), jobs).unwrap();
        assert_eq!(report.panics, 0);
    }

    #[test]
    fn lowest_index_failure_wins_regardless_of_completion_order() {
        // workers=4: job 6 (and a panicking job 2) fail immediately,
        // while job 1 fails only after a delay — the returned error must
        // still be job 1's, the lowest submitted index, every time.
        for _ in 0..3 {
            let jobs: Vec<_> = (0..8usize)
                .map(|i| {
                    move || -> Result<usize> {
                        match i {
                            1 => {
                                std::thread::sleep(
                                    std::time::Duration::from_millis(60),
                                );
                                anyhow::bail!("job 1 failed")
                            }
                            2 => panic!("fast panic"),
                            6 => anyhow::bail!("job 6 failed"),
                            _ => Ok(i),
                        }
                    }
                })
                .collect();
            let err = run_jobs::<usize, _>(Parallelism::new(4), jobs)
                .unwrap_err();
            assert_eq!(format!("{err}"), "job 1 failed");
        }
    }

    #[test]
    fn uneven_jobs_get_stolen() {
        // one long job pinned on worker 0's deque, many short ones behind
        // it; with 4 workers, the short ones must not wait for the long.
        let jobs: Vec<_> = (0..32usize)
            .map(|i| {
                move || {
                    let spins = if i == 0 { 2_000_000u64 } else { 1_000 };
                    let mut acc = 0u64;
                    for k in 0..spins {
                        acc = acc.wrapping_mul(6364136223846793005)
                            .wrapping_add(k);
                    }
                    Ok(std::hint::black_box(acc) as usize ^ i)
                }
            })
            .collect();
        let (out, report) = run_jobs(Parallelism::new(4), jobs).unwrap();
        assert_eq!(out.len(), 32);
        // 32 jobs round-robin over 4 workers = 8 each; worker 0 is busy
        // with the long job, so some of its queue must have been stolen.
        assert!(report.steals > 0, "expected steals, got {report:?}");
    }

    #[test]
    fn merge_accumulates_across_waves() {
        let mut total = PoolReport::default();
        for _ in 0..3 {
            let jobs: Vec<_> = (0..4usize).map(|i| move || Ok(i)).collect();
            let (_, r) = run_jobs(Parallelism::new(2), jobs).unwrap();
            total.merge(&r);
        }
        assert_eq!(total.jobs, 12);
        assert_eq!(total.workers, 2);
        assert_eq!(total.worker_jobs.iter().sum::<usize>(), 12);
        assert_eq!(total.worker_busy_secs.len(), 2);
    }

    #[test]
    fn utilization_bounded() {
        let jobs: Vec<_> = (0..16usize).map(|i| move || Ok(i)).collect();
        let (_, report) = run_jobs(Parallelism::new(4), jobs).unwrap();
        let u = report.utilization();
        assert!((0.0..=1.0 + 1e-9).contains(&u), "utilization {u}");
    }
}

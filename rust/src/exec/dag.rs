//! Dataflow DAG executor (DESIGN.md §15): a dependency-counting ready
//! queue over the exec pool's worker threads, replacing wave barriers
//! with work-conserving scheduling.
//!
//! [`run_dag`] dispatches every node of a dependency graph the moment
//! its in-degree drops to zero: completions decrement their dependents
//! in place, and newly ready nodes enter a priority queue ordered by
//! critical-path length ([`critical_path`]) so the long-pole chain is
//! always draining while short chains fill the remaining workers. A
//! wave scheduler ([`super::waves`]) would barrier after each
//! topological rank — one slow node idles every early finisher; here a
//! worker that finishes a node immediately pulls the highest-priority
//! ready node, whatever rank it belongs to.
//!
//! Determinism contract: `run_dag` affects *scheduling only*. Results
//! come back indexed by node (submission) id, a node's job runs exactly
//! once with the same inputs whatever the interleaving, and skip
//! propagation is a pure function of the dependency lists — so a caller
//! that merges products in node-index order (the grid executor,
//! DESIGN.md §15) is bit-identical to its wave-scheduled self at any
//! worker count.
//!
//! Failure containment mirrors the pool: a panicking job is caught
//! ([`DagNode::Panicked`]) and treated as a failed node — its
//! dependents are never dispatched ([`DagNode::Skipped`], recording the
//! first bad dependency in declaration order), while independent
//! subgraphs keep executing.

use std::collections::BinaryHeap;
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::{Condvar, Mutex};
use std::time::Instant;

use super::pool::{lock_clean, panic_message};
use super::{waves, Parallelism, PoolReport};

/// Self-inclusive longest path (in nodes) from each node to a sink of
/// its dependent subgraph: a sink scores 1, a node scores
/// `1 + max(score of its dependents)`. Used as the ready-queue priority
/// — the node with the longest chain of work hanging off it dispatches
/// first — and reported by `--dry-run` as each node's critical-path
/// depth. The maximum over all nodes equals the DAG's wave count.
///
/// Panics on cycles or out-of-range deps (delegates validation to
/// [`waves`]); programmer error, like the wave scheduler.
pub fn critical_path(deps: &[Vec<usize>]) -> Vec<usize> {
    let by_wave = waves(deps);
    let n = deps.len();
    let mut dependents: Vec<Vec<usize>> = vec![Vec::new(); n];
    for (i, ds) in deps.iter().enumerate() {
        for &d in ds {
            dependents[d].push(i);
        }
    }
    // dependents sit in strictly later waves, so a reverse wave sweep
    // resolves every node's score before its dependencies ask for it
    let mut cp = vec![1usize; n];
    for wave in by_wave.iter().rev() {
        for &i in wave {
            for &j in &dependents[i] {
                cp[i] = cp[i].max(1 + cp[j]);
            }
        }
    }
    cp
}

/// Terminal state of one DAG node after [`run_dag`].
#[derive(Debug)]
pub enum DagNode<T> {
    /// The job was dispatched and returned; `ok` is the job's own
    /// success verdict (dependents of a not-ok node are skipped).
    Ran { out: T, ok: bool },
    /// The job panicked outside any containment of its own; treated as
    /// not-ok for dependency purposes.
    Panicked(String),
    /// Never dispatched: dependency `dep` (the first not-ok dependency
    /// in the node's declaration order) failed, panicked or was itself
    /// skipped.
    Skipped { dep: usize },
}

/// Scheduling accounting for one [`run_dag`] call.
#[derive(Debug, Clone, Default)]
pub struct DagReport {
    /// Worker/busy/panic accounting in the same shape as the batch
    /// pool, so [`Metrics::record_pool`](crate::coordinator::Metrics::record_pool)
    /// applies unchanged. `steals` is always 0 (a shared ready queue
    /// has nothing to steal).
    pub pool: PoolReport,
    /// Peak ready-queue depth: how many dispatchable nodes were waiting
    /// at the worst moment (scheduling pressure; 0-1 means the DAG
    /// never had slack to reorder).
    pub max_ready_depth: usize,
    /// Per-node seconds between becoming ready and being picked up by a
    /// worker (0 for skipped nodes).
    pub queue_wait_secs: Vec<f64>,
}

/// Ready-queue entry: max-heap on priority, ties broken toward the
/// lowest node index (deterministic pop order for equal chains).
#[derive(PartialEq, Eq)]
struct Ready {
    prio: usize,
    idx: usize,
}

impl Ord for Ready {
    fn cmp(&self, other: &Self) -> std::cmp::Ordering {
        self.prio
            .cmp(&other.prio)
            .then_with(|| other.idx.cmp(&self.idx))
    }
}

impl PartialOrd for Ready {
    fn partial_cmp(&self, other: &Self) -> Option<std::cmp::Ordering> {
        Some(self.cmp(other))
    }
}

/// Shared scheduler state, guarded by one mutex (critical sections are
/// O(dependents) pointer work; the jobs themselves run unlocked).
struct DagState<T> {
    indeg: Vec<usize>,
    ready: BinaryHeap<Ready>,
    ready_at: Vec<Option<Instant>>,
    nodes: Vec<Option<DagNode<T>>>,
    /// Resolved success per node (`None` = unresolved).
    ok: Vec<Option<bool>>,
    /// Nodes finalized (ran, panicked or skipped).
    done: usize,
    /// Jobs currently executing on some worker.
    inflight: usize,
    max_ready_depth: usize,
    queue_wait_secs: Vec<f64>,
    panics: usize,
}

impl<T> DagState<T> {
    fn push_ready(&mut self, idx: usize, prio: &[usize]) {
        self.ready.push(Ready { prio: prio[idx], idx });
        self.ready_at[idx] = Some(Instant::now());
        self.max_ready_depth = self.max_ready_depth.max(self.ready.len());
    }

    /// Finalize node `i` and cascade: dependents whose in-degree hits
    /// zero either become ready or — if any dependency resolved not-ok
    /// — are skipped in place, which cascades further down the chain
    /// without ever dispatching a job.
    fn settle(
        &mut self,
        i: usize,
        node: DagNode<T>,
        ok: bool,
        deps: &[Vec<usize>],
        dependents: &[Vec<usize>],
        prio: &[usize],
    ) {
        self.nodes[i] = Some(node);
        self.ok[i] = Some(ok);
        self.done += 1;
        let mut work = vec![i];
        while let Some(c) = work.pop() {
            for &t in &dependents[c] {
                self.indeg[t] -= 1;
                if self.indeg[t] > 0 {
                    continue;
                }
                // every dep of t resolved: first not-ok dep (in the
                // node's own declaration order) decides a skip — the
                // same dep the wave scheduler's pre-dispatch scan finds
                match deps[t].iter().find(|&&d| self.ok[d] == Some(false)) {
                    Some(&bad) => {
                        self.nodes[t] = Some(DagNode::Skipped { dep: bad });
                        self.ok[t] = Some(false);
                        self.done += 1;
                        work.push(t);
                    }
                    None => self.push_ready(t, prio),
                }
            }
        }
    }
}

/// Execute a dependency DAG with work-conserving dataflow scheduling.
///
/// `run(i)` is called exactly once per non-skipped node, only after
/// every dependency of `i` resolved ok; it returns the node's product
/// plus its success verdict (a stage whose failure should quarantine
/// dependents returns `false` while still carrying its output — the
/// grid's metrics survive failed stages this way). Results come back
/// indexed by node id. `priority` orders the ready queue (higher
/// first); pass [`critical_path`] for longest-chain-first.
///
/// Workers: `par.resolve_for(deps.len())` threads share the ready
/// queue; `<= 1` short-circuits to an in-thread loop with identical
/// pop order. Panics (in `run`) are caught per node; cycles and
/// out-of-range deps panic up front (programmer error, like [`waves`]).
pub fn run_dag<T, F>(
    par: Parallelism,
    deps: &[Vec<usize>],
    priority: &[usize],
    run: F,
) -> (Vec<DagNode<T>>, DagReport)
where
    T: Send,
    F: Fn(usize) -> (T, bool) + Sync,
{
    let n = deps.len();
    assert_eq!(priority.len(), n, "run_dag: priority.len() != deps.len()");
    // validates deps (in-range, acyclic) before any thread spawns
    let _ = waves(deps);

    let mut dependents: Vec<Vec<usize>> = vec![Vec::new(); n];
    let mut indeg = vec![0usize; n];
    for (i, ds) in deps.iter().enumerate() {
        for &d in ds {
            dependents[d].push(i);
            indeg[i] += 1;
        }
    }

    let t0 = Instant::now();
    let mut state = DagState {
        indeg,
        ready: BinaryHeap::new(),
        ready_at: vec![None; n],
        nodes: (0..n).map(|_| None).collect(),
        ok: vec![None; n],
        done: 0,
        inflight: 0,
        max_ready_depth: 0,
        queue_wait_secs: vec![0.0; n],
        panics: 0,
    };
    for i in 0..n {
        if state.indeg[i] == 0 {
            state.push_ready(i, priority);
        }
    }

    let workers = par.resolve_for(n);
    let (mut worker_busy_secs, mut worker_jobs) =
        (vec![0.0f64; workers], vec![0usize; workers]);

    if workers <= 1 {
        // serial fast path: same heap, same pop order, no threads
        let (mut busy, mut count) = (0.0f64, 0usize);
        while let Some(Ready { idx, .. }) = state.ready.pop() {
            if let Some(t) = state.ready_at[idx].take() {
                state.queue_wait_secs[idx] = t.elapsed().as_secs_f64();
            }
            let tj = Instant::now();
            let caught = catch_unwind(AssertUnwindSafe(|| run(idx)));
            busy += tj.elapsed().as_secs_f64();
            count += 1;
            match caught {
                Ok((out, ok)) => state.settle(
                    idx,
                    DagNode::Ran { out, ok },
                    ok,
                    deps,
                    &dependents,
                    priority,
                ),
                Err(p) => {
                    state.panics += 1;
                    state.settle(
                        idx,
                        DagNode::Panicked(panic_message(p.as_ref())),
                        false,
                        deps,
                        &dependents,
                        priority,
                    );
                }
            }
        }
        worker_busy_secs[0] = busy;
        worker_jobs[0] = count;
    } else {
        let state_mx = Mutex::new(state);
        let cvar = Condvar::new();
        std::thread::scope(|s| {
            let handles: Vec<_> = (0..workers)
                .map(|_| {
                    let state_mx = &state_mx;
                    let cvar = &cvar;
                    let run = &run;
                    s.spawn(move || {
                        let (mut busy, mut count) = (0.0f64, 0usize);
                        let mut st = lock_clean(state_mx);
                        loop {
                            if st.done >= n {
                                break;
                            }
                            let Some(Ready { idx, .. }) = st.ready.pop()
                            else {
                                // done < n and nothing ready: some
                                // in-flight job must settle first (the
                                // DAG is acyclic, so one always exists)
                                st = cvar
                                    .wait(st)
                                    .unwrap_or_else(|p| p.into_inner());
                                continue;
                            };
                            if let Some(t) = st.ready_at[idx].take() {
                                st.queue_wait_secs[idx] =
                                    t.elapsed().as_secs_f64();
                            }
                            st.inflight += 1;
                            drop(st);
                            let tj = Instant::now();
                            let caught =
                                catch_unwind(AssertUnwindSafe(|| run(idx)));
                            busy += tj.elapsed().as_secs_f64();
                            count += 1;
                            st = lock_clean(state_mx);
                            st.inflight -= 1;
                            match caught {
                                Ok((out, ok)) => st.settle(
                                    idx,
                                    DagNode::Ran { out, ok },
                                    ok,
                                    deps,
                                    &dependents,
                                    priority,
                                ),
                                Err(p) => {
                                    st.panics += 1;
                                    st.settle(
                                        idx,
                                        DagNode::Panicked(panic_message(
                                            p.as_ref(),
                                        )),
                                        false,
                                        deps,
                                        &dependents,
                                        priority,
                                    );
                                }
                            }
                            // settling may have readied several nodes
                            // and/or finished the run: wake everyone
                            cvar.notify_all();
                        }
                        drop(st);
                        (busy, count)
                    })
                })
                .collect();
            for (w, h) in handles.into_iter().enumerate() {
                let (busy, count) = h.join().unwrap_or((0.0, 0));
                worker_busy_secs[w] = busy;
                worker_jobs[w] = count;
            }
        });
        state = state_mx.into_inner().unwrap_or_else(|p| p.into_inner());
    }

    let dispatched: usize = worker_jobs.iter().sum();
    let report = DagReport {
        pool: PoolReport {
            workers,
            jobs: dispatched,
            wall_secs: t0.elapsed().as_secs_f64(),
            worker_busy_secs,
            worker_jobs,
            steals: 0,
            panics: state.panics,
        },
        max_ready_depth: state.max_ready_depth,
        queue_wait_secs: state.queue_wait_secs,
    };
    let nodes = state
        .nodes
        .into_iter()
        .enumerate()
        .map(|(i, n)| n.unwrap_or_else(|| panic!("run_dag: node {i} lost")))
        .collect();
    (nodes, report)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::exec::{chain_deps, independent_deps};

    fn ran_ok<T>(n: &DagNode<T>) -> Option<&T> {
        match n {
            DagNode::Ran { out, ok: true } => Some(out),
            _ => None,
        }
    }

    #[test]
    fn critical_path_chain_and_independent() {
        assert_eq!(critical_path(&chain_deps(4)), vec![4, 3, 2, 1]);
        assert_eq!(critical_path(&independent_deps(3)), vec![1, 1, 1]);
        assert_eq!(critical_path(&[]), Vec::<usize>::new());
    }

    #[test]
    fn critical_path_diamond_takes_longest_branch() {
        // 0 -> {1, 2}; 2 -> 3; {1, 3} -> 4
        let deps = vec![
            vec![],
            vec![0],
            vec![0],
            vec![2],
            vec![1, 3],
        ];
        // 0 sees the 0-2-3-4 chain (4 nodes); 1 only reaches 4
        assert_eq!(critical_path(&deps), vec![4, 2, 3, 2, 1]);
    }

    #[test]
    fn run_dag_matches_submission_order_at_any_worker_count() {
        let deps = vec![
            vec![],
            vec![0],
            vec![0],
            vec![1, 2],
            vec![],
            vec![4],
        ];
        let prio = critical_path(&deps);
        for workers in [1, 2, 4, 8] {
            let (nodes, report) = run_dag(
                Parallelism::new(workers),
                &deps,
                &prio,
                |i| (i * i, true),
            );
            let got: Vec<usize> =
                nodes.iter().map(|n| *ran_ok(n).unwrap()).collect();
            assert_eq!(got, vec![0, 1, 4, 9, 16, 25], "workers={workers}");
            assert_eq!(report.pool.jobs, 6);
            assert_eq!(report.pool.workers, workers.min(6));
            assert_eq!(report.queue_wait_secs.len(), 6);
            assert!(report.max_ready_depth >= 1);
        }
    }

    #[test]
    fn serial_pop_order_is_longest_chain_first_then_lowest_index() {
        // two sources: node 0 heads a 3-chain (0->1->2), node 3 is a
        // lone sink; equal-priority nodes pop lowest-index first
        let deps = vec![vec![], vec![0], vec![1], vec![], vec![]];
        let prio = critical_path(&deps);
        assert_eq!(prio, vec![3, 2, 1, 1, 1]);
        let order = Mutex::new(Vec::new());
        let (_, _) = run_dag(Parallelism::SERIAL, &deps, &prio, |i| {
            lock_clean(&order).push(i);
            ((), true)
        });
        // 0 first (prio 3); settling it readies 1 (prio 2) which beats
        // the prio-1 sources; settling 1 readies 2, which ties 3 and 4
        // at prio 1 and wins the lowest-index tiebreak
        assert_eq!(*lock_clean(&order), vec![0, 1, 2, 3, 4]);
    }

    #[test]
    fn failed_node_skips_exactly_its_dependents() {
        // 0 fails; 1 depends on 0 (skipped); 2 independent (runs);
        // 3 depends on 1 (skip cascades); 4 depends on 2 (runs)
        let deps = vec![vec![], vec![0], vec![], vec![1], vec![2]];
        let prio = critical_path(&deps);
        for workers in [1, 4] {
            let (nodes, report) =
                run_dag(Parallelism::new(workers), &deps, &prio, |i| {
                    (i, i != 0)
                });
            assert!(
                matches!(nodes[0], DagNode::Ran { ok: false, .. }),
                "workers={workers}"
            );
            assert!(matches!(nodes[1], DagNode::Skipped { dep: 0 }));
            assert!(ran_ok(&nodes[2]).is_some());
            assert!(
                matches!(nodes[3], DagNode::Skipped { dep: 1 }),
                "skip chains propagate through skipped nodes"
            );
            assert!(ran_ok(&nodes[4]).is_some());
            assert_eq!(report.pool.jobs, 3, "skipped nodes never dispatch");
        }
    }

    #[test]
    fn skip_reports_first_bad_dep_in_declaration_order() {
        // node 2 declares deps [0, 1]; both fail — dep 0 must win
        // whatever order they settle in
        let deps = vec![vec![], vec![], vec![0, 1]];
        let prio = critical_path(&deps);
        for _ in 0..8 {
            let (nodes, _) =
                run_dag(Parallelism::new(2), &deps, &prio, |i| (i, false));
            assert!(matches!(nodes[2], DagNode::Skipped { dep: 0 }));
        }
    }

    #[test]
    fn panicking_job_is_contained_and_fails_dependents() {
        let deps = vec![vec![], vec![0], vec![]];
        let prio = critical_path(&deps);
        for workers in [1, 4] {
            let (nodes, report) =
                run_dag(Parallelism::new(workers), &deps, &prio, |i| {
                    if i == 0 {
                        panic!("boom node {i}");
                    }
                    (i, true)
                });
            match &nodes[0] {
                DagNode::Panicked(msg) => {
                    assert!(msg.contains("boom node 0"), "{msg}")
                }
                other => panic!("want Panicked, got {other:?}"),
            }
            assert!(matches!(nodes[1], DagNode::Skipped { dep: 0 }));
            assert!(ran_ok(&nodes[2]).is_some());
            assert_eq!(report.pool.panics, 1);
        }
    }

    #[test]
    fn empty_dag_is_fine() {
        let (nodes, report) = run_dag(
            Parallelism::new(4),
            &[],
            &[],
            |_| ((), true),
        );
        assert!(nodes.is_empty());
        assert_eq!(report.pool.jobs, 0);
    }

    #[test]
    #[should_panic]
    fn cycle_panics_before_dispatch() {
        let deps = vec![vec![1], vec![0]];
        let _ = run_dag(Parallelism::new(2), &deps, &[1, 1], |i| (i, true));
    }

    #[test]
    fn uneven_durations_overlap_across_ranks() {
        // wave scheduling of this DAG takes ~slow + 3 * fast (the slow
        // source barriers rank 0); dataflow lets the fast chain drain
        // while the slow node runs. Node 0: slow source. Nodes 1-3: a
        // fast chain. With 2 workers the chain must finish without
        // waiting for node 0.
        let deps = vec![vec![], vec![], vec![1], vec![2]];
        let prio = critical_path(&deps);
        let t0 = Instant::now();
        let (nodes, report) =
            run_dag(Parallelism::new(2), &deps, &prio, |i| {
                let ms = if i == 0 { 120 } else { 10 };
                std::thread::sleep(std::time::Duration::from_millis(ms));
                (i, true)
            });
        let wall = t0.elapsed().as_secs_f64();
        assert_eq!(nodes.len(), 4);
        // wave execution would need >= 150ms (120 + 3*10); dataflow
        // needs ~120ms. Allow generous scheduling slack.
        assert!(
            wall < 0.40,
            "dataflow must overlap the chain with the slow node: {wall}s"
        );
        assert!(report.pool.utilization() > 0.0);
    }
}

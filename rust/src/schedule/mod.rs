//! Learning-rate and annealing schedules (appendix A): cosine annealing
//! to zero (weight step sizes + activation steps during reconstruction),
//! exponential decay (generator LR), ReduceLROnPlateau (latent vectors,
//! "like that in ZeroQ"), and the AdaRound beta anneal.

/// Cosine annealing from `base` to 0 over `total` steps (SGDR-style,
/// single period, no restart).
#[derive(Debug, Clone)]
pub struct CosineAnnealing {
    pub base: f32,
    pub total: usize,
}

impl CosineAnnealing {
    pub fn new(base: f32, total: usize) -> Self {
        CosineAnnealing { base, total }
    }

    pub fn lr(&self, step: usize) -> f32 {
        let t = (step.min(self.total)) as f32 / self.total.max(1) as f32;
        self.base * 0.5 * (1.0 + (std::f32::consts::PI * t).cos())
    }
}

/// Exponential decay: lr = base * gamma^(step / every).
#[derive(Debug, Clone)]
pub struct ExponentialDecay {
    pub base: f32,
    pub gamma: f32,
    pub every: usize,
}

impl ExponentialDecay {
    pub fn new(base: f32, gamma: f32, every: usize) -> Self {
        ExponentialDecay { base, gamma, every }
    }

    pub fn lr(&self, step: usize) -> f32 {
        self.base * self.gamma.powi((step / self.every.max(1)) as i32)
    }
}

/// ReduceLROnPlateau: multiply lr by `factor` when the observed loss has
/// not improved by `min_delta` for `patience` observations.
#[derive(Debug, Clone)]
pub struct ReduceLROnPlateau {
    lr: f32,
    pub factor: f32,
    pub patience: usize,
    pub min_delta: f32,
    pub min_lr: f32,
    best: f32,
    wait: usize,
}

impl ReduceLROnPlateau {
    pub fn new(base: f32, factor: f32, patience: usize) -> Self {
        ReduceLROnPlateau {
            lr: base,
            factor,
            patience,
            min_delta: 1e-4,
            min_lr: 1e-6,
            best: f32::INFINITY,
            wait: 0,
        }
    }

    /// Observe a loss; returns the (possibly reduced) lr to use next.
    pub fn observe(&mut self, loss: f32) -> f32 {
        if loss < self.best - self.min_delta {
            self.best = loss;
            self.wait = 0;
        } else {
            self.wait += 1;
            if self.wait > self.patience {
                self.lr = (self.lr * self.factor).max(self.min_lr);
                self.wait = 0;
            }
        }
        self.lr
    }

    pub fn lr(&self) -> f32 {
        self.lr
    }

    /// Raw mutable state `(lr, best, wait)` — serialized into phase
    /// checkpoints so a resumed run observes losses exactly where the
    /// interrupted one stopped (DESIGN.md §9).
    pub fn raw(&self) -> (f32, f32, usize) {
        (self.lr, self.best, self.wait)
    }

    /// Restore checkpointed raw state; `observe` then behaves
    /// bit-identically to the saved scheduler.
    pub fn restore_raw(&mut self, lr: f32, best: f32, wait: usize) {
        self.lr = lr;
        self.best = best;
        self.wait = wait;
    }
}

/// AdaRound beta anneal: hold at `start` for `warmup` fraction, then
/// decay linearly to `end` (paper appendix B "beta is annealed").
#[derive(Debug, Clone)]
pub struct BetaAnneal {
    pub start: f32,
    pub end: f32,
    pub warmup: f32,
    pub total: usize,
}

impl BetaAnneal {
    pub fn new(start: f32, end: f32, warmup: f32, total: usize) -> Self {
        BetaAnneal { start, end, warmup, total }
    }

    pub fn beta(&self, step: usize) -> f32 {
        let w = (self.total as f32 * self.warmup) as usize;
        if step <= w {
            return self.start;
        }
        let t = (step - w) as f32 / (self.total - w).max(1) as f32;
        self.start + (self.end - self.start) * t.min(1.0)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn cosine_endpoints_and_monotone() {
        let s = CosineAnnealing::new(1.0, 100);
        assert!((s.lr(0) - 1.0).abs() < 1e-6);
        assert!(s.lr(100) < 1e-6);
        let mut prev = f32::INFINITY;
        for t in 0..=100 {
            let lr = s.lr(t);
            assert!(lr <= prev + 1e-7, "not monotone at {t}");
            prev = lr;
        }
    }

    #[test]
    fn cosine_clamps_past_total() {
        let s = CosineAnnealing::new(1.0, 10);
        assert_eq!(s.lr(50), s.lr(10));
    }

    #[test]
    fn exponential_decays_by_gamma_every_n() {
        let s = ExponentialDecay::new(0.01, 0.95, 100);
        assert!((s.lr(0) - 0.01).abs() < 1e-9);
        assert!((s.lr(99) - 0.01).abs() < 1e-9);
        assert!((s.lr(100) - 0.0095).abs() < 1e-9);
        assert!((s.lr(250) - 0.01 * 0.95f32.powi(2)).abs() < 1e-9);
    }

    #[test]
    fn plateau_reduces_after_patience() {
        let mut s = ReduceLROnPlateau::new(0.1, 0.5, 2);
        assert_eq!(s.observe(1.0), 0.1); // best=1.0
        assert_eq!(s.observe(1.0), 0.1); // wait=1
        assert_eq!(s.observe(1.0), 0.1); // wait=2
        assert_eq!(s.observe(1.0), 0.05); // wait=3 > patience -> reduce
    }

    #[test]
    fn plateau_resets_on_improvement() {
        let mut s = ReduceLROnPlateau::new(0.1, 0.5, 1);
        s.observe(1.0);
        s.observe(0.5); // improvement resets wait
        s.observe(0.5);
        assert_eq!(s.lr(), 0.1);
    }

    #[test]
    fn plateau_respects_min_lr() {
        let mut s = ReduceLROnPlateau::new(1e-5, 0.1, 0);
        for _ in 0..10 {
            s.observe(1.0);
        }
        assert!(s.lr() >= 1e-6);
    }

    #[test]
    fn plateau_raw_roundtrip() {
        let mut a = ReduceLROnPlateau::new(0.1, 0.5, 1);
        a.observe(1.0);
        a.observe(1.0);
        let (lr, best, wait) = a.raw();
        let mut b = ReduceLROnPlateau::new(0.1, 0.5, 1);
        b.restore_raw(lr, best, wait);
        for loss in [1.0, 0.9, 0.9, 0.9, 0.8] {
            assert_eq!(a.observe(loss), b.observe(loss));
        }
    }

    #[test]
    fn beta_anneal_warmup_then_linear() {
        let b = BetaAnneal::new(20.0, 2.0, 0.2, 100);
        assert_eq!(b.beta(0), 20.0);
        assert_eq!(b.beta(20), 20.0);
        assert!((b.beta(100) - 2.0).abs() < 1e-5);
        assert!(b.beta(60) < 20.0 && b.beta(60) > 2.0);
    }
}

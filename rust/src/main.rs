//! `genie` — CLI for the GENIE zero-shot-quantization coordinator.
//!
//! Subcommands:
//!   info                               platform + artifact inventory
//!   pretrain  --model M [k=v ...]      train + checkpoint the FP32 teacher
//!   eval      --model M [k=v ...]      FP32 teacher accuracy
//!   distill   --model M [k=v ...]      GENIE-D synthetic data (saved to runs/)
//!   zsq | run --model M [k=v ...]      full zero-shot pipeline
//!   fsq       --model M [k=v ...]      few-shot (real-data) GENIE-M
//!   grid      --axis k=v1,v2 ...       multi-run sweep on the shared-
//!                                      artifact scheduler (DESIGN.md §11);
//!                                      --dry-run prints the resolved DAG
//!   cache     stats|gc [--axis ...]    tiered artifact store inspection
//!                                      and budgeted, pin-aware GC
//!                                      (DESIGN.md §16)
//!   experiments --exp ID [k=v ...]     paper table/figure harnesses
//!
//! Config overrides are `key=value` (see coordinator::config); notably
//! `workers=K` sizes the exec worker pool (0 = one per hardware thread)
//! without changing any result bit — parallel phases are deterministic in
//! the seed alone (DESIGN.md §5).
//!
//! Caching & resume (DESIGN.md §9): pipeline stages are content-addressed
//! artifacts under `--cache-dir` (default `cache/`); a re-run with the
//! same config loads them instead of recomputing, `--resume` continues an
//! interrupted stage from its checkpoints, and `--no-cache` turns the
//! whole mechanism off. `--json <path>` writes a machine-readable outcome
//! report (run and grid).

use anyhow::{bail, Result};

use genie::artifacts::{ArtifactCache, Backend};
use genie::coordinator::{
    self, fsq, zsq, Metrics, RunConfig,
};
use genie::data::Dataset;
use genie::experiments;
use genie::grid::{GridOpts, GridPlan, RunGrid};
use genie::runtime::{ModelRt, Runtime};

fn main() -> Result<()> {
    // validate GENIE_FAULTS eagerly so a typo fails the run up front
    // instead of silently injecting nothing (DESIGN.md §13)
    genie::faults::init_from_env()?;
    let args: Vec<String> = std::env::args().skip(1).collect();
    let Some(cmd) = args.first() else {
        usage();
        return Ok(());
    };

    let mut cfg = RunConfig::default();
    let mut exp = String::new();
    let mut axes: Vec<String> = Vec::new();
    let mut dry_run = false;
    let mut overrides = Vec::new();
    let mut it = args[1..].iter().peekable();
    // `genie cache <gc|stats>` carries a bare action word before the flags
    let mut action = String::new();
    if cmd == "cache" {
        if let Some(a) = it.peek() {
            if !a.starts_with("--") && !a.contains('=') {
                action = it.next().cloned().unwrap_or_default();
            }
        }
    }
    while let Some(a) = it.next() {
        match a.as_str() {
            "--model" => cfg.model = next(&mut it, "--model")?,
            "--artifacts" => cfg.artifacts = next(&mut it, "--artifacts")?,
            "--cache-dir" => cfg.cache_dir = next(&mut it, "--cache-dir")?,
            "--no-cache" => cfg.cache = false,
            "--resume" => cfg.resume = true,
            "--cache-budget" => {
                let v = next(&mut it, "--cache-budget")?;
                cfg.set("cache.budget_bytes", &v)?;
            }
            "--cache-hot-bytes" => {
                let v = next(&mut it, "--cache-hot-bytes")?;
                cfg.set("cache.hot_bytes", &v)?;
            }
            "--cache-backend" => {
                let v = next(&mut it, "--cache-backend")?;
                cfg.set("cache.backend", &v)?;
            }
            "--cache-shared-dir" => {
                let v = next(&mut it, "--cache-shared-dir")?;
                cfg.set("cache.shared_dir", &v)?;
            }
            "--precision" => {
                let v = next(&mut it, "--precision")?;
                cfg.set("precision", &v)?;
            }
            "--target-size" => {
                let v = next(&mut it, "--target-size")?;
                cfg.set("target_size", &v)?;
            }
            "--synthesis" => {
                let v = next(&mut it, "--synthesis")?;
                cfg.set("synthesis", &v)?;
            }
            "--steps-per-dispatch" => {
                let v = next(&mut it, "--steps-per-dispatch")?;
                cfg.set("steps_per_dispatch", &v)?;
            }
            "--axis" => axes.push(next(&mut it, "--axis")?),
            "--dry-run" => dry_run = true,
            "--json" => {
                let v = next(&mut it, "--json")?;
                cfg.set("json", &v)?;
            }
            "--exp" => exp = next(&mut it, "--exp")?,
            "--help" | "-h" => {
                usage();
                return Ok(());
            }
            kv if kv.contains('=') => overrides.push(kv.to_string()),
            other => bail!("unexpected argument '{other}' (want key=value)"),
        }
    }
    cfg.apply_overrides(&overrides)?;

    match cmd.as_str() {
        "info" => info(&cfg),
        "pretrain" => cmd_pretrain(&cfg),
        "eval" => cmd_eval(&cfg),
        "distill" => cmd_distill(&cfg),
        // `run` = one pipeline run (zsq), the single-cell counterpart of
        // `grid`
        "zsq" | "run" => cmd_zsq(&cfg),
        "fsq" => cmd_fsq(&cfg),
        "grid" => cmd_grid(&cfg, &axes, dry_run),
        "cache" => cmd_cache(&cfg, &action, &axes),
        "export" => cmd_export(&cfg),
        "report" => cmd_report(),
        "experiments" => experiments::run(&exp, &cfg),
        other => {
            usage();
            bail!("unknown subcommand '{other}'")
        }
    }
}

fn next(
    it: &mut std::iter::Peekable<std::slice::Iter<String>>,
    flag: &str,
) -> Result<String> {
    it.next()
        .cloned()
        .ok_or_else(|| anyhow::anyhow!("{flag} needs a value"))
}

fn usage() {
    println!(
        "genie — GENIE zero-shot quantization (rust+JAX+Pallas reproduction)\n\
         usage: genie <info|pretrain|eval|distill|zsq|run|fsq|grid|cache|experiments>\n\
                [--model M] [--artifacts DIR] [--exp ID]\n\
                [--precision uniform|pareto] [--target-size F]\n\
                [--synthesis genie|zeroq|zaq] [--steps-per-dispatch K]\n\
                [--axis name=v1,v2 ...] [--dry-run] [--json PATH]\n\
                [--cache-dir DIR] [--no-cache] [--resume]\n\
                [--cache-budget BYTES] [--cache-hot-bytes BYTES]\n\
                [--cache-backend local|shared-dir] [--cache-shared-dir DIR]\n\
                [key=value ...]\n\
         keys: wbits abits seed workers steps_per_dispatch sched\n\
               checkpoint_every json\n\
               cache.{{budget_bytes,hot_bytes,backend,shared_dir}}\n\
               precision target_size first_last_bits granularity\n\
               sens_batches candidates synthesis retry.{{max,backoff_ms}}\n\
               pretrain.{{steps,lr}}\n\
               distill.{{engine,mode,swing,samples,steps,lr_g,lr_z}}\n\
               quant.{{steps,lr_sw,lr_v,lr_sa,lam,drop_p,pnorm,refresh_student}}\n\
         workers=K runs distill shards, quant blocks and eval batches on\n\
         K pool workers (0 = auto); results are bit-identical for any K.\n\
         steps_per_dispatch=K fuses K consecutive optimization steps into\n\
         one device dispatch (DESIGN.md §14); like workers it changes\n\
         execution shape only — results, checkpoints and cache keys are\n\
         bit-identical for any K.\n\
         sched=wave|dataflow picks the grid scheduler (DESIGN.md §15):\n\
         dataflow (default) dispatches each stage the moment its inputs\n\
         are ready, wave runs rank-by-rank with barriers; results are\n\
         bit-identical either way (GENIE_SCHED overrides the default).\n\
         --precision pareto measures per-layer sensitivity on the\n\
         calibration set and allocates mixed weight bits to meet\n\
         --target-size (fraction of the FP32 weight payload, e.g. 0.25);\n\
         first_last_bits=B pins the first/last layers (0 disables).\n\
         Stages cache as content-addressed artifacts under --cache-dir;\n\
         identical configs re-load instead of re-running, --resume picks\n\
         an interrupted stage up from its last checkpoint.\n\
         The store is tiered (DESIGN.md §16): an in-process hot tier\n\
         shares one deserialized copy across agreeing grid cells\n\
         (cache.hot_bytes caps it), disk is tier 1 with a GC budget\n\
         (cache.budget_bytes; 0 = unlimited), and cache.backend=shared-dir\n\
         pools artifacts in cache.shared_dir across machines.\n\
         `genie cache stats` reports per-tier contents; `genie cache gc`\n\
         evicts LRU down to the budget, pinning whatever the configured\n\
         run/grid (same --axis flags as `genie grid`) would read.\n\
         --synthesis picks the calibration-data engine (DESIGN.md §12):\n\
         genie (generator+latents, default), zeroq (BN-statistics\n\
         image-space matching), zaq (adversarial generator vs a W4A4\n\
         student proxy); each engine caches under its own keys.\n\
         grid sweeps axes (model bits seed samples data quant precision\n\
         synthesis) on the shared-artifact scheduler: cells are\n\
         bit-identical to standalone runs, shared teacher/distill work\n\
         dispatches once, and stages from different cells interleave\n\
         on the pool. E.g.:\n\
           genie grid --axis bits=4,3,2 --axis seed=0,1 workers=4\n\
           genie grid --axis synthesis=genie,zeroq --axis bits=w2a4 --dry-run\n\
         --json PATH writes the outcome report (run and grid) as JSON.\n\
         Fault tolerance (DESIGN.md §13): grid stages retry transient\n\
         failures (retry.max attempts, linear retry.backoff_ms between\n\
         them), a panicking stage is contained to its cell, and corrupt\n\
         cached artifacts are quarantined + recomputed. Cells report\n\
         ok|failed|skipped in --json; any non-ok cell exits nonzero.\n\
         GENIE_FAULTS=stage:site:attemptN=panic|err[,artifact:corrupt:P]\n\
         injects deterministic faults at named sites (testing only)."
    );
}

fn setup<'a>(
    rt: &'a Runtime,
    cfg: &RunConfig,
) -> Result<(ModelRt<'a>, Dataset)> {
    let mrt = ModelRt::load(rt, &cfg.artifacts, &cfg.model)?;
    let dataset = Dataset::load(&cfg.artifacts)?;
    Ok((mrt, dataset))
}

fn open_cache(cfg: &RunConfig) -> Result<ArtifactCache> {
    cfg.open_cache()
}

fn print_cache_stats(cache: &ArtifactCache) {
    let s = cache.stats();
    if cache.is_enabled() {
        println!(
            "cache: {} hits ({} hot, {} disk, {} shared), {} misses, {} \
             artifacts stored",
            s.hits, s.hot_hits, s.disk_hits, s.shared_hits, s.misses, s.stores
        );
        if s.hot_evictions + s.gc_evictions > 0 {
            println!(
                "cache: {} hot eviction(s), {} disk artifact(s) GCed to \
                 budget",
                s.hot_evictions, s.gc_evictions
            );
        }
        if s.quarantined > 0 {
            println!(
                "cache: {} corrupt artifact(s) quarantined and recomputed",
                s.quarantined
            );
        }
    }
}

/// `genie cache stats|gc` (DESIGN.md §16): inspect the tiered store or
/// collect tier 1 back under `cache.budget_bytes`. `gc` pins the
/// transitive artifact set of the configured run/grid (`--axis` flags
/// compose exactly like `genie grid --dry-run`), live claims, and this
/// process's touches; everything else is evictable, oldest use first.
fn cmd_cache(cfg: &RunConfig, action: &str, axes: &[String]) -> Result<()> {
    anyhow::ensure!(
        cfg.cache,
        "the cache is disabled (--no-cache); nothing to {action}"
    );
    let cache = open_cache(cfg)?;
    match action {
        "stats" => {
            let (hot, _disk) = cache.tier_bytes();
            println!("tier 0 (hot): {} KiB resident", hot / 1024);
            print_tier("tier 1", cache.local_backend());
            if let Some(be) = cache.shared_backend() {
                print_tier("tier 2", be);
            }
            println!(
                "budget: {} (disk), {} (hot)",
                fmt_budget(cfg.cache_budget_bytes),
                fmt_budget(cfg.cache_hot_bytes),
            );
            Ok(())
        }
        "gc" => {
            let pins: std::collections::HashSet<String> =
                match grid_pin_stems(cfg, axes, &cache) {
                    Ok(p) => p.into_iter().collect(),
                    Err(e) => {
                        println!(
                            "cache gc: no pin set resolved ({e:#}); \
                             falling back to live claims + LRU only"
                        );
                        Default::default()
                    }
                };
            let report = genie::artifacts::gc::collect(
                cache.local_backend(),
                cache.hot_namespace(),
                cfg.cache_budget_bytes,
                &pins,
            );
            println!(
                "cache gc: {} artifact(s) scanned, {} pinned, {} evicted \
                 ({} KiB reclaimed), {} KiB live",
                report.scanned,
                report.pinned,
                report.evicted,
                report.evicted_bytes / 1024,
                report.live_bytes / 1024,
            );
            if cfg.cache_budget_bytes == 0 {
                println!(
                    "cache gc: no budget set (cache.budget_bytes=0) — \
                     report only, nothing evicted"
                );
            }
            Ok(())
        }
        "" => bail!("cache needs an action: genie cache <stats|gc>"),
        other => bail!("unknown cache action '{other}' (want stats|gc)"),
    }
}

fn fmt_budget(bytes: u64) -> String {
    if bytes == 0 {
        "unlimited".to_string()
    } else {
        format!("{} KiB", bytes / 1024)
    }
}

fn print_tier(label: &str, be: &dyn Backend) {
    let files = be.list();
    let arts = files.iter().filter(|e| e.name.ends_with(".gts")).count();
    let bytes: u64 = files
        .iter()
        .filter(|e| {
            e.name.ends_with(".gts") || e.name.ends_with(".gts.fnv")
        })
        .map(|e| e.bytes)
        .sum();
    let locks = files
        .iter()
        .filter(|e| e.name.starts_with("wip_") && e.name.ends_with(".lock"))
        .count();
    let quarantined = std::fs::read_dir(be.root().join("quarantine"))
        .map(|rd| rd.count())
        .unwrap_or(0);
    println!(
        "{label} ({}): {:?} — {arts} artifact(s), {} KiB, {locks} live \
         claim(s), {quarantined} quarantined",
        be.tier(),
        be.root(),
        bytes / 1024,
    );
}

/// The pin set for `genie cache gc`: the transitive artifact stems the
/// configured grid (base config + `--axis` flags) resolves in its dry
/// run — exactly what a subsequent `genie grid` with the same flags
/// would read instead of recompute.
fn grid_pin_stems(
    cfg: &RunConfig,
    axes: &[String],
    cache: &ArtifactCache,
) -> Result<std::collections::BTreeSet<String>> {
    let mut grid = RunGrid::new();
    for a in axes {
        grid.parse_axis(a, cfg)?;
    }
    let cells = grid.cells(cfg)?;
    let mut manifests = std::collections::BTreeMap::new();
    for c in &cells {
        if !manifests.contains_key(&c.model) {
            let dir = std::path::Path::new(&cfg.artifacts).join(&c.model);
            manifests
                .insert(c.model.clone(), genie::runtime::Manifest::load(dir)?);
        }
    }
    let plan = GridPlan::build(cells, &manifests, false)?;
    let dataset = Dataset::load(&cfg.artifacts).ok();
    Ok(plan.pin_stems(&manifests, cache, dataset.as_ref()))
}

fn info(cfg: &RunConfig) -> Result<()> {
    let rt = Runtime::cpu()?;
    println!("platform: {}", rt.platform());
    println!(
        "workers: {} configured ({} hardware threads)",
        cfg.par.resolve(),
        std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
    );
    println!(
        "cache: {} at {:?} (resume {})",
        if cfg.cache { "enabled" } else { "disabled" },
        cfg.cache_dir,
        if cfg.resume { "on" } else { "off" }
    );
    let dir = std::path::Path::new(&cfg.artifacts);
    if !dir.exists() {
        println!("no artifacts at {dir:?} — run `make artifacts`");
        return Ok(());
    }
    let mut entries: Vec<_> = std::fs::read_dir(dir)?.collect::<Result<_, _>>()?;
    entries.sort_by_key(|e| e.path());
    for entry in entries {
        let p = entry.path();
        if p.join("manifest.json").exists() {
            let m = genie::runtime::Manifest::load(&p)?;
            println!(
                "  {}: {} blocks, {} quant layers, {} entrypoints",
                m.model,
                m.num_blocks,
                m.quant_layers.len(),
                m.entrypoints.len()
            );
        }
    }
    Ok(())
}

fn cmd_pretrain(cfg: &RunConfig) -> Result<()> {
    let rt = Runtime::cpu()?;
    let (mrt, dataset) = setup(&rt, cfg)?;
    let mut metrics = Metrics::with_dir(
        std::path::Path::new(&cfg.runs_dir).join(format!("pretrain_{}", cfg.model)),
    )?;
    let mut cache = open_cache(cfg)?;
    let teacher = coordinator::teacher_cached(
        &mrt, &dataset, &cfg.pretrain, &mut cache, &mut metrics,
    )?;
    let runs = std::path::Path::new(&cfg.runs_dir);
    std::fs::create_dir_all(runs)?;
    let ckpt = runs.join(format!("teacher_{}.bin", cfg.model));
    teacher.save(&ckpt)?;
    let acc = coordinator::eval_fp32_par(&mrt, &teacher, &dataset, cfg.par)?;
    println!("teacher saved to {ckpt:?}; FP32 top-1 {:.2}%", acc * 100.0);
    print_cache_stats(&cache);
    metrics.flush()
}

fn teacher_store(
    mrt: &ModelRt,
    dataset: &Dataset,
    cfg: &RunConfig,
    cache: &mut ArtifactCache,
    metrics: &mut Metrics,
) -> Result<genie::store::Store> {
    coordinator::teacher_cached(mrt, dataset, &cfg.pretrain, cache, metrics)
}

fn cmd_eval(cfg: &RunConfig) -> Result<()> {
    let rt = Runtime::cpu()?;
    let (mrt, dataset) = setup(&rt, cfg)?;
    let mut metrics = Metrics::new();
    let mut cache = open_cache(cfg)?;
    let teacher = teacher_store(&mrt, &dataset, cfg, &mut cache, &mut metrics)?;
    let acc = coordinator::eval_fp32_par(&mrt, &teacher, &dataset, cfg.par)?;
    println!("{}: FP32 top-1 {:.2}%", cfg.model, acc * 100.0);
    Ok(())
}

fn cmd_distill(cfg: &RunConfig) -> Result<()> {
    let rt = Runtime::cpu()?;
    let (mrt, dataset) = setup(&rt, cfg)?;
    let mut metrics = Metrics::with_dir(
        std::path::Path::new(&cfg.runs_dir).join(format!("distill_{}", cfg.model)),
    )?;
    let mut cache = open_cache(cfg)?;
    let teacher = teacher_store(&mrt, &dataset, cfg, &mut cache, &mut metrics)?;
    let out = coordinator::distill_cached(
        &mrt, &teacher, &cfg.distill, &mut cache, &mut metrics,
    )?;
    let mut s = genie::store::Store::new();
    s.insert("images", out.images);
    let path = std::path::Path::new(&cfg.runs_dir)
        .join(format!("synthetic_{}.bin", cfg.model));
    s.save(&path)?;
    println!("synthetic images saved to {path:?}");
    print_cache_stats(&cache);
    metrics.flush()
}

fn cmd_export(cfg: &RunConfig) -> Result<()> {
    // ZSQ then harden + emit the deployable integer artifact
    let rt = Runtime::cpu()?;
    let (mrt, dataset) = setup(&rt, cfg)?;
    let mut metrics = Metrics::new();
    let mut cache = open_cache(cfg)?;
    let teacher = teacher_store(&mrt, &dataset, cfg, &mut cache, &mut metrics)?;
    let out = coordinator::distill_cached(
        &mrt, &teacher, &cfg.distill, &mut cache, &mut metrics,
    )?;
    let qstate = coordinator::quantize_cached(
        &mrt, &teacher, &out.images, &cfg.quant, &mut cache, &mut metrics,
    )?;
    let (store, fp_bytes, q_bits) =
        genie::quant::export::export_model(&mrt.manifest, &qstate)?;
    let runs = std::path::Path::new(&cfg.runs_dir);
    std::fs::create_dir_all(runs)?;
    let path = runs.join(format!(
        "int_{}_w{}a{}.bin", cfg.model, cfg.quant.wbits, cfg.quant.abits
    ));
    store.save(&path)?;
    let qpath = runs.join(format!(
        "qstate_{}_w{}a{}.bin", cfg.model, cfg.quant.wbits, cfg.quant.abits
    ));
    qstate.save(&qpath)?;
    println!(
        "exported {path:?}: {} FP32 KiB -> {} quantized KiB ({:.1}x smaller); qstate {qpath:?}",
        fp_bytes / 1024,
        q_bits / 8 / 1024,
        fp_bytes as f64 / (q_bits as f64 / 8.0)
    );
    print_cache_stats(&cache);
    Ok(())
}

fn cmd_report() -> Result<()> {
    // aggregate results/*.csv into a single markdown report
    let dir = std::path::Path::new("results");
    anyhow::ensure!(dir.exists(), "no results/ directory — run experiments first");
    let mut names: Vec<_> = std::fs::read_dir(dir)?
        .filter_map(|e| e.ok())
        .map(|e| e.path())
        .filter(|p| p.extension().is_some_and(|x| x == "csv"))
        .collect();
    names.sort();
    let mut md = String::from("# GENIE experiment report\n");
    for path in names {
        let text = std::fs::read_to_string(&path)?;
        md.push_str(&format!(
            "\n## {}\n\n",
            path.file_stem().unwrap().to_string_lossy()
        ));
        for (i, line) in text.lines().enumerate() {
            md.push_str(&format!("| {} |\n", line.replace(',', " | ")));
            if i == 0 {
                let cols = line.split(',').count();
                md.push_str(&format!("|{}\n", "---|".repeat(cols)));
            }
        }
    }
    std::fs::write("results/REPORT.md", &md)?;
    println!("wrote results/REPORT.md ({} bytes)", md.len());
    Ok(())
}

fn cmd_zsq(cfg: &RunConfig) -> Result<()> {
    let rt = Runtime::cpu()?;
    let (mrt, dataset) = setup(&rt, cfg)?;
    let mut metrics = Metrics::with_dir(
        std::path::Path::new(&cfg.runs_dir).join(format!(
            "zsq_{}_w{}a{}",
            cfg.model, cfg.quant.wbits, cfg.quant.abits
        )),
    )?;
    let mut cache = open_cache(cfg)?;
    let teacher = teacher_store(&mrt, &dataset, cfg, &mut cache, &mut metrics)?;
    let out = zsq(
        &mrt, &teacher, &dataset, &cfg.distill, &cfg.quant, &mut cache,
        &mut metrics,
    )?;
    out.print("zsq");
    print_cache_stats(&cache);
    write_json(cfg, &out.to_json(Some(cache.stats())))?;
    metrics.flush()
}

fn cmd_fsq(cfg: &RunConfig) -> Result<()> {
    let rt = Runtime::cpu()?;
    let (mrt, dataset) = setup(&rt, cfg)?;
    let mut metrics = Metrics::with_dir(
        std::path::Path::new(&cfg.runs_dir).join(format!(
            "fsq_{}_w{}a{}",
            cfg.model, cfg.quant.wbits, cfg.quant.abits
        )),
    )?;
    let mut cache = open_cache(cfg)?;
    let teacher = teacher_store(&mrt, &dataset, cfg, &mut cache, &mut metrics)?;
    let out = fsq(
        &mrt, &teacher, &dataset, cfg.fsq_samples, &cfg.quant, &mut cache,
        &mut metrics,
    )?;
    out.print("fsq");
    print_cache_stats(&cache);
    write_json(cfg, &out.to_json(Some(cache.stats())))?;
    metrics.flush()
}

/// Write the machine-readable outcome report when `--json` was given.
fn write_json(cfg: &RunConfig, json: &genie::runtime::json::Json) -> Result<()> {
    if let Some(path) = &cfg.json {
        std::fs::write(path, json.render())?;
        println!("wrote {path}");
    }
    Ok(())
}

/// Multi-run grid sweep on the shared-artifact scheduler (DESIGN.md
/// §11). `--dry-run` prints the resolved DAG — cells, deduplicated
/// stages, expected cache dispositions — and executes nothing.
fn cmd_grid(cfg: &RunConfig, axes: &[String], dry_run: bool) -> Result<()> {
    let mut grid = RunGrid::new();
    for a in axes {
        grid.parse_axis(a, cfg)?;
    }
    if dry_run {
        let cells = grid.cells(cfg)?;
        let mut manifests = std::collections::BTreeMap::new();
        for c in &cells {
            if !manifests.contains_key(&c.model) {
                let dir = std::path::Path::new(&cfg.artifacts).join(&c.model);
                manifests
                    .insert(c.model.clone(), genie::runtime::Manifest::load(dir)?);
            }
        }
        let plan = GridPlan::build(cells, &manifests, false)?;
        let cache = open_cache(cfg)?;
        let dataset = Dataset::load(&cfg.artifacts).ok();
        print!("{}", plan.render(&manifests, &cache, dataset.as_ref()));
        return Ok(());
    }
    let rt = Runtime::cpu()?;
    let mut metrics = Metrics::with_dir(
        std::path::Path::new(&cfg.runs_dir).join("grid"),
    )?;
    let out = genie::grid::execute(
        &rt, cfg, &grid, &GridOpts::default(), &mut metrics,
    )?;
    for cell in &out.cells {
        if let Some(o) = &cell.outcome {
            o.print(&cell.spec.label());
        } else if !cell.status.is_ok() {
            println!(
                "{}: {} ({})",
                cell.spec.label(),
                cell.status.as_str(),
                cell.status.describe().unwrap_or_default()
            );
        }
    }
    // the report and metrics land even when cells failed — the exit
    // code signals the failure, the JSON says which cells and why
    write_json(cfg, &out.to_json())?;
    metrics.flush()?;
    if !out.all_ok() {
        let bad = out.cells.iter().filter(|c| !c.status.is_ok()).count();
        bail!(
            "grid: {bad} of {} cell(s) did not complete (statuses in the \
             --json report)",
            out.cells.len()
        );
    }
    Ok(())
}

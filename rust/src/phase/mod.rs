//! Unified phase engine (DESIGN.md §9): every coordinator step loop —
//! pretrain, distill shards, quantize blocks, eval chunks, QAT — runs
//! through one [`StepLoop`] driver over the [`Phase`] trait.
//!
//! A `Phase` supplies the loop's varying parts: the entrypoint name, the
//! initial device upload (`init`), the per-step schedule scalars
//! (`before_step`), scalar observation (`after_step`, e.g. plateau
//! schedulers), the names of its resumable device state (`carried`), a
//! host-state snapshot (RNG streams, schedulers), and the phase-boundary
//! host sync (`finish`). The engine owns everything the five loops used
//! to duplicate: device residency across steps, `log_every`-clamped
//! scalar tracing (the final step always logs, labeled with its real
//! step), periodic checkpointing of carried state to GTS1, resume, and
//! graceful preemption via a step budget.
//!
//! Determinism contract: a phase draws randomness only from streams it
//! snapshots, so a loop interrupted at any step and resumed from its
//! checkpoint replays the exact remaining schedule — same RNG draws,
//! same scalars, same final tensors — as an uninterrupted run
//! (`tests/integration.rs` pins this over real artifacts).
//!
//! Fused dispatch (DESIGN.md §14): with `steps_per_dispatch` K > 1 and a
//! phase that opts in via [`Phase::fusible`], the engine speculatively
//! stages K steps' feeds against the live host state, executes them as
//! ONE `call_device_fused` dispatch (per-step scalars downloaded as one
//! K-vector), then validates the speculation by replaying the host side
//! from a snapshot with the real scalars in hand — committing exactly
//! the prefix of steps whose feeds were right. Because a step's feeds
//! can only diverge after a scalar-driven host transition (a plateau LR
//! drop), the prefix is never empty and the result is bit-identical to
//! K=1 for any K: same RNG draws, same trace, same final tensors.

pub mod checkpoint;

use anyhow::Result;

use crate::runtime::{DeviceStore, LoadedEntry, ModelRt, Scalars};
use crate::store::Store;

pub use checkpoint::{CheckpointCfg, StageCkpt};

/// One pipeline stage's step-loop contract, driven by [`StepLoop`].
pub trait Phase {
    /// Phase name for logs and error context ("pretrain", "distill", ...).
    fn name(&self) -> String;

    /// Manifest entrypoint dispatched every step.
    fn entry(&self) -> String;

    /// Upload/derive the initial device state. Skipped when the engine
    /// resumes from a checkpoint (the checkpoint supplies that state).
    fn init(&mut self, dev: &mut DeviceStore) -> Result<()>;

    /// Host-side work before step `t` (1-based): schedule scalars,
    /// batch staging, buffer aliases.
    fn before_step(&mut self, t: usize, dev: &mut DeviceStore) -> Result<()>;

    /// Observe step `t`'s scalar results (plateau schedulers, per-step
    /// accumulation). `dev` is live for phases that fetch a non-scalar
    /// result per step (eval logits).
    fn after_step(
        &mut self,
        t: usize,
        scalars: &Scalars,
        dev: &mut DeviceStore,
    ) -> Result<()> {
        let _ = (t, scalars, dev);
        Ok(())
    }

    /// Device tensor names that constitute the phase's resumable state —
    /// what a checkpoint persists and a resume re-uploads.
    fn carried(&self) -> Vec<String>;

    /// Host-side mutable state (RNG streams, schedulers) as tensors;
    /// stored in every checkpoint and handed back through `restore`.
    fn snapshot(&self) -> Store {
        Store::new()
    }

    /// Restore host-side state from a checkpoint snapshot.
    fn restore(&mut self, snap: &Store) -> Result<()> {
        let _ = snap;
        Ok(())
    }

    /// May the engine drive this phase through the fused K-step dispatch
    /// path? Opting in asserts the full determinism contract the fused
    /// speculation leans on: `before_step` is a pure function of host
    /// state that `snapshot`/`restore` captures *completely* (so it can
    /// be replayed), it only writes `insert`/`alias` feeds (no fetches),
    /// and `after_step` reads nothing but the step's scalars (no
    /// per-step device work, which a megastep could not interleave).
    /// Default false: single-step dispatch, exactly as before.
    fn fusible(&self) -> bool {
        false
    }

    /// Phase boundary: materialize the phase's product on the host.
    fn finish(&mut self, dev: &mut DeviceStore) -> Result<Store>;
}

/// What one [`StepLoop::run`] produced.
#[derive(Debug)]
pub struct LoopOutcome {
    /// `finish`'s product (empty when `completed` is false).
    pub result: Store,
    /// `(step, scalars)` at each logged step — `log_every` cadence plus
    /// the final step; on resume the checkpointed prefix is kept, so the
    /// trace covers the whole loop, not just this invocation.
    pub trace: Vec<(usize, Scalars)>,
    /// False iff the step budget ran out before the final step (a
    /// checkpoint was written; re-run with `resume` to continue).
    pub completed: bool,
    /// Step the run resumed from (0 = fresh start).
    pub resumed_from: usize,
    /// Steps actually executed in this invocation.
    pub ran_steps: usize,
    /// Device dispatches issued for those steps: equal to `ran_steps`
    /// on the single-step path, one per megastep on the fused path.
    pub dispatches: usize,
    pub checkpoints_written: usize,
    /// Total bytes of checkpoint files written.
    pub checkpoint_bytes: u64,
}

/// The engine: drives a [`Phase`] for `steps` steps over a device-
/// resident working set, dispatching through `Runtime::call_device`.
#[derive(Debug, Clone, Default)]
pub struct StepLoop {
    pub steps: usize,
    /// Scalar-trace cadence (0 = no trace). The final step always logs.
    pub log_every: usize,
    pub checkpoint: Option<CheckpointCfg>,
    /// K: device steps fused into one dispatch when the phase is
    /// [`fusible`](Phase::fusible) (≤ 1 = classic single-step dispatch).
    /// Identity-neutral by construction — never part of content keys.
    pub steps_per_dispatch: usize,
}

impl StepLoop {
    pub fn new(steps: usize, log_every: usize) -> Self {
        StepLoop {
            steps,
            log_every,
            checkpoint: None,
            steps_per_dispatch: 1,
        }
    }

    /// Attach (or not) a checkpoint policy — `None` threads through so
    /// call sites can forward an optional stage config unconditionally.
    pub fn with_checkpoint(mut self, ck: Option<CheckpointCfg>) -> Self {
        self.checkpoint = ck;
        self
    }

    /// Set K, the megastep width (values ≤ 1 mean single-step dispatch).
    pub fn with_steps_per_dispatch(mut self, k: usize) -> Self {
        self.steps_per_dispatch = k.max(1);
        self
    }

    /// Run the loop. `dev` holds whatever is already resident (e.g. the
    /// Arc-shared teacher); `init` (fresh start) or the checkpoint
    /// (resume) supplies the phase's own state on top.
    pub fn run<P: Phase + ?Sized>(
        &self,
        mrt: &ModelRt,
        phase: &mut P,
        dev: &mut DeviceStore,
    ) -> Result<LoopOutcome> {
        // deterministic fault-injection site (DESIGN.md §13):
        // GENIE_FAULTS=steploop:<phase-name>:attemptN=... fires here
        crate::faults::check("steploop", &phase.name())?;
        let mut start = 0usize;
        let mut trace: Vec<(usize, Scalars)> = Vec::new();
        let mut restored = false;
        if let Some(ck) = &self.checkpoint {
            if ck.resume && ck.path.exists() {
                let snap = checkpoint::read(&ck.path)?;
                anyhow::ensure!(
                    snap.step <= self.steps,
                    "{}: checkpoint at step {} exceeds configured {} steps",
                    phase.name(),
                    snap.step,
                    self.steps
                );
                phase.restore(&snap.host)?;
                for (n, t) in &snap.carried {
                    dev.insert(n, t)?;
                }
                start = snap.step;
                trace = snap.trace;
                restored = true;
            }
        }
        if !restored {
            phase.init(dev)?;
        }

        // entry resolution is lazy so a loop that executes no steps
        // (resumed-at-end, zero budget) never needs a compiled graph
        let mut entry = None;
        let fused = self.steps_per_dispatch > 1 && phase.fusible();
        let mut executed = 0usize;
        let mut dispatches = 0usize;
        let mut written = 0usize;
        let mut ck_bytes = 0u64;
        let mut t = start;
        while t < self.steps {
            if let Some(ck) = &self.checkpoint {
                if ck.budget.is_some_and(|b| executed >= b) {
                    ck_bytes += checkpoint::write(
                        &ck.path,
                        t,
                        &phase.carried(),
                        &phase.snapshot(),
                        &trace,
                        dev,
                    )?;
                    written += 1;
                    return Ok(LoopOutcome {
                        result: Store::new(),
                        trace,
                        completed: false,
                        resumed_from: start,
                        ran_steps: executed,
                        dispatches,
                        checkpoints_written: written,
                        checkpoint_bytes: ck_bytes,
                    });
                }
            }
            if entry.is_none() {
                entry = Some(mrt.entry(&phase.entry())?);
            }
            if fused {
                // clamp the megastep to the remaining steps AND the
                // remaining budget, so graceful preemption lands on
                // exactly the same step count as a K=1 run would
                let mut k = self.steps_per_dispatch.min(self.steps - t);
                if let Some(b) =
                    self.checkpoint.as_ref().and_then(|ck| ck.budget)
                {
                    k = k.min(b - executed);
                }
                let committed = self.run_megastep(
                    mrt,
                    phase,
                    dev,
                    entry.as_ref().unwrap(),
                    t,
                    k,
                    &mut trace,
                )?;
                dispatches += 1;
                let t_old = t;
                t += committed;
                executed += committed;
                if let Some(ck) = &self.checkpoint {
                    // edge-aligned periodic checkpoints: write when the
                    // megastep crossed a multiple of `every` (at K=1
                    // this degenerates to the `t % every == 0` rule)
                    if ck.every > 0
                        && t / ck.every > t_old / ck.every
                        && t < self.steps
                    {
                        ck_bytes += checkpoint::write(
                            &ck.path,
                            t,
                            &phase.carried(),
                            &phase.snapshot(),
                            &trace,
                            dev,
                        )?;
                        written += 1;
                    }
                }
                continue;
            }
            t += 1;
            phase.before_step(t, dev)?;
            let scalars =
                mrt.rt.call_device(entry.as_ref().unwrap(), dev)?;
            dispatches += 1;
            phase.after_step(t, &scalars, dev)?;
            if self.log_every > 0
                && (t % self.log_every == 0 || t == self.steps)
            {
                trace.push((t, scalars));
            }
            executed += 1;
            if let Some(ck) = &self.checkpoint {
                if ck.every > 0 && t % ck.every == 0 && t < self.steps {
                    ck_bytes += checkpoint::write(
                        &ck.path,
                        t,
                        &phase.carried(),
                        &phase.snapshot(),
                        &trace,
                        dev,
                    )?;
                    written += 1;
                }
            }
        }
        let result = phase.finish(dev)?;
        if let Some(ck) = &self.checkpoint {
            // the loop completed; its in-progress checkpoint is obsolete
            std::fs::remove_file(&ck.path).ok();
        }
        Ok(LoopOutcome {
            result,
            trace,
            completed: true,
            resumed_from: start,
            ran_steps: executed,
            dispatches,
            checkpoints_written: written,
            checkpoint_bytes: ck_bytes,
        })
    }

    /// One megastep: speculatively stage up to `k` steps from global
    /// step `t`, execute them as one fused dispatch, validate the
    /// speculation by host replay, and commit the correct prefix.
    /// Returns how many steps committed (≥ 1).
    ///
    /// The only way staged feeds can be wrong is a scalar-driven host
    /// transition mid-megastep (e.g. a plateau scheduler dropping the LR
    /// after observing a fused step's loss): staging ran `before_step`
    /// with those observations still pending. The replay runs the exact
    /// K=1 host sequence — `before_step` (recorded, compared), then
    /// `after_step` with the real scalars — so the first step whose
    /// recorded feeds diverge bounds the prefix whose device results
    /// are exact. Step 0's feeds derive from the same host state the
    /// staging pass started from, so the prefix is never empty.
    #[allow(clippy::too_many_arguments)]
    fn run_megastep<P: Phase + ?Sized>(
        &self,
        mrt: &ModelRt,
        phase: &mut P,
        dev: &mut DeviceStore,
        entry: &LoadedEntry,
        t: usize,
        k: usize,
        trace: &mut Vec<(usize, Scalars)>,
    ) -> Result<usize> {
        let host0 = phase.snapshot();
        // speculative staging pass: record all k steps' feeds (no
        // uploads, no store mutation)
        dev.begin_staging();
        let mut stage_err = None;
        for i in 0..k {
            if i > 0 {
                dev.next_staged_step();
            }
            if let Err(e) = phase.before_step(t + i + 1, dev) {
                stage_err = Some(e);
                break;
            }
        }
        let staged = dev.end_staging();
        if let Some(e) = stage_err {
            return Err(e);
        }
        // one device dispatch for all k steps; the store is untouched
        // until commit, so a shorter prefix needs no rollback
        let (scalars, results) =
            mrt.rt.call_device_fused(entry, dev, &staged)?;
        // validation replay from the megastep-entry snapshot, feeding
        // the real scalars through after_step as K=1 would have
        phase.restore(&host0)?;
        let mut commit = k;
        for (i, step_scalars) in scalars.iter().enumerate() {
            dev.begin_staging();
            let r = phase.before_step(t + i + 1, dev);
            let replayed = dev.end_staging();
            r?;
            if !staged.step_matches(i, replayed.step(0)) {
                commit = i;
                break;
            }
            phase.after_step(t + i + 1, step_scalars, dev)?;
        }
        anyhow::ensure!(
            commit >= 1,
            "{}: fused step {} diverged on replay — the phase's \
             snapshot/restore does not capture its host state fully, so \
             it must not claim fusible()",
            phase.name(),
            t + 1
        );
        if commit < k {
            // the divergence-detecting replay already ran the
            // mismatching before_step, advancing RNG streams past the
            // prefix; rewind and replay exactly the committed steps
            // (feeds muted through a throwaway staging recorder)
            phase.restore(&host0)?;
            for (i, step_scalars) in scalars.iter().take(commit).enumerate()
            {
                dev.begin_staging();
                let r = phase.before_step(t + i + 1, dev);
                dev.end_staging();
                r?;
                phase.after_step(t + i + 1, step_scalars, dev)?;
            }
        }
        // the prefix's device results are exact: wire step commit-1 in
        mrt.rt.commit_fused(entry, dev, results, commit)?;
        // trace with true global step labels — correct for any K vs
        // log_every relation, and the final step always logs
        if self.log_every > 0 {
            for (i, step_scalars) in
                scalars.iter().take(commit).enumerate()
            {
                let g = t + i + 1;
                if g % self.log_every == 0 || g == self.steps {
                    trace.push((g, step_scalars.clone()));
                }
            }
        }
        Ok(commit)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::Runtime;
    use crate::tensor::Tensor;

    /// A phase that never dispatches (steps = 0 or budget = 0), enough to
    /// exercise the engine's init/resume/finish/checkpoint skeleton on
    /// the offline stub.
    struct Probe {
        inited: bool,
        restored: bool,
        finished: bool,
    }

    impl Probe {
        fn new() -> Self {
            Probe { inited: false, restored: false, finished: false }
        }
    }

    impl Phase for Probe {
        fn name(&self) -> String {
            "probe".into()
        }

        fn entry(&self) -> String {
            "never_dispatched".into()
        }

        fn init(&mut self, dev: &mut DeviceStore) -> Result<()> {
            self.inited = true;
            dev.insert("state", &Tensor::from_f32(&[2], vec![1.0, 2.0]))?;
            Ok(())
        }

        fn before_step(
            &mut self,
            _t: usize,
            _dev: &mut DeviceStore,
        ) -> Result<()> {
            anyhow::bail!("probe must never step")
        }

        fn carried(&self) -> Vec<String> {
            vec!["state".into()]
        }

        fn snapshot(&self) -> Store {
            let mut s = Store::new();
            s.insert("mark", Tensor::scalar_f32(7.0));
            s
        }

        fn restore(&mut self, snap: &Store) -> Result<()> {
            anyhow::ensure!(snap.get("mark")?.scalar() == 7.0);
            self.restored = true;
            Ok(())
        }

        fn finish(&mut self, dev: &mut DeviceStore) -> Result<Store> {
            self.finished = true;
            let mut out = Store::new();
            out.insert("state", dev.fetch("state")?);
            Ok(out)
        }
    }

    #[test]
    fn zero_step_loop_inits_and_finishes() {
        let rt = Runtime::cpu().unwrap();
        let mrt = fake_mrt(&rt);
        let mut dev = rt.device_store();
        let mut phase = Probe::new();
        let out = StepLoop::new(0, 10).run(&mrt, &mut phase, &mut dev).unwrap();
        assert!(phase.inited && phase.finished && !phase.restored);
        assert!(out.completed);
        assert_eq!(out.ran_steps, 0);
        assert_eq!(out.result.get("state").unwrap().as_f32(), &[1.0, 2.0]);
    }

    #[test]
    fn zero_budget_checkpoints_then_resumes() {
        let rt = Runtime::cpu().unwrap();
        let mrt = fake_mrt(&rt);
        let dir = std::env::temp_dir().join("genie_steploop_test");
        std::fs::remove_dir_all(&dir).ok();
        let ck = CheckpointCfg {
            path: dir.join("probe.ckpt"),
            every: 0,
            resume: true,
            budget: Some(0),
        };

        // run 1: init, then the zero budget forces an immediate checkpoint
        let mut dev = rt.device_store();
        let mut phase = Probe::new();
        let out = StepLoop::new(5, 1)
            .with_checkpoint(Some(ck.clone()))
            .run(&mrt, &mut phase, &mut dev)
            .unwrap();
        assert!(!out.completed);
        assert!(phase.inited && !phase.finished);
        assert_eq!(out.checkpoints_written, 1);
        assert!(out.checkpoint_bytes > 0);
        assert!(ck.path.exists());

        // run 2: resumes (restore, not init), carried state re-uploaded;
        // steps clamped to the checkpoint step so nothing dispatches
        let mut dev2 = rt.device_store();
        let mut phase2 = Probe::new();
        let out2 = StepLoop::new(0, 1)
            .with_checkpoint(Some(CheckpointCfg { budget: None, ..ck.clone() }))
            .run(&mrt, &mut phase2, &mut dev2)
            .unwrap();
        assert!(out2.completed);
        assert!(phase2.restored && !phase2.inited && phase2.finished);
        assert_eq!(out2.resumed_from, 0);
        assert_eq!(out2.result.get("state").unwrap().as_f32(), &[1.0, 2.0]);
        // a completed loop removes its in-progress checkpoint
        assert!(!ck.path.exists());
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn oversized_checkpoint_step_is_rejected() {
        let rt = Runtime::cpu().unwrap();
        let mrt = fake_mrt(&rt);
        let dir = std::env::temp_dir().join("genie_steploop_reject_test");
        std::fs::remove_dir_all(&dir).ok();
        let ck = CheckpointCfg {
            path: dir.join("probe.ckpt"),
            every: 0,
            resume: true,
            budget: Some(0),
        };
        let mut dev = rt.device_store();
        let mut phase = Probe::new();
        // write a checkpoint at step 3 (budget 0 fires after a fake
        // resume start): simplest is a hand-built file
        let host = phase.snapshot();
        phase.init(&mut dev).unwrap();
        checkpoint::write(&ck.path, 3, &phase.carried(), &host, &[], &mut dev)
            .unwrap();
        let mut dev2 = rt.device_store();
        let mut phase2 = Probe::new();
        let err = StepLoop::new(2, 1)
            .with_checkpoint(Some(ck))
            .run(&mrt, &mut phase2, &mut dev2)
            .unwrap_err();
        assert!(format!("{err}").contains("exceeds"));
        std::fs::remove_dir_all(&dir).ok();
    }

    /// A ModelRt over a synthetic manifest — never dispatched in these
    /// tests, only threaded for its runtime handle.
    fn fake_mrt(rt: &Runtime) -> ModelRt<'_> {
        let manifest = crate::runtime::Manifest::from_json_text(
            r#"{
                "model": "probe", "image": [2, 2, 1], "num_classes": 2,
                "num_blocks": 1, "latent": 4,
                "batch": {"train": 1},
                "params": [], "bn": [], "qstate": [], "gen_params": [],
                "quant_layers": [], "learnable": {"0": []},
                "bounds": [], "entrypoints": {}
            }"#,
        )
        .unwrap();
        ModelRt { rt, dir: std::path::PathBuf::from("."), manifest }
    }

    /// A ModelRt whose manifest declares the `fused_step` entrypoint,
    /// with a matching host-fn executable pre-registered in the compile
    /// cache: state' = state - lr, loss = state'. The `noise` arg is a
    /// per-step host feed the program ignores — it models an RNG-derived
    /// feed whose stream must survive the fused replay protocol.
    fn fused_mrt(rt: &Runtime) -> ModelRt<'_> {
        let manifest = crate::runtime::Manifest::from_json_text(
            r#"{
                "model": "probe", "image": [2, 2, 1], "num_classes": 2,
                "num_blocks": 1, "latent": 4,
                "batch": {"train": 1},
                "params": [], "bn": [], "qstate": [], "gen_params": [],
                "quant_layers": [], "learnable": {"0": []},
                "bounds": [], "entrypoints": {
                    "fused_step": {
                        "file": "fused_step_test.hlo.txt",
                        "args": [
                            ["state", "f32", []],
                            ["lr", "f32", []],
                            ["noise", "f32", []]
                        ],
                        "results": [
                            ["state", "f32", []],
                            ["loss", "f32", []]
                        ]
                    }
                }
            }"#,
        )
        .unwrap();
        let spec = manifest.entry("fused_step").unwrap().clone();
        let exe = xla::PjRtLoadedExecutable::from_host_fn(2, |args| {
            let state = args[0].to_vec::<f32>()?[0];
            let lr = args[1].to_vec::<f32>()?[0];
            let next = state - lr;
            Ok(vec![
                xla::Literal::vec1(&[next]).reshape(&[])?,
                xla::Literal::vec1(&[next]).reshape(&[])?,
            ])
        });
        rt.register_entry(".", "fused_step", spec, exe);
        ModelRt { rt, dir: std::path::PathBuf::from("."), manifest }
    }

    /// A fusible phase with plateau-style scalar feedback: LR drops to
    /// 0.25 the first time the loss falls below 6.5 — which, under a
    /// wide megastep, happens *mid-dispatch* and forces the speculation
    /// to commit a short prefix. `draws` models an RNG stream (advanced
    /// by every before_step, emitted as the `noise` feed), so any replay
    /// over- or under-run shows up as a diverged feed or final state.
    struct PlateauProbe {
        lr: f32,
        draws: u32,
    }

    impl PlateauProbe {
        fn new() -> Self {
            PlateauProbe { lr: 1.0, draws: 0 }
        }
    }

    impl Phase for PlateauProbe {
        fn name(&self) -> String {
            "plateau_probe".into()
        }

        fn entry(&self) -> String {
            "fused_step".into()
        }

        fn init(&mut self, dev: &mut DeviceStore) -> Result<()> {
            dev.insert("state", &Tensor::scalar_f32(10.0))
        }

        fn before_step(
            &mut self,
            _t: usize,
            dev: &mut DeviceStore,
        ) -> Result<()> {
            self.draws += 1;
            dev.insert("lr", &Tensor::scalar_f32(self.lr))?;
            dev.insert("noise", &Tensor::scalar_f32(self.draws as f32))
        }

        fn after_step(
            &mut self,
            _t: usize,
            scalars: &Scalars,
            _dev: &mut DeviceStore,
        ) -> Result<()> {
            if scalars["loss"] < 6.5 && self.lr > 0.25 {
                self.lr = 0.25;
            }
            Ok(())
        }

        fn carried(&self) -> Vec<String> {
            vec!["state".into()]
        }

        fn snapshot(&self) -> Store {
            let mut s = Store::new();
            s.insert("lr", Tensor::scalar_f32(self.lr));
            s.insert("draws", Tensor::from_u32(&[1], vec![self.draws]));
            s
        }

        fn restore(&mut self, snap: &Store) -> Result<()> {
            self.lr = snap.get("lr")?.scalar();
            self.draws = snap.get("draws")?.as_u32()[0];
            Ok(())
        }

        fn fusible(&self) -> bool {
            true
        }

        fn finish(&mut self, dev: &mut DeviceStore) -> Result<Store> {
            let mut out = Store::new();
            out.insert("state", dev.fetch("state")?);
            out.insert("lr", Tensor::scalar_f32(self.lr));
            out.insert("draws", Tensor::from_u32(&[1], vec![self.draws]));
            Ok(out)
        }
    }

    fn run_plateau(
        rt: &Runtime,
        k: usize,
        ck: Option<CheckpointCfg>,
    ) -> LoopOutcome {
        let mrt = fused_mrt(rt);
        let mut dev = rt.device_store();
        let mut phase = PlateauProbe::new();
        StepLoop::new(10, 3)
            .with_checkpoint(ck)
            .with_steps_per_dispatch(k)
            .run(&mrt, &mut phase, &mut dev)
            .unwrap()
    }

    fn assert_same_outcome(a: &LoopOutcome, b: &LoopOutcome) {
        assert_eq!(
            a.result.get("state").unwrap(),
            b.result.get("state").unwrap(),
            "final device state diverged"
        );
        assert_eq!(
            a.result.get("lr").unwrap(),
            b.result.get("lr").unwrap(),
            "final host LR diverged"
        );
        assert_eq!(
            a.result.get("draws").unwrap(),
            b.result.get("draws").unwrap(),
            "RNG stream position diverged"
        );
        let labels = |o: &LoopOutcome| -> Vec<usize> {
            o.trace.iter().map(|(t, _)| *t).collect()
        };
        assert_eq!(labels(a), labels(b), "trace labels diverged");
        for ((ta, sa), (tb, sb)) in a.trace.iter().zip(b.trace.iter()) {
            assert_eq!(ta, tb);
            assert_eq!(sa["loss"], sb["loss"], "trace at step {ta} diverged");
        }
    }

    #[test]
    fn fused_loop_bit_identical_to_single_step_through_plateau_drop() {
        let rt = Runtime::cpu().unwrap();
        let k1 = run_plateau(&rt, 1, None);
        for k in [2, 4, 8, 16] {
            let kk = run_plateau(&rt, k, None);
            assert!(kk.completed);
            assert_same_outcome(&k1, &kk);
            assert!(
                kk.dispatches < k1.dispatches || k == 1,
                "K={k} used {} dispatches, K=1 used {}",
                kk.dispatches,
                k1.dispatches
            );
        }
        // K=1: one dispatch per step; the plateau drop (step 4) splits
        // the first K=8 megastep into 4+6 → exactly 2 dispatches
        assert_eq!(k1.dispatches, 10);
        assert_eq!(run_plateau(&rt, 8, None).dispatches, 2);
    }

    #[test]
    fn fused_trace_labels_match_k1_when_log_every_divides_neither() {
        // steps=10, log_every=3, K=8: megasteps commit 4 then 6, so the
        // logged steps 3, 6, 9 and the forced final 10 all land inside
        // megasteps, never on their edges
        let rt = Runtime::cpu().unwrap();
        let k8 = run_plateau(&rt, 8, None);
        let labels: Vec<usize> = k8.trace.iter().map(|(t, _)| *t).collect();
        assert_eq!(labels, vec![3, 6, 9, 10]);
        assert_same_outcome(&run_plateau(&rt, 1, None), &k8);
    }

    #[test]
    fn fused_budget_preempts_at_the_same_step_as_single_dispatch() {
        let rt = Runtime::cpu().unwrap();
        let dir = std::env::temp_dir().join("genie_fused_budget_test");
        std::fs::remove_dir_all(&dir).ok();
        std::fs::create_dir_all(&dir).unwrap();
        let ck = |name: &str, budget: Option<usize>| CheckpointCfg {
            path: dir.join(name),
            every: 0,
            resume: true,
            budget,
        };

        // budget 3 is *inside* what the first K=8 megastep would cover:
        // the clamp must stop the fused run at exactly step 3
        let a = run_plateau(&rt, 8, Some(ck("fused.ckpt", Some(3))));
        assert!(!a.completed);
        assert_eq!(a.ran_steps, 3);
        let b = run_plateau(&rt, 1, Some(ck("single.ckpt", Some(3))));
        assert_eq!(b.ran_steps, 3);

        // cross-K resume: the fused checkpoint resumed at K=1, and the
        // single-step checkpoint resumed at K=8, both land bit-identical
        // to an uninterrupted K=1 run
        let reference = run_plateau(&rt, 1, None);
        let resumed_single =
            run_plateau(&rt, 1, Some(ck("fused.ckpt", None)));
        let resumed_fused =
            run_plateau(&rt, 8, Some(ck("single.ckpt", None)));
        assert!(resumed_single.completed && resumed_fused.completed);
        assert_eq!(resumed_single.resumed_from, 3);
        assert_eq!(resumed_fused.resumed_from, 3);
        assert_same_outcome(&reference, &resumed_single);
        assert_same_outcome(&reference, &resumed_fused);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn fused_periodic_checkpoints_land_on_megastep_edges() {
        let rt = Runtime::cpu().unwrap();
        let dir = std::env::temp_dir().join("genie_fused_edge_test");
        std::fs::remove_dir_all(&dir).ok();
        std::fs::create_dir_all(&dir).unwrap();
        let ck = CheckpointCfg {
            path: dir.join("edge.ckpt"),
            every: 4,
            resume: true,
            budget: None,
        };
        // megasteps commit 4 then 6: t crosses 4 at an edge (write),
        // crosses 8 mid-flight and only surfaces at t=10 == steps (no
        // write) — K=1 would write at 4 and 8
        let out = run_plateau(&rt, 8, Some(ck.clone()));
        assert!(out.completed);
        assert_eq!(out.checkpoints_written, 1);
        // completion removed the in-progress checkpoint
        assert!(!ck.path.exists());
        assert_same_outcome(&run_plateau(&rt, 1, None), &out);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn non_fusible_phases_ignore_steps_per_dispatch() {
        // Probe::fusible() is default-false and its before_step bails:
        // a K=8 loop over it must take the single-step path and so
        // never reach before_step when steps == 0
        let rt = Runtime::cpu().unwrap();
        let mrt = fake_mrt(&rt);
        let mut dev = rt.device_store();
        let mut phase = Probe::new();
        let out = StepLoop::new(0, 10)
            .with_steps_per_dispatch(8)
            .run(&mrt, &mut phase, &mut dev)
            .unwrap();
        assert!(out.completed);
        assert_eq!(out.dispatches, 0);
        assert!(!phase.restored);
    }
}

//! Unified phase engine (DESIGN.md §9): every coordinator step loop —
//! pretrain, distill shards, quantize blocks, eval chunks, QAT — runs
//! through one [`StepLoop`] driver over the [`Phase`] trait.
//!
//! A `Phase` supplies the loop's varying parts: the entrypoint name, the
//! initial device upload (`init`), the per-step schedule scalars
//! (`before_step`), scalar observation (`after_step`, e.g. plateau
//! schedulers), the names of its resumable device state (`carried`), a
//! host-state snapshot (RNG streams, schedulers), and the phase-boundary
//! host sync (`finish`). The engine owns everything the five loops used
//! to duplicate: device residency across steps, `log_every`-clamped
//! scalar tracing (the final step always logs, labeled with its real
//! step), periodic checkpointing of carried state to GTS1, resume, and
//! graceful preemption via a step budget.
//!
//! Determinism contract: a phase draws randomness only from streams it
//! snapshots, so a loop interrupted at any step and resumed from its
//! checkpoint replays the exact remaining schedule — same RNG draws,
//! same scalars, same final tensors — as an uninterrupted run
//! (`tests/integration.rs` pins this over real artifacts).

pub mod checkpoint;

use anyhow::Result;

use crate::runtime::{DeviceStore, ModelRt, Scalars};
use crate::store::Store;

pub use checkpoint::{CheckpointCfg, StageCkpt};

/// One pipeline stage's step-loop contract, driven by [`StepLoop`].
pub trait Phase {
    /// Phase name for logs and error context ("pretrain", "distill", ...).
    fn name(&self) -> String;

    /// Manifest entrypoint dispatched every step.
    fn entry(&self) -> String;

    /// Upload/derive the initial device state. Skipped when the engine
    /// resumes from a checkpoint (the checkpoint supplies that state).
    fn init(&mut self, dev: &mut DeviceStore) -> Result<()>;

    /// Host-side work before step `t` (1-based): schedule scalars,
    /// batch staging, buffer aliases.
    fn before_step(&mut self, t: usize, dev: &mut DeviceStore) -> Result<()>;

    /// Observe step `t`'s scalar results (plateau schedulers, per-step
    /// accumulation). `dev` is live for phases that fetch a non-scalar
    /// result per step (eval logits).
    fn after_step(
        &mut self,
        t: usize,
        scalars: &Scalars,
        dev: &mut DeviceStore,
    ) -> Result<()> {
        let _ = (t, scalars, dev);
        Ok(())
    }

    /// Device tensor names that constitute the phase's resumable state —
    /// what a checkpoint persists and a resume re-uploads.
    fn carried(&self) -> Vec<String>;

    /// Host-side mutable state (RNG streams, schedulers) as tensors;
    /// stored in every checkpoint and handed back through `restore`.
    fn snapshot(&self) -> Store {
        Store::new()
    }

    /// Restore host-side state from a checkpoint snapshot.
    fn restore(&mut self, snap: &Store) -> Result<()> {
        let _ = snap;
        Ok(())
    }

    /// Phase boundary: materialize the phase's product on the host.
    fn finish(&mut self, dev: &mut DeviceStore) -> Result<Store>;
}

/// What one [`StepLoop::run`] produced.
#[derive(Debug)]
pub struct LoopOutcome {
    /// `finish`'s product (empty when `completed` is false).
    pub result: Store,
    /// `(step, scalars)` at each logged step — `log_every` cadence plus
    /// the final step; on resume the checkpointed prefix is kept, so the
    /// trace covers the whole loop, not just this invocation.
    pub trace: Vec<(usize, Scalars)>,
    /// False iff the step budget ran out before the final step (a
    /// checkpoint was written; re-run with `resume` to continue).
    pub completed: bool,
    /// Step the run resumed from (0 = fresh start).
    pub resumed_from: usize,
    /// Steps actually executed in this invocation.
    pub ran_steps: usize,
    pub checkpoints_written: usize,
    /// Total bytes of checkpoint files written.
    pub checkpoint_bytes: u64,
}

/// The engine: drives a [`Phase`] for `steps` steps over a device-
/// resident working set, dispatching through `Runtime::call_device`.
#[derive(Debug, Clone, Default)]
pub struct StepLoop {
    pub steps: usize,
    /// Scalar-trace cadence (0 = no trace). The final step always logs.
    pub log_every: usize,
    pub checkpoint: Option<CheckpointCfg>,
}

impl StepLoop {
    pub fn new(steps: usize, log_every: usize) -> Self {
        StepLoop { steps, log_every, checkpoint: None }
    }

    /// Attach (or not) a checkpoint policy — `None` threads through so
    /// call sites can forward an optional stage config unconditionally.
    pub fn with_checkpoint(mut self, ck: Option<CheckpointCfg>) -> Self {
        self.checkpoint = ck;
        self
    }

    /// Run the loop. `dev` holds whatever is already resident (e.g. the
    /// Arc-shared teacher); `init` (fresh start) or the checkpoint
    /// (resume) supplies the phase's own state on top.
    pub fn run<P: Phase + ?Sized>(
        &self,
        mrt: &ModelRt,
        phase: &mut P,
        dev: &mut DeviceStore,
    ) -> Result<LoopOutcome> {
        // deterministic fault-injection site (DESIGN.md §13):
        // GENIE_FAULTS=steploop:<phase-name>:attemptN=... fires here
        crate::faults::check("steploop", &phase.name())?;
        let mut start = 0usize;
        let mut trace: Vec<(usize, Scalars)> = Vec::new();
        let mut restored = false;
        if let Some(ck) = &self.checkpoint {
            if ck.resume && ck.path.exists() {
                let snap = checkpoint::read(&ck.path)?;
                anyhow::ensure!(
                    snap.step <= self.steps,
                    "{}: checkpoint at step {} exceeds configured {} steps",
                    phase.name(),
                    snap.step,
                    self.steps
                );
                phase.restore(&snap.host)?;
                for (n, t) in &snap.carried {
                    dev.insert(n, t)?;
                }
                start = snap.step;
                trace = snap.trace;
                restored = true;
            }
        }
        if !restored {
            phase.init(dev)?;
        }

        // entry resolution is lazy so a loop that executes no steps
        // (resumed-at-end, zero budget) never needs a compiled graph
        let mut entry = None;
        let mut executed = 0usize;
        let mut written = 0usize;
        let mut ck_bytes = 0u64;
        let mut t = start;
        while t < self.steps {
            if let Some(ck) = &self.checkpoint {
                if ck.budget.is_some_and(|b| executed >= b) {
                    ck_bytes += checkpoint::write(
                        &ck.path,
                        t,
                        &phase.carried(),
                        &phase.snapshot(),
                        &trace,
                        dev,
                    )?;
                    written += 1;
                    return Ok(LoopOutcome {
                        result: Store::new(),
                        trace,
                        completed: false,
                        resumed_from: start,
                        ran_steps: executed,
                        checkpoints_written: written,
                        checkpoint_bytes: ck_bytes,
                    });
                }
            }
            if entry.is_none() {
                entry = Some(mrt.entry(&phase.entry())?);
            }
            t += 1;
            phase.before_step(t, dev)?;
            let scalars =
                mrt.rt.call_device(entry.as_ref().unwrap(), dev)?;
            phase.after_step(t, &scalars, dev)?;
            if self.log_every > 0
                && (t % self.log_every == 0 || t == self.steps)
            {
                trace.push((t, scalars));
            }
            executed += 1;
            if let Some(ck) = &self.checkpoint {
                if ck.every > 0 && t % ck.every == 0 && t < self.steps {
                    ck_bytes += checkpoint::write(
                        &ck.path,
                        t,
                        &phase.carried(),
                        &phase.snapshot(),
                        &trace,
                        dev,
                    )?;
                    written += 1;
                }
            }
        }
        let result = phase.finish(dev)?;
        if let Some(ck) = &self.checkpoint {
            // the loop completed; its in-progress checkpoint is obsolete
            std::fs::remove_file(&ck.path).ok();
        }
        Ok(LoopOutcome {
            result,
            trace,
            completed: true,
            resumed_from: start,
            ran_steps: executed,
            checkpoints_written: written,
            checkpoint_bytes: ck_bytes,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::Runtime;
    use crate::tensor::Tensor;

    /// A phase that never dispatches (steps = 0 or budget = 0), enough to
    /// exercise the engine's init/resume/finish/checkpoint skeleton on
    /// the offline stub.
    struct Probe {
        inited: bool,
        restored: bool,
        finished: bool,
    }

    impl Probe {
        fn new() -> Self {
            Probe { inited: false, restored: false, finished: false }
        }
    }

    impl Phase for Probe {
        fn name(&self) -> String {
            "probe".into()
        }

        fn entry(&self) -> String {
            "never_dispatched".into()
        }

        fn init(&mut self, dev: &mut DeviceStore) -> Result<()> {
            self.inited = true;
            dev.insert("state", &Tensor::from_f32(&[2], vec![1.0, 2.0]))?;
            Ok(())
        }

        fn before_step(
            &mut self,
            _t: usize,
            _dev: &mut DeviceStore,
        ) -> Result<()> {
            anyhow::bail!("probe must never step")
        }

        fn carried(&self) -> Vec<String> {
            vec!["state".into()]
        }

        fn snapshot(&self) -> Store {
            let mut s = Store::new();
            s.insert("mark", Tensor::scalar_f32(7.0));
            s
        }

        fn restore(&mut self, snap: &Store) -> Result<()> {
            anyhow::ensure!(snap.get("mark")?.scalar() == 7.0);
            self.restored = true;
            Ok(())
        }

        fn finish(&mut self, dev: &mut DeviceStore) -> Result<Store> {
            self.finished = true;
            let mut out = Store::new();
            out.insert("state", dev.fetch("state")?);
            Ok(out)
        }
    }

    #[test]
    fn zero_step_loop_inits_and_finishes() {
        let rt = Runtime::cpu().unwrap();
        let mrt = fake_mrt(&rt);
        let mut dev = rt.device_store();
        let mut phase = Probe::new();
        let out = StepLoop::new(0, 10).run(&mrt, &mut phase, &mut dev).unwrap();
        assert!(phase.inited && phase.finished && !phase.restored);
        assert!(out.completed);
        assert_eq!(out.ran_steps, 0);
        assert_eq!(out.result.get("state").unwrap().as_f32(), &[1.0, 2.0]);
    }

    #[test]
    fn zero_budget_checkpoints_then_resumes() {
        let rt = Runtime::cpu().unwrap();
        let mrt = fake_mrt(&rt);
        let dir = std::env::temp_dir().join("genie_steploop_test");
        std::fs::remove_dir_all(&dir).ok();
        let ck = CheckpointCfg {
            path: dir.join("probe.ckpt"),
            every: 0,
            resume: true,
            budget: Some(0),
        };

        // run 1: init, then the zero budget forces an immediate checkpoint
        let mut dev = rt.device_store();
        let mut phase = Probe::new();
        let out = StepLoop::new(5, 1)
            .with_checkpoint(Some(ck.clone()))
            .run(&mrt, &mut phase, &mut dev)
            .unwrap();
        assert!(!out.completed);
        assert!(phase.inited && !phase.finished);
        assert_eq!(out.checkpoints_written, 1);
        assert!(out.checkpoint_bytes > 0);
        assert!(ck.path.exists());

        // run 2: resumes (restore, not init), carried state re-uploaded;
        // steps clamped to the checkpoint step so nothing dispatches
        let mut dev2 = rt.device_store();
        let mut phase2 = Probe::new();
        let out2 = StepLoop::new(0, 1)
            .with_checkpoint(Some(CheckpointCfg { budget: None, ..ck.clone() }))
            .run(&mrt, &mut phase2, &mut dev2)
            .unwrap();
        assert!(out2.completed);
        assert!(phase2.restored && !phase2.inited && phase2.finished);
        assert_eq!(out2.resumed_from, 0);
        assert_eq!(out2.result.get("state").unwrap().as_f32(), &[1.0, 2.0]);
        // a completed loop removes its in-progress checkpoint
        assert!(!ck.path.exists());
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn oversized_checkpoint_step_is_rejected() {
        let rt = Runtime::cpu().unwrap();
        let mrt = fake_mrt(&rt);
        let dir = std::env::temp_dir().join("genie_steploop_reject_test");
        std::fs::remove_dir_all(&dir).ok();
        let ck = CheckpointCfg {
            path: dir.join("probe.ckpt"),
            every: 0,
            resume: true,
            budget: Some(0),
        };
        let mut dev = rt.device_store();
        let mut phase = Probe::new();
        // write a checkpoint at step 3 (budget 0 fires after a fake
        // resume start): simplest is a hand-built file
        let host = phase.snapshot();
        phase.init(&mut dev).unwrap();
        checkpoint::write(&ck.path, 3, &phase.carried(), &host, &[], &mut dev)
            .unwrap();
        let mut dev2 = rt.device_store();
        let mut phase2 = Probe::new();
        let err = StepLoop::new(2, 1)
            .with_checkpoint(Some(ck))
            .run(&mrt, &mut phase2, &mut dev2)
            .unwrap_err();
        assert!(format!("{err}").contains("exceeds"));
        std::fs::remove_dir_all(&dir).ok();
    }

    /// A ModelRt over a synthetic manifest — never dispatched in these
    /// tests, only threaded for its runtime handle.
    fn fake_mrt(rt: &Runtime) -> ModelRt<'_> {
        let manifest = crate::runtime::Manifest::from_json_text(
            r#"{
                "model": "probe", "image": [2, 2, 1], "num_classes": 2,
                "num_blocks": 1, "latent": 4,
                "batch": {"train": 1},
                "params": [], "bn": [], "qstate": [], "gen_params": [],
                "quant_layers": [], "learnable": {"0": []},
                "bounds": [], "entrypoints": {}
            }"#,
        )
        .unwrap();
        ModelRt { rt, dir: std::path::PathBuf::from("."), manifest }
    }
}

//! Durable phase checkpoints (DESIGN.md §9): one GTS1 file holding the
//! carried device tensors, the phase's host-side mutable state (RNG
//! streams, plateau schedulers), the engine's scalar trace so far, and
//! the step counter — everything an interrupted step loop needs to
//! resume bit-identically.
//!
//! Checkpoint writes are atomic (serialize to `<path>.tmp`, then rename),
//! so a process killed mid-write leaves the previous checkpoint intact,
//! never a truncated file. Completed shards of a sharded stage persist
//! their results as `<shard>.done.gts` next to the in-progress `.ckpt`
//! files; both live in the stage's work dir ([`StageCkpt`]), which the
//! artifact cache clears once the whole stage's artifact is stored.

use std::path::{Path, PathBuf};

use anyhow::{Context, Result};

use crate::runtime::{DeviceStore, Scalars};
use crate::schedule::ReduceLROnPlateau;
use crate::store::Store;
use crate::tensor::{Pcg32, Tensor};

const STEP_NAME: &str = "ckpt.step";
const DEV_PREFIX: &str = "dev.";
const HOST_PREFIX: &str = "host.";
const TRACE_PREFIX: &str = "ckpt.trace.";

/// Engine-side checkpoint policy for one step loop.
#[derive(Debug, Clone)]
pub struct CheckpointCfg {
    /// Checkpoint file, written atomically (tmp + rename).
    pub path: PathBuf,
    /// Steps between periodic writes (0 = only on budget exhaustion).
    pub every: usize,
    /// Load `path` (if present) before stepping, instead of phase init.
    pub resume: bool,
    /// Execute at most this many steps this invocation, then checkpoint
    /// and return with `completed = false` — graceful preemption, and
    /// the test harness's stand-in for a killed process.
    pub budget: Option<usize>,
}

/// Where one pipeline stage keeps its in-progress state: a work dir of
/// per-shard engine checkpoints (`<shard>.ckpt`) and completed-shard
/// results (`<shard>.done.gts`).
#[derive(Debug, Clone)]
pub struct StageCkpt {
    pub dir: PathBuf,
    pub every: usize,
    pub resume: bool,
    pub budget: Option<usize>,
}

impl StageCkpt {
    pub fn new(dir: impl Into<PathBuf>, every: usize, resume: bool) -> Self {
        StageCkpt { dir: dir.into(), every, resume, budget: None }
    }

    /// The engine checkpoint config for one shard of this stage.
    pub fn shard(&self, name: &str) -> CheckpointCfg {
        CheckpointCfg {
            path: self.dir.join(format!("{name}.ckpt")),
            every: self.every,
            resume: self.resume,
            budget: self.budget,
        }
    }

    /// Load a completed shard's result, if resuming and present. A file
    /// that fails to parse is treated as absent (the shard re-runs).
    pub fn load_done(&self, name: &str) -> Option<Store> {
        if !self.resume {
            return None;
        }
        let p = self.dir.join(format!("{name}.done.gts"));
        if !p.exists() {
            return None;
        }
        Store::load(&p).ok()
    }

    /// Persist a completed shard's result (atomic write).
    pub fn write_done(&self, name: &str, s: &Store) -> Result<u64> {
        std::fs::create_dir_all(&self.dir)?;
        atomic_save(s, &self.dir.join(format!("{name}.done.gts")))
    }
}

/// Write a store atomically: serialize to `<path>.tmp`, then rename.
/// Returns the byte size written.
pub fn atomic_save(s: &Store, path: &Path) -> Result<u64> {
    let tmp = path.with_extension("tmp");
    let bytes = s.to_bytes()?;
    std::fs::write(&tmp, &bytes).with_context(|| format!("write {tmp:?}"))?;
    std::fs::rename(&tmp, path)
        .with_context(|| format!("rename {tmp:?} -> {path:?}"))?;
    Ok(bytes.len() as u64)
}

/// A parsed checkpoint: the last completed step, the phase's host-side
/// snapshot, the carried device tensors, and the scalar trace so far.
#[derive(Debug)]
pub struct Snapshot {
    pub step: usize,
    pub host: Store,
    pub carried: Vec<(String, Tensor)>,
    pub trace: Vec<(usize, Scalars)>,
}

/// Write a checkpoint at `step`: the carried device tensors (fetched
/// through `dev`, so the D2H bytes are counted), the phase snapshot, and
/// the engine trace. Returns the file size in bytes.
pub fn write(
    path: &Path,
    step: usize,
    carried: &[String],
    host: &Store,
    trace: &[(usize, Scalars)],
    dev: &mut DeviceStore,
) -> Result<u64> {
    let mut s = Store::new();
    s.insert(STEP_NAME, u64_tensor(step as u64));
    // trace series: one (steps, vals) pair per scalar name, in
    // first-appearance order
    let mut names: Vec<&str> = Vec::new();
    for (_, sc) in trace {
        for (n, _) in sc.iter() {
            if !names.contains(&n) {
                names.push(n);
            }
        }
    }
    for name in names {
        let series: Vec<(usize, f32)> = trace
            .iter()
            .filter_map(|(t, sc)| sc.get(name).map(|v| (*t, v)))
            .collect();
        trace_to_store(&mut s, &format!("{TRACE_PREFIX}{name}"), &series);
    }
    for n in host.names() {
        s.insert_shared(&format!("{HOST_PREFIX}{n}"), host.get_shared(n)?);
    }
    for n in carried {
        s.insert(&format!("{DEV_PREFIX}{n}"), dev.fetch(n)?);
    }
    if let Some(parent) = path.parent() {
        std::fs::create_dir_all(parent)?;
    }
    atomic_save(&s, path)
}

/// Parse a checkpoint file back into its parts.
pub fn read(path: &Path) -> Result<Snapshot> {
    let s =
        Store::load(path).with_context(|| format!("checkpoint {path:?}"))?;
    let step = u64_from(s.get(STEP_NAME).context("checkpoint missing step")?)?
        as usize;
    let mut host = Store::new();
    let mut carried = Vec::new();
    let mut series: Vec<(String, Vec<(usize, f32)>)> = Vec::new();
    for n in s.names() {
        if let Some(rest) = n.strip_prefix(HOST_PREFIX) {
            host.insert_shared(rest, s.get_shared(n)?);
        } else if let Some(rest) = n.strip_prefix(DEV_PREFIX) {
            carried.push((rest.to_string(), s.get(n)?.clone()));
        } else if let Some(rest) = n.strip_prefix(TRACE_PREFIX) {
            if let Some(name) = rest.strip_suffix(".steps") {
                let rows =
                    trace_from_store(&s, &format!("{TRACE_PREFIX}{name}"))?;
                series.push((name.to_string(), rows));
            }
        }
    }
    // every scalar is logged at every logged step, so all series share
    // one step spine; rebuild the (step, Scalars) rows from it
    let mut trace = Vec::new();
    if let Some((_, spine)) = series.first() {
        for (i, &(t, _)) in spine.iter().enumerate() {
            let mut sc = Scalars::new();
            for (name, rows) in &series {
                sc.insert(name, rows[i].1);
            }
            trace.push((t, sc));
        }
    }
    Ok(Snapshot { step, host, carried, trace })
}

/// Encode a `(step, value)` series as `<name>.steps` (u32) +
/// `<name>.vals` (f32) tensors — the one trace wire format shared by
/// engine checkpoints, done-shard files and cache artifacts.
pub fn trace_to_store(s: &mut Store, name: &str, trace: &[(usize, f32)]) {
    s.insert(
        &format!("{name}.steps"),
        Tensor::from_u32(
            &[trace.len()],
            trace.iter().map(|&(t, _)| t as u32).collect(),
        ),
    );
    s.insert(
        &format!("{name}.vals"),
        Tensor::from_f32(
            &[trace.len()],
            trace.iter().map(|&(_, v)| v).collect(),
        ),
    );
}

/// Decode a series written by [`trace_to_store`].
pub fn trace_from_store(s: &Store, name: &str) -> Result<Vec<(usize, f32)>> {
    let steps = s.get(&format!("{name}.steps"))?.as_u32();
    let vals = s.get(&format!("{name}.vals"))?.as_f32();
    anyhow::ensure!(
        steps.len() == vals.len(),
        "trace '{name}': {} steps vs {} vals",
        steps.len(),
        vals.len()
    );
    Ok(steps
        .iter()
        .zip(vals.iter())
        .map(|(&t, &v)| (t as usize, v))
        .collect())
}

/// A u64 as a `[lo, hi]` u32 tensor (GTS1 dtypes are all 32-bit).
pub fn u64_tensor(v: u64) -> Tensor {
    Tensor::from_u32(&[2], vec![v as u32, (v >> 32) as u32])
}

pub fn u64_from(t: &Tensor) -> Result<u64> {
    let d = t.as_u32();
    anyhow::ensure!(d.len() == 2, "u64 tensor wants 2 lanes, got {}", d.len());
    Ok(d[0] as u64 | (d[1] as u64) << 32)
}

/// A PCG32 stream as a `[state_lo, state_hi, inc_lo, inc_hi]` tensor.
pub fn rng_tensor(rng: &Pcg32) -> Tensor {
    let (state, inc) = rng.raw();
    Tensor::from_u32(
        &[4],
        vec![state as u32, (state >> 32) as u32, inc as u32, (inc >> 32) as u32],
    )
}

pub fn rng_from_tensor(t: &Tensor) -> Result<Pcg32> {
    let d = t.as_u32();
    anyhow::ensure!(d.len() == 4, "rng tensor wants 4 lanes, got {}", d.len());
    Ok(Pcg32::from_raw(
        d[0] as u64 | (d[1] as u64) << 32,
        d[2] as u64 | (d[3] as u64) << 32,
    ))
}

/// A plateau scheduler's mutable state as a `[lr, best, wait]` tensor
/// (the wait count is small, so an f32 lane holds it exactly).
pub fn plateau_tensor(s: &ReduceLROnPlateau) -> Tensor {
    let (lr, best, wait) = s.raw();
    Tensor::from_f32(&[3], vec![lr, best, wait as f32])
}

pub fn plateau_restore(s: &mut ReduceLROnPlateau, t: &Tensor) -> Result<()> {
    let d = t.as_f32();
    anyhow::ensure!(
        d.len() == 3,
        "plateau tensor wants 3 lanes, got {}",
        d.len()
    );
    s.restore_raw(d[0], d[1], d[2] as usize);
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::Runtime;

    #[test]
    fn u64_and_rng_tensors_roundtrip() {
        for v in [0u64, 1, u32::MAX as u64, u64::MAX, 0x1234_5678_9abc_def0] {
            assert_eq!(u64_from(&u64_tensor(v)).unwrap(), v);
        }
        let mut rng = Pcg32::new_stream(7, 3);
        for _ in 0..9 {
            rng.next_u32();
        }
        let mut back = rng_from_tensor(&rng_tensor(&rng)).unwrap();
        for _ in 0..20 {
            assert_eq!(rng.next_u32(), back.next_u32());
        }
        assert!(u64_from(&Tensor::from_u32(&[1], vec![0])).is_err());
        assert!(rng_from_tensor(&Tensor::from_u32(&[2], vec![0, 0])).is_err());
    }

    #[test]
    fn trace_store_roundtrip() {
        let mut s = Store::new();
        let trace = vec![(5usize, 2.5f32), (10, 1.25), (12, 1.0)];
        trace_to_store(&mut s, "rec", &trace);
        assert_eq!(trace_from_store(&s, "rec").unwrap(), trace);
        // empty series round-trips too
        trace_to_store(&mut s, "empty", &[]);
        assert!(trace_from_store(&s, "empty").unwrap().is_empty());
        assert!(trace_from_store(&s, "missing").is_err());
    }

    #[test]
    fn plateau_tensor_roundtrips_mid_decay() {
        let mut a = ReduceLROnPlateau::new(0.1, 0.5, 1);
        a.observe(1.0);
        a.observe(1.0);
        let snap = plateau_tensor(&a);
        let mut b = ReduceLROnPlateau::new(0.1, 0.5, 1);
        plateau_restore(&mut b, &snap).unwrap();
        for loss in [1.0, 1.0, 0.3, 0.3, 0.3] {
            assert_eq!(a.observe(loss), b.observe(loss));
        }
    }

    #[test]
    fn checkpoint_write_read_roundtrip() {
        let rt = Runtime::cpu().unwrap();
        let mut dev = rt.device_store();
        dev.insert("w", &Tensor::from_f32(&[2], vec![1.5, -2.0])).unwrap();
        dev.insert("am.w", &Tensor::zeros(&[2])).unwrap();
        dev.insert("junk", &Tensor::scalar_f32(9.0)).unwrap();

        let mut host = Store::new();
        host.insert("rng", rng_tensor(&Pcg32::new(5)));

        let mut sc1 = Scalars::new();
        sc1.insert("loss", 2.0);
        sc1.insert("acc", 0.25);
        let mut sc2 = Scalars::new();
        sc2.insert("loss", 1.0);
        sc2.insert("acc", 0.5);
        let trace = vec![(10usize, sc1), (20usize, sc2)];

        let dir = std::env::temp_dir().join("genie_ckpt_test");
        let path = dir.join("shard0.ckpt");
        let carried = vec!["w".to_string(), "am.w".to_string()];
        let bytes =
            write(&path, 20, &carried, &host, &trace, &mut dev).unwrap();
        assert!(bytes > 0);
        assert!(!path.with_extension("tmp").exists(), "tmp must be renamed");

        let snap = read(&path).unwrap();
        assert_eq!(snap.step, 20);
        assert_eq!(snap.carried.len(), 2);
        assert_eq!(snap.carried[0].0, "w");
        assert_eq!(snap.carried[0].1.as_f32(), &[1.5, -2.0]);
        assert!(snap.host.contains("rng"));
        assert_eq!(snap.trace.len(), 2);
        assert_eq!(snap.trace[0].0, 10);
        assert_eq!(snap.trace[1].1["loss"], 1.0);
        assert_eq!(snap.trace[1].1["acc"], 0.5);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn stage_ckpt_done_roundtrip_respects_resume() {
        let dir = std::env::temp_dir().join("genie_stage_ckpt_test");
        std::fs::remove_dir_all(&dir).ok();
        let stage = StageCkpt::new(&dir, 10, true);
        assert!(stage.load_done("shard0").is_none());
        let mut s = Store::new();
        s.insert("images", Tensor::zeros(&[2, 2]));
        stage.write_done("shard0", &s).unwrap();
        let back = stage.load_done("shard0").unwrap();
        assert_eq!(back.get("images").unwrap().numel(), 4);
        // resume=false never reads done shards
        let fresh = StageCkpt::new(&dir, 10, false);
        assert!(fresh.load_done("shard0").is_none());
        std::fs::remove_dir_all(&dir).ok();
    }
}

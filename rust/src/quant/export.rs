//! Quantized-model export: harden the optimized softbits (h(V) >= 0.5,
//! eval-time rounding) and emit the *deployable* artifact — per-layer
//! integer weight tensors (u32-packed INT grid values), per-channel step
//! sizes and zero points, activation steps, and a size report. This is
//! what a downstream runtime would actually load; it also lets tests
//! verify the hard-rounding math against the `eval_quant` graph.

use anyhow::Result;

use crate::runtime::Manifest;
use crate::store::Store;
use crate::tensor::Tensor;

use super::h_sigmoid;

/// One exported layer: integers on the [n, p] grid + dequant params.
#[derive(Debug)]
pub struct ExportedLayer {
    pub name: String,
    pub out_ch: usize,
    pub flat_k: usize,
    pub bits: u32,
    /// row-major [out_ch, flat_k] integer grid values
    pub w_int: Vec<u32>,
    pub s_w: Vec<f32>,
    pub zp: Vec<f32>,
    pub s_a: f32,
}

/// Harden one layer from the optimized quant state.
pub fn harden_layer(
    qs: &Store,
    name: &str,
    out_ch: usize,
    flat_k: usize,
) -> Result<ExportedLayer> {
    let v = qs.get(&format!("q.{name}.v"))?.as_f32();
    let b = qs.get(&format!("q.{name}.b"))?.as_f32();
    let sw = qs.get(&format!("q.{name}.sw"))?.as_f32().to_vec();
    let zp = qs.get(&format!("q.{name}.zp"))?.as_f32().to_vec();
    let wn = qs.get(&format!("q.{name}.wn"))?.scalar();
    let wp = qs.get(&format!("q.{name}.wp"))?.scalar();
    let s_a = qs.get(&format!("q.{name}.sa"))?.scalar();
    let bits = (wp - wn + 1.0).log2().round() as u32;
    let mut w_int = Vec::with_capacity(v.len());
    for i in 0..v.len() {
        let hard = if h_sigmoid(v[i]) >= 0.5 { 1.0 } else { 0.0 };
        w_int.push((b[i] + hard).clamp(wn, wp) as u32);
    }
    Ok(ExportedLayer {
        name: name.to_string(),
        out_ch,
        flat_k,
        bits,
        w_int,
        s_w: sw,
        zp,
        s_a,
    })
}

/// Dequantize an exported layer back to FP32 rows (test / verification).
pub fn dequantize_layer(l: &ExportedLayer) -> Vec<f32> {
    let mut out = Vec::with_capacity(l.w_int.len());
    for ch in 0..l.out_ch {
        for j in 0..l.flat_k {
            let q = l.w_int[ch * l.flat_k + j] as f32;
            out.push(l.s_w[ch] * (q - l.zp[ch]));
        }
    }
    out
}

/// Export every quantized layer of a model into a tensorstore file,
/// returning (store, fp32_bytes, quantized_bits) for the size report.
pub fn export_model(
    manifest: &Manifest,
    qstate: &Store,
) -> Result<(Store, usize, usize)> {
    let mut out = Store::new();
    let mut fp_bytes = 0usize;
    let mut q_bits = 0usize;
    for ql in &manifest.quant_layers {
        let l = harden_layer(qstate, &ql.name, ql.out_ch, ql.flat_k)?;
        fp_bytes += l.w_int.len() * 4;
        // integer payload + per-channel scale/zero-point overhead
        q_bits += l.w_int.len() * l.bits as usize + l.out_ch * 2 * 32;
        out.insert(
            &format!("int.{}.w", ql.name),
            Tensor::from_u32(&[ql.out_ch, ql.flat_k], l.w_int.clone()),
        );
        out.insert(&format!("int.{}.sw", ql.name),
                   Tensor::from_f32(&[ql.out_ch], l.s_w.clone()));
        out.insert(&format!("int.{}.zp", ql.name),
                   Tensor::from_f32(&[ql.out_ch], l.zp.clone()));
        out.insert(&format!("int.{}.sa", ql.name), Tensor::scalar_f32(l.s_a));
        out.insert(&format!("int.{}.bits", ql.name),
                   Tensor::from_u32(&[], vec![l.bits]));
    }
    Ok((out, fp_bytes, q_bits))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::precision::wbounds;
    use crate::quant::softbit_init;

    fn mini_qstate() -> Store {
        // 2 channels x 3 weights on a 4-bit grid
        let mut qs = Store::new();
        qs.insert("q.l.v", Tensor::from_f32(
            &[2, 3],
            // h(v): ~0 (round down), ~1 (round up), exactly-initialised r
            vec![-10.0, 10.0, softbit_init(0.3),
                 -10.0, 10.0, softbit_init(0.8)],
        ));
        qs.insert("q.l.b", Tensor::from_f32(&[2, 3], vec![3., 7., 15., 0., 14., 2.]));
        qs.insert("q.l.sw", Tensor::from_f32(&[2], vec![0.1, 0.2]));
        qs.insert("q.l.zp", Tensor::from_f32(&[2], vec![8.0, 7.0]));
        let (wn, wp) = wbounds(4);
        qs.insert("q.l.wn", Tensor::scalar_f32(wn));
        qs.insert("q.l.wp", Tensor::scalar_f32(wp));
        qs.insert("q.l.sa", Tensor::scalar_f32(0.05));
        qs
    }

    #[test]
    fn harden_rounds_softbits() {
        let l = harden_layer(&mini_qstate(), "l", 2, 3).unwrap();
        assert_eq!(l.bits, 4);
        // b + {0,1}, clipped to [0,15]
        assert_eq!(l.w_int, vec![3, 8, 15, 0, 15, 3]);
    }

    #[test]
    fn dequant_matches_grid() {
        let l = harden_layer(&mini_qstate(), "l", 2, 3).unwrap();
        let deq = dequantize_layer(&l);
        assert!((deq[0] - 0.1 * (3.0 - 8.0)).abs() < 1e-6);
        assert!((deq[3] - 0.2 * (0.0 - 7.0)).abs() < 1e-6);
    }

    #[test]
    fn ints_stay_in_bit_range() {
        let l = harden_layer(&mini_qstate(), "l", 2, 3).unwrap();
        assert!(l.w_int.iter().all(|&q| q <= 15));
    }
}

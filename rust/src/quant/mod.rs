//! Quant-state initialization — the host-side half of GENIE-M.
//!
//! From the FP32 checkpoint this module derives, per quantized layer:
//!   * per-channel (or per-tensor) step size `s_w` by the Eq. 6 / Eq. A3
//!     grid search (p-norm reconstruction error, p configurable —
//!     Fig. A2),
//!   * per-channel zero point `z` (asymmetric weights),
//!   * the detached base grid `B = clip(floor(W/s) + z, n, p)` (Eq. 9),
//!   * softbit init `V = h^-1(W/s + z - B)` (AdaRound; rectified sigmoid
//!     inverse), so h(V) starts exactly at the FP remainder,
//!   * LSQ activation step `s_a = 2 E|x| / sqrt(q_p)` from teacher
//!     activation statistics.
//!
//! Bit-widths and granularity come from a
//! [`PrecisionPlan`](crate::precision::PrecisionPlan) (DESIGN.md §10) —
//! the historical first/last-layer 8-bit exception is now the plan's
//! FirstLast8 transform, not a branch here.

pub mod export;

use anyhow::Result;

use crate::precision::{abounds, wbounds, Granularity, PrecisionPlan};
use crate::runtime::{Manifest, QuantLayer};
use crate::store::Store;
use crate::tensor::Tensor;

pub const ZETA: f32 = 1.1;
pub const GAMMA: f32 = -0.1;

/// Flatten a weight tensor to out-channel-major [O][K] rows, matching
/// python's `moveaxis(w, -1, 0).reshape(O, -1)` (conv HWIO) / `w.T` (dense).
pub fn flatten_out_major(w: &Tensor) -> (usize, usize, Vec<f32>) {
    let v = w.as_f32();
    match w.shape.len() {
        4 => {
            let (kh, kw, ci, co) =
                (w.shape[0], w.shape[1], w.shape[2], w.shape[3]);
            let k = kh * kw * ci;
            let mut out = vec![0.0f32; co * k];
            for r in 0..kh * kw * ci {
                for o in 0..co {
                    out[o * k + r] = v[r * co + o];
                }
            }
            (co, k, out)
        }
        2 => {
            let (ci, co) = (w.shape[0], w.shape[1]);
            let mut out = vec![0.0f32; co * ci];
            for r in 0..ci {
                for o in 0..co {
                    out[o * ci + r] = v[r * co + o];
                }
            }
            (co, ci, out)
        }
        other => panic!("flatten_out_major: rank {other} unsupported"),
    }
}

/// Quantization error of one channel row for a candidate step size
/// (asymmetric grid), under the given p-norm. `lo` is the row minimum,
/// computed once per channel by the caller — not refolded per candidate.
fn row_error(row: &[f32], s: f32, lo: f32, p: f32, pnorm: f32) -> f64 {
    let z = (-lo / s).round().clamp(0.0, p);
    let mut err = 0.0f64;
    for &w in row {
        let q = ((w / s).round() + z).clamp(0.0, p);
        let deq = s * (q - z);
        err += ((w - deq).abs() as f64).powf(pnorm as f64);
    }
    err
}

/// Eq. 6 / Eq. A3: grid search the per-channel step size minimizing the
/// p-norm reconstruction error. Returns (s, z) per channel.
pub fn search_step_sizes(
    rows: &[f32],
    o: usize,
    k: usize,
    bits: u32,
    pnorm: f32,
) -> (Vec<f32>, Vec<f32>) {
    let (_, p) = wbounds(bits);
    let mut sw = Vec::with_capacity(o);
    let mut zp = Vec::with_capacity(o);
    for ch in 0..o {
        let row = &rows[ch * k..(ch + 1) * k];
        let lo = row.iter().cloned().fold(f32::INFINITY, f32::min);
        let hi = row.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
        let span = (hi - lo).max(1e-8);
        let s0 = span / p;
        let mut best_s = s0;
        let mut best_e = f64::INFINITY;
        // candidates 0.4..1.2 x the min-max step (80-point linear search)
        for i in 0..80 {
            let s = s0 * (0.4 + 0.01 * i as f32);
            let e = row_error(row, s, lo, p, pnorm);
            if e < best_e {
                best_e = e;
                best_s = s;
            }
        }
        let z = (-lo / best_s).round().clamp(0.0, p);
        sw.push(best_s);
        zp.push(z);
    }
    (sw, zp)
}

/// (s, z) vectors for one layer under a plan granularity: the Eq. 6
/// search per channel, or once over the whole layer (then splatted to
/// the per-channel shape the runtime grids expect).
pub fn plan_step_sizes(
    rows: &[f32],
    o: usize,
    k: usize,
    bits: u32,
    pnorm: f32,
    granularity: Granularity,
) -> (Vec<f32>, Vec<f32>) {
    match granularity {
        Granularity::PerChannel => search_step_sizes(rows, o, k, bits, pnorm),
        Granularity::PerTensor => {
            let (s, z) = search_step_sizes(rows, 1, o * k, bits, pnorm);
            (vec![s[0]; o], vec![z[0]; o])
        }
    }
}

/// AdaRound softbit init: V = sigmoid^-1((r - GAMMA)/(ZETA - GAMMA)) so
/// that h(V) equals the FP remainder r = W/s + z - B exactly.
pub fn softbit_init(r: f32) -> f32 {
    let u = ((r.clamp(0.001, 0.999) - GAMMA) / (ZETA - GAMMA)).clamp(1e-4, 1.0 - 1e-4);
    (u / (1.0 - u)).ln()
}

/// h(V): rectified sigmoid (mirror of the pallas kernel, used by tests
/// and the hardening report).
pub fn h_sigmoid(v: f32) -> f32 {
    let sig = 1.0 / (1.0 + (-v).exp());
    (sig * (ZETA - GAMMA) + GAMMA).clamp(0.0, 1.0)
}

/// Round-to-grid fake quantization of a weight tensor at `bits` (Eq. 6
/// step sizes at the given granularity, hard rounding, dequantized back
/// to FP32 in the original layout). The sensitivity probes of the
/// Pareto policy perturb one layer at a time with this, so the probe
/// quantizer matches the one the plan deploys.
pub fn fake_quant_weights(
    w: &Tensor,
    bits: u32,
    pnorm: f32,
    granularity: Granularity,
) -> Result<Tensor> {
    anyhow::ensure!(
        w.shape.len() == 2 || w.shape.len() == 4,
        "fake_quant_weights: rank {} unsupported",
        w.shape.len()
    );
    let (o, k, rows) = flatten_out_major(w);
    let (sw, zp) = plan_step_sizes(&rows, o, k, bits, pnorm, granularity);
    let (wn, wp) = wbounds(bits);
    // out-channel is the last axis in both supported layouts
    let co = *w.shape.last().unwrap();
    debug_assert_eq!(co, o);
    let v = w.as_f32();
    let out: Vec<f32> = v
        .iter()
        .enumerate()
        .map(|(i, &x)| {
            let ch = i % co;
            dequant(x, sw[ch], zp[ch], wn, wp)
        })
        .collect();
    Ok(Tensor::from_f32(&w.shape, out))
}

/// Build the full quant state for a model from its FP32 params, with
/// per-layer bit-widths and granularity supplied by `plan`.
///
/// `act_stats`: mean |x| per quant layer (from the `act_stats` entrypoint);
/// pass `None` to start with a placeholder (refreshed later).
pub fn init_qstate(
    manifest: &Manifest,
    params: &Store,
    plan: &PrecisionPlan,
    pnorm: f32,
    act_stats: Option<&[f32]>,
) -> Result<Store> {
    plan.validate(manifest)?;
    let mut qs = Store::new();
    for (li, ql) in manifest.quant_layers.iter().enumerate() {
        let lp = &plan.layers[li];
        let (wn, wp) = wbounds(lp.wbits);
        let (an, ap) = abounds(lp.abits);
        let w = params.get(&format!("{}.w", ql.name))?;
        let (o, k, rows) = flatten_out_major(w);
        anyhow::ensure!(
            o == ql.out_ch && k == ql.flat_k,
            "layer {}: manifest shape mismatch",
            ql.name
        );
        let (sw, zp) =
            plan_step_sizes(&rows, o, k, lp.wbits, pnorm, lp.granularity);
        let mut b = vec![0.0f32; o * k];
        let mut v = vec![0.0f32; o * k];
        for ch in 0..o {
            for j in 0..k {
                let wv = rows[ch * k + j];
                let base = ((wv / sw[ch]).floor() + zp[ch]).clamp(wn, wp);
                let r = (wv / sw[ch] + zp[ch] - base).clamp(0.0, 1.0);
                b[ch * k + j] = base;
                v[ch * k + j] = softbit_init(r);
            }
        }
        let sa = match act_stats {
            Some(st) => (2.0 * st[li] / ap.max(1.0).sqrt()).max(1e-5),
            None => 0.1,
        };
        let n = &ql.name;
        qs.insert(&format!("q.{n}.sw"), Tensor::from_f32(&[o], sw));
        qs.insert(&format!("q.{n}.v"), Tensor::from_f32(&[o, k], v));
        qs.insert(&format!("q.{n}.b"), Tensor::from_f32(&[o, k], b));
        qs.insert(&format!("q.{n}.zp"), Tensor::from_f32(&[o], zp));
        qs.insert(&format!("q.{n}.wn"), Tensor::scalar_f32(wn));
        qs.insert(&format!("q.{n}.wp"), Tensor::scalar_f32(wp));
        qs.insert(&format!("q.{n}.sa"), Tensor::scalar_f32(sa));
        qs.insert(&format!("q.{n}.an"), Tensor::scalar_f32(an));
        qs.insert(&format!("q.{n}.ap"), Tensor::scalar_f32(ap));
    }
    Ok(qs)
}

/// Refresh the LSQ activation steps from measured mean |x| (keeps the
/// per-layer bounds already in `qs`).
pub fn set_act_steps(
    qs: &mut Store,
    layers: &[QuantLayer],
    stats: &[f32],
) -> Result<()> {
    for (li, ql) in layers.iter().enumerate() {
        let ap = qs.get(&format!("q.{}.ap", ql.name))?.scalar();
        let sa = (2.0 * stats[li] / ap.max(1.0).sqrt()).max(1e-5);
        qs.insert(&format!("q.{}.sa", ql.name), Tensor::scalar_f32(sa));
    }
    Ok(())
}

/// Min-Max step size (Eq. 3) — the baseline initializer (used by the
/// Fig. A2 ablation arm and tests).
pub fn minmax_step(row: &[f32], bits: u32) -> (f32, f32) {
    let (_, p) = wbounds(bits);
    let lo = row.iter().cloned().fold(f32::INFINITY, f32::min);
    let hi = row.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
    let s = ((hi - lo) / p).max(1e-8);
    let z = (-lo / s).round().clamp(0.0, p);
    (s, z)
}

/// Dequantization of one value on the asymmetric grid (test helper).
pub fn dequant(w: f32, s: f32, z: f32, n: f32, p: f32) -> f32 {
    let q = ((w / s).round() + z).clamp(n, p);
    s * (q - z)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::precision::toy_manifest;

    #[test]
    fn flatten_conv_matches_moveaxis() {
        // w[kh,kw,ci,co] with co=2: row o collects w[..., o]
        let w = Tensor::from_f32(&[1, 1, 3, 2], vec![1., 10., 2., 20., 3., 30.]);
        let (o, k, rows) = flatten_out_major(&w);
        assert_eq!((o, k), (2, 3));
        assert_eq!(rows, vec![1., 2., 3., 10., 20., 30.]);
    }

    #[test]
    fn flatten_dense_is_transpose() {
        let w = Tensor::from_f32(&[2, 3], vec![1., 2., 3., 4., 5., 6.]);
        let (o, k, rows) = flatten_out_major(&w);
        assert_eq!((o, k), (3, 2));
        assert_eq!(rows, vec![1., 4., 2., 5., 3., 6.]);
    }

    #[test]
    fn grid_search_beats_minmax() {
        // heavy-tailed row: clipping outliers must win under L2
        let mut row = vec![0.0f32; 64];
        let mut rng = crate::tensor::Pcg32::new(9);
        for r in row.iter_mut() {
            *r = rng.normal() * 0.1;
        }
        row[0] = 2.0; // outlier
        let (sw, zp) = search_step_sizes(&row, 1, 64, 4, 2.0);
        let (s_mm, z_mm) = minmax_step(&row, 4);
        let err = |s: f32, z: f32| {
            row.iter()
                .map(|&w| (w - dequant(w, s, z, 0.0, 15.0)).powi(2) as f64)
                .sum::<f64>()
        };
        assert!(err(sw[0], zp[0]) <= err(s_mm, z_mm) + 1e-9);
    }

    #[test]
    fn dequant_error_bounded_by_half_step() {
        let (s, z) = (0.1f32, 7.0f32);
        for i in -50..50 {
            let w = i as f32 * 0.013;
            let q = ((w / s).round() + z).clamp(0.0, 15.0);
            if q > 0.0 && q < 15.0 {
                assert!((w - dequant(w, s, z, 0.0, 15.0)).abs() <= s / 2.0 + 1e-6);
            }
        }
    }

    #[test]
    fn softbit_init_inverts_h() {
        for r in [0.01f32, 0.2, 0.5, 0.77, 0.99] {
            let v = softbit_init(r);
            assert!((h_sigmoid(v) - r).abs() < 1e-4, "r={r}");
        }
    }

    #[test]
    fn minmax_covers_range() {
        let row = [-1.0f32, 0.0, 2.0];
        let (s, z) = minmax_step(&row, 4);
        assert!((s - 0.2).abs() < 1e-6);
        assert!((z - 5.0).abs() < 1e-6);
        // extremes representable
        assert!((dequant(-1.0, s, z, 0.0, 15.0) + 1.0).abs() < 1e-5);
        assert!((dequant(2.0, s, z, 0.0, 15.0) - 2.0).abs() < 1e-5);
    }

    #[test]
    fn per_tensor_splats_one_step() {
        let mut rng = crate::tensor::Pcg32::new(21);
        let rows: Vec<f32> = (0..4 * 16).map(|_| rng.normal()).collect();
        let (sw, zp) =
            plan_step_sizes(&rows, 4, 16, 4, 2.4, Granularity::PerTensor);
        assert_eq!(sw.len(), 4);
        assert!(sw.iter().all(|&s| s == sw[0]));
        assert!(zp.iter().all(|&z| z == zp[0]));
        // per-channel generally differs across channels
        let (sc, _) =
            plan_step_sizes(&rows, 4, 16, 4, 2.4, Granularity::PerChannel);
        assert_eq!(sc.len(), 4);
    }

    #[test]
    fn fake_quant_stays_on_grid_and_near_input() {
        let mut rng = crate::tensor::Pcg32::new(33);
        let w = Tensor::randn(&[2, 2, 3, 4], &mut rng, 0.2);
        let fq =
            fake_quant_weights(&w, 8, 2.4, Granularity::PerChannel).unwrap();
        assert_eq!(fq.shape, w.shape);
        // 8-bit fake quant is a tight approximation
        for (a, b) in w.as_f32().iter().zip(fq.as_f32()) {
            assert!((a - b).abs() < 0.05, "{a} vs {b}");
        }
        // 2-bit is coarse: at most 4 distinct values per out-channel
        let fq2 =
            fake_quant_weights(&w, 2, 2.4, Granularity::PerChannel).unwrap();
        let co = 4;
        for ch in 0..co {
            let mut vals: Vec<f32> = fq2
                .as_f32()
                .iter()
                .enumerate()
                .filter(|(i, _)| i % co == ch)
                .map(|(_, &v)| v)
                .collect();
            vals.sort_by(f32::total_cmp);
            vals.dedup();
            assert!(vals.len() <= 4, "channel {ch}: {vals:?}");
        }
        // per-tensor: one grid for the whole layer, <= 4 distinct values
        let fqt =
            fake_quant_weights(&w, 2, 2.4, Granularity::PerTensor).unwrap();
        let mut vals: Vec<f32> = fqt.as_f32().to_vec();
        vals.sort_by(f32::total_cmp);
        vals.dedup();
        assert!(vals.len() <= 4, "per-tensor: {vals:?}");
        assert!(fake_quant_weights(
            &Tensor::zeros(&[3]),
            4,
            2.0,
            Granularity::PerChannel
        )
        .is_err());
    }

    /// The seed-path contract: a default plan (uniform + FirstLast8)
    /// reproduces the historical per-layer bounds — 8-bit grids on the
    /// first and last layers, the configured bits in between.
    #[test]
    fn init_qstate_honors_plan_bits() {
        use crate::precision::PrecisionPlan;
        let m = toy_manifest(&[("stem", 2, 12), ("mid", 3, 8), ("head", 2, 6)]);
        let mut rng = crate::tensor::Pcg32::new(5);
        let mut params = Store::new();
        params.insert("stem.w", Tensor::randn(&[1, 1, 12, 2], &mut rng, 0.3));
        params.insert("mid.w", Tensor::randn(&[1, 1, 8, 3], &mut rng, 0.3));
        params.insert("head.w", Tensor::randn(&[1, 1, 6, 2], &mut rng, 0.3));
        let plan =
            PrecisionPlan::uniform(&m, 4, 4, Granularity::PerChannel)
                .unwrap()
                .with_first_last(8)
                .unwrap();
        let qs = init_qstate(&m, &params, &plan, 2.4, None).unwrap();
        assert_eq!(qs.get("q.stem.wp").unwrap().scalar(), 255.0);
        assert_eq!(qs.get("q.stem.ap").unwrap().scalar(), 127.0);
        assert_eq!(qs.get("q.mid.wp").unwrap().scalar(), 15.0);
        assert_eq!(qs.get("q.mid.an").unwrap().scalar(), -8.0);
        assert_eq!(qs.get("q.head.wp").unwrap().scalar(), 255.0);
        // a mixed plan moves only its layer's grid
        let mut mixed = plan.clone();
        mixed.layers[1].wbits = 2;
        let qs2 = init_qstate(&m, &params, &mixed, 2.4, None).unwrap();
        assert_eq!(qs2.get("q.mid.wp").unwrap().scalar(), 3.0);
        assert_eq!(
            qs.get("q.stem.b").unwrap(),
            qs2.get("q.stem.b").unwrap(),
            "untouched layers must be bit-identical across plans"
        );
    }

    #[test]
    fn init_qstate_rejects_mismatched_plan() {
        let m = toy_manifest(&[("stem", 2, 12)]);
        let other = toy_manifest(&[("nope", 2, 12)]);
        let plan = crate::precision::PrecisionPlan::uniform(
            &other, 4, 4, Granularity::PerChannel,
        )
        .unwrap();
        let params = Store::new();
        assert!(init_qstate(&m, &params, &plan, 2.4, None).is_err());
    }
}

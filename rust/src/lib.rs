//! GENIE: Show Me the Data for Quantization — rust coordinator (L3).
//!
//! This crate is the runtime half of the three-layer reproduction
//! (DESIGN.md): python/jax/pallas author and AOT-lower every compute graph
//! to HLO text at build time (`make artifacts`); this crate loads those
//! artifacts through the PJRT C API (`xla` crate) and runs the entire
//! zero-shot-quantization pipeline — pretraining the FP32 teacher,
//! GENIE-D data distillation, GENIE-M block-wise post-training
//! quantization, evaluation, and the full benchmark harness — with Python
//! never on the hot path.

pub mod tensor;
pub mod store;
pub mod exec;
pub mod runtime;
pub mod phase;
pub mod precision;
pub mod synthesis;
pub mod artifacts;
pub mod faults;
pub mod quant;
pub mod schedule;
pub mod data;
pub mod progress;
pub mod coordinator;
pub mod grid;
pub mod experiments;
pub mod testutil;

pub use tensor::{DType, Tensor};

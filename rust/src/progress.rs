//! Run-tagged, line-buffered progress logger (DESIGN.md §11).
//!
//! When the grid scheduler interleaves stage jobs from different runs on
//! the exec pool, raw `println!` calls shear: two workers can write
//! partial lines that end up interleaved on the terminal. Every stage
//! progress line therefore goes through [`emit`] (via the
//! [`progress!`](crate::progress!) macro), which formats the *complete*
//! line — including the current run tag — into one buffer and hands it
//! to the stdout lock in a single `write_all`.
//!
//! The run tag is thread-local: the grid executor pushes a tag (e.g.
//! `c3` for cell 3, `shared:distill` for a deduplicated stage) around
//! each stage job with [`push_tag`], and every progress line the job
//! prints — stage summaries, cache hits, per-shard lines — carries it as
//! a `[tag] ` prefix. Untagged threads (single runs, tests) print bare
//! lines, so the logger is invisible outside grid mode. Inner pool
//! worker threads spawned *by* a stage do not inherit the tag, but all
//! stage progress output happens on the stage job's own thread (shard
//! results are printed from the aggregation loop), so lines stay tagged.

use std::cell::RefCell;
use std::io::Write;

thread_local! {
    static TAG: RefCell<Option<String>> = const { RefCell::new(None) };
}

/// Restores the previous tag when dropped, so tags nest.
pub struct TagGuard {
    prev: Option<String>,
}

impl Drop for TagGuard {
    fn drop(&mut self) {
        TAG.with(|t| *t.borrow_mut() = self.prev.take());
    }
}

/// Tag every [`progress!`] line on this thread with `[tag] ` until the
/// returned guard drops.
pub fn push_tag(tag: &str) -> TagGuard {
    TAG.with(|t| {
        let prev = t.borrow_mut().replace(tag.to_string());
        TagGuard { prev }
    })
}

/// The current thread's run tag, if any.
pub fn current_tag() -> Option<String> {
    TAG.with(|t| t.borrow().clone())
}

/// Render one complete progress line (tag prefix + body + newline).
/// Factored out of [`emit`] so the formatting is testable without
/// capturing stdout.
pub fn render_line(tag: Option<&str>, body: &str) -> String {
    match tag {
        Some(tag) => format!("[{tag}] {body}\n"),
        None => format!("{body}\n"),
    }
}

/// Write one progress line atomically (single `write_all` under the
/// stdout lock). Prefer the [`progress!`](crate::progress!) macro.
pub fn emit(args: std::fmt::Arguments<'_>) {
    let tag = current_tag();
    let line = render_line(tag.as_deref(), &format!("{args}"));
    let stdout = std::io::stdout();
    let mut lock = stdout.lock();
    let _ = lock.write_all(line.as_bytes());
}

/// `println!`-compatible progress line through the run-tagged,
/// line-buffered logger. Multi-line bodies are written in the same
/// single syscall, so block reports (e.g. a rendered precision plan)
/// don't interleave either.
#[macro_export]
macro_rules! progress {
    ($($arg:tt)*) => {
        $crate::progress::emit(format_args!($($arg)*))
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn untagged_line_is_bare() {
        assert_eq!(render_line(None, "hello"), "hello\n");
    }

    #[test]
    fn tagged_line_carries_prefix() {
        assert_eq!(render_line(Some("c3"), "loss 0.5"), "[c3] loss 0.5\n");
    }

    #[test]
    fn tags_nest_and_restore() {
        assert_eq!(current_tag(), None);
        {
            let _a = push_tag("outer");
            assert_eq!(current_tag().as_deref(), Some("outer"));
            {
                let _b = push_tag("inner");
                assert_eq!(current_tag().as_deref(), Some("inner"));
            }
            assert_eq!(current_tag().as_deref(), Some("outer"));
        }
        assert_eq!(current_tag(), None);
    }

    #[test]
    fn tags_are_thread_local() {
        let _a = push_tag("main");
        std::thread::spawn(|| {
            assert_eq!(current_tag(), None);
            let _b = push_tag("worker");
            assert_eq!(current_tag().as_deref(), Some("worker"));
        })
        .join()
        .unwrap();
        assert_eq!(current_tag().as_deref(), Some("main"));
    }

    #[test]
    fn emit_does_not_panic() {
        let _t = push_tag("test");
        emit(format_args!("progress {} of {}", 1, 2));
    }
}

//! Per-layer precision plans (DESIGN.md §10): the quantizer-scheme
//! subsystem that replaced the global `BitConfig` + buried
//! `li == 0 || li == last` branch.
//!
//! A [`PrecisionPlan`] assigns every quantized layer its own
//! [`LayerPlan`] — weight bits, activation bits, step-size
//! [`Granularity`] — and is built by pluggable policies:
//!
//!   * **Uniform** ([`PrecisionPlan::uniform`]) — one (wbits, abits)
//!     pair everywhere; composed with the FirstLast8 transform below it
//!     reproduces the historical behavior bit-identically.
//!   * **FirstLast8** ([`PrecisionPlan::with_first_last`]) — the
//!     BRECQ/QDrop first/last-layer 8-bit exception, made an explicit
//!     plan transform (`first_last_bits = 0` turns it off) instead of a
//!     branch inside `quant::init_qstate`.
//!   * **Pareto** ([`sensitivity::pareto_plan`]) — ZeroQ-style mixed
//!     precision: per-layer quantization sensitivity measured on the
//!     cached synthetic set (teacher-vs-perturbed KL, sharded on the
//!     exec pool) drives a greedy bit allocation under a
//!     `--target-size` weight budget.
//!
//! Plans thread through `quant::init_qstate`, block reconstruction, the
//! artifact-cache keys (a different plan is a different qstate
//! artifact), `Metrics` (`plan/wbits` / `plan/abits` series) and the
//! per-layer report (`experiments --exp plan`). They round-trip GTS1
//! via [`PrecisionPlan::to_store`] / [`PrecisionPlan::from_store`], so
//! a resolved Pareto plan is itself a cached artifact.

pub mod sensitivity;

use anyhow::{bail, Result};

use crate::runtime::Manifest;
use crate::store::Store;
use crate::tensor::Tensor;

/// Inclusive bit-width range every grid in the system supports: 0 would
/// underflow the symmetric activation shift in [`abounds`], anything
/// past 8 overflows the u32-packed export grid assumptions.
pub const MIN_BITS: u32 = 1;
pub const MAX_BITS: u32 = 8;

/// Reject out-of-range bit widths with a diagnosable error (used at
/// config parse time and by every plan builder).
pub fn validate_bits(what: &str, bits: u32) -> Result<u32> {
    anyhow::ensure!(
        (MIN_BITS..=MAX_BITS).contains(&bits),
        "{what} must be between {MIN_BITS} and {MAX_BITS} bits, got {bits} \
         (0 underflows the activation grid; >8 exceeds the export grid)"
    );
    Ok(bits)
}

/// (wn, wp) for the asymmetric weight grid at `bits`.
pub fn wbounds(bits: u32) -> (f32, f32) {
    debug_assert!((MIN_BITS..=MAX_BITS).contains(&bits), "wbounds({bits})");
    (0.0, (1u64 << bits) as f32 - 1.0)
}

/// (an, ap) for the symmetric activation grid at `bits`.
pub fn abounds(bits: u32) -> (f32, f32) {
    debug_assert!((MIN_BITS..=MAX_BITS).contains(&bits), "abounds({bits})");
    let half = 1u64 << (bits - 1);
    (-(half as f32), half as f32 - 1.0)
}

/// Step-size granularity of one layer's weight quantizer.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Granularity {
    /// One (s, z) per output channel (the paper's setting; default).
    PerChannel,
    /// One (s, z) for the whole layer.
    PerTensor,
}

impl Granularity {
    pub fn parse(s: &str) -> Result<Granularity> {
        match s {
            "per_channel" | "channel" => Ok(Granularity::PerChannel),
            "per_tensor" | "tensor" => Ok(Granularity::PerTensor),
            other => bail!(
                "unknown granularity '{other}' (want per_channel|per_tensor)"
            ),
        }
    }

    pub fn as_str(&self) -> &'static str {
        match self {
            Granularity::PerChannel => "per_channel",
            Granularity::PerTensor => "per_tensor",
        }
    }

    /// One-character tag for fingerprints and labels.
    fn tag(&self) -> char {
        match self {
            Granularity::PerChannel => 'c',
            Granularity::PerTensor => 't',
        }
    }

    fn from_code(code: u32) -> Result<Granularity> {
        match code {
            0 => Ok(Granularity::PerChannel),
            1 => Ok(Granularity::PerTensor),
            other => bail!("plan store: bad granularity code {other}"),
        }
    }

    fn code(&self) -> u32 {
        match self {
            Granularity::PerChannel => 0,
            Granularity::PerTensor => 1,
        }
    }
}

/// Plan-building policy, selected by `--precision` / `precision=`.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Policy {
    /// One (wbits, abits) pair for every layer (plus the FirstLast8
    /// transform unless `first_last_bits = 0`) — today's behavior.
    Uniform,
    /// Sensitivity-driven mixed precision under a `--target-size`
    /// weight budget (ZeroQ-style Pareto allocation).
    Pareto,
}

impl Policy {
    pub fn parse(s: &str) -> Result<Policy> {
        match s {
            "uniform" => Ok(Policy::Uniform),
            "pareto" => Ok(Policy::Pareto),
            other => bail!("unknown precision policy '{other}' \
                            (want uniform|pareto)"),
        }
    }

    pub fn as_str(&self) -> &'static str {
        match self {
            Policy::Uniform => "uniform",
            Policy::Pareto => "pareto",
        }
    }
}

/// How a plan is built: the policy plus every knob that shapes it.
/// Lives inside `QuantCfg` and feeds both the plan builders and the
/// plan-artifact cache key.
#[derive(Debug, Clone, PartialEq)]
pub struct PrecisionCfg {
    pub policy: Policy,
    /// FirstLast8 transform: bits pinned on the first and last quantized
    /// layers (paper/BRECQ: 8). `0` disables the exception entirely.
    pub first_last_bits: u32,
    /// Pareto weight budget as a fraction of the FP32 weight payload
    /// (0.25 = the all-8-bit size).
    pub target_size: f32,
    pub granularity: Granularity,
    /// Calibration batches per sensitivity probe (cost control).
    pub sens_batches: usize,
    /// Candidate weight bit-widths the Pareto allocator chooses from
    /// (ascending, validated).
    pub candidates: Vec<u32>,
}

impl Default for PrecisionCfg {
    fn default() -> Self {
        PrecisionCfg {
            policy: Policy::Uniform,
            first_last_bits: 8,
            target_size: 0.25,
            granularity: Granularity::PerChannel,
            sens_batches: 2,
            candidates: vec![2, 3, 4, 5, 6, 8],
        }
    }
}

/// One quantized layer's precision assignment.
#[derive(Debug, Clone, PartialEq)]
pub struct LayerPlan {
    pub name: String,
    pub wbits: u32,
    pub abits: u32,
    pub granularity: Granularity,
}

/// Per-layer precision assignments for one model, in manifest
/// `quant_layers` order.
#[derive(Debug, Clone, PartialEq, Default)]
pub struct PrecisionPlan {
    pub layers: Vec<LayerPlan>,
}

impl PrecisionPlan {
    /// The Uniform policy: every layer at (wbits, abits).
    pub fn uniform(
        m: &Manifest,
        wbits: u32,
        abits: u32,
        granularity: Granularity,
    ) -> Result<PrecisionPlan> {
        validate_bits("wbits", wbits)?;
        validate_bits("abits", abits)?;
        Ok(PrecisionPlan {
            layers: m
                .quant_layers
                .iter()
                .map(|q| LayerPlan {
                    name: q.name.clone(),
                    wbits,
                    abits,
                    granularity,
                })
                .collect(),
        })
    }

    /// The FirstLast8 transform: pin the first and last layers' weight
    /// *and* activation bits (the historical exception). `bits = 0` is
    /// the identity (exception disabled).
    pub fn with_first_last(mut self, bits: u32) -> Result<PrecisionPlan> {
        if bits == 0 || self.layers.is_empty() {
            return Ok(self);
        }
        validate_bits("first_last_bits", bits)?;
        let last = self.layers.len() - 1;
        for li in [0, last] {
            self.layers[li].wbits = bits;
            self.layers[li].abits = bits;
        }
        Ok(self)
    }

    /// Check the plan covers exactly the manifest's quant layers, in
    /// order, with in-range bits.
    pub fn validate(&self, m: &Manifest) -> Result<()> {
        anyhow::ensure!(
            self.layers.len() == m.quant_layers.len(),
            "plan covers {} layers, manifest has {}",
            self.layers.len(),
            m.quant_layers.len()
        );
        for (lp, ql) in self.layers.iter().zip(&m.quant_layers) {
            anyhow::ensure!(
                lp.name == ql.name,
                "plan layer '{}' does not match manifest layer '{}'",
                lp.name,
                ql.name
            );
            validate_bits(&format!("{} wbits", lp.name), lp.wbits)?;
            validate_bits(&format!("{} abits", lp.name), lp.abits)?;
        }
        Ok(())
    }

    /// Stable textual identity — the plan's contribution to artifact
    /// cache keys (two plans fingerprint equal iff they quantize
    /// identically).
    pub fn fingerprint(&self) -> String {
        let mut s = String::new();
        for lp in &self.layers {
            s.push_str(&format!(
                "{}=w{}a{}{};",
                lp.name,
                lp.wbits,
                lp.abits,
                lp.granularity.tag()
            ));
        }
        s
    }

    /// Quantized weight payload in bits (Σ numel × wbits) — the quantity
    /// the Pareto budget constrains. Scale/zero-point side info is
    /// plan-invariant and reported separately by [`Self::weight_bits`].
    pub fn payload_bits(&self, m: &Manifest) -> usize {
        self.layers
            .iter()
            .zip(&m.quant_layers)
            .map(|(lp, ql)| ql.out_ch * ql.flat_k * lp.wbits as usize)
            .sum()
    }

    /// Deployable weight size in bits: payload plus the scale/zero-point
    /// overhead, mirroring `quant::export::export_model`'s size report.
    /// The export format always emits `[out_ch]` scale/zp vectors (a
    /// per-tensor plan splats one value into them), so the overhead is
    /// `out_ch × 2 × 32` regardless of granularity.
    pub fn weight_bits(&self, m: &Manifest) -> usize {
        self.layers
            .iter()
            .zip(&m.quant_layers)
            .map(|(lp, ql)| {
                ql.out_ch * ql.flat_k * lp.wbits as usize + ql.out_ch * 2 * 32
            })
            .sum()
    }

    /// FP32 weight payload in bits (Σ numel × 32) — the Pareto budget
    /// baseline.
    pub fn fp32_bits(m: &Manifest) -> usize {
        m.quant_layers
            .iter()
            .map(|q| q.out_ch * q.flat_k * 32)
            .sum()
    }

    /// Unweighted mean weight bits (display only; size math goes through
    /// [`Self::payload_bits`]).
    pub fn avg_wbits(&self) -> f32 {
        if self.layers.is_empty() {
            return 0.0;
        }
        self.layers.iter().map(|l| l.wbits as f32).sum::<f32>()
            / self.layers.len() as f32
    }

    /// Compact tag for progress lines: "W4A4" when the interior layers
    /// are uniform (matching the historical prints, which ignored the
    /// first/last pin), "Wmix~2.7A4" for mixed plans.
    pub fn label(&self) -> String {
        let n = self.layers.len();
        if n == 0 {
            return "W-A-".into();
        }
        let interior: &[LayerPlan] = if n > 2 {
            &self.layers[1..n - 1]
        } else {
            &self.layers
        };
        let w = interior[0].wbits;
        let a = interior[0].abits;
        if interior.iter().all(|l| l.wbits == w && l.abits == a) {
            format!("W{w}A{a}")
        } else {
            format!("Wmix~{:.1}A{a}", self.avg_wbits())
        }
    }

    /// Serialize for the artifact cache / GTS1: one `[wbits, abits,
    /// granularity]` u32 triple per layer, keyed by layer name.
    pub fn to_store(&self) -> Store {
        let mut s = Store::new();
        s.insert(
            "plan.len",
            Tensor::from_u32(&[], vec![self.layers.len() as u32]),
        );
        for lp in &self.layers {
            s.insert(
                &format!("plan.{}", lp.name),
                Tensor::from_u32(
                    &[3],
                    vec![lp.wbits, lp.abits, lp.granularity.code()],
                ),
            );
        }
        s
    }

    /// Rebuild a plan from [`Self::to_store`] bytes, re-keyed by the
    /// manifest's layer order (a manifest/plan mismatch is an error, not
    /// a silent misassignment).
    pub fn from_store(m: &Manifest, s: &Store) -> Result<PrecisionPlan> {
        let lt = s.get("plan.len")?;
        anyhow::ensure!(
            lt.dtype() == crate::tensor::DType::U32,
            "plan store: plan.len has dtype {:?}",
            lt.dtype()
        );
        let len = *lt
            .as_u32()
            .first()
            .ok_or_else(|| anyhow::anyhow!("plan store: empty plan.len"))?
            as usize;
        anyhow::ensure!(
            len == m.quant_layers.len(),
            "plan store covers {len} layers, manifest has {}",
            m.quant_layers.len()
        );
        let mut layers = Vec::with_capacity(len);
        for ql in &m.quant_layers {
            let t = s.get(&format!("plan.{}", ql.name))?;
            anyhow::ensure!(
                t.dtype() == crate::tensor::DType::U32,
                "plan store: layer '{}' has dtype {:?}",
                ql.name,
                t.dtype()
            );
            let v = t.as_u32();
            anyhow::ensure!(
                v.len() == 3,
                "plan store: layer '{}' record has {} fields",
                ql.name,
                v.len()
            );
            layers.push(LayerPlan {
                name: ql.name.clone(),
                wbits: validate_bits(&format!("{} wbits", ql.name), v[0])?,
                abits: validate_bits(&format!("{} abits", ql.name), v[1])?,
                granularity: Granularity::from_code(v[2])?,
            });
        }
        let plan = PrecisionPlan { layers };
        plan.validate(m)?;
        Ok(plan)
    }

    /// Aligned per-layer report (the `experiments --exp plan` table and
    /// the Pareto resolution print).
    pub fn render(&self, m: &Manifest) -> String {
        let mut out = String::new();
        out.push_str(&format!(
            "{:<16} {:>8} {:>5} {:>5} {:>5} {:>10}\n",
            "layer", "numel", "wbits", "abits", "gran", "kbits"
        ));
        for (lp, ql) in self.layers.iter().zip(&m.quant_layers) {
            let numel = ql.out_ch * ql.flat_k;
            out.push_str(&format!(
                "{:<16} {:>8} {:>5} {:>5} {:>5} {:>10.1}\n",
                lp.name,
                numel,
                lp.wbits,
                lp.abits,
                lp.granularity.tag(),
                numel as f64 * lp.wbits as f64 / 1000.0
            ));
        }
        let fp = Self::fp32_bits(m).max(1);
        out.push_str(&format!(
            "total: {:.1} kbit payload ({:.1}% of FP32), {:.1} kbit deployed\n",
            self.payload_bits(m) as f64 / 1000.0,
            100.0 * self.payload_bits(m) as f64 / fp as f64,
            self.weight_bits(m) as f64 / 1000.0,
        ));
        out
    }
}

/// Synthetic manifest builder shared by the precision unit tests.
#[cfg(test)]
pub(crate) fn toy_manifest(layers: &[(&str, usize, usize)]) -> Manifest {
    let ql: Vec<String> = layers
        .iter()
        .map(|(n, o, k)| {
            format!(
                r#"{{"name": "{n}", "w_shape": [1, 1, {k}, {o}],
                    "out_ch": {o}, "flat_k": {k}, "block": 0}}"#
            )
        })
        .collect();
    Manifest::from_json_text(&format!(
        r#"{{
            "model": "toy", "image": [8, 8, 3], "num_classes": 4,
            "num_blocks": 1, "latent": 16,
            "batch": {{"train": 8, "eval": 8, "stats": 8, "recon": 8}},
            "params": [], "bn": [], "qstate": [], "gen_params": [],
            "quant_layers": [{}], "learnable": {{"0": []}},
            "bounds": [], "entrypoints": {{}}
        }}"#,
        ql.join(",")
    ))
    .unwrap()
}

#[cfg(test)]
mod tests {
    use super::*;

    fn three_layer() -> Manifest {
        toy_manifest(&[("stem", 4, 27), ("mid", 8, 36), ("head", 4, 8)])
    }

    #[test]
    fn bounds_match_paper() {
        assert_eq!(wbounds(4), (0.0, 15.0));
        assert_eq!(wbounds(2), (0.0, 3.0));
        assert_eq!(abounds(4), (-8.0, 7.0));
        assert_eq!(abounds(8), (-128.0, 127.0));
    }

    #[test]
    fn validate_bits_rejects_degenerate_grids() {
        assert!(validate_bits("wbits", 0).is_err());
        assert!(validate_bits("abits", 9).is_err());
        for b in MIN_BITS..=MAX_BITS {
            assert_eq!(validate_bits("wbits", b).unwrap(), b);
        }
    }

    #[test]
    fn default_plan_matches_historical_first_last_formula() {
        let m = three_layer();
        let plan = PrecisionPlan::uniform(&m, 4, 4, Granularity::PerChannel)
            .unwrap()
            .with_first_last(8)
            .unwrap();
        let last = m.quant_layers.len() - 1;
        for (li, lp) in plan.layers.iter().enumerate() {
            let first_or_last = li == 0 || li == last;
            let want = if first_or_last { 8 } else { 4 };
            assert_eq!(lp.wbits, want, "layer {li} wbits");
            assert_eq!(lp.abits, want, "layer {li} abits");
        }
        plan.validate(&m).unwrap();
        assert_eq!(plan.label(), "W4A4");
    }

    #[test]
    fn strict_uniform_has_no_exception() {
        let m = three_layer();
        let plan = PrecisionPlan::uniform(&m, 4, 2, Granularity::PerTensor)
            .unwrap()
            .with_first_last(0)
            .unwrap();
        assert!(plan.layers.iter().all(|l| l.wbits == 4 && l.abits == 2));
    }

    #[test]
    fn fingerprint_tracks_every_field() {
        let m = three_layer();
        let base = PrecisionPlan::uniform(&m, 4, 4, Granularity::PerChannel)
            .unwrap();
        let fl =
            base.clone().with_first_last(8).unwrap();
        assert_ne!(base.fingerprint(), fl.fingerprint());
        let mut gran = base.clone();
        gran.layers[1].granularity = Granularity::PerTensor;
        assert_ne!(base.fingerprint(), gran.fingerprint());
        assert_eq!(base.fingerprint(), base.clone().fingerprint());
    }

    #[test]
    fn size_accounting() {
        let m = three_layer();
        let plan = PrecisionPlan::uniform(&m, 4, 4, Granularity::PerChannel)
            .unwrap();
        let numel = 4 * 27 + 8 * 36 + 4 * 8;
        assert_eq!(PrecisionPlan::fp32_bits(&m), numel * 32);
        assert_eq!(plan.payload_bits(&m), numel * 4);
        // export overhead: (4 + 8 + 4) channels x 2 x 32 bits — the GTS1
        // export always emits [out_ch] scale/zp vectors, so a per-tensor
        // plan deploys at the same size
        assert_eq!(plan.weight_bits(&m), numel * 4 + 16 * 64);
        let pt = PrecisionPlan::uniform(&m, 4, 4, Granularity::PerTensor)
            .unwrap();
        assert_eq!(pt.weight_bits(&m), plan.weight_bits(&m));
    }

    #[test]
    fn plan_round_trips_through_gts1() {
        let m = three_layer();
        let mut plan =
            PrecisionPlan::uniform(&m, 4, 4, Granularity::PerChannel)
                .unwrap()
                .with_first_last(8)
                .unwrap();
        plan.layers[1].wbits = 3;
        plan.layers[1].granularity = Granularity::PerTensor;
        let dir = std::env::temp_dir().join("genie_plan_roundtrip_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("plan.gts");
        plan.to_store().save(&path).unwrap();
        let back = PrecisionPlan::from_store(
            &m,
            &Store::load(&path).unwrap(),
        )
        .unwrap();
        assert_eq!(plan, back);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn from_store_rejects_mismatched_manifest() {
        let m = three_layer();
        let plan = PrecisionPlan::uniform(&m, 4, 4, Granularity::PerChannel)
            .unwrap();
        let other = toy_manifest(&[("stem", 4, 27)]);
        assert!(PrecisionPlan::from_store(&other, &plan.to_store()).is_err());
    }

    #[test]
    fn labels() {
        let m = three_layer();
        let mut plan =
            PrecisionPlan::uniform(&m, 4, 4, Granularity::PerChannel)
                .unwrap()
                .with_first_last(8)
                .unwrap();
        assert_eq!(plan.label(), "W4A4");
        plan.layers.push(LayerPlan {
            name: "extra".into(),
            wbits: 2,
            abits: 4,
            granularity: Granularity::PerChannel,
        });
        assert!(plan.label().starts_with("Wmix~"));
    }

    #[test]
    fn policy_and_granularity_parse() {
        assert_eq!(Policy::parse("uniform").unwrap(), Policy::Uniform);
        assert_eq!(Policy::parse("pareto").unwrap(), Policy::Pareto);
        assert!(Policy::parse("nope").is_err());
        assert_eq!(
            Granularity::parse("per_tensor").unwrap(),
            Granularity::PerTensor
        );
        assert!(Granularity::parse("nope").is_err());
    }
}

//! Pareto policy internals (DESIGN.md §10): per-layer quantization
//! sensitivity measured on the calibration set, and the greedy
//! budget-constrained bit allocator it feeds.
//!
//! Sensitivity follows ZeroQ: for layer ℓ and candidate bit-width b,
//! fake-quantize only ℓ's weights (Eq. 6 grid search at b bits and the
//! configured granularity — the same quantizer the plan deploys), run
//! the teacher forward on calibration batches, and record
//! KL(teacher ‖ perturbed) averaged per sample. One probe = one (ℓ, b)
//! pair; probes are independent, so they fan out as jobs on the exec
//! pool — deterministically, since nothing here draws randomness
//! (results land in submission order). The teacher is uploaded once
//! (`upload_store`, DESIGN.md §8) and Arc-shared by every probe, which
//! swaps in only its one perturbed weight tensor plus the batches —
//! never the full model. Layers pinned by the FirstLast8 transform are
//! not probed at all (the allocator never reads their rows).
//!
//! Allocation is the ZeroQ Pareto-frontier greedy: start every free
//! layer at the cheapest candidate, then repeatedly buy the upgrade
//! with the best ΔKL per extra payload bit that still fits the
//! `target_size` budget. First/last pins are honored as fixed costs.

use anyhow::Result;

use crate::data::image_batches;
use crate::exec::{run_jobs, Parallelism, PoolReport};
use crate::quant::fake_quant_weights;
use crate::runtime::{Manifest, ModelRt};
use crate::store::Store;

use super::{LayerPlan, PrecisionCfg, PrecisionPlan, validate_bits};

/// Cap for non-finite KL probes (an exploding perturbed forward means
/// "maximally sensitive", not "poisons the argmax with NaN").
const KL_CAP: f32 = 1e6;

/// Measured per-layer sensitivity: `kl[layer][candidate]`, layers in
/// manifest order, candidates ascending.
#[derive(Debug, Clone)]
pub struct Sensitivity {
    pub layers: Vec<String>,
    pub candidates: Vec<u32>,
    pub kl: Vec<Vec<f32>>,
}

/// Pareto weight budget in payload bits: `target_size` × the FP32
/// payload (Σ numel × 32).
pub fn budget_bits(m: &Manifest, target_size: f32) -> usize {
    (target_size as f64 * PrecisionPlan::fp32_bits(m) as f64).floor() as usize
}

/// The FirstLast8 pin set for one manifest: `Some(bits)` on the first
/// and last quant layers, `None` elsewhere (all-`None` when disabled).
/// Shared by the sensitivity sweep (pinned layers are not probed) and
/// the allocator (pins are fixed costs).
pub fn first_last_pins(m: &Manifest, first_last_bits: u32) -> Vec<Option<u32>> {
    let n = m.quant_layers.len();
    (0..n)
        .map(|i| {
            if first_last_bits != 0 && (i == 0 || i + 1 == n) {
                Some(first_last_bits)
            } else {
                None
            }
        })
        .collect()
}

/// Log-softmax of the first `valid` rows of a `[rows, classes]` logits
/// buffer (stable: max-shifted, f64 accumulation).
fn log_softmax_rows(logits: &[f32], classes: usize, valid: usize) -> Vec<f32> {
    let mut out = Vec::with_capacity(valid * classes);
    for r in 0..valid {
        let row = &logits[r * classes..(r + 1) * classes];
        let mx = row.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
        let lse = row
            .iter()
            .map(|&v| ((v - mx) as f64).exp())
            .sum::<f64>()
            .ln() as f32
            + mx;
        out.extend(row.iter().map(|&v| v - lse));
    }
    out
}

/// Σ p_ref · (log p_ref − log p_q) over flattened log-prob rows.
fn kl_sum(ref_lp: &[f32], q_lp: &[f32]) -> f64 {
    ref_lp
        .iter()
        .zip(q_lp)
        .map(|(&r, &q)| (r as f64).exp() * (r - q) as f64)
        .sum()
}

/// Measure KL(teacher ‖ layer-perturbed teacher) for every free
/// (quant layer, candidate bit-width) pair over the first
/// `cfg.sens_batches` calibration batches, sharded on the exec pool.
/// Layers pinned by `cfg.first_last_bits` are skipped (their KL rows
/// stay 0.0 — the allocator never reads them; pass a cfg with
/// `first_last_bits = 0` to probe everything, e.g. for reports). The
/// teacher is device-resident: uploaded once, Arc-shared by probes.
pub fn measure_sensitivity(
    mrt: &ModelRt,
    teacher: &Store,
    calib: &crate::tensor::Tensor,
    cfg: &PrecisionCfg,
    pnorm: f32,
    par: Parallelism,
) -> Result<(Sensitivity, PoolReport)> {
    let m = &mrt.manifest;
    let candidates: &[u32] = &cfg.candidates;
    anyhow::ensure!(!candidates.is_empty(), "sensitivity: no candidate bits");
    for &b in candidates {
        validate_bits("candidate", b)?;
    }
    anyhow::ensure!(
        !m.quant_layers.is_empty(),
        "sensitivity: manifest has no quant layers"
    );
    let classes = m.num_classes;
    let bs = m.batch("eval");
    let mut batches = image_batches(calib, bs);
    batches.truncate(cfg.sens_batches.max(1));

    // one upload of the full teacher, Arc-shared by the reference pass
    // and every probe (DESIGN.md §8)
    let teacher_dev = mrt.upload_store(teacher)?;
    let tdev = &teacher_dev;

    // reference log-probs of the unperturbed teacher, once
    let mut ref_logp = Vec::with_capacity(batches.len());
    {
        let mut dev = teacher_dev.clone();
        for (bx, valid) in &batches {
            dev.insert("x", bx)?;
            mrt.call_device("eval_batch", &mut dev)?;
            ref_logp.push(log_softmax_rows(
                dev.fetch("logits")?.as_f32(),
                classes,
                *valid,
            ));
        }
    }

    // one pool job per free (layer, candidate) probe — pinned layers
    // are fixed costs the allocator never compares
    let pins = first_last_pins(m, cfg.first_last_bits);
    let probes: Vec<(usize, usize)> = (0..m.quant_layers.len())
        .filter(|&li| pins[li].is_none())
        .flat_map(|li| (0..candidates.len()).map(move |ci| (li, ci)))
        .collect();
    let granularity = cfg.granularity;
    let batches = &batches;
    let ref_logp = &ref_logp;
    let jobs: Vec<_> = probes
        .iter()
        .map(|&(li, ci)| {
            move || -> Result<f32> {
                let ql = &m.quant_layers[li];
                let name = format!("{}.w", ql.name);
                // the probe quantizer matches the deployed one: same
                // Eq. 6 search, same granularity
                let fq = fake_quant_weights(
                    teacher.get(&name)?,
                    candidates[ci],
                    pnorm,
                    granularity,
                )?;
                let mut dev = tdev.clone();
                dev.insert(&name, &fq)?;
                let mut kl = 0.0f64;
                let mut count = 0usize;
                for (bi, (bx, valid)) in batches.iter().enumerate() {
                    dev.insert("x", bx)?;
                    mrt.call_device("eval_batch", &mut dev)?;
                    let lp = log_softmax_rows(
                        dev.fetch("logits")?.as_f32(),
                        classes,
                        *valid,
                    );
                    kl += kl_sum(&ref_logp[bi], &lp);
                    count += valid;
                }
                let kl = (kl / count.max(1) as f64) as f32;
                Ok(if kl.is_finite() { kl.clamp(0.0, KL_CAP) } else { KL_CAP })
            }
        })
        .collect();
    let (vals, pool) = run_jobs(par, jobs)?;

    let mut kl = vec![vec![0.0f32; candidates.len()]; m.quant_layers.len()];
    for (&(li, ci), v) in probes.iter().zip(vals) {
        kl[li][ci] = v;
    }
    Ok((
        Sensitivity {
            layers: m.quant_layers.iter().map(|q| q.name.clone()).collect(),
            candidates: candidates.to_vec(),
            kl,
        },
        pool,
    ))
}

/// Greedy Pareto allocation: per-layer weight bits minimizing total
/// sensitivity subject to `Σ numel × bits ≤ budget`. `pinned[i] =
/// Some(b)` forces layer i to b bits (its cost still counts against the
/// budget). Errors when even the cheapest assignment exceeds the
/// budget, naming the minimum feasible target.
pub fn allocate_bits(
    kl: &[Vec<f32>],
    candidates: &[u32],
    numel: &[usize],
    pinned: &[Option<u32>],
    budget: usize,
) -> Result<Vec<u32>> {
    anyhow::ensure!(!candidates.is_empty(), "allocate: no candidate bits");
    anyhow::ensure!(
        candidates.windows(2).all(|w| w[0] < w[1]),
        "allocate: candidates must be strictly ascending: {candidates:?}"
    );
    let n = numel.len();
    anyhow::ensure!(
        kl.len() == n && pinned.len() == n,
        "allocate: {} layers but {} kl rows / {} pins",
        n,
        kl.len(),
        pinned.len()
    );
    for (i, row) in kl.iter().enumerate() {
        anyhow::ensure!(
            row.len() == candidates.len(),
            "allocate: layer {i} has {} kl samples for {} candidates",
            row.len(),
            candidates.len()
        );
    }

    let mut bits: Vec<u32> = (0..n)
        .map(|i| pinned[i].unwrap_or(candidates[0]))
        .collect();
    let mut level: Vec<usize> = vec![0; n];
    let mut total: usize =
        (0..n).map(|i| numel[i] * bits[i] as usize).sum();
    if total > budget {
        let fp: usize = numel.iter().map(|&c| c * 32).sum();
        anyhow::bail!(
            "precision budget infeasible: cheapest plan needs {total} \
             payload bits but the budget is {budget} — raise --target-size \
             to at least {:.3}",
            total as f64 / fp.max(1) as f64
        );
    }

    loop {
        // best affordable upgrade: max ΔKL per extra payload bit,
        // tie-broken by lower layer index (deterministic)
        let mut best: Option<(f64, usize)> = None;
        for i in 0..n {
            if pinned[i].is_some() || level[i] + 1 >= candidates.len() {
                continue;
            }
            let extra = (candidates[level[i] + 1] - candidates[level[i]])
                as usize
                * numel[i];
            if total + extra > budget {
                continue;
            }
            let gain = (kl[i][level[i]] - kl[i][level[i] + 1]).max(0.0) as f64
                / extra.max(1) as f64;
            if best.map_or(true, |(g, _)| gain > g) {
                best = Some((gain, i));
            }
        }
        let Some((_, i)) = best else { break };
        total += (candidates[level[i] + 1] - candidates[level[i]]) as usize
            * numel[i];
        level[i] += 1;
        bits[i] = candidates[level[i]];
    }
    Ok(bits)
}

/// Build the Pareto plan for a manifest from measured sensitivity:
/// greedy allocation of weight bits under the `target_size` budget,
/// uniform `abits` everywhere except the first/last pin.
pub fn pareto_plan(
    m: &Manifest,
    sens: &Sensitivity,
    abits: u32,
    cfg: &PrecisionCfg,
) -> Result<PrecisionPlan> {
    let n = m.quant_layers.len();
    anyhow::ensure!(n > 0, "pareto: manifest has no quant layers");
    anyhow::ensure!(
        sens.kl.len() == n,
        "pareto: sensitivity covers {} layers, manifest has {n}",
        sens.kl.len()
    );
    validate_bits("abits", abits)?;
    let numel: Vec<usize> =
        m.quant_layers.iter().map(|q| q.out_ch * q.flat_k).collect();
    let pinned = first_last_pins(m, cfg.first_last_bits);
    let budget = budget_bits(m, cfg.target_size);
    let wbits =
        allocate_bits(&sens.kl, &sens.candidates, &numel, &pinned, budget)?;
    // compose the allocation with the canonical FirstLast8 transform —
    // one source of truth for pin semantics (the allocator already
    // charged the pinned layers at first_last_bits, so the transform
    // only re-asserts wbits and sets the pinned abits)
    let layers = m
        .quant_layers
        .iter()
        .enumerate()
        .map(|(i, q)| LayerPlan {
            name: q.name.clone(),
            wbits: wbits[i],
            abits,
            granularity: cfg.granularity,
        })
        .collect();
    let plan = PrecisionPlan { layers }
        .with_first_last(cfg.first_last_bits)?;
    plan.validate(m)?;
    anyhow::ensure!(
        plan.payload_bits(m) <= budget,
        "pareto: allocated {} payload bits over the {budget}-bit budget",
        plan.payload_bits(m)
    );
    Ok(plan)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::precision::{toy_manifest, Granularity, Policy};

    fn cands() -> Vec<u32> {
        vec![2, 4, 8]
    }

    #[test]
    fn budget_respected_and_sensitive_layer_wins() {
        // layer 1 hurts a lot at low bits, layer 0 barely cares
        let kl = vec![
            vec![0.010, 0.008, 0.007],
            vec![5.000, 0.500, 0.010],
        ];
        let numel = vec![100usize, 100];
        let pinned = vec![None, None];
        // budget for exactly one layer at 8 and one at 2: 1000 bits
        let bits =
            allocate_bits(&kl, &cands(), &numel, &pinned, 1000).unwrap();
        assert_eq!(bits, vec![2, 8], "sensitive layer must get the bits");
        let cost: usize = bits
            .iter()
            .zip(&numel)
            .map(|(&b, &c)| b as usize * c)
            .sum();
        assert!(cost <= 1000);
    }

    #[test]
    fn generous_budget_saturates_at_max_candidate() {
        let kl = vec![vec![1.0, 0.5, 0.1]; 3];
        let numel = vec![10usize; 3];
        let bits = allocate_bits(
            &kl, &cands(), &numel, &[None, None, None], usize::MAX,
        )
        .unwrap();
        assert_eq!(bits, vec![8, 8, 8]);
    }

    #[test]
    fn pins_are_honored_and_counted() {
        let kl = vec![vec![1.0, 0.5, 0.1]; 3];
        let numel = vec![100usize; 3];
        let pinned = vec![Some(8u32), None, Some(8u32)];
        // pins cost 1600; 800 left = middle layer at most 8... cap at 600
        // leaves it at 4 (400 fits, next step to 8 costs +400 more)
        let bits =
            allocate_bits(&kl, &cands(), &numel, &pinned, 2200).unwrap();
        assert_eq!(bits[0], 8);
        assert_eq!(bits[2], 8);
        assert_eq!(bits[1], 4);
    }

    #[test]
    fn infeasible_budget_errors_with_minimum_target() {
        let kl = vec![vec![1.0, 0.5, 0.1]];
        let err = allocate_bits(&kl, &cands(), &[100], &[None], 150)
            .unwrap_err();
        let msg = format!("{err}");
        assert!(msg.contains("target-size"), "{msg}");
    }

    #[test]
    fn allocation_is_deterministic() {
        let kl = vec![vec![1.0, 0.5, 0.1]; 4];
        let numel = vec![50usize; 4];
        let pinned = vec![None; 4];
        let a = allocate_bits(&kl, &cands(), &numel, &pinned, 700).unwrap();
        let b = allocate_bits(&kl, &cands(), &numel, &pinned, 700).unwrap();
        assert_eq!(a, b);
        // equal gains tie-break toward lower layer index
        assert!(a[0] >= a[3], "tie-break must favor earlier layers: {a:?}");
    }

    #[test]
    fn bad_shapes_rejected() {
        assert!(allocate_bits(&[], &cands(), &[1], &[None], 10).is_err());
        assert!(
            allocate_bits(&[vec![1.0]], &cands(), &[1], &[None], 10).is_err()
        );
        assert!(allocate_bits(
            &[vec![1.0, 0.5, 0.1]],
            &[4, 2, 8],
            &[1],
            &[None],
            10
        )
        .is_err());
    }

    #[test]
    fn pareto_plan_meets_budget_and_pins_first_last() {
        let m = toy_manifest(&[("stem", 4, 27), ("mid", 8, 36), ("head", 4, 8)]);
        let sens = Sensitivity {
            layers: vec!["stem".into(), "mid".into(), "head".into()],
            candidates: cands(),
            kl: vec![
                vec![1.0, 0.5, 0.1],
                vec![3.0, 0.2, 0.05],
                vec![1.0, 0.5, 0.1],
            ],
        };
        let cfg = PrecisionCfg {
            policy: Policy::Pareto,
            target_size: 0.25,
            granularity: Granularity::PerChannel,
            ..Default::default()
        };
        let plan = pareto_plan(&m, &sens, 4, &cfg).unwrap();
        assert_eq!(plan.layers[0].wbits, 8);
        assert_eq!(plan.layers[0].abits, 8);
        assert_eq!(plan.layers[2].wbits, 8);
        assert!(plan.payload_bits(&m) <= budget_bits(&m, 0.25));
        assert_eq!(plan.layers[1].abits, 4);
        plan.validate(&m).unwrap();
    }

    #[test]
    fn pareto_budget_scales_allocation() {
        let m = toy_manifest(&[("a", 8, 32), ("b", 8, 32), ("c", 8, 32)]);
        let sens = Sensitivity {
            layers: vec!["a".into(), "b".into(), "c".into()],
            candidates: cands(),
            kl: vec![vec![1.0, 0.5, 0.1]; 3],
        };
        let mut cfg = PrecisionCfg {
            policy: Policy::Pareto,
            first_last_bits: 0,
            ..Default::default()
        };
        cfg.target_size = 0.0626; // just above 2/32: everything at 2 bits
        let lean = pareto_plan(&m, &sens, 4, &cfg).unwrap();
        assert!(lean.layers.iter().all(|l| l.wbits == 2), "{lean:?}");
        cfg.target_size = 0.25; // the all-8-bit budget
        let rich = pareto_plan(&m, &sens, 4, &cfg).unwrap();
        assert!(rich.layers.iter().all(|l| l.wbits == 8), "{rich:?}");
        cfg.target_size = 0.001;
        assert!(pareto_plan(&m, &sens, 4, &cfg).is_err());
    }

    #[test]
    fn first_last_pins_shape() {
        let m = toy_manifest(&[("a", 2, 4), ("b", 2, 4), ("c", 2, 4)]);
        assert_eq!(
            first_last_pins(&m, 8),
            vec![Some(8), None, Some(8)]
        );
        assert_eq!(first_last_pins(&m, 0), vec![None, None, None]);
        let one = toy_manifest(&[("a", 2, 4)]);
        assert_eq!(first_last_pins(&one, 8), vec![Some(8)]);
    }

    #[test]
    fn log_softmax_and_kl_basics() {
        // identical distributions => KL 0
        let lp = log_softmax_rows(&[1.0, 2.0, 3.0, 0.0], 2, 2);
        assert!((kl_sum(&lp, &lp)).abs() < 1e-9);
        // rows sum to 1 in prob space
        let p: f64 = lp[..2].iter().map(|&v| (v as f64).exp()).sum();
        assert!((p - 1.0).abs() < 1e-5);
        // diverging distribution => positive KL
        let q = log_softmax_rows(&[3.0, 1.0, 0.0, 3.0], 2, 2);
        assert!(kl_sum(&lp, &q) > 0.0);
    }
}

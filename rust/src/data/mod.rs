//! Dataset substrate: loads the procedural dataset artifact
//! (artifacts/dataset.bin, written by python/compile/data.py) and provides
//! shuffled training batches and fixed-size (padded) eval batches.

use std::path::Path;

use anyhow::Result;

use crate::store::Store;
use crate::tensor::{Pcg32, Tensor};

#[derive(Debug, Clone)]
pub struct Dataset {
    pub train_x: Tensor,
    pub train_y: Vec<i32>,
    pub test_x: Tensor,
    pub test_y: Vec<i32>,
}

impl Dataset {
    pub fn load(artifacts: impl AsRef<Path>) -> Result<Dataset> {
        let s = Store::load(artifacts.as_ref().join("dataset.bin"))?;
        Ok(Dataset {
            train_x: s.get("train_x")?.clone(),
            train_y: s.get("train_y")?.as_i32().to_vec(),
            test_x: s.get("test_x")?.clone(),
            test_y: s.get("test_y")?.as_i32().to_vec(),
        })
    }

    pub fn train_len(&self) -> usize {
        self.train_y.len()
    }

    pub fn test_len(&self) -> usize {
        self.test_y.len()
    }

    /// A random training batch of `bs` images: ([bs,H,W,C], labels[bs]).
    pub fn train_batch(&self, rng: &mut Pcg32, bs: usize) -> (Tensor, Vec<i32>) {
        let idx: Vec<usize> =
            (0..bs).map(|_| rng.below(self.train_len())).collect();
        let x = self.train_x.gather_rows(&idx);
        let y = idx.iter().map(|&i| self.train_y[i]).collect();
        (x, y)
    }

    /// A fixed calibration subset of the first `n` training images,
    /// shuffled with `rng` (the "randomly sampled 1K images" of Table 5).
    pub fn calibration(&self, rng: &mut Pcg32, n: usize) -> (Tensor, Vec<i32>) {
        let mut idx: Vec<usize> = (0..self.train_len()).collect();
        rng.shuffle(&mut idx);
        idx.truncate(n);
        let x = self.train_x.gather_rows(&idx);
        let y = idx.iter().map(|&i| self.train_y[i]).collect();
        (x, y)
    }

    /// Fixed-size eval batches over the test set; the final batch is
    /// padded by repeating row 0 and `valid` says how many rows count.
    pub fn eval_batches(&self, bs: usize) -> Vec<(Tensor, Vec<i32>, usize)> {
        batches_padded(&self.test_x, &self.test_y, bs)
    }
}

/// Split an [N,...] tensor + labels into fixed-size padded batches.
pub fn batches_padded(
    x: &Tensor,
    y: &[i32],
    bs: usize,
) -> Vec<(Tensor, Vec<i32>, usize)> {
    let n = y.len();
    let mut out = Vec::new();
    let mut start = 0;
    while start < n {
        let valid = bs.min(n - start);
        let idx: Vec<usize> =
            (0..bs).map(|i| if i < valid { start + i } else { start }).collect();
        let bx = x.gather_rows(&idx);
        let by = idx.iter().map(|&i| y[i]).collect();
        out.push((bx, by, valid));
        start += valid;
    }
    out
}

/// Split unlabeled images into fixed-size padded batches.
pub fn image_batches(x: &Tensor, bs: usize) -> Vec<(Tensor, usize)> {
    let n = x.shape[0];
    let mut out = Vec::new();
    let mut start = 0;
    while start < n {
        let valid = bs.min(n - start);
        let idx: Vec<usize> =
            (0..bs).map(|i| if i < valid { start + i } else { start }).collect();
        out.push((x.gather_rows(&idx), valid));
        start += valid;
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny() -> Dataset {
        let n = 10;
        let x = Tensor::from_f32(
            &[n, 2, 2, 1],
            (0..n * 4).map(|i| i as f32).collect(),
        );
        let y: Vec<i32> = (0..n as i32).collect();
        Dataset {
            train_x: x.clone(),
            train_y: y.clone(),
            test_x: x,
            test_y: y,
        }
    }

    #[test]
    fn train_batch_shape() {
        let d = tiny();
        let mut rng = Pcg32::new(1);
        let (x, y) = d.train_batch(&mut rng, 4);
        assert_eq!(x.shape, vec![4, 2, 2, 1]);
        assert_eq!(y.len(), 4);
    }

    #[test]
    fn eval_batches_cover_everything_once() {
        let d = tiny();
        let batches = d.eval_batches(4);
        assert_eq!(batches.len(), 3);
        let valid: usize = batches.iter().map(|(_, _, v)| v).sum();
        assert_eq!(valid, 10);
        // padded rows replicate row `start`
        let (bx, _, v) = &batches[2];
        assert_eq!(*v, 2);
        assert_eq!(bx.shape[0], 4);
    }

    #[test]
    fn calibration_unique_samples() {
        let d = tiny();
        let mut rng = Pcg32::new(2);
        let (x, y) = d.calibration(&mut rng, 10);
        assert_eq!(x.shape[0], 10);
        let mut sorted = y.clone();
        sorted.sort();
        assert_eq!(sorted, (0..10).collect::<Vec<_>>());
    }

    #[test]
    fn image_batches_pad() {
        let x = Tensor::from_f32(&[3, 2], vec![0., 1., 10., 11., 20., 21.]);
        let b = image_batches(&x, 2);
        assert_eq!(b.len(), 2);
        assert_eq!(b[1].1, 1);
        assert_eq!(b[1].0.as_f32(), &[20., 21., 20., 21.]);
    }
}

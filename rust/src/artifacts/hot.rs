//! Tier 0 of the artifact store (DESIGN.md §16): a process-global
//! in-memory cache of deserialized artifacts behind `Arc<Store>`
//! handles. N grid jobs that agree on a content key deserialize the
//! GTS1 bytes exactly once; every later load clones an `Arc` instead of
//! re-reading and re-parsing a multi-megabyte file.
//!
//! The map is namespaced by *canonical cache directory*, and byte
//! accounting + LRU eviction are per-namespace: two `ArtifactCache`
//! instances on different dirs (every unit test, every grid job with a
//! scratch cache) never see each other's entries or evict each other's
//! budget, while instances on the same dir (the N per-node job caches of
//! one grid run) share one hot pool — which is the whole point.
//!
//! Sizes are accounted as the artifact's *serialized* length — a stable,
//! cheap proxy for resident memory (GTS1 bytes are within a few percent
//! of the deserialized tensor payload). The budget is passed per call by
//! the owning cache, so different dirs can run different budgets.
//!
//! A second process-global table counts tier-1 deserializations per
//! `(dir, stem)` — the observable the "N agreeing cells parse once"
//! acceptance test pins (`tests/grid.rs`).

use std::collections::HashMap;
use std::path::Path;
use std::sync::{Mutex, MutexGuard, OnceLock};
use std::sync::Arc;

use crate::store::Store;

#[derive(Debug)]
struct HotEntry {
    store: Arc<Store>,
    bytes: u64,
    /// Monotone recency stamp (global counter; larger = more recent).
    tick: u64,
}

#[derive(Debug, Default)]
struct DirCache {
    entries: HashMap<String, HotEntry>,
    bytes: u64,
}

#[derive(Debug, Default)]
struct HotState {
    dirs: HashMap<String, DirCache>,
    tick: u64,
}

fn state() -> MutexGuard<'static, HotState> {
    static HOT: OnceLock<Mutex<HotState>> = OnceLock::new();
    HOT.get_or_init(|| Mutex::new(HotState::default()))
        .lock()
        .unwrap_or_else(|p| p.into_inner())
}

/// The hot tier's namespace key for a cache dir: the canonical path when
/// resolvable (so `cache/` and `./cache/` share entries), the lossy
/// string otherwise.
pub(crate) fn namespace(dir: &Path) -> String {
    std::fs::canonicalize(dir)
        .map(|p| p.to_string_lossy().into_owned())
        .unwrap_or_else(|_| dir.to_string_lossy().into_owned())
}

/// Tier-0 lookup; bumps the entry's recency on hit.
pub(crate) fn get(ns: &str, stem: &str) -> Option<Arc<Store>> {
    let mut st = state();
    st.tick += 1;
    let tick = st.tick;
    let entry = st.dirs.get_mut(ns)?.entries.get_mut(stem)?;
    entry.tick = tick;
    Some(entry.store.clone())
}

/// Insert (or replace) an entry, then evict least-recently-used entries
/// of the same namespace until its bytes fit `budget` (0 = unlimited).
/// Returns how many entries were evicted. An artifact larger than the
/// whole budget is not cached at all — caching it would evict everything
/// else for a single-use resident.
pub(crate) fn insert(
    ns: &str,
    stem: &str,
    store: Arc<Store>,
    bytes: u64,
    budget: u64,
) -> u64 {
    let mut st = state();
    st.tick += 1;
    let tick = st.tick;
    let dir = st.dirs.entry(ns.to_string()).or_default();
    if budget > 0 && bytes > budget {
        // still drop any stale copy under this stem
        if let Some(old) = dir.entries.remove(stem) {
            dir.bytes -= old.bytes;
        }
        return 0;
    }
    if let Some(old) =
        dir.entries.insert(stem.to_string(), HotEntry { store, bytes, tick })
    {
        dir.bytes -= old.bytes;
    }
    dir.bytes += bytes;
    let mut evicted = 0u64;
    while budget > 0 && dir.bytes > budget {
        let Some(victim) = dir
            .entries
            .iter()
            .filter(|(k, _)| k.as_str() != stem)
            .min_by_key(|(_, e)| e.tick)
            .map(|(k, _)| k.clone())
        else {
            break;
        };
        if let Some(e) = dir.entries.remove(&victim) {
            dir.bytes -= e.bytes;
            evicted += 1;
        }
    }
    evicted
}

/// Drop one entry (GC eviction, corrupt-artifact invalidation).
pub(crate) fn remove(ns: &str, stem: &str) {
    let mut st = state();
    if let Some(dir) = st.dirs.get_mut(ns) {
        if let Some(e) = dir.entries.remove(stem) {
            dir.bytes -= e.bytes;
        }
    }
}

/// Bytes currently resident for a namespace.
pub(crate) fn dir_bytes(ns: &str) -> u64 {
    state().dirs.get(ns).map_or(0, |d| d.bytes)
}

/// Drop every hot entry of one namespace (tests, benches, `cache gc`).
pub(crate) fn clear(ns: &str) {
    state().dirs.remove(ns);
}

// ---- tier-1 deserialization counter --------------------------------

fn deser() -> MutexGuard<'static, HashMap<(String, String), u64>> {
    static DESER: OnceLock<Mutex<HashMap<(String, String), u64>>> =
        OnceLock::new();
    DESER
        .get_or_init(|| Mutex::new(HashMap::new()))
        .lock()
        .unwrap_or_else(|p| p.into_inner())
}

/// Record one GTS1 parse of `stem` from a disk tier of namespace `ns`.
pub(crate) fn note_deser(ns: &str, stem: &str) {
    *deser()
        .entry((ns.to_string(), stem.to_string()))
        .or_insert(0) += 1;
}

/// How many times `stem` has been parsed from disk for this namespace
/// over the process lifetime (the tier-0 acceptance observable).
pub(crate) fn deser_count(ns: &str, stem: &str) -> u64 {
    deser()
        .get(&(ns.to_string(), stem.to_string()))
        .copied()
        .unwrap_or(0)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::Tensor;

    fn mk(v: f32) -> Arc<Store> {
        let mut s = Store::new();
        s.insert("x", Tensor::scalar_f32(v));
        Arc::new(s)
    }

    #[test]
    fn hit_shares_the_arc_and_namespaces_isolate() {
        let ns = "hot_test_ns_a";
        clear(ns);
        let a = mk(1.0);
        insert(ns, "k1", a.clone(), 10, 0);
        let got = get(ns, "k1").unwrap();
        assert!(Arc::ptr_eq(&a, &got), "tier 0 serves shared handles");
        assert!(get("hot_test_ns_other", "k1").is_none());
        assert_eq!(dir_bytes(ns), 10);
        clear(ns);
        assert!(get(ns, "k1").is_none());
    }

    #[test]
    fn lru_evicts_oldest_within_budget() {
        let ns = "hot_test_ns_lru";
        clear(ns);
        insert(ns, "a", mk(1.0), 40, 100);
        insert(ns, "b", mk(2.0), 40, 100);
        // touch a so b is the LRU entry
        assert!(get(ns, "a").is_some());
        let evicted = insert(ns, "c", mk(3.0), 40, 100);
        assert_eq!(evicted, 1);
        assert!(get(ns, "b").is_none(), "LRU entry evicted");
        assert!(get(ns, "a").is_some());
        assert!(get(ns, "c").is_some());
        assert_eq!(dir_bytes(ns), 80);
        clear(ns);
    }

    #[test]
    fn oversized_entry_is_not_cached_and_replace_reaccounts() {
        let ns = "hot_test_ns_big";
        clear(ns);
        insert(ns, "a", mk(1.0), 10, 100);
        assert_eq!(insert(ns, "huge", mk(9.0), 1000, 100), 0);
        assert!(get(ns, "huge").is_none(), "never evict the world for one");
        assert!(get(ns, "a").is_some(), "small resident survives");
        // replacing a stem swaps the accounting, not accumulates
        insert(ns, "a", mk(2.0), 30, 100);
        assert_eq!(dir_bytes(ns), 30);
        assert_eq!(get(ns, "a").unwrap().get("x").unwrap().scalar(), 2.0);
        clear(ns);
    }

    #[test]
    fn deser_counter_tracks_per_dir_stem() {
        let ns = "hot_test_ns_deser";
        assert_eq!(deser_count(ns, "s"), 0);
        note_deser(ns, "s");
        note_deser(ns, "s");
        note_deser(ns, "t");
        assert_eq!(deser_count(ns, "s"), 2);
        assert_eq!(deser_count(ns, "t"), 1);
        assert_eq!(deser_count("elsewhere", "s"), 0);
    }
}

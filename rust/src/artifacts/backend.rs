//! Pluggable artifact storage backends (DESIGN.md §16): the byte-level
//! contract the tiered [`super::ArtifactCache`] reads and writes
//! through. A backend is a flat namespace of files (`<kind>_<key>.gts`
//! artifacts and their `.fnv` sidecars) with atomic publication — a
//! `write` lands via temp-file + rename, so a concurrent reader sees
//! either the complete previous bytes or the complete new bytes, never a
//! torn file.
//!
//! Two implementations ship:
//!
//!   * [`LocalDir`] — tier 1, the existing on-disk cache layout.
//!   * [`SharedDir`] — tier 2, the same layout on a directory many
//!     machines mount (NFS, a bind mount, a synced folder). It is
//!     deliberately dumb: no coordination beyond atomic rename, temp
//!     names salted with the writer's pid so concurrent writers from
//!     different hosts never collide, last-writer-wins on identical keys
//!     (harmless — equal keys mean equal bytes). Claim lockfiles and wip
//!     checkpoint dirs stay on the *local* tier: cross-machine runs may
//!     duplicate a computation, but every store is atomic and
//!     deterministic, so the pool converges.

use std::path::{Path, PathBuf};
use std::time::SystemTime;

use anyhow::{Context, Result};

/// One file a backend holds (used by GC and `cache stats`).
#[derive(Debug, Clone)]
pub struct Entry {
    /// File name within the backend root (e.g. `distill_ab12..ef.gts`).
    pub name: String,
    pub bytes: u64,
    pub mtime: SystemTime,
}

/// A flat, atomically-written artifact namespace. All methods are
/// `&self`: backends hold no mutable state, so one instance is shared
/// freely across the cache's load/store paths.
pub trait Backend: Send + Sync + std::fmt::Debug {
    /// Tier label for stats/metrics (`"disk"`, `"shared"`).
    fn tier(&self) -> &'static str;

    /// The backing directory.
    fn root(&self) -> &Path;

    /// Read a file's bytes; `None` for missing or unreadable.
    fn read(&self, name: &str) -> Option<Vec<u8>>;

    /// Atomically publish `bytes` under `name` (temp + rename), creating
    /// the root if needed. Returns the final path.
    fn write(&self, name: &str, bytes: &[u8]) -> Result<PathBuf>;

    /// Delete a file; `true` if it existed and was removed.
    fn remove(&self, name: &str) -> bool;

    /// Move a (corrupt) file into the backend's `quarantine/` subdir;
    /// `true` if it was moved.
    fn quarantine(&self, name: &str) -> bool;

    /// Every regular file directly under the root (subdirs — quarantine,
    /// wip work dirs — excluded).
    fn list(&self) -> Vec<Entry>;
}

fn read_file(root: &Path, name: &str) -> Option<Vec<u8>> {
    std::fs::read(root.join(name)).ok()
}

fn write_atomic(
    root: &Path,
    name: &str,
    bytes: &[u8],
    tmp_salt: &str,
) -> Result<PathBuf> {
    std::fs::create_dir_all(root)
        .with_context(|| format!("create backend dir {root:?}"))?;
    let path = root.join(name);
    let tmp = root.join(format!("{name}.tmp.{tmp_salt}"));
    std::fs::write(&tmp, bytes)
        .with_context(|| format!("write {tmp:?}"))?;
    std::fs::rename(&tmp, &path).with_context(|| {
        let _ = std::fs::remove_file(&tmp);
        format!("publish {path:?}")
    })?;
    Ok(path)
}

fn remove_file(root: &Path, name: &str) -> bool {
    std::fs::remove_file(root.join(name)).is_ok()
}

fn quarantine_file(root: &Path, name: &str) -> bool {
    let from = root.join(name);
    if !from.exists() {
        return false;
    }
    let qdir = root.join("quarantine");
    if std::fs::create_dir_all(&qdir).is_err() {
        return false;
    }
    std::fs::rename(&from, qdir.join(name)).is_ok()
}

fn list_files(root: &Path) -> Vec<Entry> {
    let Ok(rd) = std::fs::read_dir(root) else {
        return Vec::new();
    };
    let mut out = Vec::new();
    for e in rd.flatten() {
        let Ok(meta) = e.metadata() else { continue };
        if !meta.is_file() {
            continue;
        }
        let name = e.file_name().to_string_lossy().into_owned();
        out.push(Entry {
            name,
            bytes: meta.len(),
            mtime: meta.modified().unwrap_or(SystemTime::UNIX_EPOCH),
        });
    }
    // deterministic order for callers that iterate (read_dir order is
    // filesystem-dependent)
    out.sort_by(|a, b| a.name.cmp(&b.name));
    out
}

/// Tier 1: the process-local on-disk cache directory.
#[derive(Debug, Clone)]
pub struct LocalDir {
    root: PathBuf,
}

impl LocalDir {
    pub fn new(root: impl AsRef<Path>) -> Self {
        LocalDir { root: root.as_ref().to_path_buf() }
    }
}

impl Backend for LocalDir {
    fn tier(&self) -> &'static str {
        "disk"
    }

    fn root(&self) -> &Path {
        &self.root
    }

    fn read(&self, name: &str) -> Option<Vec<u8>> {
        read_file(&self.root, name)
    }

    fn write(&self, name: &str, bytes: &[u8]) -> Result<PathBuf> {
        // pid-salted temp: concurrent processes sharing one local cache
        // dir (the claim/waiter protocol allows it) never tear each
        // other's in-flight writes
        write_atomic(&self.root, name, bytes, &std::process::id().to_string())
    }

    fn remove(&self, name: &str) -> bool {
        remove_file(&self.root, name)
    }

    fn quarantine(&self, name: &str) -> bool {
        quarantine_file(&self.root, name)
    }

    fn list(&self) -> Vec<Entry> {
        list_files(&self.root)
    }
}

/// Tier 2: a dumb shared directory (same key scheme, atomic renames) so
/// many machines pool one artifact store. See the module docs for the
/// (non-)coordination contract.
#[derive(Debug, Clone)]
pub struct SharedDir {
    root: PathBuf,
}

impl SharedDir {
    pub fn new(root: impl AsRef<Path>) -> Result<Self> {
        let root = root.as_ref().to_path_buf();
        anyhow::ensure!(
            !root.as_os_str().is_empty(),
            "shared-dir backend requires a directory \
             (cache.shared_dir=<path> or --cache-shared-dir)"
        );
        std::fs::create_dir_all(&root)
            .with_context(|| format!("create shared cache dir {root:?}"))?;
        Ok(SharedDir { root })
    }
}

impl Backend for SharedDir {
    fn tier(&self) -> &'static str {
        "shared"
    }

    fn root(&self) -> &Path {
        &self.root
    }

    fn read(&self, name: &str) -> Option<Vec<u8>> {
        read_file(&self.root, name)
    }

    fn write(&self, name: &str, bytes: &[u8]) -> Result<PathBuf> {
        write_atomic(&self.root, name, bytes, &std::process::id().to_string())
    }

    fn remove(&self, name: &str) -> bool {
        remove_file(&self.root, name)
    }

    fn quarantine(&self, name: &str) -> bool {
        quarantine_file(&self.root, name)
    }

    fn list(&self) -> Vec<Entry> {
        list_files(&self.root)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn local_dir_atomic_write_read_remove() {
        let dir = std::env::temp_dir().join("genie_backend_local_test");
        std::fs::remove_dir_all(&dir).ok();
        let be = LocalDir::new(&dir);
        assert!(be.read("a.gts").is_none());
        let p = be.write("a.gts", b"hello").unwrap();
        assert_eq!(p, dir.join("a.gts"));
        assert_eq!(be.read("a.gts").unwrap(), b"hello");
        // overwrite is atomic-replace, not append
        be.write("a.gts", b"bye").unwrap();
        assert_eq!(be.read("a.gts").unwrap(), b"bye");
        // no temp droppings survive a completed write
        let names: Vec<_> =
            be.list().into_iter().map(|e| e.name).collect();
        assert_eq!(names, vec!["a.gts".to_string()]);
        assert!(be.remove("a.gts"));
        assert!(!be.remove("a.gts"));
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn quarantine_moves_into_subdir_and_list_skips_subdirs() {
        let dir = std::env::temp_dir().join("genie_backend_quar_test");
        std::fs::remove_dir_all(&dir).ok();
        let be = SharedDir::new(&dir).unwrap();
        assert_eq!(be.tier(), "shared");
        be.write("bad.gts", b"xxxx").unwrap();
        assert!(be.quarantine("bad.gts"));
        assert!(!be.quarantine("bad.gts"), "already moved");
        assert!(be.read("bad.gts").is_none());
        assert_eq!(
            std::fs::read(dir.join("quarantine/bad.gts")).unwrap(),
            b"xxxx"
        );
        // the quarantine subdir never shows up in the flat listing
        assert!(be.list().is_empty());
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn shared_dir_requires_a_path() {
        assert!(SharedDir::new("").is_err());
    }

    #[test]
    fn list_reports_sizes_sorted() {
        let dir = std::env::temp_dir().join("genie_backend_list_test");
        std::fs::remove_dir_all(&dir).ok();
        let be = LocalDir::new(&dir);
        be.write("b.gts", b"123456").unwrap();
        be.write("a.gts", b"12").unwrap();
        let l = be.list();
        assert_eq!(l.len(), 2);
        assert_eq!(l[0].name, "a.gts");
        assert_eq!(l[0].bytes, 2);
        assert_eq!(l[1].name, "b.gts");
        assert_eq!(l[1].bytes, 6);
        std::fs::remove_dir_all(&dir).ok();
    }
}

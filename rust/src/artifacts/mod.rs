//! Content-addressed artifact cache (DESIGN.md §9): pipeline stages —
//! teacher pretraining, GENIE-D synthesis, GENIE-M qstate — persist
//! their products as GTS1 files keyed by a stable hash of everything
//! that determines them: the phase config fields, the manifest identity,
//! and the content hashes of upstream artifacts. `pipeline::zsq`/`fsq`
//! then become DAG lookups — a completed stage loads in milliseconds
//! instead of re-running — and an in-progress stage's per-shard
//! checkpoints live in a `wip_*` work dir that the cache clears once the
//! stage's artifact lands.
//!
//! Keys deliberately exclude `workers` (parallel phases are bit-identical
//! for any worker count, DESIGN.md §5) and `steps_per_dispatch` (fused
//! dispatch is identity-neutral the same way, DESIGN.md §14), and include
//! `seed` (a different seed is a different artifact). Hashing is FNV-1a 64 over a canonical
//! `name=value;` rendering plus raw tensor bytes — never std's SipHash,
//! whose keys are process-random.
//!
//! Two key families share the same config field folds (DESIGN.md §11):
//!
//!   * **content keys** (`distill_key`, `quantize_key`, ...) fold
//!     upstream *content hashes* — only computable once the upstream
//!     artifact exists; they address cache files.
//!   * **spec keys** (`distill_spec_key`, `quantize_spec_key`, ...) fold
//!     upstream *spec keys* instead — computable before anything runs.
//!     The grid orchestrator dedupes its cross-run stage DAG on spec
//!     keys (equal spec ⇒ equal content within one process, where the
//!     manifests and dataset are fixed); they never address files.
//!
//! Concurrent materialization is serialized per key by
//! [`ArtifactCache::claim`]: the first claimant creates
//! `wip_<kind>_<key>.lock` and computes; later claimants block until the
//! lock releases, then re-check the cache and hit.
//!
//! Integrity (DESIGN.md §13): every store writes a `<file>.fnv` sidecar
//! carrying the FNV-1a 64 hash of the artifact bytes; every load
//! re-hashes the raw file and verifies it (plus the GTS1 parse). A
//! corrupt or torn artifact is moved into the `quarantine/` sidecar dir,
//! counted as a miss *and* as [`CacheStats::quarantined`], and the stage
//! recomputes — a crash-looping service never wedges on a bad file.

use std::path::{Path, PathBuf};

use anyhow::{Context, Result};

use crate::coordinator::{DistillCfg, PretrainCfg, QuantCfg};
use crate::phase::checkpoint::atomic_save;
use crate::phase::StageCkpt;
use crate::precision::PrecisionPlan;
use crate::runtime::Manifest;
use crate::store::{fnv1a, Store, FNV_OFFSET};
use crate::tensor::{Data, Tensor};

/// A 64-bit content-addressed cache key.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct CacheKey(pub u64);

impl CacheKey {
    pub fn hex(&self) -> String {
        format!("{:016x}", self.0)
    }
}

/// Builds a [`CacheKey`] from named fields. Every field moves the key;
/// field order is part of the recipe (documented in DESIGN.md §9).
#[derive(Debug, Clone)]
pub struct KeyBuilder {
    h: u64,
}

impl KeyBuilder {
    pub fn new(kind: &str) -> Self {
        KeyBuilder { h: FNV_OFFSET }.field("kind", kind)
    }

    pub fn field(mut self, name: &str, value: impl std::fmt::Display) -> Self {
        self.h = fnv1a(self.h, name.as_bytes());
        self.h = fnv1a(self.h, b"=");
        self.h = fnv1a(self.h, value.to_string().as_bytes());
        self.h = fnv1a(self.h, b";");
        self
    }

    /// Fold an upstream artifact's key in (a DAG edge).
    pub fn upstream(self, name: &str, key: CacheKey) -> Self {
        self.field(name, key.hex())
    }

    /// Fold a store's content address in (teacher checkpoints).
    pub fn store(self, name: &str, s: &Store) -> Self {
        self.field(name, format!("{:016x}", s.content_hash()))
    }

    /// Fold one tensor's dtype/shape/bytes in (calibration sets).
    pub fn tensor(mut self, name: &str, t: &Tensor) -> Self {
        self.h = fnv1a(self.h, name.as_bytes());
        self.h = fnv1a(self.h, b"=");
        self.h = fnv1a(
            self.h,
            format!("{:?}{:?}", t.dtype(), t.shape).as_bytes(),
        );
        match &t.data {
            Data::F32(v) => {
                for x in v {
                    self.h = fnv1a(self.h, &x.to_le_bytes());
                }
            }
            Data::I32(v) => {
                for x in v {
                    self.h = fnv1a(self.h, &x.to_le_bytes());
                }
            }
            Data::U32(v) => {
                for x in v {
                    self.h = fnv1a(self.h, &x.to_le_bytes());
                }
            }
        }
        self.h = fnv1a(self.h, b";");
        self
    }

    pub fn finish(self) -> CacheKey {
        CacheKey(self.h)
    }
}

/// Manifest identity folded into every stage key: the model name plus
/// the structural facts its graphs were lowered with.
fn manifest_fields(b: KeyBuilder, m: &Manifest) -> KeyBuilder {
    b.field("model", &m.model)
        .field("image", format!("{:?}", m.image))
        .field("classes", m.num_classes)
        .field("blocks", m.num_blocks)
        .field("latent", m.latent)
}

/// Key of the pretrained-teacher artifact. Every field is config, so
/// this doubles as the teacher's *spec* key: the grid orchestrator
/// dedupes pretrain stages on it directly.
pub fn pretrain_key(m: &Manifest, cfg: &PretrainCfg) -> CacheKey {
    manifest_fields(KeyBuilder::new("teacher"), m)
        .field("steps", cfg.steps)
        .field("lr", cfg.lr)
        .field("log_every", cfg.log_every)
        .field("seed", cfg.seed)
        .finish()
}

/// The distill-config folds shared by the content and spec keys. `par`
/// and `steps_per_dispatch` are excluded — shard fan-out and dispatch
/// fusion never change the images.
fn distill_fields(b: KeyBuilder, cfg: &DistillCfg) -> KeyBuilder {
    b.field("engine", cfg.engine.as_str())
        .field("mode", cfg.mode.as_str())
        .field("swing", cfg.swing)
        .field("samples", cfg.samples)
        .field("steps", cfg.steps)
        .field("lr_g", cfg.lr_g)
        .field("lr_z", cfg.lr_z)
        .field("log_every", cfg.log_every)
        .field("seed", cfg.seed)
}

/// Key of the synthetic-calibration artifact: the distill config plus
/// the teacher it was distilled from (by content hash, so a retrained
/// teacher invalidates downstream artifacts automatically — the caller
/// computes `Store::content_hash` once and shares it across the stage
/// keys of one run).
pub fn distill_key(
    m: &Manifest,
    cfg: &DistillCfg,
    teacher_hash: u64,
) -> CacheKey {
    distill_fields(manifest_fields(KeyBuilder::new("distill"), m), cfg)
        .field("teacher", format!("{teacher_hash:016x}"))
        .finish()
}

/// Spec key of a distill stage: same config folds, but the upstream
/// teacher enters by *spec* key — computable before the teacher exists.
pub fn distill_spec_key(
    m: &Manifest,
    cfg: &DistillCfg,
    teacher_spec: CacheKey,
) -> CacheKey {
    distill_fields(manifest_fields(KeyBuilder::new("distill"), m), cfg)
        .upstream("teacher_spec", teacher_spec)
        .finish()
}

/// Spec key of a real-data calibration draw (`fsq`): the sample count
/// and the RNG stream that selects them. Valid for dedupe only within
/// one process, where the dataset is fixed.
pub fn real_calib_spec_key(samples: usize, seed: u64) -> CacheKey {
    KeyBuilder::new("realcalib")
        .field("samples", samples)
        .field("seed", seed)
        .finish()
}

/// Key of the resolved-precision-plan artifact (Pareto runs): every
/// plan-shaping config knob plus the teacher and calibration content
/// the sensitivity pass reads. Uniform plans are derived, not cached.
pub fn plan_key(
    m: &Manifest,
    cfg: &QuantCfg,
    teacher_hash: u64,
    calib: &Tensor,
) -> CacheKey {
    // `cfg.wbits` is deliberately absent: a Pareto plan's weight bits
    // come from `candidates`, so the uniform base width cannot change
    // the resolved plan and must not invalidate it
    let p = &cfg.precision;
    manifest_fields(KeyBuilder::new("plan"), m)
        .field("policy", p.policy.as_str())
        .field("abits", cfg.abits)
        .field("first_last", p.first_last_bits)
        .field("target_size", p.target_size)
        .field("granularity", p.granularity.as_str())
        .field("sens_batches", p.sens_batches)
        .field("candidates", format!("{:?}", p.candidates))
        .field("pnorm", cfg.pnorm)
        .field("teacher", format!("{teacher_hash:016x}"))
        .tensor("calib", calib)
        .finish()
}

/// The quantizer-config folds shared by the content and spec keys
/// (everything but the plan/precision identity and the upstreams).
/// `par` and `steps_per_dispatch` are excluded — execution shape never
/// changes the optimized qstate.
fn quantize_fields(b: KeyBuilder, cfg: &QuantCfg) -> KeyBuilder {
    b.field("steps", cfg.steps_per_block)
        .field("lr_sw", cfg.lr_sw)
        .field("lr_v", cfg.lr_v)
        .field("lr_sa", cfg.lr_sa)
        .field("lam", cfg.lam)
        .field("beta_start", cfg.beta_start)
        .field("beta_end", cfg.beta_end)
        .field("drop_p", cfg.drop_p)
        .field("pnorm", cfg.pnorm)
        .field("refresh", cfg.refresh_student)
        .field("log_every", cfg.log_every)
        .field("seed", cfg.seed)
}

/// Key of the optimized-qstate artifact: the quant config plus the
/// resolved precision plan (per-layer bits/granularity — a different
/// plan is a different artifact), the teacher (by precomputed content
/// hash) and the calibration images (synthetic or real) by content.
pub fn quantize_key(
    m: &Manifest,
    cfg: &QuantCfg,
    teacher_hash: u64,
    calib: &Tensor,
    plan: &PrecisionPlan,
) -> CacheKey {
    quantize_fields(
        manifest_fields(KeyBuilder::new("qstate"), m)
            .field("plan", plan.fingerprint()),
        cfg,
    )
    .field("teacher", format!("{teacher_hash:016x}"))
    .tensor("calib", calib)
    .finish()
}

/// Spec key of a quantize stage: the plan is not resolved yet, so the
/// plan-shaping config (base bits + every precision knob) stands in for
/// the fingerprint, and both upstreams — teacher and calibration source
/// (a distill spec or a [`real_calib_spec_key`]) — enter by spec key.
pub fn quantize_spec_key(
    m: &Manifest,
    cfg: &QuantCfg,
    teacher_spec: CacheKey,
    calib_spec: CacheKey,
) -> CacheKey {
    let p = &cfg.precision;
    quantize_fields(
        manifest_fields(KeyBuilder::new("qstate"), m)
            .field("wbits", cfg.wbits)
            .field("abits", cfg.abits)
            .field("policy", p.policy.as_str())
            .field("first_last", p.first_last_bits)
            .field("target_size", p.target_size)
            .field("granularity", p.granularity.as_str())
            .field("sens_batches", p.sens_batches)
            .field("candidates", format!("{:?}", p.candidates)),
        cfg,
    )
    .upstream("teacher_spec", teacher_spec)
    .upstream("calib_spec", calib_spec)
    .finish()
}

/// Spec key of an FP32-teacher eval (dedupes across every cell that
/// shares the teacher).
pub fn eval_fp_spec_key(m: &Manifest, teacher_spec: CacheKey) -> CacheKey {
    manifest_fields(KeyBuilder::new("evalfp"), m)
        .upstream("teacher_spec", teacher_spec)
        .finish()
}

/// Spec key of a quantized eval (one per distinct qstate spec).
pub fn eval_q_spec_key(m: &Manifest, quantize_spec: CacheKey) -> CacheKey {
    manifest_fields(KeyBuilder::new("evalq"), m)
        .upstream("qstate_spec", quantize_spec)
        .finish()
}

/// Cache traffic counters, mirrored into `Metrics` by the pipeline.
#[derive(Debug, Default, Clone)]
pub struct CacheStats {
    pub hits: u64,
    pub misses: u64,
    pub stores: u64,
    /// Corrupt/torn artifacts detected on load and moved to the
    /// `quarantine/` sidecar dir (each is also counted as a miss — the
    /// stage recomputes and rewrites).
    pub quarantined: u64,
}

/// A held materialization claim on one artifact key (DESIGN.md §11):
/// while alive, `wip_<kind>_<key>.lock` exists and every concurrent
/// [`ArtifactCache::claim`] on the same key blocks. Dropping removes the
/// lockfile — but only after verifying the file still carries this
/// claim's token, so a claim whose lock was broken as stale (and
/// re-acquired by a successor) never deletes the successor's live lock.
/// A claim from a disabled cache holds nothing.
#[derive(Debug)]
pub struct WipClaim {
    path: Option<PathBuf>,
    token: String,
}

impl Drop for WipClaim {
    fn drop(&mut self) {
        if let Some(p) = self.path.take() {
            // ownership check: remove only our own lock (a stolen lock
            // belongs to whoever broke it)
            if std::fs::read_to_string(&p)
                .is_ok_and(|t| t == self.token)
            {
                std::fs::remove_file(p).ok();
            }
        }
    }
}

/// The on-disk cache: completed artifacts as `<kind>_<key>.gts`, stage
/// work dirs as `wip_<kind>_<key>/`, materialization locks as
/// `wip_<kind>_<key>.lock`.
#[derive(Debug)]
pub struct ArtifactCache {
    dir: PathBuf,
    enabled: bool,
    resume: bool,
    checkpoint_every: usize,
    /// Lockfiles older than this are treated as left by a crashed
    /// claimant and broken (claims touch their lock only at creation, so
    /// age = mtime age).
    claim_stale_secs: u64,
    stats: CacheStats,
}

impl ArtifactCache {
    /// Open (creating) a cache dir. `enabled = false` turns every lookup
    /// into a miss and every store into a no-op (`--no-cache`); `resume`
    /// lets interrupted stages continue from their wip checkpoints
    /// (`--resume`).
    pub fn open(
        dir: impl AsRef<Path>,
        enabled: bool,
        resume: bool,
    ) -> Result<Self> {
        if enabled {
            std::fs::create_dir_all(dir.as_ref())
                .with_context(|| format!("create cache dir {:?}", dir.as_ref()))?;
        }
        Ok(ArtifactCache {
            dir: dir.as_ref().to_path_buf(),
            enabled,
            resume,
            checkpoint_every: 50,
            claim_stale_secs: 1800,
            stats: CacheStats::default(),
        })
    }

    /// A cache that never hits nor persists — for call sites that opt
    /// out of caching entirely.
    pub fn disabled() -> Self {
        ArtifactCache {
            dir: PathBuf::from("cache"),
            enabled: false,
            resume: false,
            checkpoint_every: 0,
            claim_stale_secs: 1800,
            stats: CacheStats::default(),
        }
    }

    pub fn is_enabled(&self) -> bool {
        self.enabled
    }

    pub fn stats(&self) -> &CacheStats {
        &self.stats
    }

    /// Steps between mid-phase checkpoint writes (0 = shard-boundary
    /// durability only).
    pub fn set_checkpoint_every(&mut self, every: usize) {
        self.checkpoint_every = every;
    }

    pub fn path(&self, kind: &str, key: CacheKey) -> PathBuf {
        self.dir.join(format!("{kind}_{}.gts", key.hex()))
    }

    /// The content-hash sidecar next to an artifact file
    /// (`<file>.gts.fnv`, 16 hex chars of FNV-1a 64 over the file bytes).
    pub fn sidecar_path(&self, kind: &str, key: CacheKey) -> PathBuf {
        self.dir.join(format!("{kind}_{}.gts.fnv", key.hex()))
    }

    /// Where corrupt/torn artifacts are moved on detection.
    pub fn quarantine_dir(&self) -> PathBuf {
        self.dir.join("quarantine")
    }

    /// Move a bad artifact (and its sidecar) into `quarantine/`,
    /// counting it. The caller then reports a miss and recomputes; the
    /// re-store overwrites cleanly.
    fn quarantine(&mut self, kind: &str, key: CacheKey, why: &str) {
        let qdir = self.quarantine_dir();
        std::fs::create_dir_all(&qdir).ok();
        for p in [self.path(kind, key), self.sidecar_path(kind, key)] {
            if let Some(name) = p.file_name() {
                if p.exists() {
                    std::fs::rename(&p, qdir.join(name)).ok();
                }
            }
        }
        self.stats.quarantined += 1;
        crate::progress!(
            "cache: quarantined {kind}_{} ({why}); stage will recompute",
            key.hex()
        );
    }

    /// Read + verify one artifact: offer it to the fault injector, hash
    /// the raw bytes against the sidecar (a missing sidecar skips the
    /// hash check — pre-§13 caches), then parse. Hash mismatches and
    /// parse failures quarantine the file; a missing file is `None`
    /// without quarantine (the ordinary cold miss).
    fn load_verified(&mut self, kind: &str, key: CacheKey) -> Option<Store> {
        let path = self.path(kind, key);
        crate::faults::corrupt_hook(
            &format!("{kind}_{}", key.hex()),
            &path,
        );
        let bytes = std::fs::read(&path).ok()?;
        if let Ok(want) = std::fs::read_to_string(self.sidecar_path(kind, key))
        {
            let got = format!("{:016x}", fnv1a(FNV_OFFSET, &bytes));
            if want.trim() != got {
                self.quarantine(kind, key, "content hash mismatch");
                return None;
            }
        }
        match Store::from_bytes(&bytes) {
            Ok(s) => Some(s),
            Err(_) => {
                self.quarantine(kind, key, "unparseable GTS1 bytes");
                None
            }
        }
    }

    /// Look a completed artifact up, counting the hit/miss. A missing
    /// file is a miss; a corrupt/torn file is quarantined *and* counted
    /// as a miss (the stage re-runs and rewrites it).
    pub fn load(&mut self, kind: &str, key: CacheKey) -> Option<Store> {
        if !self.enabled {
            self.stats.misses += 1;
            return None;
        }
        match self.load_verified(kind, key) {
            Some(s) => {
                self.stats.hits += 1;
                Some(s)
            }
            None => {
                self.stats.misses += 1;
                None
            }
        }
    }

    /// [`Self::load`] gated on a coherence check: an artifact that
    /// parses but fails `check` — missing tensors, e.g. a partial copy
    /// from another cache — is demoted to a miss (no quarantine: the
    /// bytes are intact, just incomplete), so the stage recomputes and
    /// rewrites it instead of erroring on the decode (and the grid dry
    /// run predicts the same disposition).
    pub fn load_checked(
        &mut self,
        kind: &str,
        key: CacheKey,
        check: impl Fn(&Store) -> bool,
    ) -> Option<Store> {
        if !self.enabled {
            self.stats.misses += 1;
            return None;
        }
        match self.load_verified(kind, key) {
            Some(s) if check(&s) => {
                self.stats.hits += 1;
                Some(s)
            }
            _ => {
                self.stats.misses += 1;
                None
            }
        }
    }

    /// Store a completed artifact (atomic write + content-hash sidecar)
    /// and clear the stage's work dir. No-op when disabled. The sidecar
    /// lands after the artifact, so a crash between the two leaves a
    /// state the next load either verifies (no sidecar yet: parse-only)
    /// or quarantines — never serves silently corrupted.
    pub fn store(
        &mut self,
        kind: &str,
        key: CacheKey,
        s: &Store,
    ) -> Result<Option<PathBuf>> {
        if !self.enabled {
            return Ok(None);
        }
        let p = self.path(kind, key);
        atomic_save(s, &p)?;
        // Store::write_to is the file serializer, so the content hash
        // *is* the FNV-1a of the on-disk bytes — no re-read needed
        std::fs::write(
            self.sidecar_path(kind, key),
            format!("{:016x}", s.content_hash()),
        )
        .with_context(|| format!("write hash sidecar for {p:?}"))?;
        self.stats.stores += 1;
        self.clear_wip(kind, key);
        Ok(Some(p))
    }

    /// The in-progress work dir for one stage.
    pub fn wip_dir(&self, kind: &str, key: CacheKey) -> PathBuf {
        self.dir.join(format!("wip_{kind}_{}", key.hex()))
    }

    /// The materialization lockfile for one stage key.
    pub fn lock_path(&self, kind: &str, key: CacheKey) -> PathBuf {
        self.dir.join(format!("wip_{kind}_{}.lock", key.hex()))
    }

    /// Seconds after which a lockfile counts as abandoned (test hook;
    /// default 1800). The tradeoff: a stage that legitimately computes
    /// longer than this risks having its lock broken (the worst case is
    /// duplicated — still deterministic and atomically stored — work),
    /// while a crashed claimant blocks concurrent runs for at most this
    /// long.
    pub fn set_claim_stale_secs(&mut self, secs: u64) {
        self.claim_stale_secs = secs;
    }

    /// Claim the right to materialize `<kind>_<key>` (DESIGN.md §11).
    /// Creates the per-key lockfile atomically (`create_new`, stamped
    /// with an ownership token); if another claimant — in this process
    /// or another — holds it, blocks polling until the lock releases (or
    /// goes stale and is broken — via atomic rename, so exactly one
    /// waiter takes a stale lock over). Callers check
    /// [`load`](ArtifactCache::load) after claiming: the released
    /// claimant usually stored the artifact, turning this claimant's
    /// compute into a cache hit. Disabled caches return an empty claim
    /// immediately.
    pub fn claim(&self, kind: &str, key: CacheKey) -> Result<WipClaim> {
        use std::io::Write;
        if !self.enabled {
            return Ok(WipClaim { path: None, token: String::new() });
        }
        static SEQ: std::sync::atomic::AtomicU64 =
            std::sync::atomic::AtomicU64::new(0);
        let token = format!(
            "{}:{}",
            std::process::id(),
            SEQ.fetch_add(1, std::sync::atomic::Ordering::Relaxed)
        );
        let path = self.lock_path(kind, key);
        loop {
            match std::fs::OpenOptions::new()
                .write(true)
                .create_new(true)
                .open(&path)
            {
                Ok(mut f) => {
                    f.write_all(token.as_bytes()).ok();
                    return Ok(WipClaim { path: Some(path), token });
                }
                Err(e)
                    if e.kind() == std::io::ErrorKind::AlreadyExists =>
                {
                    // a crashed claimant never unlocks; break stale
                    // locks by renaming them away — rename is atomic,
                    // so exactly one waiter wins the takeover and a
                    // freshly re-created lock is never deleted by a
                    // racing waiter that read the old mtime
                    let stale = std::fs::metadata(&path)
                        .ok()
                        .and_then(|m| m.modified().ok())
                        .and_then(|t| t.elapsed().ok())
                        .is_some_and(|age| {
                            age.as_secs() >= self.claim_stale_secs
                        });
                    if stale {
                        let grave = self.dir.join(format!(
                            "wip_{kind}_{}.stale.{token}",
                            key.hex()
                        ));
                        if std::fs::rename(&path, &grave).is_ok() {
                            std::fs::remove_file(&grave).ok();
                        }
                        continue;
                    }
                    std::thread::sleep(
                        std::time::Duration::from_millis(25),
                    );
                }
                Err(e) => {
                    return Err(e).with_context(|| {
                        format!("claim lockfile {path:?}")
                    })
                }
            }
        }
    }

    /// Per-shard checkpoint policy for one stage; `None` when disabled.
    pub fn stage_ckpt(&self, kind: &str, key: CacheKey) -> Option<StageCkpt> {
        if !self.enabled {
            return None;
        }
        Some(StageCkpt::new(
            self.wip_dir(kind, key),
            self.checkpoint_every,
            self.resume,
        ))
    }

    pub fn clear_wip(&self, kind: &str, key: CacheKey) {
        std::fs::remove_dir_all(self.wip_dir(kind, key)).ok();
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn toy_manifest() -> Manifest {
        Manifest::from_json_text(
            r#"{
                "model": "toy", "image": [16, 16, 3], "num_classes": 10,
                "num_blocks": 2, "latent": 256,
                "batch": {"train": 64},
                "params": [], "bn": [], "qstate": [], "gen_params": [],
                "quant_layers": [], "learnable": {"0": []},
                "bounds": [], "entrypoints": {}
            }"#,
        )
        .unwrap()
    }

    #[test]
    fn keys_stable_and_config_sensitive() {
        let m = toy_manifest();
        let mut teacher = Store::new();
        teacher.insert("w", Tensor::from_f32(&[2], vec![1.0, 2.0]));
        let th = teacher.content_hash();

        let d = DistillCfg::default();
        let k1 = distill_key(&m, &d, th);
        let k2 = distill_key(&m, &d, th);
        assert_eq!(k1, k2, "same inputs must key identically");

        // any config field moves the key; `par` does not
        let mut d2 = d.clone();
        d2.steps += 1;
        assert_ne!(distill_key(&m, &d2, th), k1);
        let mut d3 = d.clone();
        d3.par = crate::exec::Parallelism::new(7);
        assert_eq!(distill_key(&m, &d3, th), k1);
        // ... and neither does dispatch fusion (DESIGN.md §14)
        let mut d4 = d.clone();
        d4.steps_per_dispatch = 8;
        assert_eq!(distill_key(&m, &d4, th), k1);

        // upstream content moves the key
        let mut teacher2 = Store::new();
        teacher2.insert("w", Tensor::from_f32(&[2], vec![1.0, 2.5]));
        assert_ne!(distill_key(&m, &d, teacher2.content_hash()), k1);

        // the synthesis engine is a key field: switching engines misses,
        // switching back re-derives the exact original key (pure hit)
        let mut dz = d.clone();
        dz.engine = crate::synthesis::Engine::Zeroq;
        assert_ne!(distill_key(&m, &dz, th), k1);
        let mut dq = d.clone();
        dq.engine = crate::synthesis::Engine::Zaq;
        assert_ne!(distill_key(&m, &dq, th), k1);
        assert_ne!(distill_key(&m, &dz, th), distill_key(&m, &dq, th));
        dz.engine = crate::synthesis::Engine::Genie;
        assert_eq!(distill_key(&m, &dz, th), k1);

        // different stage kinds never collide on the same fields
        let p = PretrainCfg::default();
        assert_ne!(pretrain_key(&m, &p).0, k1.0);
    }

    #[test]
    fn quantize_key_tracks_calib_content_and_plan() {
        use crate::precision::{Granularity, LayerPlan, PrecisionPlan};
        let m = toy_manifest();
        let th = Store::new().content_hash();
        let q = QuantCfg::default();
        let a = Tensor::from_f32(&[2, 2], vec![1.0, 2.0, 3.0, 4.0]);
        let b = Tensor::from_f32(&[2, 2], vec![1.0, 2.0, 3.0, 5.0]);
        let plan = PrecisionPlan {
            layers: vec![LayerPlan {
                name: "stem".into(),
                wbits: 4,
                abits: 4,
                granularity: Granularity::PerChannel,
            }],
        };
        let ka = quantize_key(&m, &q, th, &a, &plan);
        assert_eq!(ka, quantize_key(&m, &q, th, &a, &plan));
        assert_ne!(ka, quantize_key(&m, &q, th, &b, &plan));

        // only the plan changes -> the qstate artifact must miss
        let mut p2 = plan.clone();
        p2.layers[0].wbits = 2;
        assert_ne!(ka, quantize_key(&m, &q, th, &a, &p2));
        let mut p3 = plan.clone();
        p3.layers[0].granularity = Granularity::PerTensor;
        assert_ne!(ka, quantize_key(&m, &q, th, &a, &p3));

        // non-plan quant config fields still move the key
        let kq = {
            let mut q2 = q.clone();
            q2.steps_per_block += 1;
            quantize_key(&m, &q2, th, &a, &plan)
        };
        assert_ne!(ka, kq);
    }

    #[test]
    fn plan_key_tracks_policy_knobs() {
        use crate::precision::{Policy, PrecisionCfg};
        let m = toy_manifest();
        let th = Store::new().content_hash();
        let a = Tensor::from_f32(&[2, 2], vec![1.0, 2.0, 3.0, 4.0]);
        let q = QuantCfg {
            precision: PrecisionCfg {
                policy: Policy::Pareto,
                ..Default::default()
            },
            ..Default::default()
        };
        let k1 = plan_key(&m, &q, th, &a);
        assert_eq!(k1, plan_key(&m, &q, th, &a));
        // the uniform base width never shapes a Pareto plan, so it must
        // not invalidate the plan artifact
        let mut qw = q.clone();
        qw.wbits = 5;
        assert_eq!(k1, plan_key(&m, &qw, th, &a));
        let mut q2 = q.clone();
        q2.precision.target_size = 0.5;
        assert_ne!(k1, plan_key(&m, &q2, th, &a));
        let mut q3 = q.clone();
        q3.precision.candidates = vec![2, 8];
        assert_ne!(k1, plan_key(&m, &q3, th, &a));
        // a plan key never collides with a qstate key on the same fields
        assert_ne!(
            k1,
            quantize_key(&m, &q, th, &a, &crate::precision::PrecisionPlan::default())
        );
    }

    #[test]
    fn steps_per_dispatch_never_moves_any_key() {
        // the whole fused-dispatch contract at the cache layer: K is an
        // execution-shape knob like `workers`, so every content and spec
        // key is invariant in it — a run at K=8 hits artifacts a K=1 run
        // stored, and vice versa
        use crate::precision::{Granularity, LayerPlan, PrecisionPlan};
        let m = toy_manifest();
        let th = Store::new().content_hash();
        let calib = Tensor::from_f32(&[2, 2], vec![1.0, 2.0, 3.0, 4.0]);
        let plan = PrecisionPlan {
            layers: vec![LayerPlan {
                name: "stem".into(),
                wbits: 4,
                abits: 4,
                granularity: Granularity::PerChannel,
            }],
        };

        let p1 = PretrainCfg::default();
        let mut p8 = p1.clone();
        p8.steps_per_dispatch = 8;
        assert_eq!(pretrain_key(&m, &p1), pretrain_key(&m, &p8));

        let d1 = DistillCfg::default();
        let mut d8 = d1.clone();
        d8.steps_per_dispatch = 8;
        assert_eq!(distill_key(&m, &d1, th), distill_key(&m, &d8, th));
        let ts = pretrain_key(&m, &p1);
        assert_eq!(
            distill_spec_key(&m, &d1, ts),
            distill_spec_key(&m, &d8, ts)
        );

        let q1 = QuantCfg::default();
        let mut q8 = q1.clone();
        q8.steps_per_dispatch = 8;
        assert_eq!(
            quantize_key(&m, &q1, th, &calib, &plan),
            quantize_key(&m, &q8, th, &calib, &plan)
        );
        let ds = distill_spec_key(&m, &d1, ts);
        assert_eq!(
            quantize_spec_key(&m, &q1, ts, ds),
            quantize_spec_key(&m, &q8, ts, ds)
        );
        assert_eq!(plan_key(&m, &q1, th, &calib), plan_key(&m, &q8, th, &calib));
    }

    #[test]
    fn cache_store_load_counts_and_clears_wip() {
        let dir = std::env::temp_dir().join("genie_artifact_cache_test");
        std::fs::remove_dir_all(&dir).ok();
        let mut cache = ArtifactCache::open(&dir, true, false).unwrap();
        let key = KeyBuilder::new("test").field("x", 1).finish();

        assert!(cache.load("stage", key).is_none());
        assert_eq!(cache.stats().misses, 1);

        // a wip dir with a shard checkpoint, cleared by the store
        let stage = cache.stage_ckpt("stage", key).unwrap();
        let mut shard = Store::new();
        shard.insert("part", Tensor::scalar_f32(1.0));
        stage.write_done("shard0", &shard).unwrap();
        assert!(cache.wip_dir("stage", key).exists());

        let mut art = Store::new();
        art.insert("images", Tensor::zeros(&[2, 3]));
        let p = cache.store("stage", key, &art).unwrap().unwrap();
        assert!(p.exists());
        assert!(!cache.wip_dir("stage", key).exists(), "wip must clear");

        let back = cache.load("stage", key).unwrap();
        assert_eq!(back.get("images").unwrap().shape, vec![2, 3]);
        assert_eq!(cache.stats().hits, 1);
        assert_eq!(cache.stats().stores, 1);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn disabled_cache_is_inert() {
        let mut cache = ArtifactCache::disabled();
        let key = KeyBuilder::new("test").finish();
        assert!(!cache.is_enabled());
        assert!(cache.load("stage", key).is_none());
        let art = Store::new();
        assert!(cache.store("stage", key, &art).unwrap().is_none());
        assert!(cache.stage_ckpt("stage", key).is_none());
        assert_eq!(cache.stats().misses, 1);
        assert_eq!(cache.stats().stores, 0);
    }

    #[test]
    fn spec_keys_dedupe_on_config_not_content() {
        let m = toy_manifest();
        let p = PretrainCfg::default();
        let ts = pretrain_key(&m, &p);

        let d = DistillCfg::default();
        let k1 = distill_spec_key(&m, &d, ts);
        assert_eq!(k1, distill_spec_key(&m, &d, ts), "spec keys are stable");
        let mut d2 = d.clone();
        d2.seed += 1;
        assert_ne!(distill_spec_key(&m, &d2, ts), k1);
        // a different synthesis engine is a different distill stage
        let mut dz = d.clone();
        dz.engine = crate::synthesis::Engine::Zeroq;
        assert_ne!(distill_spec_key(&m, &dz, ts), k1);
        // a different upstream teacher spec separates downstream specs
        let mut p2 = p.clone();
        p2.steps += 1;
        let ts2 = pretrain_key(&m, &p2);
        assert_ne!(distill_spec_key(&m, &d, ts2), k1);
        // spec keys never collide with content keys on the same fields
        assert_ne!(k1, distill_key(&m, &d, ts.0));

        let q = QuantCfg::default();
        let qs = quantize_spec_key(&m, &q, ts, k1);
        assert_eq!(qs, quantize_spec_key(&m, &q, ts, k1));
        // base bits shape the (unresolved) plan, so they move the spec
        let mut qw = q.clone();
        qw.wbits = 2;
        assert_ne!(quantize_spec_key(&m, &qw, ts, k1), qs);
        // a different calibration source is a different quantize stage
        let real = real_calib_spec_key(128, q.seed ^ 0x5eed);
        assert_ne!(quantize_spec_key(&m, &q, ts, real), qs);
        assert_ne!(real_calib_spec_key(64, 1), real_calib_spec_key(128, 1));

        // eval specs: fp dedupes on the teacher, q on the qstate
        assert_eq!(eval_fp_spec_key(&m, ts), eval_fp_spec_key(&m, ts));
        assert_ne!(eval_fp_spec_key(&m, ts), eval_fp_spec_key(&m, ts2));
        assert_ne!(eval_q_spec_key(&m, qs), eval_fp_spec_key(&m, ts));
    }

    #[test]
    fn claim_serializes_concurrent_materialization() {
        let dir = std::env::temp_dir().join("genie_artifact_claim_test");
        std::fs::remove_dir_all(&dir).ok();
        let cache = ArtifactCache::open(&dir, true, false).unwrap();
        let key = KeyBuilder::new("test").field("x", 1).finish();

        let first = cache.claim("stage", key).unwrap();
        assert!(cache.lock_path("stage", key).exists());

        // a second claimant blocks until the first drops
        let t0 = std::time::Instant::now();
        let handle = {
            let dir = dir.clone();
            std::thread::spawn(move || {
                let cache = ArtifactCache::open(&dir, true, false).unwrap();
                let c = cache.claim("stage", key).unwrap();
                let waited = t0.elapsed();
                drop(c);
                waited
            })
        };
        std::thread::sleep(std::time::Duration::from_millis(120));
        drop(first);
        let waited = handle.join().unwrap();
        assert!(
            waited.as_millis() >= 100,
            "second claim should have blocked, waited {waited:?}"
        );
        assert!(!cache.lock_path("stage", key).exists(), "lock released");
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn stale_claim_is_broken() {
        let dir = std::env::temp_dir().join("genie_artifact_stale_claim_test");
        std::fs::remove_dir_all(&dir).ok();
        let mut cache = ArtifactCache::open(&dir, true, false).unwrap();
        let key = KeyBuilder::new("test").finish();
        // a lockfile left by a "crashed" claimant (no WipClaim alive)
        std::fs::write(cache.lock_path("stage", key), b"").unwrap();
        cache.set_claim_stale_secs(0);
        let c = cache.claim("stage", key).unwrap();
        drop(c);
        assert!(!cache.lock_path("stage", key).exists());
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn dead_holders_lock_is_taken_over_and_waiter_hits() {
        // crash simulation: a claimant "dies" holding the lock (the
        // lockfile exists, nobody will ever release it) *after* the
        // artifact landed. Waiters must break the stale lock via the
        // rename path and wake to a coherent cache hit — exactly one
        // takeover, no deleted live locks, no corrupted artifact.
        let dir = std::env::temp_dir().join("genie_artifact_crash_sim");
        std::fs::remove_dir_all(&dir).ok();
        let mut cache = ArtifactCache::open(&dir, true, false).unwrap();
        let key = KeyBuilder::new("test").field("x", 9).finish();
        let mut art = Store::new();
        art.insert("images", Tensor::from_f32(&[2, 2], vec![1., 2., 3., 4.]));
        cache.store("stage", key, &art).unwrap();
        // the dead holder's lock: a token no live WipClaim carries
        std::fs::write(cache.lock_path("stage", key), b"dead:0").unwrap();

        let waiters: Vec<_> = (0..2)
            .map(|_| {
                let dir = dir.clone();
                std::thread::spawn(move || {
                    let mut c =
                        ArtifactCache::open(&dir, true, false).unwrap();
                    c.set_claim_stale_secs(0);
                    let claim = c.claim("stage", key).unwrap();
                    let got = c.load("stage", key);
                    drop(claim);
                    (got, c.stats().hits)
                })
            })
            .collect();
        for w in waiters {
            let (got, hits) = w.join().unwrap();
            let got = got.expect("waiter must wake to a cache hit");
            assert_eq!(
                got.get("images").unwrap(),
                art.get("images").unwrap(),
                "takeover must surface the intact artifact"
            );
            assert_eq!(hits, 1);
        }
        // every claim released; the dead holder's lock is gone, not
        // resurrected
        assert!(!cache.lock_path("stage", key).exists());
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn released_claim_never_removes_a_foreign_lock() {
        let dir = std::env::temp_dir().join("genie_artifact_foreign_lock");
        std::fs::remove_dir_all(&dir).ok();
        let cache = ArtifactCache::open(&dir, true, false).unwrap();
        let key = KeyBuilder::new("test").finish();
        let mine = cache.claim("stage", key).unwrap();
        // simulate a stale-break + takeover by another claimant: the
        // lockfile now carries someone else's token
        std::fs::write(cache.lock_path("stage", key), b"other:0").unwrap();
        drop(mine);
        assert!(
            cache.lock_path("stage", key).exists(),
            "drop must not delete a successor's live lock"
        );
        std::fs::remove_file(cache.lock_path("stage", key)).unwrap();
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn disabled_cache_claim_is_inert() {
        let cache = ArtifactCache::disabled();
        let key = KeyBuilder::new("test").finish();
        let c = cache.claim("stage", key).unwrap();
        assert!(!cache.lock_path("stage", key).exists());
        drop(c);
    }

    #[test]
    fn corrupt_artifact_is_a_quarantined_miss() {
        let dir = std::env::temp_dir().join("genie_artifact_corrupt_test");
        std::fs::remove_dir_all(&dir).ok();
        let mut cache = ArtifactCache::open(&dir, true, false).unwrap();
        let key = KeyBuilder::new("test").finish();
        std::fs::write(cache.path("stage", key), b"NOPE").unwrap();
        assert!(cache.load("stage", key).is_none());
        assert_eq!(cache.stats().misses, 1);
        assert_eq!(cache.stats().quarantined, 1);
        // the bad file moved aside instead of lingering in the cache
        assert!(!cache.path("stage", key).exists());
        let moved = cache
            .quarantine_dir()
            .join(format!("stage_{}.gts", key.hex()));
        assert_eq!(std::fs::read(moved).unwrap(), b"NOPE");
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn store_writes_hash_sidecar_and_load_verifies_it() {
        let dir = std::env::temp_dir().join("genie_artifact_sidecar_test");
        std::fs::remove_dir_all(&dir).ok();
        let mut cache = ArtifactCache::open(&dir, true, false).unwrap();
        let key = KeyBuilder::new("test").field("x", 3).finish();
        let mut art = Store::new();
        art.insert("images", Tensor::from_f32(&[4], vec![1., 2., 3., 4.]));
        cache.store("stage", key, &art).unwrap();
        let sidecar = cache.sidecar_path("stage", key);
        let want = std::fs::read_to_string(&sidecar).unwrap();
        assert_eq!(want, format!("{:016x}", art.content_hash()));

        // a flipped byte in the middle of a *parseable* region is caught
        // by the hash (the parse alone might accept it)
        let p = cache.path("stage", key);
        let mut bytes = std::fs::read(&p).unwrap();
        let mid = bytes.len() / 2;
        bytes[mid] ^= 0xff;
        std::fs::write(&p, &bytes).unwrap();
        assert!(cache.load("stage", key).is_none());
        assert_eq!(cache.stats().quarantined, 1);
        assert_eq!(cache.stats().misses, 1);
        assert!(!p.exists() && !sidecar.exists(), "both moved aside");

        // recompute path: the re-store overwrites and the next load is a
        // bit-identical hit
        cache.store("stage", key, &art).unwrap();
        let back = cache.load("stage", key).unwrap();
        assert_eq!(back.content_hash(), art.content_hash());
        assert_eq!(cache.stats().hits, 1);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn incoherent_artifact_is_a_checked_miss() {
        let dir = std::env::temp_dir().join("genie_artifact_checked_test");
        std::fs::remove_dir_all(&dir).ok();
        let mut cache = ArtifactCache::open(&dir, true, false).unwrap();
        let key = KeyBuilder::new("test").finish();
        // parses fine, but the piece the stage decodes is missing
        let mut partial = Store::new();
        partial.insert("final_loss", Tensor::scalar_f32(0.5));
        cache.store("stage", key, &partial).unwrap();
        let check = |a: &Store| a.get("images").is_ok();
        assert!(cache.load_checked("stage", key, check).is_none());
        assert_eq!(cache.stats().misses, 1);
        // rewriting it coherently turns the same lookup into a hit
        let mut full = partial.clone();
        full.insert("images", Tensor::from_f32(&[2], vec![1.0, 2.0]));
        cache.store("stage", key, &full).unwrap();
        assert!(cache.load_checked("stage", key, check).is_some());
        assert_eq!(cache.stats().hits, 1);
        std::fs::remove_dir_all(&dir).ok();
    }
}

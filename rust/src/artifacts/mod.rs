//! Content-addressed artifact cache (DESIGN.md §9): pipeline stages —
//! teacher pretraining, GENIE-D synthesis, GENIE-M qstate — persist
//! their products as GTS1 files keyed by a stable hash of everything
//! that determines them: the phase config fields, the manifest identity,
//! and the content hashes of upstream artifacts. `pipeline::zsq`/`fsq`
//! then become DAG lookups — a completed stage loads in milliseconds
//! instead of re-running — and an in-progress stage's per-shard
//! checkpoints live in a `wip_*` work dir that the cache clears once the
//! stage's artifact lands.
//!
//! Keys deliberately exclude `workers` (parallel phases are bit-identical
//! for any worker count, DESIGN.md §5) and `steps_per_dispatch` (fused
//! dispatch is identity-neutral the same way, DESIGN.md §14), and include
//! `seed` (a different seed is a different artifact). Hashing is FNV-1a 64 over a canonical
//! `name=value;` rendering plus raw tensor bytes — never std's SipHash,
//! whose keys are process-random.
//!
//! Two key families share the same config field folds (DESIGN.md §11):
//!
//!   * **content keys** (`distill_key`, `quantize_key`, ...) fold
//!     upstream *content hashes* — only computable once the upstream
//!     artifact exists; they address cache files.
//!   * **spec keys** (`distill_spec_key`, `quantize_spec_key`, ...) fold
//!     upstream *spec keys* instead — computable before anything runs.
//!     The grid orchestrator dedupes its cross-run stage DAG on spec
//!     keys (equal spec ⇒ equal content within one process, where the
//!     manifests and dataset are fixed); they never address files.
//!
//! Concurrent materialization is serialized per key by
//! [`ArtifactCache::claim`]: the first claimant creates
//! `wip_<kind>_<key>.lock` and computes; later claimants block until the
//! lock releases, then re-check the cache and hit.
//!
//! Integrity (DESIGN.md §13): every store writes a `<file>.fnv` sidecar
//! carrying the FNV-1a 64 hash of the artifact bytes — folded in the
//! same pass that serializes them, never a re-read; every load hashes
//! the byte buffer the parser consumes, once, and verifies it (plus the
//! GTS1 parse). A corrupt or torn artifact is moved into the tier's
//! `quarantine/` sidecar dir, counted as a miss *and* as
//! [`CacheStats::quarantined`], its claim lockfile is released so
//! waiters recompute immediately, and the stage re-runs — a
//! crash-looping service never wedges on a bad file.
//!
//! **Tiers (DESIGN.md §16).** The cache is a three-tier read-through /
//! write-through stack:
//!
//!   * **tier 0** ([`hot`]) — a process-global in-memory map of
//!     deserialized artifacts behind `Arc<Store>` handles, LRU-bounded
//!     by `cache.hot_bytes`. N grid jobs agreeing on a content key parse
//!     the GTS1 bytes exactly once; every later load clones an `Arc`.
//!   * **tier 1** ([`backend::LocalDir`]) — the on-disk layout, bounded
//!     by `cache.budget_bytes` via pin-aware GC ([`gc`]).
//!   * **tier 2** ([`backend::SharedDir`], optional) — the same layout
//!     on a shared directory so many machines pool one artifact store;
//!     a tier-2 hit is copied down to tier 1, a store is written through
//!     to both.

use std::path::{Path, PathBuf};
use std::sync::Arc;

use anyhow::{Context, Result};

use crate::coordinator::{DistillCfg, PretrainCfg, QuantCfg};
use crate::phase::StageCkpt;
use crate::precision::PrecisionPlan;
use crate::runtime::Manifest;
use crate::store::{fnv1a, Store, FNV_OFFSET};
use crate::tensor::{Data, Tensor};

pub mod backend;
pub mod gc;
mod hot;

pub use backend::{Backend, LocalDir, SharedDir};
pub use gc::GcReport;

/// Drop every tier-0 entry for one cache directory (tests and benches
/// that need to observe true disk behavior after in-process stores).
pub fn clear_hot(dir: impl AsRef<Path>) {
    hot::clear(&hot::namespace(dir.as_ref()));
}

/// How many times `<kind>_<key>` has been deserialized from a disk tier
/// of `dir` over the process lifetime — the observable behind the
/// "N agreeing cells parse a shared artifact exactly once" contract.
pub fn disk_deser_count(dir: impl AsRef<Path>, kind: &str, key: CacheKey) -> u64 {
    hot::deser_count(
        &hot::namespace(dir.as_ref()),
        &format!("{kind}_{}", key.hex()),
    )
}

/// A 64-bit content-addressed cache key.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct CacheKey(pub u64);

impl CacheKey {
    pub fn hex(&self) -> String {
        format!("{:016x}", self.0)
    }
}

/// Builds a [`CacheKey`] from named fields. Every field moves the key;
/// field order is part of the recipe (documented in DESIGN.md §9).
#[derive(Debug, Clone)]
pub struct KeyBuilder {
    h: u64,
}

impl KeyBuilder {
    pub fn new(kind: &str) -> Self {
        KeyBuilder { h: FNV_OFFSET }.field("kind", kind)
    }

    pub fn field(mut self, name: &str, value: impl std::fmt::Display) -> Self {
        self.h = fnv1a(self.h, name.as_bytes());
        self.h = fnv1a(self.h, b"=");
        self.h = fnv1a(self.h, value.to_string().as_bytes());
        self.h = fnv1a(self.h, b";");
        self
    }

    /// Fold an upstream artifact's key in (a DAG edge).
    pub fn upstream(self, name: &str, key: CacheKey) -> Self {
        self.field(name, key.hex())
    }

    /// Fold a store's content address in (teacher checkpoints).
    pub fn store(self, name: &str, s: &Store) -> Self {
        self.field(name, format!("{:016x}", s.content_hash()))
    }

    /// Fold one tensor's dtype/shape/bytes in (calibration sets).
    pub fn tensor(mut self, name: &str, t: &Tensor) -> Self {
        self.h = fnv1a(self.h, name.as_bytes());
        self.h = fnv1a(self.h, b"=");
        self.h = fnv1a(
            self.h,
            format!("{:?}{:?}", t.dtype(), t.shape).as_bytes(),
        );
        match &t.data {
            Data::F32(v) => {
                for x in v {
                    self.h = fnv1a(self.h, &x.to_le_bytes());
                }
            }
            Data::I32(v) => {
                for x in v {
                    self.h = fnv1a(self.h, &x.to_le_bytes());
                }
            }
            Data::U32(v) => {
                for x in v {
                    self.h = fnv1a(self.h, &x.to_le_bytes());
                }
            }
        }
        self.h = fnv1a(self.h, b";");
        self
    }

    pub fn finish(self) -> CacheKey {
        CacheKey(self.h)
    }
}

/// Manifest identity folded into every stage key: the model name plus
/// the structural facts its graphs were lowered with.
fn manifest_fields(b: KeyBuilder, m: &Manifest) -> KeyBuilder {
    b.field("model", &m.model)
        .field("image", format!("{:?}", m.image))
        .field("classes", m.num_classes)
        .field("blocks", m.num_blocks)
        .field("latent", m.latent)
}

/// Key of the pretrained-teacher artifact. Every field is config, so
/// this doubles as the teacher's *spec* key: the grid orchestrator
/// dedupes pretrain stages on it directly.
pub fn pretrain_key(m: &Manifest, cfg: &PretrainCfg) -> CacheKey {
    manifest_fields(KeyBuilder::new("teacher"), m)
        .field("steps", cfg.steps)
        .field("lr", cfg.lr)
        .field("log_every", cfg.log_every)
        .field("seed", cfg.seed)
        .finish()
}

/// The distill-config folds shared by the content and spec keys. `par`
/// and `steps_per_dispatch` are excluded — shard fan-out and dispatch
/// fusion never change the images.
fn distill_fields(b: KeyBuilder, cfg: &DistillCfg) -> KeyBuilder {
    b.field("engine", cfg.engine.as_str())
        .field("mode", cfg.mode.as_str())
        .field("swing", cfg.swing)
        .field("samples", cfg.samples)
        .field("steps", cfg.steps)
        .field("lr_g", cfg.lr_g)
        .field("lr_z", cfg.lr_z)
        .field("log_every", cfg.log_every)
        .field("seed", cfg.seed)
}

/// Key of the synthetic-calibration artifact: the distill config plus
/// the teacher it was distilled from (by content hash, so a retrained
/// teacher invalidates downstream artifacts automatically — the caller
/// computes `Store::content_hash` once and shares it across the stage
/// keys of one run).
pub fn distill_key(
    m: &Manifest,
    cfg: &DistillCfg,
    teacher_hash: u64,
) -> CacheKey {
    distill_fields(manifest_fields(KeyBuilder::new("distill"), m), cfg)
        .field("teacher", format!("{teacher_hash:016x}"))
        .finish()
}

/// Spec key of a distill stage: same config folds, but the upstream
/// teacher enters by *spec* key — computable before the teacher exists.
pub fn distill_spec_key(
    m: &Manifest,
    cfg: &DistillCfg,
    teacher_spec: CacheKey,
) -> CacheKey {
    distill_fields(manifest_fields(KeyBuilder::new("distill"), m), cfg)
        .upstream("teacher_spec", teacher_spec)
        .finish()
}

/// Spec key of a real-data calibration draw (`fsq`): the sample count
/// and the RNG stream that selects them. Valid for dedupe only within
/// one process, where the dataset is fixed.
pub fn real_calib_spec_key(samples: usize, seed: u64) -> CacheKey {
    KeyBuilder::new("realcalib")
        .field("samples", samples)
        .field("seed", seed)
        .finish()
}

/// Key of the resolved-precision-plan artifact (Pareto runs): every
/// plan-shaping config knob plus the teacher and calibration content
/// the sensitivity pass reads. Uniform plans are derived, not cached.
pub fn plan_key(
    m: &Manifest,
    cfg: &QuantCfg,
    teacher_hash: u64,
    calib: &Tensor,
) -> CacheKey {
    // `cfg.wbits` is deliberately absent: a Pareto plan's weight bits
    // come from `candidates`, so the uniform base width cannot change
    // the resolved plan and must not invalidate it
    let p = &cfg.precision;
    manifest_fields(KeyBuilder::new("plan"), m)
        .field("policy", p.policy.as_str())
        .field("abits", cfg.abits)
        .field("first_last", p.first_last_bits)
        .field("target_size", p.target_size)
        .field("granularity", p.granularity.as_str())
        .field("sens_batches", p.sens_batches)
        .field("candidates", format!("{:?}", p.candidates))
        .field("pnorm", cfg.pnorm)
        .field("teacher", format!("{teacher_hash:016x}"))
        .tensor("calib", calib)
        .finish()
}

/// The quantizer-config folds shared by the content and spec keys
/// (everything but the plan/precision identity and the upstreams).
/// `par` and `steps_per_dispatch` are excluded — execution shape never
/// changes the optimized qstate.
fn quantize_fields(b: KeyBuilder, cfg: &QuantCfg) -> KeyBuilder {
    b.field("steps", cfg.steps_per_block)
        .field("lr_sw", cfg.lr_sw)
        .field("lr_v", cfg.lr_v)
        .field("lr_sa", cfg.lr_sa)
        .field("lam", cfg.lam)
        .field("beta_start", cfg.beta_start)
        .field("beta_end", cfg.beta_end)
        .field("drop_p", cfg.drop_p)
        .field("pnorm", cfg.pnorm)
        .field("refresh", cfg.refresh_student)
        .field("log_every", cfg.log_every)
        .field("seed", cfg.seed)
}

/// Key of the optimized-qstate artifact: the quant config plus the
/// resolved precision plan (per-layer bits/granularity — a different
/// plan is a different artifact), the teacher (by precomputed content
/// hash) and the calibration images (synthetic or real) by content.
pub fn quantize_key(
    m: &Manifest,
    cfg: &QuantCfg,
    teacher_hash: u64,
    calib: &Tensor,
    plan: &PrecisionPlan,
) -> CacheKey {
    quantize_fields(
        manifest_fields(KeyBuilder::new("qstate"), m)
            .field("plan", plan.fingerprint()),
        cfg,
    )
    .field("teacher", format!("{teacher_hash:016x}"))
    .tensor("calib", calib)
    .finish()
}

/// Spec key of a quantize stage: the plan is not resolved yet, so the
/// plan-shaping config (base bits + every precision knob) stands in for
/// the fingerprint, and both upstreams — teacher and calibration source
/// (a distill spec or a [`real_calib_spec_key`]) — enter by spec key.
pub fn quantize_spec_key(
    m: &Manifest,
    cfg: &QuantCfg,
    teacher_spec: CacheKey,
    calib_spec: CacheKey,
) -> CacheKey {
    let p = &cfg.precision;
    quantize_fields(
        manifest_fields(KeyBuilder::new("qstate"), m)
            .field("wbits", cfg.wbits)
            .field("abits", cfg.abits)
            .field("policy", p.policy.as_str())
            .field("first_last", p.first_last_bits)
            .field("target_size", p.target_size)
            .field("granularity", p.granularity.as_str())
            .field("sens_batches", p.sens_batches)
            .field("candidates", format!("{:?}", p.candidates)),
        cfg,
    )
    .upstream("teacher_spec", teacher_spec)
    .upstream("calib_spec", calib_spec)
    .finish()
}

/// Spec key of an FP32-teacher eval (dedupes across every cell that
/// shares the teacher).
pub fn eval_fp_spec_key(m: &Manifest, teacher_spec: CacheKey) -> CacheKey {
    manifest_fields(KeyBuilder::new("evalfp"), m)
        .upstream("teacher_spec", teacher_spec)
        .finish()
}

/// Spec key of a quantized eval (one per distinct qstate spec).
pub fn eval_q_spec_key(m: &Manifest, quantize_spec: CacheKey) -> CacheKey {
    manifest_fields(KeyBuilder::new("evalq"), m)
        .upstream("qstate_spec", quantize_spec)
        .finish()
}

/// Cache traffic counters, mirrored into `Metrics` by the pipeline.
/// `hits` counts a hit on *any* tier; the per-tier fields break it down
/// (`hits == hot_hits + disk_hits + shared_hits`).
#[derive(Debug, Default, Clone)]
pub struct CacheStats {
    pub hits: u64,
    pub misses: u64,
    pub stores: u64,
    /// Corrupt/torn artifacts detected on load and moved to the
    /// `quarantine/` sidecar dir (each is also counted as a miss — the
    /// stage recomputes and rewrites).
    pub quarantined: u64,
    /// Tier-0 hits: served from the in-process `Arc<Store>` map, no
    /// disk read, no parse.
    pub hot_hits: u64,
    /// Tier-1 hits: read + verified + parsed from the local dir.
    pub disk_hits: u64,
    /// Tier-2 hits: read from the shared backend (and copied down).
    pub shared_hits: u64,
    /// Tier-0 entries evicted to stay under `cache.hot_bytes`.
    pub hot_evictions: u64,
    /// Tier-1 artifacts evicted by automatic GC (`cache.budget_bytes`).
    pub gc_evictions: u64,
}

/// A held materialization claim on one artifact key (DESIGN.md §11):
/// while alive, `wip_<kind>_<key>.lock` exists and every concurrent
/// [`ArtifactCache::claim`] on the same key blocks. Dropping removes the
/// lockfile — but only after verifying the file still carries this
/// claim's token, so a claim whose lock was broken as stale (and
/// re-acquired by a successor) never deletes the successor's live lock.
/// A claim from a disabled cache holds nothing.
#[derive(Debug)]
pub struct WipClaim {
    path: Option<PathBuf>,
    token: String,
}

impl Drop for WipClaim {
    fn drop(&mut self) {
        if let Some(p) = self.path.take() {
            // ownership check: remove only our own lock (a stolen lock
            // belongs to whoever broke it)
            if std::fs::read_to_string(&p)
                .is_ok_and(|t| t == self.token)
            {
                std::fs::remove_file(p).ok();
            }
        }
    }
}

/// Outcome of reading one artifact from one disk tier.
enum TierRead {
    /// No file — the ordinary cold miss at this tier.
    Missing,
    /// Bytes present but hash-mismatched or unparseable.
    Corrupt(&'static str),
    /// Verified and parsed: the store, the raw bytes (for write-through
    /// and tier-0 size accounting), and their FNV-1a hash.
    Parsed(Store, Vec<u8>, u64),
}

/// The tiered cache: completed artifacts as `<kind>_<key>.gts` (local
/// dir = tier 1, optional shared dir = tier 2, hot `Arc<Store>` map =
/// tier 0), stage work dirs as `wip_<kind>_<key>/`, materialization
/// locks as `wip_<kind>_<key>.lock` (always local — see
/// [`backend`] for the shared tier's coordination contract).
#[derive(Debug)]
pub struct ArtifactCache {
    dir: PathBuf,
    /// Hot-tier namespace: the canonical form of `dir`, so every cache
    /// instance on the same directory shares one tier-0 pool.
    ns: String,
    local: LocalDir,
    /// Tier 2, when `cache.backend = shared-dir`.
    shared: Option<SharedDir>,
    enabled: bool,
    resume: bool,
    checkpoint_every: usize,
    /// Tier-0 byte budget (0 = unlimited).
    hot_bytes: u64,
    /// Tier-1 byte budget (0 = unlimited); enforced by a pin-aware GC
    /// pass after every store.
    budget_bytes: u64,
    /// Lockfiles older than this are treated as left by a crashed
    /// claimant and broken (claims touch their lock only at creation, so
    /// age = mtime age).
    claim_stale_secs: u64,
    stats: CacheStats,
}

impl ArtifactCache {
    /// Open (creating) a cache dir. `enabled = false` turns every lookup
    /// into a miss and every store into a no-op (`--no-cache`); `resume`
    /// lets interrupted stages continue from their wip checkpoints
    /// (`--resume`).
    pub fn open(
        dir: impl AsRef<Path>,
        enabled: bool,
        resume: bool,
    ) -> Result<Self> {
        if enabled {
            std::fs::create_dir_all(dir.as_ref())
                .with_context(|| format!("create cache dir {:?}", dir.as_ref()))?;
        }
        let dir = dir.as_ref().to_path_buf();
        Ok(ArtifactCache {
            ns: hot::namespace(&dir),
            local: LocalDir::new(&dir),
            shared: None,
            dir,
            enabled,
            resume,
            checkpoint_every: 50,
            hot_bytes: 0,
            budget_bytes: 0,
            claim_stale_secs: 1800,
            stats: CacheStats::default(),
        })
    }

    /// A cache that never hits nor persists — for call sites that opt
    /// out of caching entirely.
    pub fn disabled() -> Self {
        let dir = PathBuf::from("cache");
        ArtifactCache {
            ns: String::new(),
            local: LocalDir::new(&dir),
            shared: None,
            dir,
            enabled: false,
            resume: false,
            checkpoint_every: 0,
            hot_bytes: 0,
            budget_bytes: 0,
            claim_stale_secs: 1800,
            stats: CacheStats::default(),
        }
    }

    pub fn is_enabled(&self) -> bool {
        self.enabled
    }

    pub fn stats(&self) -> &CacheStats {
        &self.stats
    }

    /// Steps between mid-phase checkpoint writes (0 = shard-boundary
    /// durability only).
    pub fn set_checkpoint_every(&mut self, every: usize) {
        self.checkpoint_every = every;
    }

    /// Tier-0 byte budget (0 = unlimited).
    pub fn set_hot_bytes(&mut self, bytes: u64) {
        self.hot_bytes = bytes;
    }

    /// Tier-1 byte budget (0 = unlimited). When set, every store runs a
    /// pin-aware GC pass ([`gc::collect`]) — artifacts this process has
    /// touched are session-pinned, so a tight budget only evicts other
    /// sessions' leftovers.
    pub fn set_budget_bytes(&mut self, bytes: u64) {
        self.budget_bytes = bytes;
    }

    /// Attach the tier-2 shared-directory backend.
    pub fn attach_shared(&mut self, dir: impl AsRef<Path>) -> Result<()> {
        self.shared = Some(SharedDir::new(dir)?);
        Ok(())
    }

    /// The hot-tier namespace this cache reads/writes (test hook).
    pub fn hot_namespace(&self) -> &str {
        &self.ns
    }

    /// The tier-1 backend (GC and `cache stats|gc` operate on it).
    pub fn local_backend(&self) -> &dyn Backend {
        &self.local
    }

    /// The tier-2 backend, when configured.
    pub fn shared_backend(&self) -> Option<&dyn Backend> {
        self.shared.as_ref().map(|s| s as &dyn Backend)
    }

    /// `(hot, disk)` bytes currently resident for this cache dir — the
    /// `cache/<tier>/bytes` metric sources.
    pub fn tier_bytes(&self) -> (u64, u64) {
        let disk = self
            .local
            .list()
            .iter()
            .filter(|e| e.name.ends_with(".gts"))
            .map(|e| e.bytes)
            .sum();
        (hot::dir_bytes(&self.ns), disk)
    }

    pub fn path(&self, kind: &str, key: CacheKey) -> PathBuf {
        self.dir.join(format!("{kind}_{}.gts", key.hex()))
    }

    /// The content-hash sidecar next to an artifact file
    /// (`<file>.gts.fnv`, 16 hex chars of FNV-1a 64 over the file bytes).
    pub fn sidecar_path(&self, kind: &str, key: CacheKey) -> PathBuf {
        self.dir.join(format!("{kind}_{}.gts.fnv", key.hex()))
    }

    /// Where corrupt/torn artifacts are moved on detection.
    pub fn quarantine_dir(&self) -> PathBuf {
        self.dir.join("quarantine")
    }

    /// Move a bad artifact (and its sidecar) into the tier's
    /// `quarantine/`, counting it, dropping any tier-0 copy, and
    /// releasing the claim lockfile — waiters should wake and recompute
    /// immediately instead of riding out the stale-takeover timeout.
    /// (Deleting a lockfile out from under its holder is safe:
    /// [`WipClaim`]'s drop is token-checked.) The caller then reports a
    /// miss; the re-store overwrites cleanly.
    fn quarantine_tier(
        &mut self,
        shared: bool,
        kind: &str,
        key: CacheKey,
        why: &str,
    ) {
        let stem = format!("{kind}_{}", key.hex());
        let file = format!("{stem}.gts");
        let tier = if shared { "shared" } else { "disk" };
        if shared {
            if let Some(b) = &self.shared {
                b.quarantine(&file);
                b.quarantine(&format!("{file}.fnv"));
            }
        } else {
            self.local.quarantine(&file);
            self.local.quarantine(&format!("{file}.fnv"));
        }
        hot::remove(&self.ns, &stem);
        std::fs::remove_file(self.lock_path(kind, key)).ok();
        self.stats.quarantined += 1;
        crate::progress!(
            "cache[{tier}]: quarantined {stem} ({why}); stage will recompute"
        );
    }

    /// Read + verify one artifact from one disk tier: hash the byte
    /// buffer the parser consumes — once, no second read — against the
    /// sidecar (a missing sidecar skips the hash check: pre-§13
    /// caches), then parse the same buffer.
    fn read_tier(&self, shared: bool, file: &str) -> TierRead {
        let read = |name: &str| {
            if shared {
                self.shared.as_ref().and_then(|b| b.read(name))
            } else {
                self.local.read(name)
            }
        };
        let Some(bytes) = read(file) else {
            return TierRead::Missing;
        };
        let hash = fnv1a(FNV_OFFSET, &bytes);
        if let Some(sc) = read(&format!("{file}.fnv")) {
            let want = String::from_utf8_lossy(&sc);
            if want.trim() != format!("{hash:016x}") {
                return TierRead::Corrupt("content hash mismatch");
            }
        }
        match Store::from_bytes(&bytes) {
            Ok(s) => TierRead::Parsed(s, bytes, hash),
            Err(_) => TierRead::Corrupt("unparseable GTS1 bytes"),
        }
    }

    /// The tiered lookup behind [`load`](Self::load) and
    /// [`load_checked`](Self::load_checked): tier 0 serves a shared
    /// handle with no I/O; a tier-1 hit re-publishes the sidecar (which
    /// refreshes the artifact's GC recency); a tier-2 hit is copied
    /// down to tier 1; any disk hit is promoted into tier 0. A corrupt
    /// tier is quarantined and the next tier tried — read-through
    /// repair. A `check` failure at one tier falls through to the next
    /// (a partial copy elsewhere may be complete here).
    fn load_tiered(
        &mut self,
        kind: &str,
        key: CacheKey,
        check: Option<&dyn Fn(&Store) -> bool>,
    ) -> Option<Arc<Store>> {
        if !self.enabled {
            self.stats.misses += 1;
            return None;
        }
        let stem = format!("{kind}_{}", key.hex());
        let file = format!("{stem}.gts");
        // fault injection first: an injected disk corruption must be
        // observed on this load, never masked by a hot copy
        if crate::faults::corrupt_hook(&stem, &self.path(kind, key)) {
            hot::remove(&self.ns, &stem);
        }
        if let Some(s) = hot::get(&self.ns, &stem) {
            if check.map_or(true, |c| c(&s)) {
                gc::pin_session(&self.ns, &stem);
                self.stats.hits += 1;
                self.stats.hot_hits += 1;
                return Some(s);
            }
            // incoherent resident (the artifact was re-stored partial
            // elsewhere): drop it and re-read the disk tiers
            hot::remove(&self.ns, &stem);
        }
        for shared in [false, true] {
            if shared && self.shared.is_none() {
                break;
            }
            match self.read_tier(shared, &file) {
                TierRead::Missing => continue,
                TierRead::Corrupt(why) => {
                    self.quarantine_tier(shared, kind, key, why);
                    continue;
                }
                TierRead::Parsed(s, bytes, hash) => {
                    if check.is_some_and(|c| !c(&s)) {
                        continue;
                    }
                    hot::note_deser(&self.ns, &stem);
                    let hex = format!("{hash:016x}");
                    if shared {
                        // write-through down to tier 1: the next
                        // process-cold load is local
                        self.local.write(&file, &bytes).ok();
                        self.local
                            .write(&format!("{file}.fnv"), hex.as_bytes())
                            .ok();
                        self.stats.shared_hits += 1;
                    } else {
                        // re-publish the sidecar: refreshes this
                        // artifact's mtime recency for GC (and emits
                        // the sidecar for pre-§13 caches)
                        self.local
                            .write(&format!("{file}.fnv"), hex.as_bytes())
                            .ok();
                        self.stats.disk_hits += 1;
                    }
                    gc::pin_session(&self.ns, &stem);
                    let arc = Arc::new(s);
                    self.stats.hot_evictions += hot::insert(
                        &self.ns,
                        &stem,
                        arc.clone(),
                        bytes.len() as u64,
                        self.hot_bytes,
                    );
                    self.stats.hits += 1;
                    return Some(arc);
                }
            }
        }
        self.stats.misses += 1;
        None
    }

    /// Look a completed artifact up, counting the hit/miss. A missing
    /// file is a miss; a corrupt/torn file is quarantined *and* counted
    /// as a miss (the stage re-runs and rewrites it). Returns a shared
    /// handle — N agreeing callers deserialize once and clone the
    /// `Arc`; `Store::clone` through it is copy-on-write either way.
    pub fn load(&mut self, kind: &str, key: CacheKey) -> Option<Arc<Store>> {
        self.load_tiered(kind, key, None)
    }

    /// [`Self::load`] gated on a coherence check: an artifact that
    /// parses but fails `check` — missing tensors, e.g. a partial copy
    /// from another cache — is demoted to a miss (no quarantine: the
    /// bytes are intact, just incomplete), so the stage recomputes and
    /// rewrites it instead of erroring on the decode (and the grid dry
    /// run predicts the same disposition).
    pub fn load_checked(
        &mut self,
        kind: &str,
        key: CacheKey,
        check: impl Fn(&Store) -> bool,
    ) -> Option<Arc<Store>> {
        self.load_tiered(kind, key, Some(&check))
    }

    /// A tiered lookup that touches no traffic counters — the grid's
    /// `--dry-run` resolution predicts cache dispositions without
    /// polluting the stats a real run will report. Disk hits are still
    /// promoted into tier 0, so a dry run warms the real one.
    pub fn peek(&self, kind: &str, key: CacheKey) -> Option<Arc<Store>> {
        if !self.enabled {
            return None;
        }
        let stem = format!("{kind}_{}", key.hex());
        let file = format!("{stem}.gts");
        if let Some(s) = hot::get(&self.ns, &stem) {
            return Some(s);
        }
        for shared in [false, true] {
            if shared && self.shared.is_none() {
                break;
            }
            if let TierRead::Parsed(s, bytes, _) =
                self.read_tier(shared, &file)
            {
                hot::note_deser(&self.ns, &stem);
                gc::pin_session(&self.ns, &stem);
                let arc = Arc::new(s);
                hot::insert(
                    &self.ns,
                    &stem,
                    arc.clone(),
                    bytes.len() as u64,
                    self.hot_bytes,
                );
                return Some(arc);
            }
        }
        None
    }

    /// Does any tier hold this artifact? (Existence only — no read, no
    /// verification, no counters; the dry-run disposition for stages
    /// that would load lazily.)
    pub fn contains(&self, kind: &str, key: CacheKey) -> bool {
        if !self.enabled {
            return false;
        }
        let stem = format!("{kind}_{}", key.hex());
        if hot::get(&self.ns, &stem).is_some() {
            return true;
        }
        if self.path(kind, key).exists() {
            return true;
        }
        self.shared
            .as_ref()
            .is_some_and(|b| b.root().join(format!("{stem}.gts")).exists())
    }

    /// Store a completed artifact and clear the stage's work dir: one
    /// serialization pass yields the bytes *and* the FNV-1a content
    /// hash ([`Store::to_bytes_hashed`]), the artifact lands atomically
    /// on tier 1 (then tier 2, write-through), the sidecar lands after
    /// the artifact — a crash between the two leaves a state the next
    /// load either verifies (no sidecar yet: parse-only) or
    /// quarantines, never serves silently corrupted — and the
    /// deserialized store is promoted into tier 0. No-op when disabled.
    /// With a tier-1 budget set, a pin-aware GC pass runs after the
    /// write (artifacts this session touched are pinned, see [`gc`]).
    pub fn store(
        &mut self,
        kind: &str,
        key: CacheKey,
        s: &Store,
    ) -> Result<Option<PathBuf>> {
        if !self.enabled {
            return Ok(None);
        }
        let stem = format!("{kind}_{}", key.hex());
        let file = format!("{stem}.gts");
        let (bytes, hash) = s.to_bytes_hashed()?;
        let hex = format!("{hash:016x}");
        let p = self.local.write(&file, &bytes)?;
        self.local
            .write(&format!("{file}.fnv"), hex.as_bytes())
            .with_context(|| format!("write hash sidecar for {p:?}"))?;
        if let Some(sh) = &self.shared {
            sh.write(&file, &bytes)?;
            sh.write(&format!("{file}.fnv"), hex.as_bytes())?;
        }
        gc::pin_session(&self.ns, &stem);
        self.stats.hot_evictions += hot::insert(
            &self.ns,
            &stem,
            Arc::new(s.clone()),
            bytes.len() as u64,
            self.hot_bytes,
        );
        self.stats.stores += 1;
        self.clear_wip(kind, key);
        if self.budget_bytes > 0 {
            let r = gc::collect(
                &self.local,
                &self.ns,
                self.budget_bytes,
                &std::collections::HashSet::new(),
            );
            self.stats.gc_evictions += r.evicted as u64;
        }
        Ok(Some(p))
    }

    /// The in-progress work dir for one stage.
    pub fn wip_dir(&self, kind: &str, key: CacheKey) -> PathBuf {
        self.dir.join(format!("wip_{kind}_{}", key.hex()))
    }

    /// The materialization lockfile for one stage key.
    pub fn lock_path(&self, kind: &str, key: CacheKey) -> PathBuf {
        self.dir.join(format!("wip_{kind}_{}.lock", key.hex()))
    }

    /// Seconds after which a lockfile counts as abandoned (test hook;
    /// default 1800). The tradeoff: a stage that legitimately computes
    /// longer than this risks having its lock broken (the worst case is
    /// duplicated — still deterministic and atomically stored — work),
    /// while a crashed claimant blocks concurrent runs for at most this
    /// long.
    pub fn set_claim_stale_secs(&mut self, secs: u64) {
        self.claim_stale_secs = secs;
    }

    /// Claim the right to materialize `<kind>_<key>` (DESIGN.md §11).
    /// Creates the per-key lockfile atomically (`create_new`, stamped
    /// with an ownership token); if another claimant — in this process
    /// or another — holds it, blocks polling until the lock releases (or
    /// goes stale and is broken — via atomic rename, so exactly one
    /// waiter takes a stale lock over). Callers check
    /// [`load`](ArtifactCache::load) after claiming: the released
    /// claimant usually stored the artifact, turning this claimant's
    /// compute into a cache hit. Disabled caches return an empty claim
    /// immediately.
    pub fn claim(&self, kind: &str, key: CacheKey) -> Result<WipClaim> {
        use std::io::Write;
        if !self.enabled {
            return Ok(WipClaim { path: None, token: String::new() });
        }
        static SEQ: std::sync::atomic::AtomicU64 =
            std::sync::atomic::AtomicU64::new(0);
        let token = format!(
            "{}:{}",
            std::process::id(),
            SEQ.fetch_add(1, std::sync::atomic::Ordering::Relaxed)
        );
        let path = self.lock_path(kind, key);
        loop {
            match std::fs::OpenOptions::new()
                .write(true)
                .create_new(true)
                .open(&path)
            {
                Ok(mut f) => {
                    f.write_all(token.as_bytes()).ok();
                    return Ok(WipClaim { path: Some(path), token });
                }
                Err(e)
                    if e.kind() == std::io::ErrorKind::AlreadyExists =>
                {
                    // a crashed claimant never unlocks; break stale
                    // locks by renaming them away — rename is atomic,
                    // so exactly one waiter wins the takeover and a
                    // freshly re-created lock is never deleted by a
                    // racing waiter that read the old mtime
                    let stale = std::fs::metadata(&path)
                        .ok()
                        .and_then(|m| m.modified().ok())
                        .and_then(|t| t.elapsed().ok())
                        .is_some_and(|age| {
                            age.as_secs() >= self.claim_stale_secs
                        });
                    if stale {
                        let grave = self.dir.join(format!(
                            "wip_{kind}_{}.stale.{token}",
                            key.hex()
                        ));
                        if std::fs::rename(&path, &grave).is_ok() {
                            std::fs::remove_file(&grave).ok();
                        }
                        continue;
                    }
                    std::thread::sleep(
                        std::time::Duration::from_millis(25),
                    );
                }
                Err(e) => {
                    return Err(e).with_context(|| {
                        format!("claim lockfile {path:?}")
                    })
                }
            }
        }
    }

    /// Per-shard checkpoint policy for one stage; `None` when disabled.
    pub fn stage_ckpt(&self, kind: &str, key: CacheKey) -> Option<StageCkpt> {
        if !self.enabled {
            return None;
        }
        Some(StageCkpt::new(
            self.wip_dir(kind, key),
            self.checkpoint_every,
            self.resume,
        ))
    }

    pub fn clear_wip(&self, kind: &str, key: CacheKey) {
        std::fs::remove_dir_all(self.wip_dir(kind, key)).ok();
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn toy_manifest() -> Manifest {
        Manifest::from_json_text(
            r#"{
                "model": "toy", "image": [16, 16, 3], "num_classes": 10,
                "num_blocks": 2, "latent": 256,
                "batch": {"train": 64},
                "params": [], "bn": [], "qstate": [], "gen_params": [],
                "quant_layers": [], "learnable": {"0": []},
                "bounds": [], "entrypoints": {}
            }"#,
        )
        .unwrap()
    }

    #[test]
    fn keys_stable_and_config_sensitive() {
        let m = toy_manifest();
        let mut teacher = Store::new();
        teacher.insert("w", Tensor::from_f32(&[2], vec![1.0, 2.0]));
        let th = teacher.content_hash();

        let d = DistillCfg::default();
        let k1 = distill_key(&m, &d, th);
        let k2 = distill_key(&m, &d, th);
        assert_eq!(k1, k2, "same inputs must key identically");

        // any config field moves the key; `par` does not
        let mut d2 = d.clone();
        d2.steps += 1;
        assert_ne!(distill_key(&m, &d2, th), k1);
        let mut d3 = d.clone();
        d3.par = crate::exec::Parallelism::new(7);
        assert_eq!(distill_key(&m, &d3, th), k1);
        // ... and neither does dispatch fusion (DESIGN.md §14)
        let mut d4 = d.clone();
        d4.steps_per_dispatch = 8;
        assert_eq!(distill_key(&m, &d4, th), k1);

        // upstream content moves the key
        let mut teacher2 = Store::new();
        teacher2.insert("w", Tensor::from_f32(&[2], vec![1.0, 2.5]));
        assert_ne!(distill_key(&m, &d, teacher2.content_hash()), k1);

        // the synthesis engine is a key field: switching engines misses,
        // switching back re-derives the exact original key (pure hit)
        let mut dz = d.clone();
        dz.engine = crate::synthesis::Engine::Zeroq;
        assert_ne!(distill_key(&m, &dz, th), k1);
        let mut dq = d.clone();
        dq.engine = crate::synthesis::Engine::Zaq;
        assert_ne!(distill_key(&m, &dq, th), k1);
        assert_ne!(distill_key(&m, &dz, th), distill_key(&m, &dq, th));
        dz.engine = crate::synthesis::Engine::Genie;
        assert_eq!(distill_key(&m, &dz, th), k1);

        // different stage kinds never collide on the same fields
        let p = PretrainCfg::default();
        assert_ne!(pretrain_key(&m, &p).0, k1.0);
    }

    #[test]
    fn quantize_key_tracks_calib_content_and_plan() {
        use crate::precision::{Granularity, LayerPlan, PrecisionPlan};
        let m = toy_manifest();
        let th = Store::new().content_hash();
        let q = QuantCfg::default();
        let a = Tensor::from_f32(&[2, 2], vec![1.0, 2.0, 3.0, 4.0]);
        let b = Tensor::from_f32(&[2, 2], vec![1.0, 2.0, 3.0, 5.0]);
        let plan = PrecisionPlan {
            layers: vec![LayerPlan {
                name: "stem".into(),
                wbits: 4,
                abits: 4,
                granularity: Granularity::PerChannel,
            }],
        };
        let ka = quantize_key(&m, &q, th, &a, &plan);
        assert_eq!(ka, quantize_key(&m, &q, th, &a, &plan));
        assert_ne!(ka, quantize_key(&m, &q, th, &b, &plan));

        // only the plan changes -> the qstate artifact must miss
        let mut p2 = plan.clone();
        p2.layers[0].wbits = 2;
        assert_ne!(ka, quantize_key(&m, &q, th, &a, &p2));
        let mut p3 = plan.clone();
        p3.layers[0].granularity = Granularity::PerTensor;
        assert_ne!(ka, quantize_key(&m, &q, th, &a, &p3));

        // non-plan quant config fields still move the key
        let kq = {
            let mut q2 = q.clone();
            q2.steps_per_block += 1;
            quantize_key(&m, &q2, th, &a, &plan)
        };
        assert_ne!(ka, kq);
    }

    #[test]
    fn plan_key_tracks_policy_knobs() {
        use crate::precision::{Policy, PrecisionCfg};
        let m = toy_manifest();
        let th = Store::new().content_hash();
        let a = Tensor::from_f32(&[2, 2], vec![1.0, 2.0, 3.0, 4.0]);
        let q = QuantCfg {
            precision: PrecisionCfg {
                policy: Policy::Pareto,
                ..Default::default()
            },
            ..Default::default()
        };
        let k1 = plan_key(&m, &q, th, &a);
        assert_eq!(k1, plan_key(&m, &q, th, &a));
        // the uniform base width never shapes a Pareto plan, so it must
        // not invalidate the plan artifact
        let mut qw = q.clone();
        qw.wbits = 5;
        assert_eq!(k1, plan_key(&m, &qw, th, &a));
        let mut q2 = q.clone();
        q2.precision.target_size = 0.5;
        assert_ne!(k1, plan_key(&m, &q2, th, &a));
        let mut q3 = q.clone();
        q3.precision.candidates = vec![2, 8];
        assert_ne!(k1, plan_key(&m, &q3, th, &a));
        // a plan key never collides with a qstate key on the same fields
        assert_ne!(
            k1,
            quantize_key(&m, &q, th, &a, &crate::precision::PrecisionPlan::default())
        );
    }

    #[test]
    fn steps_per_dispatch_never_moves_any_key() {
        // the whole fused-dispatch contract at the cache layer: K is an
        // execution-shape knob like `workers`, so every content and spec
        // key is invariant in it — a run at K=8 hits artifacts a K=1 run
        // stored, and vice versa
        use crate::precision::{Granularity, LayerPlan, PrecisionPlan};
        let m = toy_manifest();
        let th = Store::new().content_hash();
        let calib = Tensor::from_f32(&[2, 2], vec![1.0, 2.0, 3.0, 4.0]);
        let plan = PrecisionPlan {
            layers: vec![LayerPlan {
                name: "stem".into(),
                wbits: 4,
                abits: 4,
                granularity: Granularity::PerChannel,
            }],
        };

        let p1 = PretrainCfg::default();
        let mut p8 = p1.clone();
        p8.steps_per_dispatch = 8;
        assert_eq!(pretrain_key(&m, &p1), pretrain_key(&m, &p8));

        let d1 = DistillCfg::default();
        let mut d8 = d1.clone();
        d8.steps_per_dispatch = 8;
        assert_eq!(distill_key(&m, &d1, th), distill_key(&m, &d8, th));
        let ts = pretrain_key(&m, &p1);
        assert_eq!(
            distill_spec_key(&m, &d1, ts),
            distill_spec_key(&m, &d8, ts)
        );

        let q1 = QuantCfg::default();
        let mut q8 = q1.clone();
        q8.steps_per_dispatch = 8;
        assert_eq!(
            quantize_key(&m, &q1, th, &calib, &plan),
            quantize_key(&m, &q8, th, &calib, &plan)
        );
        let ds = distill_spec_key(&m, &d1, ts);
        assert_eq!(
            quantize_spec_key(&m, &q1, ts, ds),
            quantize_spec_key(&m, &q8, ts, ds)
        );
        assert_eq!(plan_key(&m, &q1, th, &calib), plan_key(&m, &q8, th, &calib));
    }

    #[test]
    fn cache_store_load_counts_and_clears_wip() {
        let dir = std::env::temp_dir().join("genie_artifact_cache_test");
        std::fs::remove_dir_all(&dir).ok();
        let mut cache = ArtifactCache::open(&dir, true, false).unwrap();
        let key = KeyBuilder::new("test").field("x", 1).finish();

        assert!(cache.load("stage", key).is_none());
        assert_eq!(cache.stats().misses, 1);

        // a wip dir with a shard checkpoint, cleared by the store
        let stage = cache.stage_ckpt("stage", key).unwrap();
        let mut shard = Store::new();
        shard.insert("part", Tensor::scalar_f32(1.0));
        stage.write_done("shard0", &shard).unwrap();
        assert!(cache.wip_dir("stage", key).exists());

        let mut art = Store::new();
        art.insert("images", Tensor::zeros(&[2, 3]));
        let p = cache.store("stage", key, &art).unwrap().unwrap();
        assert!(p.exists());
        assert!(!cache.wip_dir("stage", key).exists(), "wip must clear");

        let back = cache.load("stage", key).unwrap();
        assert_eq!(back.get("images").unwrap().shape, vec![2, 3]);
        assert_eq!(cache.stats().hits, 1);
        assert_eq!(cache.stats().stores, 1);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn disabled_cache_is_inert() {
        let mut cache = ArtifactCache::disabled();
        let key = KeyBuilder::new("test").finish();
        assert!(!cache.is_enabled());
        assert!(cache.load("stage", key).is_none());
        let art = Store::new();
        assert!(cache.store("stage", key, &art).unwrap().is_none());
        assert!(cache.stage_ckpt("stage", key).is_none());
        assert_eq!(cache.stats().misses, 1);
        assert_eq!(cache.stats().stores, 0);
    }

    #[test]
    fn spec_keys_dedupe_on_config_not_content() {
        let m = toy_manifest();
        let p = PretrainCfg::default();
        let ts = pretrain_key(&m, &p);

        let d = DistillCfg::default();
        let k1 = distill_spec_key(&m, &d, ts);
        assert_eq!(k1, distill_spec_key(&m, &d, ts), "spec keys are stable");
        let mut d2 = d.clone();
        d2.seed += 1;
        assert_ne!(distill_spec_key(&m, &d2, ts), k1);
        // a different synthesis engine is a different distill stage
        let mut dz = d.clone();
        dz.engine = crate::synthesis::Engine::Zeroq;
        assert_ne!(distill_spec_key(&m, &dz, ts), k1);
        // a different upstream teacher spec separates downstream specs
        let mut p2 = p.clone();
        p2.steps += 1;
        let ts2 = pretrain_key(&m, &p2);
        assert_ne!(distill_spec_key(&m, &d, ts2), k1);
        // spec keys never collide with content keys on the same fields
        assert_ne!(k1, distill_key(&m, &d, ts.0));

        let q = QuantCfg::default();
        let qs = quantize_spec_key(&m, &q, ts, k1);
        assert_eq!(qs, quantize_spec_key(&m, &q, ts, k1));
        // base bits shape the (unresolved) plan, so they move the spec
        let mut qw = q.clone();
        qw.wbits = 2;
        assert_ne!(quantize_spec_key(&m, &qw, ts, k1), qs);
        // a different calibration source is a different quantize stage
        let real = real_calib_spec_key(128, q.seed ^ 0x5eed);
        assert_ne!(quantize_spec_key(&m, &q, ts, real), qs);
        assert_ne!(real_calib_spec_key(64, 1), real_calib_spec_key(128, 1));

        // eval specs: fp dedupes on the teacher, q on the qstate
        assert_eq!(eval_fp_spec_key(&m, ts), eval_fp_spec_key(&m, ts));
        assert_ne!(eval_fp_spec_key(&m, ts), eval_fp_spec_key(&m, ts2));
        assert_ne!(eval_q_spec_key(&m, qs), eval_fp_spec_key(&m, ts));
    }

    #[test]
    fn claim_serializes_concurrent_materialization() {
        let dir = std::env::temp_dir().join("genie_artifact_claim_test");
        std::fs::remove_dir_all(&dir).ok();
        let cache = ArtifactCache::open(&dir, true, false).unwrap();
        let key = KeyBuilder::new("test").field("x", 1).finish();

        let first = cache.claim("stage", key).unwrap();
        assert!(cache.lock_path("stage", key).exists());

        // a second claimant blocks until the first drops
        let t0 = std::time::Instant::now();
        let handle = {
            let dir = dir.clone();
            std::thread::spawn(move || {
                let cache = ArtifactCache::open(&dir, true, false).unwrap();
                let c = cache.claim("stage", key).unwrap();
                let waited = t0.elapsed();
                drop(c);
                waited
            })
        };
        std::thread::sleep(std::time::Duration::from_millis(120));
        drop(first);
        let waited = handle.join().unwrap();
        assert!(
            waited.as_millis() >= 100,
            "second claim should have blocked, waited {waited:?}"
        );
        assert!(!cache.lock_path("stage", key).exists(), "lock released");
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn stale_claim_is_broken() {
        let dir = std::env::temp_dir().join("genie_artifact_stale_claim_test");
        std::fs::remove_dir_all(&dir).ok();
        let mut cache = ArtifactCache::open(&dir, true, false).unwrap();
        let key = KeyBuilder::new("test").finish();
        // a lockfile left by a "crashed" claimant (no WipClaim alive)
        std::fs::write(cache.lock_path("stage", key), b"").unwrap();
        cache.set_claim_stale_secs(0);
        let c = cache.claim("stage", key).unwrap();
        drop(c);
        assert!(!cache.lock_path("stage", key).exists());
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn dead_holders_lock_is_taken_over_and_waiter_hits() {
        // crash simulation: a claimant "dies" holding the lock (the
        // lockfile exists, nobody will ever release it) *after* the
        // artifact landed. Waiters must break the stale lock via the
        // rename path and wake to a coherent cache hit — exactly one
        // takeover, no deleted live locks, no corrupted artifact.
        let dir = std::env::temp_dir().join("genie_artifact_crash_sim");
        std::fs::remove_dir_all(&dir).ok();
        let mut cache = ArtifactCache::open(&dir, true, false).unwrap();
        let key = KeyBuilder::new("test").field("x", 9).finish();
        let mut art = Store::new();
        art.insert("images", Tensor::from_f32(&[2, 2], vec![1., 2., 3., 4.]));
        cache.store("stage", key, &art).unwrap();
        // the dead holder's lock: a token no live WipClaim carries
        std::fs::write(cache.lock_path("stage", key), b"dead:0").unwrap();

        let waiters: Vec<_> = (0..2)
            .map(|_| {
                let dir = dir.clone();
                std::thread::spawn(move || {
                    let mut c =
                        ArtifactCache::open(&dir, true, false).unwrap();
                    c.set_claim_stale_secs(0);
                    let claim = c.claim("stage", key).unwrap();
                    let got = c.load("stage", key);
                    drop(claim);
                    (got, c.stats().hits)
                })
            })
            .collect();
        for w in waiters {
            let (got, hits) = w.join().unwrap();
            let got = got.expect("waiter must wake to a cache hit");
            assert_eq!(
                got.get("images").unwrap(),
                art.get("images").unwrap(),
                "takeover must surface the intact artifact"
            );
            assert_eq!(hits, 1);
        }
        // every claim released; the dead holder's lock is gone, not
        // resurrected
        assert!(!cache.lock_path("stage", key).exists());
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn released_claim_never_removes_a_foreign_lock() {
        let dir = std::env::temp_dir().join("genie_artifact_foreign_lock");
        std::fs::remove_dir_all(&dir).ok();
        let cache = ArtifactCache::open(&dir, true, false).unwrap();
        let key = KeyBuilder::new("test").finish();
        let mine = cache.claim("stage", key).unwrap();
        // simulate a stale-break + takeover by another claimant: the
        // lockfile now carries someone else's token
        std::fs::write(cache.lock_path("stage", key), b"other:0").unwrap();
        drop(mine);
        assert!(
            cache.lock_path("stage", key).exists(),
            "drop must not delete a successor's live lock"
        );
        std::fs::remove_file(cache.lock_path("stage", key)).unwrap();
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn disabled_cache_claim_is_inert() {
        let cache = ArtifactCache::disabled();
        let key = KeyBuilder::new("test").finish();
        let c = cache.claim("stage", key).unwrap();
        assert!(!cache.lock_path("stage", key).exists());
        drop(c);
    }

    #[test]
    fn corrupt_artifact_is_a_quarantined_miss() {
        let dir = std::env::temp_dir().join("genie_artifact_corrupt_test");
        std::fs::remove_dir_all(&dir).ok();
        let mut cache = ArtifactCache::open(&dir, true, false).unwrap();
        let key = KeyBuilder::new("test").finish();
        std::fs::write(cache.path("stage", key), b"NOPE").unwrap();
        assert!(cache.load("stage", key).is_none());
        assert_eq!(cache.stats().misses, 1);
        assert_eq!(cache.stats().quarantined, 1);
        // the bad file moved aside instead of lingering in the cache
        assert!(!cache.path("stage", key).exists());
        let moved = cache
            .quarantine_dir()
            .join(format!("stage_{}.gts", key.hex()));
        assert_eq!(std::fs::read(moved).unwrap(), b"NOPE");
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn store_writes_hash_sidecar_and_load_verifies_it() {
        let dir = std::env::temp_dir().join("genie_artifact_sidecar_test");
        std::fs::remove_dir_all(&dir).ok();
        let mut cache = ArtifactCache::open(&dir, true, false).unwrap();
        let key = KeyBuilder::new("test").field("x", 3).finish();
        let mut art = Store::new();
        art.insert("images", Tensor::from_f32(&[4], vec![1., 2., 3., 4.]));
        cache.store("stage", key, &art).unwrap();
        let sidecar = cache.sidecar_path("stage", key);
        let want = std::fs::read_to_string(&sidecar).unwrap();
        assert_eq!(want, format!("{:016x}", art.content_hash()));

        // drop the tier-0 copy: this test is about *disk* verification,
        // and a hot hit would legitimately never touch the bytes
        clear_hot(&dir);
        // a flipped byte in the middle of a *parseable* region is caught
        // by the hash (the parse alone might accept it)
        let p = cache.path("stage", key);
        let mut bytes = std::fs::read(&p).unwrap();
        let mid = bytes.len() / 2;
        bytes[mid] ^= 0xff;
        std::fs::write(&p, &bytes).unwrap();
        assert!(cache.load("stage", key).is_none());
        assert_eq!(cache.stats().quarantined, 1);
        assert_eq!(cache.stats().misses, 1);
        assert!(!p.exists() && !sidecar.exists(), "both moved aside");

        // recompute path: the re-store overwrites and the next load is a
        // bit-identical hit
        cache.store("stage", key, &art).unwrap();
        let back = cache.load("stage", key).unwrap();
        assert_eq!(back.content_hash(), art.content_hash());
        assert_eq!(cache.stats().hits, 1);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn hot_tier_parses_a_shared_artifact_once() {
        let dir = std::env::temp_dir().join("genie_artifact_hot_once");
        std::fs::remove_dir_all(&dir).ok();
        let mut cache = ArtifactCache::open(&dir, true, false).unwrap();
        let key = KeyBuilder::new("test").field("x", 5).finish();
        let mut art = Store::new();
        art.insert("images", Tensor::from_f32(&[8], vec![0.5; 8]));
        cache.store("stage", key, &art).unwrap();

        // force process-cold: the first load parses tier 1, every later
        // load (from any cache instance on this dir) clones the Arc
        clear_hot(&dir);
        let a = cache.load("stage", key).unwrap();
        let b = cache.load("stage", key).unwrap();
        assert!(Arc::ptr_eq(&a, &b), "tier 0 must share one handle");
        let mut cache2 = ArtifactCache::open(&dir, true, false).unwrap();
        let c = cache2.load("stage", key).unwrap();
        assert!(Arc::ptr_eq(&a, &c), "instances on one dir share tier 0");
        assert_eq!(
            disk_deser_count(&dir, "stage", key),
            1,
            "exactly one GTS1 parse for three loads"
        );
        assert_eq!(cache.stats().disk_hits, 1);
        assert_eq!(cache.stats().hot_hits, 1);
        assert_eq!(cache.stats().hits, 2);
        assert_eq!(cache2.stats().hot_hits, 1);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn quarantine_releases_the_claim_lockfile() {
        let dir = std::env::temp_dir().join("genie_artifact_quar_claim");
        std::fs::remove_dir_all(&dir).ok();
        let mut cache = ArtifactCache::open(&dir, true, false).unwrap();
        let key = KeyBuilder::new("test").field("x", 6).finish();
        let mut art = Store::new();
        art.insert("images", Tensor::from_f32(&[4], vec![1.; 4]));
        cache.store("stage", key, &art).unwrap();
        clear_hot(&dir);
        // corrupt the artifact on disk, then discover it while a claim
        // is held (the normal claim → load → recompute sequence)
        let p = cache.path("stage", key);
        let mut bytes = std::fs::read(&p).unwrap();
        let mid = bytes.len() / 2;
        bytes[mid] ^= 0xff;
        std::fs::write(&p, &bytes).unwrap();
        let claim = cache.claim("stage", key).unwrap();
        assert!(cache.lock_path("stage", key).exists());
        assert!(cache.load("stage", key).is_none());
        assert_eq!(cache.stats().quarantined, 1);
        assert!(
            !cache.lock_path("stage", key).exists(),
            "quarantine must release the claim so waiters recompute"
        );
        // the superseded claim's drop must not resurrect or remove
        // anything (token check: its file is simply gone)
        drop(claim);
        assert!(!cache.lock_path("stage", key).exists());
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn shared_backend_read_through_and_write_through() {
        let root = std::env::temp_dir().join("genie_artifact_shared");
        std::fs::remove_dir_all(&root).ok();
        let pool = root.join("pool");
        let key = KeyBuilder::new("test").field("x", 7).finish();
        let mut art = Store::new();
        art.insert("images", Tensor::from_f32(&[6], vec![2.; 6]));

        // "machine A" stores: write-through lands the artifact + sidecar
        // in both its local dir and the shared pool
        let mut a =
            ArtifactCache::open(root.join("a"), true, false).unwrap();
        a.attach_shared(&pool).unwrap();
        a.store("stage", key, &art).unwrap();
        assert!(a.path("stage", key).exists());
        let pool_file = pool.join(format!("stage_{}.gts", key.hex()));
        assert!(pool_file.exists(), "write-through to tier 2");
        assert!(pool
            .join(format!("stage_{}.gts.fnv", key.hex()))
            .exists());

        // "machine B" (cold local dir) hits via the pool, and the hit is
        // copied down so its next cold load is local
        let mut b =
            ArtifactCache::open(root.join("b"), true, false).unwrap();
        b.attach_shared(&pool).unwrap();
        let got = b.load("stage", key).unwrap();
        assert_eq!(got.content_hash(), art.content_hash());
        assert_eq!(b.stats().shared_hits, 1);
        assert_eq!(b.stats().hits, 1);
        assert!(b.path("stage", key).exists(), "read-through to tier 1");
        clear_hot(root.join("b"));
        b.load("stage", key).unwrap();
        assert_eq!(b.stats().disk_hits, 1, "second cold load is local");

        // a corrupt *local* copy falls through to the intact pool copy
        let mut c =
            ArtifactCache::open(root.join("c"), true, false).unwrap();
        c.attach_shared(&pool).unwrap();
        std::fs::write(c.path("stage", key), b"NOPE").unwrap();
        let got = c.load("stage", key).unwrap();
        assert_eq!(got.content_hash(), art.content_hash());
        assert_eq!(c.stats().quarantined, 1, "bad local copy quarantined");
        assert_eq!(c.stats().shared_hits, 1, "repaired from tier 2");
        std::fs::remove_dir_all(&root).ok();
    }

    #[test]
    fn hot_budget_bounds_residency() {
        let dir = std::env::temp_dir().join("genie_artifact_hot_budget");
        std::fs::remove_dir_all(&dir).ok();
        let mut cache = ArtifactCache::open(&dir, true, false).unwrap();
        cache.set_hot_bytes(400);
        let mk = |v: f32| {
            let mut s = Store::new();
            s.insert("x", Tensor::from_f32(&[64], vec![v; 64]));
            s
        };
        let k1 = KeyBuilder::new("test").field("i", 1).finish();
        let k2 = KeyBuilder::new("test").field("i", 2).finish();
        cache.store("stage", k1, &mk(1.0)).unwrap();
        cache.store("stage", k2, &mk(2.0)).unwrap();
        assert!(
            cache.stats().hot_evictions >= 1,
            "two ~300 B artifacts cannot both fit a 400 B hot budget: {:?}",
            cache.stats()
        );
        // evicted entries are still served — from disk
        assert!(cache.load("stage", k1).is_some());
        assert!(cache.load("stage", k2).is_some());
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn peek_counts_nothing_but_warms_tier0() {
        let dir = std::env::temp_dir().join("genie_artifact_peek");
        std::fs::remove_dir_all(&dir).ok();
        let mut cache = ArtifactCache::open(&dir, true, false).unwrap();
        let key = KeyBuilder::new("test").field("x", 8).finish();
        let mut art = Store::new();
        art.insert("images", Tensor::from_f32(&[4], vec![3.; 4]));
        cache.store("stage", key, &art).unwrap();
        clear_hot(&dir);
        assert!(cache.peek("stage", key).is_some());
        assert!(cache.contains("stage", key));
        assert_eq!(cache.stats().hits, 0, "peek is stats-silent");
        assert_eq!(cache.stats().misses, 0);
        cache.load("stage", key).unwrap();
        assert_eq!(cache.stats().hot_hits, 1, "peek warmed tier 0");
        let missing = KeyBuilder::new("test").field("x", 9999).finish();
        assert!(cache.peek("stage", missing).is_none());
        assert!(!cache.contains("stage", missing));
        assert_eq!(cache.stats().misses, 0);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn incoherent_artifact_is_a_checked_miss() {
        let dir = std::env::temp_dir().join("genie_artifact_checked_test");
        std::fs::remove_dir_all(&dir).ok();
        let mut cache = ArtifactCache::open(&dir, true, false).unwrap();
        let key = KeyBuilder::new("test").finish();
        // parses fine, but the piece the stage decodes is missing
        let mut partial = Store::new();
        partial.insert("final_loss", Tensor::scalar_f32(0.5));
        cache.store("stage", key, &partial).unwrap();
        let check = |a: &Store| a.get("images").is_ok();
        assert!(cache.load_checked("stage", key, check).is_none());
        assert_eq!(cache.stats().misses, 1);
        // rewriting it coherently turns the same lookup into a hit
        let mut full = partial.clone();
        full.insert("images", Tensor::from_f32(&[2], vec![1.0, 2.0]));
        cache.store("stage", key, &full).unwrap();
        assert!(cache.load_checked("stage", key, check).is_some());
        assert_eq!(cache.stats().hits, 1);
        std::fs::remove_dir_all(&dir).ok();
    }
}

//! Budgeted, pin-aware garbage collection for the disk tiers
//! (DESIGN.md §16). The cache dir grows without bound as grids sweep
//! configs; GC brings it back under `cache.budget_bytes` by evicting
//! complete artifacts (the `.gts` file and its `.fnv` sidecar together)
//! in least-recently-used order — recency is the newer of the pair's
//! mtimes, and the cache refreshes the sidecar on every disk hit, so
//! mtime order *is* use order without any extra bookkeeping file.
//!
//! **Pinning rule.** An artifact is never evicted while
//!
//!   * its stem is in the caller's pin set — `genie cache gc` pins the
//!     transitive artifact set a grid's `--dry-run` resolves, so a
//!     budget-squeezed store always keeps what the next grid needs;
//!   * its stem was touched (stored or loaded) by this process — the
//!     *session pin registry* below, which makes the automatic
//!     enforcement at store time safe: a tight budget can never evict an
//!     artifact a concurrently-running stage of the same process is
//!     about to read;
//!   * a live claim lockfile (`wip_<stem>.lock`) exists — another
//!     process is materializing or reading it right now.
//!
//! Eviction removes the `.gts` before the sidecar: a concurrent reader
//! either wins the read (and verifies against the still-present
//! sidecar) or sees an ordinary cold miss — never a half-evicted entry
//! that parses-but-mismatches.

use std::collections::HashSet;
use std::sync::{Mutex, MutexGuard, OnceLock};
use std::time::SystemTime;

use super::backend::Backend;
use super::hot;

/// What one GC pass did (printed by `genie cache gc`, folded into
/// [`super::CacheStats::gc_evictions`] by automatic enforcement).
#[derive(Debug, Default, Clone)]
pub struct GcReport {
    /// Complete artifacts found (gts + sidecar pairs).
    pub scanned: usize,
    /// Artifacts kept because of a pin, session touch, or live lock.
    pub pinned: usize,
    /// Artifacts evicted.
    pub evicted: usize,
    /// Bytes reclaimed (artifact + sidecar).
    pub evicted_bytes: u64,
    /// Artifact bytes remaining after the pass.
    pub live_bytes: u64,
}

// ---- session pin registry ------------------------------------------

fn pins() -> MutexGuard<'static, HashSet<(String, String)>> {
    static PINS: OnceLock<Mutex<HashSet<(String, String)>>> =
        OnceLock::new();
    PINS.get_or_init(|| Mutex::new(HashSet::new()))
        .lock()
        .unwrap_or_else(|p| p.into_inner())
}

/// Mark `(ns, stem)` as touched by this process — pinned for every
/// automatic GC pass of the session.
pub(crate) fn pin_session(ns: &str, stem: &str) {
    pins().insert((ns.to_string(), stem.to_string()));
}

/// Every stem this process has touched under namespace `ns`.
pub(crate) fn session_pins(ns: &str) -> HashSet<String> {
    pins()
        .iter()
        .filter(|(n, _)| n == ns)
        .map(|(_, s)| s.clone())
        .collect()
}

/// Forget the session pins of one namespace (tests/benches that
/// deliberately re-cold a directory).
pub(crate) fn clear_session_pins(ns: &str) {
    pins().retain(|(n, _)| n != ns);
}

// ---- the GC pass ----------------------------------------------------

struct Candidate {
    stem: String,
    bytes: u64,
    recency: SystemTime,
    has_sidecar: bool,
}

/// One GC pass over `backend`: evict unpinned artifacts, oldest use
/// first, until the artifact bytes fit `budget_bytes` (0 = report-only,
/// nothing evicted). `ns` is the hot-tier namespace to invalidate;
/// `extra_pins` are the caller's stems on top of the session registry
/// and live locks.
pub fn collect(
    backend: &dyn Backend,
    ns: &str,
    budget_bytes: u64,
    extra_pins: &HashSet<String>,
) -> GcReport {
    let files = backend.list();
    let locked: HashSet<String> = files
        .iter()
        .filter_map(|e| {
            e.name
                .strip_prefix("wip_")?
                .strip_suffix(".lock")
                .map(str::to_string)
        })
        .collect();
    let session = session_pins(ns);

    let mut cands: Vec<Candidate> = Vec::new();
    let mut total = 0u64;
    for e in &files {
        let Some(stem) = e.name.strip_suffix(".gts") else { continue };
        let mut bytes = e.bytes;
        let mut recency = e.mtime;
        let sidecar =
            files.iter().find(|f| f.name == format!("{}.fnv", e.name));
        if let Some(sc) = sidecar {
            bytes += sc.bytes;
            if sc.mtime > recency {
                recency = sc.mtime;
            }
        }
        total += bytes;
        cands.push(Candidate {
            stem: stem.to_string(),
            bytes,
            recency,
            has_sidecar: sidecar.is_some(),
        });
    }

    let mut report = GcReport {
        scanned: cands.len(),
        live_bytes: total,
        ..Default::default()
    };
    let pinned = |stem: &String| {
        extra_pins.contains(stem)
            || session.contains(stem)
            || locked.contains(stem)
    };
    report.pinned = cands.iter().filter(|c| pinned(&c.stem)).count();
    if budget_bytes == 0 || total <= budget_bytes {
        return report;
    }

    // oldest use first; stem as the tie-break so a pass is deterministic
    // on filesystems with coarse mtime granularity
    cands.sort_by(|a, b| {
        a.recency.cmp(&b.recency).then_with(|| a.stem.cmp(&b.stem))
    });
    for c in &cands {
        if report.live_bytes <= budget_bytes {
            break;
        }
        if pinned(&c.stem) {
            continue;
        }
        // artifact first, sidecar second: a racing reader sees a cold
        // miss or a complete verifiable pair, never the reverse half
        if !backend.remove(&format!("{}.gts", c.stem)) {
            continue;
        }
        if c.has_sidecar {
            backend.remove(&format!("{}.gts.fnv", c.stem));
        }
        hot::remove(ns, &c.stem);
        report.evicted += 1;
        report.evicted_bytes += c.bytes;
        report.live_bytes -= c.bytes;
    }
    report
}

#[cfg(test)]
mod tests {
    use super::super::{ArtifactCache, CacheKey, KeyBuilder};
    use super::*;
    use crate::store::Store;
    use crate::tensor::{Pcg32, Tensor};

    fn key_of(i: u64) -> CacheKey {
        KeyBuilder::new("gc").field("i", i).finish()
    }

    fn art_of(rng: &mut Pcg32, len: usize) -> Store {
        let mut s = Store::new();
        s.insert("x", Tensor::randn(&[len], rng, 1.0));
        s
    }

    /// Satellite contract: fill past budget, GC with a pinned "grid"
    /// set, and check (a) every pinned key still hits tier 1
    /// bit-identically, (b) evicted keys recompute bit-identically,
    /// (c) no stem is ever half-evicted, and (d) a concurrently-claimed
    /// stem survives untouched.
    #[test]
    fn gc_property_pins_survive_evictions_recompute() {
        for seed in [3u64, 17, 40, 99] {
            let dir = std::env::temp_dir()
                .join(format!("genie_gc_prop_{seed}"));
            std::fs::remove_dir_all(&dir).ok();
            let mut cache = ArtifactCache::open(&dir, true, false).unwrap();
            let ns = cache.hot_namespace().to_string();
            let mut rng = Pcg32::new(seed);

            let n = 6 + (rng.next_u32() % 5) as u64;
            let mut originals = Vec::new();
            let mut total = 0u64;
            for i in 0..n {
                let len = 64 + (rng.next_u32() % 512) as usize;
                let art = art_of(&mut rng, len);
                cache.store("gc", key_of(i), &art).unwrap();
                total += std::fs::metadata(cache.path("gc", key_of(i)))
                    .unwrap()
                    .len();
                originals.push(art);
            }

            // a pinned "grid transitive set": every even key (half the
            // store, and deterministically never all of it)
            let pinned: HashSet<String> = (0..n)
                .filter(|i| i % 2 == 0)
                .map(|i| format!("gc_{}", key_of(i).hex()))
                .collect();
            // one unpinned key held by a live claim during the pass
            let claimed = (0..n).find(|i| {
                !pinned.contains(&format!("gc_{}", key_of(*i).hex()))
            });
            let _claim =
                claimed.map(|i| cache.claim("gc", key_of(i)).unwrap());

            // the session registry pinned everything this process
            // stored — drop it so the pass exercises real eviction
            clear_session_pins(&ns);
            let budget = total / 3;
            let report =
                collect(cache.local_backend(), &ns, budget, &pinned);
            assert_eq!(report.scanned as u64, n);
            assert!(
                report.evicted > 0,
                "seed {seed}: past-budget store must evict something"
            );

            // (c) never half-evicted: a sidecar implies its artifact
            for e in cache.local_backend().list() {
                if let Some(stem) = e.name.strip_suffix(".gts.fnv") {
                    assert!(
                        dir.join(format!("{stem}.gts")).exists(),
                        "seed {seed}: orphan sidecar {}",
                        e.name
                    );
                }
            }

            // (a) pinned + claimed keys still hit tier 1 bit-identically
            super::super::clear_hot(&dir);
            for i in 0..n {
                let stem = format!("gc_{}", key_of(i).hex());
                let keep =
                    pinned.contains(&stem) || claimed == Some(i);
                let got = cache.load("gc", key_of(i));
                if keep {
                    let got = got.unwrap_or_else(|| {
                        panic!("seed {seed}: pinned {stem} evicted")
                    });
                    assert_eq!(
                        got.content_hash(),
                        originals[i as usize].content_hash()
                    );
                } else if let Some(got) = got {
                    // unpinned survivor (under budget before its turn):
                    // must still be intact
                    assert_eq!(
                        got.content_hash(),
                        originals[i as usize].content_hash()
                    );
                }
            }

            // (b) evicted keys recompute + re-store bit-identically
            super::super::clear_hot(&dir);
            for i in 0..n {
                if cache.load("gc", key_of(i)).is_none() {
                    cache.store("gc", key_of(i), &originals[i as usize])
                        .unwrap();
                    let back = cache.load("gc", key_of(i)).unwrap();
                    assert_eq!(
                        back.content_hash(),
                        originals[i as usize].content_hash()
                    );
                }
            }
            std::fs::remove_dir_all(&dir).ok();
        }
    }

    #[test]
    fn report_only_when_unbudgeted_or_within() {
        let dir = std::env::temp_dir().join("genie_gc_report_only");
        std::fs::remove_dir_all(&dir).ok();
        let mut cache = ArtifactCache::open(&dir, true, false).unwrap();
        let ns = cache.hot_namespace().to_string();
        let mut rng = Pcg32::new(7);
        cache.store("gc", key_of(0), &art_of(&mut rng, 64)).unwrap();
        clear_session_pins(&ns);
        let none = HashSet::new();
        let r = collect(cache.local_backend(), &ns, 0, &none);
        assert_eq!(r.evicted, 0, "budget 0 reports, never evicts");
        assert_eq!(r.scanned, 1);
        let r = collect(cache.local_backend(), &ns, u64::MAX, &none);
        assert_eq!(r.evicted, 0, "within budget evicts nothing");
        assert!(r.live_bytes > 0);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn session_registry_pins_this_processes_artifacts() {
        let dir = std::env::temp_dir().join("genie_gc_session_pins");
        std::fs::remove_dir_all(&dir).ok();
        let mut cache = ArtifactCache::open(&dir, true, false).unwrap();
        let ns = cache.hot_namespace().to_string();
        let mut rng = Pcg32::new(11);
        cache.store("gc", key_of(0), &art_of(&mut rng, 256)).unwrap();
        // stored ⇒ session-pinned ⇒ a 1-byte budget cannot evict it
        let r = collect(cache.local_backend(), &ns, 1, &HashSet::new());
        assert_eq!(r.evicted, 0);
        assert_eq!(r.pinned, 1);
        assert!(cache.path("gc", key_of(0)).exists());
        clear_session_pins(&ns);
        let r = collect(cache.local_backend(), &ns, 1, &HashSet::new());
        assert_eq!(r.evicted, 1, "unpinned it *is* evictable");
        std::fs::remove_dir_all(&dir).ok();
    }
}

//! GTS1 named-tensor binary format (rust mirror of
//! python/compile/tensorstore.py) plus the in-memory named store the
//! coordinator threads through every entrypoint call.
//!
//! Tensors are held behind `Arc`, so cloning a store (one per distill
//! shard / eval chunk / quant block) shares the immutable teacher state
//! instead of deep-copying it. Mutation only ever happens by `insert`ing
//! a replacement tensor, which swaps this store's `Arc` and leaves every
//! other clone untouched — copy-on-write at tensor granularity
//! (DESIGN.md §8).

use std::collections::HashMap;
use std::io::{Read, Write};
use std::path::Path;
use std::sync::Arc;

use anyhow::{bail, Context, Result};

use crate::tensor::{Data, DType, Tensor};

const MAGIC: &[u8; 4] = b"GTS1";

/// Ordered named tensors + O(1) lookup; the argument/result hub for
/// every AOT entrypoint call (wired by manifest names). `Clone` is cheap:
/// it copies names and `Arc` handles, never tensor data.
#[derive(Debug, Default, Clone)]
pub struct Store {
    names: Vec<String>,
    map: HashMap<String, Arc<Tensor>>,
}

impl Store {
    pub fn new() -> Self {
        Store::default()
    }

    pub fn insert(&mut self, name: &str, t: Tensor) {
        self.insert_shared(name, Arc::new(t));
    }

    /// Insert an already-shared tensor without copying its data. The
    /// handle may be aliased by other stores; replacing a name in one
    /// store never mutates through the `Arc`, so sharing is safe.
    pub fn insert_shared(&mut self, name: &str, t: Arc<Tensor>) {
        if !self.map.contains_key(name) {
            self.names.push(name.to_string());
        }
        self.map.insert(name.to_string(), t);
    }

    pub fn get(&self, name: &str) -> Result<&Tensor> {
        self.map
            .get(name)
            .map(|a| a.as_ref())
            .ok_or_else(|| anyhow::anyhow!("store: missing tensor '{name}'"))
    }

    /// The shared handle for a tensor — lets callers propagate a tensor
    /// into another store (or keep it alive) without a deep copy.
    pub fn get_shared(&self, name: &str) -> Result<Arc<Tensor>> {
        self.map
            .get(name)
            .cloned()
            .ok_or_else(|| anyhow::anyhow!("store: missing tensor '{name}'"))
    }

    pub fn contains(&self, name: &str) -> bool {
        self.map.contains_key(name)
    }

    pub fn names(&self) -> &[String] {
        &self.names
    }

    pub fn len(&self) -> usize {
        self.names.len()
    }

    pub fn is_empty(&self) -> bool {
        self.names.is_empty()
    }

    /// Merge all tensors of `other` into self (overwriting). Shares the
    /// `Arc` handles — no tensor data is copied.
    pub fn absorb(&mut self, other: &Store) {
        for n in &other.names {
            self.insert_shared(n, other.map[n].clone());
        }
    }

    pub fn save(&self, path: impl AsRef<Path>) -> Result<()> {
        let mut f = std::fs::File::create(path.as_ref())
            .context("create tensorstore file")?;
        self.write_to(&mut f)
    }

    /// Serialize to the GTS1 byte stream (the exact bytes `save` writes).
    pub fn to_bytes(&self) -> Result<Vec<u8>> {
        let mut buf = Vec::new();
        self.write_to(&mut buf)?;
        Ok(buf)
    }

    /// Stable content address: FNV-1a 64 over the GTS1 byte stream, so
    /// two stores hash equal iff they serialize identically (same names
    /// in the same order, same dtypes/shapes/bytes). Never std's SipHash,
    /// whose keys are process-random — cache keys must survive restarts.
    pub fn content_hash(&self) -> u64 {
        let mut w = FnvWriter::default();
        self.write_to(&mut w).expect("hashing writer cannot fail");
        w.hash
    }

    /// Single-pass serialize-and-hash: the GTS1 byte stream plus its
    /// FNV-1a 64 content hash from one `write_to` walk, so the artifact
    /// cache can emit the `.fnv` sidecar without re-serializing (or
    /// re-reading) the bytes it just wrote (DESIGN.md §16).
    pub fn to_bytes_hashed(&self) -> Result<(Vec<u8>, u64)> {
        let mut w = HashingBuf { buf: Vec::new(), hash: FNV_OFFSET };
        self.write_to(&mut w)?;
        Ok((w.buf, w.hash))
    }

    /// Write the GTS1 stream (magic, count, then per-tensor name/dtype/
    /// shape/bytes records) — shared by `save`, `to_bytes` and
    /// `content_hash`.
    pub fn write_to(&self, f: &mut impl Write) -> Result<()> {
        f.write_all(MAGIC)?;
        f.write_all(&(self.names.len() as u32).to_le_bytes())?;
        for name in &self.names {
            let t = &self.map[name];
            let nb = name.as_bytes();
            f.write_all(&(nb.len() as u16).to_le_bytes())?;
            f.write_all(nb)?;
            let (code, raw): (u8, Vec<u8>) = match &t.data {
                Data::F32(v) => (0, v.iter().flat_map(|x| x.to_le_bytes()).collect()),
                Data::I32(v) => (1, v.iter().flat_map(|x| x.to_le_bytes()).collect()),
                Data::U32(v) => (2, v.iter().flat_map(|x| x.to_le_bytes()).collect()),
            };
            f.write_all(&[code, t.shape.len() as u8])?;
            for &d in &t.shape {
                f.write_all(&(d as u32).to_le_bytes())?;
            }
            f.write_all(&(raw.len() as u64).to_le_bytes())?;
            f.write_all(&raw)?;
        }
        Ok(())
    }

    pub fn load(path: impl AsRef<Path>) -> Result<Store> {
        let bytes = std::fs::read(path.as_ref())
            .with_context(|| format!("read {:?}", path.as_ref()))?;
        Self::from_bytes(&bytes)
    }

    pub fn from_bytes(bytes: &[u8]) -> Result<Store> {
        let mut cur = std::io::Cursor::new(bytes);
        let mut magic = [0u8; 4];
        cur.read_exact(&mut magic)?;
        if &magic != MAGIC {
            bail!("bad GTS1 magic");
        }
        let count = read_u32(&mut cur)? as usize;
        let mut store = Store::new();
        for _ in 0..count {
            let nlen = read_u16(&mut cur)? as usize;
            let mut nb = vec![0u8; nlen];
            cur.read_exact(&mut nb)?;
            let name = String::from_utf8(nb)?;
            let mut hdr = [0u8; 2];
            cur.read_exact(&mut hdr)?;
            let (code, ndim) = (hdr[0], hdr[1] as usize);
            let mut shape = Vec::with_capacity(ndim);
            for _ in 0..ndim {
                shape.push(read_u32(&mut cur)? as usize);
            }
            let nbytes = read_u64(&mut cur)? as usize;
            let mut raw = vec![0u8; nbytes];
            cur.read_exact(&mut raw)?;
            let data = match code {
                0 => Data::F32(raw.chunks_exact(4).map(|c| f32::from_le_bytes(c.try_into().unwrap())).collect()),
                1 => Data::I32(raw.chunks_exact(4).map(|c| i32::from_le_bytes(c.try_into().unwrap())).collect()),
                2 => Data::U32(raw.chunks_exact(4).map(|c| u32::from_le_bytes(c.try_into().unwrap())).collect()),
                other => bail!("unknown dtype code {other}"),
            };
            let t = Tensor { shape, data };
            anyhow::ensure!(
                t.numel() * 4 == nbytes,
                "tensor {name}: shape/bytes mismatch"
            );
            store.insert(&name, t);
        }
        Ok(store)
    }
}

/// FNV-1a 64 offset basis — the seed for [`fnv1a`] chains.
pub const FNV_OFFSET: u64 = 0xcbf29ce484222325;

/// One FNV-1a 64 absorption step: fold `bytes` into a running hash `h`
/// (start chains from [`FNV_OFFSET`]). Deterministic across processes and
/// platforms — the primitive under every artifact cache key.
pub fn fnv1a(h: u64, bytes: &[u8]) -> u64 {
    let mut h = h;
    for &b in bytes {
        h ^= b as u64;
        h = h.wrapping_mul(0x100000001b3);
    }
    h
}

/// A `Write` sink that FNV-hashes everything written through it — lets
/// `content_hash` reuse the exact `save` serialization without buffering.
#[derive(Debug)]
struct FnvWriter {
    hash: u64,
}

impl Default for FnvWriter {
    fn default() -> Self {
        FnvWriter { hash: FNV_OFFSET }
    }
}

impl Write for FnvWriter {
    fn write(&mut self, buf: &[u8]) -> std::io::Result<usize> {
        self.hash = fnv1a(self.hash, buf);
        Ok(buf.len())
    }

    fn flush(&mut self) -> std::io::Result<()> {
        Ok(())
    }
}

/// A `Write` sink that buffers the stream *and* folds it into a running
/// FNV-1a hash — one serialization walk yields both the artifact bytes
/// and the sidecar hash (`to_bytes_hashed`).
#[derive(Debug)]
struct HashingBuf {
    buf: Vec<u8>,
    hash: u64,
}

impl Write for HashingBuf {
    fn write(&mut self, buf: &[u8]) -> std::io::Result<usize> {
        self.hash = fnv1a(self.hash, buf);
        self.buf.extend_from_slice(buf);
        Ok(buf.len())
    }

    fn flush(&mut self) -> std::io::Result<()> {
        Ok(())
    }
}

fn read_u16(c: &mut impl Read) -> Result<u16> {
    let mut b = [0u8; 2];
    c.read_exact(&mut b)?;
    Ok(u16::from_le_bytes(b))
}

fn read_u32(c: &mut impl Read) -> Result<u32> {
    let mut b = [0u8; 4];
    c.read_exact(&mut b)?;
    Ok(u32::from_le_bytes(b))
}

fn read_u64(c: &mut impl Read) -> Result<u64> {
    let mut b = [0u8; 8];
    c.read_exact(&mut b)?;
    Ok(u64::from_le_bytes(b))
}

/// Expected dtype helper for manifest-driven checks.
pub fn dtype_of(code: &str) -> Result<DType> {
    DType::from_str(code)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_all_dtypes() {
        let dir = std::env::temp_dir().join("genie_store_test.bin");
        let mut s = Store::new();
        s.insert("a", Tensor::from_f32(&[2, 3], vec![1., 2., 3., 4., 5., 6.]));
        s.insert("b.scalar", Tensor::scalar_f32(3.5));
        s.insert("c", Tensor::from_i32(&[3], vec![1, -2, 3]));
        s.insert("d", Tensor::from_u32(&[2], vec![7, 8]));
        s.save(&dir).unwrap();
        let l = Store::load(&dir).unwrap();
        assert_eq!(l.names(), s.names());
        for n in s.names() {
            assert_eq!(l.get(n).unwrap(), s.get(n).unwrap());
        }
    }

    #[test]
    fn bad_magic_rejected() {
        assert!(Store::from_bytes(b"NOPE\x00\x00\x00\x00").is_err());
    }

    #[test]
    fn to_bytes_matches_save_and_roundtrips() {
        let mut s = Store::new();
        s.insert("a", Tensor::from_f32(&[2], vec![1.0, 2.0]));
        s.insert("empty", Tensor::zeros(&[0]));
        let bytes = s.to_bytes().unwrap();
        let path = std::env::temp_dir().join("genie_store_bytes_test.bin");
        s.save(&path).unwrap();
        assert_eq!(bytes, std::fs::read(&path).unwrap());
        let l = Store::from_bytes(&bytes).unwrap();
        assert_eq!(l.names(), s.names());
        assert_eq!(l.get("empty").unwrap().numel(), 0);
    }

    #[test]
    fn content_hash_stable_and_sensitive() {
        let mut a = Store::new();
        a.insert("x", Tensor::from_f32(&[2], vec![1.0, 2.0]));
        a.insert("y", Tensor::scalar_f32(3.0));
        let mut b = Store::new();
        b.insert("x", Tensor::from_f32(&[2], vec![1.0, 2.0]));
        b.insert("y", Tensor::scalar_f32(3.0));
        // equal content hashes equal; hash == hash of the byte stream
        assert_eq!(a.content_hash(), b.content_hash());
        assert_eq!(
            a.content_hash(),
            fnv1a(FNV_OFFSET, &a.to_bytes().unwrap())
        );
        // value, shape and name-order changes all move the hash
        b.insert("y", Tensor::scalar_f32(4.0));
        assert_ne!(a.content_hash(), b.content_hash());
        let mut c = Store::new();
        c.insert("y", Tensor::scalar_f32(3.0));
        c.insert("x", Tensor::from_f32(&[2], vec![1.0, 2.0]));
        assert_ne!(a.content_hash(), c.content_hash());
    }

    #[test]
    fn to_bytes_hashed_matches_two_pass() {
        let mut s = Store::new();
        s.insert("a", Tensor::from_f32(&[2, 2], vec![1.0, 2.0, 3.0, 4.0]));
        s.insert("b", Tensor::from_i32(&[3], vec![-1, 0, 7]));
        let (bytes, hash) = s.to_bytes_hashed().unwrap();
        assert_eq!(bytes, s.to_bytes().unwrap());
        assert_eq!(hash, s.content_hash());
        assert_eq!(hash, fnv1a(FNV_OFFSET, &bytes));
    }

    #[test]
    fn missing_tensor_is_error() {
        let s = Store::new();
        assert!(s.get("nope").is_err());
    }

    #[test]
    fn insert_overwrites_without_duplicating_order() {
        let mut s = Store::new();
        s.insert("x", Tensor::scalar_f32(1.0));
        s.insert("x", Tensor::scalar_f32(2.0));
        assert_eq!(s.len(), 1);
        assert_eq!(s.get("x").unwrap().scalar(), 2.0);
    }

    #[test]
    fn clone_shares_tensors_until_insert() {
        let mut a = Store::new();
        a.insert("w", Tensor::from_f32(&[2], vec![1.0, 2.0]));
        a.insert("frozen", Tensor::from_f32(&[1], vec![5.0]));
        let mut b = a.clone();
        // a clone aliases the same Arc handles (no deep copy) ...
        assert!(Arc::ptr_eq(
            &a.get_shared("w").unwrap(),
            &b.get_shared("w").unwrap()
        ));
        // ... and replacing a tensor in the clone never leaks back
        b.insert("w", Tensor::from_f32(&[2], vec![9.0, 9.0]));
        assert_eq!(a.get("w").unwrap().as_f32(), &[1.0, 2.0]);
        assert_eq!(b.get("w").unwrap().as_f32(), &[9.0, 9.0]);
        assert!(Arc::ptr_eq(
            &a.get_shared("frozen").unwrap(),
            &b.get_shared("frozen").unwrap()
        ));
    }

    #[test]
    fn absorb_shares_not_copies() {
        let mut a = Store::new();
        let mut b = Store::new();
        b.insert("x", Tensor::scalar_f32(2.0));
        a.absorb(&b);
        assert!(Arc::ptr_eq(
            &a.get_shared("x").unwrap(),
            &b.get_shared("x").unwrap()
        ));
    }

    #[test]
    fn absorb_merges() {
        let mut a = Store::new();
        a.insert("x", Tensor::scalar_f32(1.0));
        let mut b = Store::new();
        b.insert("y", Tensor::scalar_f32(2.0));
        b.insert("x", Tensor::scalar_f32(9.0));
        a.absorb(&b);
        assert_eq!(a.get("x").unwrap().scalar(), 9.0);
        assert_eq!(a.get("y").unwrap().scalar(), 2.0);
    }
}

//! Pluggable synthesis engines (DESIGN.md §12): the data half of
//! zero-shot quantization behind a policy trait, mirroring the
//! precision `Policy` design (§10).
//!
//! A [`SynthesisPolicy`] builds the per-shard [`Phase`] that the distill
//! scheduler (`coordinator::distill`) drives through [`StepLoop`] — the
//! scheduler owns sharding, checkpoint/resume and aggregation; the
//! policy owns what one shard optimizes:
//!
//!   * [`Engine::Genie`] — GENIE-D (Alg. 1): generator + learnable
//!     latents, with the `distill.mode` ablation arms (`gba` freezes
//!     latents, `direct` drops the generator) exactly as before the
//!     refactor — byte-identical output, same entrypoints.
//!   * [`Engine::Zeroq`] — ZeroQ-style BN-statistics distribution
//!     matching (Cai et al., 2020): no generator at all; the images are
//!     the parameters, optimized directly against the stored BN µ/σ via
//!     the `distill_direct_*` graphs, whatever `distill.mode` says.
//!   * [`Engine::Zaq`] — ZAQ-style adversarial synthesis (Liu et al.,
//!     2021): generator + latents step to *maximize* the discrepancy
//!     between the FP32 teacher and a fake-quantized student proxy
//!     (the `distill_zaq_*` graphs, W4A4 Min-Max student), regularized
//!     by the BNS term so samples stay on the teacher's manifold.
//!
//! Every engine inherits the determinism contract: shard `b` draws all
//! randomness from `Pcg32::new_stream(seed, b)`, so a synthetic set is
//! bit-identical for any worker count and resumes bit-identically from
//! checkpoints. The engine choice folds into the distill cache keys
//! (`artifacts::distill_key`/`distill_spec_key`), so two engines never
//! collide on an artifact, and a grid can ablate data engines with
//! `--axis synthesis=genie,zeroq,zaq` the way it ablates bits.

use anyhow::Result;

use crate::coordinator::{DistillCfg, DistillMode};
use crate::phase::{checkpoint, Phase};
use crate::runtime::{DeviceStore, ModelRt, Scalars};
use crate::schedule::{ExponentialDecay, ReduceLROnPlateau};
use crate::store::Store;
use crate::tensor::{Pcg32, Tensor};

/// Bit-widths of the ZAQ fake-quant student proxy (fixed: the proxy is
/// a synthesis-time adversary, not the run's quantizer, so it does not
/// track `wbits`/`abits` and does not enter the cache key beyond the
/// engine name).
const ZAQ_PROXY_WBITS: f32 = 4.0;
const ZAQ_PROXY_ABITS: f32 = 4.0;

/// Which synthesis engine produces the calibration set — a config value
/// (`--synthesis`, `distill.engine=`), a grid axis (`--axis synthesis=`)
/// and a cache-key field, like `precision::Policy`.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Engine {
    Genie,
    Zeroq,
    Zaq,
}

impl Engine {
    pub fn parse(s: &str) -> Result<Self> {
        match s {
            "genie" => Ok(Engine::Genie),
            "zeroq" => Ok(Engine::Zeroq),
            "zaq" => Ok(Engine::Zaq),
            other => anyhow::bail!(
                "unknown synthesis engine '{other}' (want genie|zeroq|zaq)"
            ),
        }
    }

    /// Canonical lowercase name (config values, cache-key fields, grid
    /// cell labels).
    pub fn as_str(self) -> &'static str {
        match self {
            Engine::Genie => "genie",
            Engine::Zeroq => "zeroq",
            Engine::Zaq => "zaq",
        }
    }

    /// The policy implementing this engine.
    pub fn policy(self) -> &'static dyn SynthesisPolicy {
        match self {
            Engine::Genie => &GenieEngine,
            Engine::Zeroq => &ZeroqEngine,
            Engine::Zaq => &ZaqEngine,
        }
    }

    /// The name shown in progress lines: the GENIE engine keeps naming
    /// its `distill.mode` arm (genie/gba/direct, as before the policy
    /// refactor); the other engines are their own arm.
    pub fn display(self, mode: DistillMode) -> &'static str {
        match self {
            Engine::Genie => mode.as_str(),
            e => e.as_str(),
        }
    }
}

impl Default for Engine {
    fn default() -> Self {
        Engine::Genie
    }
}

/// One synthesis engine: builds the per-shard optimization [`Phase`]
/// the distill scheduler runs. Implementations must draw randomness
/// only from the `rng` handed in (the shard's `new_stream(seed, b)`),
/// never from ambient state — that is the whole §5 determinism
/// contract.
pub trait SynthesisPolicy: Sync {
    /// Canonical engine name; equals `Engine::as_str`.
    fn name(&self) -> &'static str;

    /// The manifest entrypoint the shard's step loop dispatches
    /// (`tag` is the swing/noswing lowering variant). Lets callers
    /// check availability before spending a shard run.
    fn entry(&self, cfg: &DistillCfg, tag: &str) -> String;

    /// Build shard phase: generator/image state init, per-step scalar
    /// schedules, checkpoint snapshot/restore, final image fetch.
    fn shard<'a>(
        &self,
        mrt: &'a ModelRt<'a>,
        cfg: &DistillCfg,
        tag: &'a str,
        rng: Pcg32,
    ) -> Box<dyn Phase + 'a>;
}

/// GENIE-D (the pre-refactor engine, ported unchanged): `distill.mode`
/// still selects the Alg. 1 generator arm or the direct ablation arm,
/// with identical dispatch, schedules and entrypoints.
pub struct GenieEngine;

impl SynthesisPolicy for GenieEngine {
    fn name(&self) -> &'static str {
        "genie"
    }

    fn entry(&self, cfg: &DistillCfg, tag: &str) -> String {
        match cfg.mode {
            DistillMode::Direct => format!("distill_direct_{tag}"),
            _ => format!("distill_genie_{tag}"),
        }
    }

    fn shard<'a>(
        &self,
        mrt: &'a ModelRt<'a>,
        cfg: &DistillCfg,
        tag: &'a str,
        rng: Pcg32,
    ) -> Box<dyn Phase + 'a> {
        match cfg.mode {
            DistillMode::Direct => {
                Box::new(DirectShard::new(mrt, cfg, tag, rng))
            }
            _ => Box::new(GenieShard::new(mrt, cfg, tag, rng)),
        }
    }
}

/// ZeroQ-style distribution matching: image-space optimization against
/// the stored BN statistics, no generator — the cheapest engine. Reuses
/// the `distill_direct_*` graphs regardless of `distill.mode` (the
/// engine, not the mode, is the arm; the cache keys separate on it).
pub struct ZeroqEngine;

impl SynthesisPolicy for ZeroqEngine {
    fn name(&self) -> &'static str {
        "zeroq"
    }

    fn entry(&self, _cfg: &DistillCfg, tag: &str) -> String {
        format!("distill_direct_{tag}")
    }

    fn shard<'a>(
        &self,
        mrt: &'a ModelRt<'a>,
        cfg: &DistillCfg,
        tag: &'a str,
        rng: Pcg32,
    ) -> Box<dyn Phase + 'a> {
        Box::new(DirectShard::new(mrt, cfg, tag, rng))
    }
}

/// ZAQ-style adversarial synthesis: the generator state machine of
/// GENIE-D (same carried tensors, same schedules) driven through the
/// `distill_zaq_*` graphs, whose loss rewards teacher-vs-student
/// discrepancy instead of pure BNS matching. Latents always learn
/// (the adversary needs every degree of freedom).
pub struct ZaqEngine;

impl SynthesisPolicy for ZaqEngine {
    fn name(&self) -> &'static str {
        "zaq"
    }

    fn entry(&self, _cfg: &DistillCfg, tag: &str) -> String {
        format!("distill_zaq_{tag}")
    }

    fn shard<'a>(
        &self,
        mrt: &'a ModelRt<'a>,
        cfg: &DistillCfg,
        tag: &'a str,
        rng: Pcg32,
    ) -> Box<dyn Phase + 'a> {
        Box::new(ZaqShard::new(mrt, cfg, tag, rng))
    }
}

/// One generator-based shard (GENIE / GBA) as a [`Phase`]: generator
/// params, Adam moments and latents stay device-resident across steps;
/// only `key`/`t`/`lr_*` go up and the loss comes down per step.
struct GenieShard<'a, 'rt> {
    mrt: &'a ModelRt<'rt>,
    tag: &'a str,
    rng: Pcg32,
    gen_sched: ExponentialDecay,
    z_sched: ReduceLROnPlateau,
    lr_z: f32,
    lr_z_active: bool,
}

impl<'a, 'rt> GenieShard<'a, 'rt> {
    fn new(
        mrt: &'a ModelRt<'rt>,
        cfg: &DistillCfg,
        tag: &'a str,
        rng: Pcg32,
    ) -> Self {
        let lr_z_active = cfg.mode == DistillMode::Genie;
        GenieShard {
            mrt,
            tag,
            rng,
            gen_sched: ExponentialDecay::new(cfg.lr_g, 0.95, 100),
            z_sched: ReduceLROnPlateau::new(cfg.lr_z, 0.5, 30),
            lr_z: if lr_z_active { cfg.lr_z } else { 0.0 },
            lr_z_active,
        }
    }
}

impl Phase for GenieShard<'_, '_> {
    fn name(&self) -> String {
        "distill".into()
    }

    fn entry(&self) -> String {
        format!("distill_genie_{}", self.tag)
    }

    fn init(&mut self, dev: &mut DeviceStore) -> Result<()> {
        let m = &self.mrt.manifest;
        let bd = m.batch("distill");
        // fresh generator per batch (appendix A)
        let (kh, kl) = self.rng.key_pair();
        dev.insert("key", &Tensor::key(kh, kl))?;
        self.mrt.call_device("gen_init", dev)?;
        for (name, shape) in &m.gen_params {
            dev.insert(&format!("am.{name}"), &Tensor::zeros(shape))?;
            dev.insert(&format!("av.{name}"), &Tensor::zeros(shape))?;
        }
        // latents z ~ N(0, I), learnable (the GLO insight, section 3.1)
        let zshape = [bd, m.latent];
        dev.insert("z", &Tensor::randn(&zshape, &mut self.rng, 1.0))?;
        dev.insert("zm", &Tensor::zeros(&zshape))?;
        dev.insert("zv", &Tensor::zeros(&zshape))?;
        Ok(())
    }

    fn before_step(&mut self, t: usize, dev: &mut DeviceStore) -> Result<()> {
        let (kh, kl) = self.rng.key_pair();
        dev.insert("key", &Tensor::key(kh, kl))?;
        dev.insert("t", &Tensor::scalar_f32(t as f32))?;
        dev.insert("lr_g", &Tensor::scalar_f32(self.gen_sched.lr(t - 1)))?;
        dev.insert("lr_z", &Tensor::scalar_f32(self.lr_z))?;
        Ok(())
    }

    fn after_step(
        &mut self,
        _t: usize,
        scalars: &Scalars,
        _dev: &mut DeviceStore,
    ) -> Result<()> {
        if self.lr_z_active {
            self.lr_z = self.z_sched.observe(scalars["loss"]);
        }
        Ok(())
    }

    fn carried(&self) -> Vec<String> {
        let m = &self.mrt.manifest;
        let mut v = Vec::new();
        for (n, _) in &m.gen_params {
            v.push(n.clone());
            v.push(format!("am.{n}"));
            v.push(format!("av.{n}"));
        }
        v.extend(["z".to_string(), "zm".to_string(), "zv".to_string()]);
        v
    }

    fn snapshot(&self) -> Store {
        let mut s = Store::new();
        s.insert("rng", checkpoint::rng_tensor(&self.rng));
        s.insert("z_sched", checkpoint::plateau_tensor(&self.z_sched));
        s.insert("lr_z", Tensor::scalar_f32(self.lr_z));
        s
    }

    fn restore(&mut self, snap: &Store) -> Result<()> {
        self.rng = checkpoint::rng_from_tensor(snap.get("rng")?)?;
        checkpoint::plateau_restore(&mut self.z_sched, snap.get("z_sched")?)?;
        self.lr_z = snap.get("lr_z")?.scalar();
        Ok(())
    }

    /// Fused-dispatch safe: before_step only inserts RNG/schedule
    /// scalars, after_step only observes the loss, and snapshot/restore
    /// carries the full host state (rng, plateau sched, lr_z) — the
    /// megastep replay handles mid-dispatch plateau drops exactly.
    fn fusible(&self) -> bool {
        true
    }

    fn finish(&mut self, dev: &mut DeviceStore) -> Result<Store> {
        // phase boundary: the only full-tensor download of the shard
        self.mrt.call_device("gen_images", dev)?;
        let mut out = Store::new();
        out.insert("images", dev.fetch("images")?);
        Ok(out)
    }
}

/// One direct (ZeroQ/DBA) shard as a [`Phase`]: the images themselves
/// are the parameters, living on device until the final fetch.
struct DirectShard<'a, 'rt> {
    mrt: &'a ModelRt<'rt>,
    tag: &'a str,
    rng: Pcg32,
    sched: ReduceLROnPlateau,
    lr: f32,
}

impl<'a, 'rt> DirectShard<'a, 'rt> {
    fn new(
        mrt: &'a ModelRt<'rt>,
        cfg: &DistillCfg,
        tag: &'a str,
        rng: Pcg32,
    ) -> Self {
        DirectShard {
            mrt,
            tag,
            rng,
            sched: ReduceLROnPlateau::new(cfg.lr_z, 0.5, 30),
            lr: cfg.lr_z,
        }
    }
}

impl Phase for DirectShard<'_, '_> {
    fn name(&self) -> String {
        "distill".into()
    }

    fn entry(&self) -> String {
        format!("distill_direct_{}", self.tag)
    }

    fn init(&mut self, dev: &mut DeviceStore) -> Result<()> {
        let m = &self.mrt.manifest;
        let bd = m.batch("distill");
        let img = &m.image;
        let xshape = [bd, img[0], img[1], img[2]];
        dev.insert("x", &Tensor::randn(&xshape, &mut self.rng, 1.0))?;
        dev.insert("xm", &Tensor::zeros(&xshape))?;
        dev.insert("xv", &Tensor::zeros(&xshape))?;
        Ok(())
    }

    fn before_step(&mut self, t: usize, dev: &mut DeviceStore) -> Result<()> {
        let (kh, kl) = self.rng.key_pair();
        dev.insert("key", &Tensor::key(kh, kl))?;
        dev.insert("t", &Tensor::scalar_f32(t as f32))?;
        dev.insert("lr", &Tensor::scalar_f32(self.lr))?;
        Ok(())
    }

    fn after_step(
        &mut self,
        _t: usize,
        scalars: &Scalars,
        _dev: &mut DeviceStore,
    ) -> Result<()> {
        self.lr = self.sched.observe(scalars["loss"]);
        Ok(())
    }

    fn carried(&self) -> Vec<String> {
        vec!["x".into(), "xm".into(), "xv".into()]
    }

    fn snapshot(&self) -> Store {
        let mut s = Store::new();
        s.insert("rng", checkpoint::rng_tensor(&self.rng));
        s.insert("sched", checkpoint::plateau_tensor(&self.sched));
        s.insert("lr", Tensor::scalar_f32(self.lr));
        s
    }

    fn restore(&mut self, snap: &Store) -> Result<()> {
        self.rng = checkpoint::rng_from_tensor(snap.get("rng")?)?;
        checkpoint::plateau_restore(&mut self.sched, snap.get("sched")?)?;
        self.lr = snap.get("lr")?.scalar();
        Ok(())
    }

    /// Same fused-dispatch contract as [`GenieShard`]: scalar-only
    /// feeds, scalar-only observation, complete snapshot.
    fn fusible(&self) -> bool {
        true
    }

    fn finish(&mut self, dev: &mut DeviceStore) -> Result<Store> {
        let mut out = Store::new();
        out.insert("images", dev.fetch("x")?);
        Ok(out)
    }
}

/// One adversarial (ZAQ) shard: the GENIE generator state machine with
/// the `distill_zaq_*` loss. The inner [`GenieShard`] carries all the
/// device state and schedules; this wrapper swaps the entrypoint and
/// feeds the student proxy's bit-widths as per-step scalars.
struct ZaqShard<'a, 'rt> {
    inner: GenieShard<'a, 'rt>,
}

impl<'a, 'rt> ZaqShard<'a, 'rt> {
    fn new(
        mrt: &'a ModelRt<'rt>,
        cfg: &DistillCfg,
        tag: &'a str,
        rng: Pcg32,
    ) -> Self {
        let mut inner = GenieShard::new(mrt, cfg, tag, rng);
        // the adversary always learns its latents, whatever the
        // (GENIE-specific) mode arm says
        inner.lr_z_active = true;
        inner.lr_z = cfg.lr_z;
        ZaqShard { inner }
    }
}

impl Phase for ZaqShard<'_, '_> {
    fn name(&self) -> String {
        "distill".into()
    }

    fn entry(&self) -> String {
        format!("distill_zaq_{}", self.inner.tag)
    }

    fn init(&mut self, dev: &mut DeviceStore) -> Result<()> {
        self.inner.init(dev)
    }

    fn before_step(&mut self, t: usize, dev: &mut DeviceStore) -> Result<()> {
        self.inner.before_step(t, dev)?;
        dev.insert("wp", &Tensor::scalar_f32(ZAQ_PROXY_WBITS))?;
        dev.insert("ap", &Tensor::scalar_f32(ZAQ_PROXY_ABITS))?;
        Ok(())
    }

    fn after_step(
        &mut self,
        t: usize,
        scalars: &Scalars,
        dev: &mut DeviceStore,
    ) -> Result<()> {
        self.inner.after_step(t, scalars, dev)
    }

    fn carried(&self) -> Vec<String> {
        self.inner.carried()
    }

    fn snapshot(&self) -> Store {
        self.inner.snapshot()
    }

    fn restore(&mut self, snap: &Store) -> Result<()> {
        self.inner.restore(snap)
    }

    /// The wrapper adds only constant scalar feeds (wp/ap) on top of the
    /// inner GENIE shard, so it inherits its fused-dispatch safety.
    fn fusible(&self) -> bool {
        self.inner.fusible()
    }

    fn finish(&mut self, dev: &mut DeviceStore) -> Result<Store> {
        self.inner.finish(dev)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn engine_parse_round_trips() {
        for e in [Engine::Genie, Engine::Zeroq, Engine::Zaq] {
            assert_eq!(Engine::parse(e.as_str()).unwrap(), e);
        }
        assert!(Engine::parse("synq").is_err());
        assert_eq!(Engine::default(), Engine::Genie);
    }

    #[test]
    fn policy_names_match_engine_names() {
        for e in [Engine::Genie, Engine::Zeroq, Engine::Zaq] {
            assert_eq!(e.policy().name(), e.as_str());
        }
    }

    #[test]
    fn entry_names_per_engine_and_mode() {
        let mut cfg = DistillCfg::default();
        let genie = Engine::Genie.policy();
        assert_eq!(genie.entry(&cfg, "swing"), "distill_genie_swing");
        cfg.mode = DistillMode::Gba;
        assert_eq!(genie.entry(&cfg, "noswing"), "distill_genie_noswing");
        cfg.mode = DistillMode::Direct;
        assert_eq!(genie.entry(&cfg, "swing"), "distill_direct_swing");

        // zeroq always optimizes images directly, whatever the mode
        for mode in [DistillMode::Genie, DistillMode::Direct] {
            cfg.mode = mode;
            assert_eq!(
                Engine::Zeroq.policy().entry(&cfg, "swing"),
                "distill_direct_swing"
            );
        }
        assert_eq!(
            Engine::Zaq.policy().entry(&cfg, "noswing"),
            "distill_zaq_noswing"
        );
    }

    #[test]
    fn display_keeps_genie_mode_arms() {
        assert_eq!(Engine::Genie.display(DistillMode::Genie), "genie");
        assert_eq!(Engine::Genie.display(DistillMode::Gba), "gba");
        assert_eq!(Engine::Genie.display(DistillMode::Direct), "direct");
        assert_eq!(Engine::Zeroq.display(DistillMode::Genie), "zeroq");
        assert_eq!(Engine::Zaq.display(DistillMode::Direct), "zaq");
    }
}

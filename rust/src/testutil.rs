//! In-tree property-testing and micro-bench helpers (the offline testbed
//! vendors neither proptest nor criterion; see Cargo.toml note).

use crate::tensor::Pcg32;

/// Run `f` over `iters` independently-seeded RNG streams; panics (with the
/// failing seed) if any case fails — a minimal proptest-style driver.
pub fn forall(base_seed: u64, iters: u64, f: impl Fn(&mut Pcg32)) {
    for i in 0..iters {
        let seed = base_seed.wrapping_mul(0x9e3779b97f4a7c15).wrapping_add(i);
        let mut rng = Pcg32::new(seed);
        let result = std::panic::catch_unwind(
            std::panic::AssertUnwindSafe(|| f(&mut rng)),
        );
        if let Err(e) = result {
            eprintln!("forall: case {i} (seed {seed}) failed");
            std::panic::resume_unwind(e);
        }
    }
}

/// Time `f` over `iters` runs after `warmup`; returns mean seconds.
pub fn bench_secs(warmup: usize, iters: usize, mut f: impl FnMut()) -> f64 {
    for _ in 0..warmup {
        f();
    }
    let t0 = std::time::Instant::now();
    for _ in 0..iters {
        f();
    }
    t0.elapsed().as_secs_f64() / iters as f64
}

/// Print one bench line in a stable, grep-friendly format.
pub fn report(name: &str, secs: f64) {
    if secs < 1e-3 {
        println!("bench {name:<42} {:>10.1} us/iter", secs * 1e6);
    } else {
        println!("bench {name:<42} {:>10.3} ms/iter", secs * 1e3);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn forall_runs_all_cases() {
        let mut count = 0u64;
        let counter = std::cell::Cell::new(0u64);
        forall(1, 25, |_| counter.set(counter.get() + 1));
        count += counter.get();
        assert_eq!(count, 25);
    }

    #[test]
    #[should_panic]
    fn forall_propagates_failures() {
        forall(2, 10, |rng| assert!(rng.uniform() < 0.5));
    }

    #[test]
    fn bench_returns_positive() {
        let s = bench_secs(1, 3, || { std::hint::black_box(1 + 1); });
        assert!(s >= 0.0);
    }
}

//! Grid executor (DESIGN.md §11, §15): run the merged stage DAG —
//! stages from *different* runs — concurrently on the shared exec pool,
//! under one of two schedulers selected by `sched=wave|dataflow`:
//!
//! * **dataflow** (default): a dependency-counting ready queue
//!   ([`crate::exec::run_dag`]) dispatches each node the moment its
//!   in-degree drops to zero, ordered by critical-path length so the
//!   long-pole chain never waits — no barriers, no idle workers while
//!   ready work exists.
//! * **wave**: the barriered reference implementation — topological
//!   waves with a full join between ranks.
//!
//! Each stage job is self-contained: it opens its own [`ArtifactCache`]
//! handle on the shared cache dir (stage artifacts are content-addressed
//! and claim-locked, so concurrent jobs cooperate instead of colliding),
//! logs into its own [`Metrics`] sink, tags its progress lines with the
//! cell (`c3`) or `shared:<stage>` it serves, and publishes its product
//! into a per-node once-cell read by downstream stages.
//!
//! Determinism (DESIGN.md §15): both schedulers affect *scheduling
//! only*. After execution, job metrics, fault accounting and cache
//! stats are merged in node (submission) index order regardless of
//! completion order, stages are bit-identical for any worker count
//! (DESIGN.md §5), and a cell's configs are exactly what a standalone
//! run with the same overrides would use — so every cell of a grid
//! reproduces the same run executed alone, bit for bit, under either
//! scheduler at any worker count (`tests/grid.rs`, `tests/faults.rs`).
//!
//! Resume: an interrupted grid re-run walks the same DAG; finished
//! stages are cache hits, the interrupted stage continues from its wip
//! checkpoints (`--resume`), and only unfinished cells compute.
//!
//! Fault tolerance (DESIGN.md §13): every stage node is dispatched
//! through [`supervise`] — bounded retries with deterministic linear
//! backoff, panics caught per attempt. A node that exhausts its budget
//! is recorded `Failed` and quarantines only its *dependents*: nodes
//! whose deps failed are marked `Skipped` without dispatching (under
//! dataflow the skip propagates through the dependency counts; under
//! wave, through the pre-dispatch scan), while independent nodes keep
//! running. Each cell then reports `ok | failed | skipped` on its
//! [`CellOutcome`], so one bad cell never aborts its siblings.

use std::collections::BTreeMap;
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::OnceLock;

use anyhow::{bail, Context, Result};

use crate::artifacts::{ArtifactCache, CacheStats};
use crate::coordinator::{
    distill_cached_keyed, eval_fp32_metered, eval_quantized_metered,
    plan_cached, quantize_cached_planned, teacher_cached, Metrics,
    PipelineOutcome, RunConfig,
};
use crate::data::Dataset;
use crate::exec::{
    critical_path, panic_message, run_dag, run_jobs, DagNode, DagReport,
    PoolReport, Sched,
};
use crate::precision::PrecisionPlan;
use crate::runtime::json::Json;
use crate::runtime::{Manifest, ModelRt, Runtime};
use crate::store::Store;
use crate::tensor::{Pcg32, Tensor};

use super::{DataMode, GridPlan, RunGrid, RunSpec, StageKind};

/// What the executor materializes beyond the per-cell outcomes.
#[derive(Debug, Clone, Copy, Default)]
pub struct GridOpts {
    /// Stop after the calibration data (teacher + distill nodes only);
    /// outcomes are `None`. Harness mode for reports that consume the
    /// shared synthetic sets directly.
    pub data_only: bool,
    /// Keep each cell's calibration tensor on the outcome.
    pub keep_calib: bool,
    /// Keep each cell's (shared) teacher store on the outcome.
    pub keep_teacher: bool,
    /// Keep each cell's optimized qstate on the outcome.
    pub keep_qstate: bool,
}

/// Terminal status of one cell after supervised execution
/// (DESIGN.md §13).
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum CellStatus {
    /// Every stage the cell needs completed.
    Ok,
    /// A stage serving this cell exhausted its retry budget.
    Failed { stage: String, reason: String },
    /// An upstream stage failed, so this cell's remaining stages were
    /// never dispatched.
    Skipped { stage: String, reason: String },
}

impl CellStatus {
    /// Status keyword as emitted in `--json`: `ok | failed | skipped`.
    pub fn as_str(&self) -> &'static str {
        match self {
            CellStatus::Ok => "ok",
            CellStatus::Failed { .. } => "failed",
            CellStatus::Skipped { .. } => "skipped",
        }
    }

    pub fn is_ok(&self) -> bool {
        matches!(self, CellStatus::Ok)
    }

    /// Human-readable `stage: reason` detail (`None` for `Ok`).
    pub fn describe(&self) -> Option<String> {
        match self {
            CellStatus::Ok => None,
            CellStatus::Failed { stage, reason }
            | CellStatus::Skipped { stage, reason } => {
                Some(format!("{stage}: {reason}"))
            }
        }
    }
}

/// One cell's results.
#[derive(Debug)]
pub struct CellOutcome {
    pub spec: RunSpec,
    /// Whether the cell's stage chain completed; non-`Ok` cells carry
    /// `None` for every product field below.
    pub status: CellStatus,
    /// `None` under [`GridOpts::data_only`].
    pub outcome: Option<PipelineOutcome>,
    /// The resolved precision plan (`None` under `data_only`).
    pub plan: Option<PrecisionPlan>,
    /// Requested via [`GridOpts::keep_calib`] (synthetic or real).
    pub calib: Option<Tensor>,
    /// Requested via [`GridOpts::keep_teacher`].
    pub teacher: Option<Store>,
    /// Requested via [`GridOpts::keep_qstate`].
    pub qstate: Option<Store>,
}

/// Whole-grid accounting: DAG shape, dedupe, merged cache traffic.
#[derive(Debug, Clone, Default)]
pub struct GridStats {
    pub cells: usize,
    pub nodes: usize,
    /// Stage count a naive cell-by-cell execution would run.
    pub naive_stages: usize,
    pub teacher_nodes: usize,
    pub distill_nodes: usize,
    pub quantize_nodes: usize,
    pub waves: usize,
    pub wall_secs: f64,
    /// Pool utilization over the whole grid: busy worker-seconds over
    /// `workers * wall` (1.0 = no worker ever idled).
    pub utilization: f64,
    /// Nodes that exhausted their retry budget.
    pub failed_nodes: usize,
    /// Nodes never dispatched because an upstream node failed.
    pub skipped_nodes: usize,
    /// Extra attempts made beyond each node's first (all nodes).
    pub retries: u64,
    /// Attempts that ended in a caught panic (all nodes).
    pub panics: u64,
    /// Cache traffic merged across every stage job.
    pub cache: CacheStats,
}

impl GridStats {
    /// Stages the dedupe removed relative to cell-by-cell execution.
    pub fn dedup_saved(&self) -> usize {
        self.naive_stages - self.nodes
    }
}

#[derive(Debug)]
pub struct GridOutcome {
    pub cells: Vec<CellOutcome>,
    pub stats: GridStats,
}

impl GridOutcome {
    /// Machine-readable grid report for `genie grid --json`
    /// (DESIGN.md §11): per-cell coordinates + outcome (null fields for
    /// stages that did not run) and the dedupe/cache statistics.
    pub fn to_json(&self) -> Json {
        let cells: Vec<Json> = self
            .cells
            .iter()
            .map(|c| {
                Json::obj(vec![
                    ("cell", Json::num(c.spec.cell as f64)),
                    ("label", Json::Str(c.spec.label())),
                    ("model", Json::Str(c.spec.model.clone())),
                    ("wbits", Json::num(c.spec.quant.wbits as f64)),
                    ("abits", Json::num(c.spec.quant.abits as f64)),
                    ("seed", Json::num(c.spec.seed as f64)),
                    ("data", Json::Str(c.spec.data.label())),
                    ("status", Json::Str(c.status.as_str().to_string())),
                    (
                        "reason",
                        match c.status.describe() {
                            Some(r) => Json::Str(r),
                            None => Json::Null,
                        },
                    ),
                    (
                        "outcome",
                        match &c.outcome {
                            Some(o) => o.to_json(None),
                            None => Json::Null,
                        },
                    ),
                ])
            })
            .collect();
        let s = &self.stats;
        Json::obj(vec![
            ("cells", Json::Arr(cells)),
            (
                "stats",
                Json::obj(vec![
                    ("cells", Json::num(s.cells as f64)),
                    ("nodes", Json::num(s.nodes as f64)),
                    ("naive_stages", Json::num(s.naive_stages as f64)),
                    ("dedup_saved", Json::num(s.dedup_saved() as f64)),
                    ("teacher_nodes", Json::num(s.teacher_nodes as f64)),
                    ("distill_nodes", Json::num(s.distill_nodes as f64)),
                    (
                        "quantize_nodes",
                        Json::num(s.quantize_nodes as f64),
                    ),
                    ("waves", Json::num(s.waves as f64)),
                    ("wall_secs", Json::num(s.wall_secs)),
                    ("utilization", Json::num(s.utilization)),
                    ("failed_nodes", Json::num(s.failed_nodes as f64)),
                    (
                        "skipped_nodes",
                        Json::num(s.skipped_nodes as f64),
                    ),
                    ("retries", Json::num(s.retries as f64)),
                    ("panics", Json::num(s.panics as f64)),
                    (
                        "cache",
                        Json::obj(vec![
                            ("hits", Json::num(s.cache.hits as f64)),
                            ("misses", Json::num(s.cache.misses as f64)),
                            ("stores", Json::num(s.cache.stores as f64)),
                            (
                                "quarantined",
                                Json::num(s.cache.quarantined as f64),
                            ),
                            (
                                "hot_hits",
                                Json::num(s.cache.hot_hits as f64),
                            ),
                            (
                                "disk_hits",
                                Json::num(s.cache.disk_hits as f64),
                            ),
                            (
                                "shared_hits",
                                Json::num(s.cache.shared_hits as f64),
                            ),
                            (
                                "hot_evictions",
                                Json::num(s.cache.hot_evictions as f64),
                            ),
                            (
                                "gc_evictions",
                                Json::num(s.cache.gc_evictions as f64),
                            ),
                        ]),
                    ),
                ]),
            ),
        ])
    }

    /// Whether every cell completed (`genie grid` exits nonzero when
    /// this is false).
    pub fn all_ok(&self) -> bool {
        self.cells.iter().all(|c| c.status.is_ok())
    }
}

/// Per-node execution state tracked by the wave scheduler.
#[derive(Debug, Clone)]
enum NodeState {
    Pending,
    Ok,
    Failed(String),
    Skipped(String),
}

/// Accounting for one supervised stage dispatch.
#[derive(Debug, Clone, Copy, Default)]
pub struct SuperviseReport {
    /// Attempts actually made (1 = first try succeeded).
    pub attempts: u32,
    /// Attempts that ended in a panic (caught, converted to errors).
    pub panics: u32,
}

/// Run `f` under the grid retry policy (DESIGN.md §13): up to
/// `max_attempts` tries, a deterministic linear backoff of
/// `(attempt-1) * backoff_ms` before each retry, and a per-attempt
/// `catch_unwind` so a panicking stage becomes a retryable error
/// instead of poisoning the pool. Injected faults
/// ([`crate::faults::check`]) fire inside the guarded region, so a
/// `panic`/`err` fault exercises exactly the recovery path a real one
/// would. Returns the final result plus attempt accounting; the caller
/// decides whether a terminal `Err` fails or skips dependents.
pub fn supervise<T>(
    stage: &str,
    site: &str,
    max_attempts: u32,
    backoff_ms: u64,
    mut f: impl FnMut() -> Result<T>,
) -> (Result<T>, SuperviseReport) {
    let max = max_attempts.max(1);
    let mut rep = SuperviseReport::default();
    let mut last_err: Option<anyhow::Error> = None;
    for attempt in 1..=max {
        if attempt > 1 {
            let ms = backoff_ms.saturating_mul(u64::from(attempt - 1));
            crate::progress!(
                "grid: retrying {stage}[{site}] attempt {attempt}/{max} \
                 after {ms}ms: {}",
                last_err
                    .as_ref()
                    .map(|e| e.to_string())
                    .unwrap_or_default(),
            );
            if ms > 0 {
                std::thread::sleep(std::time::Duration::from_millis(ms));
            }
        }
        rep.attempts = attempt;
        let caught = catch_unwind(AssertUnwindSafe(|| {
            crate::faults::check(stage, site)?;
            f()
        }));
        match caught {
            Ok(Ok(v)) => return (Ok(v), rep),
            Ok(Err(e)) => last_err = Some(e),
            Err(p) => {
                rep.panics += 1;
                last_err = Some(anyhow::anyhow!(
                    "{stage}[{site}] attempt {attempt} panicked: {}",
                    panic_message(p.as_ref())
                ));
            }
        }
    }
    let e = last_err
        .unwrap_or_else(|| anyhow::anyhow!("{stage}[{site}]: no attempts"));
    let wrapped =
        e.context(format!("{stage}[{site}]: failed after {max} attempts"));
    (Err(wrapped), rep)
}

/// One stage job's accounting, returned to the scheduler while the
/// product itself lands in the node's once-cell. Stage failure lives in
/// the first slot so the job's metrics and cache stats survive it.
type JobOut = (Result<()>, Metrics, CacheStats, SuperviseReport);

/// What execution resolved for one node, before the deterministic
/// node-index-order merge (the same shape whichever scheduler ran).
enum ExecResult {
    Ran(JobOut),
    /// Never dispatched; `dep` is the first not-ok dependency in the
    /// node's declaration order.
    Skipped { dep: usize },
}

/// One node's published product, read by downstream stages through its
/// once-cell (written exactly once by the node's own job).
#[derive(Debug)]
enum NodeOut {
    Teacher {
        store: Store,
        hash: u64,
    },
    Images {
        images: Tensor,
        final_loss: f32,
        secs: f64,
    },
    Quant {
        qstate: Store,
        plan: PrecisionPlan,
        /// Present when [`GridOpts::keep_calib`].
        calib: Option<Tensor>,
        secs: f64,
    },
    Acc(f32),
}

fn teacher_at(
    results: &[OnceLock<NodeOut>],
    i: usize,
) -> Result<(&Store, u64)> {
    match results[i].get() {
        Some(NodeOut::Teacher { store, hash }) => Ok((store, *hash)),
        _ => bail!("grid: teacher node {i} not materialized"),
    }
}

fn images_at(results: &[OnceLock<NodeOut>], i: usize) -> Result<&Tensor> {
    match results[i].get() {
        Some(NodeOut::Images { images, .. }) => Ok(images),
        _ => bail!("grid: distill node {i} not materialized"),
    }
}

fn quant_at(
    results: &[OnceLock<NodeOut>],
    i: usize,
) -> Result<(&Store, &PrecisionPlan, &Option<Tensor>, f64)> {
    match results[i].get() {
        Some(NodeOut::Quant { qstate, plan, calib, secs }) => {
            Ok((qstate, plan, calib, *secs))
        }
        _ => bail!("grid: quantize node {i} not materialized"),
    }
}

fn acc_at(results: &[OnceLock<NodeOut>], i: usize) -> Result<f32> {
    match results[i].get() {
        Some(NodeOut::Acc(a)) => Ok(*a),
        _ => bail!("grid: eval node {i} not materialized"),
    }
}

fn open_job_cache(cfg: &RunConfig) -> Result<ArtifactCache> {
    // per-job caches on one dir share the process-global tier 0, so the
    // budget/backend wiring (open_cache) applies uniformly across jobs
    cfg.open_cache()
}

fn fold_stats(total: &mut CacheStats, job: &CacheStats) {
    total.hits += job.hits;
    total.misses += job.misses;
    total.stores += job.stores;
    total.quarantined += job.quarantined;
    total.hot_hits += job.hot_hits;
    total.disk_hits += job.disk_hits;
    total.shared_hits += job.shared_hits;
    total.hot_evictions += job.hot_evictions;
    total.gc_evictions += job.gc_evictions;
}

/// First non-`Ok` node in a cell's stage chain decides the cell's
/// status: a `Failed` node makes the cell `failed` at that stage, a
/// `Skipped` (or never-dispatched) node makes it `skipped`.
fn status_of_chain(
    chain: &[(usize, &str)],
    states: &[NodeState],
) -> CellStatus {
    for &(i, kind) in chain {
        match &states[i] {
            NodeState::Ok => {}
            NodeState::Failed(r) => {
                return CellStatus::Failed {
                    stage: kind.to_string(),
                    reason: r.clone(),
                }
            }
            NodeState::Skipped(r) => {
                return CellStatus::Skipped {
                    stage: kind.to_string(),
                    reason: r.clone(),
                }
            }
            NodeState::Pending => {
                return CellStatus::Skipped {
                    stage: kind.to_string(),
                    reason: "stage never dispatched".to_string(),
                }
            }
        }
    }
    CellStatus::Ok
}

/// The cell's stage chain in execution order (teacher → distill →
/// quantize → evals), restricted to nodes the plan actually has.
fn cell_chain(plan: &GridPlan, c: usize) -> Vec<(usize, &'static str)> {
    let mut v = vec![(plan.teacher_of[c], StageKind::Teacher.as_str())];
    let opt = [
        (plan.distill_of[c], StageKind::Distill.as_str()),
        (plan.quantize_of[c], StageKind::Quantize.as_str()),
        (plan.evalfp_of[c], StageKind::EvalFp.as_str()),
        (plan.evalq_of[c], StageKind::EvalQ.as_str()),
    ];
    for (o, kind) in opt {
        if let Some(i) = o {
            v.push((i, kind));
        }
    }
    v
}

/// Expand the grid over the base config and execute it.
pub fn execute(
    rt: &Runtime,
    cfg: &RunConfig,
    grid: &RunGrid,
    opts: &GridOpts,
    metrics: &mut Metrics,
) -> Result<GridOutcome> {
    execute_cells(rt, cfg, grid.cells(cfg)?, opts, metrics)
}

/// Execute pre-expanded cells (the table harnesses build their cell
/// lists through [`RunGrid::cells`] too; this entry just skips the
/// re-expansion).
pub fn execute_cells(
    rt: &Runtime,
    cfg: &RunConfig,
    cells: Vec<RunSpec>,
    opts: &GridOpts,
    metrics: &mut Metrics,
) -> Result<GridOutcome> {
    anyhow::ensure!(!cells.is_empty(), "grid: no cells to execute");
    let t0 = std::time::Instant::now();

    // one ModelRt per distinct model; one dataset for the testbed
    let mut mrts: BTreeMap<String, ModelRt> = BTreeMap::new();
    for c in &cells {
        if !mrts.contains_key(&c.model) {
            let mrt = ModelRt::load(rt, &cfg.artifacts, &c.model)
                .with_context(|| format!("grid: load model '{}'", c.model))?;
            mrts.insert(c.model.clone(), mrt);
        }
    }
    let dataset = Dataset::load(&cfg.artifacts)?;
    let manifests: BTreeMap<String, Manifest> = mrts
        .iter()
        .map(|(k, v)| (k.clone(), v.manifest.clone()))
        .collect();

    let plan = GridPlan::build(cells, &manifests, opts.data_only)?;
    let deps = plan.deps();
    // critical-path depths double as dataflow priorities and the wave
    // count (the deepest chain is exactly how many waves the DAG has)
    let depths = critical_path(&deps);
    let n_waves = depths.iter().copied().max().unwrap_or(0);
    crate::progress!(
        "grid: {} cells -> {} stage nodes ({} deduplicated away), {} waves \
         on {} workers (sched={})",
        plan.cells.len(),
        plan.nodes.len(),
        plan.naive_stages() - plan.nodes.len(),
        n_waves,
        cfg.par.resolve(),
        cfg.sched.as_str(),
    );

    let n = plan.nodes.len();
    let results: Vec<OnceLock<NodeOut>> =
        (0..n).map(|_| OnceLock::new()).collect();

    // one self-contained job per stage node, shared by both schedulers:
    // supervised retries, job-local metrics/cache stats, product
    // published into the node's once-cell on success
    let node_job = |i: usize| -> JobOut {
        let node = &plan.nodes[i];
        // any serving cell carries the configs that key the node (equal
        // spec key ⇒ equal configs for every field the stage reads)
        let spec = &plan.cells[node.cells[0]];
        let mrt = &mrts[&spec.model];
        let mut jm = Metrics::new();
        let mut cstats = CacheStats::default();
        let tag = if node.cells.len() == 1 {
            format!("c{}", node.cells[0])
        } else {
            format!("shared:{}", node.kind.as_str())
        };
        let _tag = crate::progress::push_tag(&tag);
        let (res, rep) = supervise(
            node.kind.as_str(),
            &tag,
            cfg.retry_max,
            cfg.retry_backoff_ms,
            || {
                let mut cache = open_job_cache(cfg)?;
                let r = run_node(
                    node.kind, spec, mrt, &dataset, &results, node, opts,
                    &mut cache, &mut jm,
                );
                fold_stats(&mut cstats, cache.stats());
                r
            },
        );
        // stage failure stays in the first slot: metrics and cache
        // stats must survive it
        let res = res.map(|out| {
            let _ = results[i].set(out);
        });
        (res, jm, cstats, rep)
    };

    let mut execs: Vec<Option<ExecResult>> = (0..n).map(|_| None).collect();
    let mut pool_total = PoolReport::default();
    let mut dag_report: Option<DagReport> = None;
    match cfg.sched {
        Sched::Wave => {
            // reference scheduler: topological waves with a full
            // barrier between ranks. Dependents of failed nodes are
            // skipped in the pre-dispatch scan (first not-ok dep in
            // declaration order wins, matching dataflow).
            let mut ok: Vec<Option<bool>> = vec![None; n];
            for wave in &crate::exec::waves(&deps) {
                let mut runnable: Vec<usize> =
                    Vec::with_capacity(wave.len());
                for &i in wave {
                    let node = &plan.nodes[i];
                    match node.deps.iter().find(|&&d| ok[d] == Some(false))
                    {
                        Some(&d) => {
                            execs[i] = Some(ExecResult::Skipped { dep: d });
                            ok[i] = Some(false);
                        }
                        None => runnable.push(i),
                    }
                }
                if runnable.is_empty() {
                    continue;
                }
                let jobs: Vec<_> = runnable
                    .iter()
                    .map(|&i| {
                        let nj = &node_job;
                        move || -> Result<JobOut> { Ok(nj(i)) }
                    })
                    .collect();
                let (outs, pool) = run_jobs(cfg.par, jobs)?;
                pool_total.merge(&pool);
                for (&i, out) in runnable.iter().zip(outs) {
                    ok[i] = Some(out.0.is_ok());
                    execs[i] = Some(ExecResult::Ran(out));
                }
            }
        }
        Sched::Dataflow => {
            // work-conserving scheduler (DESIGN.md §15): dependency-
            // counting ready queue, longest-chain-first; skips flow
            // through the dependency counts inside run_dag
            let (nodes, report) = run_dag(cfg.par, &deps, &depths, |i| {
                let out = node_job(i);
                let ok = out.0.is_ok();
                (out, ok)
            });
            pool_total.merge(&report.pool);
            dag_report = Some(report);
            for (i, dn) in nodes.into_iter().enumerate() {
                match dn {
                    DagNode::Ran { out, .. } => {
                        execs[i] = Some(ExecResult::Ran(out));
                    }
                    DagNode::Skipped { dep } => {
                        execs[i] = Some(ExecResult::Skipped { dep });
                    }
                    // a panic outside supervision aborts the grid, like
                    // the wave path's run_jobs error
                    DagNode::Panicked(msg) => {
                        bail!("job {i} panicked: {msg}")
                    }
                }
            }
        }
    }

    // deterministic merge (DESIGN.md §15): whatever order nodes
    // completed in, metrics, fault accounting and cache stats fold in
    // node (submission) index order — both schedulers at any worker
    // count produce byte-identical outcomes and metrics
    let mut states = vec![NodeState::Pending; n];
    let mut cache_total = CacheStats::default();
    let mut retries_total: u64 = 0;
    let mut panics_total: u64 = 0;
    for (i, ex) in execs.into_iter().enumerate() {
        let node = &plan.nodes[i];
        let kind = node.kind.as_str();
        match ex {
            None => bail!("grid: node {i} never resolved"),
            Some(ExecResult::Skipped { dep }) => {
                // deps are lower-indexed, so states[dep] is merged
                let (what, r) = match &states[dep] {
                    NodeState::Failed(r) => ("failed", r.clone()),
                    NodeState::Skipped(r) => ("skipped", r.clone()),
                    _ => {
                        bail!("grid: node {i} skipped on healthy dep {dep}")
                    }
                };
                let reason = format!(
                    "upstream {} node {dep} {what}: {r}",
                    plan.nodes[dep].kind.as_str(),
                );
                crate::progress!("grid: skipping {kind} node {i}: {reason}");
                metrics.record_fault(kind, "skipped");
                states[i] = NodeState::Skipped(reason);
            }
            Some(ExecResult::Ran((res, jm, cstats, rep))) => {
                let prefix = if node.cells.len() == 1 {
                    format!("cell{}/", node.cells[0])
                } else {
                    format!("shared/{}{}/", kind, i)
                };
                metrics.absorb(&prefix, jm);
                fold_stats(&mut cache_total, &cstats);
                for _ in 1..rep.attempts {
                    metrics.record_fault(kind, "retry");
                }
                for _ in 0..rep.panics {
                    metrics.record_fault(kind, "panic");
                }
                for _ in 0..cstats.quarantined {
                    metrics.record_fault(kind, "quarantine");
                }
                retries_total += u64::from(rep.attempts.saturating_sub(1));
                panics_total += u64::from(rep.panics);
                match res {
                    Ok(()) => states[i] = NodeState::Ok,
                    Err(e) => {
                        let msg = format!("{e:#}");
                        crate::progress!(
                            "grid: {kind} node {i} failed permanently: {msg}"
                        );
                        metrics.record_fault(kind, "stage_failed");
                        states[i] = NodeState::Failed(msg);
                    }
                }
            }
        }
    }
    metrics.record_pool("grid", &pool_total);
    if let Some(r) = &dag_report {
        metrics.record_sched("grid", r);
    }
    // one folded emission per run: per-tier cache counters plus the
    // resident bytes of tiers 0/1 — deterministic across schedulers and
    // worker counts because the fold above is node-index-ordered and
    // the end-of-run tier contents depend only on what ran, not when
    metrics.record_cache_tiers(&cache_total, open_job_cache(cfg)?.tier_bytes());

    // assemble per-cell outcomes; non-ok cells report their status and
    // carry no products
    let mut out_cells = Vec::with_capacity(plan.cells.len());
    for (c, spec) in plan.cells.iter().enumerate() {
        let status = status_of_chain(&cell_chain(&plan, c), &states);
        let mut cell = CellOutcome {
            spec: spec.clone(),
            status: status.clone(),
            outcome: None,
            plan: None,
            calib: None,
            teacher: None,
            qstate: None,
        };
        if !status.is_ok() {
            crate::progress!(
                "grid: cell {c} {}: {}",
                status.as_str(),
                status.describe().unwrap_or_default(),
            );
            out_cells.push(cell);
            continue;
        }
        let (tstore, _) = teacher_at(&results, plan.teacher_of[c])?;
        cell.teacher = opts.keep_teacher.then(|| tstore.clone());
        if opts.data_only {
            if opts.keep_calib {
                if let Some(d) = plan.distill_of[c] {
                    cell.calib = Some(images_at(&results, d)?.clone());
                }
            }
            out_cells.push(cell);
            continue;
        }
        let q = plan.quantize_of[c]
            .with_context(|| format!("grid: cell {c} has no quantize node"))?;
        let (qstate, qplan, calib, quant_secs) = quant_at(&results, q)?;
        let fp_acc = acc_at(
            &results,
            plan.evalfp_of[c].context("grid: missing fp eval node")?,
        )?;
        let q_acc = acc_at(
            &results,
            plan.evalq_of[c].context("grid: missing quant eval node")?,
        )?;
        let (distill_secs, final_bns_loss) = match plan.distill_of[c] {
            Some(d) => match results[d].get() {
                Some(NodeOut::Images { final_loss, secs, .. }) => {
                    (Some(*secs), Some(*final_loss))
                }
                _ => (None, None),
            },
            None => (None, None),
        };
        let m = &manifests[&spec.model];
        cell.outcome = Some(PipelineOutcome {
            model: spec.model.clone(),
            fp_acc,
            q_acc,
            distill_secs,
            quant_secs,
            final_bns_loss,
            fp_weight_bits: PrecisionPlan::fp32_bits(m) as u64,
            q_weight_bits: qplan.payload_bits(m) as u64,
        });
        cell.plan = Some(qplan.clone());
        if opts.keep_calib {
            cell.calib = calib.clone();
        }
        if opts.keep_qstate {
            cell.qstate = Some(qstate.clone());
        }
        out_cells.push(cell);
    }

    let (mut failed_nodes, mut skipped_nodes) = (0, 0);
    for s in &states {
        match s {
            NodeState::Failed(_) => failed_nodes += 1,
            NodeState::Skipped(_) => skipped_nodes += 1,
            _ => {}
        }
    }
    let stats = GridStats {
        cells: plan.cells.len(),
        nodes: plan.nodes.len(),
        naive_stages: plan.naive_stages(),
        teacher_nodes: plan.count(StageKind::Teacher),
        distill_nodes: plan.count(StageKind::Distill),
        quantize_nodes: plan.count(StageKind::Quantize),
        waves: n_waves,
        wall_secs: t0.elapsed().as_secs_f64(),
        utilization: pool_total.utilization(),
        failed_nodes,
        skipped_nodes,
        retries: retries_total,
        panics: panics_total,
        cache: cache_total,
    };
    crate::progress!(
        "grid: {} cells in {:.1}s ({} stages deduplicated away; cache {} \
         hits, {} misses, {} stores)",
        stats.cells,
        stats.wall_secs,
        stats.dedup_saved(),
        stats.cache.hits,
        stats.cache.misses,
        stats.cache.stores,
    );
    if stats.failed_nodes + stats.skipped_nodes > 0
        || stats.retries > 0
        || stats.cache.quarantined > 0
    {
        crate::progress!(
            "grid: faults: {} node(s) failed, {} skipped, {} retries, {} \
             panic(s) caught, {} artifact(s) quarantined",
            stats.failed_nodes,
            stats.skipped_nodes,
            stats.retries,
            stats.panics,
            stats.cache.quarantined,
        );
    }
    Ok(GridOutcome { cells: out_cells, stats })
}

/// Execute one stage node. Runs on a pool worker; everything it touches
/// is either shared immutable state or job-local.
#[allow(clippy::too_many_arguments)]
fn run_node(
    kind: StageKind,
    spec: &RunSpec,
    mrt: &ModelRt,
    dataset: &Dataset,
    results: &[OnceLock<NodeOut>],
    node: &super::StageNode,
    opts: &GridOpts,
    cache: &mut ArtifactCache,
    jm: &mut Metrics,
) -> Result<NodeOut> {
    match kind {
        StageKind::Teacher => {
            let store =
                teacher_cached(mrt, dataset, &spec.pretrain, cache, jm)?;
            let hash = store.content_hash();
            Ok(NodeOut::Teacher { store, hash })
        }
        StageKind::Distill => {
            let (teacher, th) = teacher_at(results, node.deps[0])?;
            let out = distill_cached_keyed(
                mrt, teacher, th, &spec.distill, cache, jm,
            )?;
            Ok(NodeOut::Images {
                images: out.images,
                final_loss: out.final_loss,
                secs: jm.timer_total("distill"),
            })
        }
        StageKind::Quantize => {
            let (teacher, th) = teacher_at(results, node.deps[0])?;
            let calib: Tensor = match spec.data {
                DataMode::Synthetic { .. } => {
                    images_at(results, node.deps[1])?.clone()
                }
                DataMode::Real => {
                    let mut rng = Pcg32::new(spec.quant.seed ^ 0x5eed);
                    dataset.calibration(&mut rng, spec.fsq_samples).0
                }
            };
            let plan = plan_cached(
                mrt, teacher, th, &calib, &spec.quant, cache, jm,
            )?;
            let qstate = quantize_cached_planned(
                mrt, teacher, th, &calib, &spec.quant, &plan, cache, jm,
            )?;
            Ok(NodeOut::Quant {
                qstate,
                plan,
                calib: opts.keep_calib.then_some(calib),
                secs: jm.timer_total("quantize"),
            })
        }
        StageKind::EvalFp => {
            let (teacher, _) = teacher_at(results, node.deps[0])?;
            let acc = eval_fp32_metered(
                mrt, teacher, dataset, spec.quant.par, jm,
            )?;
            Ok(NodeOut::Acc(acc))
        }
        StageKind::EvalQ => {
            let (teacher, _) = teacher_at(results, node.deps[0])?;
            let (qstate, _, _, _) = quant_at(results, node.deps[1])?;
            let acc = eval_quantized_metered(
                mrt, teacher, qstate, dataset, spec.quant.par, jm,
            )?;
            Ok(NodeOut::Acc(acc))
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn grid_json_reports_cells_and_dedupe() {
        let spec = RunSpec::base(&RunConfig::default());
        let out = GridOutcome {
            cells: vec![CellOutcome {
                spec,
                status: CellStatus::Ok,
                outcome: Some(PipelineOutcome {
                    model: "toy".into(),
                    fp_acc: 0.9,
                    q_acc: 0.8,
                    distill_secs: None,
                    quant_secs: 2.0,
                    final_bns_loss: None,
                    fp_weight_bits: 1024,
                    q_weight_bits: 128,
                }),
                plan: None,
                calib: None,
                teacher: None,
                qstate: None,
            }],
            stats: GridStats {
                cells: 1,
                nodes: 5,
                naive_stages: 5,
                teacher_nodes: 1,
                distill_nodes: 1,
                quantize_nodes: 1,
                waves: 4,
                wall_secs: 1.25,
                utilization: 0.75,
                failed_nodes: 0,
                skipped_nodes: 0,
                retries: 1,
                panics: 0,
                cache: CacheStats {
                    hits: 1,
                    misses: 4,
                    stores: 4,
                    hot_hits: 1,
                    ..Default::default()
                },
            },
        };
        let text = out.to_json().render();
        assert!(text.contains("\"cells\":["), "{text}");
        assert!(text.contains("\"dedup_saved\":0"), "{text}");
        assert!(text.contains("\"distill_secs\":null"), "{text}");
        assert!(text.contains("\"hits\":1"), "{text}");
        assert!(text.contains("\"hot_hits\":1"), "{text}");
        assert!(text.contains("\"gc_evictions\":0"), "{text}");
        assert!(text.contains("\"status\":\"ok\""), "{text}");
        assert!(text.contains("\"reason\":null"), "{text}");
        assert!(text.contains("\"retries\":1"), "{text}");
        assert!(text.contains("\"utilization\":0.75"), "{text}");
        assert!(text.contains("\"quarantined\":0"), "{text}");
        assert!(out.all_ok());
        assert!(Json::parse(&text).is_ok());
    }

    #[test]
    fn grid_json_data_only_outcome_is_null() {
        let spec = RunSpec::base(&RunConfig::default());
        let out = GridOutcome {
            cells: vec![CellOutcome {
                spec,
                status: CellStatus::Ok,
                outcome: None,
                plan: None,
                calib: None,
                teacher: None,
                qstate: None,
            }],
            stats: GridStats::default(),
        };
        let text = out.to_json().render();
        assert!(text.contains("\"outcome\":null"), "{text}");
    }

    #[test]
    fn grid_json_reports_failed_cell_status_and_reason() {
        let spec = RunSpec::base(&RunConfig::default());
        let out = GridOutcome {
            cells: vec![CellOutcome {
                spec,
                status: CellStatus::Failed {
                    stage: "quantize".into(),
                    reason: "failed after 2 attempts".into(),
                },
                outcome: None,
                plan: None,
                calib: None,
                teacher: None,
                qstate: None,
            }],
            stats: GridStats::default(),
        };
        assert!(!out.all_ok());
        let text = out.to_json().render();
        assert!(text.contains("\"status\":\"failed\""), "{text}");
        assert!(
            text.contains("quantize: failed after 2 attempts"),
            "{text}"
        );
        assert!(Json::parse(&text).is_ok());
    }

    #[test]
    fn chain_status_first_bad_stage_wins() {
        let states = vec![
            NodeState::Ok,
            NodeState::Failed("boom".into()),
            NodeState::Skipped("upstream distill node 1 failed".into()),
            NodeState::Pending,
        ];
        // clean chain
        let ok = status_of_chain(&[(0, "teacher")], &states);
        assert!(ok.is_ok());
        // own-stage failure => failed at that stage
        let f = status_of_chain(
            &[(0, "teacher"), (1, "distill"), (2, "quantize")],
            &states,
        );
        assert_eq!(f.as_str(), "failed");
        assert_eq!(
            f.describe().unwrap(),
            "distill: boom",
            "first non-ok stage decides"
        );
        // upstream-failure propagation => skipped
        let s = status_of_chain(&[(0, "teacher"), (2, "quantize")], &states);
        assert_eq!(s.as_str(), "skipped");
        // a never-dispatched node also reads as skipped
        let p = status_of_chain(&[(3, "evalq")], &states);
        assert_eq!(p.as_str(), "skipped");
    }

    #[test]
    fn supervise_retries_transient_failures() {
        let mut n = 0;
        let (r, rep) = supervise("test", "s0", 3, 0, || {
            n += 1;
            if n < 3 {
                bail!("flaky")
            }
            Ok(n)
        });
        assert_eq!(r.unwrap(), 3);
        assert_eq!(rep.attempts, 3);
        assert_eq!(rep.panics, 0);
    }

    #[test]
    fn supervise_exhausts_budget_and_reports_last_error() {
        let (r, rep) =
            supervise("quantize", "c1", 2, 0, || -> Result<()> {
                bail!("always broken")
            });
        let msg = format!("{:#}", r.unwrap_err());
        assert!(
            msg.contains("quantize[c1]: failed after 2 attempts"),
            "{msg}"
        );
        assert!(msg.contains("always broken"), "{msg}");
        assert_eq!(rep.attempts, 2);
    }

    #[test]
    fn supervise_catches_panics_per_attempt() {
        let mut n = 0;
        let (r, rep) = supervise("distill", "c0", 2, 0, || {
            n += 1;
            if n == 1 {
                panic!("shard blew up");
            }
            Ok(n)
        });
        assert_eq!(r.unwrap(), 2);
        assert_eq!(rep.attempts, 2);
        assert_eq!(rep.panics, 1);
    }

    #[test]
    fn supervise_zero_budget_still_runs_once() {
        let mut n = 0;
        let (r, rep) = supervise("t", "s", 0, 0, || {
            n += 1;
            Ok(n)
        });
        assert_eq!(r.unwrap(), 1);
        assert_eq!(rep.attempts, 1);
    }

    #[test]
    fn missing_node_results_error_cleanly() {
        let results: Vec<OnceLock<NodeOut>> = vec![OnceLock::new()];
        assert!(teacher_at(&results, 0).is_err());
        assert!(images_at(&results, 0).is_err());
        assert!(quant_at(&results, 0).is_err());
        assert!(acc_at(&results, 0).is_err());
    }
}

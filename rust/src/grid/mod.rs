//! Multi-run grid orchestrator (DESIGN.md §11): every paper result is a
//! *grid* — Table 2 sweeps distill arms × quantizers, Table 5 sweeps
//! bit-widths over real data, Fig. 6 sweeps sample counts — and this
//! module turns such sweeps from hand-rolled sequential loops into one
//! declarative object.
//!
//! A [`RunGrid`] is a list of [`Axis`]es (model × bits × data mode ×
//! seed × samples × quantizer × precision × synthesis engine, plus
//! curated combo "arms");
//! [`RunGrid::cells`] expands their cartesian product into fully
//! resolved [`RunSpec`]s — each cell is exactly the configuration a
//! standalone `genie run` with the same overrides would use, so a grid
//! cell is bit-identical to the run executed alone. [`GridPlan::build`]
//! then lowers the cells onto a stage DAG (teacher → data → quantize →
//! evals) deduplicated on *spec keys* ([`crate::artifacts`]): every cell
//! that agrees on the pretrain config shares one teacher node, every
//! cell that agrees on the distill config shares one synthesis node —
//! the grid dispatches shared work exactly once and overlaps the rest.
//! The executor ([`run`]) walks the DAG in topological waves on the
//! shared exec pool.
//!
//! Spec keys dedupe *within* one orchestrator invocation (fixed
//! manifests + dataset); on-disk artifacts remain addressed by the
//! content-hash keys of DESIGN.md §9, so a grid also cooperates with —
//! and resumes from — everything previous single runs cached.

pub mod run;

use std::collections::{BTreeMap, HashMap};

use anyhow::{bail, Context, Result};

use crate::artifacts::{self, ArtifactCache, CacheKey};
use crate::coordinator::{
    DistillCfg, DistillMode, PretrainCfg, QuantCfg, RunConfig,
};
use crate::data::Dataset;
use crate::precision::{validate_bits, Policy, PrecisionPlan};
use crate::runtime::Manifest;
use crate::store::Store;
use crate::synthesis::Engine;
use crate::tensor::{Pcg32, Tensor};

pub use run::{
    execute, execute_cells, supervise, CellOutcome, CellStatus, GridOpts,
    GridOutcome, GridStats, SuperviseReport,
};

/// Where a cell's calibration data comes from: GENIE-D synthesis (zsq)
/// or real samples (fsq).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum DataMode {
    Synthetic { mode: DistillMode, swing: bool },
    Real,
}

impl DataMode {
    pub fn is_real(&self) -> bool {
        matches!(self, DataMode::Real)
    }

    pub fn label(&self) -> String {
        match self {
            DataMode::Synthetic { mode, swing } => {
                format!(
                    "{}{}",
                    mode.as_str(),
                    if *swing { "" } else { "+noswing" }
                )
            }
            DataMode::Real => "real".into(),
        }
    }
}

/// The quantizer ablation arm of a cell: GENIE-M (learned step sizes)
/// vs the AdaRound baseline, with or without QDrop.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub struct QuantArm {
    pub adaround: bool,
    pub no_drop: bool,
}

impl QuantArm {
    pub fn label(&self) -> String {
        let base = if self.adaround { "adaround" } else { "genie_m" };
        if self.no_drop {
            format!("{base}+nodrop")
        } else {
            base.into()
        }
    }

    pub fn parse(s: &str) -> Result<QuantArm> {
        let mut arm = QuantArm::default();
        for part in s.split('+') {
            match part.trim() {
                "genie_m" | "geniem" => arm.adaround = false,
                "adaround" => arm.adaround = true,
                "qdrop" => arm.no_drop = false,
                "nodrop" => arm.no_drop = true,
                other => bail!(
                    "unknown quantizer arm '{other}' \
                     (want genie_m|adaround[+qdrop|+nodrop])"
                ),
            }
        }
        Ok(arm)
    }

    fn apply(&self, q: &mut QuantCfg) {
        if self.adaround {
            *q = q.clone().adaround();
        }
        if self.no_drop {
            *q = q.clone().no_drop();
        }
    }
}

/// One value of one grid axis. Applying a value patches the cell's
/// [`RunSpec`]; the curated [`AxisValue::Arm`] patches several fields at
/// once (Table 2's M1–M7).
#[derive(Debug, Clone)]
pub enum AxisValue {
    Model(String),
    /// (wbits, abits)
    Bits(u32, u32),
    Seed(u64),
    /// Synthetic sample count (and fsq calibration count).
    Samples(usize),
    Data(DataMode),
    Quantizer(QuantArm),
    Precision(Policy),
    Synthesis(Engine),
    Arm { label: String, data: DataMode, quant: QuantArm },
}

impl AxisValue {
    pub fn label(&self) -> String {
        match self {
            AxisValue::Model(m) => m.clone(),
            AxisValue::Bits(w, a) => format!("w{w}a{a}"),
            AxisValue::Seed(s) => s.to_string(),
            AxisValue::Samples(n) => n.to_string(),
            AxisValue::Data(d) => d.label(),
            AxisValue::Quantizer(q) => q.label(),
            AxisValue::Precision(p) => p.as_str().into(),
            AxisValue::Synthesis(e) => e.as_str().into(),
            AxisValue::Arm { label, .. } => label.clone(),
        }
    }

    fn apply(&self, spec: &mut RunSpec) {
        match self {
            AxisValue::Model(m) => spec.model = m.clone(),
            AxisValue::Bits(w, a) => {
                spec.quant.wbits = *w;
                spec.quant.abits = *a;
            }
            AxisValue::Seed(s) => spec.set_seed(*s),
            AxisValue::Samples(n) => {
                spec.distill.samples = *n;
                spec.fsq_samples = *n;
            }
            AxisValue::Data(d) => spec.set_data(*d),
            AxisValue::Quantizer(q) => q.apply(&mut spec.quant),
            AxisValue::Precision(p) => spec.quant.precision.policy = *p,
            AxisValue::Synthesis(e) => spec.distill.engine = *e,
            AxisValue::Arm { data, quant, .. } => {
                spec.set_data(*data);
                quant.apply(&mut spec.quant);
            }
        }
    }
}

/// One grid dimension: a name (the cell-coordinate key) and its values.
#[derive(Debug, Clone)]
pub struct Axis {
    pub name: String,
    pub values: Vec<AxisValue>,
}

/// One fully resolved grid cell — the exact configuration a standalone
/// run with the same overrides would use.
#[derive(Debug, Clone)]
pub struct RunSpec {
    pub cell: usize,
    pub model: String,
    pub seed: u64,
    pub pretrain: PretrainCfg,
    pub data: DataMode,
    pub distill: DistillCfg,
    pub fsq_samples: usize,
    pub quant: QuantCfg,
    /// (axis name, value label) in axis order — the cell's coordinates.
    pub coords: Vec<(String, String)>,
}

impl RunSpec {
    /// The base cell: `cfg` verbatim, no axis applied.
    pub fn base(cfg: &RunConfig) -> RunSpec {
        RunSpec {
            cell: 0,
            model: cfg.model.split(',').next().unwrap_or("").trim().into(),
            seed: cfg.seed,
            pretrain: cfg.pretrain.clone(),
            data: DataMode::Synthetic {
                mode: cfg.distill.mode,
                swing: cfg.distill.swing,
            },
            distill: cfg.distill.clone(),
            fsq_samples: cfg.fsq_samples,
            quant: cfg.quant.clone(),
            coords: Vec::new(),
        }
    }

    /// Re-seed the cell exactly like `RunConfig::set("seed", ..)` fans
    /// the master seed into the phase configs — a grid cell at seed `s`
    /// must match `genie run seed=s` bit for bit.
    pub fn set_seed(&mut self, s: u64) {
        self.seed = s;
        self.pretrain.seed = s ^ 1;
        self.distill.seed = s ^ 2;
        self.quant.seed = s ^ 3;
    }

    fn set_data(&mut self, d: DataMode) {
        self.data = d;
        if let DataMode::Synthetic { mode, swing } = d {
            self.distill.mode = mode;
            self.distill.swing = swing;
        }
    }

    /// "bits=w2a4 seed=7" — the cell's coordinates, or `cell<i>` for an
    /// axis-less grid.
    pub fn label(&self) -> String {
        if self.coords.is_empty() {
            return format!("cell{}", self.cell);
        }
        self.coords
            .iter()
            .map(|(k, v)| format!("{k}={v}"))
            .collect::<Vec<_>>()
            .join(" ")
    }

    /// The cell's value label on one axis (row extraction in the table
    /// harnesses).
    pub fn coord(&self, axis: &str) -> Option<&str> {
        self.coords
            .iter()
            .find(|(k, _)| k == axis)
            .map(|(_, v)| v.as_str())
    }
}

/// A declarative run grid: axes over a base [`RunConfig`].
#[derive(Debug, Clone, Default)]
pub struct RunGrid {
    pub axes: Vec<Axis>,
}

impl RunGrid {
    pub fn new() -> RunGrid {
        RunGrid { axes: Vec::new() }
    }

    /// Add one axis (builder style).
    pub fn axis(mut self, name: &str, values: Vec<AxisValue>) -> RunGrid {
        self.axes.push(Axis { name: name.into(), values });
        self
    }

    /// Parse one CLI `--axis name=v1,v2,...` argument. Bits accept `4`,
    /// `2/4` or `w2a4`; data accepts distill modes (`genie`, `gba`,
    /// `direct`, optionally `+noswing`) and `real`/`fsq`; quantizer
    /// accepts `genie_m`/`adaround` (`+qdrop`/`+nodrop`); synthesis
    /// accepts the engine names (`genie`, `zeroq`, `zaq`).
    pub fn parse_axis(&mut self, arg: &str, base: &RunConfig) -> Result<()> {
        let Some((name, csv)) = arg.split_once('=') else {
            bail!("--axis wants name=v1,v2,..., got '{arg}'");
        };
        let name = name.trim();
        let toks: Vec<&str> =
            csv.split(',').map(str::trim).filter(|t| !t.is_empty()).collect();
        if toks.is_empty() {
            bail!("axis '{name}' has no values");
        }
        let mut values = Vec::with_capacity(toks.len());
        for t in &toks {
            values.push(parse_axis_value(name, t, base)?);
        }
        self.axes.push(Axis { name: name.into(), values });
        Ok(())
    }

    /// Expand the cartesian product of the axes over the base config.
    /// The first axis is the outermost loop, so rows come out in the
    /// order the axes were declared.
    pub fn cells(&self, base: &RunConfig) -> Result<Vec<RunSpec>> {
        let mut seen = std::collections::HashSet::new();
        for ax in &self.axes {
            if ax.values.is_empty() {
                bail!("axis '{}' has no values", ax.name);
            }
            if !seen.insert(ax.name.as_str()) {
                bail!("duplicate axis '{}'", ax.name);
            }
        }
        let total: usize =
            self.axes.iter().map(|a| a.values.len()).product::<usize>().max(1);
        let mut cells = Vec::with_capacity(total);
        for i in 0..total {
            let mut spec = RunSpec::base(base);
            spec.cell = i;
            let mut stride = total;
            for ax in &self.axes {
                stride /= ax.values.len();
                let v = &ax.values[(i / stride) % ax.values.len()];
                v.apply(&mut spec);
                spec.coords.push((ax.name.clone(), v.label()));
            }
            cells.push(spec);
        }
        Ok(cells)
    }
}

fn parse_axis_value(
    name: &str,
    tok: &str,
    base: &RunConfig,
) -> Result<AxisValue> {
    let int = |t: &str| -> Result<u64> {
        t.parse::<u64>()
            .with_context(|| format!("bad value '{t}' for axis '{name}'"))
    };
    Ok(match name {
        "model" => AxisValue::Model(tok.into()),
        "bits" => {
            let (w, a) = parse_bits(tok)?;
            AxisValue::Bits(w, a)
        }
        "seed" => AxisValue::Seed(int(tok)?),
        "samples" => {
            let n = int(tok)? as usize;
            anyhow::ensure!(n > 0, "samples axis value must be > 0");
            AxisValue::Samples(n)
        }
        "data" | "mode" => AxisValue::Data(parse_data(tok, base)?),
        "quant" | "quantizer" => AxisValue::Quantizer(QuantArm::parse(tok)?),
        "precision" => AxisValue::Precision(Policy::parse(tok)?),
        "synthesis" | "engine" => AxisValue::Synthesis(Engine::parse(tok)?),
        other => bail!(
            "unknown axis '{other}' \
             (want model|bits|seed|samples|data|quant|precision|synthesis)"
        ),
    })
}

/// `4` → (4,4); `2/4` → (2,4); `w2a4` → (2,4). Validated 1..=8.
pub fn parse_bits(tok: &str) -> Result<(u32, u32)> {
    let parse_one = |t: &str| -> Result<u32> {
        let b = t
            .parse::<u32>()
            .with_context(|| format!("bad bit-width '{t}'"))?;
        validate_bits("bits", b)
    };
    if let Some(rest) = tok.strip_prefix('w') {
        let Some((w, a)) = rest.split_once('a') else {
            bail!("bad bits value '{tok}' (want B, W/A or wWaA)");
        };
        return Ok((parse_one(w)?, parse_one(a)?));
    }
    if let Some((w, a)) = tok.split_once('/') {
        return Ok((parse_one(w)?, parse_one(a)?));
    }
    let b = parse_one(tok)?;
    Ok((b, b))
}

fn parse_data(tok: &str, base: &RunConfig) -> Result<DataMode> {
    if matches!(tok, "real" | "fsq") {
        return Ok(DataMode::Real);
    }
    let (mode_tok, swing) = match tok.split_once('+') {
        Some((m, "swing")) => (m, true),
        Some((m, "noswing")) => (m, false),
        Some((_, other)) => {
            bail!("bad data suffix '+{other}' (want +swing|+noswing)")
        }
        None => (tok, base.distill.swing),
    };
    Ok(DataMode::Synthetic { mode: DistillMode::parse(mode_tok)?, swing })
}

/// One deduplicated stage of the merged cross-run DAG.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum StageKind {
    Teacher,
    Distill,
    Quantize,
    EvalFp,
    EvalQ,
}

impl StageKind {
    pub fn as_str(&self) -> &'static str {
        match self {
            StageKind::Teacher => "teacher",
            StageKind::Distill => "distill",
            StageKind::Quantize => "quantize",
            StageKind::EvalFp => "eval_fp32",
            StageKind::EvalQ => "eval_quant",
        }
    }
}

#[derive(Debug, Clone)]
pub struct StageNode {
    pub kind: StageKind,
    /// Spec key the node deduplicates on (never addresses a file).
    pub spec: CacheKey,
    pub label: String,
    /// Node indices that must complete first (always < this node's).
    pub deps: Vec<usize>,
    /// Cells served by this node (≥ 2 ⇒ deduplicated shared work).
    pub cells: Vec<usize>,
}

/// The lowered grid: cells plus their merged, deduplicated stage DAG.
#[derive(Debug)]
pub struct GridPlan {
    pub cells: Vec<RunSpec>,
    pub nodes: Vec<StageNode>,
    /// Per cell: its teacher node.
    pub teacher_of: Vec<usize>,
    /// Per cell: its distill node (`None` for real-data cells).
    pub distill_of: Vec<Option<usize>>,
    /// Per cell: quantize / eval nodes (`None` when built data-only).
    pub quantize_of: Vec<Option<usize>>,
    pub evalfp_of: Vec<Option<usize>>,
    pub evalq_of: Vec<Option<usize>>,
}

impl GridPlan {
    /// Lower cells onto the deduplicated stage DAG. `data_only` stops
    /// after the calibration data (the harness mode for reports that
    /// only need the shared synthetic sets). Nodes come out in
    /// topological order.
    pub fn build(
        cells: Vec<RunSpec>,
        manifests: &BTreeMap<String, Manifest>,
        data_only: bool,
    ) -> Result<GridPlan> {
        let n = cells.len();
        let mut plan = GridPlan {
            cells,
            nodes: Vec::new(),
            teacher_of: vec![0; n],
            distill_of: vec![None; n],
            quantize_of: vec![None; n],
            evalfp_of: vec![None; n],
            evalq_of: vec![None; n],
        };
        let mut by_spec: HashMap<u64, usize> = HashMap::new();
        let mut intern = |nodes: &mut Vec<StageNode>,
                          kind: StageKind,
                          spec: CacheKey,
                          label: String,
                          deps: Vec<usize>,
                          cell: usize|
         -> usize {
            let idx = *by_spec.entry(spec.0).or_insert_with(|| {
                nodes.push(StageNode {
                    kind,
                    spec,
                    label,
                    deps,
                    cells: Vec::new(),
                });
                nodes.len() - 1
            });
            if nodes[idx].cells.last() != Some(&cell) {
                nodes[idx].cells.push(cell);
            }
            idx
        };

        for c in 0..n {
            let spec = plan.cells[c].clone();
            let m = manifests.get(&spec.model).with_context(|| {
                format!("grid: no manifest for model '{}'", spec.model)
            })?;
            let tspec = artifacts::pretrain_key(m, &spec.pretrain);
            let t = intern(
                &mut plan.nodes,
                StageKind::Teacher,
                tspec,
                format!(
                    "teacher[{}] steps={} seed={}",
                    spec.model, spec.pretrain.steps, spec.pretrain.seed
                ),
                Vec::new(),
                c,
            );
            plan.teacher_of[c] = t;

            let calib_spec = match spec.data {
                DataMode::Synthetic { .. } => {
                    let dspec =
                        artifacts::distill_spec_key(m, &spec.distill, tspec);
                    let d = intern(
                        &mut plan.nodes,
                        StageKind::Distill,
                        dspec,
                        format!(
                            "distill[{}] {}{} x{} steps={} seed={}",
                            spec.model,
                            spec.distill.engine.display(spec.distill.mode),
                            if spec.distill.swing { "" } else { "+noswing" },
                            spec.distill.samples,
                            spec.distill.steps,
                            spec.distill.seed
                        ),
                        vec![t],
                        c,
                    );
                    plan.distill_of[c] = Some(d);
                    dspec
                }
                DataMode::Real => artifacts::real_calib_spec_key(
                    spec.fsq_samples,
                    spec.quant.seed ^ 0x5eed,
                ),
            };
            if data_only {
                continue;
            }

            let qspec =
                artifacts::quantize_spec_key(m, &spec.quant, tspec, calib_spec);
            let mut qdeps = vec![t];
            if let Some(d) = plan.distill_of[c] {
                qdeps.push(d);
            }
            let q = intern(
                &mut plan.nodes,
                StageKind::Quantize,
                qspec,
                format!(
                    "quantize[{}] w{}a{} {} steps={} seed={}",
                    spec.model,
                    spec.quant.wbits,
                    spec.quant.abits,
                    spec.quant.precision.policy.as_str(),
                    spec.quant.steps_per_block,
                    spec.quant.seed
                ),
                qdeps,
                c,
            );
            plan.quantize_of[c] = Some(q);

            let efp = intern(
                &mut plan.nodes,
                StageKind::EvalFp,
                artifacts::eval_fp_spec_key(m, tspec),
                format!("eval_fp32[{}]", spec.model),
                vec![t],
                c,
            );
            plan.evalfp_of[c] = Some(efp);
            let eq = intern(
                &mut plan.nodes,
                StageKind::EvalQ,
                artifacts::eval_q_spec_key(m, qspec),
                format!(
                    "eval_quant[{}] w{}a{}",
                    spec.model, spec.quant.wbits, spec.quant.abits
                ),
                vec![t, q],
                c,
            );
            plan.evalq_of[c] = Some(eq);
        }
        Ok(plan)
    }

    /// Dependency lists in [`crate::exec::waves`] shape.
    pub fn deps(&self) -> Vec<Vec<usize>> {
        self.nodes.iter().map(|n| n.deps.clone()).collect()
    }

    /// Per-node critical-path depth (longest chain of nodes hanging off
    /// each node, self-inclusive) — the dataflow scheduler's dispatch
    /// priorities (DESIGN.md §15), also reported by `--dry-run`.
    pub fn critical_depths(&self) -> Vec<usize> {
        crate::exec::critical_path(&self.deps())
    }

    /// Stage count a naive cell-by-cell execution would run (the dedupe
    /// baseline the dry run reports against).
    pub fn naive_stages(&self) -> usize {
        self.nodes.iter().map(|n| n.cells.len()).sum()
    }

    /// Node count by kind.
    pub fn count(&self, kind: StageKind) -> usize {
        self.nodes.iter().filter(|n| n.kind == kind).count()
    }

    /// Best-effort cache resolution for the dry run: walk the DAG in
    /// topo order resolving each node's *content* key from cached
    /// upstream artifacts. [`Cached::Unknown`] means an upstream must
    /// run first, so the content key (and thus the hit) is undecidable
    /// without executing.
    pub fn resolve_cached(
        &self,
        manifests: &BTreeMap<String, Manifest>,
        cache: &ArtifactCache,
        dataset: Option<&Dataset>,
    ) -> Vec<Cached> {
        self.resolve_with_pins(manifests, cache, dataset).0
    }

    /// The transitive artifact stems (`<kind>_<hexkey>`) this grid's
    /// dry-run resolution can name — the pin set `genie cache gc`
    /// protects, so a budget-squeezed store keeps exactly what the next
    /// grid run will read. Stems whose content key is undecidable (an
    /// upstream must run first) cannot be named and thus not pinned;
    /// those are exactly the stages the dry run already predicts will
    /// recompute.
    pub fn pin_stems(
        &self,
        manifests: &BTreeMap<String, Manifest>,
        cache: &ArtifactCache,
        dataset: Option<&Dataset>,
    ) -> std::collections::BTreeSet<String> {
        self.resolve_with_pins(manifests, cache, dataset).1
    }

    fn resolve_with_pins(
        &self,
        manifests: &BTreeMap<String, Manifest>,
        cache: &ArtifactCache,
        dataset: Option<&Dataset>,
    ) -> (Vec<Cached>, std::collections::BTreeSet<String>) {
        let mut out = vec![Cached::Run; self.nodes.len()];
        let mut pins = std::collections::BTreeSet::new();
        // per teacher node: the cached teacher's content hash
        let mut teacher_hash: HashMap<usize, u64> = HashMap::new();
        // per distill node: the cached synthetic images
        let mut images: HashMap<usize, Tensor> = HashMap::new();

        for (i, node) in self.nodes.iter().enumerate() {
            // any cell of the node carries the configs that key it
            let cell = &self.cells[node.cells[0]];
            let Some(m) = manifests.get(&cell.model) else { continue };
            match node.kind {
                StageKind::Teacher => {
                    if !cache.is_enabled() {
                        continue;
                    }
                    pins.insert(format!("teacher_{}", node.spec.hex()));
                    if let Some(s) = cache.peek("teacher", node.spec) {
                        out[i] = Cached::Hit;
                        teacher_hash.insert(i, s.content_hash());
                    }
                }
                StageKind::Distill => {
                    let Some(&th) = teacher_hash.get(&node.deps[0]) else {
                        out[i] = Cached::Unknown;
                        continue;
                    };
                    let key = artifacts::distill_key(m, &cell.distill, th);
                    pins.insert(format!("distill_{}", key.hex()));
                    // a parseable artifact without its images tensor is
                    // incoherent (e.g. a partial copy): execution treats
                    // it as a miss and recomputes, so the prediction
                    // must too — Hit only when the images are loadable
                    match cache.peek("distill", key) {
                        Some(art) => match art.get("images") {
                            Ok(t) => {
                                images.insert(i, t.clone());
                                out[i] = Cached::Hit;
                            }
                            Err(_) => out[i] = Cached::Run,
                        },
                        None => out[i] = Cached::Run,
                    }
                }
                StageKind::Quantize => {
                    let Some(&th) = teacher_hash.get(&node.deps[0]) else {
                        out[i] = Cached::Unknown;
                        continue;
                    };
                    let calib: Option<Tensor> = match cell.data {
                        DataMode::Synthetic { .. } => {
                            images.get(&node.deps[1]).cloned()
                        }
                        DataMode::Real => dataset.map(|ds| {
                            let mut rng =
                                Pcg32::new(cell.quant.seed ^ 0x5eed);
                            ds.calibration(&mut rng, cell.fsq_samples).0
                        }),
                    };
                    let Some(calib) = calib else {
                        out[i] = Cached::Unknown;
                        continue;
                    };
                    let plan = match cell.quant.precision.policy {
                        Policy::Uniform => PrecisionPlan::uniform(
                            m,
                            cell.quant.wbits,
                            cell.quant.abits,
                            cell.quant.precision.granularity,
                        )
                        .and_then(|p| {
                            p.with_first_last(
                                cell.quant.precision.first_last_bits,
                            )
                        })
                        .ok(),
                        Policy::Pareto => {
                            let pk = artifacts::plan_key(
                                m, &cell.quant, th, &calib,
                            );
                            pins.insert(format!("plan_{}", pk.hex()));
                            cache.peek("plan", pk).and_then(|s| {
                                PrecisionPlan::from_store(m, &s).ok()
                            })
                        }
                    };
                    let Some(plan) = plan else {
                        out[i] = Cached::Unknown;
                        continue;
                    };
                    let key = artifacts::quantize_key(
                        m, &cell.quant, th, &calib, &plan,
                    );
                    pins.insert(format!("qstate_{}", key.hex()));
                    if cache.contains("qstate", key) {
                        out[i] = Cached::Hit;
                    }
                }
                // evals have no artifacts; they always execute
                StageKind::EvalFp | StageKind::EvalQ => out[i] = Cached::Run,
            }
        }
        (out, pins)
    }

    /// Render the resolved DAG for `--dry-run`: cells, deduplicated
    /// stages with the cells they serve, and the expected cache
    /// disposition of each.
    pub fn render(
        &self,
        manifests: &BTreeMap<String, Manifest>,
        cache: &ArtifactCache,
        dataset: Option<&Dataset>,
    ) -> String {
        let cached = self.resolve_cached(manifests, cache, dataset);
        let mut s = String::new();
        s.push_str(&format!(
            "grid: {} cells, {} stage nodes ({} naive; {} deduplicated \
             away)\n",
            self.cells.len(),
            self.nodes.len(),
            self.naive_stages(),
            self.naive_stages() - self.nodes.len(),
        ));
        for c in &self.cells {
            s.push_str(&format!("  cell {}: {}\n", c.cell, c.label()));
        }
        let hits = cached.iter().filter(|&&c| c == Cached::Hit).count();
        let pending =
            cached.iter().filter(|&&c| c == Cached::Unknown).count();
        s.push_str(&format!(
            "expected: {} cached, {} run ({} undecidable until an \
             upstream runs)\n",
            hits,
            self.nodes.len() - hits,
            pending,
        ));
        let waves = crate::exec::waves(&self.deps());
        // depth = critical-path length: the dataflow scheduler's
        // dispatch priority for the node (longest chain first)
        let depths = self.critical_depths();
        s.push_str(&format!("schedule: {} waves\n", waves.len()));
        for (w, wave) in waves.iter().enumerate() {
            s.push_str(&format!("  wave {w}:\n"));
            for &i in wave {
                let node = &self.nodes[i];
                s.push_str(&format!(
                    "    [{i}] {} ({} cell{}) depth={} — {}\n",
                    node.label,
                    node.cells.len(),
                    if node.cells.len() == 1 { "" } else { "s" },
                    depths[i],
                    cached[i].as_str(),
                ));
            }
        }
        s
    }
}

/// Dry-run cache disposition of one stage node.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Cached {
    /// The artifact exists; the node will load, not compute.
    Hit,
    /// The node will compute (no artifact, or a stage with none).
    Run,
    /// Undecidable until an upstream runs (content key unresolved).
    Unknown,
}

impl Cached {
    pub fn as_str(&self) -> &'static str {
        match self {
            Cached::Hit => "cached",
            Cached::Run => "run",
            Cached::Unknown => "run (upstream pending)",
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn toy_manifest() -> Manifest {
        Manifest::from_json_text(
            r#"{
                "model": "toy", "image": [16, 16, 3], "num_classes": 10,
                "num_blocks": 2, "latent": 256,
                "batch": {"train": 64},
                "params": [], "bn": [], "qstate": [], "gen_params": [],
                "quant_layers": [], "learnable": {"0": []},
                "bounds": [], "entrypoints": {}
            }"#,
        )
        .unwrap()
    }

    fn manifests() -> BTreeMap<String, Manifest> {
        let mut m = BTreeMap::new();
        m.insert("toy".to_string(), toy_manifest());
        m
    }

    fn base() -> RunConfig {
        RunConfig { model: "toy".into(), ..Default::default() }
    }

    #[test]
    fn cells_expand_cartesian_in_axis_order() {
        let grid = RunGrid::new()
            .axis(
                "bits",
                vec![AxisValue::Bits(4, 4), AxisValue::Bits(2, 4)],
            )
            .axis("seed", vec![AxisValue::Seed(0), AxisValue::Seed(1)]);
        let cells = grid.cells(&base()).unwrap();
        assert_eq!(cells.len(), 4);
        // first axis outermost
        assert_eq!(cells[0].label(), "bits=w4a4 seed=0");
        assert_eq!(cells[1].label(), "bits=w4a4 seed=1");
        assert_eq!(cells[2].label(), "bits=w2a4 seed=0");
        assert_eq!(cells[3].quant.wbits, 2);
        assert_eq!(cells[3].coord("seed"), Some("1"));
        assert_eq!(cells[3].cell, 3);
    }

    #[test]
    fn seed_axis_fans_out_like_runconfig() {
        let grid = RunGrid::new().axis("seed", vec![AxisValue::Seed(99)]);
        let cells = grid.cells(&base()).unwrap();
        let mut want = base();
        want.set("seed", "99").unwrap();
        assert_eq!(cells[0].pretrain.seed, want.pretrain.seed);
        assert_eq!(cells[0].distill.seed, want.distill.seed);
        assert_eq!(cells[0].quant.seed, want.quant.seed);
    }

    #[test]
    fn empty_grid_is_the_base_cell() {
        let cells = RunGrid::new().cells(&base()).unwrap();
        assert_eq!(cells.len(), 1);
        assert_eq!(cells[0].label(), "cell0");
        assert_eq!(cells[0].quant.wbits, base().quant.wbits);
    }

    #[test]
    fn duplicate_or_empty_axes_rejected() {
        let dup = RunGrid::new()
            .axis("seed", vec![AxisValue::Seed(0)])
            .axis("seed", vec![AxisValue::Seed(1)]);
        assert!(dup.cells(&base()).is_err());
        let empty = RunGrid::new().axis("seed", vec![]);
        assert!(empty.cells(&base()).is_err());
    }

    #[test]
    fn parse_axis_forms() {
        let b = base();
        let mut g = RunGrid::new();
        g.parse_axis("bits=4,2/4,w3a3", &b).unwrap();
        g.parse_axis("seed=0,1", &b).unwrap();
        g.parse_axis("data=genie,direct+noswing,real", &b).unwrap();
        g.parse_axis("quant=genie_m,adaround+nodrop", &b).unwrap();
        g.parse_axis("samples=64,128", &b).unwrap();
        g.parse_axis("precision=uniform,pareto", &b).unwrap();
        g.parse_axis("synthesis=genie,zeroq,zaq", &b).unwrap();
        g.parse_axis("model=toy", &b).unwrap();
        assert_eq!(g.axes.len(), 8);
        assert_eq!(
            g.axes[0].values.iter().map(|v| v.label()).collect::<Vec<_>>(),
            vec!["w4a4", "w2a4", "w3a3"]
        );
        assert_eq!(g.axes[2].values[1].label(), "direct+noswing");
        assert_eq!(g.axes[2].values[2].label(), "real");
        assert_eq!(g.axes[3].values[1].label(), "adaround+nodrop");
        assert_eq!(
            g.axes[6].values.iter().map(|v| v.label()).collect::<Vec<_>>(),
            vec!["genie", "zeroq", "zaq"]
        );

        assert!(RunGrid::new().parse_axis("bits=0", &b).is_err());
        assert!(RunGrid::new().parse_axis("bits=9", &b).is_err());
        assert!(RunGrid::new().parse_axis("nope=1", &b).is_err());
        assert!(RunGrid::new().parse_axis("bits", &b).is_err());
        assert!(RunGrid::new().parse_axis("samples=0", &b).is_err());
        assert!(RunGrid::new().parse_axis("data=warp", &b).is_err());
        assert!(RunGrid::new().parse_axis("synthesis=synq", &b).is_err());
    }

    #[test]
    fn synthesis_axis_splits_distill_but_shares_the_teacher() {
        let grid = RunGrid::new().axis(
            "synthesis",
            vec![
                AxisValue::Synthesis(Engine::Genie),
                AxisValue::Synthesis(Engine::Zeroq),
            ],
        );
        let cells = grid.cells(&base()).unwrap();
        assert_eq!(cells[0].distill.engine, Engine::Genie);
        assert_eq!(cells[1].distill.engine, Engine::Zeroq);
        assert_eq!(cells[1].label(), "synthesis=zeroq");
        let plan = GridPlan::build(cells, &manifests(), false).unwrap();
        // the engine folds into the distill spec key, so each engine
        // gets its own synthesis node under one shared teacher
        assert_eq!(plan.count(StageKind::Teacher), 1);
        assert_eq!(plan.count(StageKind::Distill), 2);
        assert_ne!(plan.distill_of[0], plan.distill_of[1]);
        let d1 = plan.distill_of[1].unwrap();
        assert!(plan.nodes[d1].label.contains("zeroq"), "{}", plan.nodes[d1].label);
    }

    #[test]
    fn quant_arm_applies_ablation_fields() {
        let mut spec = RunSpec::base(&base());
        AxisValue::Quantizer(QuantArm { adaround: true, no_drop: true })
            .apply(&mut spec);
        assert_eq!(spec.quant.lr_sw, 0.0);
        assert_eq!(spec.quant.lr_sa, 0.0);
        assert_eq!(spec.quant.drop_p, 0.0);
    }

    #[test]
    fn plan_dedupes_shared_teacher_and_distill() {
        let grid = RunGrid::new().axis(
            "bits",
            vec![
                AxisValue::Bits(4, 4),
                AxisValue::Bits(3, 4),
                AxisValue::Bits(2, 4),
            ],
        );
        let cells = grid.cells(&base()).unwrap();
        let plan = GridPlan::build(cells, &manifests(), false).unwrap();
        // 3 cells share 1 teacher, 1 distill, 1 fp eval; quantize and
        // quantized eval stay per-cell
        assert_eq!(plan.count(StageKind::Teacher), 1);
        assert_eq!(plan.count(StageKind::Distill), 1);
        assert_eq!(plan.count(StageKind::EvalFp), 1);
        assert_eq!(plan.count(StageKind::Quantize), 3);
        assert_eq!(plan.count(StageKind::EvalQ), 3);
        assert_eq!(plan.nodes.len(), 9);
        assert_eq!(plan.naive_stages(), 3 * 5);
        let t = plan.teacher_of[0];
        assert_eq!(plan.nodes[t].cells, vec![0, 1, 2]);
        // every cell maps to a node of the right kind
        for c in 0..3 {
            assert_eq!(plan.teacher_of[c], t);
            assert_eq!(plan.distill_of[c], plan.distill_of[0]);
            let q = plan.quantize_of[c].unwrap();
            assert_eq!(plan.nodes[q].kind, StageKind::Quantize);
            assert_eq!(plan.nodes[q].cells, vec![c]);
        }
        // deps are topologically consistent; waves accept them
        let waves = crate::exec::waves(&plan.deps());
        assert_eq!(waves.len(), 4, "teacher -> distill -> quantize -> evalq");
    }

    #[test]
    fn different_seeds_split_the_distill_node() {
        let grid = RunGrid::new()
            .axis("seed", vec![AxisValue::Seed(0), AxisValue::Seed(1)]);
        let cells = grid.cells(&base()).unwrap();
        let plan = GridPlan::build(cells, &manifests(), false).unwrap();
        // seed fans into pretrain/distill/quant, so nothing dedupes
        assert_eq!(plan.count(StageKind::Teacher), 2);
        assert_eq!(plan.count(StageKind::Distill), 2);
    }

    #[test]
    fn real_data_cells_have_no_distill_node() {
        let grid = RunGrid::new()
            .axis("data", vec![AxisValue::Data(DataMode::Real)])
            .axis(
                "bits",
                vec![AxisValue::Bits(4, 4), AxisValue::Bits(2, 4)],
            );
        let cells = grid.cells(&base()).unwrap();
        assert!(cells.iter().all(|c| c.data.is_real()));
        let plan = GridPlan::build(cells, &manifests(), false).unwrap();
        assert_eq!(plan.count(StageKind::Distill), 0);
        assert_eq!(plan.count(StageKind::Quantize), 2);
        assert!(plan.distill_of.iter().all(|d| d.is_none()));
    }

    #[test]
    fn data_only_plan_stops_at_the_images() {
        let grid = RunGrid::new().axis(
            "bits",
            vec![AxisValue::Bits(4, 4), AxisValue::Bits(2, 4)],
        );
        let cells = grid.cells(&base()).unwrap();
        let plan = GridPlan::build(cells, &manifests(), true).unwrap();
        assert_eq!(plan.count(StageKind::Teacher), 1);
        assert_eq!(plan.count(StageKind::Distill), 1);
        assert_eq!(plan.count(StageKind::Quantize), 0);
        assert!(plan.quantize_of.iter().all(|q| q.is_none()));
    }

    #[test]
    fn partially_warm_cache_predicts_miss_not_hit() {
        let dir = std::env::temp_dir().join("genie_grid_partial_warm");
        let _ = std::fs::remove_dir_all(&dir);
        let mut cache = ArtifactCache::open(&dir, true, false).unwrap();
        let ms = manifests();
        let m = &ms["toy"];

        let cells = RunGrid::new().cells(&base()).unwrap();
        let cell = cells[0].clone();
        let plan = GridPlan::build(cells, &ms, false).unwrap();
        let t = plan.teacher_of[0];
        let d = plan.distill_of[0].unwrap();
        let q = plan.quantize_of[0].unwrap();

        // warm the teacher; its spec key doubles as its content key
        let mut teacher = Store::new();
        teacher.insert("w", Tensor::from_f32(&[2], vec![1.0, 2.0]));
        cache.store("teacher", plan.nodes[t].spec, &teacher).unwrap();
        let th = teacher.content_hash();

        // a distill artifact that parses but is missing its images
        // tensor (e.g. a partial copy from another cache): execution
        // would recompute, so the dry run must say "run", and the
        // downstream quantize stays undecidable
        let dkey = artifacts::distill_key(m, &cell.distill, th);
        let mut partial = Store::new();
        partial.insert("final_loss", Tensor::scalar_f32(0.5));
        cache.store("distill", dkey, &partial).unwrap();
        let got = plan.resolve_cached(&ms, &cache, None);
        assert_eq!(got[t], Cached::Hit);
        assert_eq!(got[d], Cached::Run, "incoherent artifact must miss");
        assert_eq!(got[q], Cached::Unknown);

        // the summary line reflects the prediction
        let text = plan.render(&ms, &cache, None);
        assert!(text.contains("expected: 1 cached"), "{text}");

        // once the artifact is coherent the same node predicts a hit
        let mut full = partial.clone();
        full.insert(
            "images",
            Tensor::from_f32(&[2, 2], vec![1.0, 2.0, 3.0, 4.0]),
        );
        cache.store("distill", dkey, &full).unwrap();
        let got = plan.resolve_cached(&ms, &cache, None);
        assert_eq!(got[d], Cached::Hit);
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn dry_run_renders_cells_waves_and_dispositions() {
        let grid = RunGrid::new().axis(
            "bits",
            vec![AxisValue::Bits(4, 4), AxisValue::Bits(2, 4)],
        );
        let cells = grid.cells(&base()).unwrap();
        let plan = GridPlan::build(cells, &manifests(), false).unwrap();
        let cache = ArtifactCache::disabled();
        let text = plan.render(&manifests(), &cache, None);
        assert!(text.contains("2 cells"), "{text}");
        assert!(text.contains("deduplicated away"), "{text}");
        assert!(text.contains("cell 0: bits=w4a4"), "{text}");
        assert!(text.contains("teacher[toy]"), "{text}");
        assert!(text.contains("(2 cells)"), "{text}");
        assert!(text.contains("wave 0"), "{text}");
        // nothing cached under a disabled cache: teacher runs, its
        // dependents are pending on it
        assert!(text.contains("— run"), "{text}");
        // critical-path depths: the shared teacher heads the longest
        // chain (teacher→distill→quantize→eval_quant = 4 nodes); evals
        // are sinks at depth 1
        assert!(text.contains("depth=4 —"), "{text}");
        assert!(text.contains("depth=1 —"), "{text}");
    }

    #[test]
    fn critical_depths_match_the_stage_chain() {
        let grid = RunGrid::new().axis(
            "bits",
            vec![AxisValue::Bits(4, 4), AxisValue::Bits(2, 4)],
        );
        let cells = grid.cells(&base()).unwrap();
        let plan = GridPlan::build(cells, &manifests(), false).unwrap();
        let depths = plan.critical_depths();
        assert_eq!(depths.len(), plan.nodes.len());
        // the deepest chain equals the wave count
        let waves = crate::exec::waves(&plan.deps());
        assert_eq!(
            depths.iter().copied().max().unwrap_or(0),
            waves.len()
        );
        for c in 0..plan.cells.len() {
            let t = plan.teacher_of[c];
            assert_eq!(depths[t], 4, "teacher heads the 4-stage chain");
            if let Some(e) = plan.evalq_of[c] {
                assert_eq!(depths[e], 1, "evals are sinks");
            }
            // depth decreases strictly down a dependency chain
            if let (Some(d), Some(q)) =
                (plan.distill_of[c], plan.quantize_of[c])
            {
                assert!(depths[t] > depths[d]);
                assert!(depths[d] > depths[q]);
            }
        }
    }
}

//! Minimal host-side tensor layer: shapes, typed storage, a PCG32 RNG and
//! the statistics helpers the coordinator needs (argmax accuracy, image
//! metrics). Device compute all lives in the AOT HLO graphs; this module
//! only shuffles, slices and initializes.

mod rng;
mod stats;

pub use rng::Pcg32;
pub use stats::{accuracy, checkerboard_energy, mean, std_dev};

/// Element type of a [`Tensor`]; mirrors the manifest's dtype strings.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum DType {
    F32,
    I32,
    U32,
}

impl DType {
    pub fn from_str(s: &str) -> anyhow::Result<Self> {
        match s {
            "f32" => Ok(DType::F32),
            "i32" => Ok(DType::I32),
            "u32" => Ok(DType::U32),
            other => anyhow::bail!("unknown dtype {other}"),
        }
    }
}

/// Typed storage.
#[derive(Debug, Clone, PartialEq)]
pub enum Data {
    F32(Vec<f32>),
    I32(Vec<i32>),
    U32(Vec<u32>),
}

/// A named-shape host tensor (row-major).
#[derive(Debug, Clone, PartialEq)]
pub struct Tensor {
    pub shape: Vec<usize>,
    pub data: Data,
}

impl Tensor {
    pub fn zeros(shape: &[usize]) -> Self {
        let n = shape.iter().product();
        Tensor { shape: shape.to_vec(), data: Data::F32(vec![0.0; n]) }
    }

    pub fn from_f32(shape: &[usize], data: Vec<f32>) -> Self {
        assert_eq!(shape.iter().product::<usize>(), data.len());
        Tensor { shape: shape.to_vec(), data: Data::F32(data) }
    }

    pub fn from_i32(shape: &[usize], data: Vec<i32>) -> Self {
        assert_eq!(shape.iter().product::<usize>(), data.len());
        Tensor { shape: shape.to_vec(), data: Data::I32(data) }
    }

    pub fn from_u32(shape: &[usize], data: Vec<u32>) -> Self {
        assert_eq!(shape.iter().product::<usize>(), data.len());
        Tensor { shape: shape.to_vec(), data: Data::U32(data) }
    }

    pub fn scalar_f32(v: f32) -> Self {
        Tensor { shape: vec![], data: Data::F32(vec![v]) }
    }

    /// Full tensor of a constant value.
    pub fn full(shape: &[usize], v: f32) -> Self {
        let n = shape.iter().product();
        Tensor { shape: shape.to_vec(), data: Data::F32(vec![v; n]) }
    }

    /// PRNG key tensor (uint32[2]) for the jax threefry impl.
    pub fn key(hi: u32, lo: u32) -> Self {
        Tensor::from_u32(&[2], vec![hi, lo])
    }

    pub fn numel(&self) -> usize {
        self.shape.iter().product()
    }

    /// Size of the raw element storage (every dtype is 4 bytes wide) —
    /// the unit of the runtime's host↔device transfer accounting.
    pub fn byte_len(&self) -> usize {
        self.numel() * 4
    }

    pub fn dtype(&self) -> DType {
        match self.data {
            Data::F32(_) => DType::F32,
            Data::I32(_) => DType::I32,
            Data::U32(_) => DType::U32,
        }
    }

    pub fn as_f32(&self) -> &[f32] {
        match &self.data {
            Data::F32(v) => v,
            _ => panic!("tensor is not f32"),
        }
    }

    pub fn as_f32_mut(&mut self) -> &mut [f32] {
        match &mut self.data {
            Data::F32(v) => v,
            _ => panic!("tensor is not f32"),
        }
    }

    pub fn as_i32(&self) -> &[i32] {
        match &self.data {
            Data::I32(v) => v,
            _ => panic!("tensor is not i32"),
        }
    }

    pub fn as_u32(&self) -> &[u32] {
        match &self.data {
            Data::U32(v) => v,
            _ => panic!("tensor is not u32"),
        }
    }

    pub fn scalar(&self) -> f32 {
        assert_eq!(self.numel(), 1, "scalar() on non-scalar tensor");
        match &self.data {
            Data::F32(v) => v[0],
            Data::I32(v) => v[0] as f32,
            Data::U32(v) => v[0] as f32,
        }
    }

    /// Gaussian init (Box–Muller over the given PCG stream).
    pub fn randn(shape: &[usize], rng: &mut Pcg32, std: f32) -> Self {
        let n: usize = shape.iter().product();
        let mut v = Vec::with_capacity(n);
        for _ in 0..n {
            v.push(rng.normal() * std);
        }
        Tensor { shape: shape.to_vec(), data: Data::F32(v) }
    }

    /// Copy rows `idx` of a [N, ...] tensor into a new [idx.len(), ...] one.
    pub fn gather_rows(&self, idx: &[usize]) -> Tensor {
        assert!(!self.shape.is_empty());
        let row: usize = self.shape[1..].iter().product();
        let mut shape = self.shape.clone();
        shape[0] = idx.len();
        match &self.data {
            Data::F32(v) => {
                let mut out = Vec::with_capacity(idx.len() * row);
                for &i in idx {
                    out.extend_from_slice(&v[i * row..(i + 1) * row]);
                }
                Tensor { shape, data: Data::F32(out) }
            }
            Data::I32(v) => {
                let mut out = Vec::with_capacity(idx.len() * row);
                for &i in idx {
                    out.extend_from_slice(&v[i * row..(i + 1) * row]);
                }
                Tensor { shape, data: Data::I32(out) }
            }
            Data::U32(v) => {
                let mut out = Vec::with_capacity(idx.len() * row);
                for &i in idx {
                    out.extend_from_slice(&v[i * row..(i + 1) * row]);
                }
                Tensor { shape, data: Data::U32(out) }
            }
        }
    }

    /// Concatenate along axis 0. All tensors must agree on trailing dims
    /// and dtype; like [`gather_rows`](Tensor::gather_rows) this is
    /// dtype-generic rather than f32-only.
    pub fn concat_rows(parts: &[&Tensor]) -> Tensor {
        assert!(!parts.is_empty());
        let tail = &parts[0].shape[1..];
        let dt = parts[0].dtype();
        let mut total = 0;
        for p in parts {
            assert_eq!(&p.shape[1..], tail, "concat_rows: trailing dims differ");
            assert_eq!(p.dtype(), dt, "concat_rows: dtypes differ");
            total += p.shape[0];
        }
        let mut shape = parts[0].shape.clone();
        shape[0] = total;
        let n: usize = shape.iter().product();
        let data = match dt {
            DType::F32 => {
                let mut out = Vec::with_capacity(n);
                for p in parts {
                    out.extend_from_slice(p.as_f32());
                }
                Data::F32(out)
            }
            DType::I32 => {
                let mut out = Vec::with_capacity(n);
                for p in parts {
                    out.extend_from_slice(p.as_i32());
                }
                Data::I32(out)
            }
            DType::U32 => {
                let mut out = Vec::with_capacity(n);
                for p in parts {
                    out.extend_from_slice(p.as_u32());
                }
                Data::U32(out)
            }
        };
        Tensor { shape, data }
    }

    /// Stack same-shaped tensors along a NEW leading axis: `n` tensors
    /// of shape `s` become one `[n, ...s]` tensor (scalars stack to
    /// `[n]`). The batched-upload primitive of the fused dispatch path:
    /// K steps' host feeds for one argument travel as a single H2D, and
    /// the unrolled device program reads slice `i` per step. Dtype-
    /// generic like [`concat_rows`](Self::concat_rows).
    pub fn stack_outer(parts: &[&Tensor]) -> Tensor {
        assert!(!parts.is_empty(), "stack_outer of zero tensors");
        let tail = &parts[0].shape;
        let dt = parts[0].dtype();
        for p in parts {
            assert_eq!(&p.shape, tail, "stack_outer: shapes differ");
            assert_eq!(p.dtype(), dt, "stack_outer: dtypes differ");
        }
        let mut shape = Vec::with_capacity(tail.len() + 1);
        shape.push(parts.len());
        shape.extend_from_slice(tail);
        let n: usize = shape.iter().product();
        let data = match dt {
            DType::F32 => {
                let mut out = Vec::with_capacity(n);
                for p in parts {
                    out.extend_from_slice(p.as_f32());
                }
                Data::F32(out)
            }
            DType::I32 => {
                let mut out = Vec::with_capacity(n);
                for p in parts {
                    out.extend_from_slice(p.as_i32());
                }
                Data::I32(out)
            }
            DType::U32 => {
                let mut out = Vec::with_capacity(n);
                for p in parts {
                    out.extend_from_slice(p.as_u32());
                }
                Data::U32(out)
            }
        };
        Tensor { shape, data }
    }

    /// First `n` rows of a [N, ...] tensor — a single prefix slice copy
    /// (no index vector, no per-row gather).
    pub fn take_rows(&self, n: usize) -> Tensor {
        assert!(!self.shape.is_empty(), "take_rows on rank-0 tensor");
        assert!(
            n <= self.shape[0],
            "take_rows: {n} rows from a [{}, ...] tensor",
            self.shape[0]
        );
        let row: usize = self.shape[1..].iter().product();
        let mut shape = self.shape.clone();
        shape[0] = n;
        let data = match &self.data {
            Data::F32(v) => Data::F32(v[..n * row].to_vec()),
            Data::I32(v) => Data::I32(v[..n * row].to_vec()),
            Data::U32(v) => Data::U32(v[..n * row].to_vec()),
        };
        Tensor { shape, data }
    }

    /// Drop every row past `n` in place: no copy at all, the backing vec
    /// just shrinks. The in-place sibling of [`take_rows`](Self::take_rows)
    /// for freshly-built tensors (e.g. trimming a concat to the requested
    /// sample count).
    pub fn truncate_rows(&mut self, n: usize) {
        assert!(!self.shape.is_empty(), "truncate_rows on rank-0 tensor");
        assert!(
            n <= self.shape[0],
            "truncate_rows: {n} rows from a [{}, ...] tensor",
            self.shape[0]
        );
        let row: usize = self.shape[1..].iter().product();
        match &mut self.data {
            Data::F32(v) => v.truncate(n * row),
            Data::I32(v) => v.truncate(n * row),
            Data::U32(v) => v.truncate(n * row),
        }
        self.shape[0] = n;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn zeros_shape_numel() {
        let t = Tensor::zeros(&[2, 3, 4]);
        assert_eq!(t.numel(), 24);
        assert_eq!(t.dtype(), DType::F32);
        assert!(t.as_f32().iter().all(|&x| x == 0.0));
    }

    #[test]
    fn scalar_roundtrip() {
        assert_eq!(Tensor::scalar_f32(3.5).scalar(), 3.5);
    }

    #[test]
    #[should_panic]
    fn scalar_on_vector_panics() {
        Tensor::from_f32(&[2], vec![1.0, 2.0]).scalar();
    }

    #[test]
    fn gather_rows_picks_rows() {
        let t = Tensor::from_f32(&[3, 2], vec![0., 1., 10., 11., 20., 21.]);
        let g = t.gather_rows(&[2, 0]);
        assert_eq!(g.shape, vec![2, 2]);
        assert_eq!(g.as_f32(), &[20., 21., 0., 1.]);
    }

    #[test]
    fn concat_rows_stacks() {
        let a = Tensor::from_f32(&[1, 2], vec![1., 2.]);
        let b = Tensor::from_f32(&[2, 2], vec![3., 4., 5., 6.]);
        let c = Tensor::concat_rows(&[&a, &b]);
        assert_eq!(c.shape, vec![3, 2]);
        assert_eq!(c.as_f32(), &[1., 2., 3., 4., 5., 6.]);
    }

    #[test]
    fn concat_rows_is_dtype_generic() {
        // regression: this used to panic via as_f32() on non-f32 inputs
        let a = Tensor::from_i32(&[1, 2], vec![1, 2]);
        let b = Tensor::from_i32(&[2, 2], vec![3, 4, 5, 6]);
        let c = Tensor::concat_rows(&[&a, &b]);
        assert_eq!(c.shape, vec![3, 2]);
        assert_eq!(c.as_i32(), &[1, 2, 3, 4, 5, 6]);

        let u = Tensor::from_u32(&[1, 2], vec![7, 8]);
        let v = Tensor::from_u32(&[1, 2], vec![9, 10]);
        let w = Tensor::concat_rows(&[&u, &v]);
        assert_eq!(w.dtype(), DType::U32);
        assert_eq!(w.as_u32(), &[7, 8, 9, 10]);
    }

    #[test]
    #[should_panic(expected = "dtypes differ")]
    fn concat_rows_rejects_mixed_dtypes() {
        let a = Tensor::from_f32(&[1, 2], vec![1.0, 2.0]);
        let b = Tensor::from_i32(&[1, 2], vec![3, 4]);
        Tensor::concat_rows(&[&a, &b]);
    }

    #[test]
    fn stack_outer_adds_a_leading_axis() {
        let a = Tensor::from_f32(&[2], vec![1., 2.]);
        let b = Tensor::from_f32(&[2], vec![3., 4.]);
        let s = Tensor::stack_outer(&[&a, &b]);
        assert_eq!(s.shape, vec![2, 2]);
        assert_eq!(s.as_f32(), &[1., 2., 3., 4.]);
        // scalars stack to a vector — the fused trace-upload shape
        let t1 = Tensor::scalar_f32(0.1);
        let t2 = Tensor::scalar_f32(0.2);
        let t3 = Tensor::scalar_f32(0.3);
        let v = Tensor::stack_outer(&[&t1, &t2, &t3]);
        assert_eq!(v.shape, vec![3]);
        assert_eq!(v.as_f32(), &[0.1, 0.2, 0.3]);
        // dtype-generic: u32 keys stack too
        let k1 = Tensor::key(1, 2);
        let k2 = Tensor::key(3, 4);
        let ks = Tensor::stack_outer(&[&k1, &k2]);
        assert_eq!(ks.shape, vec![2, 2]);
        assert_eq!(ks.as_u32(), &[1, 2, 3, 4]);
    }

    #[test]
    #[should_panic(expected = "shapes differ")]
    fn stack_outer_rejects_mixed_shapes() {
        let a = Tensor::from_f32(&[2], vec![1., 2.]);
        let b = Tensor::scalar_f32(3.0);
        Tensor::stack_outer(&[&a, &b]);
    }

    #[test]
    fn take_rows_is_a_prefix_copy() {
        let t = Tensor::from_f32(&[3, 2], vec![0., 1., 10., 11., 20., 21.]);
        let p = t.take_rows(2);
        assert_eq!(p.shape, vec![2, 2]);
        assert_eq!(p.as_f32(), &[0., 1., 10., 11.]);
        // full take and empty take are both well-defined
        assert_eq!(t.take_rows(3), t);
        assert_eq!(t.take_rows(0).numel(), 0);
        // dtype-generic
        let i = Tensor::from_i32(&[2, 2], vec![1, 2, 3, 4]);
        assert_eq!(i.take_rows(1).as_i32(), &[1, 2]);
    }

    #[test]
    #[should_panic(expected = "take_rows")]
    fn take_rows_rejects_overrun() {
        Tensor::from_f32(&[2, 1], vec![1.0, 2.0]).take_rows(3);
    }

    #[test]
    fn truncate_rows_shrinks_in_place() {
        let mut t = Tensor::from_u32(&[3, 2], vec![1, 2, 3, 4, 5, 6]);
        t.truncate_rows(2);
        assert_eq!(t.shape, vec![2, 2]);
        assert_eq!(t.as_u32(), &[1, 2, 3, 4]);
        let mut f = Tensor::from_f32(&[2, 2], vec![1., 2., 3., 4.]);
        let copy = f.take_rows(1);
        f.truncate_rows(1);
        assert_eq!(f, copy, "truncate_rows must agree with take_rows");
    }

    #[test]
    fn byte_len_counts_four_bytes_per_element() {
        assert_eq!(Tensor::zeros(&[2, 3]).byte_len(), 24);
        assert_eq!(Tensor::key(1, 2).byte_len(), 8);
        assert_eq!(Tensor::scalar_f32(0.0).byte_len(), 4);
    }

    #[test]
    fn randn_reproducible() {
        let mut r1 = Pcg32::new(42);
        let mut r2 = Pcg32::new(42);
        let a = Tensor::randn(&[8], &mut r1, 1.0);
        let b = Tensor::randn(&[8], &mut r2, 1.0);
        assert_eq!(a, b);
    }

    #[test]
    fn key_is_u32_pair() {
        let k = Tensor::key(1, 2);
        assert_eq!(k.dtype(), DType::U32);
        assert_eq!(k.as_u32(), &[1, 2]);
    }
}

//! Statistics helpers: accuracy from logits, and the checkerboard-artifact
//! energy metric used by the Fig. 5 reproduction.

use super::Tensor;

pub fn mean(xs: &[f32]) -> f32 {
    if xs.is_empty() {
        return 0.0;
    }
    xs.iter().sum::<f32>() / xs.len() as f32
}

pub fn std_dev(xs: &[f32]) -> f32 {
    if xs.len() < 2 {
        return 0.0;
    }
    let m = mean(xs);
    (xs.iter().map(|x| (x - m) * (x - m)).sum::<f32>() / xs.len() as f32)
        .sqrt()
}

/// Top-1 accuracy of logits [N, C] against labels [N] over the first
/// `n` rows (n <= N handles a padded final batch).
pub fn accuracy(logits: &Tensor, labels: &[i32], n: usize) -> f32 {
    let c = logits.shape[1];
    let v = logits.as_f32();
    let mut correct = 0usize;
    for i in 0..n {
        let row = &v[i * c..(i + 1) * c];
        let mut best = 0usize;
        for j in 1..c {
            if row[j] > row[best] {
                best = j;
            }
        }
        if best as i32 == labels[i] {
            correct += 1;
        }
    }
    correct as f32 / n as f32
}

/// Checkerboard-artifact energy of an image batch [N, H, W, C]:
/// the fraction of total (per-image, per-channel) variance that lives in
/// the 2x2 Haar HH band — i.e. energy at the stride-2 Nyquist pattern that
/// transposed-conv backprop imprints (Odena et al.; paper section 3.1.1).
pub fn checkerboard_energy(images: &Tensor) -> f32 {
    let (n, h, w, c) = (
        images.shape[0],
        images.shape[1],
        images.shape[2],
        images.shape[3],
    );
    let v = images.as_f32();
    let at = |i: usize, y: usize, x: usize, ch: usize| {
        v[((i * h + y) * w + x) * c + ch]
    };
    let mut hh_energy = 0.0f64;
    let mut total = 0.0f64;
    for i in 0..n {
        for ch in 0..c {
            // image mean for total-variance normalization
            let mut m = 0.0f64;
            for y in 0..h {
                for x in 0..w {
                    m += at(i, y, x, ch) as f64;
                }
            }
            m /= (h * w) as f64;
            for y in 0..h {
                for x in 0..w {
                    let d = at(i, y, x, ch) as f64 - m;
                    total += d * d;
                }
            }
            for y in (0..h - 1).step_by(2) {
                for x in (0..w - 1).step_by(2) {
                    let hhv = (at(i, y, x, ch) - at(i, y, x + 1, ch)
                        - at(i, y + 1, x, ch)
                        + at(i, y + 1, x + 1, ch))
                        as f64
                        / 4.0;
                    hh_energy += hhv * hhv * 4.0;
                }
            }
        }
    }
    if total == 0.0 {
        0.0
    } else {
        (hh_energy / total) as f32
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn accuracy_counts() {
        let logits =
            Tensor::from_f32(&[3, 2], vec![1.0, 0.0, 0.0, 1.0, 2.0, 3.0]);
        let acc = accuracy(&logits, &[0, 1, 0], 3);
        assert!((acc - 2.0 / 3.0).abs() < 1e-6);
    }

    #[test]
    fn accuracy_partial_batch() {
        let logits =
            Tensor::from_f32(&[3, 2], vec![1.0, 0.0, 0.0, 1.0, 9.0, 0.0]);
        assert_eq!(accuracy(&logits, &[0, 1], 2), 1.0);
    }

    #[test]
    fn checkerboard_flags_alternating_pattern() {
        // pure +1/-1 checkerboard: all variance in the HH band
        let mut v = vec![0.0f32; 8 * 8];
        for y in 0..8 {
            for x in 0..8 {
                v[y * 8 + x] = if (x + y) % 2 == 0 { 1.0 } else { -1.0 };
            }
        }
        let img = Tensor::from_f32(&[1, 8, 8, 1], v);
        let e = checkerboard_energy(&img);
        assert!(e > 0.9, "checkerboard energy {e}");
    }

    #[test]
    fn checkerboard_low_for_smooth_gradient() {
        let mut v = vec![0.0f32; 8 * 8];
        for y in 0..8 {
            for x in 0..8 {
                v[y * 8 + x] = (x as f32) / 8.0 + (y as f32) / 16.0;
            }
        }
        let img = Tensor::from_f32(&[1, 8, 8, 1], v);
        let e = checkerboard_energy(&img);
        assert!(e < 0.05, "smooth energy {e}");
    }

    #[test]
    fn checkerboard_constant_image_is_zero() {
        let img = Tensor::full(&[1, 4, 4, 1], 2.0);
        assert_eq!(checkerboard_energy(&img), 0.0);
    }

    #[test]
    fn std_dev_basic() {
        assert!((std_dev(&[1.0, 3.0]) - 1.0).abs() < 1e-6);
        assert_eq!(std_dev(&[5.0]), 0.0);
    }
}

//! PCG32: small deterministic RNG for everything the coordinator
//! randomizes host-side (batch shuffling, latent init, jax key derivation).
//! Device-side randomness (swing offsets, QDrop masks) is jax threefry,
//! keyed by u32 pairs this RNG emits — so a pipeline run is reproducible
//! from a single seed.

#[derive(Debug, Clone)]
pub struct Pcg32 {
    state: u64,
    inc: u64,
}

impl Pcg32 {
    pub fn new(seed: u64) -> Self {
        let mut r = Pcg32 { state: 0, inc: (seed << 1) | 1 };
        r.next_u32();
        r.state = r.state.wrapping_add(0x853c49e6748fea9b ^ seed);
        r.next_u32();
        r
    }

    pub fn next_u32(&mut self) -> u32 {
        let old = self.state;
        self.state = old
            .wrapping_mul(6364136223846793005)
            .wrapping_add(self.inc);
        let xorshifted = (((old >> 18) ^ old) >> 27) as u32;
        let rot = (old >> 59) as u32;
        xorshifted.rotate_right(rot)
    }

    /// Uniform in [0, 1).
    pub fn uniform(&mut self) -> f32 {
        (self.next_u32() >> 8) as f32 * (1.0 / (1u32 << 24) as f32)
    }

    /// Uniform integer in [0, n).
    pub fn below(&mut self, n: usize) -> usize {
        (self.next_u32() as u64 * n as u64 >> 32) as usize
    }

    /// Standard normal via Box–Muller.
    pub fn normal(&mut self) -> f32 {
        let u1 = self.uniform().max(1e-10);
        let u2 = self.uniform();
        (-2.0 * u1.ln()).sqrt() * (2.0 * std::f32::consts::PI * u2).cos()
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            xs.swap(i, self.below(i + 1));
        }
    }

    /// A fresh jax-style key pair.
    pub fn key_pair(&mut self) -> (u32, u32) {
        (self.next_u32(), self.next_u32())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let mut a = Pcg32::new(7);
        let mut b = Pcg32::new(7);
        for _ in 0..100 {
            assert_eq!(a.next_u32(), b.next_u32());
        }
    }

    #[test]
    fn seeds_differ() {
        let mut a = Pcg32::new(1);
        let mut b = Pcg32::new(2);
        assert_ne!(
            (0..8).map(|_| a.next_u32()).collect::<Vec<_>>(),
            (0..8).map(|_| b.next_u32()).collect::<Vec<_>>()
        );
    }

    #[test]
    fn uniform_in_range() {
        let mut r = Pcg32::new(3);
        for _ in 0..1000 {
            let u = r.uniform();
            assert!((0.0..1.0).contains(&u));
        }
    }

    #[test]
    fn below_in_range() {
        let mut r = Pcg32::new(4);
        for _ in 0..1000 {
            assert!(r.below(17) < 17);
        }
    }

    #[test]
    fn normal_moments() {
        let mut r = Pcg32::new(5);
        let xs: Vec<f32> = (0..20000).map(|_| r.normal()).collect();
        let m = xs.iter().sum::<f32>() / xs.len() as f32;
        let v = xs.iter().map(|x| (x - m) * (x - m)).sum::<f32>()
            / xs.len() as f32;
        assert!(m.abs() < 0.03, "mean {m}");
        assert!((v - 1.0).abs() < 0.05, "var {v}");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Pcg32::new(6);
        let mut xs: Vec<usize> = (0..50).collect();
        r.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort();
        assert_eq!(sorted, (0..50).collect::<Vec<_>>());
    }
}

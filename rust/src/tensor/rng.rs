//! PCG32: small deterministic RNG for everything the coordinator
//! randomizes host-side (batch shuffling, latent init, jax key derivation).
//! Device-side randomness (swing offsets, QDrop masks) is jax threefry,
//! keyed by u32 pairs this RNG emits — so a pipeline run is reproducible
//! from a single seed.

#[derive(Debug, Clone)]
pub struct Pcg32 {
    state: u64,
    inc: u64,
}

impl Pcg32 {
    pub fn new(seed: u64) -> Self {
        let mut r = Pcg32 { state: 0, inc: (seed << 1) | 1 };
        r.next_u32();
        r.state = r.state.wrapping_add(0x853c49e6748fea9b ^ seed);
        r.next_u32();
        r
    }

    /// An independent stream keyed by `(seed, shard)` — the reproducibility
    /// primitive of the exec worker pool (DESIGN.md §5). PCG32 selects its
    /// sequence by the (odd) increment, so hashing the shard id into both
    /// the increment and the initial state yields streams that are
    /// deterministic in `(seed, shard)` and independent across shards,
    /// no matter which worker thread or execution order consumes them.
    pub fn new_stream(seed: u64, shard: u64) -> Self {
        let mix = splitmix64(shard.wrapping_add(0x9e3779b97f4a7c15));
        let mut r = Pcg32 { state: 0, inc: (mix << 1) | 1 };
        r.next_u32();
        r.state = r
            .state
            .wrapping_add(0x853c49e6748fea9b ^ seed ^ splitmix64(mix ^ seed));
        r.next_u32();
        r
    }

    pub fn next_u32(&mut self) -> u32 {
        let old = self.state;
        self.state = old
            .wrapping_mul(6364136223846793005)
            .wrapping_add(self.inc);
        let xorshifted = (((old >> 18) ^ old) >> 27) as u32;
        let rot = (old >> 59) as u32;
        xorshifted.rotate_right(rot)
    }

    /// Uniform in [0, 1).
    pub fn uniform(&mut self) -> f32 {
        (self.next_u32() >> 8) as f32 * (1.0 / (1u32 << 24) as f32)
    }

    /// Uniform integer in [0, n).
    pub fn below(&mut self, n: usize) -> usize {
        (self.next_u32() as u64 * n as u64 >> 32) as usize
    }

    /// Standard normal via Box–Muller.
    pub fn normal(&mut self) -> f32 {
        let u1 = self.uniform().max(1e-10);
        let u2 = self.uniform();
        (-2.0 * u1.ln()).sqrt() * (2.0 * std::f32::consts::PI * u2).cos()
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            xs.swap(i, self.below(i + 1));
        }
    }

    /// A fresh jax-style key pair.
    pub fn key_pair(&mut self) -> (u32, u32) {
        (self.next_u32(), self.next_u32())
    }

    /// Raw `(state, inc)` — the complete generator state, serialized into
    /// phase checkpoints so a resumed run continues the exact stream
    /// (DESIGN.md §9).
    pub fn raw(&self) -> (u64, u64) {
        (self.state, self.inc)
    }

    /// Rebuild a generator from checkpointed raw state; the next draw is
    /// bit-identical to what the saved generator would have produced.
    pub fn from_raw(state: u64, inc: u64) -> Self {
        Pcg32 { state, inc }
    }
}

/// SplitMix64 finalizer: a cheap, well-mixed u64 -> u64 hash.
fn splitmix64(x: u64) -> u64 {
    let mut z = x.wrapping_add(0x9e3779b97f4a7c15);
    z = (z ^ (z >> 30)).wrapping_mul(0xbf58476d1ce4e5b9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94d049bb133111eb);
    z ^ (z >> 31)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let mut a = Pcg32::new(7);
        let mut b = Pcg32::new(7);
        for _ in 0..100 {
            assert_eq!(a.next_u32(), b.next_u32());
        }
    }

    #[test]
    fn seeds_differ() {
        let mut a = Pcg32::new(1);
        let mut b = Pcg32::new(2);
        assert_ne!(
            (0..8).map(|_| a.next_u32()).collect::<Vec<_>>(),
            (0..8).map(|_| b.next_u32()).collect::<Vec<_>>()
        );
    }

    #[test]
    fn uniform_in_range() {
        let mut r = Pcg32::new(3);
        for _ in 0..1000 {
            let u = r.uniform();
            assert!((0.0..1.0).contains(&u));
        }
    }

    #[test]
    fn below_in_range() {
        let mut r = Pcg32::new(4);
        for _ in 0..1000 {
            assert!(r.below(17) < 17);
        }
    }

    #[test]
    fn normal_moments() {
        let mut r = Pcg32::new(5);
        let xs: Vec<f32> = (0..20000).map(|_| r.normal()).collect();
        let m = xs.iter().sum::<f32>() / xs.len() as f32;
        let v = xs.iter().map(|x| (x - m) * (x - m)).sum::<f32>()
            / xs.len() as f32;
        assert!(m.abs() < 0.03, "mean {m}");
        assert!((v - 1.0).abs() < 0.05, "var {v}");
    }

    #[test]
    fn stream_deterministic_in_seed_and_shard() {
        let mut a = Pcg32::new_stream(7, 3);
        let mut b = Pcg32::new_stream(7, 3);
        for _ in 0..100 {
            assert_eq!(a.next_u32(), b.next_u32());
        }
    }

    #[test]
    fn streams_differ_by_shard_and_seed() {
        let draw = |seed, shard| {
            let mut r = Pcg32::new_stream(seed, shard);
            (0..16).map(|_| r.next_u32()).collect::<Vec<_>>()
        };
        assert_ne!(draw(7, 0), draw(7, 1));
        assert_ne!(draw(7, 1), draw(7, 2));
        assert_ne!(draw(7, 0), draw(8, 0));
        // and a stream is not the plain seeded sequence shifted
        let mut plain = Pcg32::new(7);
        let plain16: Vec<u32> = (0..16).map(|_| plain.next_u32()).collect();
        assert_ne!(draw(7, 0), plain16);
    }

    #[test]
    fn stream_prefixes_do_not_collide() {
        // 64 shards x 8 draws: all 8-draw prefixes pairwise distinct.
        let mut seen = std::collections::HashSet::new();
        for shard in 0..64u64 {
            let mut r = Pcg32::new_stream(99, shard);
            let prefix: Vec<u32> = (0..8).map(|_| r.next_u32()).collect();
            assert!(seen.insert(prefix), "shard {shard} prefix collided");
        }
    }

    #[test]
    fn raw_roundtrip_continues_stream() {
        let mut a = Pcg32::new_stream(9, 4);
        for _ in 0..17 {
            a.next_u32();
        }
        let (state, inc) = a.raw();
        let mut b = Pcg32::from_raw(state, inc);
        for _ in 0..50 {
            assert_eq!(a.next_u32(), b.next_u32());
        }
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Pcg32::new(6);
        let mut xs: Vec<usize> = (0..50).collect();
        r.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort();
        assert_eq!(sorted, (0..50).collect::<Vec<_>>());
    }
}

//! manifest.json schema — the contract between python/compile/entries.py
//! (which writes it) and the runtime (which wires buffers purely by these
//! names and shapes). Parsed with the in-tree JSON parser (json.rs).

use std::collections::HashMap;
use std::path::Path;

use anyhow::{Context, Result};

use super::json::Json;

pub type NamedShape = (String, Vec<usize>);
/// (name, dtype, shape)
pub type ArgSpec = (String, String, Vec<usize>);

#[derive(Debug, Clone)]
pub struct QuantLayer {
    pub name: String,
    pub w_shape: Vec<usize>,
    pub out_ch: usize,
    pub flat_k: usize,
    pub block: usize,
}

#[derive(Debug, Clone)]
pub struct EntrySpec {
    pub file: String,
    pub args: Vec<ArgSpec>,
    pub results: Vec<ArgSpec>,
}

#[derive(Debug, Clone)]
pub struct Manifest {
    pub model: String,
    pub image: Vec<usize>,
    pub num_classes: usize,
    pub num_blocks: usize,
    pub latent: usize,
    pub batch: HashMap<String, usize>,
    pub params: Vec<NamedShape>,
    pub bn: Vec<NamedShape>,
    pub qstate: Vec<NamedShape>,
    pub gen_params: Vec<NamedShape>,
    pub quant_layers: Vec<QuantLayer>,
    pub learnable: HashMap<String, Vec<String>>,
    pub bounds: Vec<Vec<usize>>,
    pub entrypoints: HashMap<String, EntrySpec>,
}

fn named_shapes(j: &Json) -> Result<Vec<NamedShape>> {
    j.as_arr()?
        .iter()
        .map(|e| {
            let pair = e.as_arr()?;
            Ok((pair[0].as_str()?.to_string(), pair[1].usize_vec()?))
        })
        .collect()
}

fn arg_specs(j: &Json) -> Result<Vec<ArgSpec>> {
    j.as_arr()?
        .iter()
        .map(|e| {
            let t = e.as_arr()?;
            Ok((
                t[0].as_str()?.to_string(),
                t[1].as_str()?.to_string(),
                t[2].usize_vec()?,
            ))
        })
        .collect()
}

impl Manifest {
    pub fn load(model_dir: impl AsRef<Path>) -> Result<Manifest> {
        let p = model_dir.as_ref().join("manifest.json");
        let text = std::fs::read_to_string(&p)
            .with_context(|| format!("read {p:?} (run `make artifacts`)"))?;
        Self::from_json_text(&text).context("parse manifest.json")
    }

    pub fn from_json_text(text: &str) -> Result<Manifest> {
        let j = Json::parse(text)?;
        let mut batch = HashMap::new();
        for (k, v) in j.get("batch")?.as_obj()? {
            batch.insert(k.clone(), v.as_usize()?);
        }
        let quant_layers = j
            .get("quant_layers")?
            .as_arr()?
            .iter()
            .map(|q| {
                Ok(QuantLayer {
                    name: q.get("name")?.as_str()?.to_string(),
                    w_shape: q.get("w_shape")?.usize_vec()?,
                    out_ch: q.get("out_ch")?.as_usize()?,
                    flat_k: q.get("flat_k")?.as_usize()?,
                    block: q.get("block")?.as_usize()?,
                })
            })
            .collect::<Result<Vec<_>>>()?;
        let mut learnable = HashMap::new();
        for (k, v) in j.get("learnable")?.as_obj()? {
            learnable.insert(k.clone(), v.str_vec()?);
        }
        let bounds = j
            .get("bounds")?
            .as_arr()?
            .iter()
            .map(|b| b.usize_vec())
            .collect::<Result<Vec<_>>>()?;
        let mut entrypoints = HashMap::new();
        for (name, e) in j.get("entrypoints")?.as_obj()? {
            entrypoints.insert(
                name.clone(),
                EntrySpec {
                    file: e.get("file")?.as_str()?.to_string(),
                    args: arg_specs(e.get("args")?)?,
                    results: arg_specs(e.get("results")?)?,
                },
            );
        }
        Ok(Manifest {
            model: j.get("model")?.as_str()?.to_string(),
            image: j.get("image")?.usize_vec()?,
            num_classes: j.get("num_classes")?.as_usize()?,
            num_blocks: j.get("num_blocks")?.as_usize()?,
            latent: j.get("latent")?.as_usize()?,
            batch,
            params: named_shapes(j.get("params")?)?,
            bn: named_shapes(j.get("bn")?)?,
            qstate: named_shapes(j.get("qstate")?)?,
            gen_params: named_shapes(j.get("gen_params")?)?,
            quant_layers,
            learnable,
            bounds,
            entrypoints,
        })
    }

    pub fn entry(&self, name: &str) -> Result<&EntrySpec> {
        self.entrypoints
            .get(name)
            .ok_or_else(|| anyhow::anyhow!("manifest: no entrypoint '{name}'"))
    }

    pub fn batch(&self, kind: &str) -> usize {
        self.batch[kind]
    }

    /// Learnable quant-state names of a block (sw / v / sa triplets).
    pub fn learnable_block(&self, b: usize) -> &[String] {
        &self.learnable[&b.to_string()]
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = r#"{
        "model": "toy", "image": [16, 16, 3], "num_classes": 10,
        "num_blocks": 2, "latent": 256,
        "batch": {"train": 64, "distill": 64, "recon": 32, "eval": 256, "stats": 64},
        "params": [["stem.w", [3, 3, 3, 8]]],
        "bn": [["stembn.mean", [8]], ["stembn.var", [8]]],
        "qstate": [["q.stem.sw", [8]]],
        "gen_params": [["gen.fc.w", [256, 2048]]],
        "quant_layers": [{"name": "stem", "w_shape": [3, 3, 3, 8],
                          "out_ch": 8, "flat_k": 27, "block": 0}],
        "learnable": {"0": ["q.stem.sw", "q.stem.v", "q.stem.sa"], "1": []},
        "bounds": [[32, 16, 16, 3], [32, 8, 8, 16], [32, 10]],
        "entrypoints": {
            "eval_batch": {"file": "eval_batch.hlo.txt",
                "args": [["stem.w", "f32", [3, 3, 3, 8]], ["x", "f32", [256, 16, 16, 3]]],
                "results": [["logits", "f32", [256, 10]]]}
        }
    }"#;

    #[test]
    fn parses_sample() {
        let m = Manifest::from_json_text(SAMPLE).unwrap();
        assert_eq!(m.model, "toy");
        assert_eq!(m.batch("recon"), 32);
        assert_eq!(m.quant_layers[0].flat_k, 27);
        assert_eq!(m.learnable_block(0).len(), 3);
        let e = m.entry("eval_batch").unwrap();
        assert_eq!(e.args.len(), 2);
        assert_eq!(e.results[0].0, "logits");
        assert_eq!(e.results[0].2, vec![256, 10]);
    }

    #[test]
    fn missing_entry_is_error() {
        let m = Manifest::from_json_text(SAMPLE).unwrap();
        assert!(m.entry("nope").is_err());
    }
}

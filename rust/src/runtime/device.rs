//! Device-resident named buffer store (DESIGN.md §8): the step-loop
//! counterpart of [`Store`](crate::store::Store). Tensors live as PJRT
//! device buffers across calls, so a GLO-style optimization loop uploads
//! only the scalars that change each step (`t`, `lr_*`, `key`) and
//! downloads only the loss — full host materialization happens once, at
//! phase boundaries (`fetch` / `sync_to_store`).
//!
//! Buffers are held behind `Arc` and PJRT buffers are immutable, so a
//! `clone` shares the whole working set (one teacher upload serves every
//! distill shard / eval chunk / quant block on the exec pool) while
//! every `insert`/result-carry replaces only the clone's own handle —
//! the same copy-on-write discipline as the host store. `alias` goes one
//! step further and rebinds a name to an already-resident buffer for
//! zero transfer (quantize stages its per-batch block inputs this way).
//!
//! Transfer accounting is byte-exact: `bytes_h2d`/`bytes_d2h` count every
//! literal that crosses the host↔device boundary through this store, and
//! feed the `Metrics` transfer series plus `benches/runtime.rs`.

use std::collections::HashMap;
use std::sync::Arc;

use anyhow::{Context, Result};

use crate::store::Store;
use crate::tensor::{DType, Tensor};

use super::{from_literal, to_literal, Runtime};

/// A live device buffer plus the host-side metadata (dtype, shape) the
/// runtime validates manifest wiring against without touching the data.
#[derive(Debug, Clone)]
pub struct DeviceTensor {
    buf: Arc<xla::PjRtBuffer>,
    dtype: DType,
    shape: Vec<usize>,
}

impl DeviceTensor {
    pub(super) fn from_parts(
        buf: Arc<xla::PjRtBuffer>,
        dtype: DType,
        shape: Vec<usize>,
    ) -> Self {
        DeviceTensor { buf, dtype, shape }
    }

    pub(super) fn buffer(&self) -> Arc<xla::PjRtBuffer> {
        self.buf.clone()
    }

    pub fn dtype(&self) -> DType {
        self.dtype
    }

    pub fn shape(&self) -> &[usize] {
        &self.shape
    }

    pub fn numel(&self) -> usize {
        self.shape.iter().product()
    }

    pub fn byte_len(&self) -> usize {
        self.numel() * 4
    }
}

/// Ordered named device buffers bound to one [`Runtime`]'s PJRT client.
/// The argument/result hub of [`Runtime::call_device`], wired by manifest
/// names exactly like the host store is for [`Runtime::call`].
pub struct DeviceStore<'rt> {
    rt: &'rt Runtime,
    names: Vec<String>,
    map: HashMap<String, DeviceTensor>,
    bytes_h2d: u64,
    bytes_d2h: u64,
}

impl<'rt> Clone for DeviceStore<'rt> {
    /// Alias every buffer (`Arc` clone, no device traffic). Transfer
    /// counters restart at zero: a clone accounts only the traffic it
    /// causes itself, never the shared upload it aliases.
    fn clone(&self) -> Self {
        DeviceStore {
            rt: self.rt,
            names: self.names.clone(),
            map: self.map.clone(),
            bytes_h2d: 0,
            bytes_d2h: 0,
        }
    }
}

impl<'rt> DeviceStore<'rt> {
    pub(super) fn new(rt: &'rt Runtime) -> Self {
        DeviceStore {
            rt,
            names: Vec::new(),
            map: HashMap::new(),
            bytes_h2d: 0,
            bytes_d2h: 0,
        }
    }

    /// Upload a host tensor (H2D transfer, counted). Replaces any
    /// previous buffer under this name in this store only.
    pub fn insert(&mut self, name: &str, t: &Tensor) -> Result<()> {
        let lit = to_literal(t)?;
        let buf = self
            .rt
            .client
            .buffer_from_host_literal(None, &lit)
            .with_context(|| format!("upload '{name}'"))?;
        self.bytes_h2d += t.byte_len() as u64;
        self.insert_device(
            name,
            DeviceTensor::from_parts(Arc::new(buf), t.dtype(), t.shape.clone()),
        );
        Ok(())
    }

    /// Wire an already-resident buffer in under `name` (zero transfer).
    pub(super) fn insert_device(&mut self, name: &str, dt: DeviceTensor) {
        if !self.map.contains_key(name) {
            self.names.push(name.to_string());
        }
        self.map.insert(name.to_string(), dt);
    }

    /// Upload every tensor of a host store (bulk phase-boundary H2D).
    pub fn absorb(&mut self, store: &Store) -> Result<()> {
        for n in store.names() {
            self.insert(n, store.get(n)?)?;
        }
        Ok(())
    }

    /// Rebind `dst` to the buffer currently named `src` — zero bytes
    /// moved. A later replacement of `src` (e.g. by a result carry) does
    /// not retarget `dst`: the alias pins the buffer as it is now.
    pub fn alias(&mut self, dst: &str, src: &str) -> Result<()> {
        let d = self.get(src)?.clone();
        self.insert_device(dst, d);
        Ok(())
    }

    pub fn get(&self, name: &str) -> Result<&DeviceTensor> {
        self.map.get(name).ok_or_else(|| {
            anyhow::anyhow!("device store: missing tensor '{name}'")
        })
    }

    pub fn contains(&self, name: &str) -> bool {
        self.map.contains_key(name)
    }

    pub fn names(&self) -> &[String] {
        &self.names
    }

    pub fn len(&self) -> usize {
        self.names.len()
    }

    pub fn is_empty(&self) -> bool {
        self.names.is_empty()
    }

    /// Download one tensor to the host (D2H transfer, counted).
    pub fn fetch(&mut self, name: &str) -> Result<Tensor> {
        let d = self.get(name)?.clone();
        let lit = d
            .buf
            .to_literal_sync()
            .with_context(|| format!("download '{name}'"))?;
        let t = from_literal(&lit, d.dtype, &d.shape)
            .with_context(|| format!("download '{name}'"))?;
        self.bytes_d2h += t.byte_len() as u64;
        Ok(t)
    }

    /// Materialize every buffer into a host store — the once-per-phase
    /// full sync (checkpointing, export, image harvest).
    pub fn sync_to_store(&mut self, store: &mut Store) -> Result<()> {
        let names = self.names.clone();
        for n in &names {
            let t = self.fetch(n)?;
            store.insert(n, t);
        }
        Ok(())
    }

    /// `sync_to_store` into a fresh host store.
    pub fn to_store(&mut self) -> Result<Store> {
        let mut s = Store::new();
        self.sync_to_store(&mut s)?;
        Ok(s)
    }

    /// Cumulative `(host→device, device→host)` bytes moved through this
    /// store (uploads/downloads here plus scalar fetches in
    /// [`Runtime::call_device`]).
    pub fn transfer_bytes(&self) -> (u64, u64) {
        (self.bytes_h2d, self.bytes_d2h)
    }

    pub fn reset_transfer_bytes(&mut self) {
        self.bytes_h2d = 0;
        self.bytes_d2h = 0;
    }

    pub(super) fn add_d2h(&mut self, bytes: u64) {
        self.bytes_d2h += bytes;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn rt() -> Runtime {
        Runtime::cpu().unwrap()
    }

    #[test]
    fn upload_fetch_roundtrip_every_dtype() {
        let rt = rt();
        let mut dev = rt.device_store();
        let tensors = [
            ("f", Tensor::from_f32(&[2, 2], vec![1., 2., 3., 4.])),
            ("i", Tensor::from_i32(&[3], vec![-1, 0, 1])),
            ("u", Tensor::key(5, 6)),
            ("s", Tensor::scalar_f32(2.5)),
        ];
        for (n, t) in &tensors {
            dev.insert(n, t).unwrap();
        }
        assert_eq!(dev.len(), 4);
        for (n, t) in &tensors {
            assert!(dev.contains(n));
            assert_eq!(dev.get(n).unwrap().dtype(), t.dtype());
            assert_eq!(dev.get(n).unwrap().shape(), &t.shape[..]);
            assert_eq!(&dev.fetch(n).unwrap(), t, "'{n}' diverged");
        }
        assert!(dev.get("nope").is_err());
        assert!(dev.fetch("nope").is_err());
    }

    #[test]
    fn transfer_accounting_is_byte_exact() {
        let rt = rt();
        let mut dev = rt.device_store();
        dev.insert("a", &Tensor::zeros(&[8, 4])).unwrap(); // 128 B
        dev.insert("t", &Tensor::scalar_f32(1.0)).unwrap(); // 4 B
        assert_eq!(dev.transfer_bytes(), (132, 0));
        dev.fetch("t").unwrap(); // 4 B down
        assert_eq!(dev.transfer_bytes(), (132, 4));
        // overwrite re-uploads (counted), alias moves nothing
        dev.insert("t", &Tensor::scalar_f32(2.0)).unwrap();
        dev.alias("b", "a").unwrap();
        assert_eq!(dev.transfer_bytes(), (136, 4));
        dev.reset_transfer_bytes();
        assert_eq!(dev.transfer_bytes(), (0, 0));
    }

    #[test]
    fn clone_is_copy_on_write() {
        let rt = rt();
        let mut base = rt.device_store();
        base.insert("w", &Tensor::from_f32(&[2], vec![1.0, 2.0])).unwrap();
        let mut shard = base.clone();
        assert_eq!(shard.transfer_bytes(), (0, 0), "clone moves no bytes");
        shard.insert("w", &Tensor::from_f32(&[2], vec![9.0, 9.0])).unwrap();
        shard.insert("z", &Tensor::scalar_f32(3.0)).unwrap();
        // the shard sees its own state; the base is untouched
        assert_eq!(shard.fetch("w").unwrap().as_f32(), &[9.0, 9.0]);
        assert_eq!(base.fetch("w").unwrap().as_f32(), &[1.0, 2.0]);
        assert!(!base.contains("z"));
    }

    #[test]
    fn alias_pins_the_buffer_not_the_name() {
        let rt = rt();
        let mut dev = rt.device_store();
        dev.insert("src", &Tensor::scalar_f32(7.0)).unwrap();
        dev.alias("dst", "src").unwrap();
        // replacing src later must not retarget the alias
        dev.insert("src", &Tensor::scalar_f32(8.0)).unwrap();
        assert_eq!(dev.fetch("dst").unwrap().scalar(), 7.0);
        assert_eq!(dev.fetch("src").unwrap().scalar(), 8.0);
        assert!(dev.alias("x", "nope").is_err());
    }

    #[test]
    fn sync_to_store_materializes_everything_in_order() {
        let rt = rt();
        let mut dev = rt.device_store();
        dev.insert("a", &Tensor::scalar_f32(1.0)).unwrap();
        dev.insert("b", &Tensor::from_i32(&[2], vec![3, 4])).unwrap();
        let host = dev.to_store().unwrap();
        assert_eq!(host.names(), dev.names());
        assert_eq!(host.get("a").unwrap().scalar(), 1.0);
        assert_eq!(host.get("b").unwrap().as_i32(), &[3, 4]);
    }
}

//! Device-resident named buffer store (DESIGN.md §8): the step-loop
//! counterpart of [`Store`](crate::store::Store). Tensors live as PJRT
//! device buffers across calls, so a GLO-style optimization loop uploads
//! only the scalars that change each step (`t`, `lr_*`, `key`) and
//! downloads only the loss — full host materialization happens once, at
//! phase boundaries (`fetch` / `sync_to_store`).
//!
//! Buffers are held behind `Arc` and PJRT buffers are immutable, so a
//! `clone` shares the whole working set (one teacher upload serves every
//! distill shard / eval chunk / quant block on the exec pool) while
//! every `insert`/result-carry replaces only the clone's own handle —
//! the same copy-on-write discipline as the host store. `alias` goes one
//! step further and rebinds a name to an already-resident buffer for
//! zero transfer (quantize stages its per-batch block inputs this way).
//!
//! Transfer accounting is byte-exact: `bytes_h2d`/`bytes_d2h` count every
//! literal that crosses the host↔device boundary through this store, and
//! feed the `Metrics` transfer series plus `benches/runtime.rs`.

use std::collections::HashMap;
use std::sync::Arc;

use anyhow::{Context, Result};

use crate::store::Store;
use crate::tensor::{DType, Tensor};

use super::{from_literal, to_literal, Runtime};

/// A live device buffer plus the host-side metadata (dtype, shape) the
/// runtime validates manifest wiring against without touching the data.
#[derive(Debug, Clone)]
pub struct DeviceTensor {
    buf: Arc<xla::PjRtBuffer>,
    dtype: DType,
    shape: Vec<usize>,
}

impl DeviceTensor {
    pub(super) fn from_parts(
        buf: Arc<xla::PjRtBuffer>,
        dtype: DType,
        shape: Vec<usize>,
    ) -> Self {
        DeviceTensor { buf, dtype, shape }
    }

    pub(super) fn buffer(&self) -> Arc<xla::PjRtBuffer> {
        self.buf.clone()
    }

    pub fn dtype(&self) -> DType {
        self.dtype
    }

    pub fn shape(&self) -> &[usize] {
        &self.shape
    }

    pub fn numel(&self) -> usize {
        self.shape.iter().product()
    }

    pub fn byte_len(&self) -> usize {
        self.numel() * 4
    }
}

/// A feed recorded — not executed — while the store is in staging mode
/// (the speculative pass of a fused megastep, DESIGN.md §14).
#[derive(Debug, Clone)]
pub enum StagedFeed {
    /// What `insert` would have uploaded: the host tensor itself. No
    /// H2D happens at staging time; the fused dispatch batches all K
    /// steps' host feeds into one stacked upload.
    Host(Tensor),
    /// What `alias` would have rebound: the resolved resident buffer.
    Alias(DeviceTensor),
}

impl StagedFeed {
    /// Value equality for the megastep validation replay: host feeds
    /// compare by contents, alias feeds by buffer identity (the replay
    /// runs against the same resident store, so a matching alias
    /// resolves to the very same `Arc`).
    pub fn matches(&self, other: &StagedFeed) -> bool {
        match (self, other) {
            (StagedFeed::Host(a), StagedFeed::Host(b)) => a == b,
            (StagedFeed::Alias(a), StagedFeed::Alias(b)) => {
                Arc::ptr_eq(&a.buf, &b.buf)
            }
            _ => false,
        }
    }
}

/// The recorded `before_step` feeds of one speculative megastep: for
/// each of the K staged steps, the ordered `(name, feed)` writes that
/// step produced.
#[derive(Debug, Clone, Default)]
pub struct StagedSteps {
    steps: Vec<Vec<(String, StagedFeed)>>,
}

impl StagedSteps {
    /// Number of staged steps.
    pub fn len(&self) -> usize {
        self.steps.len()
    }

    pub fn is_empty(&self) -> bool {
        self.steps.is_empty()
    }

    /// The raw `(name, feed)` writes of step `i`, in program order.
    pub fn step(&self, i: usize) -> &[(String, StagedFeed)] {
        &self.steps[i]
    }

    /// The effective feed for `name` in step `i` — the last write wins,
    /// exactly as repeated `insert`s under one name do live.
    pub fn feed_in_step(&self, i: usize, name: &str) -> Option<&StagedFeed> {
        self.steps[i]
            .iter()
            .rev()
            .find(|(n, _)| n == name)
            .map(|(_, f)| f)
    }

    /// Does step `i` equal `other` (one replayed step) write-for-write?
    /// Used by the fused loop to find the commit prefix: the first
    /// staged step whose feeds diverge from the ground-truth replay.
    pub fn step_matches(&self, i: usize, other: &[(String, StagedFeed)]) -> bool {
        let a = &self.steps[i];
        a.len() == other.len()
            && a.iter().zip(other).all(|((an, af), (bn, bf))| {
                an == bn && af.matches(bf)
            })
    }

    fn record(&mut self, name: &str, feed: StagedFeed) {
        self.steps
            .last_mut()
            .expect("StagedSteps::record before begin_staging")
            .push((name.to_string(), feed));
    }
}

/// Ordered named device buffers bound to one [`Runtime`]'s PJRT client.
/// The argument/result hub of [`Runtime::call_device`], wired by manifest
/// names exactly like the host store is for [`Runtime::call`].
///
/// In *staging mode* (between [`begin_staging`](Self::begin_staging) and
/// [`end_staging`](Self::end_staging)) the mutating feed operations —
/// `insert` and `alias` — record what they would have done instead of
/// doing it: no uploads, no rebinds, no byte accounting. The resident
/// map is untouched, which is what lets the fused step loop speculate K
/// steps ahead and commit only a validated prefix.
pub struct DeviceStore<'rt> {
    rt: &'rt Runtime,
    names: Vec<String>,
    map: HashMap<String, DeviceTensor>,
    bytes_h2d: u64,
    bytes_d2h: u64,
    staging: Option<StagedSteps>,
}

impl<'rt> Clone for DeviceStore<'rt> {
    /// Alias every buffer (`Arc` clone, no device traffic). Transfer
    /// counters restart at zero: a clone accounts only the traffic it
    /// causes itself, never the shared upload it aliases.
    fn clone(&self) -> Self {
        DeviceStore {
            rt: self.rt,
            names: self.names.clone(),
            map: self.map.clone(),
            bytes_h2d: 0,
            bytes_d2h: 0,
            staging: None,
        }
    }
}

impl<'rt> DeviceStore<'rt> {
    pub(super) fn new(rt: &'rt Runtime) -> Self {
        DeviceStore {
            rt,
            names: Vec::new(),
            map: HashMap::new(),
            bytes_h2d: 0,
            bytes_d2h: 0,
            staging: None,
        }
    }

    /// Enter staging mode and open staged step 0. `insert`/`alias` now
    /// record instead of execute until [`end_staging`](Self::end_staging).
    pub fn begin_staging(&mut self) {
        assert!(self.staging.is_none(), "begin_staging while staging");
        self.staging = Some(StagedSteps { steps: vec![Vec::new()] });
    }

    /// Close the current staged step and open the next one.
    pub fn next_staged_step(&mut self) {
        self.staging
            .as_mut()
            .expect("next_staged_step outside staging")
            .steps
            .push(Vec::new());
    }

    /// Leave staging mode, returning everything recorded. The resident
    /// map and transfer counters are exactly as they were at
    /// `begin_staging`.
    pub fn end_staging(&mut self) -> StagedSteps {
        self.staging.take().expect("end_staging outside staging")
    }

    pub fn is_staging(&self) -> bool {
        self.staging.is_some()
    }

    /// Upload a host tensor (H2D transfer, counted). Replaces any
    /// previous buffer under this name in this store only. In staging
    /// mode: records the tensor as a [`StagedFeed::Host`] instead.
    pub fn insert(&mut self, name: &str, t: &Tensor) -> Result<()> {
        if let Some(st) = self.staging.as_mut() {
            st.record(name, StagedFeed::Host(t.clone()));
            return Ok(());
        }
        let lit = to_literal(t)?;
        let buf = self
            .rt
            .client
            .buffer_from_host_literal(None, &lit)
            .with_context(|| format!("upload '{name}'"))?;
        self.bytes_h2d += t.byte_len() as u64;
        self.insert_device(
            name,
            DeviceTensor::from_parts(Arc::new(buf), t.dtype(), t.shape.clone()),
        );
        Ok(())
    }

    /// Wire an already-resident buffer in under `name` (zero transfer).
    pub(super) fn insert_device(&mut self, name: &str, dt: DeviceTensor) {
        if !self.map.contains_key(name) {
            self.names.push(name.to_string());
        }
        self.map.insert(name.to_string(), dt);
    }

    /// Upload every tensor of a host store (bulk phase-boundary H2D).
    pub fn absorb(&mut self, store: &Store) -> Result<()> {
        for n in store.names() {
            self.insert(n, store.get(n)?)?;
        }
        Ok(())
    }

    /// Rebind `dst` to the buffer currently named `src` — zero bytes
    /// moved. A later replacement of `src` (e.g. by a result carry) does
    /// not retarget `dst`: the alias pins the buffer as it is now. In
    /// staging mode: resolves `src` (staged aliases in the current step
    /// first, then the resident map) and records the pinned buffer as a
    /// [`StagedFeed::Alias`]; aliasing a staged *host* feed is an error —
    /// that buffer does not exist yet, and no fusible phase needs it.
    pub fn alias(&mut self, dst: &str, src: &str) -> Result<()> {
        if let Some(st) = self.staging.as_ref() {
            let i = st.steps.len() - 1;
            let d = match st.feed_in_step(i, src) {
                Some(StagedFeed::Alias(d)) => d.clone(),
                Some(StagedFeed::Host(_)) => anyhow::bail!(
                    "staging: alias '{dst}' <- '{src}' targets a staged \
                     host upload; this phase cannot be fused"
                ),
                None => self.get(src)?.clone(),
            };
            self.staging
                .as_mut()
                .expect("staging vanished")
                .record(dst, StagedFeed::Alias(d));
            return Ok(());
        }
        let d = self.get(src)?.clone();
        self.insert_device(dst, d);
        Ok(())
    }

    pub fn get(&self, name: &str) -> Result<&DeviceTensor> {
        self.map.get(name).ok_or_else(|| {
            anyhow::anyhow!("device store: missing tensor '{name}'")
        })
    }

    pub fn contains(&self, name: &str) -> bool {
        self.map.contains_key(name)
    }

    pub fn names(&self) -> &[String] {
        &self.names
    }

    pub fn len(&self) -> usize {
        self.names.len()
    }

    pub fn is_empty(&self) -> bool {
        self.names.is_empty()
    }

    /// Download one tensor to the host (D2H transfer, counted).
    pub fn fetch(&mut self, name: &str) -> Result<Tensor> {
        let d = self.get(name)?.clone();
        let lit = d
            .buf
            .to_literal_sync()
            .with_context(|| format!("download '{name}'"))?;
        let t = from_literal(&lit, d.dtype, &d.shape)
            .with_context(|| format!("download '{name}'"))?;
        self.bytes_d2h += t.byte_len() as u64;
        Ok(t)
    }

    /// Materialize every buffer into a host store — the once-per-phase
    /// full sync (checkpointing, export, image harvest).
    pub fn sync_to_store(&mut self, store: &mut Store) -> Result<()> {
        let names = self.names.clone();
        for n in &names {
            let t = self.fetch(n)?;
            store.insert(n, t);
        }
        Ok(())
    }

    /// `sync_to_store` into a fresh host store.
    pub fn to_store(&mut self) -> Result<Store> {
        let mut s = Store::new();
        self.sync_to_store(&mut s)?;
        Ok(s)
    }

    /// Cumulative `(host→device, device→host)` bytes moved through this
    /// store (uploads/downloads here plus scalar fetches in
    /// [`Runtime::call_device`]).
    pub fn transfer_bytes(&self) -> (u64, u64) {
        (self.bytes_h2d, self.bytes_d2h)
    }

    pub fn reset_transfer_bytes(&mut self) {
        self.bytes_h2d = 0;
        self.bytes_d2h = 0;
    }

    pub(super) fn add_d2h(&mut self, bytes: u64) {
        self.bytes_d2h += bytes;
    }

    pub(super) fn add_h2d(&mut self, bytes: u64) {
        self.bytes_h2d += bytes;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn rt() -> Runtime {
        Runtime::cpu().unwrap()
    }

    #[test]
    fn upload_fetch_roundtrip_every_dtype() {
        let rt = rt();
        let mut dev = rt.device_store();
        let tensors = [
            ("f", Tensor::from_f32(&[2, 2], vec![1., 2., 3., 4.])),
            ("i", Tensor::from_i32(&[3], vec![-1, 0, 1])),
            ("u", Tensor::key(5, 6)),
            ("s", Tensor::scalar_f32(2.5)),
        ];
        for (n, t) in &tensors {
            dev.insert(n, t).unwrap();
        }
        assert_eq!(dev.len(), 4);
        for (n, t) in &tensors {
            assert!(dev.contains(n));
            assert_eq!(dev.get(n).unwrap().dtype(), t.dtype());
            assert_eq!(dev.get(n).unwrap().shape(), &t.shape[..]);
            assert_eq!(&dev.fetch(n).unwrap(), t, "'{n}' diverged");
        }
        assert!(dev.get("nope").is_err());
        assert!(dev.fetch("nope").is_err());
    }

    #[test]
    fn transfer_accounting_is_byte_exact() {
        let rt = rt();
        let mut dev = rt.device_store();
        dev.insert("a", &Tensor::zeros(&[8, 4])).unwrap(); // 128 B
        dev.insert("t", &Tensor::scalar_f32(1.0)).unwrap(); // 4 B
        assert_eq!(dev.transfer_bytes(), (132, 0));
        dev.fetch("t").unwrap(); // 4 B down
        assert_eq!(dev.transfer_bytes(), (132, 4));
        // overwrite re-uploads (counted), alias moves nothing
        dev.insert("t", &Tensor::scalar_f32(2.0)).unwrap();
        dev.alias("b", "a").unwrap();
        assert_eq!(dev.transfer_bytes(), (136, 4));
        dev.reset_transfer_bytes();
        assert_eq!(dev.transfer_bytes(), (0, 0));
    }

    #[test]
    fn clone_is_copy_on_write() {
        let rt = rt();
        let mut base = rt.device_store();
        base.insert("w", &Tensor::from_f32(&[2], vec![1.0, 2.0])).unwrap();
        let mut shard = base.clone();
        assert_eq!(shard.transfer_bytes(), (0, 0), "clone moves no bytes");
        shard.insert("w", &Tensor::from_f32(&[2], vec![9.0, 9.0])).unwrap();
        shard.insert("z", &Tensor::scalar_f32(3.0)).unwrap();
        // the shard sees its own state; the base is untouched
        assert_eq!(shard.fetch("w").unwrap().as_f32(), &[9.0, 9.0]);
        assert_eq!(base.fetch("w").unwrap().as_f32(), &[1.0, 2.0]);
        assert!(!base.contains("z"));
    }

    #[test]
    fn alias_pins_the_buffer_not_the_name() {
        let rt = rt();
        let mut dev = rt.device_store();
        dev.insert("src", &Tensor::scalar_f32(7.0)).unwrap();
        dev.alias("dst", "src").unwrap();
        // replacing src later must not retarget the alias
        dev.insert("src", &Tensor::scalar_f32(8.0)).unwrap();
        assert_eq!(dev.fetch("dst").unwrap().scalar(), 7.0);
        assert_eq!(dev.fetch("src").unwrap().scalar(), 8.0);
        assert!(dev.alias("x", "nope").is_err());
    }

    #[test]
    fn staging_records_without_touching_the_store() {
        let rt = rt();
        let mut dev = rt.device_store();
        dev.insert("w", &Tensor::scalar_f32(1.0)).unwrap();
        let (h2d0, _) = dev.transfer_bytes();

        dev.begin_staging();
        assert!(dev.is_staging());
        dev.insert("t", &Tensor::scalar_f32(1.0)).unwrap();
        dev.insert("lr", &Tensor::scalar_f32(0.1)).unwrap();
        dev.next_staged_step();
        dev.insert("t", &Tensor::scalar_f32(2.0)).unwrap();
        dev.insert("lr", &Tensor::scalar_f32(0.05)).unwrap();
        let staged = dev.end_staging();

        // nothing moved, nothing resident
        assert!(!dev.is_staging());
        assert_eq!(dev.transfer_bytes().0, h2d0);
        assert!(!dev.contains("t"));
        assert_eq!(staged.len(), 2);
        match staged.feed_in_step(1, "t") {
            Some(StagedFeed::Host(t)) => assert_eq!(t.scalar(), 2.0),
            other => panic!("bad staged feed: {other:?}"),
        }
        assert!(staged.feed_in_step(0, "nope").is_none());
    }

    #[test]
    fn staging_alias_pins_the_resident_buffer() {
        let rt = rt();
        let mut dev = rt.device_store();
        dev.insert("x_in.0", &Tensor::scalar_f32(5.0)).unwrap();
        dev.begin_staging();
        dev.alias("x_in", "x_in.0").unwrap();
        // chained alias resolves through the staged one
        dev.alias("x_again", "x_in").unwrap();
        // aliasing a staged host upload is a fusibility error
        dev.insert("fresh", &Tensor::scalar_f32(0.0)).unwrap();
        assert!(dev.alias("y", "fresh").is_err());
        let staged = dev.end_staging();
        let (a, b) = match (
            staged.feed_in_step(0, "x_in"),
            staged.feed_in_step(0, "x_again"),
        ) {
            (Some(StagedFeed::Alias(a)), Some(StagedFeed::Alias(b))) => (a, b),
            other => panic!("bad staged feeds: {other:?}"),
        };
        assert!(Arc::ptr_eq(&a.buf, &b.buf));
        assert!(Arc::ptr_eq(&a.buf, &dev.get("x_in.0").unwrap().buf));
        // and the live store never gained the alias
        assert!(!dev.contains("x_in"));
    }

    #[test]
    fn staged_step_matching_finds_divergence() {
        let rt = rt();
        let mut dev = rt.device_store();
        dev.insert("b0", &Tensor::scalar_f32(3.0)).unwrap();

        dev.begin_staging();
        dev.insert("lr", &Tensor::scalar_f32(0.1)).unwrap();
        dev.alias("x", "b0").unwrap();
        let staged = dev.end_staging();

        // identical replay matches
        dev.begin_staging();
        dev.insert("lr", &Tensor::scalar_f32(0.1)).unwrap();
        dev.alias("x", "b0").unwrap();
        let same = dev.end_staging();
        assert!(staged.step_matches(0, same.step(0)));

        // a different host value diverges
        dev.begin_staging();
        dev.insert("lr", &Tensor::scalar_f32(0.05)).unwrap();
        dev.alias("x", "b0").unwrap();
        let diff = dev.end_staging();
        assert!(!staged.step_matches(0, diff.step(0)));

        // a different alias target diverges too
        dev.insert("b1", &Tensor::scalar_f32(3.0)).unwrap();
        dev.begin_staging();
        dev.insert("lr", &Tensor::scalar_f32(0.1)).unwrap();
        dev.alias("x", "b1").unwrap();
        let realiased = dev.end_staging();
        assert!(!staged.step_matches(0, realiased.step(0)));

        // and so does a missing write
        dev.begin_staging();
        dev.insert("lr", &Tensor::scalar_f32(0.1)).unwrap();
        let short = dev.end_staging();
        assert!(!staged.step_matches(0, short.step(0)));
    }

    #[test]
    fn sync_to_store_materializes_everything_in_order() {
        let rt = rt();
        let mut dev = rt.device_store();
        dev.insert("a", &Tensor::scalar_f32(1.0)).unwrap();
        dev.insert("b", &Tensor::from_i32(&[2], vec![3, 4])).unwrap();
        let host = dev.to_store().unwrap();
        assert_eq!(host.names(), dev.names());
        assert_eq!(host.get("a").unwrap().scalar(), 1.0);
        assert_eq!(host.get("b").unwrap().as_i32(), &[3, 4]);
    }
}

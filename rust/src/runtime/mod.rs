//! PJRT runtime: load AOT HLO-text artifacts, compile once on the CPU
//! client, and execute them with arguments wired by manifest names from a
//! [`Store`]. This is the only place the `xla` crate is touched.
//!
//! Interchange is HLO *text* (see python/compile/aot.py and
//! /opt/xla-example/README.md): `HloModuleProto::from_text_file` reassigns
//! instruction ids, sidestepping the 64-bit-id protos jax >= 0.5 emits
//! which xla_extension 0.5.1 rejects.
//!
//! `Runtime` is `Sync`: the executable cache and dispatch stats sit behind
//! mutexes so one runtime (one PJRT client, one compile cache) can be
//! shared by every worker of the exec pool (DESIGN.md §5). Entry handles
//! are `Arc`s; `call` takes `&self` and only locks around cache/stat
//! bookkeeping, never across an execute.
//!
//! Two execution paths (DESIGN.md §8):
//!   * [`Runtime::call`] — host round-trip: every argument is marshalled
//!     from the [`Store`] into a fresh literal and every result is
//!     downloaded back, once per call. O(model) transfer per step.
//!   * [`Runtime::call_device`] — device-resident: arguments are live
//!     PJRT buffers in a [`DeviceStore`]; results are wired straight back
//!     in by manifest name (arg name == result name ⇒ carried state), and
//!     only scalar f32 results (losses) are downloaded. O(scalars)
//!     transfer per step — the step-loop hot path.

pub mod device;
pub mod json;
pub mod manifest;

use std::collections::HashMap;
use std::path::{Path, PathBuf};
use std::sync::{Arc, Mutex, OnceLock};
use std::time::Instant;

use anyhow::{Context, Result};

pub use device::{DeviceStore, DeviceTensor, StagedFeed, StagedSteps};
pub use manifest::{ArgSpec, EntrySpec, Manifest, QuantLayer};

use crate::store::Store;
use crate::tensor::{Data, DType, Tensor};

/// A compiled entrypoint plus its manifest spec.
pub struct LoadedEntry {
    pub name: String,
    pub spec: EntrySpec,
    exe: xla::PjRtLoadedExecutable,
}

/// Cumulative per-entry dispatch statistics (perf accounting), including
/// host↔device transfer volume: `call` moves the full argument/result
/// sets every step, `call_device` only the fetched scalars.
#[derive(Debug, Default, Clone)]
pub struct DispatchStats {
    pub calls: u64,
    /// Device steps executed across those calls. Equal to `calls` for
    /// the single-step paths; a fused dispatch counts one call but K
    /// steps, so throughput reads `steps / total_secs`, never
    /// `calls / total_secs`.
    pub steps: u64,
    pub total_secs: f64,
    /// Host→device bytes uploaded by the call itself (argument literals
    /// in the round-trip path; 0 in the device-resident paths, whose
    /// uploads happen through [`DeviceStore::insert`] or are counted on
    /// the store by the fused stacked upload).
    pub bytes_h2d: u64,
    /// Device→host bytes downloaded by the call (all results in the
    /// round-trip path; scalar results only in the device paths).
    pub bytes_d2h: u64,
}

/// Scalar results of one entrypoint call, keyed by manifest result name.
/// A small vec-backed map: entry counts are tiny (a loss, maybe an
/// accuracy), so a linear scan beats hashing and the fixed two-slot
/// capacity avoids a per-call `HashMap` allocation on the step-loop hot
/// path. Indexing by `&str` panics on a missing name, mirroring the
/// `HashMap` it replaced.
#[derive(Debug, Default, Clone)]
pub struct Scalars(Vec<(String, f32)>);

impl Scalars {
    pub fn new() -> Self {
        Scalars(Vec::with_capacity(2))
    }

    pub fn insert(&mut self, name: &str, v: f32) {
        if let Some(e) = self.0.iter_mut().find(|(n, _)| n == name) {
            e.1 = v;
        } else {
            self.0.push((name.to_string(), v));
        }
    }

    pub fn get(&self, name: &str) -> Option<f32> {
        self.0.iter().find(|(n, _)| n == name).map(|&(_, v)| v)
    }

    pub fn iter(&self) -> impl Iterator<Item = (&str, f32)> {
        self.0.iter().map(|(n, v)| (n.as_str(), *v))
    }

    pub fn len(&self) -> usize {
        self.0.len()
    }

    pub fn is_empty(&self) -> bool {
        self.0.is_empty()
    }
}

impl std::ops::Index<&str> for Scalars {
    type Output = f32;

    fn index(&self, name: &str) -> &f32 {
        self.0
            .iter()
            .find(|(n, _)| n == name)
            .map(|(_, v)| v)
            .unwrap_or_else(|| panic!("no scalar result '{name}'"))
    }
}

/// Device-resident results of one fused K-step dispatch: the untupled
/// result buffers of every executed step, held *outside* the
/// [`DeviceStore`] until the caller's validation replay picks the commit
/// prefix ([`Runtime::commit_fused`]). Dropping it discards the whole
/// speculation with zero store mutation.
pub struct FusedResults {
    steps: Vec<Vec<xla::PjRtBuffer>>,
}

impl FusedResults {
    /// Number of steps the fused dispatch executed.
    pub fn len(&self) -> usize {
        self.steps.len()
    }

    pub fn is_empty(&self) -> bool {
        self.steps.is_empty()
    }
}

/// One compile-cache slot: `built` publishes the compiled entry once
/// some thread wins the build, and `building` serializes same-key
/// builders only — callers compiling *distinct* entries never wait on
/// each other (the map lock is held just long enough to fetch the
/// slot, never across a compile).
#[derive(Default)]
struct EntrySlot {
    building: Mutex<()>,
    built: OnceLock<Arc<LoadedEntry>>,
}

/// PJRT CPU runtime with a compile-once executable cache. `Sync`: safe to
/// share across the exec pool's worker threads.
pub struct Runtime {
    client: xla::PjRtClient,
    cache: Mutex<HashMap<String, Arc<EntrySlot>>>,
    stats: Mutex<HashMap<String, DispatchStats>>,
}

impl Runtime {
    pub fn cpu() -> Result<Runtime> {
        let client = xla::PjRtClient::cpu().context("create PJRT CPU client")?;
        Ok(Runtime {
            client,
            cache: Mutex::new(HashMap::new()),
            stats: Mutex::new(HashMap::new()),
        })
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Load + compile an entrypoint (cached by path). Same-entry callers
    /// compile exactly once — the rest wait on the entry's own slot and
    /// share the `Arc` — while *distinct* entries compile concurrently:
    /// the map lock is only held to fetch a per-key slot, never across a
    /// parse or compile.
    pub fn entry(
        &self,
        model_dir: impl AsRef<Path>,
        manifest: &Manifest,
        name: &str,
    ) -> Result<Arc<LoadedEntry>> {
        let spec = manifest.entry(name)?;
        let path: PathBuf = model_dir.as_ref().join(&spec.file);
        let key = path.to_string_lossy().to_string();
        self.load_entry_with(&key, || {
            let proto = xla::HloModuleProto::from_text_file(
                path.to_str().unwrap(),
            )
            .with_context(|| format!("parse HLO text {path:?}"))?;
            let comp = xla::XlaComputation::from_proto(&proto);
            let exe = self
                .client
                .compile(&comp)
                .with_context(|| format!("compile {name}"))?;
            Ok(Arc::new(LoadedEntry {
                name: name.to_string(),
                spec: spec.clone(),
                exe,
            }))
        })
    }

    /// Per-key once-cell lookup around `build`: the winning caller runs
    /// `build` under the key's own slot lock, everyone else on the same
    /// key waits for the published `Arc`, and other keys proceed
    /// untouched. A failed build publishes nothing, so the next caller
    /// retries. Tests drive this directly with an injectable builder
    /// (the vendored offline xla stub cannot compile real HLO).
    fn load_entry_with(
        &self,
        key: &str,
        build: impl FnOnce() -> Result<Arc<LoadedEntry>>,
    ) -> Result<Arc<LoadedEntry>> {
        let slot = self
            .cache
            .lock()
            .unwrap()
            .entry(key.to_string())
            .or_default()
            .clone();
        if let Some(e) = slot.built.get() {
            return Ok(e.clone());
        }
        let _building = slot.building.lock().unwrap_or_else(|p| p.into_inner());
        // a same-key builder may have finished while we waited
        if let Some(e) = slot.built.get() {
            return Ok(e.clone());
        }
        let entry = build()?;
        let _ = slot.built.set(entry.clone());
        Ok(entry)
    }

    /// Install a pre-built executable into the compile cache under the
    /// exact key [`entry`](Self::entry) computes for
    /// (`model_dir`, `spec.file`) — subsequent `entry()` lookups hit the
    /// cache before any file I/O. This is the offline seam that lets
    /// tests and benches drive the full dispatch machinery (single-step
    /// and fused) with host-fn executables instead of compiled HLO.
    pub fn register_entry(
        &self,
        model_dir: impl AsRef<Path>,
        name: &str,
        spec: EntrySpec,
        exe: xla::PjRtLoadedExecutable,
    ) -> Arc<LoadedEntry> {
        let key = model_dir
            .as_ref()
            .join(&spec.file)
            .to_string_lossy()
            .to_string();
        let entry =
            Arc::new(LoadedEntry { name: name.to_string(), spec, exe });
        // a fresh pre-filled slot replaces any existing one (register
        // keeps its overwrite semantics; a slot's once-cell does not)
        let slot = Arc::new(EntrySlot::default());
        let _ = slot.built.set(entry.clone());
        self.cache.lock().unwrap().insert(key, slot);
        entry
    }

    /// Execute an entrypoint: arguments are read from `store` by the
    /// manifest arg names (shape/dtype validated), results are written
    /// back by result names. Returns the scalar results by name (losses,
    /// accuracies) for convenient logging.
    ///
    /// This is the host round-trip path: the full argument set is
    /// uploaded and the full result set downloaded on every call. Step
    /// loops should prefer [`call_device`](Self::call_device).
    pub fn call(
        &self,
        entry: &LoadedEntry,
        store: &mut Store,
    ) -> Result<Scalars> {
        let t0 = Instant::now();
        let mut lits = Vec::with_capacity(entry.spec.args.len());
        let mut h2d = 0u64;
        for (name, dt, shape) in &entry.spec.args {
            let t = store
                .get(name)
                .with_context(|| format!("args of {}", entry.name))?;
            validate_meta(name, t.dtype(), &t.shape, dt, shape)?;
            h2d += t.byte_len() as u64;
            lits.push(to_literal(t)?);
        }
        let result = entry
            .exe
            .execute::<xla::Literal>(&lits)
            .with_context(|| format!("execute {}", entry.name))?;
        let lit = result[0][0]
            .to_literal_sync()
            .context("fetch result literal")?;
        let outs = lit.to_tuple().context("untuple results")?;
        anyhow::ensure!(
            outs.len() == entry.spec.results.len(),
            "{}: got {} results, manifest says {}",
            entry.name,
            outs.len(),
            entry.spec.results.len()
        );
        let mut scalars = Scalars::new();
        let mut d2h = 0u64;
        for (out, (name, dt, shape)) in
            outs.into_iter().zip(entry.spec.results.iter())
        {
            let t = from_literal(&out, DType::from_str(dt)?, shape)
                .with_context(|| format!("result {name} of {}", entry.name))?;
            d2h += t.byte_len() as u64;
            if t.numel() == 1 && t.dtype() == DType::F32 {
                scalars.insert(name, t.scalar());
            }
            store.insert(name, t);
        }
        self.record_dispatch(
            &entry.name,
            1,
            t0.elapsed().as_secs_f64(),
            h2d,
            d2h,
        );
        Ok(scalars)
    }

    /// Execute an entrypoint over device-resident buffers. Arguments are
    /// taken from `dev` by manifest name (metadata validated, zero host
    /// traffic); every result buffer is wired back into `dev` under its
    /// result name — so a result named like an argument *is* that state
    /// tensor's next iteration, carried on device (DESIGN.md §8). The
    /// only downloads are scalar f32 results (losses/accuracies), which
    /// host-side schedules need every step.
    pub fn call_device(
        &self,
        entry: &LoadedEntry,
        dev: &mut DeviceStore,
    ) -> Result<Scalars> {
        let t0 = Instant::now();
        let mut args = Vec::with_capacity(entry.spec.args.len());
        for (name, dt, shape) in &entry.spec.args {
            let d = dev
                .get(name)
                .with_context(|| format!("args of {}", entry.name))?;
            validate_meta(name, d.dtype(), d.shape(), dt, shape)?;
            args.push(d.buffer());
        }
        let arg_refs: Vec<&xla::PjRtBuffer> =
            args.iter().map(|a| a.as_ref()).collect();
        // Contract with the xla layer: result[0] holds one buffer per
        // manifest result (outputs untupled on device; the real xla-rs
        // swap-in needs untuple_result set — see vendor/xla).
        let mut result = entry
            .exe
            .execute_b(&arg_refs)
            .with_context(|| format!("execute {}", entry.name))?;
        anyhow::ensure!(
            !result.is_empty(),
            "{}: execute_b returned no device results",
            entry.name
        );
        let outs = result.remove(0);
        anyhow::ensure!(
            outs.len() == entry.spec.results.len(),
            "{}: got {} results, manifest says {}",
            entry.name,
            outs.len(),
            entry.spec.results.len()
        );
        let mut scalars = Scalars::new();
        let mut d2h = 0u64;
        for (out, (name, dt, shape)) in
            outs.into_iter().zip(entry.spec.results.iter())
        {
            let dtype = DType::from_str(dt)?;
            let numel: usize = shape.iter().product();
            if numel == 1 && dtype == DType::F32 {
                let lit = out.to_literal_sync().with_context(|| {
                    format!("fetch scalar {name} of {}", entry.name)
                })?;
                let t = from_literal(&lit, dtype, shape).with_context(|| {
                    format!("result {name} of {}", entry.name)
                })?;
                scalars.insert(name, t.scalar());
                d2h += t.byte_len() as u64;
            }
            dev.insert_device(
                name,
                DeviceTensor::from_parts(Arc::new(out), dtype, shape.clone()),
            );
        }
        dev.add_d2h(d2h);
        self.record_dispatch(
            &entry.name,
            1,
            t0.elapsed().as_secs_f64(),
            0,
            d2h,
        );
        Ok(scalars)
    }

    /// Execute K consecutive steps of an entrypoint as ONE device
    /// dispatch (DESIGN.md §14). `staged` holds the recorded
    /// `before_step` feeds of the K steps (see
    /// [`DeviceStore::begin_staging`]); each manifest argument is
    /// classified by how it varies across them:
    ///
    ///   * staged host feed in every step → all K values stacked into a
    ///     `[K, ...]` tensor and uploaded once ([`xla::FusedArg::Stacked`]);
    ///   * staged alias in every step → the K pinned resident buffers
    ///     ([`xla::FusedArg::PerStep`]);
    ///   * unstaged but named like a result → device-carried state:
    ///     step i reads step i-1's result ([`xla::FusedArg::Carried`]);
    ///   * unstaged otherwise → one fixed resident buffer.
    ///
    /// Scalar f32 results come back as one per-step vector (same bytes
    /// as K single-step downloads, one sync point). Nothing is written
    /// into `dev`: the per-step result buffers ride back in
    /// [`FusedResults`] so the caller can validate the speculated feeds
    /// and commit a prefix via [`commit_fused`](Self::commit_fused).
    pub fn call_device_fused(
        &self,
        entry: &LoadedEntry,
        dev: &mut DeviceStore,
        staged: &StagedSteps,
    ) -> Result<(Vec<Scalars>, FusedResults)> {
        let t0 = Instant::now();
        let k = staged.len();
        anyhow::ensure!(k > 0, "{}: fused dispatch of 0 steps", entry.name);
        let result_names: Vec<&str> = entry
            .spec
            .results
            .iter()
            .map(|(n, _, _)| n.as_str())
            .collect();
        let mut args = Vec::with_capacity(entry.spec.args.len());
        let mut stacked_h2d = 0u64;
        for (name, dt, shape) in &entry.spec.args {
            let feeds: Vec<Option<&StagedFeed>> =
                (0..k).map(|i| staged.feed_in_step(i, name)).collect();
            let staged_count = feeds.iter().filter(|f| f.is_some()).count();
            anyhow::ensure!(
                staged_count == 0 || staged_count == k,
                "{}: arg '{name}' staged in {staged_count} of {k} fused \
                 steps; feeds must be written every step or never",
                entry.name
            );
            let arg = if staged_count == 0 {
                let d = dev
                    .get(name)
                    .with_context(|| format!("args of {}", entry.name))?;
                validate_meta(name, d.dtype(), d.shape(), dt, shape)?;
                match result_names.iter().position(|r| r == name) {
                    // arg name == result name: carried state, chained
                    // on device between the unrolled steps
                    Some(from) => {
                        xla::FusedArg::Carried { init: d.buffer(), from }
                    }
                    None => xla::FusedArg::Fixed(d.buffer()),
                }
            } else if feeds
                .iter()
                .all(|f| matches!(f, Some(StagedFeed::Host(_))))
            {
                let parts: Vec<&Tensor> = feeds
                    .iter()
                    .map(|f| match f {
                        Some(StagedFeed::Host(t)) => t,
                        _ => unreachable!(),
                    })
                    .collect();
                for t in &parts {
                    validate_meta(name, t.dtype(), &t.shape, dt, shape)?;
                }
                let stacked = Tensor::stack_outer(&parts);
                stacked_h2d += stacked.byte_len() as u64;
                let buf = self
                    .client
                    .buffer_from_host_literal(None, &to_literal(&stacked)?)
                    .with_context(|| format!("stacked upload '{name}'"))?;
                xla::FusedArg::Stacked(Arc::new(buf))
            } else if feeds
                .iter()
                .all(|f| matches!(f, Some(StagedFeed::Alias(_))))
            {
                let mut bufs = Vec::with_capacity(k);
                for f in &feeds {
                    let d = match f {
                        Some(StagedFeed::Alias(d)) => d,
                        _ => unreachable!(),
                    };
                    validate_meta(name, d.dtype(), d.shape(), dt, shape)?;
                    bufs.push(d.buffer());
                }
                xla::FusedArg::PerStep(bufs)
            } else {
                anyhow::bail!(
                    "{}: arg '{name}' mixes staged host uploads and \
                     aliases across the fused steps",
                    entry.name
                );
            };
            args.push(arg);
        }
        // The stacked uploads are H2D the K=1 path would have done via
        // DeviceStore::insert, so they land in the store's accounting
        // (keeping resident-path byte comparisons K-invariant), not in
        // the per-entry stats — same convention as call_device.
        dev.add_h2d(stacked_h2d);
        let steps = entry
            .exe
            .execute_fused(&args, k)
            .with_context(|| format!("fused execute {}", entry.name))?;
        anyhow::ensure!(
            steps.len() == k,
            "{}: fused execute returned {} step results for k={k}",
            entry.name,
            steps.len()
        );
        let mut per_step = Vec::with_capacity(k);
        let mut d2h = 0u64;
        for outs in &steps {
            anyhow::ensure!(
                outs.len() == entry.spec.results.len(),
                "{}: got {} results per step, manifest says {}",
                entry.name,
                outs.len(),
                entry.spec.results.len()
            );
            let mut scalars = Scalars::new();
            for (out, (name, dt, shape)) in
                outs.iter().zip(entry.spec.results.iter())
            {
                let dtype = DType::from_str(dt)?;
                let numel: usize = shape.iter().product();
                if numel == 1 && dtype == DType::F32 {
                    let lit = out.to_literal_sync().with_context(|| {
                        format!("fetch scalar {name} of {}", entry.name)
                    })?;
                    let t =
                        from_literal(&lit, dtype, shape).with_context(
                            || format!("result {name} of {}", entry.name),
                        )?;
                    scalars.insert(name, t.scalar());
                    d2h += t.byte_len() as u64;
                }
            }
            per_step.push(scalars);
        }
        dev.add_d2h(d2h);
        self.record_dispatch(
            &entry.name,
            k as u64,
            t0.elapsed().as_secs_f64(),
            0,
            d2h,
        );
        Ok((per_step, FusedResults { steps }))
    }

    /// Wire the results of fused step `committed - 1` into `dev` — the
    /// single store mutation of a fused dispatch. Steps `0..committed`
    /// had validated feeds, so step `committed - 1`'s result buffers are
    /// exactly the state K single-step dispatches would have left
    /// resident; the speculated tail (`committed..k`) is dropped.
    pub fn commit_fused(
        &self,
        entry: &LoadedEntry,
        dev: &mut DeviceStore,
        results: FusedResults,
        committed: usize,
    ) -> Result<()> {
        anyhow::ensure!(
            committed >= 1 && committed <= results.steps.len(),
            "{}: commit of {committed} steps from a fused dispatch of {}",
            entry.name,
            results.steps.len()
        );
        let mut steps = results.steps;
        let outs = steps.swap_remove(committed - 1);
        for (out, (name, dt, shape)) in
            outs.into_iter().zip(entry.spec.results.iter())
        {
            let dtype = DType::from_str(dt)?;
            dev.insert_device(
                name,
                DeviceTensor::from_parts(Arc::new(out), dtype, shape.clone()),
            );
        }
        Ok(())
    }

    /// An empty device store bound to this runtime's PJRT client.
    pub fn device_store(&self) -> DeviceStore<'_> {
        DeviceStore::new(self)
    }

    /// Upload every tensor of a host store as device buffers — the
    /// phase-boundary bulk transfer that replaces per-step re-uploads.
    pub fn upload_store(&self, store: &Store) -> Result<DeviceStore<'_>> {
        let mut dev = self.device_store();
        dev.absorb(store)?;
        Ok(dev)
    }

    /// Fold one dispatch (of `steps` device steps) into the per-entry
    /// stats. All counters land in a single short lock section (and the
    /// common re-dispatch case avoids allocating the key), so pool
    /// workers hammering the same entry contend for one brief mutex
    /// acquisition per call, nothing more.
    fn record_dispatch(
        &self,
        name: &str,
        steps: u64,
        secs: f64,
        h2d: u64,
        d2h: u64,
    ) {
        let mut stats = self.stats.lock().unwrap();
        if let Some(s) = stats.get_mut(name) {
            s.calls += 1;
            s.steps += steps;
            s.total_secs += secs;
            s.bytes_h2d += h2d;
            s.bytes_d2h += d2h;
        } else {
            stats.insert(
                name.to_string(),
                DispatchStats {
                    calls: 1,
                    steps,
                    total_secs: secs,
                    bytes_h2d: h2d,
                    bytes_d2h: d2h,
                },
            );
        }
    }

    pub fn dispatch_stats(&self) -> HashMap<String, DispatchStats> {
        self.stats.lock().unwrap().clone()
    }

    pub fn reset_stats(&self) {
        self.stats.lock().unwrap().clear();
    }
}

/// Shared arg/result validation against the manifest's (dtype, shape).
fn validate_meta(
    name: &str,
    got_dt: DType,
    got_shape: &[usize],
    dt: &str,
    shape: &[usize],
) -> Result<()> {
    let want = DType::from_str(dt)?;
    anyhow::ensure!(
        got_dt == want,
        "arg {name}: dtype {got_dt:?}, manifest wants {want:?}"
    );
    anyhow::ensure!(
        got_shape == shape,
        "arg {name}: shape {got_shape:?}, manifest wants {shape:?}"
    );
    Ok(())
}

/// Marshal a host tensor into an XLA literal (the H2D staging format).
pub fn to_literal(t: &Tensor) -> Result<xla::Literal> {
    let dims: Vec<i64> = t.shape.iter().map(|&d| d as i64).collect();
    let lit = match &t.data {
        Data::F32(v) => xla::Literal::vec1(v),
        Data::I32(v) => xla::Literal::vec1(v),
        Data::U32(v) => xla::Literal::vec1(v),
    };
    Ok(lit.reshape(&dims)?)
}

/// Materialize a downloaded literal as a host tensor with the manifest's
/// dtype and shape; errors if the element counts disagree.
pub fn from_literal(
    lit: &xla::Literal,
    dt: DType,
    shape: &[usize],
) -> Result<Tensor> {
    let data = match dt {
        DType::F32 => Data::F32(lit.to_vec::<f32>()?),
        DType::I32 => Data::I32(lit.to_vec::<i32>()?),
        DType::U32 => Data::U32(lit.to_vec::<u32>()?),
    };
    let t = Tensor { shape: shape.to_vec(), data };
    anyhow::ensure!(
        t.numel() == lit.element_count(),
        "literal element count {} != manifest shape {:?}",
        lit.element_count(),
        shape
    );
    Ok(t)
}

/// Convenience: a model's artifact directory + manifest + runtime handle.
pub struct ModelRt<'a> {
    pub rt: &'a Runtime,
    pub dir: PathBuf,
    pub manifest: Manifest,
}

impl<'a> ModelRt<'a> {
    pub fn load(
        rt: &'a Runtime,
        artifacts: impl AsRef<Path>,
        model: &str,
    ) -> Result<Self> {
        let dir = artifacts.as_ref().join(model);
        let manifest = Manifest::load(&dir)?;
        Ok(ModelRt { rt, dir, manifest })
    }

    pub fn entry(&self, name: &str) -> Result<Arc<LoadedEntry>> {
        self.rt.entry(&self.dir, &self.manifest, name)
    }

    pub fn call(&self, name: &str, store: &mut Store) -> Result<Scalars> {
        let e = self.entry(name)?;
        self.rt.call(&e, store)
    }

    /// Device-resident dispatch by entry name (see [`Runtime::call_device`]).
    pub fn call_device(
        &self,
        name: &str,
        dev: &mut DeviceStore,
    ) -> Result<Scalars> {
        let e = self.entry(name)?;
        self.rt.call_device(&e, dev)
    }

    /// Upload a host store to this model's runtime (phase-boundary bulk
    /// transfer); the returned store lives as long as the runtime borrow.
    pub fn upload_store(&self, store: &Store) -> Result<DeviceStore<'a>> {
        self.rt.upload_store(store)
    }

    /// Load init.bin (FP32 params + BN state + generator init).
    pub fn init_store(&self) -> Result<Store> {
        Store::load(self.dir.join("init.bin"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// The exec pool shares one Runtime across worker threads; keep the
    /// marker bounds enforced at compile time. `DeviceStore` is shared by
    /// reference across distill/eval shard jobs, so it must be `Sync` too.
    #[test]
    fn runtime_is_send_and_sync() {
        fn check<T: Send + Sync>() {}
        check::<Runtime>();
        check::<LoadedEntry>();
        check::<ModelRt<'static>>();
        check::<DeviceStore<'static>>();
        check::<Scalars>();
    }

    #[test]
    fn scalars_index_get_overwrite() {
        let mut s = Scalars::new();
        assert!(s.is_empty());
        s.insert("loss", 2.0);
        s.insert("acc", 0.5);
        s.insert("loss", 1.5); // overwrite keeps one entry
        assert_eq!(s.len(), 2);
        assert_eq!(s["loss"], 1.5);
        assert_eq!(s.get("acc"), Some(0.5));
        assert_eq!(s.get("nope"), None);
        let names: Vec<&str> = s.iter().map(|(n, _)| n).collect();
        assert_eq!(names, vec!["loss", "acc"]);
    }

    #[test]
    #[should_panic(expected = "no scalar result")]
    fn scalars_index_missing_panics() {
        let _ = Scalars::new()["loss"];
    }

    #[test]
    fn literal_roundtrip_every_dtype() {
        for t in [
            Tensor::from_f32(&[2, 3], vec![1., 2., 3., 4., 5., 6.]),
            Tensor::from_i32(&[4], vec![1, -2, 3, -4]),
            Tensor::from_u32(&[2, 2], vec![1, 2, 3, 4]),
            Tensor::scalar_f32(3.25),
            Tensor::key(7, 9),
        ] {
            let lit = to_literal(&t).unwrap();
            assert_eq!(lit.element_count(), t.numel());
            let back = from_literal(&lit, t.dtype(), &t.shape).unwrap();
            assert_eq!(back, t, "round-trip must be bit-identical");
        }
    }

    #[test]
    fn from_literal_rejects_element_count_mismatch() {
        let lit = to_literal(&Tensor::from_f32(&[4], vec![1., 2., 3., 4.]))
            .unwrap();
        for bad_shape in [&[3][..], &[2, 3][..], &[][..]] {
            let err = from_literal(&lit, DType::F32, bad_shape).unwrap_err();
            assert!(
                format!("{err}").contains("element count"),
                "shape {bad_shape:?}: {err}"
            );
        }
        // dtype mismatch surfaces as the stub's literal-op error
        assert!(from_literal(&lit, DType::I32, &[4]).is_err());
    }

    #[test]
    fn dispatch_stats_default_has_no_traffic() {
        let s = DispatchStats::default();
        assert_eq!(
            (s.calls, s.steps, s.bytes_h2d, s.bytes_d2h),
            (0, 0, 0, 0)
        );
    }

    /// A no-op host-fn entry for exercising the compile-cache locking
    /// (the vendored offline xla stub cannot compile real HLO, so the
    /// cache tests inject their builds through `load_entry_with`).
    fn slot_entry(name: &str) -> Arc<LoadedEntry> {
        let spec = EntrySpec {
            file: format!("{name}.hlo.txt"),
            args: vec![],
            results: vec![],
        };
        let exe = xla::PjRtLoadedExecutable::from_host_fn(0, |_| Ok(vec![]));
        Arc::new(LoadedEntry { name: name.to_string(), spec, exe })
    }

    #[test]
    fn same_entry_compiles_exactly_once_across_threads() {
        use std::sync::atomic::{AtomicUsize, Ordering};
        let rt = Runtime::cpu().unwrap();
        let builds = AtomicUsize::new(0);
        let got: Vec<Arc<LoadedEntry>> = std::thread::scope(|s| {
            let handles: Vec<_> = (0..8)
                .map(|_| {
                    let rt = &rt;
                    let builds = &builds;
                    s.spawn(move || {
                        rt.load_entry_with("k1", || {
                            builds.fetch_add(1, Ordering::SeqCst);
                            // widen the race window: losers must wait,
                            // not rebuild
                            std::thread::sleep(
                                std::time::Duration::from_millis(30),
                            );
                            Ok(slot_entry("k1"))
                        })
                        .unwrap()
                    })
                })
                .collect();
            handles.into_iter().map(|h| h.join().unwrap()).collect()
        });
        assert_eq!(builds.load(Ordering::SeqCst), 1, "one build per key");
        for e in &got[1..] {
            assert!(Arc::ptr_eq(&got[0], e), "every caller shares the Arc");
        }
    }

    #[test]
    fn distinct_entries_compile_concurrently() {
        use std::sync::atomic::{AtomicBool, Ordering};
        let rt = Runtime::cpu().unwrap();
        let a_in = AtomicBool::new(false);
        let b_in = AtomicBool::new(false);
        // each build announces itself, then waits (bounded, so a
        // serialization regression fails the assert instead of
        // deadlocking) to observe the other build also in flight
        let overlap = |mine: &AtomicBool, other: &AtomicBool| {
            mine.store(true, Ordering::SeqCst);
            let t0 = std::time::Instant::now();
            while !other.load(Ordering::SeqCst) {
                if t0.elapsed() > std::time::Duration::from_secs(2) {
                    return false;
                }
                std::thread::yield_now();
            }
            true
        };
        let (oa, ob) = std::thread::scope(|s| {
            let ha = s.spawn(|| {
                let mut saw = false;
                rt.load_entry_with("ka", || {
                    saw = overlap(&a_in, &b_in);
                    Ok(slot_entry("ka"))
                })
                .unwrap();
                saw
            });
            let hb = s.spawn(|| {
                let mut saw = false;
                rt.load_entry_with("kb", || {
                    saw = overlap(&b_in, &a_in);
                    Ok(slot_entry("kb"))
                })
                .unwrap();
                saw
            });
            (ha.join().unwrap(), hb.join().unwrap())
        });
        assert!(
            oa && ob,
            "distinct-entry builds must overlap, not serialize"
        );
    }

    #[test]
    fn failed_build_is_retried_not_cached() {
        let rt = Runtime::cpu().unwrap();
        let r = rt.load_entry_with("flaky", || {
            anyhow::bail!("transient compile failure")
        });
        assert!(r.is_err(), "build errors surface to the caller");
        let e = rt
            .load_entry_with("flaky", || Ok(slot_entry("flaky")))
            .unwrap();
        assert_eq!(e.name, "flaky");
        let e2 = rt
            .load_entry_with("flaky", || {
                anyhow::bail!("must not rebuild a published entry")
            })
            .unwrap();
        assert!(Arc::ptr_eq(&e, &e2), "success is cached");
    }

    /// A tiny host-fn "training step": state' = state + lr (elementwise),
    /// loss = sum(state'). Registered under a synthetic manifest spec so
    /// the full device dispatch machinery runs offline.
    fn fused_fixture(rt: &Runtime) -> Arc<LoadedEntry> {
        let spec = EntrySpec {
            file: "step_test.hlo.txt".to_string(),
            args: vec![
                ("state".to_string(), "f32".to_string(), vec![2]),
                ("lr".to_string(), "f32".to_string(), vec![]),
            ],
            results: vec![
                ("state".to_string(), "f32".to_string(), vec![2]),
                ("loss".to_string(), "f32".to_string(), vec![]),
            ],
        };
        let exe = xla::PjRtLoadedExecutable::from_host_fn(2, |args| {
            let s = args[0].to_vec::<f32>()?;
            let lr = args[1].to_vec::<f32>()?[0];
            let next: Vec<f32> = s.iter().map(|x| x + lr).collect();
            let loss: f32 = next.iter().sum();
            let state = xla::Literal::vec1(&next).reshape(&[2])?;
            let loss = xla::Literal::vec1(&[loss]).reshape(&[])?;
            Ok(vec![state, loss])
        });
        rt.register_entry(".", "step_test", spec, exe)
    }

    #[test]
    fn fused_dispatch_matches_single_steps_and_commits_prefixes() {
        let rt = Runtime::cpu().unwrap();
        let entry = fused_fixture(&rt);
        let lrs = [0.5f32, 0.25, 0.125];

        // reference: K=1, three call_device dispatches
        let mut ref_dev = rt.device_store();
        ref_dev.insert("state", &Tensor::from_f32(&[2], vec![1.0, 2.0]))
            .unwrap();
        let mut ref_losses = Vec::new();
        for lr in lrs {
            ref_dev.insert("lr", &Tensor::scalar_f32(lr)).unwrap();
            let s = rt.call_device(&entry, &mut ref_dev).unwrap();
            ref_losses.push(s["loss"]);
        }
        let ref_state = ref_dev.fetch("state").unwrap();

        // fused: one dispatch of all three staged steps
        let mut dev = rt.device_store();
        dev.insert("state", &Tensor::from_f32(&[2], vec![1.0, 2.0]))
            .unwrap();
        dev.begin_staging();
        for (i, lr) in lrs.iter().enumerate() {
            if i > 0 {
                dev.next_staged_step();
            }
            dev.insert("lr", &Tensor::scalar_f32(*lr)).unwrap();
        }
        let staged = dev.end_staging();
        let (scalars, results) =
            rt.call_device_fused(&entry, &mut dev, &staged).unwrap();
        assert_eq!(scalars.len(), 3);
        assert_eq!(results.len(), 3);
        let losses: Vec<f32> = scalars.iter().map(|s| s["loss"]).collect();
        assert_eq!(losses, ref_losses, "per-step scalar trace diverged");
        // nothing committed yet: the store still holds the init state
        assert_eq!(dev.fetch("state").unwrap().as_f32(), &[1.0, 2.0]);
        rt.commit_fused(&entry, &mut dev, results, 3).unwrap();
        assert_eq!(
            dev.fetch("state").unwrap(),
            ref_state,
            "fused K=3 final state diverged from three K=1 dispatches"
        );

        // stats: 4 calls (3 single + 1 fused) but 6 device steps
        let stats = rt.dispatch_stats();
        let s = &stats["step_test"];
        assert_eq!((s.calls, s.steps), (4, 6));
    }

    #[test]
    fn fused_prefix_commit_stops_at_the_requested_step() {
        let rt = Runtime::cpu().unwrap();
        let entry = fused_fixture(&rt);
        let mut dev = rt.device_store();
        dev.insert("state", &Tensor::from_f32(&[2], vec![0.0, 0.0]))
            .unwrap();
        dev.begin_staging();
        dev.insert("lr", &Tensor::scalar_f32(1.0)).unwrap();
        dev.next_staged_step();
        dev.insert("lr", &Tensor::scalar_f32(1.0)).unwrap();
        dev.next_staged_step();
        dev.insert("lr", &Tensor::scalar_f32(1.0)).unwrap();
        let staged = dev.end_staging();
        let (_, results) =
            rt.call_device_fused(&entry, &mut dev, &staged).unwrap();
        // commit only 2 of the 3 speculated steps
        rt.commit_fused(&entry, &mut dev, results, 2).unwrap();
        assert_eq!(dev.fetch("state").unwrap().as_f32(), &[2.0, 2.0]);
    }

    #[test]
    fn fused_rejects_partially_staged_args() {
        let rt = Runtime::cpu().unwrap();
        let entry = fused_fixture(&rt);
        let mut dev = rt.device_store();
        dev.insert("state", &Tensor::from_f32(&[2], vec![0.0, 0.0]))
            .unwrap();
        dev.begin_staging();
        dev.insert("lr", &Tensor::scalar_f32(1.0)).unwrap();
        dev.next_staged_step(); // second step never writes lr
        let staged = dev.end_staging();
        let err = rt
            .call_device_fused(&entry, &mut dev, &staged)
            .unwrap_err();
        assert!(err.to_string().contains("staged in 1 of 2"), "{err}");
    }
}

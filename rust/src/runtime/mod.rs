//! PJRT runtime: load AOT HLO-text artifacts, compile once on the CPU
//! client, and execute them with arguments wired by manifest names from a
//! [`Store`]. This is the only place the `xla` crate is touched.
//!
//! Interchange is HLO *text* (see python/compile/aot.py and
//! /opt/xla-example/README.md): `HloModuleProto::from_text_file` reassigns
//! instruction ids, sidestepping the 64-bit-id protos jax >= 0.5 emits
//! which xla_extension 0.5.1 rejects.
//!
//! `Runtime` is `Sync`: the executable cache and dispatch stats sit behind
//! mutexes so one runtime (one PJRT client, one compile cache) can be
//! shared by every worker of the exec pool (DESIGN.md §5). Entry handles
//! are `Arc`s; `call` takes `&self` and only locks around cache/stat
//! bookkeeping, never across an execute.

pub mod json;
pub mod manifest;

use std::collections::HashMap;
use std::path::{Path, PathBuf};
use std::sync::{Arc, Mutex};
use std::time::Instant;

use anyhow::{Context, Result};

pub use manifest::{ArgSpec, EntrySpec, Manifest, QuantLayer};

use crate::store::Store;
use crate::tensor::{Data, DType, Tensor};

/// A compiled entrypoint plus its manifest spec.
pub struct LoadedEntry {
    pub name: String,
    pub spec: EntrySpec,
    exe: xla::PjRtLoadedExecutable,
}

/// Cumulative per-entry dispatch statistics (perf accounting).
#[derive(Debug, Default, Clone)]
pub struct DispatchStats {
    pub calls: u64,
    pub total_secs: f64,
}

/// PJRT CPU runtime with a compile-once executable cache. `Sync`: safe to
/// share across the exec pool's worker threads.
pub struct Runtime {
    client: xla::PjRtClient,
    cache: Mutex<HashMap<String, Arc<LoadedEntry>>>,
    stats: Mutex<HashMap<String, DispatchStats>>,
}

impl Runtime {
    pub fn cpu() -> Result<Runtime> {
        let client = xla::PjRtClient::cpu().context("create PJRT CPU client")?;
        Ok(Runtime {
            client,
            cache: Mutex::new(HashMap::new()),
            stats: Mutex::new(HashMap::new()),
        })
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Load + compile an entrypoint (cached by path). The cache lock is
    /// held across the compile so concurrent workers asking for the same
    /// entry compile it exactly once and the rest wait for the `Arc`.
    pub fn entry(
        &self,
        model_dir: impl AsRef<Path>,
        manifest: &Manifest,
        name: &str,
    ) -> Result<Arc<LoadedEntry>> {
        let spec = manifest.entry(name)?;
        let path: PathBuf = model_dir.as_ref().join(&spec.file);
        let key = path.to_string_lossy().to_string();
        let mut cache = self.cache.lock().unwrap();
        if let Some(e) = cache.get(&key) {
            return Ok(e.clone());
        }
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str().unwrap(),
        )
        .with_context(|| format!("parse HLO text {path:?}"))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .with_context(|| format!("compile {name}"))?;
        let entry = Arc::new(LoadedEntry {
            name: name.to_string(),
            spec: spec.clone(),
            exe,
        });
        cache.insert(key, entry.clone());
        Ok(entry)
    }

    /// Execute an entrypoint: arguments are read from `store` by the
    /// manifest arg names (shape/dtype validated), results are written
    /// back by result names. Returns the scalar results by name (losses,
    /// accuracies) for convenient logging.
    pub fn call(
        &self,
        entry: &LoadedEntry,
        store: &mut Store,
    ) -> Result<HashMap<String, f32>> {
        let t0 = Instant::now();
        let mut lits = Vec::with_capacity(entry.spec.args.len());
        for (name, dt, shape) in &entry.spec.args {
            let t = store
                .get(name)
                .with_context(|| format!("args of {}", entry.name))?;
            validate(name, t, dt, shape)?;
            lits.push(to_literal(t)?);
        }
        let result = entry
            .exe
            .execute::<xla::Literal>(&lits)
            .with_context(|| format!("execute {}", entry.name))?;
        let lit = result[0][0]
            .to_literal_sync()
            .context("fetch result literal")?;
        let outs = lit.to_tuple().context("untuple results")?;
        anyhow::ensure!(
            outs.len() == entry.spec.results.len(),
            "{}: got {} results, manifest says {}",
            entry.name,
            outs.len(),
            entry.spec.results.len()
        );
        let mut scalars = HashMap::new();
        for (out, (name, dt, shape)) in
            outs.into_iter().zip(entry.spec.results.iter())
        {
            let t = from_literal(&out, dt, shape)
                .with_context(|| format!("result {name} of {}", entry.name))?;
            if t.numel() == 1 && t.dtype() == DType::F32 {
                scalars.insert(name.clone(), t.scalar());
            }
            store.insert(name, t);
        }
        let dt = t0.elapsed().as_secs_f64();
        let mut stats = self.stats.lock().unwrap();
        let s = stats.entry(entry.name.clone()).or_default();
        s.calls += 1;
        s.total_secs += dt;
        Ok(scalars)
    }

    pub fn dispatch_stats(&self) -> HashMap<String, DispatchStats> {
        self.stats.lock().unwrap().clone()
    }

    pub fn reset_stats(&self) {
        self.stats.lock().unwrap().clear();
    }
}

fn validate(name: &str, t: &Tensor, dt: &str, shape: &[usize]) -> Result<()> {
    let want = DType::from_str(dt)?;
    anyhow::ensure!(
        t.dtype() == want,
        "arg {name}: dtype {:?}, manifest wants {want:?}",
        t.dtype()
    );
    anyhow::ensure!(
        t.shape == shape,
        "arg {name}: shape {:?}, manifest wants {shape:?}",
        t.shape
    );
    Ok(())
}

fn to_literal(t: &Tensor) -> Result<xla::Literal> {
    let dims: Vec<i64> = t.shape.iter().map(|&d| d as i64).collect();
    let lit = match &t.data {
        Data::F32(v) => xla::Literal::vec1(v),
        Data::I32(v) => xla::Literal::vec1(v),
        Data::U32(v) => xla::Literal::vec1(v),
    };
    Ok(lit.reshape(&dims)?)
}

fn from_literal(lit: &xla::Literal, dt: &str, shape: &[usize]) -> Result<Tensor> {
    let data = match DType::from_str(dt)? {
        DType::F32 => Data::F32(lit.to_vec::<f32>()?),
        DType::I32 => Data::I32(lit.to_vec::<i32>()?),
        DType::U32 => Data::U32(lit.to_vec::<u32>()?),
    };
    let t = Tensor { shape: shape.to_vec(), data };
    anyhow::ensure!(
        t.numel() == lit.element_count(),
        "literal element count {} != manifest shape {:?}",
        lit.element_count(),
        shape
    );
    Ok(t)
}

/// Convenience: a model's artifact directory + manifest + runtime handle.
pub struct ModelRt<'a> {
    pub rt: &'a Runtime,
    pub dir: PathBuf,
    pub manifest: Manifest,
}

impl<'a> ModelRt<'a> {
    pub fn load(
        rt: &'a Runtime,
        artifacts: impl AsRef<Path>,
        model: &str,
    ) -> Result<Self> {
        let dir = artifacts.as_ref().join(model);
        let manifest = Manifest::load(&dir)?;
        Ok(ModelRt { rt, dir, manifest })
    }

    pub fn entry(&self, name: &str) -> Result<Arc<LoadedEntry>> {
        self.rt.entry(&self.dir, &self.manifest, name)
    }

    pub fn call(
        &self,
        name: &str,
        store: &mut Store,
    ) -> Result<HashMap<String, f32>> {
        let e = self.entry(name)?;
        self.rt.call(&e, store)
    }

    /// Load init.bin (FP32 params + BN state + generator init).
    pub fn init_store(&self) -> Result<Store> {
        Store::load(self.dir.join("init.bin"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// The exec pool shares one Runtime across worker threads; keep the
    /// marker bounds enforced at compile time.
    #[test]
    fn runtime_is_send_and_sync() {
        fn check<T: Send + Sync>() {}
        check::<Runtime>();
        check::<LoadedEntry>();
        check::<ModelRt<'static>>();
    }
}

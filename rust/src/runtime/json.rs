//! Minimal JSON parser + serializer (std-only; the offline testbed
//! vendors no serde). The parser supports the full JSON grammar we emit
//! from python/compile/aot.py: objects, arrays, strings (with escapes),
//! numbers, booleans, null. The serializer ([`Json::render`]) is the
//! machine-readable sink for `genie run --json` / `genie grid --json`
//! outcome reports (DESIGN.md §11): object keys render sorted so the
//! output is byte-stable across runs, `Option`-like absences render as
//! `null`, and non-finite numbers degrade to `null` rather than emitting
//! invalid JSON.

use std::collections::HashMap;

use anyhow::{bail, Result};

#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(HashMap<String, Json>),
}

impl Json {
    pub fn parse(text: &str) -> Result<Json> {
        let mut p = Parser { b: text.as_bytes(), i: 0 };
        let v = p.value()?;
        p.ws();
        if p.i != p.b.len() {
            bail!("trailing bytes at {}", p.i);
        }
        Ok(v)
    }

    pub fn get(&self, key: &str) -> Result<&Json> {
        match self {
            Json::Obj(m) => m
                .get(key)
                .ok_or_else(|| anyhow::anyhow!("json: missing key '{key}'")),
            _ => bail!("json: get('{key}') on non-object"),
        }
    }

    pub fn as_str(&self) -> Result<&str> {
        match self {
            Json::Str(s) => Ok(s),
            _ => bail!("json: not a string"),
        }
    }

    pub fn as_usize(&self) -> Result<usize> {
        match self {
            Json::Num(n) => Ok(*n as usize),
            _ => bail!("json: not a number"),
        }
    }

    pub fn as_arr(&self) -> Result<&[Json]> {
        match self {
            Json::Arr(v) => Ok(v),
            _ => bail!("json: not an array"),
        }
    }

    pub fn as_obj(&self) -> Result<&HashMap<String, Json>> {
        match self {
            Json::Obj(m) => Ok(m),
            _ => bail!("json: not an object"),
        }
    }

    pub fn usize_vec(&self) -> Result<Vec<usize>> {
        self.as_arr()?.iter().map(|v| v.as_usize()).collect()
    }

    pub fn str_vec(&self) -> Result<Vec<String>> {
        self.as_arr()?
            .iter()
            .map(|v| Ok(v.as_str()?.to_string()))
            .collect()
    }

    /// Build an object from (key, value) pairs (key order is irrelevant:
    /// [`render`](Json::render) sorts).
    pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
        Json::Obj(
            pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect(),
        )
    }

    /// `f64` value; a non-finite number becomes `null`.
    pub fn num(x: f64) -> Json {
        if x.is_finite() {
            Json::Num(x)
        } else {
            Json::Null
        }
    }

    /// Optional `f64`: `None` → `null` (the satellite contract for
    /// Option-typed outcome fields).
    pub fn opt(x: Option<f64>) -> Json {
        match x {
            Some(v) => Json::num(v),
            None => Json::Null,
        }
    }

    /// Serialize to compact JSON text. Object keys are emitted in sorted
    /// order (the backing map is unordered), so equal values render to
    /// equal bytes.
    pub fn render(&self) -> String {
        let mut out = String::new();
        self.write(&mut out);
        out
    }

    fn write(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(n) => {
                if n.is_finite() {
                    // Display for f64 never uses exponent notation, so
                    // the text is always a valid JSON number
                    out.push_str(&format!("{n}"));
                } else {
                    out.push_str("null");
                }
            }
            Json::Str(s) => write_escaped(s, out),
            Json::Arr(v) => {
                out.push('[');
                for (i, item) in v.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    item.write(out);
                }
                out.push(']');
            }
            Json::Obj(m) => {
                let mut keys: Vec<&String> = m.keys().collect();
                keys.sort();
                out.push('{');
                for (i, k) in keys.into_iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    write_escaped(k, out);
                    out.push(':');
                    m[k].write(out);
                }
                out.push('}');
            }
        }
    }
}

fn write_escaped(s: &str, out: &mut String) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\t' => out.push_str("\\t"),
            '\r' => out.push_str("\\r"),
            c if (c as u32) < 0x20 => {
                out.push_str(&format!("\\u{:04x}", c as u32));
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn ws(&mut self) {
        while self.i < self.b.len()
            && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r')
        {
            self.i += 1;
        }
    }

    fn peek(&self) -> Result<u8> {
        self.b
            .get(self.i)
            .copied()
            .ok_or_else(|| anyhow::anyhow!("json: unexpected end"))
    }

    fn expect(&mut self, c: u8) -> Result<()> {
        if self.peek()? != c {
            bail!("json: expected '{}' at {}", c as char, self.i);
        }
        self.i += 1;
        Ok(())
    }

    fn value(&mut self) -> Result<Json> {
        self.ws();
        match self.peek()? {
            b'{' => self.object(),
            b'[' => self.array(),
            b'"' => Ok(Json::Str(self.string()?)),
            b't' => self.lit("true", Json::Bool(true)),
            b'f' => self.lit("false", Json::Bool(false)),
            b'n' => self.lit("null", Json::Null),
            _ => self.number(),
        }
    }

    fn lit(&mut self, word: &str, v: Json) -> Result<Json> {
        if self.b[self.i..].starts_with(word.as_bytes()) {
            self.i += word.len();
            Ok(v)
        } else {
            bail!("json: bad literal at {}", self.i)
        }
    }

    fn object(&mut self) -> Result<Json> {
        self.expect(b'{')?;
        let mut m = HashMap::new();
        self.ws();
        if self.peek()? == b'}' {
            self.i += 1;
            return Ok(Json::Obj(m));
        }
        loop {
            self.ws();
            let k = self.string()?;
            self.ws();
            self.expect(b':')?;
            let v = self.value()?;
            m.insert(k, v);
            self.ws();
            match self.peek()? {
                b',' => self.i += 1,
                b'}' => {
                    self.i += 1;
                    return Ok(Json::Obj(m));
                }
                c => bail!("json: expected ',' or '}}', got '{}'", c as char),
            }
        }
    }

    fn array(&mut self) -> Result<Json> {
        self.expect(b'[')?;
        let mut v = Vec::new();
        self.ws();
        if self.peek()? == b']' {
            self.i += 1;
            return Ok(Json::Arr(v));
        }
        loop {
            v.push(self.value()?);
            self.ws();
            match self.peek()? {
                b',' => self.i += 1,
                b']' => {
                    self.i += 1;
                    return Ok(Json::Arr(v));
                }
                c => bail!("json: expected ',' or ']', got '{}'", c as char),
            }
        }
    }

    fn string(&mut self) -> Result<String> {
        self.expect(b'"')?;
        let mut s = String::new();
        loop {
            let c = self.peek()?;
            self.i += 1;
            match c {
                b'"' => return Ok(s),
                b'\\' => {
                    let e = self.peek()?;
                    self.i += 1;
                    match e {
                        b'"' => s.push('"'),
                        b'\\' => s.push('\\'),
                        b'/' => s.push('/'),
                        b'n' => s.push('\n'),
                        b't' => s.push('\t'),
                        b'r' => s.push('\r'),
                        b'b' => s.push('\u{8}'),
                        b'f' => s.push('\u{c}'),
                        b'u' => {
                            let hex = std::str::from_utf8(
                                &self.b[self.i..self.i + 4],
                            )?;
                            let code = u32::from_str_radix(hex, 16)?;
                            self.i += 4;
                            s.push(
                                char::from_u32(code).unwrap_or('\u{fffd}'),
                            );
                        }
                        other => bail!("json: bad escape \\{}", other as char),
                    }
                }
                _ => {
                    // collect the full UTF-8 sequence
                    let start = self.i - 1;
                    while self.i < self.b.len()
                        && self.b[self.i] & 0xC0 == 0x80
                    {
                        self.i += 1;
                    }
                    s.push_str(std::str::from_utf8(&self.b[start..self.i])?);
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json> {
        let start = self.i;
        while self.i < self.b.len()
            && matches!(self.b[self.i],
                b'0'..=b'9' | b'-' | b'+' | b'.' | b'e' | b'E')
        {
            self.i += 1;
        }
        let text = std::str::from_utf8(&self.b[start..self.i])?;
        Ok(Json::Num(text.parse::<f64>()?))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_manifest_like_structure() {
        let j = Json::parse(
            r#"{"model": "toy", "batch": {"train": 64},
                "params": [["stem.w", [3, 3, 3, 8]]],
                "bounds": [[32, 16, 16, 3]], "ok": true, "x": null}"#,
        )
        .unwrap();
        assert_eq!(j.get("model").unwrap().as_str().unwrap(), "toy");
        assert_eq!(
            j.get("batch").unwrap().get("train").unwrap().as_usize().unwrap(),
            64
        );
        let p = &j.get("params").unwrap().as_arr().unwrap()[0];
        assert_eq!(p.as_arr().unwrap()[0].as_str().unwrap(), "stem.w");
        assert_eq!(
            p.as_arr().unwrap()[1].usize_vec().unwrap(),
            vec![3, 3, 3, 8]
        );
    }

    #[test]
    fn parses_numbers() {
        assert_eq!(Json::parse("-1.5e2").unwrap(), Json::Num(-150.0));
        assert_eq!(Json::parse("0").unwrap(), Json::Num(0.0));
    }

    #[test]
    fn parses_escapes() {
        let j = Json::parse(r#""a\n\"bA""#).unwrap();
        assert_eq!(j.as_str().unwrap(), "a\n\"bA");
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("12 34").is_err());
    }

    #[test]
    fn empty_containers() {
        assert_eq!(Json::parse("[]").unwrap(), Json::Arr(vec![]));
        assert!(matches!(Json::parse("{}").unwrap(), Json::Obj(_)));
    }

    #[test]
    fn render_sorts_keys_and_round_trips() {
        let j = Json::obj(vec![
            ("zeta", Json::num(1.5)),
            ("alpha", Json::Str("a\"b\n".into())),
            ("mid", Json::Arr(vec![Json::Bool(true), Json::Null])),
        ]);
        let text = j.render();
        assert_eq!(
            text,
            r#"{"alpha":"a\"b\n","mid":[true,null],"zeta":1.5}"#
        );
        assert_eq!(Json::parse(&text).unwrap(), j);
    }

    #[test]
    fn render_options_and_nonfinite_as_null() {
        assert_eq!(Json::opt(None).render(), "null");
        assert_eq!(Json::opt(Some(2.0)).render(), "2");
        assert_eq!(Json::num(f64::NAN).render(), "null");
        assert_eq!(Json::num(f64::INFINITY).render(), "null");
    }

    #[test]
    fn render_is_stable_across_equal_objects() {
        let a = Json::obj(vec![("b", Json::num(1.0)), ("a", Json::num(2.0))]);
        let b = Json::obj(vec![("a", Json::num(2.0)), ("b", Json::num(1.0))]);
        assert_eq!(a.render(), b.render());
    }

    #[test]
    fn render_escapes_control_chars() {
        let j = Json::Str("\u{1}x".into());
        let text = j.render();
        assert_eq!(text, "\"\\u0001x\"");
        assert_eq!(Json::parse(&text).unwrap(), j);
    }
}

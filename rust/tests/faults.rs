//! Fault-tolerance acceptance tests (DESIGN.md §13): deterministic
//! injection through the process-global fault plan, supervised-retry
//! recovery, corrupt-artifact quarantine (property-tested over random
//! byte flips and truncations), and — over the real toy artifacts —
//! grids that complete bit-identically under injected panics, transient
//! errors and artifact corruption, with exhausted cells isolated from
//! their siblings.
//!
//! The fault plan is process-global, so every test that installs one
//! serializes on [`PLAN_GUARD`] and scopes the plan with
//! [`faults::scoped`] (which restores the previous plan — including any
//! `GENIE_FAULTS` environment plan the CI fault job sets — on drop).

use std::path::Path;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Mutex;

use genie::artifacts::{self, ArtifactCache, KeyBuilder};
use genie::coordinator::{Metrics, RunConfig};
use genie::faults::{self, FaultPlan};
use genie::grid::{self, supervise, AxisValue, GridOpts, RunGrid};
use genie::runtime::json::Json;
use genie::runtime::Runtime;
use genie::store::Store;
use genie::tensor::Tensor;
use genie::testutil::forall;

static PLAN_GUARD: Mutex<()> = Mutex::new(());

fn guard() -> std::sync::MutexGuard<'static, ()> {
    PLAN_GUARD.lock().unwrap_or_else(|p| p.into_inner())
}

fn artifacts_dir() -> String {
    Path::new(env!("CARGO_MANIFEST_DIR"))
        .join("artifacts")
        .to_string_lossy()
        .into_owned()
}

fn require_artifacts() -> bool {
    let ok = Path::new(&artifacts_dir()).join("toy/manifest.json").exists();
    if !ok {
        eprintln!("skipping: run `make artifacts` first");
    }
    ok
}

/// Fused steps per dispatch (`GENIE_STEPS_PER_DISPATCH`, default 1):
/// the CI K=8 leg runs the whole fault suite through the megastep path
/// — recovery must be K-oblivious (DESIGN.md §14).
fn env_steps_per_dispatch() -> usize {
    match std::env::var("GENIE_STEPS_PER_DISPATCH") {
        Ok(v) => v
            .parse()
            .expect("GENIE_STEPS_PER_DISPATCH must be an integer"),
        Err(_) => 1,
    }
}

/// Small-budget base config at workers=1, so the order injection sites
/// are reached in is deterministic (results are bit-identical for any
/// worker count either way).
fn base_cfg(cache_dir: &Path) -> RunConfig {
    let mut cfg = RunConfig {
        model: "toy".into(),
        artifacts: artifacts_dir(),
        cache_dir: cache_dir.to_string_lossy().into_owned(),
        ..Default::default()
    };
    cfg.apply_overrides(&[
        "pretrain.steps=30".into(),
        "distill.samples=64".into(),
        "distill.steps=6".into(),
        "quant.steps=8".into(),
        "workers=1".into(),
        format!("steps_per_dispatch={}", env_steps_per_dispatch()),
    ])
    .unwrap();
    // the shared-dir CI leg sets GENIE_CACHE_BACKEND/GENIE_CACHE_SHARED_DIR
    // globally; scope the tier-2 pool under this test's own cache root so a
    // pool warmed by an earlier run never diverges the cold-run cache
    // series the determinism properties compare
    if cfg.cache_backend == "shared-dir" {
        cfg.cache_shared_dir =
            cache_dir.join("pool").to_string_lossy().into_owned();
    }
    cfg
}

#[test]
fn injected_panic_is_recovered_by_supervised_retry() {
    let _g = guard();
    let _s = faults::scoped(
        FaultPlan::parse("distill:shard0:attempt1=panic").unwrap(),
    );
    let mut runs = 0;
    let (r, rep) = supervise("distill", "shard0", 2, 0, || {
        runs += 1;
        Ok(runs)
    });
    assert_eq!(r.unwrap(), 1, "attempt 1 panicked before f ran");
    assert_eq!(rep.attempts, 2);
    assert_eq!(rep.panics, 1);
}

#[test]
fn exhausted_retry_budget_reports_terminal_error() {
    let _g = guard();
    let _s =
        faults::scoped(FaultPlan::parse("quantize:c1:*=err").unwrap());
    let (r, rep) = supervise("quantize", "c1", 3, 0, || Ok(()));
    let msg = format!("{:#}", r.unwrap_err());
    assert!(msg.contains("failed after 3 attempts"), "{msg}");
    assert!(msg.contains("injected transient fault"), "{msg}");
    assert_eq!(rep.attempts, 3);
    assert_eq!(rep.panics, 0);
    // sites the plan does not name are untouched
    let (ok, _) = supervise("quantize", "c0", 1, 0, || Ok(7));
    assert_eq!(ok.unwrap(), 7);
}

#[test]
fn scoped_plan_restores_previous_on_drop() {
    let _g = guard();
    {
        let _s =
            faults::scoped(FaultPlan::parse("x:y:*=err").unwrap());
        assert!(faults::check("x", "y").is_err());
    }
    assert!(faults::check("x", "y").is_ok(), "plan must be restored");
}

/// When the harness sets `GENIE_FAULTS` (the CI fault-injection job),
/// the eager path must accept it and the lazy path must seed a plan;
/// without it, every check point is inert.
#[test]
fn env_plan_seeds_when_present() {
    let _g = guard();
    match std::env::var("GENIE_FAULTS") {
        Ok(text) if !text.trim().is_empty() => {
            faults::init_from_env().expect("CI fault plan must parse");
            assert!(faults::current().is_some());
        }
        _ => {
            let _s = faults::scoped(FaultPlan::empty());
            assert!(faults::check("teacher", "c0").is_ok());
        }
    }
}

/// Property (DESIGN.md §13): whatever byte you flip — or wherever you
/// truncate — in a cached artifact, the next load detects the damage
/// via the content-hash sidecar, quarantines the file, counts a miss,
/// and a recompute + re-store round-trips bit-identically.
#[test]
fn prop_corrupt_artifact_quarantined_then_recomputed_bit_identical() {
    let _g = guard();
    // insulate the cache loads from any environment fault plan
    let _s = faults::scoped(FaultPlan::empty());
    let root = std::env::temp_dir().join("genie_faults_prop_corrupt");
    std::fs::remove_dir_all(&root).ok();
    let case = AtomicUsize::new(0);
    forall(29, 24, |rng| {
        let c = case.fetch_add(1, Ordering::Relaxed);
        let dir = root.join(format!("case{c}"));
        let mut cache = ArtifactCache::open(&dir, true, false).unwrap();
        let key = KeyBuilder::new("distill").field("case", c).finish();

        let n = 8 + rng.below(64);
        let data: Vec<f32> = (0..n).map(|_| rng.normal()).collect();
        let mut s = Store::new();
        s.insert("images", Tensor::from_f32(&[n], data));
        cache.store("distill", key, &s).unwrap();
        let path = cache.path("distill", key);
        let clean = std::fs::read(&path).unwrap();

        // damage the file at a seeded point: flip one byte or truncate
        let mut bytes = clean.clone();
        if rng.below(2) == 0 {
            let off = rng.below(bytes.len());
            bytes[off] ^= 1 + rng.below(255) as u8;
        } else {
            bytes.truncate(rng.below(bytes.len()));
        }
        std::fs::write(&path, &bytes).unwrap();
        // the damage was done behind the cache's back, so drop the
        // tier-0 copy too — this property is about *disk* verification
        artifacts::clear_hot(&dir);

        let before = cache.stats().clone();
        assert!(
            cache.load("distill", key).is_none(),
            "corrupt load must miss"
        );
        let st = cache.stats();
        assert_eq!(st.misses, before.misses + 1, "counted as a miss");
        assert_eq!(st.quarantined, before.quarantined + 1);
        assert_eq!(st.hits, before.hits, "never served corrupt bytes");
        assert!(!path.exists(), "bad file must be moved out of the way");
        assert!(
            cache
                .quarantine_dir()
                .join(path.file_name().unwrap())
                .exists(),
            "bad file must land in quarantine/"
        );

        // recompute (same deterministic inputs) and re-store
        cache.store("distill", key, &s).unwrap();
        assert_eq!(
            std::fs::read(&path).unwrap(),
            clean,
            "recomputed artifact must be bit-identical"
        );
        let loaded = cache.load("distill", key).unwrap();
        assert_eq!(
            loaded.get("images").unwrap(),
            s.get("images").unwrap()
        );
    });
    std::fs::remove_dir_all(&root).ok();
}

fn bits_seed_grid() -> RunGrid {
    RunGrid::new()
        .axis("bits", vec![AxisValue::Bits(4, 4), AxisValue::Bits(2, 4)])
        .axis("seed", vec![AxisValue::Seed(1234), AxisValue::Seed(99)])
}

fn assert_cells_match(
    a: &grid::GridOutcome,
    b: &grid::GridOutcome,
    what: &str,
) {
    assert_eq!(a.cells.len(), b.cells.len());
    for (ca, cb) in a.cells.iter().zip(&b.cells) {
        let (oa, ob) = (
            ca.outcome.as_ref().unwrap(),
            cb.outcome.as_ref().unwrap(),
        );
        assert_eq!(
            oa.fp_acc,
            ob.fp_acc,
            "{what}: cell {} FP32 acc diverged",
            ca.spec.label()
        );
        assert_eq!(
            oa.q_acc,
            ob.q_acc,
            "{what}: cell {} quant acc diverged",
            ca.spec.label()
        );
        let (qa, qb) =
            (ca.qstate.as_ref().unwrap(), cb.qstate.as_ref().unwrap());
        assert_eq!(qa.names(), qb.names());
        for name in qa.names() {
            assert_eq!(
                qa.get(name).unwrap(),
                qb.get(name).unwrap(),
                "{what}: cell {} qstate '{name}' diverged",
                ca.spec.label()
            );
        }
    }
}

/// Acceptance (DESIGN.md §13): a 2×2 grid with an injected distill-shard
/// panic (contained by the inner pool), a supervise-level quantize panic,
/// a transient quantize error, and — on a second pass over the warm
/// cache — a corrupted cached artifact, completes every cell with
/// accuracies and qstates bit-identical to the fault-free grid.
#[test]
fn grid_completes_bit_identical_under_injected_faults() {
    if !require_artifacts() {
        return;
    }
    let _g = guard();
    let rt = Runtime::cpu().unwrap();
    let root = std::env::temp_dir().join("genie_faults_grid");
    std::fs::remove_dir_all(&root).ok();
    let opts = GridOpts { keep_qstate: true, ..Default::default() };

    // fault-free reference
    let reference = {
        let _s = faults::scoped(FaultPlan::empty());
        let cfg = base_cfg(&root.join("ref"));
        let mut m = Metrics::new();
        grid::execute(&rt, &cfg, &bits_seed_grid(), &opts, &mut m)
            .unwrap()
    };
    assert!(reference.all_ok());

    // cold cache + panic at a distill shard, panic at one quantize
    // node, transient error at another: every fault recovered by retry
    let faulted = {
        let _s = faults::scoped(
            FaultPlan::parse(
                "distill:shard0:attempt1=panic,\
                 quantize:c0:attempt1=err,\
                 quantize:c1:attempt1=panic",
            )
            .unwrap(),
        );
        let cfg = base_cfg(&root.join("faulted"));
        let mut m = Metrics::new();
        grid::execute(&rt, &cfg, &bits_seed_grid(), &opts, &mut m)
            .unwrap()
    };
    assert!(faulted.all_ok(), "retries must absorb every fault");
    assert!(faulted.stats.retries >= 3, "{:?}", faulted.stats);
    assert!(
        faulted.stats.panics >= 1,
        "the quantize panic is caught at the supervise level: {:?}",
        faulted.stats
    );
    assert_eq!(faulted.stats.failed_nodes, 0);
    assert_cells_match(&reference, &faulted, "faulted");

    // warm reference cache + one corrupted teacher artifact: the load
    // quarantines it, the stage recomputes, the results do not move
    let corrupted = {
        let _s = faults::scoped(
            FaultPlan::parse("artifact:corrupt:teacher").unwrap(),
        );
        let cfg = base_cfg(&root.join("ref"));
        let mut m = Metrics::new();
        grid::execute(&rt, &cfg, &bits_seed_grid(), &opts, &mut m)
            .unwrap()
    };
    assert!(corrupted.all_ok());
    assert_eq!(
        corrupted.stats.cache.quarantined,
        1,
        "{:?}",
        corrupted.stats.cache
    );
    assert_cells_match(&reference, &corrupted, "corrupted");

    std::fs::remove_dir_all(&root).ok();
}

/// Zero every timing field in a grid report: object values under a key
/// ending `_secs` or named `utilization` become `0` (nulls stay null —
/// whether a stage ran at all is part of the contract being compared).
fn scrub_timings(j: &mut Json) {
    match j {
        Json::Obj(m) => {
            for (k, v) in m.iter_mut() {
                if k.ends_with("_secs") || k == "utilization" {
                    if let Json::Num(n) = v {
                        *n = 0.0;
                    }
                } else {
                    scrub_timings(v);
                }
            }
        }
        Json::Arr(v) => v.iter_mut().for_each(scrub_timings),
        _ => {}
    }
}

fn normalized_report(out: &grid::GridOutcome) -> String {
    let mut j = out.to_json();
    scrub_timings(&mut j);
    j.render()
}

/// Every metric series that is a function of the computation rather
/// than of the clock: pool accounting (`pool/`), scheduler telemetry
/// (`sched/`) and throughput rates (`*_per_sec`) are dropped, the rest
/// must be byte-identical across schedulers and worker counts.
fn det_series(m: &Metrics) -> Vec<(String, Vec<(usize, f32)>)> {
    m.series_iter()
        .filter(|(n, _)| {
            !n.contains("pool/")
                && !n.contains("sched/")
                && !n.ends_with("_per_sec")
        })
        .map(|(n, rows)| (n.to_string(), rows.to_vec()))
        .collect()
}

fn run_grid_sched(
    rt: &Runtime,
    root: &Path,
    sched: &str,
    workers: usize,
    plan: FaultPlan,
) -> (grid::GridOutcome, Metrics) {
    let _s = faults::scoped(plan);
    // a fresh cache dir per run: cache hit/miss series are part of the
    // deterministic metrics being compared, so every run must be cold
    let mut cfg = base_cfg(&root.join(format!("{sched}-w{workers}")));
    cfg.apply_overrides(&[
        format!("sched={sched}"),
        format!("workers={workers}"),
    ])
    .unwrap();
    let mut m = Metrics::new();
    let opts = GridOpts { keep_qstate: true, ..Default::default() };
    let out =
        grid::execute(rt, &cfg, &bits_seed_grid(), &opts, &mut m).unwrap();
    (out, m)
}

/// Property (DESIGN.md §15): the dataflow scheduler is an execution-
/// order optimization only. Injected per-node `sleep` faults force
/// adversarial completion orders (late-submitted nodes finish first);
/// the grid report with timing fields zeroed, every cell outcome and
/// qstate tensor, and every clock-independent metric series must be
/// byte-identical to the wave scheduler at workers=1, for both
/// schedulers at workers 1 and 4.
#[test]
fn prop_dataflow_matches_wave_bit_identical_under_delays() {
    if !require_artifacts() {
        return;
    }
    let _g = guard();
    let rt = Runtime::cpu().unwrap();
    let root = std::env::temp_dir().join("genie_sched_equiv");
    std::fs::remove_dir_all(&root).ok();

    let (ref_out, ref_m) =
        run_grid_sched(&rt, &root, "wave", 1, FaultPlan::empty());
    assert!(ref_out.all_ok());
    let ref_json = normalized_report(&ref_out);
    let ref_series = det_series(&ref_m);

    // delay plans chosen to invert the submission order at the finish
    // line: early cells sleep longest, so under dataflow their
    // dependents complete after later-submitted siblings
    let cases = [
        ("wave", 4, ""),
        ("dataflow", 1, "quantize:c0:*=sleep120,quantize:c2:*=sleep60"),
        ("dataflow", 4, "quantize:c0:*=sleep120,quantize:c2:*=sleep60"),
        ("dataflow", 4, "quantize:c3:*=sleep100,distill:shard0:*=sleep80"),
    ];
    for (i, (sched, workers, plan)) in cases.iter().enumerate() {
        let plan = if plan.is_empty() {
            FaultPlan::empty()
        } else {
            FaultPlan::parse(plan).unwrap()
        };
        let root = root.join(format!("case{i}"));
        let (out, m) = run_grid_sched(&rt, &root, sched, *workers, plan);
        let what = format!("case {i}: {sched} workers={workers}");
        assert!(out.all_ok(), "{what}: grid must complete");
        assert_cells_match(&ref_out, &out, &what);
        assert_eq!(
            ref_json,
            normalized_report(&out),
            "{what}: report diverged"
        );
        assert_eq!(
            ref_series,
            det_series(&m),
            "{what}: metrics diverged"
        );
    }

    std::fs::remove_dir_all(&root).ok();
}

/// Acceptance (DESIGN.md §13): a cell whose quantize stage exhausts the
/// retry budget is reported non-ok (failed at quantize, its eval
/// skipped) while its sibling completes normally, the executor returns
/// `Ok`, and the `--json` report carries both statuses.
#[test]
fn exhausted_cell_is_isolated_from_siblings() {
    if !require_artifacts() {
        return;
    }
    let _g = guard();
    let rt = Runtime::cpu().unwrap();
    let root = std::env::temp_dir().join("genie_faults_isolation");
    std::fs::remove_dir_all(&root).ok();

    let _s =
        faults::scoped(FaultPlan::parse("quantize:c1:*=err").unwrap());
    let cfg = base_cfg(&root);
    let grid2 = RunGrid::new().axis(
        "bits",
        vec![AxisValue::Bits(4, 4), AxisValue::Bits(2, 4)],
    );
    let mut m = Metrics::new();
    let out = grid::execute(
        &rt, &cfg, &grid2, &GridOpts::default(), &mut m,
    )
    .unwrap();

    assert_eq!(out.cells.len(), 2);
    let good = &out.cells[0];
    assert!(good.status.is_ok(), "{:?}", good.status);
    assert!(good.outcome.is_some(), "sibling must complete normally");

    let bad = &out.cells[1];
    assert!(!bad.status.is_ok(), "exhausted cell must not be ok");
    assert_eq!(bad.status.as_str(), "failed");
    assert!(
        bad.status.describe().unwrap().contains("quantize"),
        "{:?}",
        bad.status
    );
    assert!(bad.outcome.is_none());

    assert!(!out.all_ok());
    assert_eq!(out.stats.failed_nodes, 1, "{:?}", out.stats);
    assert!(
        out.stats.skipped_nodes >= 1,
        "the failed cell's quantized eval must be skipped: {:?}",
        out.stats
    );
    assert!(out.stats.retries >= 1, "{:?}", out.stats);

    let text = out.to_json().render();
    assert!(text.contains("\"status\":\"ok\""), "{text}");
    assert!(text.contains("\"status\":\"failed\""), "{text}");
    assert!(
        genie::runtime::json::Json::parse(&text).is_ok(),
        "report must stay machine-readable with failed cells"
    );

    std::fs::remove_dir_all(&root).ok();
}
